"""BlockAMC-preconditioned optimizer: the paper's solver inside training.

The paper positions AMC as a linear-system accelerator; its natural home in
an LM training stack is the second-order preconditioner (cf. RePAST, the
paper's ref [30]: a ReRAM in-memory accelerator for second-order training).
We maintain a Kronecker-factored Gram matrix G = E[g g^T] over each 2-D
parameter's output dimension and precondition Shampoo-style with the
inverse root

    p = g (G + lambda I)^-1/2

computed by the Denman-Beavers iteration

    Y_0 = A, Z_0 = I;  Y_{k+1} = (Y_k + Z_k^-1)/2, Z_{k+1} = (Z_k + Y_k^-1)/2
    Y_k -> A^1/2, Z_k -> A^-1/2

whose core primitive is *matrix inversion* - each step's two inverses run
through `distributed.block_inv`, the digital BlockAMC recursion (GEMM-only,
mesh-shardable, exactly Algorithm 1's divide-and-conquer identity).
Optionally those inverses can be routed through the *analog* simulator
(`use_analog=True`), modelling an AMC accelerator attached to the optimizer
with the paper's non-idealities + digital refinement (repro.hybrid: one
batched analog-preconditioned CG over all identity columns).

This is a lightweight Shampoo-class method: refreshed inverses every
`update_every` steps, preconditioning only dims <= max_dim.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.core import blockamc
from repro.core.analog import AnalogConfig
from repro.core.distributed import block_inv
from repro.hybrid import AnalogPreconditioner, matvec_from_dense, pcg_fixed


class PrecondState(NamedTuple):
    gram: Any        # per-leaf (d, d) or None placeholder
    inv: Any         # cached inverse factors
    step: jnp.ndarray


@dataclasses.dataclass(frozen=True)
class BlockAMCPrecond:
    beta: float = 0.95
    damping: float = 1e-3
    update_every: int = 20
    leaf_size: int = 64         # BlockAMC array size for the recursion
    max_dim: int = 2048         # precondition only if output dim <= this
    use_analog: bool = False    # route solves through the analog simulator
    analog_cfg: AnalogConfig = AnalogConfig(array_size=64)
    refine_iters: int = 4       # digital refinement after an analog seed
    analog_precond: bool = False  # also precondition CG with the programmed
    # arrays: faster under near-ideal programming, but a noisy analog
    # inverse can leave the SPD cone and make the fixed refine_iters budget
    # *worse* than seed-only CG (TESTING.md regime map) - so opt-in.
    db_iters: int = 14          # Denman-Beavers iterations for the inv-root

    def _eligible(self, p) -> bool:
        return p.ndim == 2 and p.shape[1] <= self.max_dim

    def init(self, params) -> PrecondState:
        def gram(p):
            if not self._eligible(p):
                return jnp.zeros((0,))
            d = p.shape[1]
            return jnp.eye(d, dtype=jnp.float32) * self.damping

        def inv(p):
            if not self._eligible(p):
                return jnp.zeros((0,))
            d = p.shape[1]
            return jnp.eye(d, dtype=jnp.float32) / self.damping

        return PrecondState(gram=jax.tree.map(gram, params),
                            inv=jax.tree.map(inv, params),
                            step=jnp.zeros((), jnp.int32))

    def update_stats(self, grads, state: PrecondState) -> PrecondState:
        def one(g, gr):
            if gr.size == 0:
                return gr
            g32 = g.astype(jnp.float32)
            new = (g32.T @ g32) / g.shape[0]
            return self.beta * gr + (1 - self.beta) * new

        gram = jax.tree.map(one, grads, state.gram)
        return state._replace(gram=gram, step=state.step + 1)

    def _inv(self, a: jnp.ndarray, key) -> jnp.ndarray:
        """One matrix inverse - the BlockAMC primitive (digital or analog)."""
        if not self.use_analog:
            return block_inv(a, self.leaf_size)
        # analog path: program the matrix once, then run one batched CG over
        # all n identity columns (leading-axis multi-RHS) seeded by the
        # fused analog solve; analog_precond=True additionally applies the
        # programmed cascade inside the iteration.  `pcg_fixed` spends
        # exactly refine_iters iterations per column - the fixed digital
        # budget - and, being a `lax.scan`, keeps this whole preconditioner
        # reverse-mode differentiable (pcg's while_loop is not).
        solver = blockamc.ProgrammedSolver.program(a, key, self.analog_cfg)
        precond = AnalogPreconditioner.from_solver(solver)
        eye = jnp.eye(a.shape[0], dtype=jnp.float32)
        res = pcg_fixed(matvec_from_dense(a), eye,
                        precond=precond if self.analog_precond else None,
                        x0=precond(eye), iters=self.refine_iters)
        return res.x.T    # row i solves A x = e_i -> column i of A^-1

    def _invert(self, gram: jnp.ndarray, key) -> jnp.ndarray:
        """(G + lambda I)^-1/2 via Denman-Beavers (inverse-only iteration)."""
        d = gram.shape[0]
        a = gram + self.damping * jnp.eye(d, dtype=jnp.float32)
        # scale to unit spectral-ish norm for DB convergence
        c = jnp.trace(a) / d
        y = a / c
        z = jnp.eye(d, dtype=jnp.float32)
        for i in range(self.db_iters):
            ki = jax.random.fold_in(key, i)
            y_inv = self._inv(y, ki)
            z_inv = self._inv(z, jax.random.fold_in(ki, 1))
            y, z = 0.5 * (y + z_inv), 0.5 * (z + y_inv)
        return z / jnp.sqrt(c)       # -> (A/c)^-1/2 / sqrt(c) = A^-1/2

    def refresh_inverses(self, state: PrecondState,
                         key=None) -> PrecondState:
        key = key if key is not None else jax.random.PRNGKey(0)

        def one(gr, old_inv):
            if gr.size == 0:
                return old_inv
            return self._invert(gr, key)

        return state._replace(inv=jax.tree.map(one, state.gram, state.inv))

    def precondition(self, grads, state: PrecondState):
        def one(g, inv):
            if inv.size == 0:
                return g
            return (g.astype(jnp.float32) @ inv).astype(g.dtype)

        return jax.tree.map(one, grads, state.inv)

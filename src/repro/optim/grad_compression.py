"""Cross-pod gradient compression: int8 quantisation + error feedback.

At multi-pod scale the pod-to-pod links are the slow hop, so gradients are
reduced hierarchically: full-precision `psum` *within* a pod (fast ICI),
int8-compressed `psum` *across* pods (slow DCN/optical), with per-tensor
scales and an error-feedback residual so compression noise is unbiased over
time (Seide et al. 1-bit SGD lineage).

`compressed_psum` is written against an explicit mesh axis name and used
inside shard_map over the "pod" axis; within-pod reduction happens in the
enclosing pjit program as usual.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp


def quantize_int8(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Symmetric per-tensor int8: returns (q, scale)."""
    amax = jnp.max(jnp.abs(x)) + 1e-12
    scale = amax / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def compressed_psum(tree: Any, axis_name: str, error_state: Any
                    ) -> Tuple[Any, Any]:
    """psum each leaf across `axis_name` after int8 compression.

    error_state: pytree like `tree` holding the error-feedback residual.
    Returns (reduced_tree_f32, new_error_state).
    """

    def one(g, err):
        g32 = g.astype(jnp.float32) + err
        q, scale = quantize_int8(g32)
        deq = dequantize_int8(q, scale)
        new_err = g32 - deq
        # int8 payload summed across pods (bandwidth = 1/4 of f32);
        # scales are tiny and psum'd in f32.
        total = jax.lax.psum(deq, axis_name)
        return total, new_err

    flat, treedef = jax.tree.flatten(tree)
    flat_err = treedef.flatten_up_to(error_state)
    out = [one(g, e) for g, e in zip(flat, flat_err)]
    return (treedef.unflatten([o[0] for o in out]),
            treedef.unflatten([o[1] for o in out]))


def init_error_state(tree: Any) -> Any:
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), tree)

"""AdamW in plain JAX, with configurable moment dtype (bf16 moments let the
400B MoE config fit 16 GB/chip HBM; see DESIGN.md memory budget)."""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp


class OptState(NamedTuple):
    step: jnp.ndarray
    m: Any
    v: Any


@dataclasses.dataclass(frozen=True)
class AdamW:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    moments_dtype: Any = jnp.float32

    def init(self, params) -> OptState:
        zeros = lambda p: jnp.zeros(p.shape, dtype=self.moments_dtype)
        return OptState(step=jnp.zeros((), jnp.int32),
                        m=jax.tree.map(zeros, params),
                        v=jax.tree.map(zeros, params))

    def update(self, grads, state: OptState, params,
               lr_scale: jnp.ndarray | float = 1.0
               ) -> Tuple[Any, OptState]:
        step = state.step + 1
        b1, b2 = self.b1, self.b2
        bc1 = 1.0 - b1 ** step.astype(jnp.float32)
        bc2 = 1.0 - b2 ** step.astype(jnp.float32)
        lr = self.lr * lr_scale

        def upd(g, m, v, p):
            g32 = g.astype(jnp.float32)
            m32 = b1 * m.astype(jnp.float32) + (1 - b1) * g32
            v32 = b2 * v.astype(jnp.float32) + (1 - b2) * jnp.square(g32)
            mh = m32 / bc1
            vh = v32 / bc2
            delta = mh / (jnp.sqrt(vh) + self.eps)
            if p.ndim >= 2:   # decay matrices only (standard practice)
                delta = delta + self.weight_decay * p.astype(jnp.float32)
            new_p = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
            return new_p, m32.astype(self.moments_dtype), v32.astype(self.moments_dtype)

        flat_p, treedef = jax.tree.flatten(params)
        flat_g = treedef.flatten_up_to(grads)
        flat_m = treedef.flatten_up_to(state.m)
        flat_v = treedef.flatten_up_to(state.v)
        out = [upd(g, m, v, p)
               for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
        new_p = treedef.unflatten([o[0] for o in out])
        new_m = treedef.unflatten([o[1] for o in out])
        new_v = treedef.unflatten([o[2] for o in out])
        return new_p, OptState(step=step, m=new_m, v=new_v)

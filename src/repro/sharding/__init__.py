from repro.sharding.api import (  # noqa: F401
    ShardingPolicy, set_policy, current_policy, shard, clear_policy)

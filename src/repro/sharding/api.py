"""Activation-sharding policy: a scoped registry of named PartitionSpecs.

Model code annotates tensors by *logical* name (`shard(x, "act_btd")`); the
launcher installs a policy binding those names to PartitionSpecs on the
active mesh.  With no policy installed every call is a no-op, so unit tests
and single-host runs never touch device APIs.
"""
from __future__ import annotations

import contextlib
import dataclasses
from typing import Dict, Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec


@dataclasses.dataclass
class ShardingPolicy:
    mesh: Mesh
    rules: Dict[str, PartitionSpec]
    meta: Dict[str, int] = dataclasses.field(default_factory=dict)


_ACTIVE: Optional[ShardingPolicy] = None


def set_policy(policy: Optional[ShardingPolicy]) -> None:
    global _ACTIVE
    _ACTIVE = policy


def clear_policy() -> None:
    set_policy(None)


def current_policy() -> Optional[ShardingPolicy]:
    return _ACTIVE


@contextlib.contextmanager
def policy_scope(policy: ShardingPolicy):
    prev = current_policy()
    set_policy(policy)
    try:
        yield
    finally:
        set_policy(prev)


def shard(x: jax.Array, name: str) -> jax.Array:
    """Constrain x to the active policy's spec for `name` (no-op if unbound)."""
    pol = _ACTIVE
    if pol is None:
        return x
    spec = pol.rules.get(name)
    if spec is None:
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(pol.mesh, spec))


def get_meta(name: str, default: int = 1) -> int:
    """Trace-time integer hints from the active policy (e.g. dp_groups)."""
    pol = _ACTIVE
    if pol is None:
        return default
    return pol.meta.get(name, default)

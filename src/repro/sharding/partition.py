"""Parameter partitioning rules: path-pattern -> PartitionSpec.

Strategy (DESIGN.md Section 5):
  * TP over "model": projection output features, expert axis (EP), vocab.
  * Optional FSDP/ZeRO over "data": the other large dim of each matrix
    (enabled for >=30B configs; moments/params shards congruent).
  * DP across "pod" (multi-pod): replicated params, batch-sharded acts.
Every rule checks divisibility against the actual mesh axis sizes and falls
back to replication rather than producing an invalid sharding.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, RunConfig


def _axis_size(mesh: Mesh, name: Optional[str]) -> int:
    if name is None:
        return 1
    return mesh.shape[name]


def _ok(dim: int, mesh: Mesh, axis) -> bool:
    if axis is None:
        return True
    if isinstance(axis, tuple):
        size = int(np.prod([_axis_size(mesh, a) for a in axis]))
    else:
        size = _axis_size(mesh, axis)
    return dim % size == 0


def _spec(shape, mesh: Mesh, *axes):
    """Build a PartitionSpec, dropping any axis that doesn't divide."""
    cleaned = []
    for dim, ax in zip(shape, axes):
        cleaned.append(ax if _ok(dim, mesh, ax) else None)
    # trailing axes unspecified = replicated
    return P(*cleaned)


def param_spec(path: str, shape: Tuple[int, ...], mesh: Mesh,
               fsdp: bool) -> P:
    """Sharding rule for one parameter leaf (path uses '/' separators).

    Stacked (scan) params carry a leading layer dim -> leading None.
    """
    dp = "data" if fsdp else None
    lead: Tuple = ()
    if "blocks/" in path:                # scanned stack: (L, ...)
        lead = (None,)
        shape = shape[1:]

    leaf = path.split("/")[-1]

    if leaf in ("embed",):               # (V, d)
        return _spec(lead + shape, mesh, *lead, "model", dp)
    if leaf in ("head",):                # (d, V)
        return _spec(lead + shape, mesh, *lead, dp, "model")
    if leaf in ("wq", "wk", "wv", "w_y", "w_u", "w_a", "w_x", "in_proj"):
        return _spec(lead + shape, mesh, *lead, dp, "model")
    if leaf in ("wo", "w_o", "out_proj"):
        return _spec(lead + shape, mesh, *lead, "model", dp)
    if leaf in ("gate", "up", "down"):
        if len(shape) == 3:              # MoE experts: (E, d, f)
            return _spec(lead + shape, mesh, *lead, "model", dp, None)
        if leaf == "down":               # (f, d)
            return _spec(lead + shape, mesh, *lead, "model", dp)
        return _spec(lead + shape, mesh, *lead, dp, "model")
    if leaf == "router":                 # (d, E): replicate E (small)
        return _spec(lead + shape, mesh, *lead, dp, None)
    if leaf == "conv_w" or shape == ():
        return P()
    if len(shape) == 1:                  # norms, biases, scalars
        return _spec(lead + shape, mesh, *lead, None)
    # default 2D: shard last dim on model if divisible
    return _spec(lead + shape, mesh, *lead, dp, "model")


def _path_str(path) -> str:
    return "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                    for p in path)


def make_param_shardings(params_shape, mesh: Mesh, fsdp: bool):
    """Pytree of NamedShardings for an eval_shape'd params tree."""

    def one(path, leaf):
        spec = param_spec(_path_str(path), leaf.shape, mesh, fsdp)
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map_with_path(one, params_shape)


def make_state_shardings(state_shape, mesh: Mesh, fsdp: bool):
    """TrainState shardings: moments follow their parameters; step replicated."""

    def one(path, leaf):
        p = _path_str(path)
        if leaf.ndim == 0:
            return NamedSharding(mesh, P())
        # strip the TrainState prefix (params/..., opt/m/..., opt/v/...)
        parts = p.split("/")
        if parts[0] == "params":
            core = "/".join(parts[1:])
        elif parts[0] == "opt" and parts[1] in ("m", "v"):
            core = "/".join(parts[2:])
        else:
            core = p
        spec = param_spec(core, leaf.shape, mesh, fsdp)
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map_with_path(one, state_shape)


# ---------------------------------------------------------------------------
# Activation rules + batch sharding
# ---------------------------------------------------------------------------

def activation_rules(mesh: Mesh, model_cfg: ModelConfig,
                     run_cfg: RunConfig) -> Dict[str, P]:
    """Named activation constraints consumed by sharding.api.shard().

    Divisibility-aware: head-sharded attention ("act_bshd") when n_heads
    divides the model axis, otherwise context-parallel k/v (sequence dim on
    "model"); MoE dispatch buffers expert-sharded (EP) when E divides.
    """
    batch_axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
    batch: Any = batch_axes if len(batch_axes) > 1 else (
        batch_axes[0] if batch_axes else None)
    gb = run_cfg.global_batch
    bsize = int(np.prod([mesh.shape[a] for a in (batch_axes or ())]))
    if gb % max(bsize, 1):
        batch = "data" if gb % dict(mesh.shape).get("data", 1) == 0 else None
    tp = mesh.shape["model"] if "model" in mesh.shape else 1

    seq_ax = None
    if (run_cfg.seq_shard and run_cfg.mode == "train"
            and run_cfg.seq_len % max(tp, 1) == 0):
        seq_ax = "model"      # Megatron-SP: LN/residual segments S-sharded
    rules = {
        "act_btd": P(batch, seq_ax, None),
        "act_btv": P(batch, None, "model"),
    }
    if model_cfg.n_heads and model_cfg.n_heads % tp == 0:
        rules["act_q"] = P(batch, None, "model", None)
        rules["act_kv"] = P(batch, None, "model", None)
    else:
        # context parallelism: shard the sequence axis of k/v; q replicated
        # along heads (softmax/psum over the sharded kv axis is GSPMD's job)
        rules["act_q"] = P(batch, None, None, None)
        rules["act_kv"] = P(batch, "model", None, None)
    # decode KV cache: batch on data, sequence on model (flash-decode layout)
    rules["act_cache"] = P(batch, "model", None, None)
    if model_cfg.n_experts and model_cfg.n_experts % tp == 0:
        rules["act_ecd"] = P("model", None, None)
        # group-local MoE dispatch buffer (g, E, C, d): groups on the batch
        # axes, experts on the TP axis -> the EP all_to_all boundary.
        rules["act_gecd"] = P(batch, "model", None, None)
    return rules


def dp_group_count(mesh: Mesh, model_cfg: ModelConfig,
                   run_cfg: RunConfig) -> int:
    """Number of data-parallel groups for group-local MoE dispatch."""
    rules = activation_rules(mesh, model_cfg, run_cfg)
    batch = rules["act_btd"][0]
    if batch is None:
        return 1
    axes = batch if isinstance(batch, tuple) else (batch,)
    return int(np.prod([dict(mesh.shape)[a] for a in axes]))


def make_policy(mesh: Mesh, model_cfg: ModelConfig, run_cfg: RunConfig):
    """ShardingPolicy with activation rules + trace-time meta hints."""
    from repro.sharding.api import ShardingPolicy
    return ShardingPolicy(
        mesh=mesh,
        rules=activation_rules(mesh, model_cfg, run_cfg),
        meta={"dp_groups": dp_group_count(mesh, model_cfg, run_cfg)})


def batch_sharding(mesh: Mesh, model_cfg: ModelConfig,
                   run_cfg: RunConfig) -> NamedSharding:
    rules = activation_rules(mesh, model_cfg, run_cfg)
    return NamedSharding(mesh, rules["act_btd"])


# ---------------------------------------------------------------------------
# Monte-Carlo solver sharding (blockamc.solve_batched_sharded)
# ---------------------------------------------------------------------------

def mc_solve_specs(axis_name: str = "mc"):
    """shard_map specs for a Monte-Carlo BlockAMC sweep.

    The partitioned system and right-hand sides are replicated on every
    device; only the noise-key axis is sharded, so each device programs and
    solves its own independent draws.  Returns (in_specs, out_specs) for
    `(partitioned_system, b, keys) -> solutions`.
    """
    return (P(), P(), P(axis_name)), P(axis_name)


def mc_packed_specs(pp, axis_name: str = "mc"):
    """shard_map specs for a packed multi-tenant arena execution.

    `(packed_plan, bs) -> xs`: every instance-carrying leaf of the
    `PackedArenaPlan` (operator stacks, scales, per-instance whole-schedule
    operator sequence) and the (M, n, k) rhs stack shard their leading
    instance axis over `axis_name`; the shared window-program metadata
    (identical across instances by the signature-stackability invariant)
    is replicated.  The spec tree mirrors the plan's pytree structure, so
    it must be built from the concrete plan being dispatched.
    """
    inst, rep = P(axis_name), P()
    children, aux = pp.tree_flatten()
    stacks, scale, program_ops, program_meta = children
    spec_children = (
        tuple(inst for _ in stacks),
        inst,
        None if program_ops is None else inst,
        None if program_meta is None else tuple(rep for _ in program_meta),
    )
    plan_spec = type(pp).tree_unflatten(aux, spec_children)
    return (plan_spec, P(axis_name)), P(axis_name)


def mc_refined_specs(axis_name: str = "mc"):
    """shard_map specs for a Monte-Carlo *hybrid refined* solve.

    Same discipline as `mc_solve_specs` with the dense digital matrix along
    for the ride: `(a, partitioned_system, b, keys) -> KrylovResult`.  The
    matrix, pre-processing and right-hand sides are replicated; each device
    programs and refines its own shard of noisy preconditioners, and every
    field of the per-key KrylovResult comes back sharded on the key axis.
    """
    return (P(), P(), P(), P(axis_name)), P(axis_name)

"""Pure-jnp oracles for every Pallas kernel (the correctness ground truth)."""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.quantization import quantize as _quantize


def crossbar_mvm_ref(v, gpos, gneg, *, g0, dac_bits=None, adc_bits=None,
                     fullscale=1.0):
    """out[b, r] = -ADC(sum_c (gpos - gneg)[r, c] * DAC(v[b, c]) / g0)."""
    vq = _quantize(v.astype(jnp.float32), dac_bits, fullscale)
    g = (gpos - gneg).astype(jnp.float32)
    out = -(vq @ g.T) / g0
    return _quantize(out, adc_bits, fullscale)


def arena_level_ref(arena, ops, in_offs, in_signs, out_offs, out_init, *,
                    dac_bits=None, adc_bits=None, fullscale=1.0):
    """Oracle for the arena level-megakernel (kernels/arena_mvm.py).

    Sequential tile loop over one level group: signed whole-window gather,
    operator apply, init-or-accumulate into the output window.
    """
    arena = arena.astype(jnp.float32)
    l, rows, cols = ops.shape
    for t in range(l):
        v = jnp.zeros((cols, arena.shape[1]), jnp.float32)
        for j in range(in_offs.shape[1]):
            off = int(in_offs[t, j])
            v = v + in_signs[t, j] * arena[off:off + cols]
        v = _quantize(v, dac_bits, fullscale)
        out = _quantize(ops[t].astype(jnp.float32) @ v, adc_bits, fullscale)
        o = int(out_offs[t])
        tgt = arena.at[o:o + rows]
        arena = tgt.set(out) if int(out_init[t]) else tgt.add(out)
    return arena


def arena_packed_ref(arena, ops, in_offs, in_signs, out_offs, out_init, *,
                     dac_bits=None, adc_bits=None, fullscale=1.0):
    """Oracle for the instance-packed megakernel (kernels/arena_mvm.py).

    Each packed instance replays the shared tile program on its own arena
    with its own operator sequence - M independent `arena_level_ref` runs.
    """
    return jnp.stack([
        arena_level_ref(arena[i], ops[i], in_offs, in_signs, out_offs,
                        out_init, dac_bits=dac_bits, adc_bits=adc_bits,
                        fullscale=fullscale)
        for i in range(arena.shape[0])])


def block_tridiag_solve_ref(minv, rhs, *, gw):
    """Oracle for the batched block-Thomas sweeps (kernels/banded_solve.py).

    Python loop over the block row axis; batch axis vectorized.
    minv: (B, nr, s, s), rhs: (B, nr, s, k) -> (B, nr, s, k).
    """
    b, nr, s, k = rhs.shape
    z = jnp.zeros((b, s, k), rhs.dtype)
    zs = []
    for i in range(nr):
        z = jnp.einsum("bij,bjk->bik", minv[:, i], rhs[:, i] + gw * z)
        zs.append(z)
    x = jnp.zeros_like(z)
    xs = [None] * nr
    for i in reversed(range(nr)):
        x = zs[i] + gw * jnp.einsum("bij,bjk->bik", minv[:, i], x)
        xs[i] = x
    return jnp.stack(xs, axis=1)


def schur_update_ref(a4, a3, w):
    """A4 - A3 @ W in f32."""
    return a4.astype(jnp.float32) - a3.astype(jnp.float32) @ w.astype(jnp.float32)


def flash_attention_ref(q, k, v, *, causal=True):
    """Plain softmax attention.  q, k, v: (BH, S, D)."""
    import jax
    s = jnp.einsum("bqd,bkd->bqk", q, k) * (q.shape[-1] ** -0.5)
    if causal:
        mask = jnp.tril(jnp.ones((q.shape[1], q.shape[1]), bool))
        s = jnp.where(mask[None], s, -1e30)
    w = jax.nn.softmax(s.astype(jnp.float32), axis=-1).astype(q.dtype)
    return jnp.einsum("bqk,bkd->bqd", w, v)

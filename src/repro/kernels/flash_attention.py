"""Pallas TPU kernel: causal flash attention (forward).

Online-softmax blocked attention (Dao et al.) adapted to the TPU memory
hierarchy: (block_q x d) query tiles resident in VMEM, K/V streamed in
(block_k x d) tiles over the innermost grid axis, running (max, denom)
statistics in VMEM scratch, MXU-aligned tiles.  Causal masking skips fully
masked K blocks via pl.when (structural zero-work, not just masking).

Replaces the q-chunked jnp attention path on TPU for the 32k-prefill cells
(projected ~1.5x on their memory roofline terms: scores never round-trip
to HBM).  Forward-only: training wraps it with jax.checkpoint and the
backward recompute uses the same kernel (standard flash-style remat)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale: float, n_kb: int, block_q: int, block_k: int,
                  causal: bool):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # causal: K block strictly after the Q block is all-masked -> skip.
    live = (not causal) or (ki * block_k <= qi * block_q + block_q - 1)

    @pl.when(live)
    def _step():
        q = q_ref[0].astype(jnp.float32)            # (bq, d)
        k = k_ref[0].astype(jnp.float32)            # (bk, d)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if causal:
            rows = qi * block_q + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 0)
            cols = ki * block_k + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 1)
            s = jnp.where(rows >= cols, s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=1, keepdims=True)
        acc_scr[...] = acc_scr[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(ki == n_kb - 1)
    def _finish():
        denom = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / denom).astype(o_ref.dtype)


def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    causal: bool = True, block_q: int = 128,
                    block_k: int = 128,
                    interpret: bool = False) -> jnp.ndarray:
    """Blocked causal attention.  q, k, v: (BH, S, D) -> (BH, S, D).

    S must divide by the block sizes (ops.py pads); D MXU-aligned.
    """
    bh, s, d = q.shape
    assert k.shape == v.shape == (bh, s, d)
    assert s % block_q == 0 and s % block_k == 0, (s, block_q, block_k)
    n_qb, n_kb = s // block_q, s // block_k
    scale = d ** -0.5
    kernel = functools.partial(
        _flash_kernel, scale=scale, n_kb=n_kb, block_q=block_q,
        block_k=block_k, causal=causal)
    return pl.pallas_call(
        kernel,
        grid=(bh, n_qb, n_kb),
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, s, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),   # running max
            pltpu.VMEM((block_q, 1), jnp.float32),   # running denom
            pltpu.VMEM((block_q, d), jnp.float32),   # output accumulator
        ],
        interpret=interpret,
    )(q, k, v)

"""Pallas TPU kernels for the compute hot-spots:

  crossbar_mvm - differential analog crossbar MVM simulation (DAC/ADC fused)
  arena_mvm    - arena-executor level megakernel (stacked tiles over one
                 register arena; signs/divisors folded, DAC/ADC fused)
  schur_gemm   - fused Schur-complement update A4 - A3 @ W
  banded_solve - batched block-tridiagonal sweeps for the nodal wire oracle

Use repro.kernels.ops for the public (padded, jit'd) entry points and
repro.kernels.ref for the pure-jnp oracles.
"""
from repro.kernels import ops, ref  # noqa: F401

"""Pallas TPU kernel: fused Schur-complement update  A4s = A4 - A3 @ W.

The digital pre-processing of every BlockAMC stage (paper Eq. 3) computes
A4s = A4 - A3 A1^-1 A2.  With W = A1^-1 A2 from the leaf/block inverse, the
remaining work is a GEMM whose accumulator is *initialised from A4* and
*subtracts* the product - fusing the subtraction saves one full HBM
round-trip of the (n/2)^2 output against a matmul-then-subtract pair.

Grid (I, J, K) with K-accumulation in the output ref; MXU-aligned tiles.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _schur_kernel(a4_ref, a3_ref, w_ref, out_ref, *, n_k: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        out_ref[...] = a4_ref[...].astype(jnp.float32)

    out_ref[...] -= jax.lax.dot_general(
        a3_ref[...].astype(jnp.float32), w_ref[...].astype(jnp.float32),
        (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)


def schur_update(a4: jnp.ndarray, a3: jnp.ndarray, w: jnp.ndarray, *,
                 block_i: int = 128, block_j: int = 128, block_k: int = 128,
                 interpret: bool = False) -> jnp.ndarray:
    """A4 - A3 @ W with the subtraction fused into the GEMM epilogue.

    a4: (I, J), a3: (I, K), w: (K, J); multiples of block sizes (ops.py pads).
    """
    i, j = a4.shape
    i2, k = a3.shape
    k2, j2 = w.shape
    assert i == i2 and j == j2 and k == k2
    assert i % block_i == 0 and j % block_j == 0 and k % block_k == 0
    n_k = k // block_k
    grid = (i // block_i, j // block_j, n_k)
    return pl.pallas_call(
        functools.partial(_schur_kernel, n_k=n_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_i, block_j), lambda gi, gj, gk: (gi, gj)),
            pl.BlockSpec((block_i, block_k), lambda gi, gj, gk: (gi, gk)),
            pl.BlockSpec((block_k, block_j), lambda gi, gj, gk: (gk, gj)),
        ],
        out_specs=pl.BlockSpec((block_i, block_j), lambda gi, gj, gk: (gi, gj)),
        out_shape=jax.ShapeDtypeStruct((i, j), jnp.float32),
        interpret=interpret,
    )(a4, a3, w)

"""Pallas TPU kernel: differential analog crossbar MVM simulation.

The Monte-Carlo hot spot of the whole reproduction: for every (seed x size x
matrix family) cell of the paper's accuracy study, and for every partitioned
MVM inside a BlockAMC cascade, we evaluate

    out[b, r] = -ADC( sum_c (gpos[r, c] - gneg[r, c]) * DAC(v[b, c]) / g0 )

i.e. a batched signed MVM with converter quantisation fused in.  On TPU this
is a classic MXU matmul with a K-accumulation grid; the differential
subtract, the DAC quantisation of the inputs and the ADC quantisation of the
outputs are fused into the tile loop so conductances stream HBM->VMEM once.

Tiling: (BB x BC) activation tiles and (BR x BC) conductance tiles in VMEM;
MXU-aligned 128 multiples.  The kernel accumulates over the C grid axis in
the output ref (revisited across c steps - standard Pallas accumulation).

Hardware adaptation note (DESIGN.md): the analog circuit sums currents in
space; the TPU sums partial products in time over the K grid axis.  The
bit-exact quantiser placement (DAC before the sum, ADC after the *complete*
sum) is preserved - ADC fires only on the last K step.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# The one converter model (pure jnp, so it traces inside the kernel body).
from repro.core.quantization import quantize as _quantize


def _crossbar_mvm_kernel(v_ref, gpos_ref, gneg_ref, out_ref, *,
                         n_ck: int, inv_g0: float,
                         dac_bits: int | None, adc_bits: int | None,
                         fullscale: float):
    ck = pl.program_id(2)

    @pl.when(ck == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    v = _quantize(v_ref[...].astype(jnp.float32), dac_bits, fullscale)
    g = (gpos_ref[...] - gneg_ref[...]).astype(jnp.float32)
    # (BB, BC) x (BR, BC)^T -> (BB, BR) on the MXU
    out_ref[...] += jax.lax.dot_general(
        v, g, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(ck == n_ck - 1)
    def _finish():
        acc = out_ref[...] * (-inv_g0)
        out_ref[...] = _quantize(acc, adc_bits, fullscale)


def crossbar_mvm(v: jnp.ndarray, gpos: jnp.ndarray, gneg: jnp.ndarray, *,
                 g0: float, dac_bits: int | None = None,
                 adc_bits: int | None = None, fullscale: float = 1.0,
                 block_b: int = 128, block_r: int = 128, block_c: int = 128,
                 interpret: bool = False) -> jnp.ndarray:
    """Batched differential crossbar MVM.

    Args:
      v:    (B, C) input voltage vectors.
      gpos: (R, C) positive conductance array.
      gneg: (R, C) negative conductance array.
    Returns:
      (B, R) float32: -ADC((gpos - gneg) @ DAC(v) / g0) per batch row.
    Shapes must be multiples of the block sizes (ops.py pads ragged inputs).
    """
    b, c = v.shape
    r, c2 = gpos.shape
    assert c == c2 and gpos.shape == gneg.shape
    assert b % block_b == 0 and r % block_r == 0 and c % block_c == 0, \
        (v.shape, gpos.shape, (block_b, block_r, block_c))
    n_ck = c // block_c
    grid = (b // block_b, r // block_r, n_ck)
    kernel = functools.partial(
        _crossbar_mvm_kernel, n_ck=n_ck, inv_g0=1.0 / g0,
        dac_bits=dac_bits, adc_bits=adc_bits, fullscale=fullscale)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, block_c), lambda i, j, k: (i, k)),
            pl.BlockSpec((block_r, block_c), lambda i, j, k: (j, k)),
            pl.BlockSpec((block_r, block_c), lambda i, j, k: (j, k)),
        ],
        out_specs=pl.BlockSpec((block_b, block_r), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((b, r), jnp.float32),
        interpret=interpret,
    )(v, gpos, gneg)


def _crossbar_mvm_batched_kernel(v_ref, gpos_ref, gneg_ref, out_ref, *,
                                 n_ck: int, inv_g0: float,
                                 dac_bits: int | None, adc_bits: int | None,
                                 fullscale: float):
    ck = pl.program_id(3)

    @pl.when(ck == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    v = _quantize(v_ref[0].astype(jnp.float32), dac_bits, fullscale)
    g = (gpos_ref[0] - gneg_ref[0]).astype(jnp.float32)
    out_ref[0, ...] += jax.lax.dot_general(
        v, g, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(ck == n_ck - 1)
    def _finish():
        acc = out_ref[...] * (-inv_g0)
        out_ref[...] = _quantize(acc, adc_bits, fullscale)


def crossbar_mvm_batched(v: jnp.ndarray, gpos: jnp.ndarray,
                         gneg: jnp.ndarray, *, g0: float,
                         dac_bits: int | None = None,
                         adc_bits: int | None = None,
                         fullscale: float = 1.0, block_b: int = 128,
                         block_r: int = 128, block_c: int = 128,
                         interpret: bool = False) -> jnp.ndarray:
    """Leading-dim batched crossbar MVM: one grid axis per physical array.

    The flat BlockAMC executor stacks all same-shape arrays of one cascade
    level into (L, R, C) conductance tensors; this entry point drives the
    whole stack in one pallas_call - the leading grid axis walks the arrays
    (so every array's tiles stream HBM->VMEM once) and the inner three axes
    are the standard batched-MVM grid.

    Args:
      v:    (L, B, C) per-array input voltage batches.
      gpos: (L, R, C) positive conductance stacks.
      gneg: (L, R, C) negative conductance stacks.
    Returns:
      (L, B, R) float32: per-array -ADC((gpos - gneg) @ DAC(v) / g0).
    Trailing dims must be multiples of the block sizes (ops.py pads).
    """
    l, b, c = v.shape
    l2, r, c2 = gpos.shape
    assert l == l2 and c == c2 and gpos.shape == gneg.shape
    assert b % block_b == 0 and r % block_r == 0 and c % block_c == 0, \
        (v.shape, gpos.shape, (block_b, block_r, block_c))
    n_ck = c // block_c
    grid = (l, b // block_b, r // block_r, n_ck)
    kernel = functools.partial(
        _crossbar_mvm_batched_kernel, n_ck=n_ck, inv_g0=1.0 / g0,
        dac_bits=dac_bits, adc_bits=adc_bits, fullscale=fullscale)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_b, block_c), lambda a, i, j, k: (a, i, k)),
            pl.BlockSpec((1, block_r, block_c), lambda a, i, j, k: (a, j, k)),
            pl.BlockSpec((1, block_r, block_c), lambda a, i, j, k: (a, j, k)),
        ],
        out_specs=pl.BlockSpec((1, block_b, block_r),
                               lambda a, i, j, k: (a, i, j)),
        out_shape=jax.ShapeDtypeStruct((l, b, r), jnp.float32),
        interpret=interpret,
    )(v, gpos, gneg)

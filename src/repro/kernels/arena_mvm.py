"""Pallas level-megakernel for the arena-form BlockAMC executor.

One call executes one schedule-level group of the arena executor
(`repro.core.blockamc.execute_arena`): a stack of same-shape tiles, each
applying its precomputed operator (explicit INV inverse or sign/divisor-
folded MVM tile - see the DESIGN note in core/blockamc.py) to a signed sum
of static arena windows, writing or accumulating into its output window.
This generalises `crossbar_mvm_batched` from one conductance stack driving
private per-array inputs to shape-bucketed ragged tiles reading and writing
one shared register arena:

    v_t   = sum_j signs[t, j] * arena[in_offs[t, j] : in_offs[t, j] + C]
    out_t = ADC(ops[t] @ DAC(v_t))                      # (R, K) on the MXU
    arena[out_offs[t] : out_offs[t] + R] {=, +=} out_t  # init flag per tile

The leading grid axis walks the tiles of the group (each operator tile
streams HBM->VMEM once); the arena lives in one unblocked buffer revisited
by every step, so row-partial accumulation across the tiles of one MVM
tile-row happens in-place, in the schedule's order.  Signs, the summing-node
divisor and the circuit minus are folded into `ops` at arena-compile time;
DAC/ADC quantisation is fused into the tile loop exactly as in
`crossbar_mvm.py` (ideal converters by default - the cascade quantises once
at the input and once at the output, not per level).

`arena_packed_apply` is the multi-tenant extension: an *instance* grid
axis in front (grid = (M, T)) runs the whole shared tile program for M
packed same-signature plans over an (M, S, K) arena stack - window
metadata is one shared SMEM copy, operators carry a per-instance axis -
so one pallas_call serves an entire fleet of matrices.

On TPU the metadata arrays (offsets, signs, init flags) ride in SMEM so
the dynamic window starts are scalar reads, and the dot hits the MXU;
`interpret=True` (the CPU CI smoke) executes the same body in Python per
grid step.  TPU alignment note: tile shapes and the RHS-batch dim follow
the usual (8, 128) f32 tiling; the `ops.arena_level_apply` wrapper pads
the batch dim, and arena offsets of production plans are multiples of the
leaf array size (64+ on paper configs).  Compiled-mode lowering has not
been exercised in this CPU-only container (same status as the other
kernels in this package): interpret-mode parity is the tested contract.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:  # TPU memory spaces; absent/unused on CPU-only installs
    from jax.experimental.pallas import tpu as pltpu
    _SMEM = pltpu.SMEM
except Exception:  # pragma: no cover - CPU container fallback
    _SMEM = None

# The one converter model (pure jnp, so it traces inside the kernel body).
from repro.core.quantization import quantize as _quantize


def _arena_packed_kernel(in_offs_ref, in_signs_ref, out_offs_ref,
                         out_init_ref, ops_ref, arena_ref, out_ref, *,
                         rows: int, cols: int, n_terms: int,
                         dac_bits: int | None, adc_bits: int | None,
                         fullscale: float):
    """The one arena tile-program body, instance-packed.

    grid = (M, T) walks every tile of the shared schedule (t, the fast
    axis) for each packed instance i.  Instance i owns its own (1, S, K)
    arena block - revisited across its whole t sweep, so level outputs
    accumulate in place - while the window metadata is one shared
    (T, ...) copy in SMEM and `ops` carries the per-instance operator
    sequence (M, T, R, C).  One pallas_call therefore executes the ENTIRE
    cascade of the ENTIRE fleet; the single-instance entry point
    (`arena_level_apply`) is the M=1 special case of this same body, so
    the two paths cannot diverge.
    """
    t = pl.program_id(1)

    # Carry the untouched arena cells through: the output buffer is the
    # arena, and only this level's output windows may change.  (With the
    # wrapper's input/output aliasing this lowers to a no-op self-copy.)
    @pl.when(t == 0)
    def _carry():
        out_ref[...] = arena_ref[...]

    # Signed static-window gather (the folded slice/add/catneg wiring).
    # Reads go through out_ref so tiles see this level's in-order writes
    # never needed for correctness (inputs and outputs of one level are
    # disjoint by construction) but required when the buffers alias.
    v = jnp.zeros((cols, out_ref.shape[-1]), jnp.float32)
    for j in range(n_terms):                       # static unroll
        off = in_offs_ref[t, j]
        v = v + in_signs_ref[t, j] * out_ref[0, pl.ds(off, cols), :]
    v = _quantize(v, dac_bits, fullscale)

    # (R, C) x (C, K) -> (R, K) on the MXU; sign/divisor pre-folded in ops.
    out = jax.lax.dot_general(
        ops_ref[0, 0], v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    out = _quantize(out, adc_bits, fullscale)

    o = out_offs_ref[t]

    @pl.when(out_init_ref[t] == 1)
    def _set():
        out_ref[0, pl.ds(o, rows), :] = out

    @pl.when(out_init_ref[t] == 0)
    def _accumulate():
        out_ref[0, pl.ds(o, rows), :] += out


def arena_packed_apply(arena: jnp.ndarray, ops: jnp.ndarray,
                       in_offs: jnp.ndarray, in_signs: jnp.ndarray,
                       out_offs: jnp.ndarray, out_init: jnp.ndarray, *,
                       dac_bits: int | None = None,
                       adc_bits: int | None = None, fullscale: float = 1.0,
                       interpret: bool = False) -> jnp.ndarray:
    """Run a whole packed tile program; returns the updated arena stack.

    Args:
      arena:    (M, S, K) f32 register arenas, one per packed instance.
      ops:      (M, T, R, C) operator tiles in shared schedule order.
      in_offs:  (T, J) int32 arena offsets of each tile's input windows
                (shared across instances - the stackability invariant).
      in_signs: (T, J) f32 signs (+1/-1; 0 pads unused term slots).
      out_offs: (T,) int32 output window offsets.
      out_init: (T,) int32; 1 = first write of its window, 0 = accumulate.
    """
    m, s, k = arena.shape
    _, t_steps, rows, cols = ops.shape
    assert ops.shape[0] == m, (ops.shape, m)
    assert in_offs.shape == in_signs.shape == (t_steps, in_offs.shape[1])
    assert out_offs.shape == out_init.shape == (t_steps,)
    n_terms = in_offs.shape[1]
    kernel = functools.partial(
        _arena_packed_kernel, rows=rows, cols=cols, n_terms=n_terms,
        dac_bits=dac_bits, adc_bits=adc_bits, fullscale=fullscale)
    smem = {} if interpret or _SMEM is None else {"memory_space": _SMEM}
    meta = pl.BlockSpec(in_offs.shape, lambda i, t: (0, 0), **smem)
    flat = pl.BlockSpec((t_steps,), lambda i, t: (0,), **smem)
    inst = pl.BlockSpec((1, s, k), lambda i, t: (i, 0, 0))
    return pl.pallas_call(
        kernel,
        grid=(m, t_steps),
        in_specs=[meta, meta, flat, flat,
                  pl.BlockSpec((1, 1, rows, cols),
                               lambda i, t: (i, t, 0, 0)),
                  inst],
        out_specs=inst,
        out_shape=jax.ShapeDtypeStruct((m, s, k), jnp.float32),
        input_output_aliases={5: 0},     # each arena updates in place
        interpret=interpret,
    )(in_offs, in_signs, out_offs, out_init, ops, arena)


def arena_level_apply(arena: jnp.ndarray, ops: jnp.ndarray,
                      in_offs: jnp.ndarray, in_signs: jnp.ndarray,
                      out_offs: jnp.ndarray, out_init: jnp.ndarray, *,
                      dac_bits: int | None = None,
                      adc_bits: int | None = None, fullscale: float = 1.0,
                      interpret: bool = False) -> jnp.ndarray:
    """Apply one arena level group; returns the updated arena.

    The M=1 special case of `arena_packed_apply` (one kernel body for the
    single-tenant and packed paths - they cannot diverge).

    Args:
      arena:    (S, K) f32 register arena (K = RHS batch).
      ops:      (L, R, C) operator tiles (sign/divisor folded).
      in_offs:  (L, T) int32 arena offsets of each tile's input windows.
      in_signs: (L, T) f32 signs (+1/-1; 0 pads unused term slots).
      out_offs: (L,) int32 output window offsets.
      out_init: (L,) int32; 1 = first write of its window, 0 = accumulate.
    """
    l = ops.shape[0]
    assert in_offs.shape == in_signs.shape == (l, in_offs.shape[1])
    assert out_offs.shape == out_init.shape == (l,)
    return arena_packed_apply(
        arena[None], ops[None], in_offs, in_signs, out_offs, out_init,
        dac_bits=dac_bits, adc_bits=adc_bits, fullscale=fullscale,
        interpret=interpret)[0]

"""Pallas kernel: batched block-tridiagonal solve sweeps for the nodal oracle.

The physics-grade crossbar solve (`physics/nodal.py`) reduces each crossbar
to a block-tridiagonal SPD system - nr blocks of size s with constant
off-diagonal blocks -gw*I - factored once into an explicit-inverse stack
Minv (nr, s, s).  The remaining work, and the Monte-Carlo hot loop, is the
pair of block-Thomas sweeps

    forward:   z_i = Minv_i (rhs_i + gw * z_{i-1}),     z_{-1} = 0
    backward:  x_i = z_i + gw * Minv_i x_{i+1},         x_{nr} = 0

i.e. 2*nr dense (s x s) @ (s x k) matmuls per crossbar with a sequential
carry.  This kernel runs them for a whole batch in one pallas_call: the
grid walks the batch axis (one crossbar per grid step, its Minv stack and
rhs streamed HBM->VMEM once), and the two `lax.scan`s run inside the kernel
body on the MXU.

Hybrid factor/solve split (deliberate, documented): the *factorization*
(the Minv recursion) stays in XLA - it is irreducibly sequential in i and
batched `linalg.inv` is already optimal there - so the kernel is pure
matmul sweeps over precomputed factors.  That is also what makes the
zero-padding contract trivial: padded rows/columns of Minv and rhs are
zero, zeros propagate zeros through both scans, and `ops.py` slices the
result back.

TPU alignment: ops.py pads s and k to the 128 lane width.  On CPU the
kernel executes with interpret=True; interpret-mode parity against
`ref.block_tridiag_solve_ref` and the in-line jnp scans of nodal.py is the
tested contract (tests/test_physics_oracle.py), matching every other
kernel in this package.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _block_tridiag_kernel(minv_ref, rhs_ref, out_ref, *, gw: float):
    minv = minv_ref[0]                      # (nr, s, s)
    rhs = rhs_ref[0]                        # (nr, s, k)
    dims = (((1,), (0,)), ((), ()))         # (s,s) @ (s,k)
    z0 = jnp.zeros(rhs.shape[1:], rhs.dtype)

    def fwd(z, x):
        mi, ri = x
        zn = jax.lax.dot_general(mi, ri + gw * z, dims,
                                 preferred_element_type=rhs.dtype)
        return zn, zn

    _, zs = jax.lax.scan(fwd, z0, (minv, rhs))

    def bwd(xn, x):
        mi, zi = x
        xi = zi + gw * jax.lax.dot_general(mi, xn, dims,
                                           preferred_element_type=rhs.dtype)
        return xi, xi

    _, xs = jax.lax.scan(bwd, z0, (minv[::-1], zs[::-1]))
    out_ref[0] = xs[::-1]


def block_tridiag_solve(minv: jnp.ndarray, rhs: jnp.ndarray, *, gw: float,
                        interpret: bool = False) -> jnp.ndarray:
    """Batched block-Thomas sweeps over precomputed inverse factors.

    Args:
      minv: (B, nr, s, s) per-crossbar explicit-inverse factor stacks.
      rhs:  (B, nr, s, k) right-hand-side blocks.
      gw:   wire segment conductance 1/r_seg (static Python float - it is
            baked into the kernel like g0 in crossbar_mvm).
    Returns:
      (B, nr, s, k) solution blocks.  s and k must be 128-aligned on TPU
      (ops.py pads); zero padding is exact (zeros propagate zeros).
    """
    b, nr, s, s2 = minv.shape
    b2, nr2, s3, k = rhs.shape
    assert (b, nr, s) == (b2, nr2, s3) and s == s2, (minv.shape, rhs.shape)
    kernel = functools.partial(_block_tridiag_kernel, gw=gw)
    return pl.pallas_call(
        kernel,
        grid=(b,),
        in_specs=[
            pl.BlockSpec((1, nr, s, s), lambda i: (i, 0, 0, 0)),
            pl.BlockSpec((1, nr, s, k), lambda i: (i, 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, nr, s, k), lambda i: (i, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, nr, s, k), rhs.dtype),
        interpret=interpret,
    )(minv, rhs)

"""Jit'd public wrappers for the Pallas kernels: padding, dtype policy,
CPU-interpret fallback.

On a CPU host (tests, this container) `interpret=True` executes the kernel
body in Python per grid step; on TPU the same BlockSpecs compile to Mosaic.
The wrappers pad ragged shapes up to the 128-aligned tile grid and slice the
result back, so callers never see the alignment constraint.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels import arena_mvm as _arena
from repro.kernels import banded_solve as _banded
from repro.kernels import crossbar_mvm as _xbar
from repro.kernels import schur_gemm as _schur


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _pad_to(x: jnp.ndarray, mults) -> jnp.ndarray:
    pads = [(0, (-s) % m) for s, m in zip(x.shape, mults)]
    if all(p == (0, 0) for p in pads):
        return x
    return jnp.pad(x, pads)


@partial(jax.jit, static_argnames=("g0", "dac_bits", "adc_bits", "fullscale",
                                   "interpret"))
def crossbar_mvm(v, gpos, gneg, *, g0: float, dac_bits=None, adc_bits=None,
                 fullscale: float = 1.0, interpret: bool | None = None):
    """Batched differential crossbar MVM; see kernels/crossbar_mvm.py.

    v: (B, C), gpos/gneg: (R, C) -> (B, R).  Any shapes; pads to 128s.
    """
    if interpret is None:
        interpret = not _on_tpu()
    b, c = v.shape
    r = gpos.shape[0]
    blk = 128
    vp = _pad_to(v, (blk, blk))
    gp = _pad_to(gpos, (blk, blk))
    gn = _pad_to(gneg, (blk, blk))
    out = _xbar.crossbar_mvm(vp, gp, gn, g0=g0, dac_bits=dac_bits,
                             adc_bits=adc_bits, fullscale=fullscale,
                             interpret=interpret)
    return out[:b, :r]


@partial(jax.jit, static_argnames=("g0", "dac_bits", "adc_bits", "fullscale",
                                   "interpret"))
def crossbar_mvm_batched(v, gpos, gneg, *, g0: float, dac_bits=None,
                         adc_bits=None, fullscale: float = 1.0,
                         interpret: bool | None = None):
    """Leading-dim batched crossbar MVM over a stack of physical arrays.

    v: (L, B, C), gpos/gneg: (L, R, C) -> (L, B, R).  The leading axis L
    (one entry per array of a flat-executor shape bucket) is a grid axis,
    never padded; trailing dims pad to 128s.
    """
    if interpret is None:
        interpret = not _on_tpu()
    l, b, c = v.shape
    r = gpos.shape[1]
    blk = 128
    vp = _pad_to(v, (1, blk, blk))
    gp = _pad_to(gpos, (1, blk, blk))
    gn = _pad_to(gneg, (1, blk, blk))
    out = _xbar.crossbar_mvm_batched(vp, gp, gn, g0=g0, dac_bits=dac_bits,
                                     adc_bits=adc_bits, fullscale=fullscale,
                                     interpret=interpret)
    return out[:, :b, :r]


@partial(jax.jit, static_argnames=("dac_bits", "adc_bits", "fullscale",
                                   "interpret"))
def arena_level_apply(arena, ops, in_offs, in_signs, out_offs, out_init, *,
                      dac_bits=None, adc_bits=None, fullscale: float = 1.0,
                      interpret: bool | None = None):
    """One arena level group (see kernels/arena_mvm.py); returns the arena.

    arena: (S, K), ops: (L, R, C), metadata per tile.  The RHS batch dim K
    is padded to the f32 lane width and sliced back; S and the tile dims
    are used as-is (arena offsets are byte positions in the register file -
    padding them would shift every window).  The kernel computes in f32
    (like every kernel in this package); the result is cast back to the
    arena's dtype so the caller's executor dtype is stable - under x64,
    accuracy is capped at f32 on this path (the jnp path keeps f64).
    """
    if interpret is None:
        interpret = not _on_tpu()
    s, k = arena.shape
    blk = 128
    ap = _pad_to(arena.astype(jnp.float32), (1, blk))
    out = _arena.arena_level_apply(
        ap, ops.astype(jnp.float32), in_offs, in_signs, out_offs, out_init,
        dac_bits=dac_bits, adc_bits=adc_bits, fullscale=fullscale,
        interpret=interpret)
    return out[:, :k].astype(arena.dtype)


@partial(jax.jit, static_argnames=("dac_bits", "adc_bits", "fullscale",
                                   "interpret"))
def arena_packed_apply(arena, ops, in_offs, in_signs, out_offs, out_init, *,
                       dac_bits=None, adc_bits=None, fullscale: float = 1.0,
                       interpret: bool | None = None):
    """Whole packed tile program (see kernels/arena_mvm.py); returns arenas.

    arena: (M, S, K) instance-stacked register arenas, ops: (M, T, R, C)
    per-instance operator sequences, window metadata (T, ...) shared across
    instances.  Same padding/dtype policy as `arena_level_apply`: the RHS
    batch dim K pads to the f32 lane width and slices back; M, S and the
    tile dims are used as-is (arena offsets are positions in the register
    file).  Computes in f32, cast back to the arena's dtype.
    """
    if interpret is None:
        interpret = not _on_tpu()
    m, s, k = arena.shape
    blk = 128
    ap = _pad_to(arena.astype(jnp.float32), (1, 1, blk))
    out = _arena.arena_packed_apply(
        ap, ops.astype(jnp.float32), in_offs, in_signs, out_offs, out_init,
        dac_bits=dac_bits, adc_bits=adc_bits, fullscale=fullscale,
        interpret=interpret)
    return out[:, :, :k].astype(arena.dtype)


@partial(jax.jit, static_argnames=("gw", "interpret"))
def block_tridiag_solve(minv, rhs, *, gw: float,
                        interpret: bool | None = None):
    """Batched block-Thomas sweeps; see kernels/banded_solve.py.

    minv: (B, nr, s, s), rhs: (B, nr, s, k) -> (B, nr, s, k).  The block
    size s and RHS width k pad to 128 and slice back; zero padding is exact
    for this kernel (zeros propagate zeros through both sweeps), so callers
    never see the alignment constraint.  Keeps the input dtype (the nodal
    oracle runs it under x64 for parity tests; interpret mode handles f64).
    """
    if interpret is None:
        interpret = not _on_tpu()
    b, nr, s, k = rhs.shape
    blk = 128
    mp = _pad_to(minv, (1, 1, blk, blk))
    rp = _pad_to(rhs, (1, 1, blk, blk))
    out = _banded.block_tridiag_solve(mp, rp, gw=gw, interpret=interpret)
    return out[:, :, :s, :k]


@partial(jax.jit, static_argnames=("interpret",))
def schur_update(a4, a3, w, *, interpret: bool | None = None):
    """Fused A4 - A3 @ W; see kernels/schur_gemm.py.  Any shapes; pads."""
    if interpret is None:
        interpret = not _on_tpu()
    i, j = a4.shape
    blk = 128
    a4p = _pad_to(a4, (blk, blk))
    a3p = _pad_to(a3, (blk, blk))
    wp = _pad_to(w, (blk, blk))
    out = _schur.schur_update(a4p, a3p, wp, interpret=interpret)
    return out[:i, :j]


@partial(jax.jit, static_argnames=("causal", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True,
                    interpret: bool | None = None):
    """Blocked causal attention; see kernels/flash_attention.py.

    q, k, v: (BH, S, D).  Pads S to 128 (padded keys are masked by
    causality for the real rows; padded query rows are sliced away).
    """
    from repro.kernels import flash_attention as _fa
    if interpret is None:
        interpret = not _on_tpu()
    bh, s, d = q.shape
    blk = 128
    # padded keys sit after every real query, so causality masks them;
    # non-causal inputs must be pre-aligned.
    assert causal or s % blk == 0, "non-causal flash requires S % 128 == 0"
    qp = _pad_to(q, (1, blk, 1))
    kp = _pad_to(k, (1, blk, 1))
    vp = _pad_to(v, (1, blk, 1))
    out = _fa.flash_attention(qp, kp, vp, causal=causal,
                              interpret=interpret)
    return out[:, :s, :]

"""LM continuous batching: slot-level request scheduling over a shared cache.

Production serving keeps every batch slot busy: when one sequence finishes,
the next queued request is admitted into its slot immediately - prompts
stream through the same per-token decode step (teacher-forced) while
neighbouring slots keep generating.  This needs per-slot positions (each
sequence is at its own offset), which `attention_decode` supports natively,
plus per-slot cache invalidation on admission (`reset_slots`: attention
validity masks already exclude entries past the new position; recurrent
SSM/RG-LRU states are zeroed explicitly).

The host loop does slot bookkeeping; the per-token step stays one jitted
SPMD program - the standard split in production engines.  The solver
analogue of this discipline is `repro.serve.scheduler
.PackedSolverScheduler` (this module used to share a file with it; the LM
half moved here with the rest of the retired `serve.Engine` surface).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import transformer as tr


@dataclasses.dataclass
class Request:
    req_id: int
    prompt: List[int]
    max_new: int


def _batch_axis(path) -> int:
    return 1 if any(str(getattr(p, "key", "")) == "blocks" for p in path) else 0


def reset_slots(cache, mask: jnp.ndarray):
    """Zero the cache state of slots where mask[b] is True."""

    def one(path, leaf):
        ax = _batch_axis(path)
        shape = [1] * leaf.ndim
        shape[ax] = mask.shape[0]
        m = mask.reshape(shape)
        return jnp.where(m, jnp.zeros_like(leaf), leaf)

    return jax.tree_util.tree_map_with_path(one, cache)


class ContinuousBatchingEngine:
    """Greedy continuous-batching server with `n_slots` parallel lanes."""

    def __init__(self, cfg: ModelConfig, params, n_slots: int,
                 max_len: int, eos_id: Optional[int] = None):
        self.cfg = cfg
        self.params = params
        self.n_slots = n_slots
        self.max_len = max_len
        self.eos_id = eos_id
        self._cache = tr.init_cache(n_slots, max_len, cfg, dtype=jnp.float32)

        def step(params, cache, tokens_t, pos):
            logits, cache = tr.decode_step(params, cache, tokens_t, pos, cfg)
            return jnp.argmax(logits, axis=-1).astype(jnp.int32), cache

        self._step = jax.jit(step, donate_argnums=(1,))
        self._reset = jax.jit(reset_slots)

    def run(self, requests: List[Request]) -> Dict[int, List[int]]:
        """Serve all requests to completion; returns generated ids per req."""
        queue = list(requests)
        out: Dict[int, List[int]] = {r.req_id: [] for r in requests}
        # host-side slot state
        slot_req: List[Optional[Request]] = [None] * self.n_slots
        pos = np.zeros(self.n_slots, np.int32)
        cur = np.zeros(self.n_slots, np.int32)
        n_gen = np.zeros(self.n_slots, np.int32)
        cache = self._cache

        def admit(s):
            nonlocal cache
            if not queue:
                slot_req[s] = None
                return False
            req = queue.pop(0)
            slot_req[s] = req
            pos[s] = 0
            cur[s] = req.prompt[0]
            n_gen[s] = 0
            mask = jnp.asarray(np.arange(self.n_slots) == s)
            cache = self._reset(cache, mask)
            return True

        for s in range(self.n_slots):
            admit(s)

        while any(r is not None for r in slot_req):
            nxt, cache = self._step(self.params, cache,
                                    jnp.asarray(cur), jnp.asarray(pos))
            nxt = np.asarray(nxt)
            for s, req in enumerate(slot_req):
                if req is None:
                    continue
                in_prompt = pos[s] + 1 < len(req.prompt)
                if in_prompt:                      # stream the prompt
                    cur[s] = req.prompt[pos[s] + 1]
                else:                              # generating
                    tok = int(nxt[s])
                    out[req.req_id].append(tok)
                    n_gen[s] += 1
                    cur[s] = tok
                    done = (n_gen[s] >= req.max_new
                            or (self.eos_id is not None
                                and tok == self.eos_id)
                            or pos[s] + 2 >= self.max_len)
                    if done:
                        admit(s)
                        continue
                pos[s] += 1
        return out

"""Decoder-only transformer assembly covering all assigned families.

Layer stack = repeated `layer_pattern` of blocks (attn | rec | ssm), each
optionally followed by a dense or MoE FFN.  Homogeneous repeats are stacked
and scanned (`lax.scan` over stacked params) so the HLO stays compact at 48
layers x 400B params; pattern remainders are unrolled.

Inputs are either token ids (LMs) or precomputed frontend embeddings
([vlm]/[audio] stubs per the brief).  Decode threads a per-layer cache
pytree (KV ring buffers, SSM states, RG-LRU states).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn_mod
from repro.models import moe as moe_mod
from repro.models import rglru as rglru_mod
from repro.models import ssm as ssm_mod
from repro.models.layers import rms_norm, swiglu_ffn, swiglu_ffn_init, softcap
from repro.sharding import shard


def padded_vocab(cfg: ModelConfig) -> int:
    return -(-cfg.vocab // 256) * 256


def _dtype(name: str):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32}[name]


# ---------------------------------------------------------------------------
# Blocks
# ---------------------------------------------------------------------------

def init_block(key, cfg: ModelConfig, kind: str, is_moe: bool) -> Dict:
    dtype = _dtype(cfg.param_dtype)
    k_mix, k_ffn = jax.random.split(key)
    params: Dict[str, Any] = {
        "ln1": jnp.zeros((cfg.d_model,), dtype=jnp.float32),
    }
    if kind == "attn":
        params["mix"] = attn_mod.init_attention(k_mix, cfg, dtype)
    elif kind == "rec":
        params["mix"] = rglru_mod.init_rglru(k_mix, cfg, dtype)
    elif kind == "ssm":
        params["mix"] = ssm_mod.init_ssm(k_mix, cfg, dtype)
    else:
        raise ValueError(kind)
    if cfg.d_ff > 0:
        params["ln2"] = jnp.zeros((cfg.d_model,), dtype=jnp.float32)
        if is_moe:
            params["ffn"] = moe_mod.init_moe(k_ffn, cfg, dtype)
        else:
            params["ffn"] = swiglu_ffn_init(k_ffn, cfg.d_model, cfg.d_ff, dtype)
    return params


def block_forward(params: Dict, x: jnp.ndarray, positions: jnp.ndarray,
                  cfg: ModelConfig, kind: str, is_moe: bool,
                  window: Optional[int]) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Full-sequence block.  Returns (x, aux_loss)."""
    h = rms_norm(x, params["ln1"], cfg.norm_eps)
    if kind == "attn":
        mixed = attn_mod.attention(params["mix"], h, positions, cfg,
                                   window=window)
    elif kind == "rec":
        mixed = rglru_mod.rglru_forward(params["mix"], h, cfg)
    else:
        mixed = ssm_mod.ssm_forward(params["mix"], h, cfg)
    x = shard(x + mixed, "act_btd")
    aux = jnp.zeros((), jnp.float32)
    if cfg.d_ff > 0:
        h = rms_norm(x, params["ln2"], cfg.norm_eps)
        if is_moe:
            out, aux = moe_mod.moe_ffn(params["ffn"], h, cfg)
        else:
            out = swiglu_ffn(params["ffn"], h)
        x = shard(x + out, "act_btd")
    return x, aux


def init_block_cache(batch: int, cache_len: int, cfg: ModelConfig,
                     kind: str, dtype=jnp.bfloat16) -> Dict:
    if kind == "attn":
        return attn_mod.init_kv_cache(batch, cache_len, cfg, dtype)
    if kind == "rec":
        return rglru_mod.init_rglru_cache(batch, cfg, dtype)
    return ssm_mod.init_ssm_cache(batch, cfg, dtype)


def block_decode(params: Dict, x_t: jnp.ndarray, cache: Dict,
                 pos: jnp.ndarray, cfg: ModelConfig, kind: str, is_moe: bool,
                 window: Optional[int]) -> Tuple[jnp.ndarray, Dict]:
    h = rms_norm(x_t, params["ln1"], cfg.norm_eps)
    if kind == "attn":
        mixed, cache = attn_mod.attention_decode(params["mix"], h, cache, pos,
                                                 cfg, window=window)
    elif kind == "rec":
        mixed, cache = rglru_mod.rglru_decode(params["mix"], h, cache, cfg)
    else:
        mixed, cache = ssm_mod.ssm_decode(params["mix"], h, cache, cfg)
    x_t = x_t + mixed
    if cfg.d_ff > 0:
        h = rms_norm(x_t, params["ln2"], cfg.norm_eps)
        if is_moe:
            out, _ = moe_mod.moe_ffn(params["ffn"], h, cfg)
        else:
            out = swiglu_ffn(params["ffn"], h)
        x_t = x_t + out
    return x_t, cache


# ---------------------------------------------------------------------------
# Stack layout: scanned super-layers + unrolled remainder
# ---------------------------------------------------------------------------

def _pattern(cfg: ModelConfig) -> Tuple[Tuple[str, bool], ...]:
    """The repeating unit as ((kind, is_moe), ...)."""
    if cfg.layer_pattern:
        kinds = cfg.layer_pattern
    elif cfg.family == "ssm":
        kinds = ("ssm",)
    else:
        kinds = ("attn",)
    period = max(len(kinds), cfg.moe_every if cfg.n_experts else 1)
    # extend kinds cyclically to the common period
    unit = []
    for i in range(period):
        unit.append((kinds[i % len(kinds)], cfg.is_moe_layer(i)))
    return tuple(unit)


def stack_layout(cfg: ModelConfig) -> Tuple[Tuple[Tuple[str, bool], ...], int, int]:
    """(pattern unit, n_scanned_repeats, n_remainder_layers)."""
    unit = _pattern(cfg)
    p = len(unit)
    return unit, cfg.n_layers // p, cfg.n_layers % p


def init_params(key, cfg: ModelConfig) -> Dict:
    dtype = _dtype(cfg.param_dtype)
    unit, n_rep, n_rem = stack_layout(cfg)
    k_embed, k_head, k_layers, k_rem = jax.random.split(key, 4)
    v = padded_vocab(cfg)
    params: Dict[str, Any] = {
        "embed": (jax.random.normal(k_embed, (v, cfg.d_model),
                                    dtype=jnp.float32) * 0.02).astype(dtype),
        "ln_f": jnp.zeros((cfg.d_model,), dtype=jnp.float32),
    }
    if not cfg.tie_embeddings:
        params["head"] = (jax.random.normal(
            k_head, (cfg.d_model, v), dtype=jnp.float32)
            * (cfg.d_model ** -0.5)).astype(dtype)

    def init_super(k):
        ks = jax.random.split(k, len(unit))
        return {f"b{i}": init_block(ks[i], cfg, kind, is_moe)
                for i, (kind, is_moe) in enumerate(unit)}

    if n_rep > 0:
        params["blocks"] = jax.vmap(init_super)(jax.random.split(k_layers, n_rep))
    for r in range(n_rem):
        kind, is_moe = unit[r]
        params[f"rem{r}"] = init_block(
            jax.random.fold_in(k_rem, r), cfg, kind, is_moe)
    return params


# ---------------------------------------------------------------------------
# Forward (train / prefill)
# ---------------------------------------------------------------------------

_REMAT_POLICIES = {
    "none": None,
    "dots": "dots",
    "full": "full",
}


def _maybe_remat(fn, remat: str):
    if remat == "none":
        return fn
    if remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims)
    return jax.checkpoint(fn)   # "full": save nothing


def forward(params: Dict, cfg: ModelConfig, *,
            tokens: Optional[jnp.ndarray] = None,
            embeds: Optional[jnp.ndarray] = None,
            window_override: Optional[int] = None,
            remat: str = "none"
            ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (logits (B, S, Vpad), aux_loss scalar)."""
    dtype = _dtype(cfg.compute_dtype)
    if embeds is None:
        x = jnp.take(params["embed"], tokens, axis=0).astype(dtype)
    else:
        x = embeds.astype(dtype)
    b, s, _ = x.shape
    x = shard(x, "act_btd")
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    unit, n_rep, n_rem = stack_layout(cfg)
    window = window_override if window_override is not None else (
        cfg.local_window if "rec" in [u[0] for u in unit] else None)

    def super_fwd(carry, layer_params):
        x, aux = carry
        for i, (kind, is_moe) in enumerate(unit):
            w = window if kind == "attn" else None
            x, a = block_forward(layer_params[f"b{i}"], x, positions, cfg,
                                 kind, is_moe, w)
            aux = aux + a
        return (x, aux), None

    aux = jnp.zeros((), jnp.float32)
    if n_rep > 0:
        body = _maybe_remat(super_fwd, remat)
        (x, aux), _ = jax.lax.scan(body, (x, aux), params["blocks"])
    for r in range(n_rem):
        kind, is_moe = unit[r]
        w = window if kind == "attn" else None
        x, a = block_forward(params[f"rem{r}"], x, positions, cfg, kind,
                             is_moe, w)
        aux = aux + a
    x = rms_norm(x, params["ln_f"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["head"]
    logits = shard(x @ head, "act_btv")
    logits = softcap(logits, cfg.logit_softcap)
    return logits, aux


def block_prefill(params: Dict, x: jnp.ndarray, positions: jnp.ndarray,
                  cfg: ModelConfig, kind: str, is_moe: bool,
                  window: Optional[int], cache_len: int,
                  cache_dtype) -> Tuple[jnp.ndarray, Dict, jnp.ndarray]:
    h = rms_norm(x, params["ln1"], cfg.norm_eps)
    if kind == "attn":
        clen = min(cache_len, window) if window is not None else cache_len
        mixed, cache = attn_mod.attention_prefill(
            params["mix"], h, positions, cfg, clen, window=window,
            cache_dtype=cache_dtype)
    elif kind == "rec":
        mixed, cache = rglru_mod.rglru_forward(params["mix"], h, cfg,
                                               return_cache=True)
    else:
        mixed, cache = ssm_mod.ssm_forward(params["mix"], h, cfg,
                                           return_cache=True)
    x = shard(x + mixed, "act_btd")
    aux = jnp.zeros((), jnp.float32)
    if cfg.d_ff > 0:
        h = rms_norm(x, params["ln2"], cfg.norm_eps)
        if is_moe:
            out, aux = moe_mod.moe_ffn(params["ffn"], h, cfg)
        else:
            out = swiglu_ffn(params["ffn"], h)
        x = shard(x + out, "act_btd")
    return x, cache, aux


def prefill(params: Dict, cfg: ModelConfig, *,
            tokens: Optional[jnp.ndarray] = None,
            embeds: Optional[jnp.ndarray] = None,
            cache_len: Optional[int] = None,
            cache_dtype=jnp.bfloat16) -> Tuple[jnp.ndarray, Dict]:
    """Full forward emitting (last-position logits, decode-ready cache)."""
    dtype = _dtype(cfg.compute_dtype)
    if embeds is None:
        x = jnp.take(params["embed"], tokens, axis=0).astype(dtype)
    else:
        x = embeds.astype(dtype)
    b, s, _ = x.shape
    if cache_len is None:
        cache_len = s
    x = shard(x, "act_btd")
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    unit, n_rep, n_rem = stack_layout(cfg)
    window = cfg.local_window if "rec" in [u[0] for u in unit] else None

    def super_pre(x, layer_params):
        caches = {}
        for i, (kind, is_moe) in enumerate(unit):
            w = window if kind == "attn" else None
            x, c, _ = block_prefill(layer_params[f"b{i}"], x, positions, cfg,
                                    kind, is_moe, w, cache_len, cache_dtype)
            caches[f"b{i}"] = c
        return x, caches

    cache: Dict[str, Any] = {}
    if n_rep > 0:
        x, cache["blocks"] = jax.lax.scan(super_pre, x, params["blocks"])
    for r in range(n_rem):
        kind, is_moe = unit[r]
        w = window if kind == "attn" else None
        x, c, _ = block_prefill(params[f"rem{r}"], x, positions, cfg, kind,
                                is_moe, w, cache_len, cache_dtype)
        cache[f"rem{r}"] = c
    x = rms_norm(x, params["ln_f"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["head"]
    logits = softcap(x[:, -1:] @ head, cfg.logit_softcap)
    return logits[:, 0, :], cache


# ---------------------------------------------------------------------------
# Decode
# ---------------------------------------------------------------------------

def init_cache(batch: int, cache_len: int, cfg: ModelConfig,
               dtype=jnp.bfloat16) -> Dict:
    unit, n_rep, n_rem = stack_layout(cfg)

    def one_super():
        out = {}
        for i, (kind, _) in enumerate(unit):
            clen = min(cache_len, cfg.local_window) if (
                kind == "attn" and "rec" in [u[0] for u in unit]) else cache_len
            out[f"b{i}"] = init_block_cache(batch, clen, cfg, kind, dtype)
        return out

    cache: Dict[str, Any] = {}
    if n_rep > 0:
        cache["blocks"] = jax.tree.map(
            lambda x: jnp.broadcast_to(x[None], (n_rep,) + x.shape),
            one_super())
    for r in range(n_rem):
        kind, _ = unit[r]
        clen = min(cache_len, cfg.local_window) if (
            kind == "attn" and "rec" in [u[0] for u in unit]) else cache_len
        cache[f"rem{r}"] = init_block_cache(batch, clen, cfg, kind, dtype)
    return cache


def decode_step(params: Dict, cache: Dict, tokens_t: jnp.ndarray,
                pos: jnp.ndarray, cfg: ModelConfig, *,
                embeds_t: Optional[jnp.ndarray] = None
                ) -> Tuple[jnp.ndarray, Dict]:
    """One token for the whole batch.  tokens_t: (B,) int32; pos scalar."""
    dtype = _dtype(cfg.compute_dtype)
    if embeds_t is None:
        x = jnp.take(params["embed"], tokens_t[:, None], axis=0).astype(dtype)
    else:
        x = embeds_t.astype(dtype)
    x = shard(x, "act_btd")
    unit, n_rep, n_rem = stack_layout(cfg)
    window = cfg.local_window if "rec" in [u[0] for u in unit] else None

    def super_step(x, inp):
        layer_params, layer_cache = inp
        new_cache = {}
        for i, (kind, is_moe) in enumerate(unit):
            w = window if kind == "attn" else None
            x, c = block_decode(layer_params[f"b{i}"], x,
                                layer_cache[f"b{i}"], pos, cfg, kind,
                                is_moe, w)
            new_cache[f"b{i}"] = c
        return x, new_cache

    new_cache: Dict[str, Any] = {}
    if n_rep > 0:
        x, new_cache["blocks"] = jax.lax.scan(
            super_step, x, (params["blocks"], cache["blocks"]))
    for r in range(n_rem):
        kind, is_moe = unit[r]
        w = window if kind == "attn" else None
        x, c = block_decode(params[f"rem{r}"], x, cache[f"rem{r}"], pos, cfg,
                            kind, is_moe, w)
        new_cache[f"rem{r}"] = c
    x = rms_norm(x, params["ln_f"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["head"]
    logits = softcap(x @ head, cfg.logit_softcap)
    return logits[:, 0, :], new_cache

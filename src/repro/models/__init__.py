"""LM architecture zoo: layers, attention, MoE, SSM, RG-LRU, assembly."""

"""Batched LM generation engine: prefill once, decode in a jitted scan loop.

A deliberately small but production-shaped engine: static batch slots,
greedy or temperature sampling, per-request stop handling, cache reuse.

Lives under `models/` with the transformer it serves: `repro.serve` is
the *solver* serving namespace (SolverService / AsyncSolverEngine /
ReplicatedSolverFleet), and this class's old `serve.Engine` name collided
with it.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import transformer as tr
from repro.models.serve_step import make_decode_step, make_prefill_step


@dataclasses.dataclass
class Engine:
    cfg: ModelConfig
    params: Dict
    max_len: int = 512
    temperature: float = 0.0
    eos_id: Optional[int] = None

    def __post_init__(self):
        self._prefill = jax.jit(make_prefill_step(self.cfg, self.max_len))
        self._decode = jax.jit(make_decode_step(self.cfg, self.temperature))

    def generate(self, tokens: jnp.ndarray, n_steps: int,
                 key: Optional[jax.Array] = None) -> jnp.ndarray:
        """tokens: (B, S_prompt) -> (B, n_steps) generated ids."""
        b, s = tokens.shape
        assert s + n_steps <= self.max_len
        key = key if key is not None else jax.random.PRNGKey(0)
        next_tok, cache = self._prefill(self.params, {"tokens": tokens})

        def body(carry, k):
            tok, cache, pos, done = carry
            new_tok, cache = self._decode(self.params, cache, tok, pos, k)
            if self.eos_id is not None:
                done = jnp.logical_or(done, new_tok == self.eos_id)
                new_tok = jnp.where(done, self.eos_id, new_tok)
            return (new_tok, cache, pos + 1, done), tok

        keys = jax.random.split(key, n_steps)
        init = (next_tok, cache, jnp.int32(s), jnp.zeros((b,), bool))
        _, out = jax.lax.scan(body, init, keys)
        return jnp.moveaxis(out, 0, 1)              # (B, n_steps)

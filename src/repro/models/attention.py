"""GQA attention: chunked-causal for train/prefill, ring-buffer KV decode.

Memory discipline: scores are never materialised at (S x S) - queries are
processed in static chunks via lax.scan (flash-style blocking, the TPU-native
adaptation of memory-efficient attention), so a 32k prefill peaks at
(chunk x S) per (batch, head) shard.  Local (sliding-window) attention
restricts the KV cache to the window - this is what makes recurrentgemma's
long_500k decode O(window) instead of O(S).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import init_dense, rope
from repro.sharding import shard

NEG_INF = -2.0 ** 30


def init_attention(key, cfg: ModelConfig, dtype) -> Dict:
    k1, k2, k3, k4 = jax.random.split(key, 4)
    hd = cfg.head_dim
    return {
        "wq": init_dense(k1, cfg.d_model, cfg.n_heads * hd, dtype),
        "wk": init_dense(k2, cfg.d_model, cfg.kv_heads * hd, dtype),
        "wv": init_dense(k3, cfg.d_model, cfg.kv_heads * hd, dtype),
        "wo": init_dense(k4, cfg.n_heads * hd, cfg.d_model, dtype),
    }


def _qkv(params: Dict, x: jnp.ndarray, positions: jnp.ndarray,
         cfg: ModelConfig) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    b, s, _ = x.shape
    hd = cfg.head_dim
    q = (x @ params["wq"]).reshape(b, s, cfg.n_heads, hd)
    k = (x @ params["wk"]).reshape(b, s, cfg.kv_heads, hd)
    v = (x @ params["wv"]).reshape(b, s, cfg.kv_heads, hd)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    return q, k, v


def _chunked_attend(q, k, v, positions, cfg: ModelConfig,
                    window: Optional[int], q_chunk: int) -> jnp.ndarray:
    """Flash-style q-chunked causal attention core, flat-head layout.

    q: (B, S, H, hd); k, v: (B, S, KV, hd) - KV heads are broadcast to H
    (GQA semantics: query head h reads kv head h // q_per_kv), which keeps
    every activation 4-D with a head axis shardable over the TP mesh axis
    ("act_bshd" rule); 40-head archs that don't divide the axis fall back to
    sequence (context-parallel) sharding of k/v instead ("act_kv_seq").
    """
    b, s = q.shape[0], q.shape[1]
    hd = q.shape[-1]
    scale = hd ** -0.5
    if cfg.q_per_kv > 1:
        k = jnp.repeat(k, cfg.q_per_kv, axis=2)    # (B, S, H, hd)
        v = jnp.repeat(v, cfg.q_per_kv, axis=2)
    k = shard(k, "act_kv")
    v = shard(v, "act_kv")
    q = shard(q, "act_q")
    q_chunk = min(q_chunk, s)
    assert s % q_chunk == 0, (s, q_chunk)
    n_chunks = s // q_chunk

    qc = q.reshape(b, n_chunks, q_chunk, cfg.n_heads, hd)
    qc = jnp.moveaxis(qc, 1, 0)                    # (C, B, qc, H, hd)
    pc = positions.reshape(b, n_chunks, q_chunk)
    pc = jnp.moveaxis(pc, 1, 0)                    # (C, B, qc)

    def one_chunk(carry, inp):
        q_i, pos_i = inp
        scores = jnp.einsum("bqhd,bshd->bhqs", q_i, k) * scale
        mask = pos_i[:, None, :, None] >= positions[:, None, None, :]
        if window is not None:
            near = (pos_i[:, None, :, None]
                    - positions[:, None, None, :]) < window
            mask = jnp.logical_and(mask, near)
        scores = jnp.where(mask, scores, NEG_INF)
        w = jax.nn.softmax(scores.astype(jnp.float32), axis=-1).astype(q.dtype)
        out_i = jnp.einsum("bhqs,bshd->bqhd", w, v)
        return carry, out_i

    _, out = jax.lax.scan(one_chunk, None, (qc, pc))
    return jnp.moveaxis(out, 0, 1).reshape(b, s, cfg.n_heads * hd)


def attention(params: Dict, x: jnp.ndarray, positions: jnp.ndarray,
              cfg: ModelConfig, *, window: Optional[int] = None,
              q_chunk: int = 1024, use_flash: bool = False) -> jnp.ndarray:
    """Causal (optionally sliding-window) self-attention.

    x: (B, S, d) -> (B, S, d).  positions: (B, S) absolute positions.
    use_flash routes full-causal attention through the Pallas flash kernel
    (TPU target; interpret on CPU) - the beyond-paper prefill optimisation.
    """
    b, s, _ = x.shape
    q, k, v = _qkv(params, x, positions, cfg)
    if use_flash and window is None:
        from repro.kernels import ops as kops
        g = cfg.q_per_kv
        if g > 1:
            k = jnp.repeat(k, g, axis=2)
            v = jnp.repeat(v, g, axis=2)
        hd = cfg.head_dim
        fold = lambda t: t.transpose(0, 2, 1, 3).reshape(
            b * cfg.n_heads, s, hd)
        out = kops.flash_attention(fold(q), fold(k), fold(v), causal=True)
        out = out.reshape(b, cfg.n_heads, s, hd).transpose(0, 2, 1, 3)
        return out.reshape(b, s, cfg.n_heads * hd) @ params["wo"]
    out = _chunked_attend(q, k, v, positions, cfg, window, q_chunk)
    return out @ params["wo"]


def attention_prefill(params: Dict, x: jnp.ndarray, positions: jnp.ndarray,
                      cfg: ModelConfig, cache_len: int, *,
                      window: Optional[int] = None, q_chunk: int = 1024,
                      cache_dtype=jnp.bfloat16) -> Tuple[jnp.ndarray, Dict]:
    """Full forward that also emits the KV cache for subsequent decode.

    Full-attention caches are laid out [0..S) with tail zeros; sliding-window
    caches are ring buffers (slot = pos % window) matching attention_decode.
    """
    b, s, _ = x.shape
    q, k, v = _qkv(params, x, positions, cfg)
    out = _chunked_attend(q, k, v, positions, cfg, window, q_chunk)

    if window is not None:
        w_eff = min(window, s)
        slots = (jnp.arange(s - w_eff, s)) % cache_len
        cache_k = jnp.zeros((b, cache_len) + k.shape[2:], cache_dtype)
        cache_v = jnp.zeros_like(cache_k)
        cache_k = cache_k.at[:, slots].set(k[:, -w_eff:].astype(cache_dtype))
        cache_v = cache_v.at[:, slots].set(v[:, -w_eff:].astype(cache_dtype))
    else:
        pad = cache_len - s
        cache_k = jnp.pad(k.astype(cache_dtype), ((0, 0), (0, pad), (0, 0), (0, 0)))
        cache_v = jnp.pad(v.astype(cache_dtype), ((0, 0), (0, pad), (0, 0), (0, 0)))
    return out @ params["wo"], {"k": cache_k, "v": cache_v}


# ---------------------------------------------------------------------------
# Decode path (one new token against a KV cache)
# ---------------------------------------------------------------------------

def init_kv_cache(batch: int, cache_len: int, cfg: ModelConfig,
                  dtype=jnp.bfloat16) -> Dict:
    hd = cfg.head_dim
    return {
        "k": jnp.zeros((batch, cache_len, cfg.kv_heads, hd), dtype=dtype),
        "v": jnp.zeros((batch, cache_len, cfg.kv_heads, hd), dtype=dtype),
    }


def attention_decode(params: Dict, x_t: jnp.ndarray, cache: Dict,
                     pos: jnp.ndarray, cfg: ModelConfig, *,
                     window: Optional[int] = None
                     ) -> Tuple[jnp.ndarray, Dict]:
    """One decode step.  x_t: (B, 1, d); pos: scalar OR (B,) positions.

    Per-slot positions are what enable continuous batching: each sequence in
    the batch advances independently (new admissions restart at 0 while
    others keep generating).  Full-attention caches hold the whole context;
    sliding-window caches are ring buffers of length `window` - the
    sub-quadratic long-context path.
    """
    b = x_t.shape[0]
    hd = cfg.head_dim
    cache_len = cache["k"].shape[1]
    pos_b = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (b,))
    positions = pos_b[:, None]
    q, k_t, v_t = _qkv(params, x_t, positions, cfg)

    slot_b = pos_b % cache_len if window is not None else pos_b
    upd = jax.vmap(
        lambda c, kt, s: jax.lax.dynamic_update_slice_in_dim(
            c, kt, s, axis=0))
    k = upd(cache["k"], k_t.astype(cache["k"].dtype), slot_b)
    v = upd(cache["v"], v_t.astype(cache["v"].dtype), slot_b)
    k = shard(k, "act_cache")
    v = shard(v, "act_cache")

    g = cfg.q_per_kv
    q = q.reshape(b, 1, cfg.kv_heads, g, hd)
    scores = jnp.einsum("bqkgd,bskd->bkgqs", q, k) * (hd ** -0.5)

    slot_ids = jnp.arange(cache_len)[None, :]     # (1, S)
    if window is not None:
        # ring buffer: valid entries are the last min(pos+1, window) writes
        age = (slot_b[:, None] - slot_ids) % cache_len   # 0 = newest
        valid = age < jnp.minimum(pos_b + 1, cache_len)[:, None]
    else:
        valid = slot_ids <= pos_b[:, None]
    scores = jnp.where(valid[:, None, None, None, :], scores, NEG_INF)
    w = jax.nn.softmax(scores.astype(jnp.float32), axis=-1).astype(x_t.dtype)
    out = jnp.einsum("bkgqs,bskd->bqkgd", w, v).reshape(b, 1, cfg.n_heads * hd)
    return out @ params["wo"], {"k": k, "v": v}

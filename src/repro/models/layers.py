"""Common neural layers, functional style (params are plain dict pytrees)."""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp


def rms_norm(x: jnp.ndarray, weight: jnp.ndarray, eps: float) -> jnp.ndarray:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * (1.0 + weight.astype(jnp.float32))).astype(dtype)


def init_dense(key, d_in: int, d_out: int, dtype) -> jnp.ndarray:
    scale = 1.0 / jnp.sqrt(d_in)
    return (jax.random.normal(key, (d_in, d_out), dtype=jnp.float32)
            * scale).astype(dtype)


def swiglu_ffn_init(key, d_model: int, d_ff: int, dtype) -> Dict:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "gate": init_dense(k1, d_model, d_ff, dtype),
        "up": init_dense(k2, d_model, d_ff, dtype),
        "down": init_dense(k3, d_ff, d_model, dtype),
    }


def swiglu_ffn(params: Dict, x: jnp.ndarray) -> jnp.ndarray:
    h = jax.nn.silu(x @ params["gate"]) * (x @ params["up"])
    return h @ params["down"]


def rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """Rotary embedding.  x: (..., S, H, D), positions: (..., S)."""
    d = x.shape[-1]
    half = d // 2
    freqs = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, half)
    angles = angles[..., None, :]                              # (..., S, 1, half)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def softcap(logits: jnp.ndarray, cap: float | None) -> jnp.ndarray:
    if cap is None:
        return logits
    return cap * jnp.tanh(logits / cap)

"""Mixture-of-Experts layer with group-local capacity dispatch (EP-shardable).

Dispatch is performed *per data-parallel group* (the production EP pattern):
tokens are routed, sorted and scattered into an (E, C_group, d) buffer using
only group-local indices - a batched scatter whose operand, update and index
tensors all shard over the group axis, so GSPMD keeps it communication-free.
The only cross-device exchange is the (g, E, C, d) -> expert-sharded
boundary, which lowers to the canonical MoE all_to_all over the TP/EP axis.

(The first implementation used globally-indexed scatter/segment_sum; GSPMD
could not prove locality and lowered it to full-tensor all-reduces - 8.6 GB
per op per layer on the phi3.5 cell.  The group-local rewrite cut the
dry-run collective term ~100x; see EXPERIMENTS.md S-Perf iteration 2.)

Tokens over a group's capacity are dropped (residual passes through),
matching capacity-bounded MoE semantics per device.
"""
from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import init_dense
from repro.sharding import shard
from repro.sharding.api import get_meta


def init_moe(key, cfg: ModelConfig, dtype) -> Dict:
    kr, kg, ku, kd, ks = jax.random.split(key, 5)
    e, d, f = cfg.n_experts, cfg.d_model, cfg.d_ff
    scale_in = 1.0 / math.sqrt(d)
    scale_out = 1.0 / math.sqrt(f)
    params = {
        "router": init_dense(kr, d, e, jnp.float32),
        "gate": (jax.random.normal(kg, (e, d, f), dtype=jnp.float32)
                 * scale_in).astype(dtype),
        "up": (jax.random.normal(ku, (e, d, f), dtype=jnp.float32)
               * scale_in).astype(dtype),
        "down": (jax.random.normal(kd, (e, f, d), dtype=jnp.float32)
                 * scale_out).astype(dtype),
    }
    if cfg.shared_expert:
        from repro.models.layers import swiglu_ffn_init
        params["shared"] = swiglu_ffn_init(ks, d, f, dtype)
    return params


def capacity(n_tokens: int, cfg: ModelConfig) -> int:
    c = int(math.ceil(n_tokens * cfg.top_k / cfg.n_experts
                      * cfg.capacity_factor))
    if c >= 8:
        return -(-c // 8) * 8       # pad to 8 for layout friendliness
    return max(1, c)                # decode-sized groups: no padded floor


def effective_groups(n_tokens: int, g: int) -> int:
    """Shrink the group count for small token batches (decode): with E
    experts and a handful of tokens per group, per-group capacity padding
    would multiply expert compute by ~E/tokens (measured 33x useful-flops
    regression on llama4 decode before this guard)."""
    while g > 1 and (n_tokens % g or n_tokens // g < 64):
        g //= 2
    return g


def moe_ffn(params: Dict, x: jnp.ndarray, cfg: ModelConfig
            ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B, S, d) -> (out (B, S, d), aux_loss scalar)."""
    b, s, d = x.shape
    t = b * s
    e, k = cfg.n_experts, cfg.top_k
    g = effective_groups(t, get_meta("dp_groups", 1))
    tl = t // g
    cap = capacity(tl, cfg)
    xf = x.reshape(t, d)

    logits = (xf.astype(jnp.float32) @ params["router"])      # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_w, gate_i = jax.lax.top_k(probs, k)                  # (T, k)
    gate_w = gate_w / jnp.sum(gate_w, axis=-1, keepdims=True)

    # load-balancing auxiliary loss (Switch-style; global statistics)
    me = jnp.mean(probs, axis=0)
    ce = jnp.mean(jax.nn.one_hot(gate_i[:, 0], e, dtype=jnp.float32), axis=0)
    aux = e * jnp.sum(me * ce)

    # ---- group-local dispatch (batched over g; no cross-group indices) ----
    xg = xf.reshape(g, tl, d)
    ei = gate_i.reshape(g, tl * k)
    ew = gate_w.reshape(g, tl * k).astype(x.dtype)

    def dispatch_one(xg_i, ei_i):
        order = jnp.argsort(ei_i)                             # (tl*k,)
        se = ei_i[order]
        st = order // k                                       # token of slot
        seg_starts = jnp.searchsorted(se, jnp.arange(e))
        rank = jnp.arange(tl * k) - seg_starts[se]
        keep = rank < cap
        dest = jnp.where(keep, se * cap + rank, e * cap)
        buf = jnp.zeros((e * cap + 1, d), dtype=xg_i.dtype)
        buf = buf.at[dest].set(xg_i[st])
        return buf[:e * cap].reshape(e, cap, d), order, st, keep, dest

    buf, order, st, keep, dest = jax.vmap(dispatch_one)(xg, ei)
    buf = shard(buf, "act_gecd")      # EP boundary: all_to_all to E-sharding

    # ---- expert computation: batched GEMMs, sharded over E ----
    h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", buf, params["gate"]))
    h = h * jnp.einsum("gecd,edf->gecf", buf, params["up"])
    y = jnp.einsum("gecf,efd->gecd", h, params["down"])       # (g, E, C, d)
    y = shard(y, "act_gecd")

    # ---- group-local combine ----
    def combine_one(y_i, order_i, st_i, keep_i, dest_i, ew_i):
        yf = jnp.concatenate(
            [y_i.reshape(e * cap, d),
             jnp.zeros((1, d), dtype=y_i.dtype)], axis=0)
        w = jnp.where(keep_i, ew_i[order_i], 0.0)
        contrib = yf[dest_i] * w[:, None]
        return jax.ops.segment_sum(contrib, st_i, num_segments=tl)

    out = jax.vmap(combine_one)(y, order, st, keep, dest, ew)  # (g, tl, d)
    out = out.reshape(t, d)

    if cfg.shared_expert:
        from repro.models.layers import swiglu_ffn
        out = out + swiglu_ffn(params["shared"], xf)
    return out.reshape(b, s, d), aux

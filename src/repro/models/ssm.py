"""Mamba-2 SSD (state-space duality) layer - arXiv:2405.21060.

Training/prefill uses the chunked SSD algorithm: the sequence is split into
chunks; within a chunk the output is a masked quadratic form (the 'attention
mode' of the duality), across chunks a compact (H, P, N) state is passed
recurrently (the 'SSM mode').  Decode carries the state one token at a time.

Per-head scalar A (the Mamba-2 simplification), G=1 B/C group, depthwise
conv on the (x, B, C) projections, gated RMSNorm output - faithful to the
reference architecture at the block level.
"""
from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import init_dense, rms_norm


def _dims(cfg: ModelConfig):
    d_inner = cfg.ssm_expand * cfg.d_model
    n_heads = d_inner // cfg.ssm_head_dim
    return d_inner, n_heads, cfg.ssm_head_dim, cfg.ssm_state


def init_ssm(key, cfg: ModelConfig, dtype) -> Dict:
    d_inner, h, p, n = _dims(cfg)
    d = cfg.d_model
    keys = jax.random.split(key, 6)
    conv_dim = d_inner + 2 * n   # conv over (x, B, C)
    return {
        # in_proj emits (z, x, B, C, dt)
        "in_proj": init_dense(keys[0], d, 2 * d_inner + 2 * n + h, dtype),
        "conv_w": (jax.random.normal(keys[1], (cfg.conv_kernel, conv_dim),
                                     dtype=jnp.float32) / math.sqrt(cfg.conv_kernel)
                   ).astype(dtype),
        "a_log": jnp.zeros((h,), dtype=jnp.float32),
        "dt_bias": jnp.zeros((h,), dtype=jnp.float32),
        "d_skip": jnp.ones((h,), dtype=jnp.float32),
        "norm_w": jnp.zeros((d_inner,), dtype=jnp.float32),
        "out_proj": init_dense(keys[2], d_inner, d, dtype),
    }


def _split_proj(params, x, cfg):
    d_inner, h, p, n = _dims(cfg)
    zxbcdt = x @ params["in_proj"]
    z, xs, bc, dt = jnp.split(
        zxbcdt, [d_inner, 2 * d_inner, 2 * d_inner + 2 * n], axis=-1)
    return z, xs, bc, dt


def _causal_conv(u: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """Depthwise causal conv1d.  u: (B, S, C), w: (K, C)."""
    k = w.shape[0]
    pad = jnp.pad(u, ((0, 0), (k - 1, 0), (0, 0)))
    out = jnp.zeros_like(u)
    for i in range(k):
        out = out + pad[:, i:i + u.shape[1], :] * w[i]
    return jax.nn.silu(out)


def ssm_forward(params: Dict, x: jnp.ndarray, cfg: ModelConfig,
                return_cache: bool = False):
    """Chunked SSD, full sequence.  x: (B, S, d) -> (B, S, d)."""
    b, s, _ = x.shape
    d_inner, h, p, n = _dims(cfg)
    z, xs, bc, dt = _split_proj(params, x, cfg)
    conv_in = jnp.concatenate([xs, bc], axis=-1)
    conv_out = _causal_conv(conv_in, params["conv_w"])
    xs, bmat, cmat = jnp.split(conv_out, [d_inner, d_inner + n], axis=-1)
    xh = xs.reshape(b, s, h, p)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])  # (B,S,H)
    a = -jnp.exp(params["a_log"])                                     # (H,)
    la = dt * a[None, None, :]             # log decay per step, <= 0
    xbar = xh * dt[..., None].astype(xh.dtype)

    ch = cfg.ssm_chunk
    ch = min(ch, s)
    assert s % ch == 0
    nc = s // ch
    # reshape into chunks
    xbar = xbar.reshape(b, nc, ch, h, p)
    bmat_c = bmat.reshape(b, nc, ch, n)
    cmat_c = cmat.reshape(b, nc, ch, n)
    la_c = la.reshape(b, nc, ch, h)
    cum = jnp.cumsum(la_c, axis=2)                 # (B, NC, ch, H)
    total = cum[:, :, -1, :]                       # (B, NC, H)

    # ---- intra-chunk (quadratic/'attention' mode) ----
    # L[i, j] = exp(cum_i - cum_j) for i >= j
    diff = cum[:, :, :, None, :] - cum[:, :, None, :, :]   # (B,NC,i,j,H)
    mask = jnp.tril(jnp.ones((ch, ch), dtype=bool))
    decay = jnp.where(mask[None, None, :, :, None], jnp.exp(diff), 0.0)
    cb = jnp.einsum("bcin,bcjn->bcij", cmat_c, bmat_c)     # (B,NC,i,j)
    y_intra = jnp.einsum("bcij,bcijh,bcjhp->bcihp",
                         cb.astype(jnp.float32), decay,
                         xbar.astype(jnp.float32))

    # ---- inter-chunk states ----
    # state_c = sum_j exp(total - cum_j) * B_j^T xbar_j
    w_in = jnp.exp(total[:, :, None, :] - cum)             # (B,NC,ch,H)
    state_c = jnp.einsum("bcjn,bcjh,bcjhp->bchpn", bmat_c.astype(jnp.float32),
                         w_in, xbar.astype(jnp.float32))   # per-chunk update

    def scan_states(prev, inp):
        upd, tot = inp                                     # (B,H,P,N), (B,H)
        new = prev * jnp.exp(tot)[:, :, None, None] + upd
        return new, prev                                   # emit incoming state

    upd_seq = jnp.moveaxis(state_c, 1, 0)                  # (NC,B,H,P,N)
    tot_seq = jnp.moveaxis(total, 1, 0)                    # (NC,B,H)
    init = jnp.zeros((b, h, p, n), dtype=jnp.float32)
    final_state, in_states = jax.lax.scan(scan_states, init, (upd_seq, tot_seq))
    in_states = jnp.moveaxis(in_states, 0, 1)              # (B,NC,H,P,N)

    # ---- inter-chunk contribution: C_i exp(cum_i) state_in ----
    w_out = jnp.exp(cum)                                   # (B,NC,ch,H)
    y_inter = jnp.einsum("bcin,bcih,bchpn->bcihp",
                         cmat_c.astype(jnp.float32), w_out, in_states)

    y = (y_intra + y_inter).reshape(b, s, h, p)
    y = y + params["d_skip"][None, None, :, None] * xh.astype(jnp.float32)
    y = y.reshape(b, s, d_inner).astype(x.dtype)
    # gated RMSNorm then out projection
    y = rms_norm(y * jax.nn.silu(z), params["norm_w"], cfg.norm_eps)
    out = y @ params["out_proj"]
    if not return_cache:
        return out
    k = cfg.conv_kernel
    cache = {"state": final_state, "conv": conv_in[:, -(k - 1):, :]}
    return out, cache


# ---------------------------------------------------------------------------
# Decode path: recurrent state + conv ring buffer
# ---------------------------------------------------------------------------

def init_ssm_cache(batch: int, cfg: ModelConfig, dtype=jnp.float32) -> Dict:
    d_inner, h, p, n = _dims(cfg)
    conv_dim = d_inner + 2 * n
    return {
        "state": jnp.zeros((batch, h, p, n), dtype=jnp.float32),
        "conv": jnp.zeros((batch, cfg.conv_kernel - 1, conv_dim), dtype=dtype),
    }


def ssm_decode(params: Dict, x_t: jnp.ndarray, cache: Dict,
               cfg: ModelConfig) -> Tuple[jnp.ndarray, Dict]:
    """One token.  x_t: (B, 1, d)."""
    b = x_t.shape[0]
    d_inner, h, p, n = _dims(cfg)
    z, xs, bc, dt = _split_proj(params, x_t, cfg)
    conv_in = jnp.concatenate([xs, bc], axis=-1)           # (B,1,conv_dim)
    hist = jnp.concatenate([cache["conv"], conv_in], axis=1)  # (B,K,conv)
    w = params["conv_w"]
    conv_out = jax.nn.silu(jnp.sum(hist * w[None], axis=1, keepdims=True))
    new_conv = hist[:, 1:, :]
    xs, bmat, cmat = jnp.split(conv_out, [d_inner, d_inner + n], axis=-1)
    xh = xs.reshape(b, h, p)
    dt_t = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + params["dt_bias"])  # (B,H)
    a = -jnp.exp(params["a_log"])
    decay = jnp.exp(dt_t * a[None, :])                     # (B,H)
    xbar = xh.astype(jnp.float32) * dt_t[..., None]
    upd = jnp.einsum("bn,bhp->bhpn", bmat[:, 0].astype(jnp.float32), xbar)
    state = cache["state"] * decay[:, :, None, None] + upd
    y = jnp.einsum("bn,bhpn->bhp", cmat[:, 0].astype(jnp.float32), state)
    y = y + params["d_skip"][None, :, None] * xh.astype(jnp.float32)
    y = y.reshape(b, 1, d_inner).astype(x_t.dtype)
    y = rms_norm(y * jax.nn.silu(z), params["norm_w"], cfg.norm_eps)
    return y @ params["out_proj"], {"state": state, "conv": new_conv}

"""Serving steps: jit-able prefill and decode, the dry-run lowering targets.

decode_* shapes lower `serve_step` (one new token against a cache of
seq_len), prefill_* shapes lower the full prompt forward - per the brief.
"""
from __future__ import annotations

from functools import partial
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import transformer as tr


def make_prefill_step(cfg: ModelConfig, cache_len: Optional[int] = None):
    """prefill(params, tokens|embeds) -> (next_token, cache)."""

    def prefill_step(params, batch: Dict):
        logits, cache = tr.prefill(
            params, cfg, tokens=batch.get("tokens"),
            embeds=batch.get("embeds"), cache_len=cache_len)
        next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return next_tok, cache

    return prefill_step


def make_decode_step(cfg: ModelConfig, temperature: float = 0.0):
    """decode(params, cache, token, pos, key) -> (token, cache)."""

    def decode_step(params, cache, tokens_t, pos, key):
        logits, cache = tr.decode_step(params, cache, tokens_t, pos, cfg)
        if temperature > 0.0:
            logits = logits / temperature
            nxt = jax.random.categorical(key, logits.astype(jnp.float32),
                                         axis=-1)
        else:
            nxt = jnp.argmax(logits, axis=-1)
        return nxt.astype(jnp.int32), cache

    return decode_step

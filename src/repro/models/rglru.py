"""RG-LRU recurrent block (RecurrentGemma / Griffin, arXiv:2402.19427).

Real-gated linear recurrent unit:
    r_t = sigmoid(W_a u_t + b_a)          (recurrence gate)
    i_t = sigmoid(W_x u_t + b_x)          (input gate)
    log a_t = -c * softplus(Lambda) * r_t (c = 8)
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * u_t)

Block: two branches - GeLU(W1 x) and conv1d->RG-LRU(W2 x) - merged
multiplicatively then projected out.  Prefill uses an associative scan
(log-depth on TPU); decode is the one-step recurrence with an (B, width)
state - the constant-memory path that makes long_500k feasible.
"""
from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import init_dense

_C = 8.0


def _width(cfg: ModelConfig) -> int:
    return cfg.lru_width or cfg.d_model


def init_rglru(key, cfg: ModelConfig, dtype) -> Dict:
    w = _width(cfg)
    d = cfg.d_model
    keys = jax.random.split(key, 6)
    return {
        "w_y": init_dense(keys[0], d, w, dtype),
        "w_u": init_dense(keys[1], d, w, dtype),
        "conv_w": (jax.random.normal(keys[2], (cfg.conv_kernel, w),
                                     dtype=jnp.float32)
                   / math.sqrt(cfg.conv_kernel)).astype(dtype),
        "w_a": init_dense(keys[3], w, w, dtype),
        "w_x": init_dense(keys[4], w, w, dtype),
        "lam": jnp.full((w,), 2.0, dtype=jnp.float32),   # softplus(2) ~ 2.1
        "w_o": init_dense(keys[5], w, d, dtype),
    }


def _gates(params: Dict, u: jnp.ndarray):
    r = jax.nn.sigmoid((u @ params["w_a"]).astype(jnp.float32))
    i = jax.nn.sigmoid((u @ params["w_x"]).astype(jnp.float32))
    log_a = -_C * jax.nn.softplus(params["lam"]) * r
    a = jnp.exp(log_a)
    beta = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-9))
    b = beta * (i * u.astype(jnp.float32))
    return a, b


def _causal_conv(u: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    k = w.shape[0]
    pad = jnp.pad(u, ((0, 0), (k - 1, 0), (0, 0)))
    out = jnp.zeros_like(u)
    for i in range(k):
        out = out + pad[:, i:i + u.shape[1], :] * w[i]
    return out


def rglru_forward(params: Dict, x: jnp.ndarray, cfg: ModelConfig,
                  return_cache: bool = False):
    """Full-sequence forward via associative scan.  x: (B, S, d)."""
    y = jax.nn.gelu(x @ params["w_y"])
    u_in = x @ params["w_u"]
    u = _causal_conv(u_in, params["conv_w"])
    a, b = _gates(params, u)                       # (B, S, W) f32

    def combine(l, r):
        al, bl = l
        ar, br = r
        return al * ar, ar * bl + br

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    out = (y * h.astype(x.dtype)) @ params["w_o"]
    if not return_cache:
        return out
    k = cfg.conv_kernel
    cache = {"h": h[:, -1],
             "conv": u_in[:, -(k - 1):, :]}        # conv history tail
    return out, cache


def init_rglru_cache(batch: int, cfg: ModelConfig, dtype=jnp.float32) -> Dict:
    w = _width(cfg)
    return {
        "h": jnp.zeros((batch, w), dtype=jnp.float32),
        "conv": jnp.zeros((batch, cfg.conv_kernel - 1, w), dtype=dtype),
    }


def rglru_decode(params: Dict, x_t: jnp.ndarray, cache: Dict,
                 cfg: ModelConfig) -> Tuple[jnp.ndarray, Dict]:
    """One token.  x_t: (B, 1, d)."""
    y = jax.nn.gelu(x_t @ params["w_y"])
    u_in = x_t @ params["w_u"]                      # (B, 1, W)
    hist = jnp.concatenate([cache["conv"], u_in], axis=1)
    u = jnp.sum(hist * params["conv_w"][None], axis=1, keepdims=True)
    a, b = _gates(params, u)                        # (B, 1, W)
    h = a[:, 0] * cache["h"] + b[:, 0]
    out = (y * h[:, None].astype(x_t.dtype)) @ params["w_o"]
    return out, {"h": h, "conv": hist[:, 1:, :]}

from repro.train.train_step import (  # noqa: F401
    TrainState, init_train_state, make_train_step, loss_fn)

"""Pipeline parallelism: GPipe-style stage execution over a mesh axis.

The production mesh's "pod" axis can host pipeline stages instead of data
parallelism: stage s holds layers [s*L/S, (s+1)*L/S), microbatches stream
through the ring via `ppermute`, and every device executes the same SPMD
program under `shard_map` (stage identity = axis index).  The schedule is
the classic GPipe fill/steady/drain: M microbatches over S stages complete
in M + S - 1 ticks; differentiability comes for free because ppermute's
transpose is the reverse permute, so `jax.grad` through `pipeline_apply`
yields pipeline-parallel backprop (full activation stash per in-flight
microbatch - 1F1B scheduling is a memory optimisation left to future work).

Stages must be shape-preserving ((B, S, d) -> (B, S, d)), which transformer
blocks are.  Exercised on a host mesh in tests/test_pipeline.py.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp


def pipeline_apply(stage_fn: Callable, stage_params, x_micro: jnp.ndarray,
                   axis_name: str) -> jnp.ndarray:
    """Run M microbatches through S pipeline stages on `axis_name`.

    Must be called inside shard_map with `axis_name` mapped.

    Args:
      stage_fn: (params_local, x) -> y, shape-preserving.
      stage_params: this device's stage parameters.
      x_micro: (M, ...) microbatch inputs (read on stage 0).
    Returns:
      (M, ...) final-stage outputs (meaningful on the LAST stage; zeros
      elsewhere - callers psum or slice).
    """
    s_idx = jax.lax.axis_index(axis_name)
    # jax.lax.axis_size only exists in newer jax; psum(1) is the portable way
    # to read a mapped axis size.
    n_stages = jax.lax.psum(1, axis_name)
    m = x_micro.shape[0]
    n_ticks = m + n_stages - 1
    fwd_perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]

    recv0 = jnp.zeros_like(x_micro[0])
    outs0 = jnp.zeros_like(x_micro)

    def tick(carry, t):
        recv, outs = carry
        # stage 0 ingests microbatch min(t, m-1) (ignored once t >= m);
        # later stages take what arrived on the ring.
        mb = jnp.clip(t, 0, m - 1)
        inj = jax.lax.dynamic_index_in_dim(x_micro, mb, keepdims=False)
        x_in = jnp.where(s_idx == 0, inj, recv)
        y = stage_fn(stage_params, x_in)
        # the last stage banks microbatch (t - S + 1)'s result when valid
        done_idx = jnp.clip(t - (n_stages - 1), 0, m - 1)
        is_done = jnp.logical_and(s_idx == n_stages - 1, t >= n_stages - 1)
        prev = jax.lax.dynamic_index_in_dim(outs, done_idx, keepdims=False)
        outs = jax.lax.dynamic_update_index_in_dim(
            outs, jnp.where(is_done, y, prev), done_idx, 0)
        # pass activations to the next stage (last -> 0 wraps, stage 0 ignores)
        recv = jax.lax.ppermute(y, axis_name, fwd_perm)
        return (recv, outs), None

    (_, outs), _ = jax.lax.scan(tick, (recv0, outs0), jnp.arange(n_ticks))
    return outs


def split_stages(layer_params, n_stages: int):
    """Split a stacked (L, ...) layer-param pytree into (S, L/S, ...)."""

    def split(x):
        l = x.shape[0]
        assert l % n_stages == 0, (l, n_stages)
        return x.reshape(n_stages, l // n_stages, *x.shape[1:])

    return jax.tree.map(split, layer_params)

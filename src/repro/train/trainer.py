"""Training loop: data -> step -> metrics, with checkpoint/restart, watchdog,
and optional BlockAMC-preconditioned second-order updates.

This is the single-process driver; launch/train.py wraps it with mesh setup
and sharded state placement for pod runs.
"""
from __future__ import annotations

import dataclasses
import logging
import time
from typing import Callable, Dict, Optional

import jax

from repro.checkpoint.ckpt import (CheckpointManager, latest_step,
                                   restore_checkpoint)
from repro.configs.base import ModelConfig, RunConfig
from repro.data.pipeline import SyntheticLM
from repro.optim.adamw import AdamW
from repro.runtime.fault_tolerance import StepWatchdog, retry_step
from repro.train.train_step import TrainState, init_train_state, make_train_step

log = logging.getLogger("repro.train")


@dataclasses.dataclass
class Trainer:
    model_cfg: ModelConfig
    run_cfg: RunConfig
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 50
    seed: int = 0
    log_every: int = 10
    on_metrics: Optional[Callable[[int, Dict], None]] = None

    def __post_init__(self):
        self.state, self.opt = init_train_state(
            jax.random.PRNGKey(self.seed), self.model_cfg, self.run_cfg)
        self.step_fn = jax.jit(make_train_step(
            self.model_cfg, self.run_cfg, self.opt), donate_argnums=(0,))
        self.data = SyntheticLM(self.model_cfg, self.run_cfg, seed=self.seed)
        self.start_step = 0
        self.ckpt_mgr = None
        if self.ckpt_dir is not None:
            self.ckpt_mgr = CheckpointManager(self.ckpt_dir, self.ckpt_every)
            last = latest_step(self.ckpt_dir)
            if last is not None:
                log.info("resuming from checkpoint step %d", last)
                self.state = restore_checkpoint(self.ckpt_dir, last, self.state)
                self.start_step = last

    def run(self, n_steps: int) -> Dict[str, list]:
        history: Dict[str, list] = {"loss": [], "step": [], "dt": []}
        watchdog = StepWatchdog()
        for step in range(self.start_step, self.start_step + n_steps):
            batch = self.data.batch(step)
            t0 = time.monotonic()
            with watchdog:
                self.state, metrics = retry_step(
                    lambda: self.step_fn(self.state, batch))
            loss = float(metrics["loss"])
            dt = time.monotonic() - t0
            history["loss"].append(loss)
            history["step"].append(step)
            history["dt"].append(dt)
            if self.on_metrics:
                self.on_metrics(step, metrics)
            if step % self.log_every == 0:
                log.info("step %d loss %.4f (%.2fs)", step, loss, dt)
            if self.ckpt_mgr is not None:
                self.ckpt_mgr.maybe_save(step + 1, self.state)
        if self.ckpt_mgr is not None:
            self.ckpt_mgr.wait()
        return history

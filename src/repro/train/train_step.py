"""Training step: loss, grads, microbatch accumulation, optimizer update.

The step is a pure function suitable for pjit on the production mesh:
activations carry `shard()` constraints from the model, parameters carry
NamedShardings assigned by sharding/partition.py, and XLA inserts the
gradient all-reduces.  Microbatching (gradient accumulation) runs as a
lax.scan over batch slices so arbitrarily large global batches fit HBM;
XLA's latency-hiding scheduler overlaps microbatch k+1's compute with
microbatch k's reduction.
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, RunConfig
from repro.models import transformer as tr
from repro.optim.adamw import AdamW, OptState
from repro.optim.schedule import warmup_cosine

AUX_WEIGHT = 0.01   # MoE load-balance loss weight


class TrainState(NamedTuple):
    params: Any
    opt: OptState


def init_train_state(key, model_cfg: ModelConfig, run_cfg: RunConfig,
                     opt: Optional[AdamW] = None) -> Tuple[TrainState, AdamW]:
    if opt is None:
        opt = AdamW(lr=run_cfg.learning_rate,
                    moments_dtype={"float32": jnp.float32,
                                   "bfloat16": jnp.bfloat16}[run_cfg.moments_dtype])
    params = tr.init_params(key, model_cfg)
    return TrainState(params=params, opt=opt.init(params)), opt


def loss_fn(params, batch: Dict, model_cfg: ModelConfig,
            remat: str = "none") -> Tuple[jnp.ndarray, Dict]:
    """Next-token cross entropy (+ MoE aux)."""
    logits, aux = tr.forward(
        params, model_cfg,
        tokens=batch.get("tokens"),
        embeds=batch.get("embeds"),
        remat=remat)
    labels = batch["labels"]                        # (B, S) int32, -1 = pad
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    # select label logits via a fused one-hot reduce rather than
    # take_along_axis: the gather would force an all-gather of the
    # vocab-sharded logits; the masked reduce stays sharded + psums a scalar.
    vocab_ids = jax.lax.broadcasted_iota(jnp.int32, logits.shape,
                                         logits.ndim - 1)
    onehot = vocab_ids == jnp.maximum(labels, 0)[..., None]
    ll = jnp.sum(jnp.where(onehot, logits, 0.0), axis=-1)
    mask = (labels >= 0).astype(jnp.float32)
    denom = jnp.maximum(jnp.sum(mask), 1.0)
    ce = jnp.sum((lse - ll) * mask) / denom
    loss = ce + AUX_WEIGHT * aux
    return loss, {"ce": ce, "aux": aux}


def make_train_step(model_cfg: ModelConfig, run_cfg: RunConfig, opt: AdamW,
                    grad_shardings=None):
    """Returns step(state, batch) -> (state, metrics).

    grad_shardings: optional pytree of NamedShardings matching params.
    Constraining per-microbatch gradients to the parameter sharding turns
    the batch-axis reduction into a reduce-scatter fused with accumulation
    (ZeRO-style) instead of an all-reduce of replicated full gradients -
    measured ~50x collective-bytes reduction on the MoE cells (S-Perf).
    """
    n_micro = 1
    if run_cfg.microbatch is not None:
        assert run_cfg.global_batch % run_cfg.microbatch == 0
        n_micro = run_cfg.global_batch // run_cfg.microbatch

    grad_fn = jax.value_and_grad(
        lambda p, b: loss_fn(p, b, model_cfg, run_cfg.remat), has_aux=True)

    def constrain(grads):
        if grad_shardings is None:
            return grads
        return jax.tree.map(jax.lax.with_sharding_constraint, grads,
                            grad_shardings)

    def step(state: TrainState, batch: Dict) -> Tuple[TrainState, Dict]:
        if n_micro == 1:
            (loss, metrics), grads = grad_fn(state.params, batch)
            grads = constrain(grads)
        else:
            acc_dt = {"float32": jnp.float32,
                      "bfloat16": jnp.bfloat16}[run_cfg.accum_dtype]

            def slice_micro(x):
                b = x.shape[0]
                return x.reshape(n_micro, b // n_micro, *x.shape[1:])

            micro = jax.tree.map(slice_micro, batch)

            def accum(carry, mb):
                g_acc, l_acc = carry
                (l, _), g = grad_fn(state.params, mb)
                g = constrain(g)
                g_acc = jax.tree.map(
                    lambda a, x: a + (x / n_micro).astype(acc_dt), g_acc, g)
                g_acc = constrain(g_acc)
                return (g_acc, l_acc + l / n_micro), None

            g0 = constrain(jax.tree.map(
                lambda p: jnp.zeros(p.shape, acc_dt), state.params))
            (grads, loss), _ = jax.lax.scan(accum, (g0, 0.0), micro)
            metrics = {"ce": loss, "aux": jnp.zeros((), jnp.float32)}

        lr_scale = warmup_cosine(state.opt.step)
        new_params, new_opt = opt.update(grads, state.opt, state.params,
                                         lr_scale=lr_scale)
        gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                             for g in jax.tree.leaves(grads)))
        metrics = dict(metrics, loss=loss, grad_norm=gnorm,
                       lr_scale=lr_scale)
        return TrainState(new_params, new_opt), metrics

    return step

"""Multi-pod dry-run: AOT lower + compile every (arch x shape x mesh) cell.

THE ONLY entry point that fakes 512 devices - the env var must be set before
any other import touches jax (jax locks the device count at first init).

Per cell this produces, without allocating any model-sized buffer:
  * compiled.memory_analysis()  - proof the cell fits HBM,
  * compiled.cost_analysis()    - HLO FLOPs / bytes for the roofline,
  * a collective-bytes breakdown parsed from the partitioned HLO,
  * the three roofline terms + dominant bottleneck (EXPERIMENTS.md S-Roofline).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch glm4-9b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod]
"""
import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", ""))

# ruff: noqa: E402
import argparse
import dataclasses
import json
import re
import time
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs import ARCHS, get_config
from repro.configs.base import ModelConfig, RunConfig, SHAPES
from repro.launch import hlo_analysis
from repro.launch.mesh import make_production_mesh
from repro.models import transformer as tr
from repro.optim.adamw import AdamW
from repro.models.serve_step import make_decode_step, make_prefill_step
from repro.sharding import api as shapi
from repro.sharding import partition
from repro.train.train_step import init_train_state, make_train_step

# --- TPU v5e-class hardware constants (per chip) ---
PEAK_FLOPS = 197e12          # bf16
HBM_BW = 819e9               # bytes/s
LINK_BW = 50e9               # bytes/s per ICI link (conservative single link)

ARTIFACT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                            "artifacts", "dryrun")

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1}

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")


# ---------------------------------------------------------------------------
# Run-config presets (memory-budget policy per model size; DESIGN.md S5)
# ---------------------------------------------------------------------------

def param_count(cfg: ModelConfig) -> int:
    shapes = jax.eval_shape(lambda k: tr.init_params(k, cfg),
                            jax.random.PRNGKey(0))
    return sum(int(np.prod(x.shape)) for x in jax.tree.leaves(shapes))


def active_param_count(cfg: ModelConfig) -> int:
    if cfg.n_experts == 0:
        return param_count(cfg)
    active = dataclasses.replace(cfg, n_experts=cfg.top_k)
    return param_count(active)


def make_run_config(cfg: ModelConfig, shape_key: str) -> RunConfig:
    shape = SHAPES[shape_key]
    n = param_count(cfg)
    if shape["mode"] == "train":
        if n >= 100e9:
            extra = dict(fsdp=True, moments_dtype="bfloat16",
                         microbatch=shape["global_batch"] // 16, remat="full",
                         accum_dtype="bfloat16", seq_shard=True)
        elif n >= 10e9:
            extra = dict(fsdp=True, moments_dtype="float32",
                         microbatch=shape["global_batch"] // 4, remat="full",
                         seq_shard=True)
        elif n >= 5e9:
            extra = dict(fsdp=True, moments_dtype="float32",
                         microbatch=shape["global_batch"] // 8, remat="full")
        elif n >= 2e9:
            extra = dict(fsdp=True, moments_dtype="float32",
                         microbatch=shape["global_batch"] // 4, remat="full")
        else:
            # small models: dots-remat alone saves attention scores at
            # (B_loc, H, S, S) f32 - 41 GB/chip at B=256; microbatch 4x
            extra = dict(fsdp=True, remat="dots",
                         microbatch=shape["global_batch"] // 4)
        return RunConfig(model=cfg, **shape, **extra)
    return RunConfig(model=cfg, **shape, fsdp=True, remat="none")


# ---------------------------------------------------------------------------
# Input specs (ShapeDtypeStruct stand-ins; never allocated)
# ---------------------------------------------------------------------------

def input_specs(cfg: ModelConfig, run: RunConfig) -> Dict[str, Any]:
    b, s = run.global_batch, run.seq_len
    if run.mode in ("train", "prefill"):
        if cfg.frontend == "vit_stub":
            batch = {"embeds": jax.ShapeDtypeStruct((b, s, cfg.d_model),
                                                    jnp.bfloat16)}
        else:
            batch = {"tokens": jax.ShapeDtypeStruct((b, s), jnp.int32)}
        if run.mode == "train":
            batch["labels"] = jax.ShapeDtypeStruct((b, s), jnp.int32)
        return batch
    # decode: one new token against a seq_len cache
    cache = jax.eval_shape(lambda: tr.init_cache(b, s, cfg))
    return {
        "cache": cache,
        "tokens_t": jax.ShapeDtypeStruct((b,), jnp.int32),
        "pos": jax.ShapeDtypeStruct((), jnp.int32),
    }


def skip_reason(cfg: ModelConfig, shape_key: str) -> Optional[str]:
    if shape_key == "long_500k" and not cfg.subquadratic:
        return ("full-attention arch: 500k dense-KV decode excluded by the "
                "shape key (needs sub-quadratic attention); see DESIGN.md")
    return None


# ---------------------------------------------------------------------------
# Cache shardings
# ---------------------------------------------------------------------------

def cache_shardings(cache_shapes, mesh: Mesh, rules) -> Any:
    """KV leaves: (L?, B, S, KV, hd) -> batch on data, seq on model.
    SSM/LRU states: batch on data only."""
    batch_spec = rules["act_btd"][0]

    def one(path, leaf):
        name = str(getattr(path[-1], "key", ""))
        nd = leaf.ndim
        lead = (None,) if nd >= 3 and leaf.shape[0] != 0 and _has_layer_dim(path) else ()
        core = leaf.shape[len(lead):]
        if name in ("k", "v") and len(core) == 4:
            spec = lead + (batch_spec, "model", None, None)
            # drop axes that do not divide
            spec = _fix(core, spec[len(lead):], mesh, lead)
        elif name == "state":
            spec = _fix(core, (batch_spec,) + (None,) * (len(core) - 1), mesh, lead)
        else:
            spec = _fix(core, (batch_spec,) + (None,) * (len(core) - 1), mesh, lead)
        return NamedSharding(mesh, P(*spec))

    return jax.tree_util.tree_map_with_path(one, cache_shapes)


def _has_layer_dim(path) -> bool:
    return any(str(getattr(p, "key", "")) == "blocks" for p in path)


def _axis_prod(mesh, ax):
    if ax is None:
        return 1
    if isinstance(ax, tuple):
        return int(np.prod([dict(mesh.shape)[a] for a in ax]))
    return dict(mesh.shape)[ax]


def _fix(shape, spec, mesh, lead):
    out = list(lead)
    for dim, ax in zip(shape, spec):
        out.append(ax if ax is not None and dim % _axis_prod(mesh, ax) == 0
                   else None)
    return tuple(out)


# ---------------------------------------------------------------------------
# HLO collective parsing
# ---------------------------------------------------------------------------

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _type_bytes(type_str: str) -> int:
    m = _SHAPE_RE.match(type_str)
    if not m:
        return 0
    dt, dims = m.groups()
    nbytes = _DTYPE_BYTES.get(dt, 4)
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * nbytes


def parse_collectives(hlo: str) -> Dict[str, Dict[str, float]]:
    """Sum operand bytes of every collective in the partitioned HLO."""
    out: Dict[str, Dict[str, float]] = {
        c: {"count": 0, "bytes": 0} for c in COLLECTIVES}
    for line in hlo.splitlines():
        stripped = line.strip()
        m = re.match(r"%?[\w.\-]+ = (\S+) ([\w\-]+)\((.*)", stripped)
        if not m:
            continue
        result_type, opname, rest = m.groups()
        base = opname.rstrip("-start").rstrip("-done")
        matched = None
        for c in COLLECTIVES:
            if opname == c or opname == c + "-start" or base == c:
                matched = c
                break
        if matched is None:
            continue
        if opname.endswith("-done"):
            continue   # counted at -start
        # operand types appear inline: f32[..]{..} %name
        op_types = re.findall(r"(\w+\[[\d,]*\])(?:\{[^}]*\})? %?[\w.\-]+",
                              rest)
        if op_types:
            nbytes = sum(_type_bytes(t) for t in op_types)
        else:
            nbytes = _type_bytes(result_type)
        out[matched]["count"] += 1
        out[matched]["bytes"] += nbytes
    return out


# ---------------------------------------------------------------------------
# The dry-run itself
# ---------------------------------------------------------------------------

def lower_cell(arch: str, shape_key: str, *, multi_pod: bool = False,
               run_override=None) -> Dict[str, Any]:
    cfg = get_config(arch)
    reason = skip_reason(cfg, shape_key)
    if reason:
        return {"arch": arch, "shape": shape_key, "skipped": reason}
    run = run_override or make_run_config(cfg, shape_key)
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = int(np.prod(list(dict(mesh.shape).values())))
    rules = partition.activation_rules(mesh, cfg, run)
    policy = partition.make_policy(mesh, cfg, run)
    t0 = time.time()

    with shapi.policy_scope(policy):
        if run.mode == "train":
            opt = AdamW(lr=run.learning_rate,
                        moments_dtype={"float32": jnp.float32,
                                       "bfloat16": jnp.bfloat16}[run.moments_dtype])
            state_shapes = jax.eval_shape(
                lambda k: init_train_state(k, cfg, run, opt)[0],
                jax.random.PRNGKey(0))
            state_sh = partition.make_state_shardings(state_shapes, mesh,
                                                      run.fsdp)
            batch_specs = input_specs(cfg, run)
            batch_sh = jax.tree.map(
                lambda x: NamedSharding(
                    mesh, rules["act_btd"] if x.ndim == 3 else
                    P(rules["act_btd"][0], None)), batch_specs)
            step = make_train_step(cfg, run, opt,
                                   grad_shardings=state_sh.params)
            jitted = jax.jit(step,
                             in_shardings=(state_sh, batch_sh),
                             out_shardings=(state_sh, None),
                             donate_argnums=(0,))
            lowered = jitted.lower(state_shapes, batch_specs)
        elif run.mode == "prefill":
            params_shapes = jax.eval_shape(
                lambda k: tr.init_params(k, cfg), jax.random.PRNGKey(0))
            params_sh = partition.make_param_shardings(params_shapes, mesh,
                                                       fsdp=True)
            batch_specs = input_specs(cfg, run)
            batch_sh = jax.tree.map(
                lambda x: NamedSharding(
                    mesh, rules["act_btd"] if x.ndim == 3 else
                    P(rules["act_btd"][0], None)), batch_specs)
            cache_like = jax.eval_shape(
                lambda: tr.init_cache(run.global_batch, run.seq_len, cfg))
            cache_sh = cache_shardings(cache_like, mesh, rules)
            fn = make_prefill_step(cfg, cache_len=run.seq_len)
            jitted = jax.jit(fn, in_shardings=(params_sh, batch_sh),
                             out_shardings=(None, cache_sh))
            lowered = jitted.lower(params_shapes, batch_specs)
        else:   # decode
            params_shapes = jax.eval_shape(
                lambda k: tr.init_params(k, cfg), jax.random.PRNGKey(0))
            params_sh = partition.make_param_shardings(params_shapes, mesh,
                                                       fsdp=True)
            specs = input_specs(cfg, run)
            cache_sh = cache_shardings(specs["cache"], mesh, rules)
            fn = make_decode_step(cfg)

            def step(p, c, t, pos):
                return fn(p, c, t, pos, None)

            jitted = jax.jit(
                step,
                in_shardings=(params_sh, cache_sh, None, None),
                out_shardings=(None, cache_sh),
                donate_argnums=(1,))
            lowered = jitted.lower(params_shapes, specs["cache"],
                                   specs["tokens_t"], specs["pos"])
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    # ---- analyses ----
    try:
        mem = compiled.memory_analysis()
        mem_info = {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "generated_code_bytes": getattr(mem, "generated_code_size_in_bytes", None),
        }
    except Exception as e:   # CPU backend may not implement it
        mem_info = {"error": str(e)}
    try:
        cost = compiled.cost_analysis()
        if isinstance(cost, (list, tuple)):
            cost = cost[0]
        raw_flops = float(cost.get("flops", -1))
        raw_bytes = float(cost.get("bytes accessed", -1))
    except Exception as e:
        raw_flops, raw_bytes = -1.0, -1.0

    # Loop-aware accounting (cost_analysis counts while bodies once; see
    # hlo_analysis docstring).  This is the roofline source of truth.
    hlo = compiled.as_text()
    acc = hlo_analysis.analyze(hlo)
    flops = acc["flops"]
    bytes_accessed = acc["bytes"]
    coll = acc["collectives"]
    coll_bytes = acc["collective_bytes"]

    # ---- roofline terms (per chip; HLO module is already per-device) ----
    compute_term = flops / PEAK_FLOPS if flops > 0 else None
    memory_term = bytes_accessed / HBM_BW if bytes_accessed > 0 else None
    collective_term = coll_bytes / LINK_BW
    terms = {"compute_s": compute_term, "memory_s": memory_term,
             "collective_s": collective_term}
    valid = {k: v for k, v in terms.items() if v is not None}
    dominant = max(valid, key=valid.get) if valid else None

    n_active = active_param_count(cfg)
    if run.mode == "train":
        model_flops = 6.0 * n_active * run.global_batch * run.seq_len
    elif run.mode == "prefill":
        model_flops = 2.0 * n_active * run.global_batch * run.seq_len
    else:
        model_flops = 2.0 * n_active * run.global_batch
    useful_ratio = (model_flops / n_chips) / flops if flops > 0 else None

    return {
        "arch": arch, "shape": shape_key,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "n_chips": n_chips,
        "mode": run.mode,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "flops_per_chip": flops,
        "bytes_per_chip": bytes_accessed,
        "collective_bytes_per_chip": coll_bytes,
        "collectives": coll,
        "bytes_by_op": acc.get("by_op", {}),
        "raw_cost_analysis": {"flops": raw_flops, "bytes": raw_bytes},
        "hlo_warnings": acc["warnings"][:5],
        "memory": mem_info,
        "roofline": terms,
        "dominant": dominant,
        "model_flops_global": model_flops,
        "n_active_params": n_active,
        "useful_flop_ratio": useful_ratio,
    }


def lower_solver_cell(*, n: int = 16384, stages: int = 2,
                      multi_pod: bool = False) -> Dict[str, Any]:
    """Dry-run the paper's own technique: the distributed BlockAMC solver
    (plan build + five-step cascade) lowered on the production mesh.

    A is sharded (data, model); the GEMM-only Schur pre-processing and the
    vectorised tile MVMs shard under GSPMD; leaf INVs gather small blocks.
    """
    from repro.core import distributed
    from repro.core.analog import AnalogConfig
    from repro.core.nonideal import NonidealConfig

    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = int(np.prod(list(dict(mesh.shape).values())))
    cfg = AnalogConfig(array_size=256, nonideal=NonidealConfig(sigma=0.05))
    t0 = time.time()

    def solve(a, b, key):
        return distributed.solve_distributed(a, b, key, cfg, stages=stages,
                                             mesh=mesh)

    a_spec = jax.ShapeDtypeStruct((n, n), jnp.float32)
    b_spec = jax.ShapeDtypeStruct((n,), jnp.float32)
    key_spec = jax.eval_shape(lambda: jax.random.PRNGKey(0))
    a_sh = NamedSharding(mesh, P("data", "model"))
    lowered = jax.jit(solve, in_shardings=(a_sh, None, None)).lower(
        a_spec, b_spec, key_spec)
    t_lower = time.time() - t0
    compiled = lowered.compile()
    t_compile = time.time() - t0 - t_lower
    acc = hlo_analysis.analyze(compiled.as_text())
    try:
        mem = compiled.memory_analysis()
        mem_info = {"argument_bytes": mem.argument_size_in_bytes,
                    "temp_bytes": mem.temp_size_in_bytes}
    except Exception as e:
        mem_info = {"error": str(e)}
    terms = {"compute_s": acc["flops"] / PEAK_FLOPS,
             "memory_s": acc["bytes"] / HBM_BW,
             "collective_s": acc["collective_bytes"] / LINK_BW}
    model_flops = 2.0 / 3.0 * n ** 3 * 2 * 2   # block-inv ~2x one LU(2/3 n^3)
    return {"arch": "blockamc-solver", "shape": f"n{n}_s{stages}",
            "mesh": "2x16x16" if multi_pod else "16x16",
            "n_chips": n_chips, "mode": "solve",
            "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
            "flops_per_chip": acc["flops"], "bytes_per_chip": acc["bytes"],
            "collective_bytes_per_chip": acc["collective_bytes"],
            "collectives": acc["collectives"], "memory": mem_info,
            "roofline": terms,
            "dominant": max(terms, key=terms.get),
            "model_flops_global": model_flops,
            "useful_flop_ratio": (model_flops / n_chips) / max(acc["flops"], 1),
            "bytes_by_op": acc.get("by_op", {})}


def cell_path(arch: str, shape_key: str, multi_pod: bool) -> str:
    os.makedirs(ARTIFACT_DIR, exist_ok=True)
    mesh = "2x16x16" if multi_pod else "16x16"
    return os.path.join(ARTIFACT_DIR, f"{arch}__{shape_key}__{mesh}.json")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--solver", action="store_true",
                    help="dry-run the distributed BlockAMC solver cell")
    args = ap.parse_args()

    if args.solver:
        result = lower_solver_cell(multi_pod=args.multi_pod)
        path = cell_path("blockamc-solver", result["shape"], args.multi_pod)
        with open(path, "w") as f:
            json.dump(result, f, indent=1)
        print(f"solver cell: dominant={result['dominant']} "
              f"terms={result['roofline']} (compile {result['compile_s']}s)")
        return

    cells = []
    if args.all:
        for arch in ARCHS:
            for shape in SHAPES:
                cells.append((arch, shape))
    else:
        assert args.arch and args.shape, "--arch and --shape (or --all)"
        cells.append((args.arch, args.shape))

    for arch, shape in cells:
        path = cell_path(arch, shape, args.multi_pod)
        if os.path.exists(path) and not args.force:
            print(f"[skip-cached] {arch} {shape}")
            continue
        print(f"[dryrun] {arch} {shape} multi_pod={args.multi_pod} ...",
              flush=True)
        try:
            result = lower_cell(arch, shape, multi_pod=args.multi_pod)
        except Exception as e:
            result = {"arch": arch, "shape": shape,
                      "mesh": "2x16x16" if args.multi_pod else "16x16",
                      "error": f"{type(e).__name__}: {e}"}
            print(f"  ERROR: {e}")
        with open(path, "w") as f:
            json.dump(result, f, indent=1)
        if "roofline" in result:
            print(f"  ok: dominant={result['dominant']} "
                  f"terms={result['roofline']} "
                  f"(compile {result['compile_s']}s)")


if __name__ == "__main__":
    main()

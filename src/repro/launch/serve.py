"""Serving launcher: --arch <id> [--host-scale] batched generation demo."""
from __future__ import annotations

import argparse
import logging
import time

import jax

from repro.configs import get_config
from repro.launch.train import host_scale_config
from repro.models import transformer as tr
from repro.models.lm_engine import Engine

log = logging.getLogger("repro.launch.serve")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--host-scale", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen-len", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    logging.basicConfig(level=logging.INFO)
    cfg = get_config(args.arch)
    if args.host_scale:
        cfg = host_scale_config(cfg)
    params = tr.init_params(jax.random.PRNGKey(0), cfg)
    engine = Engine(cfg, params,
                    max_len=args.prompt_len + args.gen_len + 1,
                    temperature=args.temperature)
    prompts = jax.random.randint(
        jax.random.PRNGKey(1), (args.batch, args.prompt_len), 0, cfg.vocab)
    t0 = time.perf_counter()
    out = engine.generate(prompts, args.gen_len)
    dt = time.perf_counter() - t0
    tps = args.batch * args.gen_len / dt
    log.info("generated %s tokens in %.2fs (%.1f tok/s)", out.shape, dt, tps)
    print(out[:, :16])
    return out


if __name__ == "__main__":
    main()

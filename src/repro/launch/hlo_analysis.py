"""Loop-aware roofline accounting over optimized (post-SPMD) HLO text.

XLA's `compiled.cost_analysis()` counts a while-loop body ONCE, which makes
scan-over-layers models look L-times cheaper than they are (verified:
scan-of-10-matmuls reports 1/10th the flops of its unrolled twin).  This
module re-derives the three roofline inputs by walking the HLO call graph
with loop-trip multipliers:

  flops            - 2*prod(result)*prod(contracting dims) per dot,
                     recursively through fusions/calls/whiles (x trips).
  hbm bytes        - operand+result bytes of every top-level instruction in
                     each computation (fusion internals excluded: a fusion
                     touches HBM only at its boundary), x trips.
  collective bytes - operand bytes per collective op, x trips.

Trip counts come from the integer bound in the while condition computation
(jax scans lower to `compare(iv, constant(N)), direction=LT`); dynamic
bounds fall back to 1 with a warning.  Conditionals take the max branch.
Elementwise flops are not counted (dot-dominated models; documented in
EXPERIMENTS.md).
"""
from __future__ import annotations

import re
from typing import Dict, List, Optional

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
                "s4": 1, "u4": 1}

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute", "ragged-all-to-all")

_SHAPE_TOKEN = re.compile(r"\b(\w+)\[([\d,]*)\]")
_INSTR = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*)$")
_COMP_HEADER = re.compile(r"^\s*(ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->.*\{\s*$")
_BODY_SPLIT = re.compile(r"((?:\([^=]*?\)|[^\s(]+))\s+([\w\-]+)\((.*)$")

_SKIP_BYTES_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "add-dependency", "partition-id", "replica-id", "iota",
    "copy-start", "copy-done", "reshape", "while", "conditional", "call",
}

_BYTES_OPS_EXTRA = {
    "copy", "transpose", "broadcast", "reduce", "sort", "scatter", "gather",
    "dynamic-slice", "dynamic-update-slice", "pad", "concatenate", "select",
    "convert", "slice", "reverse", "map", "reduce-window", "convolution",
    "custom-call", "rng", "cholesky", "triangular-solve", "compare", "dot",
    "fusion", "add", "multiply", "subtract", "divide", "exponential", "tanh",
    "select-and-scatter", "clamp", "maximum", "minimum", "rsqrt", "negate",
}


def _tensor_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_TOKEN.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _result_elems(type_str: str) -> float:
    m = _SHAPE_TOKEN.search(type_str)
    if not m:
        return 0.0
    n = 1
    for d in m.group(2).split(","):
        if d:
            n *= int(d)
    return float(n)


class Instr:
    __slots__ = ("name", "opcode", "line", "result_type", "operands", "attrs")

    def __init__(self, name: str, body: str, line: str):
        self.name = name
        self.line = line
        # result type: balanced-paren tuple (may contain /*index=N*/ comments)
        # or a single whitespace-free token.
        body = body.lstrip()
        if body.startswith("("):
            depth = 0
            end = -1
            for i, ch in enumerate(body):
                if ch == "(":
                    depth += 1
                elif ch == ")":
                    depth -= 1
                    if depth == 0:
                        end = i
                        break
            if end < 0:
                self.result_type, self.opcode = "", ""
                self.operands, self.attrs = [], ""
                return
            self.result_type = body[:end + 1]
            tail = body[end + 1:].lstrip()
        else:
            parts = body.split(None, 1)
            self.result_type = parts[0]
            tail = parts[1] if len(parts) > 1 else ""
        m = re.match(r"([\w\-]+)\((.*)$", tail)
        if not m:
            self.result_type, self.opcode = self.result_type, ""
            self.operands, self.attrs = [], ""
            return
        self.opcode, rest = m.groups()
        # split operand segment from attrs at the balanced closing paren
        depth = 1
        cut = len(rest)
        for i, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    cut = i
                    break
        operand_seg = rest[:cut]
        self.attrs = rest[cut + 1:]
        self.operands = re.findall(r"%([\w.\-]+)", operand_seg)


def parse_computations(hlo: str):
    comps: Dict[str, List[Instr]] = {}
    types: Dict[str, str] = {}
    entry = ""
    current: Optional[str] = None
    for raw in hlo.splitlines():
        line = raw.rstrip()
        header = _COMP_HEADER.match(line)
        if header:
            current = header.group(2)
            comps[current] = []
            if header.group(1):
                entry = current
            continue
        if current is None:
            continue
        if line.strip() == "}":
            current = None
            continue
        m = _INSTR.match(line)
        if m:
            ins = Instr(m.group(1), m.group(2), line)
            comps[current].append(ins)
            types[ins.name] = ins.result_type
    return comps, types, entry


def analyze(hlo: str) -> Dict:
    comps, types, entry = parse_computations(hlo)
    warnings: List[str] = []
    cache: Dict[str, Dict] = {}

    def operand_bytes(ins: Instr) -> int:
        return sum(_tensor_bytes(types.get(o, "")) for o in ins.operands)

    def is_convert_only(comp_name: Optional[str]) -> bool:
        """True for fusions that only change dtype/layout (convert/bitcast/
        reshape chains).  XLA CPU materialises f32 copies of bf16 tensors
        around dots (no native bf16); on the TPU target these fusions do not
        exist, so their traffic is discounted (EXPERIMENTS.md S-Roofline)."""
        body = comps.get(comp_name or "", [])
        saw_work = False
        for bi in body:
            if bi.opcode in ("parameter", "tuple", "get-tuple-element"):
                continue
            if bi.opcode not in ("convert", "bitcast", "reshape", "copy"):
                return False
            saw_work = True
        return saw_work

    def fusion_io_bytes(ins: Instr, comp_name: Optional[str]) -> float:
        """Boundary traffic of a fusion: result + operands, where an operand
        that is only dynamic-slice'd/slice'd inside the fused computation is
        charged at the slice-result size (XLA input fusions take the whole
        stacked scan parameter as an operand but only read one layer's
        slice per trip - charging the full tensor would overcount by L)."""
        if is_convert_only(comp_name):
            return 0.0
        total = float(_tensor_bytes(ins.result_type))
        body = comps.get(comp_name or "", [])
        # parameter lines look like: %p = TYPE parameter(IDX)
        param_idx = {}
        for bi in body:
            pm = re.search(r"parameter\((\d+)\)", bi.line)
            if pm and bi.opcode == "parameter":
                param_idx[bi.name] = int(pm.group(1))
        sliced_ok: Dict[str, float] = {}
        for pname in param_idx:
            consumers = [bi for bi in body if pname in bi.operands]
            if consumers and all(bi.opcode in ("dynamic-slice", "slice",
                                               "gather")
                                 for bi in consumers):
                sliced_ok[pname] = sum(
                    _tensor_bytes(bi.result_type) for bi in consumers)
        for pname, idx in param_idx.items():
            if idx >= len(ins.operands):
                continue
            full = _tensor_bytes(types.get(ins.operands[idx], ""))
            total += min(sliced_ok.get(pname, full), full) if pname in sliced_ok \
                else full
        if not param_idx:   # fallback: no parsable body
            total += operand_bytes(ins)
        return total

    def dot_flops(ins: Instr) -> float:
        result = _result_elems(ins.result_type)
        mdims = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", ins.attrs)
        lhs_type = types.get(ins.operands[0], "") if ins.operands else ""
        sm = _SHAPE_TOKEN.search(lhs_type)
        if not sm:
            return 0.0
        lhs_shape = [int(d) for d in sm.group(2).split(",") if d]
        contract = 1
        if mdims:
            for d in mdims.group(1).split(","):
                if d and int(d) < len(lhs_shape):
                    contract *= lhs_shape[int(d)]
        return 2.0 * result * contract

    def trip_count(cond_name: str) -> float:
        best = None
        for ins in comps.get(cond_name, []):
            m = re.search(r"\b[su]\d+\[\]\s+constant\((\d+)\)", ins.line)
            if m:
                v = int(m.group(1))
                best = v if best is None else max(best, v)
        if best is None or best <= 0:
            warnings.append(f"while cond {cond_name}: non-constant bound, trip=1")
            return 1.0
        return float(best)

    def attr_comp(ins: Instr, key: str) -> Optional[str]:
        m = re.search(key + r"=%?([\w.\-]+)", ins.attrs)
        if m and m.group(1) in comps:
            return m.group(1)
        return None

    def attr_comps(ins: Instr, key: str) -> List[str]:
        m = re.search(key + r"=\{([^}]*)\}", ins.attrs)
        if not m:
            single = attr_comp(ins, key)
            return [single] if single else []
        out = []
        for nm in m.group(1).split(","):
            nm = nm.strip().lstrip("%")
            if nm in comps:
                out.append(nm)
        return out

    def zero():
        return {"flops": 0.0, "bytes": 0.0, "by_op": {},
                "coll": {c: {"count": 0.0, "bytes": 0.0} for c in COLLECTIVES}}

    def add_scaled(dst, src, scale=1.0):
        dst["flops"] += scale * src["flops"]
        dst["bytes"] += scale * src["bytes"]
        for op, b in src["by_op"].items():
            dst["by_op"][op] = dst["by_op"].get(op, 0.0) + scale * b
        for c in COLLECTIVES:
            dst["coll"][c]["count"] += scale * src["coll"][c]["count"]
            dst["coll"][c]["bytes"] += scale * src["coll"][c]["bytes"]

    def comp_cost(name: str) -> Dict:
        if name in cache:
            return cache[name]
        cache[name] = zero()   # cycle guard
        total = zero()
        for ins in comps.get(name, []):
            op = ins.opcode
            if not op:
                continue
            if op == "dot":
                total["flops"] += dot_flops(ins)
            base = op[:-6] if op.endswith("-start") else op
            if base in COLLECTIVES and not op.endswith("-done"):
                nbytes = operand_bytes(ins) or _tensor_bytes(ins.result_type)
                total["coll"][base]["count"] += 1
                total["coll"][base]["bytes"] += nbytes
            if op not in _SKIP_BYTES_OPS:
                if op == "fusion":
                    b = fusion_io_bytes(ins, attr_comp(ins, "calls"))
                else:
                    b = operand_bytes(ins) + _tensor_bytes(ins.result_type)
                total["bytes"] += b
                total["by_op"][op] = total["by_op"].get(op, 0.0) + b
            if op == "while":
                body = attr_comp(ins, "body")
                cond = attr_comp(ins, "condition")
                trips = trip_count(cond) if cond else 1.0
                if body:
                    # Body instructions account their own HBM traffic
                    # (dynamic-slice/dus of the carried state); charging the
                    # full carry tuple per trip would double-count massively.
                    add_scaled(total, comp_cost(body), trips)
            elif op == "conditional":
                branches = attr_comps(ins, "branch_computations")
                if not branches:
                    branches = [c for key in ("true_computation",
                                              "false_computation")
                                for c in ([attr_comp(ins, key)] if attr_comp(ins, key) else [])]
                subs = [comp_cost(b) for b in branches]
                if subs:
                    add_scaled(total, max(
                        subs, key=lambda s: s["flops"] + s["bytes"]))
            elif op in ("fusion", "call", "async-start"):
                key = "calls" if op == "fusion" else "to"
                sub_name = attr_comp(ins, key) or attr_comp(ins, "calls")
                if sub_name:
                    sub = comp_cost(sub_name)
                    # fusion internals stay in registers/VMEM: only flops and
                    # collectives flow up; calls propagate bytes too.
                    scale_bytes = 1.0 if op == "call" else 0.0
                    total["flops"] += sub["flops"]
                    total["bytes"] += scale_bytes * sub["bytes"]
                    for c in COLLECTIVES:
                        total["coll"][c]["count"] += sub["coll"][c]["count"]
                        total["coll"][c]["bytes"] += sub["coll"][c]["bytes"]
        cache[name] = total
        return total

    # effective execution multiplier per computation (for diagnostics)
    multipliers: Dict[str, float] = {}

    def propagate(name: str, mult: float, depth=0):
        if depth > 50:
            return
        multipliers[name] = multipliers.get(name, 0.0) + mult
        for ins in comps.get(name, []):
            if ins.opcode == "while":
                body = attr_comp(ins, "body")
                cond = attr_comp(ins, "condition")
                trips = trip_count(cond) if cond else 1.0
                if body:
                    propagate(body, mult * trips, depth + 1)
            elif ins.opcode in ("fusion", "call", "async-start", "conditional"):
                for sub in called_comps_of(ins):
                    propagate(sub, mult, depth + 1)

    def called_comps_of(ins: Instr) -> List[str]:
        out = []
        for key in ("calls", "to", "branch_computations"):
            out.extend(attr_comps(ins, key))
        return out

    if not entry:
        return {"flops": 0.0, "bytes": 0.0, "collectives": {},
                "collective_bytes": 0.0,
                "warnings": ["no ENTRY computation found"]}
    result = comp_cost(entry)
    coll_bytes = sum(v["bytes"] for v in result["coll"].values())
    propagate(entry, 1.0)
    top: List = []
    for cname, mult in multipliers.items():
        for ins in comps.get(cname, []):
            if ins.opcode in _SKIP_BYTES_OPS or not ins.opcode:
                continue
            if ins.opcode == "fusion":
                b = fusion_io_bytes(ins, attr_comp(ins, "calls"))
            else:
                b = operand_bytes(ins) + _tensor_bytes(ins.result_type)
            if b:
                top.append((b * mult, ins.opcode, ins.result_type[:48],
                            cname[:40], mult))
    top.sort(key=lambda x: -x[0])
    return {"flops": result["flops"], "bytes": result["bytes"],
            "by_op": dict(sorted(result["by_op"].items(),
                                 key=lambda kv: -kv[1])[:12]),
            "top_instrs": [
                {"gbytes": round(b / 1e9, 2), "op": op, "type": t,
                 "comp": c, "mult": m} for b, op, t, c, m in top[:16]],
            "collectives": result["coll"], "collective_bytes": coll_bytes,
            "warnings": warnings}

"""Training launcher: --arch <id> --shape train_4k [--steps N] [--host-scale].

On the production pod this process runs per host with jax.distributed;
on this container it runs the same code path at a reduced (host) scale:
`--host-scale` shrinks the model to a trainable-on-CPU config with the same
family/topology, which is what examples/train_lm.py uses end to end.
"""
from __future__ import annotations

import argparse
import dataclasses
import logging

from repro.configs import get_config
from repro.configs.base import RunConfig, SHAPES
from repro.train.trainer import Trainer

log = logging.getLogger("repro.launch.train")


def host_scale_config(cfg):
    """Shrink an arch config to a ~CPU-trainable size, same topology."""
    return dataclasses.replace(
        cfg,
        n_layers=min(cfg.n_layers, 4 if not cfg.layer_pattern else
                     2 * len(cfg.layer_pattern)),
        d_model=256, d_ff=512 if cfg.d_ff else 0,
        n_heads=4 if cfg.n_heads else 0,
        kv_heads=min(cfg.kv_heads, 2) if cfg.kv_heads else 0,
        head_dim=64, vocab=min(cfg.vocab, 2048),
        n_experts=min(cfg.n_experts, 4), local_window=64,
        lru_width=256 if cfg.lru_width else None,
        ssm_chunk=32,
        param_dtype="float32", compute_dtype="float32",
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default="train_4k", choices=list(SHAPES))
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--host-scale", action="store_true",
                    help="shrink model + batch for single-host runs")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    logging.basicConfig(level=logging.INFO,
                        format="%(asctime)s %(name)s %(message)s")
    cfg = get_config(args.arch)
    shape = dict(SHAPES[args.shape])
    if args.host_scale:
        cfg = host_scale_config(cfg)
        shape.update(seq_len=min(shape["seq_len"], 128),
                     global_batch=min(shape["global_batch"], 8))
    run = RunConfig(model=cfg, **shape)
    trainer = Trainer(cfg, run, ckpt_dir=args.ckpt_dir, seed=args.seed)
    history = trainer.run(args.steps)
    first, last = history["loss"][0], history["loss"][-1]
    log.info("done: loss %.4f -> %.4f over %d steps", first, last, args.steps)
    return history


if __name__ == "__main__":
    main()

"""Production mesh builders.

Functions, not module-level constants: importing this module never touches
jax device state, so library imports stay side-effect free (the dry-run sets
XLA_FLAGS before anything else touches jax).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips/pod; 2 pods = 512 chips with a leading 'pod' axis."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over host (CPU) devices for tests."""
    return jax.make_mesh((data, model), ("data", "model"))


def make_mc_mesh(n_devices: int | None = None, axis_name: str = "mc"):
    """1-D mesh for sharding a Monte-Carlo key axis (blockamc sweeps).

    Defaults to all local devices; `solve_batched_sharded` gives every
    device its own shard of independent noise keys.
    """
    if n_devices is None:
        n_devices = jax.device_count()
    return jax.make_mesh((n_devices,), (axis_name,))

"""repro: BlockAMC (scalable in-memory analog matrix computing) in JAX,
plus the multi-pod LM training/serving framework it is embedded in."""
__version__ = "1.0.0"

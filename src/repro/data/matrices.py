"""Benchmark matrix generators from the paper (Section IV.A).

The paper evaluates on two matrix families:
  * Wishart  A = X^T X with X an (m x n) real Gaussian matrix  (Eq. 4)
  * Toeplitz A[i, j] = a_{i-j}, constant along diagonals       (Eq. 5)

The paper does not state the Wishart aspect ratio m/n.  A square Wishart
(m == n) is near-singular for large n (Marchenko-Pastur: smallest eigenvalue
-> 0), which would make *any* solver's relative error diverge; the paper's
reported error curves are stable across 40-seed Monte Carlo, which implies a
well-conditioned ensemble.  We default to m = 4n (condition number
((1+sqrt(1/4))/(1-sqrt(1/4)))^2 = 9, independent of n) and expose the ratio.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def wishart(key: jax.Array, n: int, *, aspect: float = 4.0,
            dtype=jnp.float32) -> jnp.ndarray:
    """Wishart matrix A = X^T X / m, X ~ N(0,1)^(m x n), m = aspect*n.

    The 1/m scaling keeps element magnitudes O(1) across sizes; the paper
    normalises to max-element 1 before mapping anyway, so scaling is free.
    """
    m = int(round(aspect * n))
    x = jax.random.normal(key, (m, n), dtype=dtype)
    return (x.T @ x) / m


def wishart_with_cond(key: jax.Array, n: int, cond: float,
                      *, dtype=jnp.float32) -> jnp.ndarray:
    """SPD matrix with prescribed condition number in a Wishart eigenbasis.

    Draws a Wishart instance, keeps its (Haar-like) eigenvectors and
    replaces the spectrum with a log-uniform ramp from 1 down to 1/cond, so
    cond_2(A) == cond exactly.  This is how the hybrid-refinement tests and
    benchmarks sweep conditioning independently of the matrix family.
    """
    _, v = jnp.linalg.eigh(wishart(key, n, dtype=dtype))
    eigs = jnp.logspace(0.0, -math.log10(cond), n, dtype=dtype)
    return (v * eigs) @ v.T


def toeplitz(key: jax.Array, n: int, *, decay: float = 1.0,
             diag_boost: float = 2.0, dtype=jnp.float32) -> jnp.ndarray:
    """Random Toeplitz matrix, invertible w.h.p.

    Independent first row/column entries a_{-n+1..n-1} ~ N(0,1) damped by
    1/(1+|k|)^decay, with the main diagonal boosted for diagonal dominance
    (the paper needs invertible instances for the INV circuit to settle).
    """
    coeffs = jax.random.normal(key, (2 * n - 1,), dtype=dtype)
    k = jnp.abs(jnp.arange(-(n - 1), n))
    coeffs = coeffs / (1.0 + k.astype(dtype)) ** decay
    coeffs = coeffs.at[n - 1].set(diag_boost * jnp.sign(coeffs[n - 1] + 1e-9)
                                  * (jnp.abs(coeffs[n - 1]) + 1.0))
    i = jnp.arange(n)[:, None]
    j = jnp.arange(n)[None, :]
    # A[i, j] = a_{i - j}; index into coeffs centred at n-1.
    return coeffs[(i - j) + (n - 1)]


def random_rhs(key: jax.Array, n: int, *, dtype=jnp.float32) -> jnp.ndarray:
    """Random input vector b, uniform in [-1, 1] (DAC full-scale)."""
    return jax.random.uniform(key, (n,), dtype=dtype, minval=-1.0, maxval=1.0)


MATRIX_FAMILIES = {"wishart": wishart, "toeplitz": toeplitz}

"""Deterministic synthetic LM data pipeline (host-sharded layout).

Every batch is a pure function of (seed, step, shard), so any host in a
multi-pod job can regenerate exactly its slice - the property that makes
checkpoint-restart and elastic re-sharding deterministic without a data
service.  Tokens follow a Zipf-ish distribution with short-range structure
(repeated n-grams) so models actually have signal to fit in the
train-for-a-few-hundred-steps examples.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, RunConfig


@dataclasses.dataclass
class SyntheticLM:
    model_cfg: ModelConfig
    run_cfg: RunConfig
    seed: int = 0
    n_shards: int = 1
    shard: int = 0

    def batch(self, step: int) -> Dict[str, jnp.ndarray]:
        b = self.run_cfg.global_batch // self.n_shards
        s = self.run_cfg.seq_len
        v = self.model_cfg.vocab
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step, self.shard]))
        # Zipf-ish marginals + copied spans for learnable structure
        base = rng.zipf(1.3, size=(b, s + 1)).astype(np.int64) % v
        span = max(8, s // 64)
        starts = rng.integers(0, s + 1 - 2 * span, size=b)
        for i in range(b):
            st = starts[i]
            base[i, st + span:st + 2 * span] = base[i, st:st + span]
        tokens = jnp.asarray(base[:, :-1], dtype=jnp.int32)
        labels = jnp.asarray(base[:, 1:], dtype=jnp.int32)
        out = {"tokens": tokens, "labels": labels}
        if self.model_cfg.frontend == "vit_stub":
            # Pixtral-style: image patch embeddings prepended conceptually;
            # the stub supplies the fused embedding stream directly.
            emb = rng.standard_normal((b, s, self.model_cfg.d_model),
                                      dtype=np.float32) * 0.02
            out = {"embeds": jnp.asarray(emb), "labels": labels}
        return out

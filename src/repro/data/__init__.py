from repro.data.matrices import wishart, toeplitz, random_rhs  # noqa: F401

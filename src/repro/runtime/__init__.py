from repro.runtime.fault_tolerance import StepWatchdog, retry_step  # noqa: F401
from repro.runtime.elastic import ElasticMesh  # noqa: F401
from repro.runtime.chaos import (  # noqa: F401
    AcceleratedDrift, ChaosInjector, CheckpointCorruption, DeviceFault,
    DispatchException, DispatchLatency, HotBlock, ReplicaDeath,
    ReplicaDeathError, ReplicaStall, ScriptedDispatchError)

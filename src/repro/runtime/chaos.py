"""Deterministic fault injection for the serving stack (chaos harness).

Production chaos testing injects failures at random; a *test* harness must
inject them deterministically or the suite flakes.  Everything here is
keyed on the engine's **dispatch counter** - a monotonically increasing
integer the `AsyncSolverEngine` bumps once per dispatch *attempt* - never
on wall-clock time, so a scripted scenario replays identically however
fast or slow the host is.

Three event kinds, mirroring the three production failure surfaces:

* `DispatchException` - the dispatch itself blows up (a driver error, a
  device OOM, a collective timeout surfacing as an exception).  Raised as
  `ScriptedDispatchError`, a `RuntimeError` subclass, so the engine's
  `retry_step` ladder treats it as transient.
* `DispatchLatency` - a straggling dispatch (the `StepWatchdog` failure
  mode): the harness sleeps inside the dispatch attempt.
* `DeviceFault` - the crossbar degrades mid-session: the engine re-programs
  the matrix's arrays under the event's faulty `NonidealConfig` (stuck-at
  rates, drift - the knobs PR 6's physics subsystem added), which its
  canary health check then discovers *through the answers*, exactly like a
  real drift/stuck-at failure.  `persistent=True` re-applies the faulty
  config on every recovery re-program too, forcing the engine down the
  quarantine -> re-program -> degrade ladder to the digital fallback.

Events fire once, at the first dispatch whose index reaches `at_dispatch`
(>= semantics: an event scheduled "at 5" still fires if the engine happens
to jump from 4 to 6).  `ChaosInjector.log` records every firing for test
assertions.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional, Sequence, Tuple, Union

from repro.core.nonideal import NonidealConfig


class ScriptedDispatchError(RuntimeError):
    """A chaos-scripted transient dispatch failure (retriable)."""


class ReplicaDeathError(BaseException):
    """A chaos-scripted replica worker-thread death.

    Deliberately *not* a RuntimeError (nor even an Exception): it must
    sail past `retry_step`'s retriable filter and the engine's dispatch
    containment the same way a segfaulting driver or an OOM kill would -
    nothing inside the replica is allowed to catch and survive it.  The
    worker thread dies with queued and in-flight futures unresolved;
    resolving them is the *fleet's* job (replay on survivors), which is
    exactly the contract under test.
    """


@dataclasses.dataclass(frozen=True)
class DispatchException:
    """Raise `ScriptedDispatchError` inside dispatch attempt `at_dispatch`.

    `replica=None` matches any replica; a name scopes the event to one
    engine's dispatch counter in a fleet run.
    """
    at_dispatch: int
    message: str = "chaos: scripted dispatch failure"
    replica: Optional[str] = None


@dataclasses.dataclass(frozen=True)
class DispatchLatency:
    """Sleep `seconds` inside dispatch attempt `at_dispatch` (straggler)."""
    at_dispatch: int
    seconds: float
    replica: Optional[str] = None


@dataclasses.dataclass(frozen=True)
class ReplicaDeath:
    """Kill `replica`'s worker thread at dispatch attempt `at_dispatch`.

    Raises `ReplicaDeathError` inside the dispatch, which propagates
    through every containment layer and terminates the worker loop with
    its queues intact - the closest software analog of a hard device
    loss.
    """
    at_dispatch: int
    replica: Optional[str] = None
    message: str = "chaos: replica worker death"


@dataclasses.dataclass(frozen=True)
class ReplicaStall:
    """Sustained stall: `replica` sleeps `seconds` on *every* dispatch
    from `at_dispatch` through `until_dispatch` (inclusive) - a replica
    that is alive but useless, the gray-failure case the health score
    (not liveness) must catch.  Unlike one-shot events this stays armed
    across the window.
    """
    at_dispatch: int
    seconds: float
    until_dispatch: int = 1 << 62
    replica: Optional[str] = None


@dataclasses.dataclass(frozen=True)
class CheckpointCorruption:
    """Damage `matrix_id`'s stored programmed state at `at_dispatch`.

    The fleet applies it via `ProgramStore.corrupt(matrix_id, how)`;
    how="values" survives the integrity check and must be caught by the
    physics canary, how="truncate" by the manifest cross-check.
    """
    at_dispatch: int
    matrix_id: str
    how: str = "values"
    replica: Optional[str] = None


@dataclasses.dataclass(frozen=True)
class DeviceFault:
    """Degrade `matrix_id`'s programmed arrays before dispatch `at_dispatch`.

    The engine re-programs the matrix under `nonideal` (same dense target,
    deterministic key), simulating a crossbar that developed stuck-at
    faults or drifted - the answers go bad, the canary residual trips.
    `persistent` faults survive recovery: the injector substitutes the
    faulty config for whatever the engine tries to re-program with, so
    health cannot be restored and the engine must degrade to digital.
    """
    at_dispatch: int
    matrix_id: str
    nonideal: NonidealConfig
    persistent: bool = False


@dataclasses.dataclass(frozen=True)
class AcceleratedDrift:
    """Multiply `matrix_id`'s aging rate by `factor` at `at_dispatch`.

    A retention excursion (thermal event, weak conditioning): every
    programmed array of the matrix ages `factor`x faster in device-clock
    time from this dispatch on.  Applied by the engine to the matrix's
    maintenance state, so the background scrubber sees the steepened
    trend and must repair sooner - the forcing function for the
    proactive-repair path.
    """
    at_dispatch: int
    matrix_id: str
    factor: float = 10.0
    replica: Optional[str] = None


@dataclasses.dataclass(frozen=True)
class HotBlock:
    """Age ONE block of `matrix_id` `factor`x faster from `at_dispatch`.

    `block` is a maintenance block ref ("inv"|"mvm", bucket, index) -
    see `core.blockamc.plan_block_map`.  The localized failure mode that
    makes block-granular repair pay: one array degrades while the rest
    of the plan stays healthy, so a whole-matrix re-program would be
    n^2-wasteful.
    """
    at_dispatch: int
    matrix_id: str
    block: Tuple[str, int, int]
    factor: float = 100.0
    replica: Optional[str] = None


ChaosEvent = Union[DispatchException, DispatchLatency, DeviceFault,
                   ReplicaDeath, ReplicaStall, CheckpointCorruption,
                   AcceleratedDrift, HotBlock]


def _matches(e, replica: Optional[str]) -> bool:
    scope = getattr(e, "replica", None)
    return scope is None or scope == replica


class ChaosInjector:
    """Scripted, dispatch-indexed fault schedule for `AsyncSolverEngine`.

    The engine calls exactly three hooks, all from its worker thread (the
    injector needs no locking of its own):

    * `faults_due(idx)` at the start of a dispatch cycle - returns the
      `DeviceFault`s to apply now.
    * `on_dispatch(idx)` inside each dispatch attempt (inside the retry
      ladder, so scripted exceptions exercise it) - sleeps scripted
      latency, raises scripted exceptions.
    * `reprogram_nonideal(matrix_id, cfg)` when recovery re-programs a
      quarantined matrix - persistent faults override the engine's
      recovery config here.
    """

    def __init__(self, events: Sequence[ChaosEvent] = (),
                 sleep: Callable[[float], None] = time.sleep):
        self.events: List[ChaosEvent] = list(events)
        self.sleep = sleep
        self.log: List[Tuple[int, ChaosEvent]] = []   # (dispatch idx, event)
        self._fired: set = set()
        self._persistent: Dict[str, NonidealConfig] = {}

    def _due(self, idx: int, kind,
             replica: Optional[str] = None) -> List[ChaosEvent]:
        due = []
        for i, e in enumerate(self.events):
            if i in self._fired or not isinstance(e, kind):
                continue
            if idx >= e.at_dispatch and _matches(e, replica):
                self._fired.add(i)
                self.log.append((idx, e))
                due.append(e)
        return due

    def faults_due(self, idx: int,
                   replica: Optional[str] = None) -> List[DeviceFault]:
        """Device faults to apply before dispatch cycle `idx` (fire once)."""
        due = self._due(idx, DeviceFault, replica)
        for e in due:
            if e.persistent:
                self._persistent[e.matrix_id] = e.nonideal
        return due

    def aging_due(self, idx: int,
                  replica: Optional[str] = None) -> List[ChaosEvent]:
        """Aging events (AcceleratedDrift / HotBlock) due at dispatch
        cycle `idx` (fire once).  Keyed on the DISPATCH counter like
        every other event - maintenance probes run on a separate counter
        and never consume these indices (the determinism contract)."""
        return (self._due(idx, AcceleratedDrift, replica)
                + self._due(idx, HotBlock, replica))

    def corruptions_due(self, idx: int,
                        replica: Optional[str] = None
                        ) -> List[CheckpointCorruption]:
        """Checkpoint-corruption events due at `idx` (fire once); the
        fleet applies them to its ProgramStore."""
        return self._due(idx, CheckpointCorruption, replica)

    def _stalls_due(self, idx: int,
                    replica: Optional[str]) -> List[ReplicaStall]:
        """Window events: armed on every dispatch inside the window, logged
        only on first firing, retired (fired-once) past the window end."""
        due = []
        for i, e in enumerate(self.events):
            if i in self._fired or not isinstance(e, ReplicaStall):
                continue
            if not _matches(e, replica):
                continue
            if idx > e.until_dispatch:
                self._fired.add(i)
                continue
            if idx >= e.at_dispatch:
                if (i, "logged") not in self._fired:
                    self._fired.add((i, "logged"))
                    self.log.append((idx, e))
                due.append(e)
        return due

    def on_dispatch(self, idx: int, replica: Optional[str] = None) -> None:
        """Latency first (a straggler can also fail), then stalls, then
        deaths, then exceptions.  `replica` scopes the lookup in fleet
        runs; replica-agnostic events (replica=None) always match."""
        for e in self._due(idx, DispatchLatency, replica):
            self.sleep(e.seconds)
        for e in self._stalls_due(idx, replica):
            self.sleep(e.seconds)
        for e in self._due(idx, ReplicaDeath, replica):
            raise ReplicaDeathError(e.message)
        for e in self._due(idx, DispatchException, replica):
            raise ScriptedDispatchError(e.message)

    def reprogram_nonideal(self, matrix_id: str,
                           nonideal: NonidealConfig) -> NonidealConfig:
        """What a recovery re-program of `matrix_id` actually programs
        under: the engine's recovery config, unless a persistent fault
        pins the device in its broken state."""
        return self._persistent.get(matrix_id, nonideal)

    @property
    def fired(self) -> int:
        return len(self.log)

"""Fault-tolerance runtime pieces: step watchdog + bounded retry.

At thousand-node scale the failure modes are (a) a chip/host dying (surfaces
as an exception from the collective), (b) a straggler/hang (surfaces as a
step that never completes).  The watchdog covers (b) by timing each step
against a rolling deadline; the retry wrapper covers (a) by re-raising after
bounded, logged retries so the outer launcher can restore from the last
checkpoint - the standard checkpoint/restart contract.
"""
from __future__ import annotations

import logging
import threading
import time
from typing import Callable, Optional, TypeVar

log = logging.getLogger("repro.runtime")

T = TypeVar("T")


class StepWatchdog:
    """Flags steps exceeding `factor` x rolling-median duration (stragglers).

    Use as a context manager around each training step.  `on_straggle` is
    called with (step_time, median) - production would page / trigger
    preemptive re-scheduling; tests inject a callback.
    """

    def __init__(self, factor: float = 3.0, warmup_steps: int = 5,
                 hard_timeout: Optional[float] = None,
                 on_straggle: Optional[Callable[[float, float], None]] = None):
        self.factor = factor
        self.warmup_steps = warmup_steps
        self.hard_timeout = hard_timeout
        self.on_straggle = on_straggle or (
            lambda t, m: log.warning("straggler: step %.3fs vs median %.3fs", t, m))
        self.durations: list = []
        self._timer: Optional[threading.Timer] = None
        self.straggles = 0

    def _median(self) -> float:
        d = sorted(self.durations)
        return d[len(d) // 2]

    def __enter__(self):
        self._t0 = time.monotonic()
        if self.hard_timeout is not None:
            self._timer = threading.Timer(
                self.hard_timeout,
                lambda: self.on_straggle(self.hard_timeout, float("inf")))
            self._timer.start()
        return self

    def __exit__(self, exc_type, exc, tb):
        if self._timer is not None:
            self._timer.cancel()
        dt = time.monotonic() - self._t0
        # the window must be non-empty before a median exists, whatever
        # warmup the caller asked for (warmup_steps=0 is a valid config:
        # hard_timeout-only watchdogs in the serving engine use it)
        if self.durations and len(self.durations) >= self.warmup_steps:
            med = self._median()
            if dt > self.factor * med:
                self.straggles += 1
                self.on_straggle(dt, med)
        self.durations.append(dt)
        if len(self.durations) > 100:
            self.durations.pop(0)
        return False


def retry_step(fn: Callable[[], T], retries: int = 2,
               backoff: float = 0.0,
               retriable=(RuntimeError,),
               on_retry: Optional[Callable[[int, BaseException], None]] = None,
               sleep: Callable[[float], None] = time.sleep) -> T:
    """Run fn with bounded retries on transient runtime errors.

    `backoff` is the base of an exponential schedule: attempt k sleeps
    `backoff * 2**k` before retrying (0 disables sleeping).  Non-retriable
    exceptions propagate immediately; the last retriable failure re-raises
    unchanged after `retries` retries so the caller's failover ladder (the
    async engine quarantines / re-programs) sees the original error.
    `on_retry(attempt_index, exc)` is called before each backoff sleep -
    serving engines hang their retry counters there; `sleep` is injectable
    so tests can pin the exact backoff schedule without waiting it out.
    """
    for attempt in range(retries + 1):
        try:
            return fn()
        except retriable as e:
            if attempt == retries:
                raise
            log.warning("step failed (%s); retry %d/%d", e, attempt + 1, retries)
            if on_retry is not None:
                on_retry(attempt, e)
            if backoff:
                sleep(backoff * (2 ** attempt))
    raise AssertionError("unreachable")

"""Elastic scaling: rebuild the mesh at a new size and reshard from ckpt.

Elasticity here is restart-path (the production-standard approach for TPU
pods): on a capacity change the job checkpoints (or uses the last one),
re-launches with a new mesh, and `restore_checkpoint` device_puts every
leaf with the *new* sharding.  ElasticMesh picks the best (data, model)
factorisation for the surviving device count given the model's divisibility
constraints.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

import jax
from jax.sharding import Mesh


@dataclasses.dataclass
class ElasticMesh:
    """Chooses mesh shapes as device counts change."""
    model_axis_candidates: Tuple[int, ...] = (16, 8, 4, 2, 1)
    min_model_axis: int = 1

    def choose_shape(self, n_devices: int,
                     model_divisors: Tuple[int, ...] = ()) -> Tuple[int, int]:
        """(data, model) with model as large as divisibility allows."""
        for m in self.model_axis_candidates:
            if m < self.min_model_axis or n_devices % m:
                continue
            if model_divisors and any(d % m for d in model_divisors):
                continue
            return (n_devices // m, m)
        return (n_devices, 1)

    def make_mesh(self, devices: Optional[List] = None,
                  model_divisors: Tuple[int, ...] = ()) -> Mesh:
        devices = devices if devices is not None else jax.devices()
        data, model = self.choose_shape(len(devices), model_divisors)
        import numpy as np
        arr = np.asarray(devices[:data * model]).reshape(data, model)
        return Mesh(arr, ("data", "model"))

    def assign_replicas(self, n_replicas: int,
                        devices: Optional[List] = None) -> List:
        """One device per serving replica, round-robin over the pool.

        With fewer devices than replicas the pool wraps (CPU test runs:
        every replica shares device 0); with more, replicas land on
        distinct devices and the remainder stays free for elasticity.
        Placement is deterministic in (n_replicas, pool order) so fleet
        chaos runs are replayable.
        """
        devices = devices if devices is not None else jax.devices()
        if not devices:
            raise ValueError("no devices to place replicas on")
        return [devices[i % len(devices)] for i in range(n_replicas)]

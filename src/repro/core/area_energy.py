"""Area / power model of the three solver designs (paper Fig. 10).

The paper reports, for a 512 x 512 system at FreePDK 45 nm:

    total area  : original 0.01577 mm^2, one-stage 0.00807, two-stage 0.01383
    area saving : one-stage 48.83%, two-stage 12.30%
    power saving: one-stage 40.0%,  two-stage 37.4%

with four components: OPA, DAC, ADC, RRAM array.  The paper does not publish
its per-component unit values, so we *recover* a consistent parameterisation
from the component-count structure of the three designs plus the six reported
observables (3 area totals + 2 power ratios + normalisation).  The count
structure (documented also in DESIGN.md):

    component          original      one-stage       two-stage
    OPA sets           2n amps       n amps (shared) 2n amps (per-macro INV+MVM sets)
    OPA drive width    n             n/2             n/4
    DAC channels       n             n/2             n (4 macros x n/4)
    ADC channels       n             n/2             n (4 macros x n/4)
    RRAM cells         2 n^2         2 n^2           2 n^2   (differential pairs)

OPA area/power are affine in drive width (output stage scales with the
column load): a_opa(w) = a0 + a1 * w.  Writing, for the original design,
  alpha = 2n * a0            (OPA fixed part)
  beta  = 2n * a1 * n        (OPA width-scaled part)
  delta = n * (a_dac + a_adc)
  gamma = 2 n^2 * a_cell
the three designs cost:
  original  = alpha   + beta   + delta   + gamma
  one-stage = alpha/2 + beta/4 + delta/2 + gamma
  two-stage = alpha   + beta/4 + delta   + gamma
and the reported savings pin down (see EXPERIMENTS.md for the algebra):
  area : beta = 4/3 * 0.1230 * T,  alpha + delta = 2*(0.4883 - 0.1230)*T,
         gamma = T - alpha - beta - delta            (T = 0.01577 mm^2)
  power: beta_p = 4/3 * 0.374 * P, alpha_p + delta_p = 2*(0.400 - 0.374)*P,
         gamma_p = P - ...                           (P normalised to 1)
The alpha:delta split inside their sum is not observable from the paper's
totals; we split 50:50 (documented free choice; it does not affect any
reported percentage).  `solve_calibration()` performs this recovery and
`breakdown()` evaluates any (n, solver) with the recovered units.
"""
from __future__ import annotations

import dataclasses
from typing import Dict

N_PAPER = 512
AREA_TOTAL_PAPER = 0.01577          # mm^2, original AMC, n = 512
AREA_SAVING_ONE = 0.4883            # abstract: 48.83%
AREA_SAVING_TWO = 0.1230
POWER_SAVING_ONE = 0.400
POWER_SAVING_TWO = 0.374


@dataclasses.dataclass(frozen=True)
class UnitCosts:
    """Recovered per-unit areas (mm^2) and powers (normalised W)."""
    opa_fixed: float      # a0: per amplifier
    opa_per_width: float  # a1: per amplifier per unit drive width
    dac: float            # per channel
    adc: float            # per channel
    cell: float           # per RRAM cell


def _solve(total: float, s1: float, s2: float) -> tuple:
    """Recover (alpha, beta, delta, gamma) from total + two savings."""
    beta = 4.0 / 3.0 * s2 * total
    alpha_plus_delta = 2.0 * (s1 - s2) * total
    alpha = 0.5 * alpha_plus_delta   # documented 50:50 split
    delta = 0.5 * alpha_plus_delta
    gamma = total - alpha - beta - delta
    assert gamma > 0, "calibration produced negative array cost"
    return alpha, beta, delta, gamma


def solve_calibration(n: int = N_PAPER,
                      area_total: float = AREA_TOTAL_PAPER,
                      power_total: float = 1.0) -> Dict[str, UnitCosts]:
    """Recover unit areas and powers from the paper's reported numbers."""
    out = {}
    for kind, total, s1, s2 in (
            ("area", area_total, AREA_SAVING_ONE, AREA_SAVING_TWO),
            ("power", power_total, POWER_SAVING_ONE, POWER_SAVING_TWO)):
        alpha, beta, delta, gamma = _solve(total, s1, s2)
        out[kind] = UnitCosts(
            opa_fixed=alpha / (2 * n),
            opa_per_width=beta / (2 * n * n),
            dac=delta / (2 * n),       # delta = n*(dac+adc); split 50:50
            adc=delta / (2 * n),
            cell=gamma / (2 * n * n),
        )
    return out


def _counts(n: int, solver: str):
    """(amp count, amp width, dac ch, adc ch, cells) per design."""
    if solver == "original":
        return 2 * n, n, n, n, 2 * n * n
    if solver == "one_stage":
        return n, n // 2, n // 2, n // 2, 2 * n * n
    if solver == "two_stage":
        return 2 * n, n // 4, n, n, 2 * n * n
    raise ValueError(solver)


def breakdown(n: int, solver: str, units: UnitCosts) -> Dict[str, float]:
    """Component breakdown for one design at size n with given unit costs."""
    n_amp, w_amp, n_dac, n_adc, n_cell = _counts(n, solver)
    opa = n_amp * (units.opa_fixed + units.opa_per_width * w_amp)
    return {
        "opa": opa,
        "dac": n_dac * units.dac,
        "adc": n_adc * units.adc,
        "array": n_cell * units.cell,
        "total": opa + n_dac * units.dac + n_adc * units.adc + n_cell * units.cell,
    }


def report(n: int = N_PAPER) -> Dict[str, Dict[str, Dict[str, float]]]:
    """Full Fig. 10 reproduction: area and power breakdowns, three solvers."""
    cal = solve_calibration(n=N_PAPER)
    out: Dict[str, Dict[str, Dict[str, float]]] = {}
    for kind in ("area", "power"):
        out[kind] = {s: breakdown(n, s, cal[kind])
                     for s in ("original", "one_stage", "two_stage")}
    return out


def savings(rep: Dict[str, Dict[str, Dict[str, float]]]) -> Dict[str, Dict[str, float]]:
    """Savings vs original, per kind and solver - the headline numbers."""
    out = {}
    for kind, solvers in rep.items():
        t0 = solvers["original"]["total"]
        out[kind] = {s: 1.0 - solvers[s]["total"] / t0
                     for s in ("one_stage", "two_stage")}
    return out

"""Compatibility shim: the hybrid subsystem moved to `repro.hybrid`.

`core/hybrid.py` began as a 110-line single-RHS sketch of the paper's
Section IV positioning (AMC output as seed/preconditioner for digital
iteration).  It is now a full subsystem - batched Krylov drivers, the
`AnalogPreconditioner` operator adapter, fused/sharded refinement - living
in `repro.hybrid`.  This module re-exports the whole public surface so
existing imports (`from repro.core import hybrid`) keep working.
"""
from repro.hybrid import (  # noqa: F401
    AnalogPreconditioner, KrylovResult, cg_refine, gmres, iterations_to_tol,
    matvec_from_dense, pcg, richardson_refine, solve_refined,
    solve_refined_batched, solve_refined_batched_sharded)

"""BlockAMC macro schedule model (paper Section III.B).

"In every clock cycle, an MVM or INV operation is accomplished."  The macro
shares one set of OPAs among its four arrays (transmission-gate reconfig),
so its five steps are strictly sequential; S&H double buffering lets a
*stream* of right-hand sides pipeline through.  The two-stage solver deploys
four one-stage macros on a bus with per-macro OPA sets for INV and MVM.

This is a resource-constrained list scheduler over the operation DAG - the
behavioural stand-in for Fig. 4(b)'s clock controller.  It reports latency
(cycles until the first solve completes), steady-state initiation interval
(cycles between successive solve completions), and per-solve energy from the
recovered unit powers of `area_energy`.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple


@dataclasses.dataclass(frozen=True)
class Op:
    name: str
    resource: str           # which OPA set executes it
    deps: Tuple[int, ...]   # indices of ops that must complete first


def one_stage_dag() -> List[Op]:
    """The five-step cascade on one shared OPA set."""
    r = "macro0"
    return [
        Op("inv_A1_f", r, ()),        # step 1
        Op("mvm_A3", r, (0,)),        # step 2
        Op("inv_A4s", r, (1,)),       # step 3
        Op("mvm_A2", r, (2,)),        # step 4
        Op("inv_A1_fs", r, (3,)),     # step 5
    ]


def two_stage_dag() -> List[Op]:
    """Stage-1 cascade where each INV expands into a 5-op stage-2 cascade
    on its own macro, and stage-1 MVMs run on dedicated MVM OPA sets
    ("OPAs are separately deployed for the first-stage INV and MVM")."""
    ops: List[Op] = []

    def inv_block(macro: str, deps: Tuple[int, ...]) -> Tuple[int, ...]:
        base = len(ops)
        ops.append(Op(f"{macro}.inv_A1_f", macro, deps))
        ops.append(Op(f"{macro}.mvm_A3", macro, (base,)))
        ops.append(Op(f"{macro}.inv_A4s", macro, (base + 1,)))
        ops.append(Op(f"{macro}.mvm_A2", macro, (base + 2,)))
        ops.append(Op(f"{macro}.inv_A1_fs", macro, (base + 3,)))
        return (base + 2, base + 4)   # outputs: z at step3, y at step5

    s1 = inv_block("macroA1", ())                    # stage-1 step 1
    m2 = len(ops)
    ops.append(Op("mvm_A3_s1", "mvm_set", s1))       # stage-1 step 2
    s3 = inv_block("macroA4s", (m2,))                # stage-1 step 3
    m4 = len(ops)
    ops.append(Op("mvm_A2_s1", "mvm_set", s3))       # stage-1 step 4
    inv_block("macroA1", (m4,))                      # stage-1 step 5 (reuse)
    return ops


def schedule(ops: List[Op], n_solves: int = 1) -> Dict[str, float]:
    """Greedy list schedule of `n_solves` back-to-back solves.

    Each op takes one clock cycle; each resource runs one op per cycle; an
    op may start once its deps (within its own solve instance) are done.
    S&H double buffering means an op's output is available the next cycle.
    """
    total = []
    for s in range(n_solves):
        for op in ops:
            total.append(Op(f"s{s}.{op.name}", op.resource,
                            tuple(d + s * len(ops) for d in op.deps)))
    finish: List[Optional[int]] = [None] * len(total)
    busy_until: Dict[str, int] = {}
    t = 0
    remaining = set(range(len(total)))
    completion_per_solve = [0] * n_solves
    while remaining:
        # ready ops whose deps are finished by cycle t
        launched = set()
        for i in sorted(remaining):
            op = total[i]
            if any(finish[d] is None or finish[d] > t for d in op.deps):
                continue
            if busy_until.get(op.resource, -1) >= t:
                continue
            busy_until[op.resource] = t
            finish[i] = t + 1
            launched.add(i)
        remaining -= launched
        t += 1
        if t > 100 * len(total):
            raise RuntimeError("scheduler wedged")
    for i, op in enumerate(total):
        s = int(op.name.split(".")[0][1:])
        completion_per_solve[s] = max(completion_per_solve[s], finish[i])
    latency = completion_per_solve[0]
    if n_solves > 1:
        ii = (completion_per_solve[-1] - completion_per_solve[0]) / (n_solves - 1)
    else:
        ii = float(latency)
    return {"latency_cycles": float(latency),
            "initiation_interval": float(ii),
            "makespan": float(max(completion_per_solve))}


def solver_performance(solver: str, n_solves: int = 16) -> Dict[str, float]:
    """Latency/II for 'original' (1 cycle), one- or two-stage macros."""
    if solver == "original":
        return {"latency_cycles": 1.0, "initiation_interval": 1.0,
                "makespan": float(n_solves)}
    dag = one_stage_dag() if solver == "one_stage" else two_stage_dag()
    return schedule(dag, n_solves)

"""Accuracy metrics used throughout the paper's evaluation (Eq. 6)."""
from __future__ import annotations

import jax.numpy as jnp


def relative_error(x_ideal: jnp.ndarray, x_actual: jnp.ndarray) -> jnp.ndarray:
    """Paper Eq. (6): eps_r = | sum_i sqrt((x_i - xhat_i)^2) / sum_i sqrt(x_i^2) |.

    Note sqrt((.)^2) == abs(.), i.e. this is an L1/L1 relative error. We keep
    the paper's exact definition (not the L2 norm ratio).
    Supports batched inputs: reduction is over the last axis.
    """
    num = jnp.sum(jnp.abs(x_ideal - x_actual), axis=-1)
    den = jnp.sum(jnp.abs(x_ideal), axis=-1)
    return jnp.abs(num / den)


def l2_relative_error(x_ideal: jnp.ndarray, x_actual: jnp.ndarray) -> jnp.ndarray:
    """Standard ||x - xhat|| / ||x||, reported alongside the paper metric."""
    num = jnp.linalg.norm(x_ideal - x_actual, axis=-1)
    den = jnp.linalg.norm(x_ideal, axis=-1)
    return num / den

"""Distributed BlockAMC over a TPU mesh (the paper's Fig. 3/5 at pod scale).

The two-stage BlockAMC architecture - many fixed-size arrays on a data bus,
partial MVMs recovered by summation, INV results cascaded - maps naturally
onto a JAX device mesh:

  RRAM array  -> one VMEM-resident tile of conductance state on one chip
  data bus    -> ICI collectives (psum / all_gather across mesh axes)
  macro       -> shard_map-ed tile kernel

Everything here is *vectorised over tiles* (a (rt, ct, s, s) tile tensor,
not Python tile lists) so a 65536^2 system lowers to a compact HLO: the
per-tile axes shard over the ("data", "model") mesh axes and XLA inserts
the bus traffic.  The digital Schur pre-processing is expressed as recursive
*block inversion* (the BlockAMC identity itself, digitally) so it is pure
GEMMs + tiny leaf inverses - ideal for GSPMD sharding, no LU factorisation
of a distributed matrix anywhere.

Execution on CPU for tests uses small n and a host-device mesh; the dry-run
lowers n = 65536 on the production 16x16 mesh.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.analog import AnalogConfig
# Stacked-tile form lives in core/analog.py (shared with the flat
# level-scheduled executor); re-exported here for backward compatibility.
from repro.core.analog import TileGrid, map_tiled_vec


def mvm_tiled_vec(grid: TileGrid, v: jnp.ndarray, cfg: AnalogConfig,
                  mesh: Optional[Mesh] = None) -> jnp.ndarray:
    """Partitioned analog MVM: out = -A_eff @ v with per-tile partial sums.

    With a mesh, tile axes are shard-constrained to ("data", "model") and the
    partial-sum contraction becomes a psum-like reduction over "model" - the
    'recover the final solution' step of refs [13]-[15] on the ICI bus.
    """
    a_eff = grid.a_eff(cfg)                        # (rt, ct, s, s)
    rt, ct, s, _ = a_eff.shape
    vt = v.reshape(ct, s)
    if mesh is not None:
        a_eff = jax.lax.with_sharding_constraint(
            a_eff, NamedSharding(mesh, P("data", "model", None, None)))
        vt = jax.lax.with_sharding_constraint(
            vt, NamedSharding(mesh, P("model", None)))
    out = -jnp.einsum("rcij,cj->ri", a_eff, vt)
    if mesh is not None:
        out = jax.lax.with_sharding_constraint(
            out, NamedSharding(mesh, P("data", None)))
    return out.reshape(rt * s)


# ---------------------------------------------------------------------------
# Digital block inversion (GEMM-only Schur recursion; the pre-processor)
# ---------------------------------------------------------------------------

def block_inv(a: jnp.ndarray, leaf: int) -> jnp.ndarray:
    """Recursive 2x2 block inversion - BlockAMC's identity, digitally.

      A = [[A1, A2], [A3, A4]],  S = A4 - A3 A1^-1 A2
      A^-1 = [[A1i + W S^-1 V,  -W S^-1],
              [-S^-1 V,          S^-1  ]],  W = A1i A2, V = A3 A1i

    Only GEMMs + leaf-size inverses: shards cleanly under GSPMD, unlike a
    distributed LU.  FLOPs ~ 2x a one-shot inverse; the win is layout.
    """
    n = a.shape[0]
    if n <= leaf:
        return jnp.linalg.inv(a)
    m = n // 2
    a1, a2 = a[:m, :m], a[:m, m:]
    a3, a4 = a[m:, :m], a[m:, m:]
    a1i = block_inv(a1, leaf)
    w = a1i @ a2
    v = a3 @ a1i
    s = a4 - a3 @ w
    si = block_inv(s, leaf)
    top = jnp.concatenate([a1i + w @ (si @ v), -(w @ si)], axis=1)
    bot = jnp.concatenate([-(si @ v), si], axis=1)
    return jnp.concatenate([top, bot], axis=0)


# ---------------------------------------------------------------------------
# Distributed BlockAMC solver
# ---------------------------------------------------------------------------

@jax.tree_util.register_pytree_node_class
class DistPlan:
    """Flattened distributed plan: per-level tile grids.

    Level k (k = 0 .. stages-1) holds the A2/A3 MVM grids of every block at
    that depth, stacked along a leading 'block' axis (2^k blocks), plus the
    Schur complements already folded into the next level.  The leaves hold
    the final INV tile pairs (2^stages of them, each one array).
    """

    def __init__(self, mvm2, mvm3, leaves, scale, stages):
        self.mvm2 = mvm2          # list over levels: TileGrid w/ leading block axis
        self.mvm3 = mvm3
        self.leaves = leaves      # TileGrid: (n_leaves, 1, 1, s, s)-ish
        self.scale = scale
        self.stages = stages

    def tree_flatten(self):
        return (self.mvm2, self.mvm3, self.leaves, self.scale), (self.stages,)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children, aux[0])


def build_dist_plan(a: jnp.ndarray, key: jax.Array, cfg: AnalogConfig,
                    stages: int) -> DistPlan:
    """Vectorised plan builder: all blocks of one level mapped with one vmap.

    Digital pre-processing uses `block_inv` (GEMM-only) so the whole builder
    lowers to sharded GEMMs on the production mesh.
    """
    n = a.shape[0]
    s = cfg.array_size
    assert n % (2 ** stages) == 0 and (n // 2 ** stages) % s == 0 or \
        (n // 2 ** stages) == s or (n // 2 ** stages) % s == 0, "pow2 sizes"
    scale = 1.0 / jnp.max(jnp.abs(a))

    mvm2_levels, mvm3_levels = [], []
    blocks = [a]                       # blocks at current level
    for level in range(stages):
        m = blocks[0].shape[0] // 2
        a2s = jnp.stack([blk[:m, m:] for blk in blocks])   # (nb, m, m)
        a3s = jnp.stack([blk[m:, :m] for blk in blocks])
        key, k2, k3 = jax.random.split(key, 3)
        k2s = jax.random.split(k2, len(blocks))
        k3s = jax.random.split(k3, len(blocks))
        mvm2_levels.append(jax.vmap(
            lambda blk, kk: map_tiled_vec(blk, kk, cfg, scale))(a2s, k2s))
        mvm3_levels.append(jax.vmap(
            lambda blk, kk: map_tiled_vec(blk, kk, cfg, scale))(a3s, k3s))
        next_blocks = []
        for blk in blocks:
            b1 = blk[:m, :m]
            b2 = blk[:m, m:]
            b3 = blk[m:, :m]
            b4 = blk[m:, m:]
            # Schur complement via GEMM-only digital inversion.
            s4 = b4 - b3 @ (block_inv(b1, cfg.array_size) @ b2)
            next_blocks.extend([b1, s4])
        blocks = next_blocks
    key, kl = jax.random.split(key)
    kls = jax.random.split(kl, len(blocks))
    leaves = jax.vmap(
        lambda blk, kk: map_tiled_vec(blk, kk, cfg, scale))(jnp.stack(blocks), kls)
    return DistPlan(mvm2_levels, mvm3_levels, leaves, scale, stages)


def _index_grid(grid: TileGrid, i: int) -> TileGrid:
    return TileGrid(grid.gpos[i], grid.gneg[i], grid.scale, grid.g0)


def dist_execute(plan: DistPlan, b: jnp.ndarray, cfg: AnalogConfig,
                 mesh: Optional[Mesh] = None) -> jnp.ndarray:
    """Run the cascade; same five-step signs as blockamc._exec_inv."""

    def exec_inv(level: int, block_idx: int, v: jnp.ndarray) -> jnp.ndarray:
        if level == plan.stages:
            grid = _index_grid(plan.leaves, block_idx)
            a_eff = grid.a_eff(cfg)          # (rt, ct, s, s)
            rt, ct, s, _ = a_eff.shape
            # Reassemble multi-tile leaves (generalised block-matrix circuit,
            # paper ref [25]) into the single INV operand.
            a_full = a_eff.transpose(0, 2, 1, 3).reshape(rt * s, ct * s)
            return -jnp.linalg.solve(a_full, v)
        m = v.shape[0] // 2
        f, g = v[:m], v[m:]
        g2 = _index_grid(plan.mvm2[level], block_idx)
        g3 = _index_grid(plan.mvm3[level], block_idx)
        neg_yt = exec_inv(level + 1, 2 * block_idx, f)          # step 1
        gt = mvm_tiled_vec(g3, neg_yt, cfg, mesh)               # step 2
        z = exec_inv(level + 1, 2 * block_idx + 1, -g + gt)     # step 3
        neg_ft = mvm_tiled_vec(g2, z, cfg, mesh)                # step 4
        neg_y = exec_inv(level + 1, 2 * block_idx, f + neg_ft)  # step 5
        return jnp.concatenate([neg_y, -z])

    out = exec_inv(0, 0, b)
    return -plan.scale * out


def solve_distributed(a: jnp.ndarray, b: jnp.ndarray, key: jax.Array,
                      cfg: AnalogConfig, stages: int,
                      mesh: Optional[Mesh] = None) -> jnp.ndarray:
    plan = build_dist_plan(a, key, cfg, stages)
    return dist_execute(plan, b, cfg, mesh)

"""BlockAMC: block-partitioned analog solver for A x = b (paper Section III).

The original matrix A is partitioned

        A = [[A1, A2],      b = [f,
             [A3, A4]]           g]

and the solve proceeds in five cascaded analog operations (Algorithm 1):

    step 1  INV(A1):   -y_t = -A1^-1 f
    step 2  MVM(A3):    g_t = -A3 (-y_t)
    step 3  INV(A4s):   z   = -A4s^-1 (-g_s),  A4s = A4 - A3 A1^-1 A2,
                                               -g_s = -g + g_t
    step 4  MVM(A2):   -f_t = -A2 z
    step 5  INV(A1):   -y   = -A1^-1 f_s,      f_s = f - f_t

    x = [y; z]

A4s (the Schur complement) is computed **digitally in advance** and programmed
into its own array - the paper's stated pre-processing overhead.  Multi-stage
solving recurses on the INV steps: every INV whose operand exceeds the
physical array size is itself solved by BlockAMC, and oversized MVM operands
use partitioned (tiled) MVM.  Two stages on a 256x256 system yields 16 arrays
of 64x64, matching paper Fig. 8.

The implementation is plan/execute:

  * `build_plan(A, key, cfg, stages)` does everything that happens at
    *programming time*: partitioning, digital Schur complements, matrix
    normalisation, conductance mapping with per-array programming noise.
  * `execute(plan, b, cfg)` runs the five-step cascade - the *analog runtime*
    - reusing the programmed arrays for any number of right-hand sides.

Both are pure functions of their inputs (vmap-able over noise keys for the
paper's 40-seed Monte Carlo, and jit-able end to end).

On top of the recursive reference executor sits the *flat* level-scheduled
executor (`compile_plan` / `execute_flat` / `solve_batched`): the recursive
plan is compiled once into shape-bucketed stacks of physical arrays (e.g. a
two-stage 256x256 solve becomes 16 arrays of 64x64, stored as a handful of
(num_arrays, 64, 64) conductance tensors - paper Fig. 8) plus a static
straight-line schedule over virtual registers.  Execution is a short loop
over schedule levels; every level is one batched analog op, so vmapping over
Monte-Carlo noise keys and right-hand sides turns the whole cascade into a
few large batched matmuls/solves instead of a per-seed tree walk.  The
recursive executor stays as the bit-level reference the flat executor is
tested against.

On top of *that* sits the finalization layer (`finalize` / `FinalizedPlan` /
`ProgrammedSolver`): once per programmed matrix, every INV bucket's effective
operator is LU-factorised and every MVM level's effective tile operators are
gathered into fused (num_tiles, r, c) stacks, so each subsequent solve is
pure batched `lu_solve`s and stacked matmuls - the paper's program-once /
solve-many cost model.  `execute_flat` remains the unfinalized reference the
finalized path is pinned to bit-for-bit.

DESIGN - the arena executor (`compile_arena` / `ArenaPlan` / `execute_arena`)
=============================================================================
The serving hot path compiles one step further.  `execute_finalized` still
runs a Python-interpreted schedule of small XLA ops: a growing register
list, `jnp.concatenate` at every "catneg", per-tile-row Python loops in
`_MvmLevel.apply`, and one `lu_solve` per INV level.  The AMC hardware view
(Sun & Ielmini 2022) is simpler: the INV macro is a one-step closed-form
inverse operator and the cascade is a handful of stacked MVMs.  The arena
form mirrors that:

  * **Static register arena.**  At compile time a live-range analysis walks
    the flat schedule.  Only *compute* results (leaf INV outputs, MVM
    outputs) and the DAC'd input vector are materialized; each gets a
    static offset in one preallocated f32 arena (trailing RHS-batch dim).
    The offline allocator (best of first-fit-in-def-order and
    greedy-by-size over the known live intervals) recycles dead slots: the
    arena extent equals the schedule's peak liveness exactly on aligned
    power-of-two schedules and stays within one slot of it on ragged odd
    splits (`tests/test_plan_properties.py` pins no-overlap, window
    containment and both bounds).
  * **Wiring ops cost zero copies.**  "slice"/"add"/"catneg" levels never
    execute: they are folded into *views* - each consumer reads its operand
    as a static list of signed slot windows (segment = (dst_lo, len,
    ((mreg, local_off, sign), ...)), arena offset = slot_offsets[mreg] +
    local_off), evaluated in the reference accumulation order, so the
    gather is bit-identical to the folded adds/negations.
  * **One stacked-tile form for INV and MVM.**  Every INV bucket's
    effective operator is explicitly inverted once at compile time (batched
    solve of the identity against the finalize-time LU factors, sign
    folded: W = -A_fx^-1), and every MVM tile's operator is stored with the
    circuit sign and its tile-row's finite-gain summing-node divisor folded
    in (W = -A_eff / div).  Each runtime level is then `out += W @ gather`
    - pure stacked matmuls.
  * **Two executions of one layout.**  On TPU the Pallas level-megakernel
    (`repro.kernels.arena_mvm`) owns the physical arena buffer - uniform
    power-of-two plans flatten to a whole-schedule tile program
    (`ArenaPlan.program`) run as ONE pallas_call; `interpret=True` runs
    the same body on CPU (the CI smoke).  The CPU fast path executes the
    identical layout in slot-SSA form (each slot its own XLA value), which
    keeps the gathers/writes fusible and skips whole-arena update copies.

Bit-compat contract: recursive == flat == finalized stays bit-for-bit on
CPU (eager) as before.  The arena mode is *float-tolerance* by design - the
explicit inverse reassociates the INV solve and the divisor is applied
before the tile dot instead of after - and is pinned against the finalized
executor by the four-way equivalence suite (tests/test_fused_arena.py,
TESTING.md).  It is the default `mode="fused"` on the serving surfaces
(`ProgrammedSolver`, `SolverService`, `AnalogPreconditioner`);
`mode="reference"` keeps the finalized path.

DESIGN - the packed instance axis (multi-tenant serving)
========================================================
A solver service fields many *different matrices* concurrently; the packed
layer adds the cross-tenant axis the per-matrix arena form lacks.

  * **Signature-stackability invariant.**  Every static artifact of the
    compile pipeline - partition split tree, bucket shapes, flat schedule,
    finalized windows, arena slot layout, whole-schedule window program -
    is a deterministic function of (n, stages, cfg) alone; matrix values
    and noise keys only ever flow into array *contents*.
    `plan_signature(n, stages, cfg)` is therefore a sufficient key: plans
    with equal signatures flatten to identical treedefs, leaf shapes and
    static metadata, and may be stacked leaf-for-leaf on a leading
    instance axis (pinned by tests/test_plan_properties.py).
  * **Instance-axis layout.**  A `PackedArenaPlan` stores the shared
    static metadata once and carries every operator stack as
    (M, L, rows, cols) - instance axis first, then the ArenaPlan layout
    unchanged - with (M,) scales and, for uniform plans, the (M, T, r, c)
    whole-schedule operator sequence over ONE shared (T, ...) window
    program.  Batched programming (`program_system_batched` /
    `finalize_batched` / `compile_arena_batched`, or `program_packed`
    end to end) vmaps the per-matrix pipeline, so programming a fleet
    costs one trace; `pack_arena_plans` stacks independently programmed
    plans (the `SolverService.flush_all` path).
  * **One dispatch over (tenants x rhs).**  `execute_arena_packed` runs
    every schedule level as stacked-tile matmuls whose batch dims carry
    the instance axis (per-tenant results bit-for-bit with that tenant's
    own `execute_arena` eagerly on CPU for aligned power-of-two plans;
    last-ulp on ragged splits), and the packed Pallas megakernel
    (`kernels/arena_mvm.py arena_packed_apply`) grows an instance grid
    axis: grid (M, T) over an (M, S, K) arena stack, the whole fleet in
    ONE pallas_call.  `sharding.partition.mc_packed_specs` shards the
    instance axis over the mc mesh (`execute_arena_packed_sharded`).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional, Tuple, Union

import jax
import jax.numpy as jnp

from repro.core import analog
from repro.core.analog import AnalogConfig, CrossbarPair, TileGrid


# ---------------------------------------------------------------------------
# Plans (pytrees)
# ---------------------------------------------------------------------------

@jax.tree_util.register_pytree_node_class
class LeafInvPlan:
    """An INV operation small enough for one physical array."""

    def __init__(self, pair: CrossbarPair):
        self.pair = pair

    def tree_flatten(self):
        return (self.pair,), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0])

    @property
    def n(self):
        return self.pair.shape[0]


@jax.tree_util.register_pytree_node_class
class BlockPlan:
    """One BlockAMC stage: INV plans for A1/A4s, tiled MVM grids for A2/A3."""

    def __init__(self, inv1, mvm2, mvm3, inv4s, m):
        self.inv1 = inv1      # plan for A1 (LeafInvPlan or BlockPlan)
        self.mvm2 = mvm2      # tile grid for A2
        self.mvm3 = mvm3      # tile grid for A3
        self.inv4s = inv4s    # plan for A4s
        self.m = m            # split point (static)

    def tree_flatten(self):
        return (self.inv1, self.mvm2, self.mvm3, self.inv4s), (self.m,)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children, aux[0])

    @property
    def n(self):
        return self.inv1.n + self.inv4s.n


Plan = Union[LeafInvPlan, BlockPlan]


@dataclasses.dataclass
class SolvePlan:
    """Top-level plan: the recursive structure plus the global scale."""
    root: Plan
    scale: jnp.ndarray   # c = 1/max|A|; solution is descaled digitally


jax.tree_util.register_dataclass(
    SolvePlan, data_fields=["root", "scale"], meta_fields=[])


# ---------------------------------------------------------------------------
# Plan construction (programming time)
#
# Split into two walks so the Monte-Carlo path can hoist the expensive,
# *key-independent* digital pre-processing (partitioning, Schur complements,
# normalisation) out of the per-noise-key loop:
#
#   partition_system(a, cfg, stages)  -> PartitionedSystem   (digital, once)
#   program_system(parts, key, cfg)   -> SolvePlan           (per noise key)
#
# `build_plan` composes the two and is unchanged API-wise; the key-splitting
# order of `program_system` matches the old fused builder exactly, so noise
# draws (and therefore every downstream golden test) are bit-identical.
# ---------------------------------------------------------------------------

def required_stages(n: int, array_size: int) -> int:
    """Smallest number of partitioning stages so every INV fits one array."""
    stages = 0
    while n > array_size:
        n = -(-n // 2)
        stages += 1
    return stages


@jax.tree_util.register_pytree_node_class
class LeafTarget:
    """Partitioning leaf: one block destined for a single INV array."""

    def __init__(self, a):
        self.a = a

    def tree_flatten(self):
        return (self.a,), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0])

    @property
    def n(self):
        return self.a.shape[0]


@jax.tree_util.register_pytree_node_class
class BlockTarget:
    """One partitioning stage: INV targets for A1/A4s, raw blocks A2/A3."""

    def __init__(self, inv1, a2, a3, inv4s, m):
        self.inv1 = inv1
        self.a2 = a2
        self.a3 = a3
        self.inv4s = inv4s
        self.m = m

    def tree_flatten(self):
        return (self.inv1, self.a2, self.a3, self.inv4s), (self.m,)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children, aux[0])

    @property
    def n(self):
        return self.inv1.n + self.inv4s.n


Target = Union[LeafTarget, BlockTarget]


@dataclasses.dataclass
class PartitionedSystem:
    """Key-independent digital pre-processing of one system matrix."""
    root: Target
    scale: jnp.ndarray   # c = 1/max|A|


jax.tree_util.register_dataclass(
    PartitionedSystem, data_fields=["root", "scale"], meta_fields=[])


def _split_tree(n: int, stages: int):
    """The static partition split tree for (n, stages): a leaf size, or a
    pair of subtrees.

    The one definition of the split rule - `_partition` consumes this tree
    and `plan_signature` hashes it, so the packed-serving stackability key
    stays correct by construction if the rule ever changes.  A 1x1 block
    cannot be partitioned further: splitting it would produce zero-width
    A2/A3 and an empty Schur complement (physical arrays with no devices),
    so surplus stages stop there.  Paper: for odd n, A1 takes (n+1)/2; any
    square A1 works.
    """
    if stages == 0 or n <= 1:
        return int(n)
    m = -(-n // 2)
    return (_split_tree(m, stages - 1), _split_tree(n - m, stages - 1))


def _tree_size(tree) -> int:
    return tree if isinstance(tree, int) else \
        _tree_size(tree[0]) + _tree_size(tree[1])


def _partition_by(a: jnp.ndarray, tree) -> Target:
    if isinstance(tree, int):
        return LeafTarget(a)
    left, right = tree
    m = _tree_size(left)
    a1, a2 = a[:m, :m], a[:m, m:]
    a3, a4 = a[m:, :m], a[m:, m:]
    # Digital pre-processing of the Schur complement (paper Eq. 3).  Done in
    # f32 here, standing in for the host preprocessor in Fig. 3.
    a4s = a4 - a3 @ jnp.linalg.solve(a1, a2)
    return BlockTarget(_partition_by(a1, left), a2, a3,
                       _partition_by(a4s, right), m)


def _partition(a: jnp.ndarray, stages: int) -> Target:
    return _partition_by(a, _split_tree(a.shape[0], stages))


def partition_system(a: jnp.ndarray, cfg: AnalogConfig,
                     stages: Optional[int] = None) -> PartitionedSystem:
    """Partition, Schur-complement and normalise A (no noise key needed).

    stages=None auto-selects the minimum depth so leaves fit cfg.array_size
    (stages=1 -> paper's one-stage solver, 2 -> two-stage, 0 -> original AMC).
    """
    n = a.shape[0]
    if stages is None:
        stages = required_stages(n, cfg.array_size)
    # Global normalisation: largest |element| of the *original* matrix -> 1.
    scale = 1.0 / jnp.max(jnp.abs(a))
    return PartitionedSystem(root=_partition(a, stages), scale=scale)


def _program(t: Target, key: jax.Array, cfg: AnalogConfig,
             scale: jnp.ndarray) -> Plan:
    if isinstance(t, LeafTarget):
        return LeafInvPlan(analog.map_matrix(t.a, key, cfg, scale))
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return BlockPlan(
        inv1=_program(t.inv1, k1, cfg, scale),
        mvm2=analog.map_tiled(t.a2, k2, cfg, scale),
        mvm3=analog.map_tiled(t.a3, k3, cfg, scale),
        inv4s=_program(t.inv4s, k4, cfg, scale),
        m=t.m,
    )


def program_system(parts: PartitionedSystem, key: jax.Array,
                   cfg: AnalogConfig) -> SolvePlan:
    """'Program' a partitioned system: conductance mapping + device noise."""
    return SolvePlan(root=_program(parts.root, key, cfg, parts.scale),
                     scale=parts.scale)


def build_plan(a: jnp.ndarray, key: jax.Array, cfg: AnalogConfig,
               stages: Optional[int] = None) -> SolvePlan:
    """Partition, pre-process, normalise and 'program' matrix A."""
    return program_system(partition_system(a, cfg, stages), key, cfg)


def build_original_plan(a: jnp.ndarray, key: jax.Array,
                        cfg: AnalogConfig) -> SolvePlan:
    """The baseline 'original AMC': one monolithic INV array of size n.

    Used by every paper comparison ('compared to a single AMC circuit
    solving the same problem').  Ignores cfg.array_size deliberately.
    """
    scale = 1.0 / jnp.max(jnp.abs(a))
    return SolvePlan(root=LeafInvPlan(analog.map_matrix(a, key, cfg, scale)),
                     scale=scale)


# ---------------------------------------------------------------------------
# Execution (analog runtime; five-step cascade per stage)
# ---------------------------------------------------------------------------

def _exec_inv(plan: Plan, v_in: jnp.ndarray, cfg: AnalogConfig) -> jnp.ndarray:
    """Run an INV plan with the circuit sign convention: returns -A^-1 v_in."""
    if isinstance(plan, LeafInvPlan):
        return analog.amc_inv(plan.pair, v_in, cfg)
    m = plan.m
    f, g = v_in[:m], v_in[m:]
    # --- Algorithm 1, signs kept exactly as the circuits produce them. ---
    neg_yt = _exec_inv(plan.inv1, f, cfg)                 # step 1: -y_t
    gt = analog.amc_mvm_tiled(plan.mvm3, neg_yt, cfg)     # step 2: -A3(-y_t) = g_t
    neg_gs = -g + gt                                      # analog summation: -g_s
    z = _exec_inv(plan.inv4s, neg_gs, cfg)                # step 3: -A4s^-1(-g_s) = +z
    neg_ft = analog.amc_mvm_tiled(plan.mvm2, z, cfg)      # step 4: -f_t
    fs = f + neg_ft                                       # f_s = f - f_t
    neg_y = _exec_inv(plan.inv1, fs, cfg)                 # step 5: -y  (A1 reused)
    # This function's contract is 'return -A^-1 v_in' = [-y; -z].
    return jnp.concatenate([neg_y, -z])


def execute(plan: SolvePlan, b: jnp.ndarray, cfg: AnalogConfig) -> jnp.ndarray:
    """Solve A x = b with the programmed plan; returns x (digitally descaled).

    With the global normalisation A' = c A (c = plan.scale), the arrays hold
    A' and the cascade's ADC output is  out = -(A')^-1 b = -(A^-1 b)/c, so the
    host recovers  x = -c * out  - one sign flip and one scalar multiply in
    the digital domain.
    """
    b_in = analog.dac(b, cfg)
    out = _exec_inv(plan.root, b_in, cfg)       # = -(cA)^-1 b = -x/c
    out = analog.adc(out, cfg)
    return -plan.scale * out


def solve(a: jnp.ndarray, b: jnp.ndarray, key: jax.Array, cfg: AnalogConfig,
          stages: Optional[int] = None) -> jnp.ndarray:
    """Convenience: build_plan + execute."""
    return execute(build_plan(a, key, cfg, stages), b, cfg)


def solve_original(a: jnp.ndarray, b: jnp.ndarray, key: jax.Array,
                   cfg: AnalogConfig) -> jnp.ndarray:
    """Baseline: original (monolithic) AMC solve."""
    return execute(build_original_plan(a, key, cfg), b, cfg)


# ---------------------------------------------------------------------------
# Flat (level-scheduled) executor
#
# compile_plan() walks a SolvePlan once at trace time and lowers it to
#   * stacked conductance tensors: every physical array of the cascade is
#     interned into a (depth, shape) bucket, so all same-shape arrays at the
#     same cascade depth live in one (num_arrays, rows, cols) TileGrid, and
#   * a static straight-line schedule of levels over virtual registers.
#
# Each schedule level is exactly one analog operation (a leaf INV, a tiled
# MVM, an analog summation, or a wiring step), so executing a plan is a short
# Python loop whose body is entirely batched jnp ops - no tree recursion at
# run time.  Because the schedule and all shapes are static, `execute_flat`
# vmaps/jits cleanly: batching over Monte-Carlo noise keys adds a leading
# axis to every stack and turns each level into one batched matmul or
# batched solve, which is how the hot Monte-Carlo path scales with the
# *number of arrays* instead of the depth of the tree.
# ---------------------------------------------------------------------------

# Schedule instruction set (all operands are static Python ints):
#   ("slice", src, lo, hi)        reg = regs[src][lo:hi]      (partition wiring)
#   ("inv",   bucket, idx, src)   reg = amc_inv(inv_stack[bucket][idx], regs[src])
#   ("mvm",   rows, src)          reg = amc_mvm_tiled(grid, regs[src]); `rows`
#                                 is a tuple of tile-rows of (bucket, idx)
#                                 refs into the MVM stacks
#   ("add",   s1, r1, s2, r2)     reg = s1*regs[r1] + s2*regs[r2], s in {+1,-1}
#                                 (analog current summation at a summing node)
#   ("catneg", r1, r2)            reg = concat([regs[r1], -regs[r2]])
#                                 (reassemble [ -y ; -z ] from cascade halves)


@jax.tree_util.register_pytree_node_class
class FlatPlan:
    """Level-scheduled form of a SolvePlan.

    `inv_stacks` / `mvm_stacks` are tuples of TileGrid, one per
    (cascade depth, array shape) bucket; entry i of a stack holds physical
    array i of that bucket as programmed (identical conductances to the
    recursive plan it was compiled from).  `schedule` is the static level
    program; `inv_keys` / `mvm_keys` record each bucket's (depth, shape)
    for introspection and tests.
    """

    def __init__(self, inv_stacks, mvm_stacks, scale, schedule, n,
                 inv_keys, mvm_keys):
        self.inv_stacks = inv_stacks
        self.mvm_stacks = mvm_stacks
        self.scale = scale
        self.schedule = schedule
        self.n = n
        self.inv_keys = inv_keys
        self.mvm_keys = mvm_keys

    def tree_flatten(self):
        return ((self.inv_stacks, self.mvm_stacks, self.scale),
                (self.schedule, self.n, self.inv_keys, self.mvm_keys))

    @classmethod
    def tree_unflatten(cls, aux, children):
        inv_stacks, mvm_stacks, scale = children
        return cls(inv_stacks, mvm_stacks, scale, *aux)

    @property
    def num_arrays(self) -> int:
        """Total physical arrays of the cascade (16 for 256^2 two-stage)."""
        return sum(g.shape[-3] for g in self.inv_stacks) + \
            sum(g.shape[-3] for g in self.mvm_stacks)

    @property
    def num_levels(self) -> int:
        return len(self.schedule)


class _Interner:
    """Dedupes physical arrays into (depth, shape)-bucketed stacking lists.

    The same CrossbarPair object can be referenced several times by the
    schedule (A1 serves cascade steps 1 and 5), but is programmed - and
    therefore stacked - exactly once.
    """

    def __init__(self):
        self.key_to_bucket = {}
        self.lists = []
        self.keys = []
        self._memo = {}

    def ref(self, key, pair) -> Tuple[int, int]:
        tag = id(pair)
        if tag in self._memo:
            return self._memo[tag]
        if key not in self.key_to_bucket:
            self.key_to_bucket[key] = len(self.lists)
            self.lists.append([])
            self.keys.append(key)
        bucket = self.key_to_bucket[key]
        self.lists[bucket].append(pair)
        out = (bucket, len(self.lists[bucket]) - 1)
        self._memo[tag] = out
        return out


def compile_plan(plan: SolvePlan) -> FlatPlan:
    """Lower a recursive SolvePlan to its level-scheduled flat form.

    Pure restructuring: the stacked conductances are exactly the recursive
    plan's (same noise draws), so both executors compute with identical
    arrays.  Traceable (works under jit/vmap over noise keys).
    """
    invs, mvms = _Interner(), _Interner()
    prog = []
    n_regs = [1]                      # register 0 is the cascade input

    def emit(instr) -> int:
        prog.append(instr)
        r = n_regs[0]
        n_regs[0] += 1
        return r

    def emit_inv(p: Plan, src: int, depth: int) -> int:
        if isinstance(p, LeafInvPlan):
            bucket, idx = invs.ref((depth, p.pair.shape), p.pair)
            return emit(("inv", bucket, idx, src))
        m, n = p.m, p.n
        f = emit(("slice", src, 0, m))
        g = emit(("slice", src, m, n))
        # Five-step cascade (Algorithm 1), one schedule level per step.
        neg_yt = emit_inv(p.inv1, f, depth + 1)                  # step 1
        rows3 = tuple(tuple(mvms.ref((depth, t.shape), t) for t in row)
                      for row in p.mvm3)
        gt = emit(("mvm", rows3, neg_yt))                        # step 2
        neg_gs = emit(("add", -1, g, 1, gt))
        z = emit_inv(p.inv4s, neg_gs, depth + 1)                 # step 3
        rows2 = tuple(tuple(mvms.ref((depth, t.shape), t) for t in row)
                      for row in p.mvm2)
        neg_ft = emit(("mvm", rows2, z))                         # step 4
        fs = emit(("add", 1, f, 1, neg_ft))
        neg_y = emit_inv(p.inv1, fs, depth + 1)                  # step 5
        return emit(("catneg", neg_y, z))

    emit_inv(plan.root, 0, 0)
    g0 = _first_pair(plan.root).g0
    inv_stacks = tuple(analog.stack_pairs(ps, plan.scale, g0)
                       for ps in invs.lists)
    mvm_stacks = tuple(analog.stack_pairs(ps, plan.scale, g0)
                       for ps in mvms.lists)
    return FlatPlan(inv_stacks, mvm_stacks, plan.scale, tuple(prog),
                    plan.root.n, tuple(invs.keys), tuple(mvms.keys))


def _first_pair(p: Plan) -> CrossbarPair:
    return p.pair if isinstance(p, LeafInvPlan) else _first_pair(p.inv1)


def build_flat_plan(a: jnp.ndarray, key: jax.Array, cfg: AnalogConfig,
                    stages: Optional[int] = None) -> FlatPlan:
    """Convenience: build_plan + compile_plan."""
    return compile_plan(build_plan(a, key, cfg, stages))


def _inv_operators(grid: TileGrid, cfg: AnalogConfig,
                   r_wire=None, drift_t=None) -> jnp.ndarray:
    """The (num, s, s) matrices one INV bucket's circuits solve with.

    Matches analog.amc_inv: effective conductance matrix plus the diagonal
    summing-node loading term under finite OPA gain.  `r_wire` optionally
    overrides the static config wire resistance with a traced scalar (the
    calibration path; see `finalize`); `drift_t` optionally overrides the
    static device age - a scalar, or a (num,) vector aging each array of
    the bucket independently (the simulated-device-clock path).
    """
    a = grid.a_eff(cfg, r_wire=r_wire, drift_t=drift_t)
    if cfg.opa_gain is not None:
        load = (cfg.g0 + jnp.sum(grid.gpos + grid.gneg, axis=-1)) \
            / (cfg.opa_gain * cfg.g0)
        a = a + load[..., :, None] * jnp.eye(a.shape[-1], dtype=a.dtype)
    return a


def execute_flat(fplan: FlatPlan, b: jnp.ndarray, cfg: AnalogConfig
                 ) -> jnp.ndarray:
    """Run the level schedule; returns x like `execute`.

    `b` may be a vector (n,) or a matrix (n, k) of k right-hand sides -
    every schedule level then computes all k solves in one batched op.

    Program-once / solve-many: every leaf INV operator is factorised once
    per bucket (one batched LU per stack), and the schedule's INV levels
    reuse the factors - cascade steps 1 and 5 share A1's factorisation
    exactly as the hardware reuses the programmed array.
    """
    lu_stacks = [jax.scipy.linalg.lu_factor(_inv_operators(g, cfg))
                 for g in fplan.inv_stacks]
    regs = [analog.dac(b, cfg)]
    for instr in fplan.schedule:
        op = instr[0]
        if op == "slice":
            _, src, lo, hi = instr
            regs.append(regs[src][lo:hi])
        elif op == "inv":
            _, bucket, idx, src = instr
            lu, piv = lu_stacks[bucket]
            regs.append(-jax.scipy.linalg.lu_solve((lu[idx], piv[idx]),
                                                   regs[src]))
        elif op == "mvm":
            _, rows, src = instr
            grid = [[fplan.mvm_stacks[bk].pair(i) for bk, i in row]
                    for row in rows]
            regs.append(analog.amc_mvm_tiled(grid, regs[src], cfg))
        elif op == "add":
            _, s1, r1, s2, r2 = instr
            x1 = regs[r1] if s1 > 0 else -regs[r1]
            x2 = regs[r2] if s2 > 0 else -regs[r2]
            regs.append(x1 + x2)
        elif op == "catneg":
            _, r1, r2 = instr
            regs.append(jnp.concatenate([regs[r1], -regs[r2]]))
        else:  # pragma: no cover - compile_plan only emits the ops above
            raise ValueError(f"unknown schedule op {op!r}")
    return -fplan.scale * analog.adc(regs[-1], cfg)


# ---------------------------------------------------------------------------
# Finalization: program-once / solve-many
#
# `execute_flat` still re-pays programming-time costs on every call: it
# re-factorises every INV bucket and re-derives every MVM tile's effective
# operator (wire model + loading) per solve.  On AMC hardware those costs are
# paid exactly once, when the arrays are programmed; each subsequent solve is
# nearly free (paper Section III; Sun et al. 2020).
#
# `finalize` mirrors that split in the simulator.  Once per programmed
# matrix it precomputes
#   * per-INV-bucket effective operator stacks (wire model + finite-gain
#     loading folded in) together with their batched LU factors, and
#   * per-MVM-level effective tile stacks in (L, rows, cols) layout, grouped
#     by tile shape, with static input-gather windows and precomputed
#     summing-node divisors,
# so every runtime level of `execute_finalized` is a pure batched `lu_solve`
# or a stacked MVM over precomputed operators (XLA's dot merger fuses each
# level's same-shape tile dots under jit) - zero per-call re-derivation.
# The numbers are the ones `execute_flat` computes (same factors, same
# per-tile operators, same accumulation order), so the two agree bit-for-bit
# on CPU when run in the same regime; `execute_flat` stays as the
# unfinalized reference.
# ---------------------------------------------------------------------------


@jax.tree_util.register_pytree_node_class
class _MvmLevel:
    """One finalized tiled-MVM schedule level.

    `stacks[g]` holds the effective operator matrices of all same-shape tiles
    of this level as one (L, rows, cols) tensor; `windows[g]` the static
    input column windows, tile l reading v[lo:hi].  `rows` lists, per output
    tile-row, the (group, index) tile refs in original column order - the
    runtime accumulates partial products in exactly `amc_mvm_tiled`'s order,
    which keeps the finalized path bit-compatible with the flat one.  `divs`
    are the per-tile-row finite-gain summing-node divisors (empty tuple for
    an ideal OPA).
    """

    def __init__(self, stacks, divs, windows, rows):
        self.stacks = stacks      # tuple of (L, r, c) arrays, one per shape
        self.divs = divs          # () or one divisor vector per tile-row
        self.windows = windows    # tuple (per group) of ((lo, hi), ...)
        self.rows = rows          # tuple (per tile-row) of ((group, idx), ..)

    def tree_flatten(self):
        return (self.stacks, self.divs), (self.windows, self.rows)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children, *aux)

    def apply(self, v: jnp.ndarray) -> jnp.ndarray:
        """Stacked MVM level: v (cols,) or (cols, k) -> (rows,) / (rows, k).

        Each tile's partial product reads its precomputed operator out of the
        (L, r, c) stack; the reduction replays `amc_mvm_tiled`'s per-row
        accumulation order exactly (the bit-compatibility contract), and XLA's
        dot merger fuses the same-shape tile dots of one level into a single
        batched matmul under jit - a batched einsum here would reorder the
        matvec reduction and break bitwise parity with the flat executor.
        """
        divs = self.divs if self.divs else (None,) * len(self.rows)
        outs = []
        for refs, div in zip(self.rows, divs):
            acc = None
            for g, i in refs:
                lo, hi = self.windows[g][i]
                p = -(self.stacks[g][i] @ v[lo:hi])
                acc = p if acc is None else acc + p
            if div is not None:
                acc = acc / (div[:, None] if acc.ndim == 2 else div)
            outs.append(acc)
        return jnp.concatenate(outs)


@jax.tree_util.register_pytree_node_class
class FinalizedPlan:
    """A FlatPlan finalized against one AnalogConfig: ready-to-solve form.

    Holds the precomputed per-bucket LU factors (`lu_stacks`), the fused
    per-level MVM operators (`mvm_levels`), and the rewritten schedule in
    which every "mvm" level references a finalized _MvmLevel.  The config is
    baked in (aux data): the precomputed operators are only valid for the
    cfg they were derived under.
    """

    def __init__(self, lu_stacks, mvm_levels, scale, schedule, n, cfg,
                 num_arrays):
        self.lu_stacks = lu_stacks    # tuple of (lu, piv) per INV bucket
        self.mvm_levels = mvm_levels  # tuple of _MvmLevel
        self.scale = scale
        self.schedule = schedule      # "mvm" ops rewritten to ("fmvm", ...)
        self.n = n
        self.cfg = cfg
        self.num_arrays = num_arrays

    def tree_flatten(self):
        return ((self.lu_stacks, self.mvm_levels, self.scale),
                (self.schedule, self.n, self.cfg, self.num_arrays))

    @classmethod
    def tree_unflatten(cls, aux, children):
        lu_stacks, mvm_levels, scale = children
        return cls(lu_stacks, mvm_levels, scale, *aux)

    @property
    def num_levels(self) -> int:
        return len(self.schedule)


def _finalize_mvm_level(fplan: FlatPlan, rows, cfg: AnalogConfig,
                        r_wire=None, drift_t=None) -> _MvmLevel:
    """Precompute one "mvm" level's effective operators and divisors.

    Derivations match `execute_flat`'s runtime path exactly: per-tile
    `CrossbarPair.a_eff` (wire model folded in) and `amc_mvm_tiled`'s
    sequential summing-node load accumulation, evaluated once here.
    `r_wire` optionally overrides the config wire resistance with a traced
    scalar (see `finalize`); `drift_t`, when given, is one age per MVM
    bucket (a scalar or a (num,) vector indexed by the tile's bucket slot)
    feeding the per-tile readout drift.
    """
    groups: dict = {}        # (r, c) tile shape -> group index
    stacks: list = []        # per group: list of a_eff tiles
    windows: list = []       # per group: list of (lo, hi) windows
    row_refs = []
    divs = []
    for row in rows:
        col_off = 0
        refs = []
        load = cfg.g0
        for bk, i in row:
            pair = fplan.mvm_stacks[bk].pair(i)
            r, c = pair.shape
            if (r, c) not in groups:
                groups[(r, c)] = len(stacks)
                stacks.append([])
                windows.append([])
            g = groups[(r, c)]
            refs.append((g, len(stacks[g])))
            dt = None
            if drift_t is not None:
                d_b = drift_t[bk]
                dt = d_b if jnp.ndim(d_b) == 0 else d_b[i]
            stacks[g].append(pair.a_eff(cfg, r_wire=r_wire, drift_t=dt))
            windows[g].append((col_off, col_off + c))
            load = load + jnp.sum(pair.gpos + pair.gneg, axis=1)
            col_off += c
        row_refs.append(tuple(refs))
        if cfg.opa_gain is not None:
            divs.append(1.0 + load / (cfg.opa_gain * cfg.g0))
    return _MvmLevel(tuple(jnp.stack(s) for s in stacks), tuple(divs),
                     tuple(tuple(w) for w in windows), tuple(row_refs))


@dataclasses.dataclass(frozen=True)
class PlanAges:
    """Per-physical-array device ages of one FlatPlan (simulated clock).

    `inv[b]` / `mvm[b]` is bucket b's age: a scalar, or a (num,) vector
    giving each array of the bucket its own age (arrays repaired at
    different times drift by different amounts).  Ages are in the drift
    model's t0 = 1 s units; `finalize(..., drift_t=PlanAges(...))` routes
    them into every `a_eff` readout.  Like the `r_wire` override, ages are
    array *contents* - they never enter `plan_signature`.
    """
    inv: tuple
    mvm: tuple


jax.tree_util.register_dataclass(
    PlanAges, data_fields=["inv", "mvm"], meta_fields=[])


def uniform_ages(fplan: FlatPlan, t) -> PlanAges:
    """PlanAges giving every array of `fplan` the same age `t`."""
    return PlanAges(
        inv=tuple(jnp.full((g.shape[-3],), t, jnp.float32)
                  for g in fplan.inv_stacks),
        mvm=tuple(jnp.full((g.shape[-3],), t, jnp.float32)
                  for g in fplan.mvm_stacks))


def _split_ages(fplan: FlatPlan, drift_t):
    """Normalise a finalize `drift_t` argument to per-bucket age tuples."""
    if drift_t is None:
        return None, None
    if isinstance(drift_t, PlanAges):
        return drift_t.inv, drift_t.mvm
    return (tuple(drift_t for _ in fplan.inv_stacks),
            tuple(drift_t for _ in fplan.mvm_stacks))


def finalize(fplan: FlatPlan, cfg: AnalogConfig,
             r_wire=None, drift_t=None) -> FinalizedPlan:
    """Precompute all per-solve-invariant operators of a flat plan.

    Traceable (pure jnp), so it can run under jit; typically called once per
    programmed matrix via `ProgrammedSolver.program`.

    `r_wire` optionally overrides `cfg.nonideal.r_wire` with a *traced*
    scalar, routed through the differentiable first-order wire model (the
    static config keeps selecting everything else).  This is the
    calibration hook (`repro.calib`): `finalize(fplan, cfg, r_wire=r_hat)`
    -> `compile_arena` -> `execute_arena` is differentiable end-to-end in
    `r_hat`, so planted wire parameters can be recovered by gradient
    descent against the `repro.physics.nodal` oracle.  The override never
    enters `plan_signature` - it changes array contents only, never shapes
    or schedules.

    `drift_t` optionally overrides the static config device age the same
    way: None keeps `cfg.nonideal.drift_t`; a traced scalar ages the whole
    plan uniformly; a `PlanAges` ages every physical array independently
    (the simulated-device-clock serving path, where one programmed plan is
    re-finalized as it grows old and block repairs reset individual
    arrays' ages).  The stored conductances never change - drift is a
    readout effect - so re-finalizing the same FlatPlan at new ages is the
    exact aging model.
    """
    inv_ages, mvm_ages = _split_ages(fplan, drift_t)
    lu_stacks = tuple(
        jax.scipy.linalg.lu_factor(_inv_operators(
            g, cfg, r_wire=r_wire,
            drift_t=None if inv_ages is None else inv_ages[b]))
        for b, g in enumerate(fplan.inv_stacks))
    mvm_levels = []
    schedule = []
    for instr in fplan.schedule:
        if instr[0] == "mvm":
            _, rows, src = instr
            schedule.append(("fmvm", len(mvm_levels), src))
            mvm_levels.append(
                _finalize_mvm_level(fplan, rows, cfg, r_wire=r_wire,
                                    drift_t=mvm_ages))
        else:
            schedule.append(instr)
    return FinalizedPlan(lu_stacks, tuple(mvm_levels), fplan.scale,
                         tuple(schedule), fplan.n, cfg, fplan.num_arrays)


def execute_finalized(fin: FinalizedPlan, b: jnp.ndarray) -> jnp.ndarray:
    """Run a finalized schedule; returns x like `execute` / `execute_flat`.

    `b` may be (n,) or (n, k).  Every level is a batched `lu_solve` against
    precomputed factors or one fused stacked MVM - nothing is re-derived.
    """
    cfg = fin.cfg
    regs = [analog.dac(b, cfg)]
    for instr in fin.schedule:
        op = instr[0]
        if op == "slice":
            _, src, lo, hi = instr
            regs.append(regs[src][lo:hi])
        elif op == "inv":
            _, bucket, idx, src = instr
            lu, piv = fin.lu_stacks[bucket]
            regs.append(-jax.scipy.linalg.lu_solve((lu[idx], piv[idx]),
                                                   regs[src]))
        elif op == "fmvm":
            _, level, src = instr
            regs.append(fin.mvm_levels[level].apply(regs[src]))
        elif op == "add":
            _, s1, r1, s2, r2 = instr
            x1 = regs[r1] if s1 > 0 else -regs[r1]
            x2 = regs[r2] if s2 > 0 else -regs[r2]
            regs.append(x1 + x2)
        elif op == "catneg":
            _, r1, r2 = instr
            regs.append(jnp.concatenate([regs[r1], -regs[r2]]))
        else:  # pragma: no cover - finalize only emits the ops above
            raise ValueError(f"unknown schedule op {op!r}")
    return -fin.scale * analog.adc(regs[-1], cfg)


_execute_finalized = jax.jit(execute_finalized)
_execute_finalized_donated = jax.jit(execute_finalized, donate_argnums=(1,))


# ---------------------------------------------------------------------------
# Arena executor: single-dispatch fused serving form
#
# See the module docstring's DESIGN note for the layout and the
# accumulation-order contract.  Static metadata vocabulary (hashable aux
# data; every number is a Python int).  Operand windows carry *both*
# coordinate systems: the materialized register they read (slot-SSA form,
# used by the jnp executor so XLA never copies the whole arena per level)
# and the resolved arena offset (`slot_offsets[m] + local`, used by the
# Pallas megakernel, the uniform whole-schedule program and the allocator
# property tests):
#
#   term     (mreg, local_off, sign)       one signed window read
#   segment  (dst_lo, seg_len, terms)      one contiguous chunk of an operand
#   tile     (stack_id, idx, m_out, init,  one operator application, in
#             segs)                        schedule order; init=True starts
#                                          its output register / row,
#                                          False accumulates into it
#   level    tuple of tiles                one schedule compute level
# ---------------------------------------------------------------------------


# --- compile-time views: registers as signed windows over materialized regs.
# A view is a tuple of chunks (chunk_len, terms), terms = ((mreg, off, sign),
# ...): position i of the chunk reads sum_t sign_t * mreg_t[off_t + i].

def _view_slice(view, lo, hi):
    out, pos = [], 0
    for chunk_len, terms in view:
        s_lo, s_hi = max(lo, pos), min(hi, pos + chunk_len)
        if s_lo < s_hi:
            d = s_lo - pos
            out.append((s_hi - s_lo,
                        tuple((m, o + d, s) for m, o, s in terms)))
        pos += chunk_len
    return tuple(out)


def _view_scale(view, sign):
    if sign > 0:
        return view
    return tuple((n_, tuple((m, o, -s) for m, o, s in terms))
                 for n_, terms in view)


def _view_add(v1, v2):
    """Refine two equal-length views to common chunk boundaries; the term
    order (all of v1's chunk terms, then v2's) replays `x1 + x2`."""
    out = []
    v1, v2 = list(v1), list(v2)
    i = j = 0
    while i < len(v1):
        l1, t1 = v1[i]
        l2, t2 = v2[j]
        step = min(l1, l2)
        out.append((step, t1 + t2))
        if l1 > step:
            v1[i] = (l1 - step, tuple((m, o + step, s) for m, o, s in t1))
        else:
            i += 1
        if l2 > step:
            v2[j] = (l2 - step, tuple((m, o + step, s) for m, o, s in t2))
        else:
            j += 1
    return tuple(out)


def _view_len(view):
    return sum(chunk_len for chunk_len, _ in view)


@jax.tree_util.register_pytree_node_class
class ArenaPlan:
    """Arena-form of a FinalizedPlan: the single-dispatch serving executor.

    `stacks` holds every operator the schedule applies, uniformly as
    (num, rows, cols) tensors: first one stack per INV bucket (explicit
    negated inverses, finite-gain loading folded in before inversion), then
    one per (MVM level, tile shape) group (circuit sign and summing-node
    divisor folded into the rows).  `levels` / `out_spec` / `slot_offsets`
    / `slot_ranges` are static metadata (see the vocabulary note above):
    `slot_offsets[m]` is materialized register m's arena offset and
    `slot_ranges` its (offset, length, def_pos, last_use) live range (the
    allocator property tests read these).  `program`, present when every
    tile shares one shape with whole-window gathers (the power-of-two
    serving configs), is the whole schedule flattened to arena-resolved
    metadata arrays - the form the Pallas megakernel executes in ONE call.
    """

    def __init__(self, stacks, scale, program, levels, out_spec, arena_size,
                 n, in_off, cfg, kernel_ok, num_arrays, slot_offsets,
                 slot_ranges, peak_liveness):
        self.stacks = stacks
        self.scale = scale
        self.program = program    # uniform whole-schedule form, or None
        self.levels = levels
        self.out_spec = out_spec
        self.arena_size = arena_size
        self.n = n
        self.in_off = in_off
        self.cfg = cfg
        self.kernel_ok = kernel_ok
        self.num_arrays = num_arrays
        self.slot_offsets = slot_offsets
        self.slot_ranges = slot_ranges
        self.peak_liveness = peak_liveness

    def tree_flatten(self):
        return ((self.stacks, self.scale, self.program),
                (self.levels, self.out_spec, self.arena_size, self.n,
                 self.in_off, self.cfg, self.kernel_ok, self.num_arrays,
                 self.slot_offsets, self.slot_ranges, self.peak_liveness))

    @classmethod
    def tree_unflatten(cls, aux, children):
        stacks, scale, program = children
        return cls(stacks, scale, program, *aux)

    @property
    def num_levels(self) -> int:
        return len(self.levels)


def _lowest_fit(placed, length):
    """Lowest offset where `length` cells avoid every (off, len) in placed."""
    off = 0
    for lo, ln in sorted(placed):
        if off + length <= lo:
            break
        off = max(off, lo + ln)
    return off


def _allocate_slots(intervals):
    """Offline register-arena allocation over known live intervals.

    `intervals`: {mreg: (length, def_pos, last_use)}.  Two greedy layouts
    are computed - first-fit in definition order (good for the cascade's
    mostly-nested lifetimes) and greedy-by-size (the ML-compiler heap-
    simulator heuristic, better on ragged odd-split schedules) - and the
    smaller extent wins.  On aligned power-of-two schedules (the serving
    hot path) the extent equals the schedule's peak liveness exactly; odd
    splits can fragment by at most a small slack (optimal dynamic storage
    allocation can itself exceed peak liveness, so a slack-free bound is
    not attainable in general) - both pinned by test_plan_properties.py.
    """
    def extent(offsets):
        return max(o + intervals[m][0] for m, o in offsets.items())

    def overlaps(m1, m2):
        _, d1, u1 = intervals[m1]
        _, d2, u2 = intervals[m2]
        return not (u1 < d2 or u2 < d1)

    layouts = []
    for order in (
            sorted(intervals, key=lambda m: (intervals[m][1], m)),
            sorted(intervals, key=lambda m: (-intervals[m][0],
                                             intervals[m][1], m))):
        offsets = {}
        for m in order:
            placed = [(offsets[m2], intervals[m2][0])
                      for m2 in offsets if overlaps(m, m2)]
            offsets[m] = _lowest_fit(placed, intervals[m][0])
        layouts.append(offsets)
    return min(layouts, key=extent)


def compile_arena(fin: FinalizedPlan) -> ArenaPlan:
    """Lower a FinalizedPlan to its arena form (see DESIGN note).

    Static analysis (views, live ranges, offsets) runs once per schedule
    shape; the numeric work (batched explicit inversion, divisor folding)
    is pure jnp, so `compile_arena` traces under jit/vmap like `finalize`.
    """
    schedule = fin.schedule
    n_steps = len(schedule)

    # --- pass 1: views, materialized registers, compute levels ------------
    views = {0: ((fin.n, ((0, 0, 1),)),)}   # register -> view
    mreg_len = {0: fin.n}                   # materialized reg -> length
    mreg_def = {0: -1}                      # -> defining schedule position
    computes = []                           # (pos, kind, payload, def_mreg)
    next_mreg = 1
    for p, instr in enumerate(schedule):
        r, op = p + 1, instr[0]
        if op == "slice":
            _, src, lo, hi = instr
            views[r] = _view_slice(views[src], lo, hi)
        elif op == "add":
            _, s1, r1, s2, r2 = instr
            views[r] = _view_add(_view_scale(views[r1], s1),
                                 _view_scale(views[r2], s2))
        elif op == "catneg":
            _, r1, r2 = instr
            views[r] = views[r1] + _view_scale(views[r2], -1)
        elif op == "inv":
            _, bucket, idx, src = instr
            m, next_mreg = next_mreg, next_mreg + 1
            size = fin.lu_stacks[bucket][0].shape[-1]
            mreg_len[m], mreg_def[m] = size, p
            views[r] = ((size, ((m, 0, 1),)),)
            computes.append((p, "inv", (bucket, idx, src), m))
        elif op == "fmvm":
            _, li, src = instr
            lvl = fin.mvm_levels[li]
            m, next_mreg = next_mreg, next_mreg + 1
            out_len = sum(lvl.stacks[refs[0][0]].shape[-2]
                          for refs in lvl.rows)
            mreg_len[m], mreg_def[m] = out_len, p
            views[r] = ((out_len, ((m, 0, 1),)),)
            computes.append((p, "fmvm", (li, src), m))
        else:  # pragma: no cover - finalize only emits the ops above
            raise ValueError(f"unknown schedule op {op!r}")

    # --- pass 2: per-compute input views (in mreg coordinates), last uses -
    def note_uses(view, p, last_use):
        for _, terms in view:
            for m, _, _ in terms:
                last_use[m] = max(last_use.get(m, mreg_def[m]), p)

    last_use = {0: 0}
    in_views = []       # per compute: view ("inv") or per-tile views ("fmvm")
    for p, kind, payload, _ in computes:
        if kind == "inv":
            view = views[payload[2]]
            note_uses(view, p, last_use)
            in_views.append(view)
        else:
            li, src = payload
            lvl = fin.mvm_levels[li]
            tile_views = []
            for refs in lvl.rows:
                for g, i in refs:
                    lo, hi = lvl.windows[g][i]
                    tv = _view_slice(views[src], lo, hi)
                    note_uses(tv, p, last_use)
                    tile_views.append(tv)
            in_views.append(tuple(tile_views))
    out_view = views[n_steps]
    note_uses(out_view, n_steps, last_use)
    for m in mreg_def:                       # unread defs die immediately
        last_use.setdefault(m, mreg_def[m])

    # --- pass 3: offline allocation over the known live intervals ---------
    intervals = {m: (mreg_len[m], mreg_def[m], last_use[m])
                 for m in mreg_def}
    offsets = _allocate_slots(intervals)
    arena_size = max(offsets[m] + mreg_len[m] for m in mreg_def)
    peak = max(
        sum(mreg_len[m] for m in mreg_def
            if mreg_def[m] <= p <= last_use[m])
        for p in range(-1, n_steps + 1))

    def segs(view):
        """A view as static segments in (mreg, local_off, sign) terms."""
        out, dst = [], 0
        for chunk_len, terms in view:
            out.append((dst, chunk_len, tuple(terms)))
            dst += chunk_len
        return tuple(out)

    # --- pass 4: operator stacks (explicit inverses; sign/divisor folded) -
    stacks = []
    for lu, piv in fin.lu_stacks:
        eye = jnp.eye(lu.shape[-1], dtype=lu.dtype)
        stacks.append(-jax.vmap(
            lambda l_, p_: jax.scipy.linalg.lu_solve((l_, p_), eye))(lu, piv))
    mvm_stack_id = {}
    for li, lvl in enumerate(fin.mvm_levels):
        divs = lvl.divs if lvl.divs else (None,) * len(lvl.rows)
        folded = [[None] * s.shape[-3] for s in lvl.stacks]
        for refs, div in zip(lvl.rows, divs):
            for g, i in refs:
                w = -lvl.stacks[g][i]
                if div is not None:
                    w = w / div[:, None]
                folded[g][i] = w
        for g, tiles in enumerate(folded):
            mvm_stack_id[(li, g)] = len(stacks)
            stacks.append(jnp.stack(tiles))

    # --- pass 5: levels (schedule order; slot-SSA + arena coordinates) ----
    levels = []
    for (p, kind, payload, m_out), in_view in zip(computes, in_views):
        if kind == "inv":
            bucket, idx, _ = payload
            levels.append(((bucket, idx, m_out, 0, True, segs(in_view)),))
        else:
            li, _ = payload
            lvl = fin.mvm_levels[li]
            tiles, row_off, tv = [], 0, iter(in_view)
            for refs in lvl.rows:
                for pos, (g, i) in enumerate(refs):
                    tiles.append((mvm_stack_id[(li, g)], i, m_out, row_off,
                                  pos == 0, segs(next(tv))))
                row_off += lvl.stacks[refs[0][0]].shape[-2]
            levels.append(tuple(tiles))

    def whole_window(tile):
        sg = tile[5]
        return len(sg) == 1 and sg[0][0] == 0 \
            and sg[0][1] == stacks[tile[0]].shape[-1]

    kernel_ok = all(whole_window(t) for level in levels for t in level)

    # --- pass 6: uniform whole-schedule program ---------------------------
    # When every tile of the cascade shares one (r, c) shape and reads
    # whole-window gathers (true for the power-of-two serving configs: a
    # two-stage 256^2 solve is 23 applications of 64x64 operators), the
    # entire schedule lowers to ONE tile program: stacked operators in
    # execution order plus flat arena-resolved metadata arrays - the form
    # the Pallas megakernel runs as a single call, grid walking the tiles
    # in schedule order over one physical arena buffer.  Mixed shapes /
    # ragged windows fall back to the per-level form (program=None).
    program = None
    if kernel_ok and len({s.shape[-2:] for s in stacks}) == 1:
        seq, offs_l, signs_l, outs_l, init_l = [], [], [], [], []
        n_terms = max(len(t[5][0][2]) for level in levels for t in level)
        for level in levels:
            for sid, idx, m_out, out_local, init, segments in level:
                terms = segments[0][2]
                seq.append(stacks[sid][idx])
                offs_l.append([offsets[m] + o for m, o, _ in terms]
                              + [0] * (n_terms - len(terms)))
                signs_l.append([float(s) for _, _, s in terms]
                               + [0.0] * (n_terms - len(terms)))
                outs_l.append(offsets[m_out] + out_local)
                init_l.append(1 if init else 0)
        program = (jnp.stack(seq), jnp.asarray(offs_l, jnp.int32),
                   jnp.asarray(signs_l, jnp.float32),
                   jnp.asarray(outs_l, jnp.int32),
                   jnp.asarray(init_l, jnp.int32))

    slot_offsets = tuple(offsets[m] for m in range(next_mreg))
    slot_ranges = tuple(                     # indexed by materialized reg
        (offsets[m], mreg_len[m], mreg_def[m], last_use[m])
        for m in range(next_mreg))
    return ArenaPlan(tuple(stacks), fin.scale, program, tuple(levels),
                     segs(out_view), arena_size, fin.n, offsets[0], fin.cfg,
                     kernel_ok, fin.num_arrays, slot_offsets, slot_ranges,
                     peak)


def _slot_gather(vals, segments):
    """Signed static-window gather: the folded slice/add/catneg wiring.

    Terms are evaluated in segment order, first term first - exactly the
    reference executors' negation/summation order.
    """
    parts = []
    for _, seg_len, terms in segments:
        acc = None
        for m, off, sign in terms:
            w = vals[m][off:off + seg_len]
            w = -w if sign < 0 else w
            acc = w if acc is None else acc + w
        parts.append(acc)
    return parts[0] if len(parts) == 1 else jnp.concatenate(parts, axis=0)


def _arena_out_spec(out_spec, slot_offsets):
    """`out_spec` with register terms rebased to physical arena offsets
    (register 0 = the whole arena buffer) - the kernel-path output gather
    form, shared by the single-instance and packed executors."""
    return tuple(
        (dst, ln, tuple((0, slot_offsets[m] + off, sign)
                        for m, off, sign in terms))
        for dst, ln, terms in out_spec)


def _apply_level_jnp(vals, stacks, level):
    """One schedule level in slot-SSA form (the CPU fast path).

    Each materialized register is its own value keyed by `slot_offsets`
    slot id - same layout contract as the physical arena, but XLA assigns
    the buffers, so level outputs never pay a whole-arena update copy.
    Tile-row accumulation replays the schedule order (init starts a row
    part, later tiles add into it); the row parts concatenate into the
    level's output register.

    A multi-tile level whose tiles share one operator stack runs as ONE
    batched dot over the tile axis instead of one dot per tile: each
    tile's matvec reduction is unchanged (per-slice identical math; the
    accumulation below still replays schedule order), but XLA:CPU's
    batched-matmul throughput scales strongly with batch size, which is
    what makes the packed multi-tenant executor - where the instance axis
    multiplies the batch again - beat the per-tenant dispatch loop.
    """
    parts, m_out = [], level[0][2]
    if len(level) > 1 and len({t[0] for t in level}) == 1:
        sid, idxs = level[0][0], tuple(t[1] for t in level)
        gathers = jnp.stack([_slot_gather(vals, t[5]) for t in level])
        lo = idxs[0]
        ops_sel = (stacks[sid][lo:lo + len(idxs)]
                   if idxs == tuple(range(lo, lo + len(idxs)))
                   else stacks[sid][jnp.asarray(idxs)])
        outs = ops_sel @ gathers                    # (L, rows, k)
        tile_outs = [outs[pos] for pos in range(len(level))]
    else:
        tile_outs = [stacks[sid][idx] @ _slot_gather(vals, segments)
                     for sid, idx, _, _, _, segments in level]
    for out, (_, _, _, _, init, _) in zip(tile_outs, level):
        if init:
            parts.append(out)
        else:
            parts[-1] = parts[-1] + out
    vals[m_out] = parts[0] if len(parts) == 1 else jnp.concatenate(parts,
                                                                   axis=0)


# ---------------------------------------------------------------------------
# Differentiable cascade core (implicit-diff VJP)
#
# The whole jnp-path cascade - input register to output gather - is one
# `jax.custom_vjp` over (stacks, b_in) with the static metadata (levels,
# out_spec) as nondiff arguments.  The primal replays `_apply_level_jnp` /
# `_slot_gather` op for op, so wrapping it changes no forward bit; the
# backward pass is a reverse sweep over the SAME programmed operator stacks
# (each tile's adjoint is one transposed tile matmul), i.e. one more solve
# against the resident plan - no re-factorisation, no re-programming, no
# `lax.while_loop`.  Cotangents are produced for both the right-hand side
# (the IFT adjoint solve) and the operator stacks (per-tile outer products,
# the hook calibration loops differentiate through); when only the rhs
# gradient is consumed, XLA dead-code-eliminates the stack outer products
# under jit, so a backward costs ~1 forward arena solve (benchmarked in
# artifacts/bench/grad.json).  Contract details: TESTING.md "differentiable
# solver contract".
# ---------------------------------------------------------------------------


def _run_levels(levels, stacks, b_in):
    vals = {0: b_in}
    for level in levels:
        _apply_level_jnp(vals, stacks, level)
    return vals


@partial(jax.custom_vjp, nondiff_argnums=(0, 1))
def _cascade(levels, out_spec, stacks, b_in):
    """The jnp cascade as a differentiable primitive: registers in slot-SSA
    form, levels applied in schedule order, output gathered via `out_spec`.
    `levels`/`out_spec` are the hashable static metadata of an ArenaPlan."""
    return _slot_gather(_run_levels(levels, stacks, b_in), out_spec)


def _cascade_fwd(levels, out_spec, stacks, b_in):
    vals = _run_levels(levels, stacks, b_in)
    out = _slot_gather(vals, out_spec)
    # level i defines mreg i+1 (SSA), so vals is keyed 0..num_levels densely
    return out, (stacks, tuple(vals[m] for m in range(len(vals))))


def _scatter_ct(cot, vals, segments, u):
    """Adjoint of `_slot_gather`: scatter-add the cotangent `u` back through
    the signed static windows (sign per term, mirroring the gather)."""
    for dst, seg_len, terms in segments:
        piece = u[dst:dst + seg_len]
        for m, off, sign in terms:
            w = -piece if sign < 0 else piece
            prev = cot.get(m)
            if prev is None:
                prev = jnp.zeros_like(vals[m])
            cot[m] = prev.at[off:off + seg_len].add(w.astype(prev.dtype))


def _cascade_bwd(levels, out_spec, res, g):
    stacks, vals_t = res
    vals = dict(enumerate(vals_t))
    stack_bars = [jnp.zeros_like(s) for s in stacks]
    cot = {}                                 # mreg -> cotangent register
    _scatter_ct(cot, vals, out_spec, g)
    for level in reversed(levels):
        c = cot.pop(level[0][2], None)       # this level's output cotangent
        if c is None:
            continue                         # unread def: no contribution
        if len(level) > 1 and len({t[0] for t in level}) == 1:
            # mirror the forward shared-stack batched dot: one transposed
            # batched matmul for the input adjoints, one batched outer
            # product for the stack cotangents
            sid, idxs = level[0][0], tuple(t[1] for t in level)
            rows = stacks[sid].shape[-2]
            cps = jnp.stack([c[t[3]:t[3] + rows] for t in level])
            gathers = jnp.stack([_slot_gather(vals, t[5]) for t in level])
            lo = idxs[0]
            contiguous = idxs == tuple(range(lo, lo + len(idxs)))
            ops_sel = (stacks[sid][lo:lo + len(idxs)] if contiguous
                       else stacks[sid][jnp.asarray(idxs)])
            ubars = jnp.swapaxes(ops_sel, -1, -2) @ cps      # (L, cols, k)
            wbars = (cps @ jnp.swapaxes(gathers, -1, -2)
                     ).astype(stacks[sid].dtype)             # (L, rows, cols)
            stack_bars[sid] = (
                stack_bars[sid].at[lo:lo + len(idxs)].add(wbars) if contiguous
                else stack_bars[sid].at[jnp.asarray(idxs)].add(wbars))
            for pos, t in enumerate(level):
                _scatter_ct(cot, vals, t[5], ubars[pos])
        else:
            for sid, idx, _, out_local, _, segments in level:
                rows = stacks[sid].shape[-2]
                cp = c[out_local:out_local + rows]
                gat = _slot_gather(vals, segments)
                stack_bars[sid] = stack_bars[sid].at[idx].add(
                    (cp @ gat.T).astype(stacks[sid].dtype))
                _scatter_ct(cot, vals, segments, stacks[sid][idx].T @ cp)
    b_bar = cot.get(0)
    if b_bar is None:
        b_bar = jnp.zeros_like(vals[0])
    return tuple(stack_bars), b_bar


_cascade.defvjp(_cascade_fwd, _cascade_bwd)


def _apply_level_kernel(arena, ap, level, interpret):
    """One schedule level on the physical arena via the Pallas megakernel.

    Tiles are grouped by operator stack (shape bucket), one pallas_call
    per group; metadata resolves to arena coordinates via `slot_offsets`.
    """
    from repro.kernels import ops as kops
    so = ap.slot_offsets
    groups = {}
    for tile in level:
        groups.setdefault(tile[0], []).append(tile)
    for sid, tiles in groups.items():
        n_terms = max(len(t[5][0][2]) for t in tiles)
        offs = [[so[m] + o for m, o, _ in t[5][0][2]] for t in tiles]
        signs = [[float(s) for _, _, s in t[5][0][2]] for t in tiles]
        for o, s in zip(offs, signs):       # pad ragged term counts
            o.extend([0] * (n_terms - len(o)))
            s.extend([0.0] * (n_terms - len(s)))
        stack = ap.stacks[sid]
        ops_used = stack[jnp.asarray([t[1] for t in tiles], jnp.int32)]
        arena = kops.arena_level_apply(
            arena, ops_used,
            jnp.asarray(offs, jnp.int32), jnp.asarray(signs, jnp.float32),
            jnp.asarray([so[t[2]] + t[3] for t in tiles], jnp.int32),
            jnp.asarray([1 if t[4] else 0 for t in tiles], jnp.int32),
            interpret=interpret)
    return arena


def execute_arena(ap: ArenaPlan, b: jnp.ndarray,
                  use_kernel: Optional[bool] = None) -> jnp.ndarray:
    """Run an arena plan; returns x like the other executors.

    `b` may be (n,) or (n, k).  Every level is a stacked-tile matmul over
    signed static gather windows - no register list, no runtime factor
    solves, no wiring copies.  use_kernel=None routes through the Pallas
    megakernel on TPU (when the plan's gather specs are whole-window,
    `ap.kernel_ok`) and the slot-SSA jnp path on CPU; use_kernel=True
    forces the kernel (interpret mode off TPU - the CI smoke), False
    forces jnp.  On the kernel path a uniform plan (`ap.program`) runs
    the ENTIRE cascade as one megakernel call over the physical arena
    buffer - the single-dispatch serving form.
    """
    cfg = ap.cfg
    on_tpu = jax.default_backend() == "tpu"
    if use_kernel is None:
        use_kernel = on_tpu and ap.kernel_ok
    elif use_kernel and not ap.kernel_ok:
        # forcing the kernel on a plan it cannot express must fail loudly:
        # silently measuring/testing the jnp path as "the kernel" is worse
        raise ValueError(
            "use_kernel=True but this plan has ragged (multi-segment) "
            "gather windows the megakernel does not express; use the jnp "
            "path or an aligned power-of-two configuration")
    single = b.ndim == 1
    dtype = jnp.result_type(b.dtype, ap.scale.dtype)
    # Always carry an explicit RHS-batch dim: a trailing batch of 1 costs
    # nothing, while 1-D update chains defeat XLA:CPU buffer reuse.
    bk = b[:, None] if single else b
    b_in = analog.dac(bk, cfg).astype(dtype)
    if use_kernel:
        arena = jnp.zeros((ap.arena_size,) + bk.shape[1:], dtype)
        arena = arena.at[ap.in_off:ap.in_off + ap.n].set(b_in)
        if ap.program is not None:
            # the whole cascade in ONE megakernel call (the grid walks
            # tiles in schedule order; the arena carries level outputs)
            from repro.kernels import ops as kops
            ops_seq, in_offs, in_signs, out_offs, out_init = ap.program
            arena = kops.arena_level_apply(
                arena, ops_seq, in_offs, in_signs, out_offs, out_init,
                interpret=not on_tpu)
        else:
            for level in ap.levels:
                arena = _apply_level_kernel(arena, ap, level,
                                            interpret=not on_tpu)
        out = _slot_gather({0: arena},
                           _arena_out_spec(ap.out_spec, ap.slot_offsets))
    else:
        # the differentiable cascade core: identical ops to the plain level
        # loop (bit-compatible), plus the implicit-diff VJP for jax.grad
        out = _cascade(ap.levels, ap.out_spec, ap.stacks, b_in)
    if single:
        out = out[:, 0]
    return -ap.scale * analog.adc(out, cfg)


_execute_arena = jax.jit(execute_arena, static_argnames=("use_kernel",))
_execute_arena_donated = jax.jit(execute_arena, donate_argnums=(1,),
                                 static_argnames=("use_kernel",))


def pad_rhs_pow2(bs: jnp.ndarray) -> Tuple[jnp.ndarray, int]:
    """Zero-pad the trailing rhs-batch axis to the next power-of-two k.

    The one padding policy of the serving layer (ProgrammedSolver.solve_many,
    SolverService's refined flush and the packed `flush_all` all route
    through it): jitted executors then compile at most one new batch shape
    per doubling instead of one per distinct queue length.  Accepts the
    single-matrix (n, k) layout or the packed (M, n, k) layout - the rhs
    axis is always the last.  Returns (padded batch, original k); slice the
    result back with `[..., :k]`.
    """
    k = bs.shape[-1]
    k_pad = 1 << (k - 1).bit_length() if k else 0
    if k_pad > k:
        bs = jnp.pad(bs, [(0, 0)] * (bs.ndim - 1) + [(0, k_pad - k)])
    return bs, k


# ---------------------------------------------------------------------------
# Block-level repair (drift-aware self-healing)
#
# The paper's accuracy argument is that partitioning confines non-idealities
# to small arrays; the maintenance flip side is that *repair* can be equally
# local.  `plan_block_map` statically enumerates every physical array of a
# plan - (kind, bucket, index) exactly as `compile_plan` interns them -
# together with the PRNG key-derivation path `_program`/`map_tiled` would
# use for that array.  `repair_blocks` then re-programs ONLY the named
# arrays (full conductance-mapping pipeline, write-verify included) under
# keys derived from a fresh root key and splices the slices into the
# FlatPlan stacks; `splice_finalized` / `splice_arena` propagate the change
# through the finalized LU factors, MVM operator stacks, summing-node
# divisors and arena inverse/folded stacks by recomputing exactly the
# affected slices with the same expressions `finalize`/`compile_arena`
# evaluate.  Repairing every block under root key k is therefore
# bit-identical (eager CPU) to fully re-programming under k, and repairing
# a subset touches nothing outside the subset's buckets/rows - repair cost
# scales with the degraded fraction, not n^2 (tests/test_block_repair.py).
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class BlockRecord:
    """One physical array of a plan: its stack slot and programming key path.

    `kind`/`bucket`/`index` address the array inside
    FlatPlan.inv_stacks/mvm_stacks (same intern order as `compile_plan`);
    `path` is the static PRNG derivation from the root programming key -
    a sequence of ("split", num, idx) / ("tile", num, idx) steps mirroring
    `_program`'s 4-way key split and `map_tiled`'s per-tile split.
    """
    kind: str          # "inv" | "mvm"
    bucket: int
    index: int
    depth: int
    shape: Tuple[int, int]
    path: tuple

    @property
    def ref(self) -> Tuple[str, int, int]:
        return (self.kind, self.bucket, self.index)


def plan_block_map(n: int, stages: Optional[int],
                   cfg: AnalogConfig) -> Tuple[BlockRecord, ...]:
    """Statically enumerate every physical array of a (n, stages, cfg) plan.

    Walks the `_split_tree` in `compile_plan`'s emission order (inv1
    subtree, mvm3 tiles row-major, inv4s subtree, mvm2 tiles row-major), so
    bucket numbering and per-bucket indices match the FlatPlan intern order
    exactly; key paths match `_program`'s split(key, 4) -> (inv1, a2, a3,
    inv4s) and `map_tiled`'s split(key, r_tiles*c_tiles) discipline.  A
    pure function of the plan signature - no arrays needed.
    """
    if stages is None:
        stages = required_stages(n, cfg.array_size)
    s = cfg.array_size
    inv_buckets: dict = {}
    mvm_buckets: dict = {}
    records = []

    def ref(buckets, key):
        if key not in buckets:
            buckets[key] = [len(buckets), 0]
        b = buckets[key]
        out = (b[0], b[1])
        b[1] += 1
        return out

    def tiles(shape, depth, path):
        rows, cols = shape
        r_t, c_t = -(-rows // s), -(-cols // s)
        for ri in range(r_t):
            for ci in range(c_t):
                tshape = (min((ri + 1) * s, rows) - ri * s,
                          min((ci + 1) * s, cols) - ci * s)
                b, i = ref(mvm_buckets, (depth, tshape))
                records.append(BlockRecord(
                    "mvm", b, i, depth, tshape,
                    path + (("tile", r_t * c_t, ri * c_t + ci),)))

    def walk(tree, depth, path):
        if isinstance(tree, int):
            b, i = ref(inv_buckets, (depth, (tree, tree)))
            records.append(BlockRecord(
                "inv", b, i, depth, (tree, tree), path))
            return
        left, right = tree
        m = _tree_size(left)
        nn = m + _tree_size(right)
        walk(left, depth + 1, path + (("split", 4, 0),))
        tiles((nn - m, m), depth, path + (("split", 4, 2),))   # mvm3 <- a3
        walk(right, depth + 1, path + (("split", 4, 3),))
        tiles((m, nn - m), depth, path + (("split", 4, 1),))   # mvm2 <- a2

    walk(_split_tree(n, stages), 0, ())
    return tuple(records)


def _path_key(root_key: jax.Array, path) -> jax.Array:
    """Derive one array's programming key from the plan's root key.

    Replays the exact split sequence of `_program` (split into 4; inv1,
    a2, a3, inv4s in that order) and `map_tiled` (split into
    r_tiles*c_tiles, row-major) so the derived key equals the one a full
    re-program under `root_key` would hand that array's `map_matrix`.
    """
    k = root_key
    for _, num, idx in path:
        k = jax.random.split(k, num)[idx]
    return k


def _target_block(root: Target, path, array_size: int) -> jnp.ndarray:
    """The digital target block a BlockRecord's array was programmed from."""
    t = root
    for kind, _, idx in path:
        if kind == "split":
            t = (t.inv1, t.a2, t.a3, t.inv4s)[idx]
        else:
            rows, cols = t.shape
            c_t = -(-cols // array_size)
            ri, ci = idx // c_t, idx % c_t
            t = t[ri * array_size:min((ri + 1) * array_size, rows),
                  ci * array_size:min((ci + 1) * array_size, cols)]
    return t.a if isinstance(t, LeafTarget) else t


def _split_changed(changed):
    """Group a changed-block set into per-bucket index lists."""
    inv: dict = {}
    mvm: dict = {}
    for kind, b, i in changed:
        (inv if kind == "inv" else mvm).setdefault(b, set()).add(i)
    return ({b: sorted(s) for b, s in inv.items()},
            {b: sorted(s) for b, s in mvm.items()})


def repair_blocks(fplan: FlatPlan, parts: PartitionedSystem,
                  cfg: AnalogConfig, blocks, key: jax.Array,
                  stages: Optional[int] = None):
    """Re-program only the named physical arrays of a programmed plan.

    `blocks` is an iterable of ("inv"|"mvm", bucket, index) refs into the
    FlatPlan stacks.  Each named array is re-derived from its digital
    target block and re-programmed through the FULL conductance pipeline
    (write-verify pre-distortion, variation, faults) under the key
    `_path_key(key, path)` - the key a whole-plan re-program under `key`
    would use for that array - then spliced into the stacks.  Returns
    (new FlatPlan, frozenset of changed refs); untouched slices are the
    original arrays, bit-for-bit.
    """
    recs = {r.ref: r for r in plan_block_map(fplan.n, stages, cfg)}
    if len(recs) != fplan.num_arrays:
        raise ValueError(
            f"block map has {len(recs)} arrays but the plan holds "
            f"{fplan.num_arrays}: wrong stages for this plan?")
    changed = frozenset((k, int(b), int(i)) for k, b, i in blocks)
    new_pairs: dict = {}
    for blk in changed:
        rec = recs.get(blk)
        if rec is None:
            raise KeyError(f"no such block in this plan: {blk}")
        a_blk = _target_block(parts.root, rec.path, cfg.array_size)
        new_pairs[blk] = analog.map_matrix(
            a_blk, _path_key(key, rec.path), cfg, parts.scale)
    changed_inv, changed_mvm = _split_changed(changed)

    def splice(stacks, per_bucket, kind):
        out = list(stacks)
        for b, idxs in per_bucket.items():
            g = out[b]
            gp, gn = g.gpos, g.gneg
            for i in idxs:
                pair = new_pairs[(kind, b, i)]
                gp = gp.at[i].set(pair.gpos)
                gn = gn.at[i].set(pair.gneg)
            out[b] = TileGrid(gp, gn, g.scale, g.g0)
        return tuple(out)

    out = FlatPlan(splice(fplan.inv_stacks, changed_inv, "inv"),
                   splice(fplan.mvm_stacks, changed_mvm, "mvm"),
                   fplan.scale, fplan.schedule, fplan.n,
                   fplan.inv_keys, fplan.mvm_keys)
    return out, changed


def _mvm_level_layout(fplan: FlatPlan):
    """Replay `_finalize_mvm_level`'s shape grouping statically.

    Per "mvm" schedule level, returns the row structure as tuples of
    (bucket, index, group, pos): the tile's FlatPlan slot plus its
    (stack-group, group-local position) inside the finalized level.  Pure
    metadata - the splice functions use it to locate a repaired tile's
    every occurrence (A1-subtree levels appear twice, steps 1 and 5).
    """
    layouts = []
    for instr in fplan.schedule:
        if instr[0] != "mvm":
            continue
        rows = instr[1]
        groups: dict = {}
        counts: list = []
        row_tiles = []
        for row in rows:
            rt = []
            for bk, i in row:
                shape = tuple(fplan.mvm_stacks[bk].shape[-2:])
                if shape not in groups:
                    groups[shape] = len(counts)
                    counts.append(0)
                g = groups[shape]
                rt.append((bk, i, g, counts[g]))
                counts[g] += 1
            row_tiles.append(tuple(rt))
        layouts.append(tuple(row_tiles))
    return tuple(layouts)


def splice_finalized(fin: FinalizedPlan, fplan: FlatPlan, changed,
                     r_wire=None, drift_t=None) -> FinalizedPlan:
    """Propagate repaired FlatPlan slices into a FinalizedPlan.

    Recomputes exactly the affected pieces with the same expressions
    `finalize` uses: the changed INV slices' effective operators + LU
    factors (batched over the changed subset only), the changed MVM tiles'
    effective operators, and the summing-node divisors of every tile-row
    containing a changed tile (the divisor sums the whole row's
    conductances, so it moves when any tile of the row is re-programmed).
    Everything else is carried over untouched - bit-for-bit the original.
    `drift_t` gives the ages the recomputed slices are evaluated at
    (finalize semantics; None = the static config age, i.e. fresh).
    """
    cfg = fin.cfg
    inv_ages, mvm_ages = _split_ages(fplan, drift_t)
    changed_inv, changed_mvm = _split_changed(changed)
    changed_set = {("mvm", b, i) for b, idxs in changed_mvm.items()
                   for i in idxs}

    lu_stacks = list(fin.lu_stacks)
    for b, idxs in changed_inv.items():
        grid = fplan.inv_stacks[b]
        sel = jnp.asarray(idxs)
        sub = TileGrid(grid.gpos[sel], grid.gneg[sel], grid.scale, grid.g0)
        dt = None
        if inv_ages is not None:
            a_b = inv_ages[b]
            dt = a_b if jnp.ndim(a_b) == 0 else a_b[sel]
        lu_s, piv_s = jax.scipy.linalg.lu_factor(
            _inv_operators(sub, cfg, r_wire=r_wire, drift_t=dt))
        lu, piv = lu_stacks[b]
        lu_stacks[b] = (lu.at[sel].set(lu_s), piv.at[sel].set(piv_s))

    mvm_levels = list(fin.mvm_levels)
    for li, row_tiles in enumerate(_mvm_level_layout(fplan)):
        lvl = mvm_levels[li]
        new_stacks = list(lvl.stacks)
        new_divs = list(lvl.divs)
        touched = False
        for r_idx, rt in enumerate(row_tiles):
            if not any(("mvm", bk, i) in changed_set for bk, i, _, _ in rt):
                continue
            touched = True
            load = cfg.g0
            for bk, i, g, pos in rt:
                pair = fplan.mvm_stacks[bk].pair(i)
                if ("mvm", bk, i) in changed_set:
                    dt = None
                    if mvm_ages is not None:
                        a_b = mvm_ages[bk]
                        dt = a_b if jnp.ndim(a_b) == 0 else a_b[i]
                    new_stacks[g] = new_stacks[g].at[pos].set(
                        pair.a_eff(cfg, r_wire=r_wire, drift_t=dt))
                load = load + jnp.sum(pair.gpos + pair.gneg, axis=1)
            if new_divs:
                new_divs[r_idx] = 1.0 + load / (cfg.opa_gain * cfg.g0)
        if touched:
            mvm_levels[li] = _MvmLevel(tuple(new_stacks), tuple(new_divs),
                                       lvl.windows, lvl.rows)
    return FinalizedPlan(tuple(lu_stacks), tuple(mvm_levels), fin.scale,
                         fin.schedule, fin.n, cfg, fin.num_arrays)


def splice_arena(ap: ArenaPlan, fin: FinalizedPlan, fplan: FlatPlan,
                 changed) -> ArenaPlan:
    """Propagate a spliced FinalizedPlan into an ArenaPlan.

    `fin` must be the already-spliced finalized plan (splice_finalized's
    result).  Recomputes the changed INV slices' explicit inverses from
    the new LU factors and re-folds the changed MVM tiles - plus every
    tile sharing a row with one (their common summing-node divisor is
    folded into the arena operators) - then patches the uniform
    whole-schedule program's operator sequence at the affected positions.
    Expressions match `compile_arena` pass 4 slice-for-slice.
    """
    cfg = ap.cfg
    changed_inv, changed_mvm = _split_changed(changed)
    changed_set = {("mvm", b, i) for b, idxs in changed_mvm.items()
                   for i in idxs}
    stacks = list(ap.stacks)
    updated = set()
    for b, idxs in changed_inv.items():
        lu, piv = fin.lu_stacks[b]
        sel = jnp.asarray(idxs)
        eye = jnp.eye(lu.shape[-1], dtype=lu.dtype)
        inv_s = -jax.vmap(
            lambda l_, p_: jax.scipy.linalg.lu_solve((l_, p_), eye))(
                lu[sel], piv[sel])
        stacks[b] = stacks[b].at[sel].set(inv_s)
        updated.update((b, i) for i in idxs)

    sid_of = {}
    next_id = len(fin.lu_stacks)
    for li, lvl in enumerate(fin.mvm_levels):
        for g in range(len(lvl.stacks)):
            sid_of[(li, g)] = next_id
            next_id += 1
    for li, row_tiles in enumerate(_mvm_level_layout(fplan)):
        lvl = fin.mvm_levels[li]
        divs = lvl.divs if lvl.divs else (None,) * len(row_tiles)
        for r_idx, rt in enumerate(row_tiles):
            if not any(("mvm", bk, i) in changed_set for bk, i, _, _ in rt):
                continue
            div = divs[r_idx]
            for bk, i, g, pos in rt:
                if div is None and ("mvm", bk, i) not in changed_set:
                    continue
                w = -lvl.stacks[g][pos]
                if div is not None:
                    w = w / div[:, None]
                sid = sid_of[(li, g)]
                stacks[sid] = stacks[sid].at[pos].set(w)
                updated.add((sid, pos))

    program = ap.program
    if program is not None and updated:
        ops_seq = program[0]
        p = 0
        for level in ap.levels:
            for tile in level:
                if (tile[0], tile[1]) in updated:
                    ops_seq = ops_seq.at[p].set(stacks[tile[0]][tile[1]])
                p += 1
        program = (ops_seq,) + program[1:]
    return ArenaPlan(tuple(stacks), ap.scale, program, ap.levels,
                     ap.out_spec, ap.arena_size, ap.n, ap.in_off, cfg,
                     ap.kernel_ok, ap.num_arrays, ap.slot_offsets,
                     ap.slot_ranges, ap.peak_liveness)


class ProgrammedSolver:
    """Program-once / solve-many handle over one finalized matrix.

    The AMC serving abstraction: `program` pays the full programming-time
    cost (partitioning, Schur complements, conductance mapping, operator
    finalization and arena compilation) exactly once; `solve` /
    `solve_many` then stream any number of right-hand sides against the
    programmed arrays at marginal cost.  All solves dispatch through one
    shared jitted executor keyed on the plan's pytree structure, so
    repeated solves never re-trace; `solve_many` pads the batch dim to the
    next power of two, so distinct queue lengths never re-trace either.

    `mode` selects the executor (overridable per call): "fused" (default)
    runs the arena-form single-dispatch executor - the serving fast path -
    while "reference" runs the finalized schedule that is pinned
    bit-for-bit against `execute_flat` (TESTING.md four-way contract).
    """

    def __init__(self, fin: FinalizedPlan, arena: Optional[ArenaPlan] = None,
                 mode: str = "fused", fplan: Optional[FlatPlan] = None,
                 parts: Optional[PartitionedSystem] = None,
                 stages: Optional[int] = None):
        if mode not in ("reference", "fused"):
            raise ValueError(f"mode must be 'reference' or 'fused', "
                             f"got {mode!r}")
        self._fin = fin
        # arena compilation (explicit bucket inversions + layout analysis)
        # is paid at programming time for fused-mode solvers and lazily on
        # first fused use otherwise - reference-mode callers never pay it.
        self._arena = arena
        if self._arena is None and mode == "fused":
            self._arena = compile_arena(fin)
        self._mode = mode
        # Maintenance state: the flat plan (raw conductance stacks - drift
        # is a readout effect, so aging re-finalizes from here without
        # re-programming) and the partitioned system + resolved stage
        # count (block repair re-derives target blocks from them).  Both
        # optional: checkpoint-restored solvers carry neither, and then
        # `aged`/`repaired` are unavailable (callers fall back to a full
        # re-program).
        self._fplan = fplan
        self._parts = parts
        self._stages = stages

    @classmethod
    def program(cls, a: jnp.ndarray, key: jax.Array, cfg: AnalogConfig,
                stages: Optional[int] = None,
                mode: str = "fused") -> "ProgrammedSolver":
        """Full programming flow for matrix A (one noise draw)."""
        parts = partition_system(a, cfg, stages)
        if stages is None:
            stages = required_stages(a.shape[0], cfg.array_size)
        return cls.from_plan(program_system(parts, key, cfg), cfg,
                             mode=mode, parts=parts, stages=stages)

    @classmethod
    def from_plan(cls, plan: Union[SolvePlan, FlatPlan], cfg: AnalogConfig,
                  mode: str = "fused",
                  parts: Optional[PartitionedSystem] = None,
                  stages: Optional[int] = None) -> "ProgrammedSolver":
        """Finalize an already-built plan (recursive or flat)."""
        fplan = plan if isinstance(plan, FlatPlan) else compile_plan(plan)
        return cls(finalize(fplan, cfg), mode=mode, fplan=fplan,
                   parts=parts, stages=stages)

    @property
    def finalized(self) -> FinalizedPlan:
        return self._fin

    @property
    def flat(self) -> Optional[FlatPlan]:
        return self._fplan

    @property
    def stages(self) -> Optional[int]:
        return self._stages

    @property
    def ageable(self) -> bool:
        """Can this solver be re-finalized at new device ages?"""
        return self._fplan is not None

    @property
    def repairable(self) -> bool:
        """Can this solver re-program individual blocks in place?"""
        return self._fplan is not None and self._parts is not None \
            and self._stages is not None

    def block_map(self) -> Tuple[BlockRecord, ...]:
        """Every physical array of this plan (requires `repairable`)."""
        if self._stages is None:
            raise ValueError("solver was built without a resolved stage "
                             "count; block map unavailable")
        return plan_block_map(self._fin.n, self._stages, self._fin.cfg)

    def aged(self, drift_t) -> "ProgrammedSolver":
        """This solver with its readout evaluated at new device ages.

        `drift_t` follows `finalize` semantics (scalar or `PlanAges`).
        The conductance stacks are shared, not copied - drift is a
        readout effect - and the returned solver has identical pytree
        structure, so existing jit caches keep hitting.
        """
        if self._fplan is None:
            raise ValueError("solver does not retain its flat plan "
                             "(checkpoint-restored?); aging unavailable")
        fin = finalize(self._fplan, self._fin.cfg, drift_t=drift_t)
        arena = compile_arena(fin) if self._arena is not None else None
        return ProgrammedSolver(fin, arena, self._mode, fplan=self._fplan,
                                parts=self._parts, stages=self._stages)

    def repaired(self, blocks, key: jax.Array,
                 drift_t=None) -> "ProgrammedSolver":
        """Block-level repair: re-program only `blocks`, splice in place.

        `blocks` are ("inv"|"mvm", bucket, index) refs (see `block_map`);
        `key` is the fresh root key the per-block programming keys are
        derived from.  `drift_t` (finalize semantics) gives the ages the
        recomputed slices are evaluated at - None means fresh.  Cost
        scales with the number of repaired blocks: nothing outside the
        affected bucket slices / tile rows is recomputed, and repairing
        every block under `key` is bit-identical to a full re-program
        under `key` (tests/test_block_repair.py).
        """
        if not self.repairable:
            raise ValueError("solver does not retain its partitioned "
                             "system (checkpoint-restored?); block repair "
                             "unavailable - fall back to a full re-program")
        fplan, changed = repair_blocks(self._fplan, self._parts,
                                       self._fin.cfg, blocks, key,
                                       stages=self._stages)
        fin = splice_finalized(self._fin, fplan, changed, drift_t=drift_t)
        arena = None
        if self._arena is not None:
            arena = splice_arena(self._arena, fin, fplan, changed)
        return ProgrammedSolver(fin, arena, self._mode, fplan=fplan,
                                parts=self._parts, stages=self._stages)

    @property
    def arena(self) -> ArenaPlan:
        if self._arena is None:
            self._arena = compile_arena(self._fin)
        return self._arena

    @property
    def mode(self) -> str:
        return self._mode

    @property
    def cfg(self) -> AnalogConfig:
        return self._fin.cfg

    @property
    def n(self) -> int:
        return self._fin.n

    @property
    def num_arrays(self) -> int:
        return self._fin.num_arrays

    def solve(self, b: jnp.ndarray, jit: bool = True,
              mode: Optional[str] = None) -> jnp.ndarray:
        """Solve A x = b for one (n,) rhs or an (n, k) batch.

        mode=None uses the solver's default.  In "reference" mode,
        jit=False runs the finalized schedule eagerly - op for op the same
        numbers as `execute_flat`, bit-for-bit on CPU (the equivalence
        contract); the jitted path lets XLA merge each level's same-shape
        tile dots (float-tolerance equal).  "fused" mode runs the arena
        executor - float-tolerance against the reference by design (see
        the DESIGN note).
        """
        mode = self._mode if mode is None else mode
        if mode == "reference":
            return (_execute_finalized if jit else execute_finalized)(
                self._fin, b)
        return (_execute_arena if jit else execute_arena)(self.arena, b)

    def solve_many(self, bs: jnp.ndarray, donate: bool = False,
                   mode: Optional[str] = None,
                   pad_to_pow2: bool = True) -> jnp.ndarray:
        """Solve an (n, k) batch of right-hand sides in one fused call.

        pad_to_pow2=True (default) zero-pads the batch dim to the next
        power of two before dispatch and slices the padding away after, so
        the jitted executor compiles at most one new shape per doubling
        instead of one per distinct k (serving queues flush at arbitrary
        lengths).  donate=True donates the rhs buffer to the computation -
        opt in from serving hot loops that never reuse bs after the call
        (XLA then aliases it for the output on backends that support
        donation; a no-op on CPU).
        """
        k = bs.shape[1]
        if k == 0:
            return jnp.zeros_like(bs)
        if pad_to_pow2:
            bs, k = pad_rhs_pow2(bs)
        k_pad = bs.shape[1]
        mode = self._mode if mode is None else mode
        if mode == "reference":
            fn = _execute_finalized_donated if donate else _execute_finalized
            xs = fn(self._fin, bs)
        else:
            fn = _execute_arena_donated if donate else _execute_arena
            xs = fn(self.arena, bs)
        return xs[:, :k] if k_pad > k else xs


# ---------------------------------------------------------------------------
# Packed multi-tenant serving: one dispatch over (instances x rhs)
#
# A production solver service fields requests for many *different* matrices
# concurrently.  Per matrix, the arena executor already collapses a solve to
# one dispatch; across matrices the service still paid one dispatch per
# tenant per flush.  The packed layer adds the missing instance axis:
#
#   plan_signature(n, stages, cfg)   the structural stackability key
#   pack_partitioned / program_system_batched / finalize_batched /
#   compile_arena_batched            the batched programming pipeline -
#                                    one vmapped trace programs M matrices
#   PackedArenaPlan                  M same-signature arena plans stacked
#                                    leaf-for-leaf: (M, L, r, c) operator
#                                    stacks, (M,) scales, one shared static
#                                    schedule / layout / window program
#   pack_arena_plans                 stack already-compiled ArenaPlans
#                                    (the serving flush_all path)
#   execute_arena_packed             the whole fleet as stacked-tile
#                                    matmuls; the Pallas megakernel grows
#                                    an instance grid axis
#
# Stackability invariant: every *static* artifact of the compile pipeline
# (partition split tree, bucket shapes, flat schedule, finalized windows,
# arena slot layout, whole-schedule window program) is a deterministic
# function of (n, stages, cfg) alone - matrix values and noise keys only
# ever flow into array *contents*, never into shapes or schedules.  Plans
# with equal `plan_signature` therefore flatten to identical treedefs with
# identical leaf shapes and identical static metadata, and may be stacked
# on a leading instance axis and executed by one program.  The signature-
# bucketing properties are pinned in tests/test_plan_properties.py; the
# packed-vs-loop equivalence in tests/test_packed_serving.py.
# ---------------------------------------------------------------------------


def plan_signature(n: int, stages: Optional[int], cfg: AnalogConfig):
    """Structural signature of the whole compile pipeline for (n, stages, cfg).

    Returns a hashable key with the property: equal signatures imply the
    flat schedule, bucket shapes, finalized windows and arena layout of two
    programmed matrices are identical (see the stackability invariant
    above), so their plans can be packed on a leading instance axis.
    stages=None resolves to `required_stages` exactly like
    `partition_system`.  The split tree hashed here is the `_split_tree`
    `_partition` itself consumes (the root static artifact every later
    stage derives from), so the signature tracks the split rule by
    construction; n, the resolved stage count and the full AnalogConfig
    make unequal problems hash apart.
    """
    if stages is None:
        stages = required_stages(n, cfg.array_size)
    return ("blockamc", int(n), int(stages), _split_tree(n, stages), cfg)


def pack_partitioned(parts_seq) -> PartitionedSystem:
    """Stack same-signature PartitionedSystems on a leading instance axis.

    The stacked system feeds `program_system_batched`; callers are expected
    to have bucketed by `plan_signature` (same treedef / leaf shapes), which
    `jnp.stack` enforces mechanically anyway.
    """
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *parts_seq)


def program_system_batched(parts: PartitionedSystem, keys: jax.Array,
                           cfg: AnalogConfig) -> FlatPlan:
    """Program + flat-compile M instances in one vmap.

    `parts` carries a leading instance axis on every leaf (from
    `pack_partitioned`) and `keys` is (M, ...), one independent noise draw
    per instance; the result is a FlatPlan whose conductance stacks are
    (M, num_arrays, r, c) under one shared static schedule.  Programming M
    matrices costs one trace instead of M - the per-matrix loop's Python
    walk and per-plan dispatch disappear.
    """
    return jax.vmap(lambda p, k: compile_plan(program_system(p, k, cfg)))(
        parts, keys)


def finalize_batched(fplans: FlatPlan, cfg: AnalogConfig) -> FinalizedPlan:
    """`finalize` over a leading instance axis: (M, ...) LU factor stacks,
    (M, L, r, c) MVM tile stacks, one shared schedule."""
    return jax.vmap(lambda fp: finalize(fp, cfg))(fplans)


@jax.tree_util.register_pytree_node_class
class PackedArenaPlan:
    """M same-signature ArenaPlans stacked on a leading instance axis.

    `stacks[i]` is the i-th operator stack of the shared layout with shape
    (M, L, r, c) (explicit negated INV inverses first, then the
    sign/divisor-folded MVM tiles - exactly ArenaPlan's vocabulary, one
    instance axis in front); `scale` is (M,).  The static metadata (levels,
    out_spec, slot offsets, arena size) is the single shared copy every
    instance was compiled to - that sharing is what `plan_signature`
    guarantees and `pack_arena_plans` verifies.  For uniform power-of-two
    plans, `program_ops` is the (M, T, r, c) whole-schedule operator
    sequence and `program_meta` the shared (T, ...) window metadata the
    packed Pallas megakernel executes with an instance grid axis.
    """

    def __init__(self, stacks, scale, program_ops, program_meta, levels,
                 out_spec, arena_size, n, in_off, cfg, kernel_ok,
                 num_arrays, slot_offsets, num_instances):
        self.stacks = stacks
        self.scale = scale
        self.program_ops = program_ops    # (M, T, r, c) or None
        self.program_meta = program_meta  # shared (T, ...) metadata or None
        self.levels = levels
        self.out_spec = out_spec
        self.arena_size = arena_size
        self.n = n
        self.in_off = in_off
        self.cfg = cfg
        self.kernel_ok = kernel_ok
        self.num_arrays = num_arrays      # per instance
        self.slot_offsets = slot_offsets
        self.num_instances = num_instances

    def tree_flatten(self):
        return ((self.stacks, self.scale, self.program_ops,
                 self.program_meta),
                (self.levels, self.out_spec, self.arena_size, self.n,
                 self.in_off, self.cfg, self.kernel_ok, self.num_arrays,
                 self.slot_offsets, self.num_instances))

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children, *aux)

    @property
    def num_levels(self) -> int:
        return len(self.levels)


# Static ArenaPlan metadata that must agree for plans to share one packed
# program (the mechanical form of the signature-stackability invariant).
_STACKABLE_FIELDS = ("levels", "out_spec", "arena_size", "n", "in_off",
                     "cfg", "kernel_ok", "slot_offsets")


def pack_arena_plans(aps) -> PackedArenaPlan:
    """Stack already-compiled same-signature ArenaPlans into a packed plan.

    The serving `flush_all` path: each tenant's matrix was programmed (and
    arena-compiled) independently at admission time; packing is a pure
    leaf-for-leaf `jnp.stack` plus a static-metadata equality check, so it
    is cheap enough to run per flush.  Raises ValueError when the plans'
    static structure diverges (different `plan_signature` - they cannot
    share one schedule).
    """
    aps = list(aps)
    if not aps:
        raise ValueError("pack_arena_plans needs at least one plan")
    ap0 = aps[0]
    for ap in aps[1:]:
        for f in _STACKABLE_FIELDS:
            if getattr(ap, f) != getattr(ap0, f):
                raise ValueError(
                    f"arena plans are not stackable: static field {f!r} "
                    f"differs (plans compiled from different "
                    f"plan_signature buckets?)")
    stacks = tuple(jnp.stack([ap.stacks[i] for ap in aps])
                   for i in range(len(ap0.stacks)))
    scale = jnp.stack([ap.scale for ap in aps])
    program_ops = program_meta = None
    if ap0.program is not None:
        program_ops = jnp.stack([ap.program[0] for ap in aps])
        program_meta = ap0.program[1:]
    return PackedArenaPlan(stacks, scale, program_ops, program_meta,
                           ap0.levels, ap0.out_spec, ap0.arena_size, ap0.n,
                           ap0.in_off, ap0.cfg, ap0.kernel_ok,
                           ap0.num_arrays, ap0.slot_offsets, len(aps))


def compile_arena_batched(fins: FinalizedPlan) -> PackedArenaPlan:
    """`compile_arena` over a leading instance axis -> PackedArenaPlan.

    `fins` is a finalized-plan stack from `finalize_batched`.  The static
    analysis (views, live ranges, offsets) traces once for the shared
    schedule; only the numeric operator work (explicit bucket inversion,
    divisor folding) is vmapped, so the packed compile costs one trace for
    all M instances.  The whole-schedule window metadata is identical
    across instances by construction and stored once.
    """
    aps = jax.vmap(compile_arena)(fins)
    program_ops = program_meta = None
    if aps.program is not None:
        # vmap broadcast the (constant) metadata arrays; keep one copy.
        ops_seq, in_offs, in_signs, out_offs, out_init = aps.program
        program_ops = ops_seq
        program_meta = (in_offs[0], in_signs[0], out_offs[0], out_init[0])
    return PackedArenaPlan(aps.stacks, aps.scale, program_ops, program_meta,
                           aps.levels, aps.out_spec, aps.arena_size, aps.n,
                           aps.in_off, aps.cfg, aps.kernel_ok,
                           aps.num_arrays, aps.slot_offsets,
                           aps.scale.shape[0])


def program_packed(As: jnp.ndarray, keys: jax.Array, cfg: AnalogConfig,
                   stages: Optional[int] = None) -> PackedArenaPlan:
    """Full batched programming flow for an (M, n, n) matrix stack.

    One jitted trace runs partitioning, Schur complements, conductance
    mapping, finalization and arena compilation for all M matrices -
    programming a fleet stops costing M traces/compiles.  All matrices
    share (n, stages, cfg), i.e. one `plan_signature`.
    """
    return _program_packed(As, keys, cfg, stages)


@partial(jax.jit, static_argnames=("cfg", "stages"))
def _program_packed(As, keys, cfg, stages):
    parts = jax.vmap(lambda a: partition_system(a, cfg, stages))(As)
    fplans = program_system_batched(parts, keys, cfg)
    return compile_arena_batched(finalize_batched(fplans, cfg))


def execute_arena_packed(pp: PackedArenaPlan, bs: jnp.ndarray,
                         use_kernel: Optional[bool] = None) -> jnp.ndarray:
    """Run the whole packed fleet; returns per-instance solutions.

    `bs` is (M, n) - one rhs per instance - or (M, n, k): instance i's
    k-column batch.  Every schedule level of the jnp path is one stacked-
    tile matmul over the (M, L, r, c) operator stacks (the instance axis
    rides the batch dims of each dot), so the fleet costs one schedule
    walk instead of M.  On the kernel path, a uniform plan runs ALL
    instances' cascades as ONE megakernel call whose grid walks
    (instance, tile) over an (M, S, K) arena stack; use_kernel=None routes
    through the kernel on TPU when the plan is uniform, True forces it
    (interpret mode off TPU - the CI smoke), False forces jnp.

    Per-instance results equal `execute_arena` on that instance's own plan
    bit-for-bit when both run eagerly on CPU on aligned power-of-two plans
    (batching the dots over the instance axis neither reassociates a
    per-instance reduction nor changes the per-slice dot kernel); ragged
    odd splits are last-ulp float tolerance (the packed-vs-loop contract,
    tests/test_packed_serving.py).
    """
    cfg = pp.cfg
    on_tpu = jax.default_backend() == "tpu"
    if use_kernel is None:
        use_kernel = on_tpu and pp.kernel_ok and pp.program_ops is not None
    elif use_kernel and (not pp.kernel_ok or pp.program_ops is None):
        raise ValueError(
            "use_kernel=True but this packed plan has no uniform "
            "whole-schedule program (ragged windows or mixed tile "
            "shapes); use the jnp path or a power-of-two configuration")
    single = bs.ndim == 2
    dtype = jnp.result_type(bs.dtype, pp.scale.dtype)
    bk = bs[..., None] if single else bs
    b_in = analog.dac(bk, cfg).astype(dtype)
    if use_kernel:
        from repro.kernels import ops as kops
        m = b_in.shape[0]
        arena = jnp.zeros((m, pp.arena_size) + bk.shape[2:], dtype)
        arena = arena.at[:, pp.in_off:pp.in_off + pp.n].set(b_in)
        in_offs, in_signs, out_offs, out_init = pp.program_meta
        arena = kops.arena_packed_apply(
            arena, pp.program_ops, in_offs, in_signs, out_offs, out_init,
            interpret=not on_tpu)
        out_spec = _arena_out_spec(pp.out_spec, pp.slot_offsets)
        out = jax.vmap(lambda ar: _slot_gather({0: ar}, out_spec))(arena)
    else:
        def one(stacks, b1):
            # per-instance differentiable cascade (custom_vjp vmaps cleanly)
            return _cascade(pp.levels, pp.out_spec, stacks, b1)

        out = jax.vmap(one)(pp.stacks, b_in)
    if single:
        out = out[..., 0]
    scale = pp.scale.reshape((-1,) + (1,) * (out.ndim - 1))
    return -scale * analog.adc(out, cfg)


_execute_arena_packed = jax.jit(execute_arena_packed,
                                static_argnames=("use_kernel",))
_execute_arena_packed_donated = jax.jit(execute_arena_packed,
                                        donate_argnums=(1,),
                                        static_argnames=("use_kernel",))


def execute_arena_packed_sharded(pp: PackedArenaPlan, bs: jnp.ndarray,
                                 mesh=None, axis_name: str = "mc",
                                 use_kernel: Optional[bool] = None
                                 ) -> jnp.ndarray:
    """`execute_arena_packed` with the instance axis sharded over a mesh.

    Each device runs its own shard of the packed fleet (operator stacks,
    scales and right-hand sides all carry the instance axis; the shared
    window-program metadata is replicated - specs from
    `repro.sharding.partition.mc_packed_specs`).  num_instances must
    divide evenly over the mesh axis.  mesh=None builds a 1-D mesh over
    all local devices via `repro.launch.mesh.make_mc_mesh`.
    """
    if mesh is None:
        from repro.launch.mesh import make_mc_mesh
        mesh = make_mc_mesh(axis_name=axis_name)
    n_shards = mesh.shape[axis_name]
    if pp.num_instances % n_shards:
        raise ValueError(
            f"num_instances={pp.num_instances} must divide over the "
            f"{axis_name!r} mesh axis of size {n_shards}")
    return _sharded_packed_executor(pp, bs, mesh, axis_name, use_kernel)


@partial(jax.jit, static_argnames=("mesh", "axis_name", "use_kernel"))
def _sharded_packed_executor(pp, bs, mesh, axis_name, use_kernel):
    from jax.experimental.shard_map import shard_map

    from repro.sharding.partition import mc_packed_specs

    in_specs, out_specs = mc_packed_specs(pp, axis_name)
    mapped = shard_map(
        lambda p, b: execute_arena_packed(p, b, use_kernel=use_kernel),
        mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_rep=False)
    return mapped(pp, bs)


# ---------------------------------------------------------------------------
# Batched / sharded Monte-Carlo solving
# ---------------------------------------------------------------------------

def _mc_execute(parts: PartitionedSystem, b: jnp.ndarray, keys: jax.Array,
                cfg: AnalogConfig, mode: str = "reference") -> jnp.ndarray:
    """Per-key program + compile + execute, vmapped over noise keys.

    mode="reference" runs `execute_flat` per key (the accuracy-study path,
    bit-compatible with the recursive reference); mode="fused" finalizes
    and arena-compiles each key's plan inside the vmap and runs the arena
    executor - the serving-form Monte-Carlo sweep.
    """
    if mode == "fused":
        def one(k):
            fplan = compile_plan(program_system(parts, k, cfg))
            return execute_arena(compile_arena(finalize(fplan, cfg)), b)
        return jax.vmap(one)(keys)
    fplans = jax.vmap(lambda k: compile_plan(program_system(parts, k, cfg)))(
        keys)
    return jax.vmap(lambda fp: execute_flat(fp, b, cfg))(fplans)


@partial(jax.jit, static_argnames=("cfg", "stages", "mode"))
def solve_batched(a: jnp.ndarray, b: jnp.ndarray, keys: jax.Array,
                  cfg: AnalogConfig, stages: Optional[int] = None,
                  mode: str = "reference") -> jnp.ndarray:
    """Batched Monte-Carlo BlockAMC solve in one jit.

    The key-independent digital pre-processing (partitioning, Schur
    complements, normalisation) is hoisted out of the per-key path via
    `partition_system` and traced exactly once; only conductance mapping,
    noise draws and the cascade itself are vmapped over keys, so each
    schedule level is one batched solve/matmul over (num_keys, ...) stacks.
    mode="fused" routes each key through the arena executor instead of
    `execute_flat` (float-tolerance; default keeps the reference path so
    the paper accuracy sweeps stay bit-stable).

    Args:
      a:    (n, n) system matrix.
      b:    (n,) rhs vector or (n, k) matrix of k right-hand sides.
      keys: (num_keys, ...) PRNG keys, one independent device-noise draw each.
    Returns:
      (num_keys, n) or (num_keys, n, k) solutions.
    """
    parts = partition_system(a, cfg, stages)
    return _mc_execute(parts, b, keys, cfg, mode)


@partial(jax.jit, static_argnames=("cfg", "mesh", "axis_name", "mode"))
def _sharded_mc_executor(parts: PartitionedSystem, b: jnp.ndarray,
                         keys: jax.Array, cfg: AnalogConfig, mesh,
                         axis_name: str, mode: str) -> jnp.ndarray:
    """shard_map executor; cfg/mesh/axis are static so jit caches per combo."""
    from jax.experimental.shard_map import shard_map

    from repro.sharding.partition import mc_solve_specs

    in_specs, out_specs = mc_solve_specs(axis_name)
    mapped = shard_map(
        lambda p, bb, kk: _mc_execute(p, bb, kk, cfg, mode),
        mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_rep=False)
    return mapped(parts, b, keys)


def solve_batched_sharded(a: jnp.ndarray, b: jnp.ndarray, keys: jax.Array,
                          cfg: AnalogConfig, stages: Optional[int] = None,
                          mesh=None, axis_name: str = "mc",
                          mode: str = "reference") -> jnp.ndarray:
    """`solve_batched` with the Monte-Carlo key axis sharded over a mesh.

    Each device programs and solves its own shard of noise keys; the system
    matrix, partitioned pre-processing and right-hand sides are replicated.
    With mesh=None a 1-D mesh over all local devices is built via
    `repro.launch.mesh.make_mc_mesh`.  num_keys must divide evenly over the
    mesh axis.  mode="fused" runs each shard's keys through the arena
    executor (same flag as `solve_batched`).
    """
    if mesh is None:
        from repro.launch.mesh import make_mc_mesh
        mesh = make_mc_mesh(axis_name=axis_name)
    n_shards = mesh.shape[axis_name]
    if keys.shape[0] % n_shards:
        raise ValueError(
            f"num_keys={keys.shape[0]} must divide over the "
            f"{axis_name!r} mesh axis of size {n_shards}")
    parts = partition_system(a, cfg, stages)
    return _sharded_mc_executor(parts, b, keys, cfg, mesh, axis_name, mode)


@partial(jax.jit, static_argnames=("cfg",))
def solve_original_batched(a: jnp.ndarray, b: jnp.ndarray, keys: jax.Array,
                           cfg: AnalogConfig) -> jnp.ndarray:
    """Batched Monte-Carlo baseline: original (monolithic) AMC solve."""
    fplans = jax.vmap(
        lambda k: compile_plan(build_original_plan(a, k, cfg)))(keys)
    return jax.vmap(lambda fp: execute_flat(fp, b, cfg))(fplans)

"""BlockAMC: block-partitioned analog solver for A x = b (paper Section III).

The original matrix A is partitioned

        A = [[A1, A2],      b = [f,
             [A3, A4]]           g]

and the solve proceeds in five cascaded analog operations (Algorithm 1):

    step 1  INV(A1):   -y_t = -A1^-1 f
    step 2  MVM(A3):    g_t = -A3 (-y_t)
    step 3  INV(A4s):   z   = -A4s^-1 (-g_s),  A4s = A4 - A3 A1^-1 A2,
                                               -g_s = -g + g_t
    step 4  MVM(A2):   -f_t = -A2 z
    step 5  INV(A1):   -y   = -A1^-1 f_s,      f_s = f - f_t

    x = [y; z]

A4s (the Schur complement) is computed **digitally in advance** and programmed
into its own array - the paper's stated pre-processing overhead.  Multi-stage
solving recurses on the INV steps: every INV whose operand exceeds the
physical array size is itself solved by BlockAMC, and oversized MVM operands
use partitioned (tiled) MVM.  Two stages on a 256x256 system yields 16 arrays
of 64x64, matching paper Fig. 8.

The implementation is plan/execute:

  * `build_plan(A, key, cfg, stages)` does everything that happens at
    *programming time*: partitioning, digital Schur complements, matrix
    normalisation, conductance mapping with per-array programming noise.
  * `execute(plan, b, cfg)` runs the five-step cascade - the *analog runtime*
    - reusing the programmed arrays for any number of right-hand sides.

Both are pure functions of their inputs (vmap-able over noise keys for the
paper's 40-seed Monte Carlo, and jit-able end to end).

On top of the recursive reference executor sits the *flat* level-scheduled
executor (`compile_plan` / `execute_flat` / `solve_batched`): the recursive
plan is compiled once into shape-bucketed stacks of physical arrays (e.g. a
two-stage 256x256 solve becomes 16 arrays of 64x64, stored as a handful of
(num_arrays, 64, 64) conductance tensors - paper Fig. 8) plus a static
straight-line schedule over virtual registers.  Execution is a short loop
over schedule levels; every level is one batched analog op, so vmapping over
Monte-Carlo noise keys and right-hand sides turns the whole cascade into a
few large batched matmuls/solves instead of a per-seed tree walk.  The
recursive executor stays as the bit-level reference the flat executor is
tested against.

On top of *that* sits the finalization layer (`finalize` / `FinalizedPlan` /
`ProgrammedSolver`): once per programmed matrix, every INV bucket's effective
operator is LU-factorised and every MVM level's effective tile operators are
gathered into fused (num_tiles, r, c) stacks, so each subsequent solve is
pure batched `lu_solve`s and stacked matmuls - the paper's program-once /
solve-many cost model.  `execute_flat` remains the unfinalized reference the
finalized path is pinned to bit-for-bit.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional, Tuple, Union

import jax
import jax.numpy as jnp

from repro.core import analog
from repro.core.analog import AnalogConfig, CrossbarPair, TileGrid


# ---------------------------------------------------------------------------
# Plans (pytrees)
# ---------------------------------------------------------------------------

@jax.tree_util.register_pytree_node_class
class LeafInvPlan:
    """An INV operation small enough for one physical array."""

    def __init__(self, pair: CrossbarPair):
        self.pair = pair

    def tree_flatten(self):
        return (self.pair,), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0])

    @property
    def n(self):
        return self.pair.shape[0]


@jax.tree_util.register_pytree_node_class
class BlockPlan:
    """One BlockAMC stage: INV plans for A1/A4s, tiled MVM grids for A2/A3."""

    def __init__(self, inv1, mvm2, mvm3, inv4s, m):
        self.inv1 = inv1      # plan for A1 (LeafInvPlan or BlockPlan)
        self.mvm2 = mvm2      # tile grid for A2
        self.mvm3 = mvm3      # tile grid for A3
        self.inv4s = inv4s    # plan for A4s
        self.m = m            # split point (static)

    def tree_flatten(self):
        return (self.inv1, self.mvm2, self.mvm3, self.inv4s), (self.m,)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children, aux[0])

    @property
    def n(self):
        return self.inv1.n + self.inv4s.n


Plan = Union[LeafInvPlan, BlockPlan]


@dataclasses.dataclass
class SolvePlan:
    """Top-level plan: the recursive structure plus the global scale."""
    root: Plan
    scale: jnp.ndarray   # c = 1/max|A|; solution is descaled digitally


jax.tree_util.register_dataclass(
    SolvePlan, data_fields=["root", "scale"], meta_fields=[])


# ---------------------------------------------------------------------------
# Plan construction (programming time)
#
# Split into two walks so the Monte-Carlo path can hoist the expensive,
# *key-independent* digital pre-processing (partitioning, Schur complements,
# normalisation) out of the per-noise-key loop:
#
#   partition_system(a, cfg, stages)  -> PartitionedSystem   (digital, once)
#   program_system(parts, key, cfg)   -> SolvePlan           (per noise key)
#
# `build_plan` composes the two and is unchanged API-wise; the key-splitting
# order of `program_system` matches the old fused builder exactly, so noise
# draws (and therefore every downstream golden test) are bit-identical.
# ---------------------------------------------------------------------------

def required_stages(n: int, array_size: int) -> int:
    """Smallest number of partitioning stages so every INV fits one array."""
    stages = 0
    while n > array_size:
        n = -(-n // 2)
        stages += 1
    return stages


@jax.tree_util.register_pytree_node_class
class LeafTarget:
    """Partitioning leaf: one block destined for a single INV array."""

    def __init__(self, a):
        self.a = a

    def tree_flatten(self):
        return (self.a,), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0])

    @property
    def n(self):
        return self.a.shape[0]


@jax.tree_util.register_pytree_node_class
class BlockTarget:
    """One partitioning stage: INV targets for A1/A4s, raw blocks A2/A3."""

    def __init__(self, inv1, a2, a3, inv4s, m):
        self.inv1 = inv1
        self.a2 = a2
        self.a3 = a3
        self.inv4s = inv4s
        self.m = m

    def tree_flatten(self):
        return (self.inv1, self.a2, self.a3, self.inv4s), (self.m,)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children, aux[0])

    @property
    def n(self):
        return self.inv1.n + self.inv4s.n


Target = Union[LeafTarget, BlockTarget]


@dataclasses.dataclass
class PartitionedSystem:
    """Key-independent digital pre-processing of one system matrix."""
    root: Target
    scale: jnp.ndarray   # c = 1/max|A|


jax.tree_util.register_dataclass(
    PartitionedSystem, data_fields=["root", "scale"], meta_fields=[])


def _partition(a: jnp.ndarray, stages: int) -> Target:
    n = a.shape[0]
    if stages == 0 or n <= 1:
        # a 1x1 block cannot be partitioned further: splitting it would
        # produce zero-width A2/A3 and an empty Schur complement (i.e.
        # physical arrays with no devices), so surplus stages stop here.
        return LeafTarget(a)
    # Paper: for odd n, A1 takes (n+1)/2; any square A1 works.
    m = -(-n // 2)
    a1, a2 = a[:m, :m], a[:m, m:]
    a3, a4 = a[m:, :m], a[m:, m:]
    # Digital pre-processing of the Schur complement (paper Eq. 3).  Done in
    # f32 here, standing in for the host preprocessor in Fig. 3.
    a4s = a4 - a3 @ jnp.linalg.solve(a1, a2)
    return BlockTarget(_partition(a1, stages - 1), a2, a3,
                       _partition(a4s, stages - 1), m)


def partition_system(a: jnp.ndarray, cfg: AnalogConfig,
                     stages: Optional[int] = None) -> PartitionedSystem:
    """Partition, Schur-complement and normalise A (no noise key needed).

    stages=None auto-selects the minimum depth so leaves fit cfg.array_size
    (stages=1 -> paper's one-stage solver, 2 -> two-stage, 0 -> original AMC).
    """
    n = a.shape[0]
    if stages is None:
        stages = required_stages(n, cfg.array_size)
    # Global normalisation: largest |element| of the *original* matrix -> 1.
    scale = 1.0 / jnp.max(jnp.abs(a))
    return PartitionedSystem(root=_partition(a, stages), scale=scale)


def _program(t: Target, key: jax.Array, cfg: AnalogConfig,
             scale: jnp.ndarray) -> Plan:
    if isinstance(t, LeafTarget):
        return LeafInvPlan(analog.map_matrix(t.a, key, cfg, scale))
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return BlockPlan(
        inv1=_program(t.inv1, k1, cfg, scale),
        mvm2=analog.map_tiled(t.a2, k2, cfg, scale),
        mvm3=analog.map_tiled(t.a3, k3, cfg, scale),
        inv4s=_program(t.inv4s, k4, cfg, scale),
        m=t.m,
    )


def program_system(parts: PartitionedSystem, key: jax.Array,
                   cfg: AnalogConfig) -> SolvePlan:
    """'Program' a partitioned system: conductance mapping + device noise."""
    return SolvePlan(root=_program(parts.root, key, cfg, parts.scale),
                     scale=parts.scale)


def build_plan(a: jnp.ndarray, key: jax.Array, cfg: AnalogConfig,
               stages: Optional[int] = None) -> SolvePlan:
    """Partition, pre-process, normalise and 'program' matrix A."""
    return program_system(partition_system(a, cfg, stages), key, cfg)


def build_original_plan(a: jnp.ndarray, key: jax.Array,
                        cfg: AnalogConfig) -> SolvePlan:
    """The baseline 'original AMC': one monolithic INV array of size n.

    Used by every paper comparison ('compared to a single AMC circuit
    solving the same problem').  Ignores cfg.array_size deliberately.
    """
    scale = 1.0 / jnp.max(jnp.abs(a))
    return SolvePlan(root=LeafInvPlan(analog.map_matrix(a, key, cfg, scale)),
                     scale=scale)


# ---------------------------------------------------------------------------
# Execution (analog runtime; five-step cascade per stage)
# ---------------------------------------------------------------------------

def _exec_inv(plan: Plan, v_in: jnp.ndarray, cfg: AnalogConfig) -> jnp.ndarray:
    """Run an INV plan with the circuit sign convention: returns -A^-1 v_in."""
    if isinstance(plan, LeafInvPlan):
        return analog.amc_inv(plan.pair, v_in, cfg)
    m = plan.m
    f, g = v_in[:m], v_in[m:]
    # --- Algorithm 1, signs kept exactly as the circuits produce them. ---
    neg_yt = _exec_inv(plan.inv1, f, cfg)                 # step 1: -y_t
    gt = analog.amc_mvm_tiled(plan.mvm3, neg_yt, cfg)     # step 2: -A3(-y_t) = g_t
    neg_gs = -g + gt                                      # analog summation: -g_s
    z = _exec_inv(plan.inv4s, neg_gs, cfg)                # step 3: -A4s^-1(-g_s) = +z
    neg_ft = analog.amc_mvm_tiled(plan.mvm2, z, cfg)      # step 4: -f_t
    fs = f + neg_ft                                       # f_s = f - f_t
    neg_y = _exec_inv(plan.inv1, fs, cfg)                 # step 5: -y  (A1 reused)
    # This function's contract is 'return -A^-1 v_in' = [-y; -z].
    return jnp.concatenate([neg_y, -z])


def execute(plan: SolvePlan, b: jnp.ndarray, cfg: AnalogConfig) -> jnp.ndarray:
    """Solve A x = b with the programmed plan; returns x (digitally descaled).

    With the global normalisation A' = c A (c = plan.scale), the arrays hold
    A' and the cascade's ADC output is  out = -(A')^-1 b = -(A^-1 b)/c, so the
    host recovers  x = -c * out  - one sign flip and one scalar multiply in
    the digital domain.
    """
    b_in = analog.dac(b, cfg)
    out = _exec_inv(plan.root, b_in, cfg)       # = -(cA)^-1 b = -x/c
    out = analog.adc(out, cfg)
    return -plan.scale * out


def solve(a: jnp.ndarray, b: jnp.ndarray, key: jax.Array, cfg: AnalogConfig,
          stages: Optional[int] = None) -> jnp.ndarray:
    """Convenience: build_plan + execute."""
    return execute(build_plan(a, key, cfg, stages), b, cfg)


def solve_original(a: jnp.ndarray, b: jnp.ndarray, key: jax.Array,
                   cfg: AnalogConfig) -> jnp.ndarray:
    """Baseline: original (monolithic) AMC solve."""
    return execute(build_original_plan(a, key, cfg), b, cfg)


# ---------------------------------------------------------------------------
# Flat (level-scheduled) executor
#
# compile_plan() walks a SolvePlan once at trace time and lowers it to
#   * stacked conductance tensors: every physical array of the cascade is
#     interned into a (depth, shape) bucket, so all same-shape arrays at the
#     same cascade depth live in one (num_arrays, rows, cols) TileGrid, and
#   * a static straight-line schedule of levels over virtual registers.
#
# Each schedule level is exactly one analog operation (a leaf INV, a tiled
# MVM, an analog summation, or a wiring step), so executing a plan is a short
# Python loop whose body is entirely batched jnp ops - no tree recursion at
# run time.  Because the schedule and all shapes are static, `execute_flat`
# vmaps/jits cleanly: batching over Monte-Carlo noise keys adds a leading
# axis to every stack and turns each level into one batched matmul or
# batched solve, which is how the hot Monte-Carlo path scales with the
# *number of arrays* instead of the depth of the tree.
# ---------------------------------------------------------------------------

# Schedule instruction set (all operands are static Python ints):
#   ("slice", src, lo, hi)        reg = regs[src][lo:hi]      (partition wiring)
#   ("inv",   bucket, idx, src)   reg = amc_inv(inv_stack[bucket][idx], regs[src])
#   ("mvm",   rows, src)          reg = amc_mvm_tiled(grid, regs[src]); `rows`
#                                 is a tuple of tile-rows of (bucket, idx)
#                                 refs into the MVM stacks
#   ("add",   s1, r1, s2, r2)     reg = s1*regs[r1] + s2*regs[r2], s in {+1,-1}
#                                 (analog current summation at a summing node)
#   ("catneg", r1, r2)            reg = concat([regs[r1], -regs[r2]])
#                                 (reassemble [ -y ; -z ] from cascade halves)


@jax.tree_util.register_pytree_node_class
class FlatPlan:
    """Level-scheduled form of a SolvePlan.

    `inv_stacks` / `mvm_stacks` are tuples of TileGrid, one per
    (cascade depth, array shape) bucket; entry i of a stack holds physical
    array i of that bucket as programmed (identical conductances to the
    recursive plan it was compiled from).  `schedule` is the static level
    program; `inv_keys` / `mvm_keys` record each bucket's (depth, shape)
    for introspection and tests.
    """

    def __init__(self, inv_stacks, mvm_stacks, scale, schedule, n,
                 inv_keys, mvm_keys):
        self.inv_stacks = inv_stacks
        self.mvm_stacks = mvm_stacks
        self.scale = scale
        self.schedule = schedule
        self.n = n
        self.inv_keys = inv_keys
        self.mvm_keys = mvm_keys

    def tree_flatten(self):
        return ((self.inv_stacks, self.mvm_stacks, self.scale),
                (self.schedule, self.n, self.inv_keys, self.mvm_keys))

    @classmethod
    def tree_unflatten(cls, aux, children):
        inv_stacks, mvm_stacks, scale = children
        return cls(inv_stacks, mvm_stacks, scale, *aux)

    @property
    def num_arrays(self) -> int:
        """Total physical arrays of the cascade (16 for 256^2 two-stage)."""
        return sum(g.shape[-3] for g in self.inv_stacks) + \
            sum(g.shape[-3] for g in self.mvm_stacks)

    @property
    def num_levels(self) -> int:
        return len(self.schedule)


class _Interner:
    """Dedupes physical arrays into (depth, shape)-bucketed stacking lists.

    The same CrossbarPair object can be referenced several times by the
    schedule (A1 serves cascade steps 1 and 5), but is programmed - and
    therefore stacked - exactly once.
    """

    def __init__(self):
        self.key_to_bucket = {}
        self.lists = []
        self.keys = []
        self._memo = {}

    def ref(self, key, pair) -> Tuple[int, int]:
        tag = id(pair)
        if tag in self._memo:
            return self._memo[tag]
        if key not in self.key_to_bucket:
            self.key_to_bucket[key] = len(self.lists)
            self.lists.append([])
            self.keys.append(key)
        bucket = self.key_to_bucket[key]
        self.lists[bucket].append(pair)
        out = (bucket, len(self.lists[bucket]) - 1)
        self._memo[tag] = out
        return out


def compile_plan(plan: SolvePlan) -> FlatPlan:
    """Lower a recursive SolvePlan to its level-scheduled flat form.

    Pure restructuring: the stacked conductances are exactly the recursive
    plan's (same noise draws), so both executors compute with identical
    arrays.  Traceable (works under jit/vmap over noise keys).
    """
    invs, mvms = _Interner(), _Interner()
    prog = []
    n_regs = [1]                      # register 0 is the cascade input

    def emit(instr) -> int:
        prog.append(instr)
        r = n_regs[0]
        n_regs[0] += 1
        return r

    def emit_inv(p: Plan, src: int, depth: int) -> int:
        if isinstance(p, LeafInvPlan):
            bucket, idx = invs.ref((depth, p.pair.shape), p.pair)
            return emit(("inv", bucket, idx, src))
        m, n = p.m, p.n
        f = emit(("slice", src, 0, m))
        g = emit(("slice", src, m, n))
        # Five-step cascade (Algorithm 1), one schedule level per step.
        neg_yt = emit_inv(p.inv1, f, depth + 1)                  # step 1
        rows3 = tuple(tuple(mvms.ref((depth, t.shape), t) for t in row)
                      for row in p.mvm3)
        gt = emit(("mvm", rows3, neg_yt))                        # step 2
        neg_gs = emit(("add", -1, g, 1, gt))
        z = emit_inv(p.inv4s, neg_gs, depth + 1)                 # step 3
        rows2 = tuple(tuple(mvms.ref((depth, t.shape), t) for t in row)
                      for row in p.mvm2)
        neg_ft = emit(("mvm", rows2, z))                         # step 4
        fs = emit(("add", 1, f, 1, neg_ft))
        neg_y = emit_inv(p.inv1, fs, depth + 1)                  # step 5
        return emit(("catneg", neg_y, z))

    emit_inv(plan.root, 0, 0)
    g0 = _first_pair(plan.root).g0
    inv_stacks = tuple(analog.stack_pairs(ps, plan.scale, g0)
                       for ps in invs.lists)
    mvm_stacks = tuple(analog.stack_pairs(ps, plan.scale, g0)
                       for ps in mvms.lists)
    return FlatPlan(inv_stacks, mvm_stacks, plan.scale, tuple(prog),
                    plan.root.n, tuple(invs.keys), tuple(mvms.keys))


def _first_pair(p: Plan) -> CrossbarPair:
    return p.pair if isinstance(p, LeafInvPlan) else _first_pair(p.inv1)


def build_flat_plan(a: jnp.ndarray, key: jax.Array, cfg: AnalogConfig,
                    stages: Optional[int] = None) -> FlatPlan:
    """Convenience: build_plan + compile_plan."""
    return compile_plan(build_plan(a, key, cfg, stages))


def _inv_operators(grid: TileGrid, cfg: AnalogConfig) -> jnp.ndarray:
    """The (num, s, s) matrices one INV bucket's circuits solve with.

    Matches analog.amc_inv: effective conductance matrix plus the diagonal
    summing-node loading term under finite OPA gain.
    """
    a = grid.a_eff(cfg)
    if cfg.opa_gain is not None:
        load = (cfg.g0 + jnp.sum(grid.gpos + grid.gneg, axis=-1)) \
            / (cfg.opa_gain * cfg.g0)
        a = a + load[..., :, None] * jnp.eye(a.shape[-1], dtype=a.dtype)
    return a


def execute_flat(fplan: FlatPlan, b: jnp.ndarray, cfg: AnalogConfig
                 ) -> jnp.ndarray:
    """Run the level schedule; returns x like `execute`.

    `b` may be a vector (n,) or a matrix (n, k) of k right-hand sides -
    every schedule level then computes all k solves in one batched op.

    Program-once / solve-many: every leaf INV operator is factorised once
    per bucket (one batched LU per stack), and the schedule's INV levels
    reuse the factors - cascade steps 1 and 5 share A1's factorisation
    exactly as the hardware reuses the programmed array.
    """
    lu_stacks = [jax.scipy.linalg.lu_factor(_inv_operators(g, cfg))
                 for g in fplan.inv_stacks]
    regs = [analog.dac(b, cfg)]
    for instr in fplan.schedule:
        op = instr[0]
        if op == "slice":
            _, src, lo, hi = instr
            regs.append(regs[src][lo:hi])
        elif op == "inv":
            _, bucket, idx, src = instr
            lu, piv = lu_stacks[bucket]
            regs.append(-jax.scipy.linalg.lu_solve((lu[idx], piv[idx]),
                                                   regs[src]))
        elif op == "mvm":
            _, rows, src = instr
            grid = [[fplan.mvm_stacks[bk].pair(i) for bk, i in row]
                    for row in rows]
            regs.append(analog.amc_mvm_tiled(grid, regs[src], cfg))
        elif op == "add":
            _, s1, r1, s2, r2 = instr
            x1 = regs[r1] if s1 > 0 else -regs[r1]
            x2 = regs[r2] if s2 > 0 else -regs[r2]
            regs.append(x1 + x2)
        elif op == "catneg":
            _, r1, r2 = instr
            regs.append(jnp.concatenate([regs[r1], -regs[r2]]))
        else:  # pragma: no cover - compile_plan only emits the ops above
            raise ValueError(f"unknown schedule op {op!r}")
    return -fplan.scale * analog.adc(regs[-1], cfg)


# ---------------------------------------------------------------------------
# Finalization: program-once / solve-many
#
# `execute_flat` still re-pays programming-time costs on every call: it
# re-factorises every INV bucket and re-derives every MVM tile's effective
# operator (wire model + loading) per solve.  On AMC hardware those costs are
# paid exactly once, when the arrays are programmed; each subsequent solve is
# nearly free (paper Section III; Sun et al. 2020).
#
# `finalize` mirrors that split in the simulator.  Once per programmed
# matrix it precomputes
#   * per-INV-bucket effective operator stacks (wire model + finite-gain
#     loading folded in) together with their batched LU factors, and
#   * per-MVM-level effective tile stacks in (L, rows, cols) layout, grouped
#     by tile shape, with static input-gather windows and precomputed
#     summing-node divisors,
# so every runtime level of `execute_finalized` is a pure batched `lu_solve`
# or a stacked MVM over precomputed operators (XLA's dot merger fuses each
# level's same-shape tile dots under jit) - zero per-call re-derivation.
# The numbers are the ones `execute_flat` computes (same factors, same
# per-tile operators, same accumulation order), so the two agree bit-for-bit
# on CPU when run in the same regime; `execute_flat` stays as the
# unfinalized reference.
# ---------------------------------------------------------------------------


@jax.tree_util.register_pytree_node_class
class _MvmLevel:
    """One finalized tiled-MVM schedule level.

    `stacks[g]` holds the effective operator matrices of all same-shape tiles
    of this level as one (L, rows, cols) tensor; `windows[g]` the static
    input column windows, tile l reading v[lo:hi].  `rows` lists, per output
    tile-row, the (group, index) tile refs in original column order - the
    runtime accumulates partial products in exactly `amc_mvm_tiled`'s order,
    which keeps the finalized path bit-compatible with the flat one.  `divs`
    are the per-tile-row finite-gain summing-node divisors (empty tuple for
    an ideal OPA).
    """

    def __init__(self, stacks, divs, windows, rows):
        self.stacks = stacks      # tuple of (L, r, c) arrays, one per shape
        self.divs = divs          # () or one divisor vector per tile-row
        self.windows = windows    # tuple (per group) of ((lo, hi), ...)
        self.rows = rows          # tuple (per tile-row) of ((group, idx), ..)

    def tree_flatten(self):
        return (self.stacks, self.divs), (self.windows, self.rows)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children, *aux)

    def apply(self, v: jnp.ndarray) -> jnp.ndarray:
        """Stacked MVM level: v (cols,) or (cols, k) -> (rows,) / (rows, k).

        Each tile's partial product reads its precomputed operator out of the
        (L, r, c) stack; the reduction replays `amc_mvm_tiled`'s per-row
        accumulation order exactly (the bit-compatibility contract), and XLA's
        dot merger fuses the same-shape tile dots of one level into a single
        batched matmul under jit - a batched einsum here would reorder the
        matvec reduction and break bitwise parity with the flat executor.
        """
        divs = self.divs if self.divs else (None,) * len(self.rows)
        outs = []
        for refs, div in zip(self.rows, divs):
            acc = None
            for g, i in refs:
                lo, hi = self.windows[g][i]
                p = -(self.stacks[g][i] @ v[lo:hi])
                acc = p if acc is None else acc + p
            if div is not None:
                acc = acc / (div[:, None] if acc.ndim == 2 else div)
            outs.append(acc)
        return jnp.concatenate(outs)


@jax.tree_util.register_pytree_node_class
class FinalizedPlan:
    """A FlatPlan finalized against one AnalogConfig: ready-to-solve form.

    Holds the precomputed per-bucket LU factors (`lu_stacks`), the fused
    per-level MVM operators (`mvm_levels`), and the rewritten schedule in
    which every "mvm" level references a finalized _MvmLevel.  The config is
    baked in (aux data): the precomputed operators are only valid for the
    cfg they were derived under.
    """

    def __init__(self, lu_stacks, mvm_levels, scale, schedule, n, cfg,
                 num_arrays):
        self.lu_stacks = lu_stacks    # tuple of (lu, piv) per INV bucket
        self.mvm_levels = mvm_levels  # tuple of _MvmLevel
        self.scale = scale
        self.schedule = schedule      # "mvm" ops rewritten to ("fmvm", ...)
        self.n = n
        self.cfg = cfg
        self.num_arrays = num_arrays

    def tree_flatten(self):
        return ((self.lu_stacks, self.mvm_levels, self.scale),
                (self.schedule, self.n, self.cfg, self.num_arrays))

    @classmethod
    def tree_unflatten(cls, aux, children):
        lu_stacks, mvm_levels, scale = children
        return cls(lu_stacks, mvm_levels, scale, *aux)

    @property
    def num_levels(self) -> int:
        return len(self.schedule)


def _finalize_mvm_level(fplan: FlatPlan, rows, cfg: AnalogConfig) -> _MvmLevel:
    """Precompute one "mvm" level's effective operators and divisors.

    Derivations match `execute_flat`'s runtime path exactly: per-tile
    `CrossbarPair.a_eff` (wire model folded in) and `amc_mvm_tiled`'s
    sequential summing-node load accumulation, evaluated once here.
    """
    groups: dict = {}        # (r, c) tile shape -> group index
    stacks: list = []        # per group: list of a_eff tiles
    windows: list = []       # per group: list of (lo, hi) windows
    row_refs = []
    divs = []
    for row in rows:
        col_off = 0
        refs = []
        load = cfg.g0
        for bk, i in row:
            pair = fplan.mvm_stacks[bk].pair(i)
            r, c = pair.shape
            if (r, c) not in groups:
                groups[(r, c)] = len(stacks)
                stacks.append([])
                windows.append([])
            g = groups[(r, c)]
            refs.append((g, len(stacks[g])))
            stacks[g].append(pair.a_eff(cfg))
            windows[g].append((col_off, col_off + c))
            load = load + jnp.sum(pair.gpos + pair.gneg, axis=1)
            col_off += c
        row_refs.append(tuple(refs))
        if cfg.opa_gain is not None:
            divs.append(1.0 + load / (cfg.opa_gain * cfg.g0))
    return _MvmLevel(tuple(jnp.stack(s) for s in stacks), tuple(divs),
                     tuple(tuple(w) for w in windows), tuple(row_refs))


def finalize(fplan: FlatPlan, cfg: AnalogConfig) -> FinalizedPlan:
    """Precompute all per-solve-invariant operators of a flat plan.

    Traceable (pure jnp), so it can run under jit; typically called once per
    programmed matrix via `ProgrammedSolver.program`.
    """
    lu_stacks = tuple(jax.scipy.linalg.lu_factor(_inv_operators(g, cfg))
                      for g in fplan.inv_stacks)
    mvm_levels = []
    schedule = []
    for instr in fplan.schedule:
        if instr[0] == "mvm":
            _, rows, src = instr
            schedule.append(("fmvm", len(mvm_levels), src))
            mvm_levels.append(_finalize_mvm_level(fplan, rows, cfg))
        else:
            schedule.append(instr)
    return FinalizedPlan(lu_stacks, tuple(mvm_levels), fplan.scale,
                         tuple(schedule), fplan.n, cfg, fplan.num_arrays)


def execute_finalized(fin: FinalizedPlan, b: jnp.ndarray) -> jnp.ndarray:
    """Run a finalized schedule; returns x like `execute` / `execute_flat`.

    `b` may be (n,) or (n, k).  Every level is a batched `lu_solve` against
    precomputed factors or one fused stacked MVM - nothing is re-derived.
    """
    cfg = fin.cfg
    regs = [analog.dac(b, cfg)]
    for instr in fin.schedule:
        op = instr[0]
        if op == "slice":
            _, src, lo, hi = instr
            regs.append(regs[src][lo:hi])
        elif op == "inv":
            _, bucket, idx, src = instr
            lu, piv = fin.lu_stacks[bucket]
            regs.append(-jax.scipy.linalg.lu_solve((lu[idx], piv[idx]),
                                                   regs[src]))
        elif op == "fmvm":
            _, level, src = instr
            regs.append(fin.mvm_levels[level].apply(regs[src]))
        elif op == "add":
            _, s1, r1, s2, r2 = instr
            x1 = regs[r1] if s1 > 0 else -regs[r1]
            x2 = regs[r2] if s2 > 0 else -regs[r2]
            regs.append(x1 + x2)
        elif op == "catneg":
            _, r1, r2 = instr
            regs.append(jnp.concatenate([regs[r1], -regs[r2]]))
        else:  # pragma: no cover - finalize only emits the ops above
            raise ValueError(f"unknown schedule op {op!r}")
    return -fin.scale * analog.adc(regs[-1], cfg)


_execute_finalized = jax.jit(execute_finalized)
_execute_finalized_donated = jax.jit(execute_finalized, donate_argnums=(1,))


class ProgrammedSolver:
    """Program-once / solve-many handle over one finalized matrix.

    The AMC serving abstraction: `program` pays the full programming-time
    cost (partitioning, Schur complements, conductance mapping, operator
    finalization) exactly once; `solve` / `solve_many` then stream any
    number of right-hand sides against the programmed arrays at marginal
    cost.  All solves dispatch through one shared jitted executor keyed on
    the plan's pytree structure, so repeated solves never re-trace.
    """

    def __init__(self, fin: FinalizedPlan):
        self._fin = fin

    @classmethod
    def program(cls, a: jnp.ndarray, key: jax.Array, cfg: AnalogConfig,
                stages: Optional[int] = None) -> "ProgrammedSolver":
        """Full programming flow for matrix A (one noise draw)."""
        return cls.from_plan(build_plan(a, key, cfg, stages), cfg)

    @classmethod
    def from_plan(cls, plan: Union[SolvePlan, FlatPlan],
                  cfg: AnalogConfig) -> "ProgrammedSolver":
        """Finalize an already-built plan (recursive or flat)."""
        fplan = plan if isinstance(plan, FlatPlan) else compile_plan(plan)
        return cls(finalize(fplan, cfg))

    @property
    def finalized(self) -> FinalizedPlan:
        return self._fin

    @property
    def cfg(self) -> AnalogConfig:
        return self._fin.cfg

    @property
    def n(self) -> int:
        return self._fin.n

    @property
    def num_arrays(self) -> int:
        return self._fin.num_arrays

    def solve(self, b: jnp.ndarray, jit: bool = True) -> jnp.ndarray:
        """Solve A x = b for one (n,) rhs or an (n, k) batch.

        jit=False runs the schedule eagerly - op for op the same numbers as
        `execute_flat`, bit-for-bit on CPU (the equivalence contract).  The
        default jitted path lets XLA merge each level's same-shape tile dots,
        which reassociates final-ulp rounding (float-tolerance equal).
        """
        return (_execute_finalized if jit else execute_finalized)(
            self._fin, b)

    def solve_many(self, bs: jnp.ndarray, donate: bool = False) -> jnp.ndarray:
        """Solve an (n, k) batch of right-hand sides in one fused call.

        donate=True donates the rhs buffer to the computation - opt in from
        serving hot loops that never reuse bs after the call (XLA then
        aliases it for the output on backends that support donation; it is
        a no-op on CPU).  The default keeps bs valid for the caller.
        """
        fn = _execute_finalized_donated if donate else _execute_finalized
        return fn(self._fin, bs)


# ---------------------------------------------------------------------------
# Batched / sharded Monte-Carlo solving
# ---------------------------------------------------------------------------

def _mc_execute(parts: PartitionedSystem, b: jnp.ndarray, keys: jax.Array,
                cfg: AnalogConfig) -> jnp.ndarray:
    """Per-key program + compile + flat execute, vmapped over noise keys."""
    fplans = jax.vmap(lambda k: compile_plan(program_system(parts, k, cfg)))(
        keys)
    return jax.vmap(lambda fp: execute_flat(fp, b, cfg))(fplans)


@partial(jax.jit, static_argnames=("cfg", "stages"))
def solve_batched(a: jnp.ndarray, b: jnp.ndarray, keys: jax.Array,
                  cfg: AnalogConfig, stages: Optional[int] = None
                  ) -> jnp.ndarray:
    """Batched Monte-Carlo BlockAMC solve in one jit.

    The key-independent digital pre-processing (partitioning, Schur
    complements, normalisation) is hoisted out of the per-key path via
    `partition_system` and traced exactly once; only conductance mapping,
    noise draws and the cascade itself are vmapped over keys, so each
    schedule level is one batched solve/matmul over (num_keys, ...) stacks.

    Args:
      a:    (n, n) system matrix.
      b:    (n,) rhs vector or (n, k) matrix of k right-hand sides.
      keys: (num_keys, ...) PRNG keys, one independent device-noise draw each.
    Returns:
      (num_keys, n) or (num_keys, n, k) solutions.
    """
    parts = partition_system(a, cfg, stages)
    return _mc_execute(parts, b, keys, cfg)


@partial(jax.jit, static_argnames=("cfg", "mesh", "axis_name"))
def _sharded_mc_executor(parts: PartitionedSystem, b: jnp.ndarray,
                         keys: jax.Array, cfg: AnalogConfig, mesh,
                         axis_name: str) -> jnp.ndarray:
    """shard_map executor; cfg/mesh/axis are static so jit caches per combo."""
    from jax.experimental.shard_map import shard_map

    from repro.sharding.partition import mc_solve_specs

    in_specs, out_specs = mc_solve_specs(axis_name)
    mapped = shard_map(
        lambda p, bb, kk: _mc_execute(p, bb, kk, cfg),
        mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_rep=False)
    return mapped(parts, b, keys)


def solve_batched_sharded(a: jnp.ndarray, b: jnp.ndarray, keys: jax.Array,
                          cfg: AnalogConfig, stages: Optional[int] = None,
                          mesh=None, axis_name: str = "mc") -> jnp.ndarray:
    """`solve_batched` with the Monte-Carlo key axis sharded over a mesh.

    Each device programs and solves its own shard of noise keys; the system
    matrix, partitioned pre-processing and right-hand sides are replicated.
    With mesh=None a 1-D mesh over all local devices is built via
    `repro.launch.mesh.make_mc_mesh`.  num_keys must divide evenly over the
    mesh axis.
    """
    if mesh is None:
        from repro.launch.mesh import make_mc_mesh
        mesh = make_mc_mesh(axis_name=axis_name)
    n_shards = mesh.shape[axis_name]
    if keys.shape[0] % n_shards:
        raise ValueError(
            f"num_keys={keys.shape[0]} must divide over the "
            f"{axis_name!r} mesh axis of size {n_shards}")
    parts = partition_system(a, cfg, stages)
    return _sharded_mc_executor(parts, b, keys, cfg, mesh, axis_name)


@partial(jax.jit, static_argnames=("cfg",))
def solve_original_batched(a: jnp.ndarray, b: jnp.ndarray, keys: jax.Array,
                           cfg: AnalogConfig) -> jnp.ndarray:
    """Batched Monte-Carlo baseline: original (monolithic) AMC solve."""
    fplans = jax.vmap(
        lambda k: compile_plan(build_original_plan(a, k, cfg)))(keys)
    return jax.vmap(lambda fp: execute_flat(fp, b, cfg))(fplans)

"""BlockAMC: block-partitioned analog solver for A x = b (paper Section III).

The original matrix A is partitioned

        A = [[A1, A2],      b = [f,
             [A3, A4]]           g]

and the solve proceeds in five cascaded analog operations (Algorithm 1):

    step 1  INV(A1):   -y_t = -A1^-1 f
    step 2  MVM(A3):    g_t = -A3 (-y_t)
    step 3  INV(A4s):   z   = -A4s^-1 (-g_s),  A4s = A4 - A3 A1^-1 A2,
                                               -g_s = -g + g_t
    step 4  MVM(A2):   -f_t = -A2 z
    step 5  INV(A1):   -y   = -A1^-1 f_s,      f_s = f - f_t

    x = [y; z]

A4s (the Schur complement) is computed **digitally in advance** and programmed
into its own array - the paper's stated pre-processing overhead.  Multi-stage
solving recurses on the INV steps: every INV whose operand exceeds the
physical array size is itself solved by BlockAMC, and oversized MVM operands
use partitioned (tiled) MVM.  Two stages on a 256x256 system yields 16 arrays
of 64x64, matching paper Fig. 8.

The implementation is plan/execute:

  * `build_plan(A, key, cfg, stages)` does everything that happens at
    *programming time*: partitioning, digital Schur complements, matrix
    normalisation, conductance mapping with per-array programming noise.
  * `execute(plan, b, cfg)` runs the five-step cascade - the *analog runtime*
    - reusing the programmed arrays for any number of right-hand sides.

Both are pure functions of their inputs (vmap-able over noise keys for the
paper's 40-seed Monte Carlo, and jit-able end to end).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Union

import jax
import jax.numpy as jnp

from repro.core import analog
from repro.core.analog import AnalogConfig, CrossbarPair


# ---------------------------------------------------------------------------
# Plans (pytrees)
# ---------------------------------------------------------------------------

@jax.tree_util.register_pytree_node_class
class LeafInvPlan:
    """An INV operation small enough for one physical array."""

    def __init__(self, pair: CrossbarPair):
        self.pair = pair

    def tree_flatten(self):
        return (self.pair,), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0])

    @property
    def n(self):
        return self.pair.shape[0]


@jax.tree_util.register_pytree_node_class
class BlockPlan:
    """One BlockAMC stage: INV plans for A1/A4s, tiled MVM grids for A2/A3."""

    def __init__(self, inv1, mvm2, mvm3, inv4s, m):
        self.inv1 = inv1      # plan for A1 (LeafInvPlan or BlockPlan)
        self.mvm2 = mvm2      # tile grid for A2
        self.mvm3 = mvm3      # tile grid for A3
        self.inv4s = inv4s    # plan for A4s
        self.m = m            # split point (static)

    def tree_flatten(self):
        return (self.inv1, self.mvm2, self.mvm3, self.inv4s), (self.m,)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children, aux[0])

    @property
    def n(self):
        return self.inv1.n + self.inv4s.n


Plan = Union[LeafInvPlan, BlockPlan]


@dataclasses.dataclass
class SolvePlan:
    """Top-level plan: the recursive structure plus the global scale."""
    root: Plan
    scale: jnp.ndarray   # c = 1/max|A|; solution is descaled digitally


jax.tree_util.register_dataclass(
    SolvePlan, data_fields=["root", "scale"], meta_fields=[])


# ---------------------------------------------------------------------------
# Plan construction (programming time; digital pre-processing)
# ---------------------------------------------------------------------------

def required_stages(n: int, array_size: int) -> int:
    """Smallest number of partitioning stages so every INV fits one array."""
    stages = 0
    while n > array_size:
        n = -(-n // 2)
        stages += 1
    return stages


def _build(a: jnp.ndarray, key: jax.Array, cfg: AnalogConfig,
           stages: int, scale: jnp.ndarray) -> Plan:
    n = a.shape[0]
    if stages == 0:
        return LeafInvPlan(analog.map_matrix(a, key, cfg, scale))
    # Paper: for odd n, A1 takes (n+1)/2; any square A1 works.
    m = -(-n // 2)
    a1, a2 = a[:m, :m], a[:m, m:]
    a3, a4 = a[m:, :m], a[m:, m:]
    # Digital pre-processing of the Schur complement (paper Eq. 3).  Done in
    # f32 here, standing in for the host preprocessor in Fig. 3.
    a4s = a4 - a3 @ jnp.linalg.solve(a1, a2)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return BlockPlan(
        inv1=_build(a1, k1, cfg, stages - 1, scale),
        mvm2=analog.map_tiled(a2, k2, cfg, scale),
        mvm3=analog.map_tiled(a3, k3, cfg, scale),
        inv4s=_build(a4s, k4, cfg, stages - 1, scale),
        m=m,
    )


def build_plan(a: jnp.ndarray, key: jax.Array, cfg: AnalogConfig,
               stages: Optional[int] = None) -> SolvePlan:
    """Partition, pre-process, normalise and 'program' matrix A.

    stages=None auto-selects the minimum depth so leaves fit cfg.array_size
    (stages=1 -> paper's one-stage solver, 2 -> two-stage, 0 -> original AMC).
    """
    n = a.shape[0]
    if stages is None:
        stages = required_stages(n, cfg.array_size)
    # Global normalisation: largest |element| of the *original* matrix -> 1.
    scale = 1.0 / jnp.max(jnp.abs(a))
    return SolvePlan(root=_build(a, key, cfg, stages, scale), scale=scale)


def build_original_plan(a: jnp.ndarray, key: jax.Array,
                        cfg: AnalogConfig) -> SolvePlan:
    """The baseline 'original AMC': one monolithic INV array of size n.

    Used by every paper comparison ('compared to a single AMC circuit
    solving the same problem').  Ignores cfg.array_size deliberately.
    """
    scale = 1.0 / jnp.max(jnp.abs(a))
    return SolvePlan(root=LeafInvPlan(analog.map_matrix(a, key, cfg, scale)),
                     scale=scale)


# ---------------------------------------------------------------------------
# Execution (analog runtime; five-step cascade per stage)
# ---------------------------------------------------------------------------

def _exec_inv(plan: Plan, v_in: jnp.ndarray, cfg: AnalogConfig) -> jnp.ndarray:
    """Run an INV plan with the circuit sign convention: returns -A^-1 v_in."""
    if isinstance(plan, LeafInvPlan):
        return analog.amc_inv(plan.pair, v_in, cfg)
    m = plan.m
    f, g = v_in[:m], v_in[m:]
    # --- Algorithm 1, signs kept exactly as the circuits produce them. ---
    neg_yt = _exec_inv(plan.inv1, f, cfg)                 # step 1: -y_t
    gt = analog.amc_mvm_tiled(plan.mvm3, neg_yt, cfg)     # step 2: -A3(-y_t) = g_t
    neg_gs = -g + gt                                      # analog summation: -g_s
    z = _exec_inv(plan.inv4s, neg_gs, cfg)                # step 3: -A4s^-1(-g_s) = +z
    neg_ft = analog.amc_mvm_tiled(plan.mvm2, z, cfg)      # step 4: -f_t
    fs = f + neg_ft                                       # f_s = f - f_t
    neg_y = _exec_inv(plan.inv1, fs, cfg)                 # step 5: -y  (A1 reused)
    # This function's contract is 'return -A^-1 v_in' = [-y; -z].
    return jnp.concatenate([neg_y, -z])


def execute(plan: SolvePlan, b: jnp.ndarray, cfg: AnalogConfig) -> jnp.ndarray:
    """Solve A x = b with the programmed plan; returns x (digitally descaled).

    With the global normalisation A' = c A (c = plan.scale), the arrays hold
    A' and the cascade's ADC output is  out = -(A')^-1 b = -(A^-1 b)/c, so the
    host recovers  x = -c * out  - one sign flip and one scalar multiply in
    the digital domain.
    """
    b_in = analog.dac(b, cfg)
    out = _exec_inv(plan.root, b_in, cfg)       # = -(cA)^-1 b = -x/c
    out = analog.adc(out, cfg)
    return -plan.scale * out


def solve(a: jnp.ndarray, b: jnp.ndarray, key: jax.Array, cfg: AnalogConfig,
          stages: Optional[int] = None) -> jnp.ndarray:
    """Convenience: build_plan + execute."""
    return execute(build_plan(a, key, cfg, stages), b, cfg)


def solve_original(a: jnp.ndarray, b: jnp.ndarray, key: jax.Array,
                   cfg: AnalogConfig) -> jnp.ndarray:
    """Baseline: original (monolithic) AMC solve."""
    return execute(build_original_plan(a, key, cfg), b, cfg)

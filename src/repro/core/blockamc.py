"""BlockAMC: block-partitioned analog solver for A x = b (paper Section III).

The original matrix A is partitioned

        A = [[A1, A2],      b = [f,
             [A3, A4]]           g]

and the solve proceeds in five cascaded analog operations (Algorithm 1):

    step 1  INV(A1):   -y_t = -A1^-1 f
    step 2  MVM(A3):    g_t = -A3 (-y_t)
    step 3  INV(A4s):   z   = -A4s^-1 (-g_s),  A4s = A4 - A3 A1^-1 A2,
                                               -g_s = -g + g_t
    step 4  MVM(A2):   -f_t = -A2 z
    step 5  INV(A1):   -y   = -A1^-1 f_s,      f_s = f - f_t

    x = [y; z]

A4s (the Schur complement) is computed **digitally in advance** and programmed
into its own array - the paper's stated pre-processing overhead.  Multi-stage
solving recurses on the INV steps: every INV whose operand exceeds the
physical array size is itself solved by BlockAMC, and oversized MVM operands
use partitioned (tiled) MVM.  Two stages on a 256x256 system yields 16 arrays
of 64x64, matching paper Fig. 8.

The implementation is plan/execute:

  * `build_plan(A, key, cfg, stages)` does everything that happens at
    *programming time*: partitioning, digital Schur complements, matrix
    normalisation, conductance mapping with per-array programming noise.
  * `execute(plan, b, cfg)` runs the five-step cascade - the *analog runtime*
    - reusing the programmed arrays for any number of right-hand sides.

Both are pure functions of their inputs (vmap-able over noise keys for the
paper's 40-seed Monte Carlo, and jit-able end to end).

On top of the recursive reference executor sits the *flat* level-scheduled
executor (`compile_plan` / `execute_flat` / `solve_batched`): the recursive
plan is compiled once into shape-bucketed stacks of physical arrays (e.g. a
two-stage 256x256 solve becomes 16 arrays of 64x64, stored as a handful of
(num_arrays, 64, 64) conductance tensors - paper Fig. 8) plus a static
straight-line schedule over virtual registers.  Execution is a short loop
over schedule levels; every level is one batched analog op, so vmapping over
Monte-Carlo noise keys and right-hand sides turns the whole cascade into a
few large batched matmuls/solves instead of a per-seed tree walk.  The
recursive executor stays as the bit-level reference the flat executor is
tested against.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional, Tuple, Union

import jax
import jax.numpy as jnp

from repro.core import analog
from repro.core.analog import AnalogConfig, CrossbarPair, TileGrid


# ---------------------------------------------------------------------------
# Plans (pytrees)
# ---------------------------------------------------------------------------

@jax.tree_util.register_pytree_node_class
class LeafInvPlan:
    """An INV operation small enough for one physical array."""

    def __init__(self, pair: CrossbarPair):
        self.pair = pair

    def tree_flatten(self):
        return (self.pair,), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0])

    @property
    def n(self):
        return self.pair.shape[0]


@jax.tree_util.register_pytree_node_class
class BlockPlan:
    """One BlockAMC stage: INV plans for A1/A4s, tiled MVM grids for A2/A3."""

    def __init__(self, inv1, mvm2, mvm3, inv4s, m):
        self.inv1 = inv1      # plan for A1 (LeafInvPlan or BlockPlan)
        self.mvm2 = mvm2      # tile grid for A2
        self.mvm3 = mvm3      # tile grid for A3
        self.inv4s = inv4s    # plan for A4s
        self.m = m            # split point (static)

    def tree_flatten(self):
        return (self.inv1, self.mvm2, self.mvm3, self.inv4s), (self.m,)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children, aux[0])

    @property
    def n(self):
        return self.inv1.n + self.inv4s.n


Plan = Union[LeafInvPlan, BlockPlan]


@dataclasses.dataclass
class SolvePlan:
    """Top-level plan: the recursive structure plus the global scale."""
    root: Plan
    scale: jnp.ndarray   # c = 1/max|A|; solution is descaled digitally


jax.tree_util.register_dataclass(
    SolvePlan, data_fields=["root", "scale"], meta_fields=[])


# ---------------------------------------------------------------------------
# Plan construction (programming time; digital pre-processing)
# ---------------------------------------------------------------------------

def required_stages(n: int, array_size: int) -> int:
    """Smallest number of partitioning stages so every INV fits one array."""
    stages = 0
    while n > array_size:
        n = -(-n // 2)
        stages += 1
    return stages


def _build(a: jnp.ndarray, key: jax.Array, cfg: AnalogConfig,
           stages: int, scale: jnp.ndarray) -> Plan:
    n = a.shape[0]
    if stages == 0:
        return LeafInvPlan(analog.map_matrix(a, key, cfg, scale))
    # Paper: for odd n, A1 takes (n+1)/2; any square A1 works.
    m = -(-n // 2)
    a1, a2 = a[:m, :m], a[:m, m:]
    a3, a4 = a[m:, :m], a[m:, m:]
    # Digital pre-processing of the Schur complement (paper Eq. 3).  Done in
    # f32 here, standing in for the host preprocessor in Fig. 3.
    a4s = a4 - a3 @ jnp.linalg.solve(a1, a2)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return BlockPlan(
        inv1=_build(a1, k1, cfg, stages - 1, scale),
        mvm2=analog.map_tiled(a2, k2, cfg, scale),
        mvm3=analog.map_tiled(a3, k3, cfg, scale),
        inv4s=_build(a4s, k4, cfg, stages - 1, scale),
        m=m,
    )


def build_plan(a: jnp.ndarray, key: jax.Array, cfg: AnalogConfig,
               stages: Optional[int] = None) -> SolvePlan:
    """Partition, pre-process, normalise and 'program' matrix A.

    stages=None auto-selects the minimum depth so leaves fit cfg.array_size
    (stages=1 -> paper's one-stage solver, 2 -> two-stage, 0 -> original AMC).
    """
    n = a.shape[0]
    if stages is None:
        stages = required_stages(n, cfg.array_size)
    # Global normalisation: largest |element| of the *original* matrix -> 1.
    scale = 1.0 / jnp.max(jnp.abs(a))
    return SolvePlan(root=_build(a, key, cfg, stages, scale), scale=scale)


def build_original_plan(a: jnp.ndarray, key: jax.Array,
                        cfg: AnalogConfig) -> SolvePlan:
    """The baseline 'original AMC': one monolithic INV array of size n.

    Used by every paper comparison ('compared to a single AMC circuit
    solving the same problem').  Ignores cfg.array_size deliberately.
    """
    scale = 1.0 / jnp.max(jnp.abs(a))
    return SolvePlan(root=LeafInvPlan(analog.map_matrix(a, key, cfg, scale)),
                     scale=scale)


# ---------------------------------------------------------------------------
# Execution (analog runtime; five-step cascade per stage)
# ---------------------------------------------------------------------------

def _exec_inv(plan: Plan, v_in: jnp.ndarray, cfg: AnalogConfig) -> jnp.ndarray:
    """Run an INV plan with the circuit sign convention: returns -A^-1 v_in."""
    if isinstance(plan, LeafInvPlan):
        return analog.amc_inv(plan.pair, v_in, cfg)
    m = plan.m
    f, g = v_in[:m], v_in[m:]
    # --- Algorithm 1, signs kept exactly as the circuits produce them. ---
    neg_yt = _exec_inv(plan.inv1, f, cfg)                 # step 1: -y_t
    gt = analog.amc_mvm_tiled(plan.mvm3, neg_yt, cfg)     # step 2: -A3(-y_t) = g_t
    neg_gs = -g + gt                                      # analog summation: -g_s
    z = _exec_inv(plan.inv4s, neg_gs, cfg)                # step 3: -A4s^-1(-g_s) = +z
    neg_ft = analog.amc_mvm_tiled(plan.mvm2, z, cfg)      # step 4: -f_t
    fs = f + neg_ft                                       # f_s = f - f_t
    neg_y = _exec_inv(plan.inv1, fs, cfg)                 # step 5: -y  (A1 reused)
    # This function's contract is 'return -A^-1 v_in' = [-y; -z].
    return jnp.concatenate([neg_y, -z])


def execute(plan: SolvePlan, b: jnp.ndarray, cfg: AnalogConfig) -> jnp.ndarray:
    """Solve A x = b with the programmed plan; returns x (digitally descaled).

    With the global normalisation A' = c A (c = plan.scale), the arrays hold
    A' and the cascade's ADC output is  out = -(A')^-1 b = -(A^-1 b)/c, so the
    host recovers  x = -c * out  - one sign flip and one scalar multiply in
    the digital domain.
    """
    b_in = analog.dac(b, cfg)
    out = _exec_inv(plan.root, b_in, cfg)       # = -(cA)^-1 b = -x/c
    out = analog.adc(out, cfg)
    return -plan.scale * out


def solve(a: jnp.ndarray, b: jnp.ndarray, key: jax.Array, cfg: AnalogConfig,
          stages: Optional[int] = None) -> jnp.ndarray:
    """Convenience: build_plan + execute."""
    return execute(build_plan(a, key, cfg, stages), b, cfg)


def solve_original(a: jnp.ndarray, b: jnp.ndarray, key: jax.Array,
                   cfg: AnalogConfig) -> jnp.ndarray:
    """Baseline: original (monolithic) AMC solve."""
    return execute(build_original_plan(a, key, cfg), b, cfg)


# ---------------------------------------------------------------------------
# Flat (level-scheduled) executor
#
# compile_plan() walks a SolvePlan once at trace time and lowers it to
#   * stacked conductance tensors: every physical array of the cascade is
#     interned into a (depth, shape) bucket, so all same-shape arrays at the
#     same cascade depth live in one (num_arrays, rows, cols) TileGrid, and
#   * a static straight-line schedule of levels over virtual registers.
#
# Each schedule level is exactly one analog operation (a leaf INV, a tiled
# MVM, an analog summation, or a wiring step), so executing a plan is a short
# Python loop whose body is entirely batched jnp ops - no tree recursion at
# run time.  Because the schedule and all shapes are static, `execute_flat`
# vmaps/jits cleanly: batching over Monte-Carlo noise keys adds a leading
# axis to every stack and turns each level into one batched matmul or
# batched solve, which is how the hot Monte-Carlo path scales with the
# *number of arrays* instead of the depth of the tree.
# ---------------------------------------------------------------------------

# Schedule instruction set (all operands are static Python ints):
#   ("slice", src, lo, hi)        reg = regs[src][lo:hi]      (partition wiring)
#   ("inv",   bucket, idx, src)   reg = amc_inv(inv_stack[bucket][idx], regs[src])
#   ("mvm",   rows, src)          reg = amc_mvm_tiled(grid, regs[src]); `rows`
#                                 is a tuple of tile-rows of (bucket, idx)
#                                 refs into the MVM stacks
#   ("add",   s1, r1, s2, r2)     reg = s1*regs[r1] + s2*regs[r2], s in {+1,-1}
#                                 (analog current summation at a summing node)
#   ("catneg", r1, r2)            reg = concat([regs[r1], -regs[r2]])
#                                 (reassemble [ -y ; -z ] from cascade halves)


@jax.tree_util.register_pytree_node_class
class FlatPlan:
    """Level-scheduled form of a SolvePlan.

    `inv_stacks` / `mvm_stacks` are tuples of TileGrid, one per
    (cascade depth, array shape) bucket; entry i of a stack holds physical
    array i of that bucket as programmed (identical conductances to the
    recursive plan it was compiled from).  `schedule` is the static level
    program; `inv_keys` / `mvm_keys` record each bucket's (depth, shape)
    for introspection and tests.
    """

    def __init__(self, inv_stacks, mvm_stacks, scale, schedule, n,
                 inv_keys, mvm_keys):
        self.inv_stacks = inv_stacks
        self.mvm_stacks = mvm_stacks
        self.scale = scale
        self.schedule = schedule
        self.n = n
        self.inv_keys = inv_keys
        self.mvm_keys = mvm_keys

    def tree_flatten(self):
        return ((self.inv_stacks, self.mvm_stacks, self.scale),
                (self.schedule, self.n, self.inv_keys, self.mvm_keys))

    @classmethod
    def tree_unflatten(cls, aux, children):
        inv_stacks, mvm_stacks, scale = children
        return cls(inv_stacks, mvm_stacks, scale, *aux)

    @property
    def num_arrays(self) -> int:
        """Total physical arrays of the cascade (16 for 256^2 two-stage)."""
        return sum(g.shape[-3] for g in self.inv_stacks) + \
            sum(g.shape[-3] for g in self.mvm_stacks)

    @property
    def num_levels(self) -> int:
        return len(self.schedule)


class _Interner:
    """Dedupes physical arrays into (depth, shape)-bucketed stacking lists.

    The same CrossbarPair object can be referenced several times by the
    schedule (A1 serves cascade steps 1 and 5), but is programmed - and
    therefore stacked - exactly once.
    """

    def __init__(self):
        self.key_to_bucket = {}
        self.lists = []
        self.keys = []
        self._memo = {}

    def ref(self, key, pair) -> Tuple[int, int]:
        tag = id(pair)
        if tag in self._memo:
            return self._memo[tag]
        if key not in self.key_to_bucket:
            self.key_to_bucket[key] = len(self.lists)
            self.lists.append([])
            self.keys.append(key)
        bucket = self.key_to_bucket[key]
        self.lists[bucket].append(pair)
        out = (bucket, len(self.lists[bucket]) - 1)
        self._memo[tag] = out
        return out


def compile_plan(plan: SolvePlan) -> FlatPlan:
    """Lower a recursive SolvePlan to its level-scheduled flat form.

    Pure restructuring: the stacked conductances are exactly the recursive
    plan's (same noise draws), so both executors compute with identical
    arrays.  Traceable (works under jit/vmap over noise keys).
    """
    invs, mvms = _Interner(), _Interner()
    prog = []
    n_regs = [1]                      # register 0 is the cascade input

    def emit(instr) -> int:
        prog.append(instr)
        r = n_regs[0]
        n_regs[0] += 1
        return r

    def emit_inv(p: Plan, src: int, depth: int) -> int:
        if isinstance(p, LeafInvPlan):
            bucket, idx = invs.ref((depth, p.pair.shape), p.pair)
            return emit(("inv", bucket, idx, src))
        m, n = p.m, p.n
        f = emit(("slice", src, 0, m))
        g = emit(("slice", src, m, n))
        # Five-step cascade (Algorithm 1), one schedule level per step.
        neg_yt = emit_inv(p.inv1, f, depth + 1)                  # step 1
        rows3 = tuple(tuple(mvms.ref((depth, t.shape), t) for t in row)
                      for row in p.mvm3)
        gt = emit(("mvm", rows3, neg_yt))                        # step 2
        neg_gs = emit(("add", -1, g, 1, gt))
        z = emit_inv(p.inv4s, neg_gs, depth + 1)                 # step 3
        rows2 = tuple(tuple(mvms.ref((depth, t.shape), t) for t in row)
                      for row in p.mvm2)
        neg_ft = emit(("mvm", rows2, z))                         # step 4
        fs = emit(("add", 1, f, 1, neg_ft))
        neg_y = emit_inv(p.inv1, fs, depth + 1)                  # step 5
        return emit(("catneg", neg_y, z))

    emit_inv(plan.root, 0, 0)
    g0 = _first_pair(plan.root).g0
    inv_stacks = tuple(analog.stack_pairs(ps, plan.scale, g0)
                       for ps in invs.lists)
    mvm_stacks = tuple(analog.stack_pairs(ps, plan.scale, g0)
                       for ps in mvms.lists)
    return FlatPlan(inv_stacks, mvm_stacks, plan.scale, tuple(prog),
                    plan.root.n, tuple(invs.keys), tuple(mvms.keys))


def _first_pair(p: Plan) -> CrossbarPair:
    return p.pair if isinstance(p, LeafInvPlan) else _first_pair(p.inv1)


def build_flat_plan(a: jnp.ndarray, key: jax.Array, cfg: AnalogConfig,
                    stages: Optional[int] = None) -> FlatPlan:
    """Convenience: build_plan + compile_plan."""
    return compile_plan(build_plan(a, key, cfg, stages))


def _inv_operators(grid: TileGrid, cfg: AnalogConfig) -> jnp.ndarray:
    """The (num, s, s) matrices one INV bucket's circuits solve with.

    Matches analog.amc_inv: effective conductance matrix plus the diagonal
    summing-node loading term under finite OPA gain.
    """
    a = grid.a_eff(cfg)
    if cfg.opa_gain is not None:
        load = (cfg.g0 + jnp.sum(grid.gpos + grid.gneg, axis=-1)) \
            / (cfg.opa_gain * cfg.g0)
        a = a + load[..., :, None] * jnp.eye(a.shape[-1], dtype=a.dtype)
    return a


def execute_flat(fplan: FlatPlan, b: jnp.ndarray, cfg: AnalogConfig
                 ) -> jnp.ndarray:
    """Run the level schedule; returns x like `execute`.

    `b` may be a vector (n,) or a matrix (n, k) of k right-hand sides -
    every schedule level then computes all k solves in one batched op.

    Program-once / solve-many: every leaf INV operator is factorised once
    per bucket (one batched LU per stack), and the schedule's INV levels
    reuse the factors - cascade steps 1 and 5 share A1's factorisation
    exactly as the hardware reuses the programmed array.
    """
    lu_stacks = [jax.scipy.linalg.lu_factor(_inv_operators(g, cfg))
                 for g in fplan.inv_stacks]
    regs = [analog.dac(b, cfg)]
    for instr in fplan.schedule:
        op = instr[0]
        if op == "slice":
            _, src, lo, hi = instr
            regs.append(regs[src][lo:hi])
        elif op == "inv":
            _, bucket, idx, src = instr
            lu, piv = lu_stacks[bucket]
            regs.append(-jax.scipy.linalg.lu_solve((lu[idx], piv[idx]),
                                                   regs[src]))
        elif op == "mvm":
            _, rows, src = instr
            grid = [[fplan.mvm_stacks[bk].pair(i) for bk, i in row]
                    for row in rows]
            regs.append(analog.amc_mvm_tiled(grid, regs[src], cfg))
        elif op == "add":
            _, s1, r1, s2, r2 = instr
            x1 = regs[r1] if s1 > 0 else -regs[r1]
            x2 = regs[r2] if s2 > 0 else -regs[r2]
            regs.append(x1 + x2)
        elif op == "catneg":
            _, r1, r2 = instr
            regs.append(jnp.concatenate([regs[r1], -regs[r2]]))
        else:  # pragma: no cover - compile_plan only emits the ops above
            raise ValueError(f"unknown schedule op {op!r}")
    return -fplan.scale * analog.adc(regs[-1], cfg)


@partial(jax.jit, static_argnames=("cfg", "stages"))
def solve_batched(a: jnp.ndarray, b: jnp.ndarray, keys: jax.Array,
                  cfg: AnalogConfig, stages: Optional[int] = None
                  ) -> jnp.ndarray:
    """Batched Monte-Carlo BlockAMC solve in one jit.

    Builds and compiles one flat plan per noise key with a single vmap (the
    key-independent digital pre-processing - partitioning, Schur complements,
    normalisation - is traced once and shared), then executes the level
    schedule with all keys and right-hand sides batched: each level is one
    batched solve/matmul over (num_keys, ...) stacks.

    Args:
      a:    (n, n) system matrix.
      b:    (n,) rhs vector or (n, k) matrix of k right-hand sides.
      keys: (num_keys, ...) PRNG keys, one independent device-noise draw each.
    Returns:
      (num_keys, n) or (num_keys, n, k) solutions.
    """
    fplans = jax.vmap(lambda k: build_flat_plan(a, k, cfg, stages))(keys)
    return jax.vmap(lambda fp: execute_flat(fp, b, cfg))(fplans)


@partial(jax.jit, static_argnames=("cfg",))
def solve_original_batched(a: jnp.ndarray, b: jnp.ndarray, keys: jax.Array,
                           cfg: AnalogConfig) -> jnp.ndarray:
    """Batched Monte-Carlo baseline: original (monolithic) AMC solve."""
    fplans = jax.vmap(
        lambda k: compile_plan(build_original_plan(a, k, cfg)))(keys)
    return jax.vmap(lambda fp: execute_flat(fp, b, cfg))(fplans)

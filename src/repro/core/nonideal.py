"""Device and circuit non-ideality models (paper Section IV).

Two non-idealities are studied by the paper:

1. Conductance variation: each programmed RRAM conductance deviates from its
   target by additive Gaussian noise with sigma = 0.05 * G0 (write&verify
   limit, refs [6], [20]).  Applied independently per device, per array.

2. Interconnect (wire) resistance: 1 ohm per segment between adjacent cells
   along a bit-line or word-line (65 nm node, ref [12]).  The paper simulates
   the full circuit in HSPICE; here we provide
     * a first-order effective-conductance model (fast, O(n^2), used at all
       sizes) following the standard IR-drop approximation (Chen ICCAD'15,
       Luo TCAS-I'22 - both cited by the paper), and
     * an exact Modified-Nodal-Analysis (MNA) solver of the full crossbar
       (dense, used for validation at small n; this plays HSPICE's role).

Geometry convention (fixed; documented in DESIGN.md): input drivers sit at
row 0 of each bit-line; the sensing amplifier (TIA virtual ground for the MVM
circuit, OPA summing node for the INV circuit) sits at the last column of
each word-line.  Current through cell (i, j) therefore traverses ~ (i + 1)
BL segments and ~ (n_cols - j) WL segments.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# Conductance variation
# ---------------------------------------------------------------------------

def apply_variation(g: jnp.ndarray, key: jax.Array, sigma_g: float) -> jnp.ndarray:
    """Additive Gaussian conductance noise, clipped at zero (physical)."""
    if sigma_g == 0.0:
        return g
    noise = sigma_g * jax.random.normal(key, g.shape, dtype=g.dtype)
    return jnp.maximum(g + noise, 0.0)


# ---------------------------------------------------------------------------
# First-order interconnect-resistance model
# ---------------------------------------------------------------------------

def effective_conductance(g: jnp.ndarray, r_seg: float) -> jnp.ndarray:
    """First-order (in r*G) effective conductance matrix of a wired crossbar.

    Perturbation with the *true* current distribution: the IR drop seen by
    cell (i, j) is a linear functional of all cell currents that share wire
    segments with its path.  With the driver at row 0 of each bit line and
    the sense node past the last column of each word line,

      shared BL segments of cells (i, j) and (i', j):  1 + min(i, i')
      shared WL segments of cells (i, j) and (i, j'):  n_c - max(j, j')

    giving (elementwise products with the segment-count kernels C, S):

      G_eff = G - r * [ G .* (C @ G) + G .* (G @ S) ],
      C[i, i'] = 1 + min(i, i'),   S[j, j'] = n_c - max(j, j').

    Exact to O((r G n)^2); validated against the exact MNA oracle in tests.
    Cost is two n x n matmuls - free at crossbar sizes.

    `r_seg` may be a traced scalar (the model is linear in r_seg, so it is
    differentiable - the calibration path); the zero-resistance early-out
    only fires for static Python zeros.
    """
    if isinstance(r_seg, (int, float)) and r_seg == 0.0:
        return g
    n_rows, n_cols = g.shape
    dtype = g.dtype
    i = jnp.arange(n_rows, dtype=dtype)
    j = jnp.arange(n_cols, dtype=dtype)
    c_bl = 1.0 + jnp.minimum(i[:, None], i[None, :])
    s_wl = n_cols - jnp.maximum(j[:, None], j[None, :])
    drop = g * (c_bl @ g) + g * (g @ s_wl)
    return g - r_seg * drop


def compensate_conductances(g_target: jnp.ndarray, r_seg: float,
                            iters: int = 3) -> jnp.ndarray:
    """Write-verify compensation for wire IR drop (paper ref [29], Luo et al.
    TCAS-I'22: program conductances such that the *effective* matrix equals
    the target).

    Solves G_eff(G_prog) = G_target by fixed-point iteration on the
    linearised model: G_prog <- G_target + r * drop(G_prog).  Converges in
    2-3 iterations in the r*G*n << 1 regime (the paper's operating point).
    Physical constraint: programmed conductances must stay non-negative.
    """
    if r_seg == 0.0:
        return g_target
    n_rows, n_cols = g_target.shape
    dtype = g_target.dtype
    i = jnp.arange(n_rows, dtype=dtype)
    j = jnp.arange(n_cols, dtype=dtype)
    c_bl = 1.0 + jnp.minimum(i[:, None], i[None, :])
    s_wl = n_cols - jnp.maximum(j[:, None], j[None, :])
    g = g_target
    for _ in range(iters):
        drop = g * (c_bl @ g) + g * (g @ s_wl)
        g = jnp.maximum(g_target + r_seg * drop, 0.0)
    return g


# ---------------------------------------------------------------------------
# Exact MNA crossbar solvers (validation oracles, small n; HSPICE stand-in)
# ---------------------------------------------------------------------------
#
# Node layout for an (nr x nc) crossbar with wire segments:
#   BL node b(i,j): on bit-line (column) j at row i       -> index i*nc + j
#   WL node w(i,j): on word-line (row) i at column j      -> index nr*nc + i*nc + j
# Cell (i,j) connects b(i,j) <-> w(i,j) with conductance g[i,j].
# BL segments connect b(i-1,j) <-> b(i,j); the driver feeds b(0,j) through
# one segment.  WL segments connect w(i,j) <-> w(i,j+1); the sense node is
# one segment past w(i, nc-1) and is held at virtual ground.


def _crossbar_laplacian(g, r_seg: float):
    """Build the (2*nr*nc) x (2*nr*nc) conductance Laplacian plus the
    driver/sense coupling matrices.  Dense numpy (validation oracle only)."""
    import numpy as np
    g = np.asarray(g, dtype=np.float64)
    nr, nc = g.shape
    n_nodes = 2 * nr * nc
    gw = 1.0 / r_seg

    bl = (np.arange(nr)[:, None] * nc + np.arange(nc)[None, :])
    wl = nr * nc + bl

    L = np.zeros((n_nodes, n_nodes))

    def stamp(a_idx, b_idx, cond):
        a_idx = np.asarray(a_idx)
        cond = np.broadcast_to(np.asarray(cond, dtype=np.float64), a_idx.shape)
        a_idx = a_idx.ravel()
        b_idx = np.asarray(b_idx).ravel()
        cond = cond.ravel()
        np.add.at(L, (a_idx, a_idx), cond)
        np.add.at(L, (b_idx, b_idx), cond)
        np.add.at(L, (a_idx, b_idx), -cond)
        np.add.at(L, (b_idx, a_idx), -cond)

    stamp(bl, wl, g)                         # cells
    stamp(bl[:-1, :], bl[1:, :], gw)         # BL wire segments (vertical)
    stamp(wl[:, :-1], wl[:, 1:], gw)         # WL wire segments (horizontal)
    # Driver coupling: v_in[j] -> b(0,j) through one BL segment.
    drive = np.zeros((n_nodes, nc))
    np.add.at(L, (bl[0, :], bl[0, :]), gw)
    drive[bl[0, :], np.arange(nc)] = gw
    # Sense coupling: w(i, nc-1) -> virtual ground through one WL segment.
    sense = np.zeros((n_nodes, nr))
    np.add.at(L, (wl[:, -1], wl[:, -1]), gw)
    sense[wl[:, -1], np.arange(nr)] = gw
    return L, drive, sense


def mna_mvm_currents(g, v_in, r_seg: float):
    """Exact sense currents of the MVM crossbar (TIA inputs at 0 V).

    Returns I[i], the current flowing into the virtual ground of row i.
    Ideal limit (r_seg -> 0): I = g @ v_in.  Numpy float64 oracle: the
    return value is a float64 numpy array regardless of jax's x64 mode
    (a `jnp.asarray` here used to truncate the oracle to f32).
    """
    import numpy as np
    L, drive, sense = _crossbar_laplacian(g, r_seg)
    v_in = np.asarray(v_in, dtype=np.float64)
    # KCL at all internal nodes: L v = drive @ v_in   (sense nodes at 0 V are
    # already folded into L's diagonal via the sense coupling).
    v = np.linalg.solve(L, drive @ v_in)
    # Current into each virtual ground = gw * v(w(i, nc-1)).
    return sense.T @ v


def mna_inv_outputs(g, v_in, r_seg: float, g0: float):
    """Exact OPA output voltages of the INV circuit with wire resistance.
    Returns a float64 numpy array (full-precision oracle, like
    `mna_mvm_currents`).

    Circuit (paper Fig. 1b): v_in[i] injected through a G0 resistor into word
    line i's summing node; OPA i senses that node (ideal virtual ground) and
    drives bit line i.  Feedback through the crossbar enforces
        G0 v_in + G_eff v_out = 0   =>   v_out = -(G_eff/G0)^-1 v_in.

    Unknowns: internal node voltages v (2*nr*nc) and OPA outputs u (nc).
    Equations: KCL at every internal node, plus n 'summing node at 0 V'
    constraints.  The summing node of row i is the sense node (one WL segment
    past w(i, nc-1)); it receives gw*(w(i,nc-1) - 0) + g0*(v_in[i] - 0) and
    sources the OPA input current (ideal OPA: zero), so KCL there is the
    constraint row.
    """
    import numpy as np
    nr, nc = g.shape
    assert nr == nc, "INV circuit requires a square array"
    L, drive, sense = _crossbar_laplacian(g, r_seg)
    v_in = np.asarray(v_in, dtype=np.float64)
    n_nodes = 2 * nr * nc
    # OPA outputs u drive the BLs where v_in drove them in MVM mode.
    #   KCL at internal nodes:  L v - drive @ u = 0.
    #   Summing-node constraint (ideal OPA, node at 0 V, no input current):
    #   array current into the node + G0 input branch current = 0:
    #       (sense.T @ v)[i] + g0 * v_in[i] = 0.
    top = np.concatenate([L, -drive], axis=1)
    bot = np.concatenate([sense.T, np.zeros((nr, nc))], axis=1)
    M = np.concatenate([top, bot], axis=0)
    rhs = np.concatenate([np.zeros((n_nodes,)), -g0 * v_in])
    sol = np.linalg.solve(M, rhs)
    return sol[n_nodes:]


# ---------------------------------------------------------------------------
# Config
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class NonidealConfig:
    """Knobs for the analog non-ideality models (paper Section IV defaults).

    All fields are static Python scalars: the config is hashed into
    `plan_signature`, so any field combination is a distinct compile/packing
    key and new fields flow into the packed-serving stackability rule
    automatically.

    Wire model dispatch: "first_order" is the O(n^2) perturbation used on
    the hot path; "nodal" routes readout through the exact batched MNA
    solver in `repro.physics.nodal` (block-tridiagonal, jit/vmap-safe);
    "none" disables the wire model even when r_wire > 0.

    Device dynamics (physics subsystem):
      * drift_t / drift_nu: power-law retention drift G(t) = G (t/t0)^-nu
        with t0 = 1 s, applied at readout time (`readout_conductance`).
      * p_stuck_on / p_stuck_off: per-device stuck-at fault rates applied at
        programming time; stuck cells read g_stuck_{on,off} * G0 regardless
        of target.  `remap_faults` enables target-aware row/column remapping
        (repro.physics.faults) that steers faults onto low-impact entries.
      * compensate_model: which wire model write-verify tracks
        (None = same as `wire_model`); `wv_iters` is the fixed-point depth.
    """
    sigma: float = 0.0        # conductance sigma in units of G0 (paper: 0.05)
    r_wire: float = 0.0       # wire segment resistance in ohms (paper: 1.0)
    wire_model: str = "first_order"   # "first_order" | "nodal" | "none"
    compensate_wire: bool = False     # write-verify IR-drop compensation
    # (paper ref [29] mitigation; applied at programming time in map_matrix)
    compensate_model: Optional[str] = None  # None -> wire_model
    wv_iters: int = 3                 # write-verify fixed-point iterations
    drift_t: float = 0.0              # readout time since programming [s]
    drift_nu: float = 0.0             # power-law drift exponent (0 = off)
    p_stuck_on: float = 0.0           # fraction of devices stuck at G_on
    p_stuck_off: float = 0.0          # fraction of devices stuck at G_off
    g_stuck_on: float = 1.0           # stuck-ON conductance, units of G0
    g_stuck_off: float = 0.0          # stuck-OFF conductance, units of G0
    remap_faults: bool = False        # fault-aware row/column remapping

    VARIATION_PAPER = 0.05
    R_WIRE_PAPER = 1.0


IDEAL = NonidealConfig()
PAPER_VARIATION = NonidealConfig(sigma=0.05)
PAPER_FULL = NonidealConfig(sigma=0.05, r_wire=1.0)


# ---------------------------------------------------------------------------
# Shared programming / readout pipeline
# ---------------------------------------------------------------------------
#
# Everything the config can express funnels through exactly two functions:
#
#   program_conductances : target -> device state   (write-verify, write
#                          noise, stuck-at faults; programming time)
#   readout_conductance + wire_readout : device state -> matrix the circuit
#                          computes with (drift, then the wire model;
#                          readout time, called from {CrossbarPair,
#                          TileGrid}.a_eff)
#
# so all four executors (recursive / flat / finalized / fused-arena) and the
# packed-serving layer see identical physics without any changes of their
# own.  The physics subsystem (repro.physics) is imported lazily so the core
# package has no hard dependency on it at import time.

def _over_tiles(fn, g: jnp.ndarray) -> jnp.ndarray:
    """Apply a 2-D (r, c) -> (r, c) map over arbitrary leading batch axes."""
    lead = g.shape[:-2]
    if not lead:
        return fn(g)
    flat = g.reshape((-1,) + g.shape[-2:])
    return jax.vmap(fn)(flat).reshape(g.shape)


def program_conductances(g_target: jnp.ndarray, key: jax.Array,
                         ni: NonidealConfig, g0: float) -> jnp.ndarray:
    """The one programming pipeline: write-verify -> write noise -> faults.

    `g_target` is a (..., r, c) stack of target conductances (one physical
    array per trailing 2-D slice; leading axes are tile/batch axes).
    Deterministic write-verify pre-distortion happens against the configured
    wire model; Gaussian write noise and stuck-at faults are drawn from
    `key` independently per device.
    """
    g = g_target
    if ni.compensate_wire and ni.r_wire > 0.0:
        model = ni.compensate_model or ni.wire_model
        if model == "first_order":
            g = _over_tiles(
                partial(compensate_conductances, r_seg=ni.r_wire,
                        iters=ni.wv_iters), g)
        elif model == "nodal":
            from repro.physics import dynamics as _dyn
            g = _over_tiles(
                partial(_dyn.write_verify, r_seg=ni.r_wire, model="nodal",
                        iters=ni.wv_iters), g)
        elif model != "none":
            raise ValueError(f"unknown compensate_model: {model!r}")
    # Key discipline: with faults off, variation consumes `key` directly so
    # seeded noise realizations are bit-identical to the pre-physics pipeline.
    has_faults = ni.p_stuck_on > 0.0 or ni.p_stuck_off > 0.0
    k_var, k_fault = jax.random.split(key) if has_faults else (key, key)
    g = apply_variation(g, k_var, ni.sigma * g0)
    if has_faults:
        from repro.physics import faults as _faults
        g = _faults.apply_stuck_faults(
            g, g_target, k_fault, p_on=ni.p_stuck_on, p_off=ni.p_stuck_off,
            g_on=ni.g_stuck_on * g0, g_off=ni.g_stuck_off * g0,
            remap=ni.remap_faults)
    return g


def readout_conductance(g: jnp.ndarray, ni: NonidealConfig,
                        drift_t=None) -> jnp.ndarray:
    """Device state at readout time: power-law retention drift.

    G(t) = G(t0) * (t/t0)^-nu with t0 = 1 s; `drift_t`/`drift_nu` are static
    config floats, so the no-drift case costs nothing at trace time.

    `drift_t` optionally overrides the static config age with a *traced*
    value (the simulated-device-clock path, mirroring `wire_readout`'s
    r_wire override): a scalar ages the whole stack, a vector of leading-
    axis extent ages each tile of a (..., r, c) stack independently (the
    block-repair path, where repaired arrays are younger than their
    neighbours).  Ages below t0 = 1 s clamp to 1 (a freshly programmed
    device has not drifted), and `drift_nu == 0` disables drift entirely
    whatever the override says.
    """
    if drift_t is not None:
        if ni.drift_nu == 0.0:
            return g
        t = jnp.maximum(jnp.asarray(drift_t, dtype=g.dtype), 1.0)
        factor = t ** jnp.asarray(-ni.drift_nu, dtype=g.dtype)
        if factor.ndim:
            factor = factor.reshape(
                factor.shape + (1,) * (g.ndim - factor.ndim))
        return g * factor
    if ni.drift_nu == 0.0 or ni.drift_t <= 0.0 or ni.drift_t == 1.0:
        return g
    return g * (ni.drift_t ** (-ni.drift_nu))


def wire_readout(g: jnp.ndarray, ni: NonidealConfig,
                 r_wire=None) -> jnp.ndarray:
    """Dispatch the configured wire model over a (..., r, c) stack.

    `r_wire` optionally overrides `ni.r_wire` with a *traced* scalar: the
    override always routes through the differentiable first-order model,
    regardless of `ni.wire_model` / `ni.r_wire` gating (the calibration
    loops in `repro.calib` differentiate solver outputs with respect to
    it; the exact "nodal" model needs a static r_seg and stays the
    non-differentiable oracle).
    """
    if r_wire is not None:
        return _over_tiles(partial(effective_conductance, r_seg=r_wire), g)
    if ni.r_wire <= 0.0 or ni.wire_model == "none":
        return g
    if ni.wire_model == "first_order":
        return _over_tiles(partial(effective_conductance, r_seg=ni.r_wire), g)
    if ni.wire_model == "nodal":
        from repro.physics import nodal as _nodal
        return _over_tiles(
            partial(_nodal.nodal_effective_conductance, r_seg=ni.r_wire), g)
    raise ValueError(f"unknown wire_model: {ni.wire_model!r}")

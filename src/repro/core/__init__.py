"""BlockAMC core: the paper's contribution as composable JAX modules."""
from repro.core.analog import AnalogConfig, IDEAL_CFG, G0_PAPER  # noqa: F401
from repro.core.nonideal import (  # noqa: F401
    NonidealConfig, IDEAL, PAPER_VARIATION, PAPER_FULL)
from repro.core.blockamc import (  # noqa: F401
    build_plan, build_original_plan, execute, solve, solve_original,
    required_stages, partition_system, program_system, finalize,
    execute_finalized, ProgrammedSolver, solve_batched,
    solve_batched_sharded)
from repro.core.metrics import relative_error, l2_relative_error  # noqa: F401

"""Behavioural models of the in-memory AMC circuits (paper Section II).

Two primitives, built from the same components (RRAM crosspoint array + a
column of amplifiers) in different feedback topologies:

  MVM circuit (Fig. 1a):  v_out = -(G / G0) @ v_in
  INV circuit (Fig. 1b):  v_out = -(G / G0)^-1 @ v_in

Both primitives carry a minus sign from the negative-feedback amplifiers;
Algorithm 1's cascade is arranged so the signs cancel.  We keep the signs
explicit and faithful.

Matrix mapping (paper Section IV): the matrix is normalised so its largest
|element| equals 1, then mapped with unit conductance G0 = 100 uS.  Signed
matrices are split A = A+ - A- onto two differential arrays (Section II.B),
each subject to its *own* device noise - doubling the noise sources exactly
as the hardware does.

DAC/ADC interfaces: optional uniform quantisation of circuit inputs/outputs
(paper Fig. 3-4 include 8-bit-class converters; ideal by default since the
paper's accuracy study isolates device/wire effects).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import nonideal
from repro.core.nonideal import NonidealConfig
from repro.core.quantization import quantize  # noqa: F401  (canonical home)

G0_PAPER = 100e-6  # unit conductance, 100 uS


@dataclasses.dataclass(frozen=True)
class AnalogConfig:
    """Static configuration of the AMC substrate."""
    g0: float = G0_PAPER
    array_size: int = 256          # max rows/cols of one physical array
    nonideal: NonidealConfig = nonideal.IDEAL
    dac_bits: Optional[int] = None  # None = ideal interface
    adc_bits: Optional[int] = None
    v_fullscale: float = 1.0        # converter full-scale (normalised units)
    opa_gain: Optional[float] = None  # OPA open-loop gain; None = ideal OPA.
    # Finite gain reproduces the HSPICE behaviour behind paper Fig. 6(c):
    # the summing-node error scales with the row conductance sum (prop. to
    # array size), so smaller BlockAMC arrays are *intrinsically* more
    # accurate even with ideal device mapping.

    def with_(self, **kw) -> "AnalogConfig":
        return dataclasses.replace(self, **kw)


IDEAL_CFG = AnalogConfig()


# ---------------------------------------------------------------------------
# Crossbar pair: differential mapping of one signed matrix block
# ---------------------------------------------------------------------------

@jax.tree_util.register_pytree_node_class
class CrossbarPair:
    """A signed matrix block programmed on two differential RRAM arrays.

    `gpos`/`gneg` are conductances in Siemens *after* programming noise.
    `scale` is the single global normalisation factor c = 1 / max|A_orig|
    shared by every array of one solver instance (the paper normalises the
    original matrix once; per-block rescaling would break the analog cascade).
    The circuit computes with  A_eff = (gpos_eff - gneg_eff) / g0,  which
    approximates c * A_block.
    """

    def __init__(self, gpos, gneg, scale, g0):
        self.gpos = gpos
        self.gneg = gneg
        self.scale = scale
        self.g0 = g0

    def tree_flatten(self):
        return (self.gpos, self.gneg, self.scale), (self.g0,)

    @classmethod
    def tree_unflatten(cls, aux, children):
        gpos, gneg, scale = children
        return cls(gpos, gneg, scale, aux[0])

    @property
    def shape(self):
        return self.gpos.shape

    def a_eff(self, cfg: AnalogConfig, r_wire=None, drift_t=None) -> jnp.ndarray:
        """The matrix the circuit actually computes with: retention drift on
        the device state, then the configured wire model ("first_order" hot
        path or the exact "nodal" oracle) - the one readout pipeline shared
        with TileGrid, so all four executors see identical physics.
        `r_wire` optionally overrides the config wire resistance with a
        traced scalar (differentiable first-order model; calibration);
        `drift_t` optionally overrides the config device age with a traced
        scalar (the simulated-device-clock path)."""
        ni = cfg.nonideal
        gp = nonideal.wire_readout(
            nonideal.readout_conductance(self.gpos, ni, drift_t=drift_t),
            ni, r_wire=r_wire)
        gn = nonideal.wire_readout(
            nonideal.readout_conductance(self.gneg, ni, drift_t=drift_t),
            ni, r_wire=r_wire)
        return (gp - gn) / self.g0


def map_matrix(a_block: jnp.ndarray, key: jax.Array, cfg: AnalogConfig,
               scale: jnp.ndarray) -> CrossbarPair:
    """Program one signed block onto a differential crossbar pair.

    `scale` is the solver-global normalisation 1/max|A_original| (a traced
    scalar).  Programming noise is drawn independently for the two arrays.
    """
    a_norm = a_block * scale
    gpos_t = jnp.maximum(a_norm, 0.0) * cfg.g0   # target conductances
    gneg_t = jnp.maximum(-a_norm, 0.0) * cfg.g0
    kp, kn = jax.random.split(key)
    # one programming pipeline (write-verify -> write noise -> stuck faults)
    gpos = nonideal.program_conductances(gpos_t, kp, cfg.nonideal, cfg.g0)
    gneg = nonideal.program_conductances(gneg_t, kn, cfg.nonideal, cfg.g0)
    return CrossbarPair(gpos, gneg, scale, cfg.g0)


# ---------------------------------------------------------------------------
# Converter interfaces
# ---------------------------------------------------------------------------

def dac(v: jnp.ndarray, cfg: AnalogConfig) -> jnp.ndarray:
    return quantize(v, cfg.dac_bits, cfg.v_fullscale)


def adc(v: jnp.ndarray, cfg: AnalogConfig) -> jnp.ndarray:
    return quantize(v, cfg.adc_bits, cfg.v_fullscale)


# ---------------------------------------------------------------------------
# Circuit primitives (signed, faithful to Fig. 1)
# ---------------------------------------------------------------------------

def _row_load(pair: CrossbarPair, cfg: AnalogConfig) -> jnp.ndarray:
    """Total physical conductance on each row summing node (both arrays)."""
    return cfg.g0 + jnp.sum(pair.gpos + pair.gneg, axis=1)


def _per_row(load: jnp.ndarray, out: jnp.ndarray) -> jnp.ndarray:
    """Broadcast a per-row quantity against a vector or (rows, k) matrix."""
    return load[:, None] if out.ndim == 2 else load


def amc_mvm(pair: CrossbarPair, v_in: jnp.ndarray, cfg: AnalogConfig) -> jnp.ndarray:
    """MVM circuit: v_out = -A_eff @ v_in (TIA feedback sign included).

    `v_in` may be a vector (cols,) or a matrix (cols, k) of k simultaneous
    input vectors (time-multiplexed drive of the same programmed array).

    With finite OPA open-loop gain A_ol, the TIA summing node sits at
    v_s = -v_out/A_ol instead of 0, giving
        v_out = -(G v_in)_i / (G0 * (1 + (G0 + sum_j G_ij) / (A_ol G0))).
    """
    out = -(pair.a_eff(cfg) @ v_in)
    if cfg.opa_gain is not None:
        load = _row_load(pair, cfg)
        out = out / (1.0 + _per_row(load, out) / (cfg.opa_gain * cfg.g0))
    return out


def amc_inv(pair: CrossbarPair, v_in: jnp.ndarray, cfg: AnalogConfig) -> jnp.ndarray:
    """INV circuit equilibrium: G0 v_in + G v_out = 0 => v_out = -A_eff^-1 v_in.

    The equilibrium of the nested feedback loops of Fig. 1(b); solved
    digitally here (the behavioural stand-in for the one-step analog solve).
    `v_in` may be (n,) or (n, k).  With finite OPA gain, KCL at summing
    node i (held at -v_out_i/A_ol) adds a diagonal loading term:
        (G + diag(load)/A_ol) v_out = -G0 v_in.
    """
    a = pair.a_eff(cfg)
    if cfg.opa_gain is not None:
        load = _row_load(pair, cfg) / (cfg.opa_gain * cfg.g0)
        a = a + jnp.diag(load)
    return -jnp.linalg.solve(a, v_in)


# ---------------------------------------------------------------------------
# Bit-sliced mapping (ISAAC-style; beyond-paper precision extension)
# ---------------------------------------------------------------------------

def map_matrix_sliced(a_block: jnp.ndarray, key: jax.Array, cfg: AnalogConfig,
                      scale: jnp.ndarray, n_slices: int = 2,
                      bits_per_slice: int = 4):
    """Map one signed block as `n_slices` arrays of `bits_per_slice` each.

    Each slice stores a quantised digit of the target conductance at full
    dynamic range (re-normalised to G0), so the per-device *absolute* noise
    sigma*G0 is divided by the slice weight on recombination - the standard
    in-memory-computing precision trick (ISAAC, ISCA'16).  Returns a list of
    (CrossbarPair, weight); `amc_mvm_sliced` recombines digitally.
    """
    a_norm = a_block * scale
    levels = 2 ** bits_per_slice
    pairs = []
    residual_pos = jnp.maximum(a_norm, 0.0)
    residual_neg = jnp.maximum(-a_norm, 0.0)
    keys = jax.random.split(key, n_slices)
    sigma_g = cfg.nonideal.sigma * cfg.g0
    for s in range(n_slices):
        weight = float(levels) ** (-s)
        # digit in [0, 1): quantise the residual at this significance
        dig_p = jnp.floor(jnp.clip(residual_pos / weight, 0, 1 - 1e-9)
                          * levels) / levels
        dig_n = jnp.floor(jnp.clip(residual_neg / weight, 0, 1 - 1e-9)
                          * levels) / levels
        residual_pos = residual_pos - dig_p * weight
        residual_neg = residual_neg - dig_n * weight
        kp, kn = jax.random.split(keys[s])
        gpos = nonideal.apply_variation(dig_p * cfg.g0, kp, sigma_g)
        gneg = nonideal.apply_variation(dig_n * cfg.g0, kn, sigma_g)
        pairs.append((CrossbarPair(gpos, gneg, scale, cfg.g0), weight))
    return pairs


def amc_mvm_sliced(pairs, v_in: jnp.ndarray, cfg: AnalogConfig) -> jnp.ndarray:
    """MVM over bit-sliced arrays; digital shift-add recombination."""
    out = None
    for pair, weight in pairs:
        part = amc_mvm(pair, v_in, cfg) * weight
        out = part if out is None else out + part
    return out


# ---------------------------------------------------------------------------
# Partitioned MVM for blocks larger than one physical array (refs [13]-[15])
# ---------------------------------------------------------------------------

def map_tiled(a: jnp.ndarray, key: jax.Array, cfg: AnalogConfig,
              scale: jnp.ndarray):
    """Map an (R x C) matrix onto a grid of <= array_size tiles.

    Returns a list-of-lists of CrossbarPair (static tiling - sizes are
    Python ints, so this unrolls at trace time as real hardware would be
    physically laid out).  R and C need not be multiples of the array size.
    """
    s = cfg.array_size
    rows, cols = a.shape
    r_tiles = -(-rows // s)
    c_tiles = -(-cols // s)
    keys = jax.random.split(key, r_tiles * c_tiles)
    grid = []
    for ri in range(r_tiles):
        row = []
        for ci in range(c_tiles):
            blk = a[ri * s:min((ri + 1) * s, rows), ci * s:min((ci + 1) * s, cols)]
            row.append(map_matrix(blk, keys[ri * c_tiles + ci], cfg, scale))
        grid.append(row)
    return grid


def amc_mvm_tiled(grid, v_in: jnp.ndarray, cfg: AnalogConfig) -> jnp.ndarray:
    """Partitioned MVM: partial products per tile column, summed per tile row.

    Analog partial sums: each tile's TIA output currents are summed along the
    tile row (current summing is free in analog), so the sign convention is
    identical to a single amc_mvm.
    """
    out_rows = []
    for row in grid:
        col_off = 0
        acc = None
        load = cfg.g0
        for pair in row:
            c = pair.shape[1]
            part = -(pair.a_eff(cfg) @ v_in[col_off:col_off + c])
            acc = part if acc is None else acc + part
            load = load + jnp.sum(pair.gpos + pair.gneg, axis=1)
            col_off += c
        if cfg.opa_gain is not None:
            # The tiles of one tile-row share the row TIAs (analog current
            # summing), so the summing-node load is the whole tile-row's.
            acc = acc / (1.0 + _per_row(load, acc) / (cfg.opa_gain * cfg.g0))
        out_rows.append(acc)
    return jnp.concatenate(out_rows)


# ---------------------------------------------------------------------------
# Stacked-tile form (shared by the flat BlockAMC executor and the
# distributed solver; formerly private to core/distributed.py)
# ---------------------------------------------------------------------------

@jax.tree_util.register_pytree_node_class
class TileGrid:
    """A stacked differential crossbar tile tensor: (..., rows, cols).

    The leading axes are arbitrary batch/tile axes - (rt, ct, s, s) for the
    distributed solver's 2-D tile layout, (num_tiles, r, c) for the flat
    executor's shape buckets, possibly with an extra Monte-Carlo axis in
    front under vmap.  The trailing two axes are one physical array.
    """

    def __init__(self, gpos, gneg, scale, g0):
        self.gpos = gpos
        self.gneg = gneg
        self.scale = scale
        self.g0 = g0

    def tree_flatten(self):
        return (self.gpos, self.gneg, self.scale), (self.g0,)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children, aux[0])

    @property
    def shape(self):
        return self.gpos.shape

    def a_eff(self, cfg: AnalogConfig, r_wire=None, drift_t=None) -> jnp.ndarray:
        # same readout pipeline as CrossbarPair.a_eff (drift, then wire
        # model, with the same traced r_wire / drift_t overrides);
        # nonideal.wire_readout maps over the leading tile axes, and a
        # (num,)-shaped drift_t ages each tile of the stack independently
        ni = cfg.nonideal
        gp = nonideal.wire_readout(
            nonideal.readout_conductance(self.gpos, ni, drift_t=drift_t),
            ni, r_wire=r_wire)
        gn = nonideal.wire_readout(
            nonideal.readout_conductance(self.gneg, ni, drift_t=drift_t),
            ni, r_wire=r_wire)
        return (gp - gn) / self.g0

    def pair(self, idx) -> CrossbarPair:
        """View one tile of the stack as a CrossbarPair (static index)."""
        return CrossbarPair(self.gpos[idx], self.gneg[idx], self.scale, self.g0)


def stack_pairs(pairs, scale, g0) -> TileGrid:
    """Stack same-shape CrossbarPairs into a (num, r, c) TileGrid."""
    return TileGrid(jnp.stack([p.gpos for p in pairs]),
                    jnp.stack([p.gneg for p in pairs]), scale, g0)


def map_tiled_vec(a: jnp.ndarray, key: jax.Array, cfg: AnalogConfig,
                  scale: jnp.ndarray) -> TileGrid:
    """Map an (R x C) matrix onto an (rt, ct, s, s) tile tensor.

    R and C must be multiples of cfg.array_size (the vectorised path keeps
    power-of-two sizes; `map_tiled` handles ragged shapes).
    """
    s = cfg.array_size
    rows, cols = a.shape
    assert rows % s == 0 and cols % s == 0, (rows, cols, s)
    rt, ct = rows // s, cols // s
    tiles = a.reshape(rt, s, ct, s).transpose(0, 2, 1, 3)  # (rt, ct, s, s)
    a_norm = tiles * scale
    gpos_t = jnp.maximum(a_norm, 0.0) * cfg.g0
    gneg_t = jnp.maximum(-a_norm, 0.0) * cfg.g0
    kp, kn = jax.random.split(key)
    # shared programming pipeline (this path previously skipped write-verify;
    # it now honours compensate_wire like map_matrix does)
    gpos = nonideal.program_conductances(gpos_t, kp, cfg.nonideal, cfg.g0)
    gneg = nonideal.program_conductances(gneg_t, kn, cfg.nonideal, cfg.g0)
    return TileGrid(gpos, gneg, scale, cfg.g0)

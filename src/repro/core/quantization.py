"""The uniform converter quantiser - single source of truth.

Every DAC/ADC in the repo models the same converter: a uniform mid-rise
quantiser over [-fullscale, +fullscale] with clipping (paper Fig. 3-4
include 8-bit-class converters).  The circuit model (core/analog.py), the
Pallas kernel (kernels/crossbar_mvm.py - the function is traced inside the
kernel body, so it must stay pure jnp) and the jnp oracles (kernels/ref.py)
all import this one definition; a parity test pins them together.

Autodiff: the rounding step is piecewise constant (zero gradient almost
everywhere), which would silently kill every gradient that crosses a
converter.  `quantize` therefore carries a straight-through estimator
(TESTING.md "differentiable solver contract"): the JVP passes the tangent
through unchanged inside the converter's full-scale range and zeroes it in
the clipped region - the gradient of the clip, with the rounding treated as
identity.  The primal value is bit-identical to the plain computation.
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp


@partial(jax.custom_jvp, nondiff_argnums=(1, 2))
def _quantize_ste(v: jnp.ndarray, bits: int, fullscale: float) -> jnp.ndarray:
    levels = 2 ** bits - 1
    step = 2.0 * fullscale / levels
    v = jnp.clip(v, -fullscale, fullscale)
    return jnp.round(v / step) * step


@_quantize_ste.defjvp
def _quantize_ste_jvp(bits, fullscale, primals, tangents):
    (v,), (dv,) = primals, tangents
    out = _quantize_ste(v, bits, fullscale)
    # straight-through: d(round(clip(v)))/dv ~ d(clip(v))/dv
    inside = (jnp.abs(v) <= fullscale).astype(dv.dtype)
    return out, dv * inside


def quantize(v: jnp.ndarray, bits: Optional[int],
             fullscale: float) -> jnp.ndarray:
    """Uniform mid-rise quantiser over [-fullscale, +fullscale]; clips.

    bits=None models an ideal converter (identity).  Differentiable via a
    straight-through estimator (see module docstring).
    """
    if bits is None:
        return v
    return _quantize_ste(v, bits, fullscale)

"""The uniform converter quantiser - single source of truth.

Every DAC/ADC in the repo models the same converter: a uniform mid-rise
quantiser over [-fullscale, +fullscale] with clipping (paper Fig. 3-4
include 8-bit-class converters).  The circuit model (core/analog.py), the
Pallas kernel (kernels/crossbar_mvm.py - the function is traced inside the
kernel body, so it must stay pure jnp) and the jnp oracles (kernels/ref.py)
all import this one definition; a parity test pins them together.
"""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp


def quantize(v: jnp.ndarray, bits: Optional[int],
             fullscale: float) -> jnp.ndarray:
    """Uniform mid-rise quantiser over [-fullscale, +fullscale]; clips.

    bits=None models an ideal converter (identity).
    """
    if bits is None:
        return v
    levels = 2 ** bits - 1
    step = 2.0 * fullscale / levels
    v = jnp.clip(v, -fullscale, fullscale)
    return jnp.round(v / step) * step

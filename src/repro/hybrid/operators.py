"""Linear-operator adapters bridging the analog solver into digital Krylov.

Layout contract of the Krylov drivers (`repro.hybrid.krylov`): right-hand
sides ride on *leading* axes - a vector is `(n,)`, a multi-RHS batch is
`(..., n)` - and an operator is any callable mapping `(..., n) -> (..., n)`
over the trailing axis.  This module provides the two operators the hybrid
loop needs:

  * `matvec_from_dense(a)` - the digital matrix-vector product `v -> A v`
    in the drivers' layout (the exact, full-precision residual operator).
  * `AnalogPreconditioner` - one programmed BlockAMC cascade (a
    `FinalizedPlan`: noisy conductances, wire model, finite gain and
    quantisers all folded in) applied as `M ~ A^-1`.  It is a registered
    pytree, so it passes through jit/vmap/shard_map as an argument, and it
    is *mixed precision*: inputs are cast down to the plan's compute dtype
    (the analog substrate), outputs cast back up to the caller's dtype
    (the digital iteration) - the Le Gallo et al. mixed-precision IMC
    split.
"""
from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.core import blockamc
from repro.core.analog import AnalogConfig
from repro.core.blockamc import FinalizedPlan


def matvec_from_dense(a: jnp.ndarray) -> Callable[[jnp.ndarray], jnp.ndarray]:
    """`v -> A v` over the trailing axis of `v` ((..., n) -> (..., n))."""
    def matvec(v: jnp.ndarray) -> jnp.ndarray:
        # (A v)_i = sum_j A_ij v_j for every leading batch index.
        return v @ a.T

    return matvec


@jax.tree_util.register_pytree_node_class
class AnalogPreconditioner:
    """A programmed analog inverse as a batched digital-domain operator.

    Wraps one `FinalizedPlan` (program-once form of a BlockAMC cascade) and
    applies it to `(..., n)` inputs: one analog solve per trailing vector,
    all leading axes batched through the finalized executor's multi-RHS
    path.  Because the plan is finalized, every application is pure batched
    `lu_solve`s / stacked matmuls - the marginal-cost analog solve the
    paper's cost model promises, which is what makes it affordable *inside*
    a Krylov iteration.
    """

    def __init__(self, fin: FinalizedPlan):
        self.fin = fin

    def tree_flatten(self):
        return (self.fin,), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0])

    @classmethod
    def program(cls, a: jnp.ndarray, key: jax.Array, cfg: AnalogConfig,
                stages: Optional[int] = None) -> "AnalogPreconditioner":
        """Full programming flow: partition, Schur, map + noise, finalize."""
        fplan = blockamc.compile_plan(blockamc.build_plan(a, key, cfg, stages))
        return cls(blockamc.finalize(fplan, cfg))

    @classmethod
    def from_solver(cls, solver: "blockamc.ProgrammedSolver"
                    ) -> "AnalogPreconditioner":
        """Share an already-programmed `ProgrammedSolver`'s finalized plan."""
        return cls(solver.finalized)

    @property
    def n(self) -> int:
        return self.fin.n

    @property
    def cfg(self) -> AnalogConfig:
        return self.fin.cfg

    @property
    def compute_dtype(self):
        """The analog substrate's dtype (set when the plan was built)."""
        return self.fin.scale.dtype

    def __call__(self, v: jnp.ndarray) -> jnp.ndarray:
        """Apply M ~ A^-1 to (..., n); returns (..., n) in v's dtype."""
        n = self.fin.n
        if v.ndim == 1:
            out = blockamc.execute_finalized(self.fin,
                                             v.astype(self.compute_dtype))
            return out.astype(v.dtype)
        lead = v.shape[:-1]
        cols = v.reshape((-1, n)).T.astype(self.compute_dtype)  # (n, k)
        out = blockamc.execute_finalized(self.fin, cols)
        return out.T.reshape(lead + (n,)).astype(v.dtype)

    # LinearOperator-flavoured alias
    apply = __call__

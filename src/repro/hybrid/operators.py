"""Linear-operator adapters bridging the analog solver into digital Krylov.

Layout contract of the Krylov drivers (`repro.hybrid.krylov`): right-hand
sides ride on *leading* axes - a vector is `(n,)`, a multi-RHS batch is
`(..., n)` - and an operator is any callable mapping `(..., n) -> (..., n)`
over the trailing axis.  This module provides the two operators the hybrid
loop needs:

  * `matvec_from_dense(a)` - the digital matrix-vector product `v -> A v`
    in the drivers' layout (the exact, full-precision residual operator).
  * `AnalogPreconditioner` - one programmed BlockAMC cascade (a
    `FinalizedPlan`: noisy conductances, wire model, finite gain and
    quantisers all folded in) applied as `M ~ A^-1`.  It is a registered
    pytree, so it passes through jit/vmap/shard_map as an argument, and it
    is *mixed precision*: inputs are cast down to the plan's compute dtype
    (the analog substrate), outputs cast back up to the caller's dtype
    (the digital iteration) - the Le Gallo et al. mixed-precision IMC
    split.
"""
from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.core import blockamc
from repro.core.analog import AnalogConfig
from repro.core.blockamc import ArenaPlan, FinalizedPlan


def matvec_from_dense(a: jnp.ndarray) -> Callable[[jnp.ndarray], jnp.ndarray]:
    """`v -> A v` over the trailing axis of `v` ((..., n) -> (..., n))."""
    def matvec(v: jnp.ndarray) -> jnp.ndarray:
        # (A v)_i = sum_j A_ij v_j for every leading batch index.
        return v @ a.T

    return matvec


@jax.tree_util.register_pytree_node_class
class AnalogPreconditioner:
    """A programmed analog inverse as a batched digital-domain operator.

    Wraps one `FinalizedPlan` (program-once form of a BlockAMC cascade) and
    applies it to `(..., n)` inputs: one analog solve per trailing vector,
    all leading axes batched through the executor's multi-RHS path.
    Because the plan is finalized, every application is pure batched
    `lu_solve`s / stacked matmuls - the marginal-cost analog solve the
    paper's cost model promises, which is what makes it affordable *inside*
    a Krylov iteration.

    `mode` picks the executor for the inner-loop apply: "fused" (default)
    runs the arena-form single-dispatch executor (core/blockamc.py DESIGN
    note) - the serving fast path - and "reference" the finalized schedule
    it is float-tolerance-pinned against (TESTING.md four-way contract).

    Differentiability (TESTING.md "differentiable solver contract"): the
    apply is reverse-mode differentiable in both the input `v` and the
    plan's *array* leaves (effective-operator stacks, scale) - the fused
    path routes through the arena executor's implicit-diff `custom_vjp`,
    so the backward pass is one transposed cascade, never a re-programming.
    The pytree split is load-bearing for that: `tree_flatten` keeps every
    calibratable array in the children and only static metadata (`mode`,
    and the plans' hashable level/spec tuples inside their own flattening)
    in aux_data, so `jax.grad`/`jax.vmap` see exactly the differentiable
    leaves and jit caches never retrace on a re-programmed instance
    (pinned by the retrace-guard tests in tests/test_autodiff.py).
    """

    def __init__(self, fin: FinalizedPlan,
                 aplan: Optional[ArenaPlan] = None, mode: str = "fused"):
        self.fin = fin
        self.mode = mode
        if aplan is None and mode == "fused":
            aplan = blockamc.compile_arena(fin)
        self.aplan = aplan

    def tree_flatten(self):
        return (self.fin, self.aplan), (self.mode,)

    @classmethod
    def tree_unflatten(cls, aux, children):
        obj = cls.__new__(cls)
        obj.fin, obj.aplan = children
        obj.mode = aux[0]
        return obj

    @classmethod
    def program(cls, a: jnp.ndarray, key: jax.Array, cfg: AnalogConfig,
                stages: Optional[int] = None,
                mode: str = "fused") -> "AnalogPreconditioner":
        """Full programming flow: partition, Schur, map + noise, finalize."""
        fplan = blockamc.compile_plan(blockamc.build_plan(a, key, cfg, stages))
        return cls(blockamc.finalize(fplan, cfg), mode=mode)

    @classmethod
    def from_solver(cls, solver: "blockamc.ProgrammedSolver"
                    ) -> "AnalogPreconditioner":
        """Share an already-programmed `ProgrammedSolver`'s plans + mode."""
        aplan = solver.arena if solver.mode == "fused" else None
        return cls(solver.finalized, aplan=aplan, mode=solver.mode)

    @property
    def n(self) -> int:
        return self.fin.n

    @property
    def cfg(self) -> AnalogConfig:
        return self.fin.cfg

    @property
    def compute_dtype(self):
        """The analog substrate's dtype (set when the plan was built)."""
        return self.fin.scale.dtype

    def _execute(self, cols: jnp.ndarray) -> jnp.ndarray:
        """One executor dispatch on (n,) / (n, k) columns (mode-routed)."""
        if self.mode == "fused" and self.aplan is not None:
            return blockamc.execute_arena(self.aplan, cols)
        return blockamc.execute_finalized(self.fin, cols)

    def __call__(self, v: jnp.ndarray) -> jnp.ndarray:
        """Apply M ~ A^-1 to (..., n); returns (..., n) in v's dtype."""
        n = self.fin.n
        if v.ndim == 1:
            out = self._execute(v.astype(self.compute_dtype))
            return out.astype(v.dtype)
        lead = v.shape[:-1]
        cols = v.reshape((-1, n)).T.astype(self.compute_dtype)  # (n, k)
        out = self._execute(cols)
        return out.T.reshape(lead + (n,)).astype(v.dtype)

    # LinearOperator-flavoured alias
    apply = __call__

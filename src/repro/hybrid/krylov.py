"""Batched, jit/vmap-safe Krylov drivers over abstract linear operators.

Layout contract: right-hand sides ride on *leading* axes - `b` is `(n,)`
for one system or `(batch..., n)` for a multi-RHS batch - and operators
(`matvec`, `precond`) are callables mapping `(..., n) -> (..., n)` over the
trailing axis (see `repro.hybrid.operators`).  Everything is pure jnp over
fuel-bounded `lax.while_loop`s, so the drivers jit, vmap (e.g. over
Monte-Carlo noise keys of an analog preconditioner) and shard_map cleanly.

Per-RHS convergence masks: each right-hand side carries its own `active`
flag.  A converged column's state is frozen exactly (its step sizes are
masked to zero and its search direction held), so streaming one easy and
one hard system together costs the hard system nothing in accuracy and the
easy system nothing in extra updates - and the batched result for a column
matches a solo run of that column up to XLA's batched-matmul reduction
order (float tolerance; documented in TESTING.md).

Convergence is measured as ||b - A x|| <= tol * ||b|| per right-hand side.
`pcg`'s loop still *exits* on the cheap recurrence residual, but the
reported `resnorm`/`converged` recompute the true exit residual with one
extra matvec (`gmres` recomputes it every cycle anyway), so the report can
never over-state convergence when recurrence drift sets in on
ill-conditioned low-precision systems.  `iters` counts the iterations a
column was active: exact per-column counts for `pcg`; restart-cycle
granularity (multiples of `restart`) for `gmres`.  `pcg_fixed` is the
fixed-budget, reverse-mode-differentiable variant (lax.scan, no early
exit).
"""
from __future__ import annotations

from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

Operator = Callable[[jnp.ndarray], jnp.ndarray]


class KrylovResult(NamedTuple):
    """Per-RHS outcome of a batched Krylov solve (leading-axis layout)."""
    x: jnp.ndarray          # (..., n) solutions
    iters: jnp.ndarray      # (...,) int32 iterations while active
    resnorm: jnp.ndarray    # (...,) final relative residual ||b-Ax||/||b||
    converged: jnp.ndarray  # (...,) bool, reached tol within fuel


def _dot(u: jnp.ndarray, v: jnp.ndarray) -> jnp.ndarray:
    return jnp.sum(u * v, axis=-1)


def _identity(v: jnp.ndarray) -> jnp.ndarray:
    return v


class _CGState(NamedTuple):
    x: jnp.ndarray
    r: jnp.ndarray
    p: jnp.ndarray
    rz: jnp.ndarray
    r2: jnp.ndarray
    k: jnp.ndarray
    iters: jnp.ndarray
    active: jnp.ndarray


def pcg(matvec: Operator, b: jnp.ndarray, *, precond: Optional[Operator] = None,
        x0: Optional[jnp.ndarray] = None, tol: float = 1e-10,
        maxiter: int = 1000) -> KrylovResult:
    """Batched preconditioned conjugate gradients (A SPD).

    `matvec`/`precond` map `(..., n) -> (..., n)`; `b` is `(n,)` or
    `(batch..., n)`.  The preconditioner must be (an approximation of) an
    SPD inverse - e.g. `AnalogPreconditioner` over an SPD system.  Columns
    whose residual is already below tol (including b == 0) never update.
    """
    mv_m = precond if precond is not None else _identity
    x0 = jnp.zeros_like(b) if x0 is None else x0
    tiny = jnp.asarray(jnp.finfo(b.dtype).tiny, b.dtype)
    stop2 = (tol ** 2) * _dot(b, b)

    r0 = b - matvec(x0)
    z0 = mv_m(r0)
    r2_0 = _dot(r0, r0)
    active0 = r2_0 > stop2
    init = _CGState(x=x0, r=r0, p=z0, rz=_dot(r0, z0), r2=r2_0,
                    k=jnp.int32(0),
                    iters=jnp.zeros(r2_0.shape, jnp.int32), active=active0)

    def cond(s: _CGState):
        return jnp.any(s.active) & (s.k < maxiter)

    def body(s: _CGState) -> _CGState:
        ap = matvec(s.p)
        pap = _dot(s.p, ap)
        alpha = jnp.where(s.active, s.rz / (pap + tiny), 0.0)
        x = s.x + alpha[..., None] * s.p
        r = s.r - alpha[..., None] * ap
        z = mv_m(r)
        rz_new = _dot(r, z)
        beta = jnp.where(s.active, rz_new / (s.rz + tiny), 0.0)
        # frozen columns keep their direction bit-identical (beta is 0 but
        # z still differs; the where keeps their whole state untouched)
        p = jnp.where(s.active[..., None], z + beta[..., None] * s.p, s.p)
        r2 = _dot(r, r)
        return _CGState(x=x, r=r, p=p,
                        rz=jnp.where(s.active, rz_new, s.rz),
                        r2=r2, k=s.k + 1,
                        iters=s.iters + s.active.astype(jnp.int32),
                        active=s.active & (r2 > stop2))

    s = jax.lax.while_loop(cond, body, init)
    # Truth in reporting (module contract: ||b - A x|| <= tol * ||b||).
    # The loop exits on the *recurrence* residual, which drifts from the
    # true residual on ill-conditioned systems (classically O(eps * iters)
    # relative; catastrophic at f32 x cond ~ 1e6, where the recurrence
    # keeps shrinking long after the true residual has stagnated).  One
    # extra matvec at exit recomputes the exit residual, so `resnorm` /
    # `converged` can never over-report convergence.
    b2 = _dot(b, b)
    r_true = b - matvec(s.x)
    rt2 = _dot(r_true, r_true)
    resnorm = jnp.sqrt(rt2) / jnp.sqrt(jnp.where(b2 > 0, b2, 1.0))
    return KrylovResult(x=s.x, iters=s.iters, resnorm=resnorm,
                        converged=rt2 <= stop2)


def pcg_fixed(matvec: Operator, b: jnp.ndarray, *,
              precond: Optional[Operator] = None,
              x0: Optional[jnp.ndarray] = None, iters: int = 10,
              tol: float = 0.0) -> KrylovResult:
    """Fixed-budget batched PCG: exactly `iters` steps via `lax.scan`.

    The reverse-mode-differentiable sibling of `pcg(tol=0.0,
    maxiter=iters)`: `lax.while_loop` is not reverse-differentiable, so
    gradient-based loops with a fixed digital refinement budget (e.g.
    `optim.blockamc_precond`'s analog inverse) use this driver.  No early
    exit and no per-column active masks - every column takes every step
    (a zero right-hand side is a fixed point of the update, so it still
    returns zero).  Reporting matches `pcg`: one true matvec at exit,
    `converged` against `tol`.
    """
    mv_m = precond if precond is not None else _identity
    x0 = jnp.zeros_like(b) if x0 is None else x0
    tiny = jnp.asarray(jnp.finfo(b.dtype).tiny, b.dtype)
    r0 = b - matvec(x0)
    z0 = mv_m(r0)

    def step(carry, _):
        x, r, p, rz = carry
        ap = matvec(p)
        alpha = rz / (_dot(p, ap) + tiny)
        x = x + alpha[..., None] * p
        r = r - alpha[..., None] * ap
        z = mv_m(r)
        rz_new = _dot(r, z)
        beta = rz_new / (rz + tiny)
        p = z + beta[..., None] * p
        return (x, r, p, rz_new), None

    (x, _, _, _), _ = jax.lax.scan(step, (x0, r0, z0, _dot(r0, z0)), None,
                                   length=int(iters))
    b2 = _dot(b, b)
    r_true = b - matvec(x)
    rt2 = _dot(r_true, r_true)
    resnorm = jnp.sqrt(rt2) / jnp.sqrt(jnp.where(b2 > 0, b2, 1.0))
    return KrylovResult(x=x, iters=jnp.full(rt2.shape, int(iters), jnp.int32),
                        resnorm=resnorm, converged=rt2 <= (tol ** 2) * b2)


class _GmresState(NamedTuple):
    x: jnp.ndarray
    r2: jnp.ndarray
    k: jnp.ndarray
    iters: jnp.ndarray
    active: jnp.ndarray


def gmres(matvec: Operator, b: jnp.ndarray, *,
          precond: Optional[Operator] = None,
          x0: Optional[jnp.ndarray] = None, tol: float = 1e-10,
          restart: int = 32, maxiter: int = 1000) -> KrylovResult:
    """Batched restarted GMRES(m) with right preconditioning (A square).

    Solves `A M u = b, x = M u`: right preconditioning keeps the monitored
    residual the *true* residual, so a noisy analog `M` changes only the
    convergence rate, never the solution.  One cycle = `restart` Arnoldi
    steps (twice-iterated classical Gram-Schmidt, batched over all leading
    axes) followed by a batched QR least-squares update.  A cycle's update
    is accepted per column only if it does not increase the residual
    (restarted GMRES is monotone in exact arithmetic; the guard makes
    happy-breakdown garbage inert), and columns that converge or stagnate
    are masked out of further updates.
    """
    mv_m = precond if precond is not None else _identity
    x0 = jnp.zeros_like(b) if x0 is None else x0
    # honour the fuel bound exactly: a cycle never exceeds maxiter inner
    # steps, and whole cycles are fitted under maxiter (round down, >= 1)
    m = min(int(restart), int(maxiter))
    n = b.shape[-1]
    batch = b.shape[:-1]
    dtype = b.dtype
    tiny = jnp.asarray(jnp.finfo(dtype).tiny, dtype)
    n_cycles = max(int(maxiter) // m, 1)
    b2 = _dot(b, b)
    stop2 = (tol ** 2) * b2

    def op(v):
        return matvec(mv_m(v))

    def cycle(x):
        """One GMRES(m) cycle from x; returns the candidate update."""
        r = b - matvec(x)
        beta = jnp.sqrt(_dot(r, r))
        v_basis = jnp.zeros(batch + (m + 1, n), dtype)
        v_basis = v_basis.at[..., 0, :].set(r / (beta + tiny)[..., None])
        h_mat = jnp.zeros(batch + (m + 1, m), dtype)

        def arnoldi(j, carry):
            v_b, h_m = carry
            w = op(v_b[..., j, :])
            mask = (jnp.arange(m + 1) <= j).astype(dtype)
            # CGS2: two passes of classical Gram-Schmidt (batched; the
            # second pass restores orthogonality CGS1 loses)
            h1 = jnp.einsum("...in,...n->...i", v_b, w) * mask
            w = w - jnp.einsum("...i,...in->...n", h1, v_b)
            h2 = jnp.einsum("...in,...n->...i", v_b, w) * mask
            w = w - jnp.einsum("...i,...in->...n", h2, v_b)
            hcol = h1 + h2
            wnorm = jnp.sqrt(_dot(w, w))
            hcol = hcol.at[..., j + 1].set(wnorm)
            v_b = v_b.at[..., j + 1, :].set(w / (wnorm + tiny)[..., None])
            h_m = h_m.at[..., :, j].set(hcol)
            return v_b, h_m

        v_basis, h_mat = jax.lax.fori_loop(0, m, arnoldi, (v_basis, h_mat))
        # least squares  min_y || beta e1 - H y ||  via batched reduced QR
        e1 = jnp.zeros(batch + (m + 1,), dtype).at[..., 0].set(beta)
        q_f, r_f = jnp.linalg.qr(h_mat)
        rhs = jnp.einsum("...ij,...i->...j", q_f, e1)
        # guard exactly-singular R (happy breakdown); the acceptance test
        # below discards any garbage this lets through
        diag = jnp.diagonal(r_f, axis1=-2, axis2=-1)
        r_f = r_f + (jnp.abs(diag) < tiny)[..., None] * jnp.eye(m, dtype=dtype)
        y = jax.scipy.linalg.solve_triangular(r_f, rhs, lower=False)
        dx = jnp.einsum("...j,...jn->...n", y, v_basis[..., :m, :])
        return x + mv_m(dx)

    r0 = b - matvec(x0)
    r2_0 = _dot(r0, r0)
    init = _GmresState(x=x0, r2=r2_0, k=jnp.int32(0),
                       iters=jnp.zeros(r2_0.shape, jnp.int32),
                       active=r2_0 > stop2)

    def cond(s: _GmresState):
        return jnp.any(s.active) & (s.k < n_cycles)

    def body(s: _GmresState) -> _GmresState:
        x_new = cycle(s.x)
        r_new = b - matvec(x_new)
        r2_new = _dot(r_new, r_new)
        take = s.active & (r2_new <= s.r2)
        x = jnp.where(take[..., None], x_new, s.x)
        r2 = jnp.where(take, r2_new, s.r2)
        # stagnated columns (no residual decrease) stop burning cycles
        progressed = take & (r2_new < s.r2)
        return _GmresState(x=x, r2=r2, k=s.k + 1,
                           iters=s.iters + s.active.astype(jnp.int32) * m,
                           active=progressed & (r2 > stop2))

    s = jax.lax.while_loop(cond, body, init)
    # s.r2 is already a TRUE residual: every cycle recomputes
    # r_new = b - matvec(x_new) and the monotone guard keeps (x, r2)
    # paired, so the exit report is exact at restart-cycle granularity
    # (pinned by the truth-in-reporting regression tests alongside pcg's
    # recomputed exit residual).
    resnorm = jnp.sqrt(s.r2) / jnp.sqrt(jnp.where(b2 > 0, b2, 1.0))
    return KrylovResult(x=s.x, iters=s.iters, resnorm=resnorm,
                        converged=s.r2 <= stop2)

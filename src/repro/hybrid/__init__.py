"""Hybrid analog-digital solving subsystem (paper Section IV).

The paper positions the AMC output as "a seed solution (or equivalently a
preconditioner) for digital computers, to speed up the convergence of
iterative algorithms".  This package is that loop made production-shaped
(cf. Le Gallo et al., mixed-precision in-memory computing; Shah et al.,
hybrid digital-analog approximate-inverse preconditioning):

  * `operators`  - LinearOperator-style adapters: `AnalogPreconditioner`
    wraps a finalized BlockAMC plan (noisy, wire-modeled analog inverse)
    as a batched digital-domain operator; `matvec_from_dense` adapts a
    dense matrix to the drivers' leading-axis layout.
  * `krylov`     - fully batched, jit/vmap-safe `pcg` and restarted
    `gmres(m)` drivers: multi-RHS on leading axes, fuel-bounded
    `lax.while_loop`s, per-RHS convergence masks.
  * `refine`     - the fused analog-seed -> Krylov-refine path
    (`solve_refined`) plus its Monte-Carlo batched and mesh-sharded forms,
    and `solve_fallback`, the digital-only degraded serving mode (no
    analog seed/preconditioner - safe whatever state the device is in).
  * `classic`    - the original fixed-iteration refinement helpers
    (`richardson_refine`, `cg_refine`, `iterations_to_tol`), kept for the
    paper-figure benchmarks; `repro.core.hybrid` re-exports everything
    here for backwards compatibility.
"""
from repro.hybrid.classic import (  # noqa: F401
    cg_refine, iterations_to_tol, richardson_refine)
from repro.hybrid.krylov import (  # noqa: F401
    KrylovResult, gmres, pcg, pcg_fixed)
from repro.hybrid.operators import (  # noqa: F401
    AnalogPreconditioner, matvec_from_dense)
from repro.hybrid.refine import (  # noqa: F401
    solve_fallback, solve_refined, solve_refined_batched,
    solve_refined_batched_sharded)

"""Classic fixed-iteration refinement from an analog seed.

The original `core/hybrid.py` helpers: Richardson / CG iterations started
from the analog seed, and `iterations_to_tol` - how many digital iterations
the seed saves.  The batched production drivers live in
`repro.hybrid.krylov`; these stay as the single-RHS reference used by the
paper-figure benchmarks and as the simplest statement of the scheme.

All functions are jit/vmap-friendly (lax.while_loop with a fuel bound).
"""
from __future__ import annotations

from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp


def _residual_norm(a, b, x):
    return jnp.linalg.norm(b - a @ x) / jnp.linalg.norm(b)


@partial(jax.jit, static_argnames=("iters",))
def richardson_refine(a: jnp.ndarray, b: jnp.ndarray, x0: jnp.ndarray,
                      iters: int, omega: float | None = None) -> jnp.ndarray:
    """x_{k+1} = x_k + omega (b - A x_k); omega defaults to 1/||A||_inf."""
    if omega is None:
        omega_v = 1.0 / jnp.max(jnp.sum(jnp.abs(a), axis=1))
    else:
        omega_v = jnp.asarray(omega, a.dtype)

    def body(x, _):
        return x + omega_v * (b - a @ x), None

    x, _ = jax.lax.scan(body, x0, None, length=iters)
    return x


@partial(jax.jit, static_argnames=("iters",))
def cg_refine(a: jnp.ndarray, b: jnp.ndarray, x0: jnp.ndarray,
              iters: int) -> jnp.ndarray:
    """Conjugate gradients from seed x0 (A SPD; Wishart qualifies)."""
    r0 = b - a @ x0

    def body(carry, _):
        x, r, p, rs = carry
        ap = a @ p
        alpha = rs / (p @ ap + 1e-30)
        x = x + alpha * p
        r = r - alpha * ap
        rs_new = r @ r
        beta = rs_new / (rs + 1e-30)
        p = r + beta * p
        return (x, r, p, rs_new), None

    init = (x0, r0, r0, r0 @ r0)
    (x, _, _, _), _ = jax.lax.scan(body, init, None, length=iters)
    return x


@partial(jax.jit, static_argnames=("method", "max_iters"))
def iterations_to_tol(a: jnp.ndarray, b: jnp.ndarray, x0: jnp.ndarray,
                      tol: float = 1e-6, method: str = "cg",
                      max_iters: int = 2000) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Run the iteration until ||b - Ax||/||b|| < tol; return (x, n_iters).

    Fuel-bounded while_loop (jit-safe).  n_iters == max_iters means no
    convergence within fuel.
    """
    b_norm = jnp.linalg.norm(b)

    if method == "richardson":
        omega_v = 1.0 / jnp.max(jnp.sum(jnp.abs(a), axis=1))

        def step(state):
            x, _, k = state
            x = x + omega_v * (b - a @ x)
            return x, jnp.linalg.norm(b - a @ x) / b_norm, k + 1

        def cond(state):
            _, res, k = state
            return (res >= tol) & (k < max_iters)

        x, _, k = jax.lax.while_loop(
            cond, lambda s: step(s), (x0, _residual_norm(a, b, x0), jnp.int32(0)))
        return x, k

    # CG with explicit state
    def cond(state):
        _, r, _, _, k = state
        return (jnp.linalg.norm(r) / b_norm >= tol) & (k < max_iters)

    def step(state):
        x, r, p, rs, k = state
        ap = a @ p
        alpha = rs / (p @ ap + 1e-30)
        x = x + alpha * p
        r = r - alpha * ap
        rs_new = r @ r
        p = r + (rs_new / (rs + 1e-30)) * p
        return x, r, p, rs_new, k + 1

    r0 = b - a @ x0
    x, _, _, _, k = jax.lax.while_loop(
        cond, step, (x0, r0, r0, r0 @ r0, jnp.int32(0)))
    return x, k

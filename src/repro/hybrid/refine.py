"""The fused analog-seed -> Krylov-refine path, batched and sharded.

`solve_refined` is the end-to-end hybrid solve the paper's Section IV
sketches: one programmed BlockAMC cascade supplies both the *seed*
(`x0 = M b`, one analog solve) and, optionally, the *preconditioner* for a
digital Krylov iteration that polishes the seed to full digital precision.
Right-hand sides use the solver-service layout (`(n,)` or `(n, k)`
columns); internally they ride the Krylov drivers' leading axis.

Regime note (recorded by the differential tests and the hybrid benchmark):
with device noise sigma and condition number kappa, the preconditioned
operator's spectrum is perturbed by O(kappa * sigma * sqrt(n)); when that
product is large the noisy analog inverse can leave the SPD cone and PCG
stalls.  `use_precond=False` then falls back to seed-only refinement -
plain CG/GMRES from the analog seed - which always converges on the
digital side and still banks the seed's head start.

`solve_refined_batched` vmaps the whole path (per-key programming included)
over Monte-Carlo noise keys with the key-independent digital pre-processing
hoisted, exactly like `blockamc.solve_batched`; `solve_refined_batched_
sharded` shards that key axis over a device mesh via shard_map.
"""
from __future__ import annotations

from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import blockamc
from repro.core.analog import AnalogConfig
from repro.core.blockamc import PartitionedSystem
from repro.hybrid.krylov import KrylovResult, gmres, pcg
from repro.hybrid.operators import AnalogPreconditioner, matvec_from_dense


def _sanitize_seed(x0: jnp.ndarray) -> jnp.ndarray:
    """Per-column seed guard: a faulted crossbar emits non-finite analog
    seeds (stuck-at arrays can make the programmed inverse singular), and a
    single NaN in `x0` would poison the whole Krylov recurrence for that
    column.  Any column with a non-finite entry degrades to the zero seed -
    the digital iteration then simply starts cold, instead of answering
    NaN (one poisoned tenant must not poison its own refinement, let alone
    a batch-mate's; regression-pinned in tests/test_autodiff.py)."""
    finite = jnp.all(jnp.isfinite(x0), axis=-1, keepdims=True)
    return jnp.where(finite, x0, jnp.zeros_like(x0))


def _refine_core(a: jnp.ndarray, bt: jnp.ndarray,
                 precond: AnalogPreconditioner, method: str, tol: float,
                 maxiter: int, restart: int,
                 use_precond: bool) -> KrylovResult:
    """Core driver on leading-axis right-hand sides bt: (..., n)."""
    matvec = matvec_from_dense(a)
    x0 = _sanitize_seed(precond(bt))       # the analog seed, one solve
    mv_m = precond if use_precond else None
    if method == "cg":
        return pcg(matvec, bt, precond=mv_m, x0=x0, tol=tol, maxiter=maxiter)
    if method == "gmres":
        return gmres(matvec, bt, precond=mv_m, x0=x0, tol=tol,
                     restart=restart, maxiter=maxiter)
    raise ValueError(f"unknown method {method!r} (want 'cg' or 'gmres')")


# --- implicit-function-theorem VJP around the refined solve ----------------
#
# The Krylov drivers iterate inside `lax.while_loop`, which JAX cannot
# reverse-differentiate - and unrolling hundreds of CG steps would be the
# wrong gradient anyway (noisy, memory-hungry).  At convergence the output
# satisfies A x = b independently of the iteration path, so the implicit
# function theorem gives the exact adjoint:
#
#     lambda = A^-T gx,   b_bar = lambda,   A_bar = -sum_cols lambda x^T,
#
# i.e. the backward pass is ONE more (digital, seed-less) solve against the
# transposed system with the same method and fuel.  Only `x` carries
# gradients: the diagnostic fields (iters/resnorm/converged) and the analog
# preconditioner's arrays are treated as non-differentiable constants (the
# preconditioner changes the path, never the fixed point).  Second-order
# differentiation is out of contract (TESTING.md "differentiable solver
# contract").

def _zero_ct(leaf):
    """A zero cotangent of `leaf`'s dtype (float0 for int/bool leaves, as
    custom_vjp requires for non-differentiable primal inputs)."""
    if jnp.issubdtype(jnp.asarray(leaf).dtype, jnp.inexact):
        return jnp.zeros_like(leaf)
    return np.zeros(jnp.shape(leaf), dtype=jax.dtypes.float0)


@partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7))
def _refine(a, bt, precond, method, tol, maxiter, restart, use_precond):
    return _refine_core(a, bt, precond, method, tol, maxiter, restart,
                        use_precond)


def _refine_fwd(a, bt, precond, method, tol, maxiter, restart, use_precond):
    res = _refine_core(a, bt, precond, method, tol, maxiter, restart,
                       use_precond)
    return res, (a, precond, res.x)


def _refine_bwd(method, tol, maxiter, restart, use_precond, saved, ct):
    a, precond, x = saved
    gx = ct.x                      # cotangents of the diagnostics are unused
    at = jnp.swapaxes(a, -1, -2)   # cg implies A SPD, but stay exact
    lam = _fallback(at, gx, method, tol, maxiter, restart).x
    n = a.shape[-1]
    a_bar = -(lam.reshape(-1, n).T @ x.reshape(-1, n)).astype(a.dtype)
    return (a_bar, lam.astype(gx.dtype),
            jax.tree_util.tree_map(_zero_ct, precond))


_refine.defvjp(_refine_fwd, _refine_bwd)


@partial(jax.jit, static_argnames=("method", "tol", "maxiter", "restart",
                                   "use_precond"))
def _solve_refined_jit(a, bt, precond, method, tol, maxiter, restart,
                       use_precond):
    return _refine(a, bt, precond, method, tol, maxiter, restart, use_precond)


def solve_refined(a: jnp.ndarray, b: jnp.ndarray,
                  precond: AnalogPreconditioner, *, method: str = "cg",
                  tol: float = 1e-10, maxiter: int = 400, restart: int = 32,
                  use_precond: bool = True,
                  jit: bool = True) -> Tuple[jnp.ndarray, KrylovResult]:
    """Hybrid solve of A x = b: analog seed + digital Krylov refinement.

    Args:
      a:       (n, n) digital system matrix (residuals run in a's dtype -
               pass float64 under x64 for tolerances beyond f32).
      b:       (n,) one rhs or (n, k) columns (solver-service layout).
      precond: programmed analog inverse (seed source; also the Krylov
               preconditioner unless use_precond=False).
      method:  "cg" (A SPD) or "gmres" (general A).
      jit:     False runs the drivers eagerly - the reference the jitted
               multi-RHS path is pinned to (TESTING.md).
    Returns:
      (x, result): x shaped like b; result per-RHS stats in the drivers'
      leading-axis layout.
    """
    single = b.ndim == 1
    bt = (b if single else b.T).astype(a.dtype)
    run = _solve_refined_jit if jit else _refine
    res = run(a, bt, precond, method, float(tol), int(maxiter), int(restart),
              bool(use_precond))
    return (res.x if single else res.x.T), res


# ---------------------------------------------------------------------------
# Degraded-mode digital fallback (no analog operator involved)
# ---------------------------------------------------------------------------

def _fallback(a: jnp.ndarray, bt: jnp.ndarray, method: str, tol: float,
              maxiter: int, restart: int) -> KrylovResult:
    """Digital-only Krylov solve from a zero seed on leading-axis rhs."""
    matvec = matvec_from_dense(a)
    if method == "cg":
        return pcg(matvec, bt, tol=tol, maxiter=maxiter)
    if method == "gmres":
        return gmres(matvec, bt, tol=tol, restart=restart, maxiter=maxiter)
    raise ValueError(f"unknown method {method!r} (want 'cg' or 'gmres')")


@partial(jax.jit, static_argnames=("method", "tol", "maxiter", "restart"))
def _solve_fallback_jit(a, bt, method, tol, maxiter, restart):
    return _fallback(a, bt, method, tol, maxiter, restart)


def solve_fallback(a: jnp.ndarray, b: jnp.ndarray, *, method: str = "cg",
                   tol: float = 1e-8, maxiter: int = 800, restart: int = 32,
                   jit: bool = True) -> Tuple[jnp.ndarray, KrylovResult]:
    """Fully digital solve of A x = b: the degraded serving mode.

    The bottom rung of the quarantine -> re-program -> degrade ladder
    (TESTING.md "serving robustness contract"): when the analog substrate
    cannot be restored to health, the engine keeps answering from the
    stored digital matrix alone.  Unlike `solve_refined` this takes *no*
    analog seed and *no* analog preconditioner - a faulted crossbar can
    produce non-finite seeds, which would poison the Krylov recurrence -
    so it is correct whatever state the device is in, just slower (plain
    CG/GMRES from zero; the mixed-precision IMC papers' pure-digital
    baseline).  Same layout contract as `solve_refined`: b is `(n,)` or
    `(n, k)` columns, x comes back shaped like b.
    """
    single = b.ndim == 1
    bt = (b if single else b.T).astype(a.dtype)
    run = _solve_fallback_jit if jit else _fallback
    res = run(a, bt, method, float(tol), int(maxiter), int(restart))
    return (res.x if single else res.x.T), res


# ---------------------------------------------------------------------------
# Monte-Carlo batched / sharded refinement
# ---------------------------------------------------------------------------

def _refined_mc(a: jnp.ndarray, parts: PartitionedSystem, bt: jnp.ndarray,
                keys: jax.Array, cfg: AnalogConfig, method: str, tol: float,
                maxiter: int, restart: int, use_precond: bool,
                mode: str = "fused"):
    """Program + finalize + refine per noise key, vmapped over keys."""

    def one(k):
        fplan = blockamc.compile_plan(blockamc.program_system(parts, k, cfg))
        precond = AnalogPreconditioner(blockamc.finalize(fplan, cfg),
                                       mode=mode)
        return _refine(a, bt, precond, method, tol, maxiter, restart,
                       use_precond)

    return jax.vmap(one)(keys)    # KrylovResult with a leading key axis


@partial(jax.jit, static_argnames=("cfg", "method", "tol", "maxiter",
                                   "restart", "use_precond", "mode"))
def _refined_mc_jit(a, parts, bt, keys, cfg, method, tol, maxiter, restart,
                    use_precond, mode):
    return _refined_mc(a, parts, bt, keys, cfg, method, tol, maxiter,
                       restart, use_precond, mode)


def solve_refined_batched(a: jnp.ndarray, b: jnp.ndarray, keys: jax.Array,
                          cfg: AnalogConfig, *, stages: Optional[int] = None,
                          method: str = "cg", tol: float = 1e-10,
                          maxiter: int = 400, restart: int = 32,
                          use_precond: bool = True,
                          mode: str = "fused") -> KrylovResult:
    """Monte-Carlo hybrid solve: one refined solve per noise key, one jit.

    Every key programs its own noisy preconditioner (key-independent digital
    pre-processing hoisted via `partition_system`) and refines the same
    right-hand sides.  Returns a KrylovResult with a leading (num_keys, ...)
    axis on every field; `b` may be (n,) or (n, k) (x comes back as
    (num_keys, n) / (num_keys, k, n)).  `mode` picks the seed/
    preconditioner executor ("fused" arena default / "reference").
    """
    parts = blockamc.partition_system(a, cfg, stages)
    bt = (b if b.ndim == 1 else b.T).astype(a.dtype)
    return _refined_mc_jit(a, parts, bt, keys, cfg, method, float(tol),
                           int(maxiter), int(restart), bool(use_precond),
                           mode)


@partial(jax.jit, static_argnames=("cfg", "method", "tol", "maxiter",
                                   "restart", "use_precond", "mesh",
                                   "axis_name", "mode"))
def _refined_mc_sharded(a, parts, bt, keys, cfg, method, tol, maxiter,
                        restart, use_precond, mesh, axis_name, mode):
    from jax.experimental.shard_map import shard_map

    from repro.sharding.partition import mc_refined_specs

    in_specs, out_specs = mc_refined_specs(axis_name)
    mapped = shard_map(
        lambda aa, pp, bb, kk: _refined_mc(aa, pp, bb, kk, cfg, method, tol,
                                           maxiter, restart, use_precond,
                                           mode),
        mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_rep=False)
    return mapped(a, parts, bt, keys)


def solve_refined_batched_sharded(a: jnp.ndarray, b: jnp.ndarray,
                                  keys: jax.Array, cfg: AnalogConfig, *,
                                  stages: Optional[int] = None,
                                  method: str = "cg", tol: float = 1e-10,
                                  maxiter: int = 400, restart: int = 32,
                                  use_precond: bool = True, mesh=None,
                                  axis_name: str = "mc",
                                  mode: str = "fused") -> KrylovResult:
    """`solve_refined_batched` with the noise-key axis sharded over a mesh.

    Each device programs and refines its own shard of noisy preconditioners;
    the system matrix, partitioned pre-processing and right-hand sides are
    replicated (same composition as `blockamc.solve_batched_sharded`).
    num_keys must divide evenly over the mesh axis.
    """
    if mesh is None:
        from repro.launch.mesh import make_mc_mesh
        mesh = make_mc_mesh(axis_name=axis_name)
    n_shards = mesh.shape[axis_name]
    if keys.shape[0] % n_shards:
        raise ValueError(
            f"num_keys={keys.shape[0]} must divide over the "
            f"{axis_name!r} mesh axis of size {n_shards}")
    parts = blockamc.partition_system(a, cfg, stages)
    bt = (b if b.ndim == 1 else b.T).astype(a.dtype)
    return _refined_mc_sharded(a, parts, bt, keys, cfg, method, float(tol),
                               int(maxiter), int(restart), bool(use_precond),
                               mesh, axis_name, mode)

"""recurrentgemma-2b [hybrid]: RG-LRU + local attention, 1:2 pattern.
[arXiv:2402.19427; hf]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    n_layers=26,
    d_model=2560,
    n_heads=10,
    kv_heads=1,              # MQA
    head_dim=256,
    d_ff=7680,
    vocab=256000,
    layer_pattern=("rec", "rec", "attn"),   # 1 attn : 2 recurrent
    local_window=2048,
    lru_width=2560,
    logit_softcap=30.0,
    tie_embeddings=True,     # gemma-family weight tying
    subquadratic=True,       # RG-LRU state + windowed KV -> long_500k eligible
)

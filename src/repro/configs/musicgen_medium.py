"""musicgen-medium [audio]: decoder-only over EnCodec tokens (frontend stub).
[arXiv:2306.05284; hf]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-medium",
    family="audio",
    n_layers=48,
    d_model=1536,
    n_heads=24,
    kv_heads=24,             # MHA
    head_dim=64,
    d_ff=6144,
    vocab=2048,              # EnCodec codebook
    frontend="encodec_stub",
)

"""pixtral-12b [vlm]: pixtral-ViT frontend (stub) + mistral-nemo backbone.
[hf:mistralai/Pixtral-12B-2409; unverified]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="pixtral-12b",
    family="vlm",
    n_layers=40,
    d_model=5120,
    n_heads=32,
    kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab=131072,
    frontend="vit_stub",     # input_specs() supplies patch embeddings
)

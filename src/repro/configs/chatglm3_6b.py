"""chatglm3-6b [dense]: RoPE 2d (approximated as standard RoPE; DESIGN.md),
GQA kv=2.  [arXiv:2406.12793; hf]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="chatglm3-6b",
    family="dense",
    n_layers=28,
    d_model=4096,
    n_heads=32,
    kv_heads=2,
    head_dim=128,
    d_ff=13696,
    vocab=65024,
)

"""Architecture registry: --arch <id> resolution."""
from repro.configs import (
    chatglm3_6b, command_r_35b, glm4_9b, llama4_maverick_400b_a17b,
    mamba2_130m, musicgen_medium, phi35_moe_42b_a6_6b, pixtral_12b,
    recurrentgemma_2b, stablelm_1_6b)

ARCHS = {
    m.CONFIG.name: m.CONFIG for m in (
        recurrentgemma_2b, llama4_maverick_400b_a17b, phi35_moe_42b_a6_6b,
        pixtral_12b, glm4_9b, stablelm_1_6b, command_r_35b, chatglm3_6b,
        musicgen_medium, mamba2_130m)
}


def get_config(name: str):
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCHS)}")
    return ARCHS[name]

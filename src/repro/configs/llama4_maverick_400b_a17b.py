"""llama4-maverick-400b-a17b [moe]: 128-expert top-1 MoE, alternating
dense/MoE layers, shared expert.  [hf:meta-llama/Llama-4-*; unverified]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama4-maverick-400b-a17b",
    family="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab=202048,
    n_experts=128,
    top_k=1,
    moe_every=2,             # every other layer is MoE (llama4 interleave)
    shared_expert=True,
)

"""Model/run configuration dataclasses for the LM framework."""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Architecture description; one instance per assigned architecture."""
    name: str
    family: str                 # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 128
    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    moe_every: int = 1          # every k-th layer is MoE (llama4: 2)
    shared_expert: bool = False
    capacity_factor: float = 1.25
    # --- SSM (mamba2 SSD) ---
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_chunk: int = 256
    conv_kernel: int = 4
    # --- hybrid (recurrentgemma) ---
    local_window: int = 2048
    layer_pattern: Tuple[str, ...] = ()   # e.g. ("rec", "rec", "attn")
    lru_width: Optional[int] = None
    # --- positional / norm ---
    rope_theta: float = 10000.0
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    logit_softcap: Optional[float] = None
    # --- modality frontend (stub per brief) ---
    frontend: str = "none"      # none | vit_stub | encodec_stub
    # --- dtypes ---
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"
    # sub-quadratic? (drives long_500k eligibility)
    subquadratic: bool = False

    @property
    def q_per_kv(self) -> int:
        return self.n_heads // self.kv_heads

    def layer_kind(self, i: int) -> str:
        """'attn' | 'rec' | 'ssm' layer types; 'moe' vs 'dense' is separate."""
        if self.family == "ssm":
            return "ssm"
        if self.layer_pattern:
            return self.layer_pattern[i % len(self.layer_pattern)]
        return "attn"

    def is_moe_layer(self, i: int) -> bool:
        if self.n_experts == 0:
            return False
        return (i % self.moe_every) == (self.moe_every - 1)


@dataclasses.dataclass(frozen=True)
class RunConfig:
    """One (arch x shape) execution cell."""
    model: ModelConfig
    mode: str                   # train | prefill | decode
    seq_len: int
    global_batch: int
    microbatch: Optional[int] = None  # global microbatch size (grad accum)
    remat: str = "dots"         # none | dots | full
    fsdp: bool = False          # ZeRO-style param/optimizer sharding on data
    moments_dtype: str = "float32"
    accum_dtype: str = "float32"      # grad-accumulation buffer dtype
    seq_shard: bool = False           # Megatron-SP: residual S dim on "model"
    learning_rate: float = 3e-4
    grad_compression: bool = False   # int8 + error feedback across pods
    scan_layers: bool = True


# The four assigned input shapes (LM-family transformers).
SHAPES = {
    "train_4k": dict(mode="train", seq_len=4096, global_batch=256),
    "prefill_32k": dict(mode="prefill", seq_len=32768, global_batch=32),
    "decode_32k": dict(mode="decode", seq_len=32768, global_batch=128),
    "long_500k": dict(mode="decode", seq_len=524288, global_batch=1),
}

from repro.configs.base import ModelConfig, RunConfig, SHAPES  # noqa: F401
from repro.configs.registry import get_config, ARCHS  # noqa: F401

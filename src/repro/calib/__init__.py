"""Gradient-based calibration of the analog substrate's physics knobs.

The differentiable solver (TESTING.md "differentiable solver contract")
makes the whole programmed pipeline - effective-operator finalization,
arena compilation, cascade execution - reverse-mode differentiable in the
wire resistance via the `r_wire` override threaded through
`core.blockamc.finalize`.  This package closes the loop: fit the
first-order wire model's parameters to measurements of a *real* (here:
exactly simulated) crossbar by plain gradient descent on solver outputs.

  * `wire` - recover a planted wire segment resistance by matching the
    differentiable first-order model against the exact nodal MNA oracle
    (`repro.physics.nodal`).
"""
from repro.calib.wire import (  # noqa: F401
    WireCalibration, calibrate_wire, calibrate_wire_to)

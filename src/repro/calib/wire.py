"""Wire-resistance calibration by gradient descent through the solver.

The hot-path wire model (`core.nonideal.effective_conductance`) is a
first-order perturbation in r*G; the exact physics is the batched nodal MNA
solve in `repro.physics.nodal`.  The nodal model needs a *static* r_seg
(its interior solver specializes on the resistance), so it cannot be
differentiated - but the first-order model is linear in r_seg, and with the
arena executor's implicit-diff VJP the whole chain

    r_hat -> finalize(fplan, cfg, r_wire=r_hat) -> compile_arena
          -> execute_arena -> x_model(r_hat)

is reverse-mode differentiable end-to-end (one `jax.grad`, no
re-programming).  Calibration is then ordinary optimization: descend the
mismatch between model outputs and observed outputs until the first-order
r_hat explains the measurements.

Validity envelope: the first-order-vs-nodal output gap is pinned by
tests/test_wire_validation.py at ~0.2% (n=8, r=1 Ohm) growing to ~6%
(n=64) - so planted-parameter recovery to the <5% acceptance bound holds
at small array sizes, and degrades gracefully (the fit absorbs model error
into r_hat) as r*G*n leaves the perturbative regime.

Sigma (programming noise) is *not* calibrated here: a single noise draw is
a realization, not a parameter - recovering it takes moment-matching over
many keys, which rides on the same differentiable pipeline but is out of
scope for this loop.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import blockamc
from repro.core.analog import AnalogConfig
from repro.core.nonideal import NonidealConfig


@dataclasses.dataclass(frozen=True)
class WireCalibration:
    """Result of a wire-resistance fit."""
    r_hat: float                 # fitted wire segment resistance [Ohm]
    loss: float                  # final relative-MSE mismatch
    history: Tuple[float, ...]   # per-step loss curve (for the benchmark)
    r_history: Tuple[float, ...]  # per-step r_hat trajectory
    steps: int

    def rel_err(self, r_true: float) -> float:
        """Relative recovery error against a known planted resistance."""
        return abs(self.r_hat - r_true) / abs(r_true)


def _model_outputs(fplan, cfg: AnalogConfig, b: jnp.ndarray,
                   r_hat: jnp.ndarray) -> jnp.ndarray:
    """Differentiable solver outputs under the first-order model at r_hat."""
    fin = blockamc.finalize(fplan, cfg, r_wire=r_hat)
    return blockamc.execute_arena(blockamc.compile_arena(fin), b)


def calibrate_wire_to(fplan, cfg: AnalogConfig, b: jnp.ndarray,
                      x_obs: jnp.ndarray, *, r_init: float = 0.25,
                      lr: float = 0.05, steps: int = 150,
                      on_step: Optional[Callable[[int, float, float],
                                                 None]] = None
                      ) -> WireCalibration:
    """Fit r_hat so the first-order solver output matches observations.

    Args:
      fplan:  compiled FlatPlan of the system (clean programming - the fit
              attributes *all* mismatch to wire resistance).
      cfg:    substrate config used for finalization (its static
              nonideal.r_wire is irrelevant here; the traced override wins).
      b:      (n, k) probe right-hand sides.
      x_obs:  (n, k) observed solutions for those probes (the measurement).
      r_init: starting resistance guess [Ohm]; must be > 0.
      lr:     Adam learning rate in log-resistance space.
      steps:  fixed descent budget.
      on_step: optional callback (step, loss, r_hat) for live logging.

    Returns a `WireCalibration` with the fit and its loss/parameter curves.
    """
    denom = jnp.mean(x_obs * x_obs)

    def loss_fn(theta):
        # log-space parameterization keeps r_hat > 0 with unconstrained Adam
        x_m = _model_outputs(fplan, cfg, b, jnp.exp(theta))
        return jnp.mean((x_m - x_obs) ** 2) / denom

    value_and_grad = jax.jit(jax.value_and_grad(loss_fn))

    # scalar Adam (no optimizer dependency; standard b1/b2/eps)
    b1, b2, eps = 0.9, 0.999, 1e-8
    theta = jnp.log(jnp.asarray(r_init, jnp.float64 if jax.config.jax_enable_x64
                                else jnp.float32))
    m = jnp.zeros_like(theta)
    v = jnp.zeros_like(theta)
    history, r_history = [], []
    loss = float("nan")
    for t in range(1, steps + 1):
        loss, g = value_and_grad(theta)
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        mhat = m / (1 - b1 ** t)
        vhat = v / (1 - b2 ** t)
        theta = theta - lr * mhat / (jnp.sqrt(vhat) + eps)
        loss = float(loss)
        r_now = float(jnp.exp(theta))
        history.append(loss)
        r_history.append(r_now)
        if on_step is not None:
            on_step(t, loss, r_now)
    return WireCalibration(r_hat=float(jnp.exp(theta)), loss=loss,
                           history=tuple(history),
                           r_history=tuple(r_history), steps=steps)


def calibrate_wire(a: jnp.ndarray, *, r_true: float = 1.0,
                   cfg: Optional[AnalogConfig] = None,
                   stages: Optional[int] = None, num_probes: int = 8,
                   key: Optional[jax.Array] = None, r_init: float = 0.25,
                   lr: float = 0.05, steps: int = 150,
                   on_step: Optional[Callable[[int, float, float],
                                              None]] = None
                   ) -> WireCalibration:
    """Plant r_true in the exact nodal oracle, recover it by descent.

    The end-to-end acceptance loop: program `a` cleanly (sigma=0, no
    faults), generate "measurements" by finalizing the same FlatPlan under
    `wire_model="nodal"` at the planted resistance, then recover r_hat from
    those measurements with `calibrate_wire_to`.  At small n the recovery
    lands within the first-order model's validity gap (<5% relative for
    n <= 16, r ~ 1 Ohm - see module docstring).
    """
    if cfg is None:
        cfg = AnalogConfig(array_size=max(8, a.shape[0] // 2))
    clean = cfg.with_(nonideal=NonidealConfig())
    if key is None:
        key = jax.random.PRNGKey(0)
    kprog, kprobe = jax.random.split(key)
    fplan = blockamc.compile_plan(
        blockamc.build_plan(a, kprog, clean, stages))
    b = jax.random.normal(kprobe, (a.shape[0], num_probes), a.dtype)

    # the oracle: exact nodal readout of the SAME programmed conductances
    oracle_cfg = clean.with_(nonideal=NonidealConfig(
        r_wire=float(r_true), wire_model="nodal"))
    fin_oracle = blockamc.finalize(fplan, oracle_cfg)
    x_obs = blockamc.execute_arena(blockamc.compile_arena(fin_oracle), b)

    return calibrate_wire_to(fplan, clean, b, x_obs, r_init=r_init, lr=lr,
                             steps=steps, on_step=on_step)

"""Replicated serving fleet: health-scored routing, hedging, durable recovery.

`ReplicatedSolverFleet` is the multi-replica layer over PR 7's
`AsyncSolverEngine` (the ROADMAP "go multi-replica" step): N engine
replicas, each with its own `SolverService`, worker thread and (when the
host has them) its own device via `ElasticMesh.assign_replicas`, behind a
router that owns admission, placement, hedging and failure recovery.

**Replicated programming.** `program` programs every matrix on every
replica with the *same* key.  Programming is deterministic in (matrix,
key, cfg), so the conductance stacks are bit-identical across replicas -
which is what makes three things free: any replica can answer any
request, any survivor is a valid pytree template for checkpoint restore
(stackability invariant), and replayed requests get the same answers the
dead replica would have produced.

**Health-scored routing.** Each replica carries an EWMA composite score:
canary-residual ratio (current residual / calibrated trip - the physics
signal), deadline-miss rate (the SLO signal), and queue depth (the load
signal).  Lower is healthier.  Placement is least-loaded with
signature-affinity: same-signature requests prefer the replica already
accumulating that signature's batch (packed dispatch efficiency), unless
its score has fallen behind the best replica by more than
`affinity_slack`.

**Hedged requests.** A deadline-critical submit (`hedge=True`, or any
deadlined submit when `hedge_delay` is set) arms a timer: if the primary
leg has not answered after the hedge delay, a duplicate leg goes to the
next-best replica.  First finite answer wins the outer future; the
losing leg is cancelled if still queued (`engine.cancel`) and its answer
is ignored otherwise.  A hedge turns a straggling replica from a tail
latency event into one wasted dispatch.

**Lifecycle ladder.** degraded -> drained -> quarantined -> replaced:
a replica whose score crosses `degrade_score` is deprioritized (routing
order); past `drain_score` it is drained (no new requests); a drained
replica whose in-flight work has settled (or that overstays
`drain_grace`) is quarantined - its engine is stopped, every leg still
unresolved is replayed on survivors - and replaced.  A replica whose
worker *dies* (chaos `ReplicaDeath`, or anything else that kills the
thread) skips the ladder: the monitor detects the dead worker, replays
every outstanding leg on the survivors immediately (no future ever
hangs; the replays are the only requests that can miss deadlines, so
tenants routed to healthy replicas see zero misses), and then rebuilds
the replica.

**Durable recovery.** Replacement programming is the expensive path -
write-verify analog programming is exactly the cost the paper's
program-once/solve-many economics amortize away.  With a `ProgramStore`
attached, `program` persists each matrix's programmed state (FinalizedPlan
+ ArenaPlan, keyed by plan_signature + program key + matrix hash, with
the calibrated canary trip in the manifest); a replacement replica
*restores* stacks from the checkpoint and re-validates them against the
ORIGINAL trip threshold (`engine.install`).  Only when the checkpoint is
stale (signature/hash/key mismatch), corrupt (manifest cross-check), or
physically bad (canary rejection) does it fall back to full
re-programming.  Restore-vs-reprogram times are recorded per recovery in
`FleetStats` - the measurable ratio `benchmarks/router_bench.py` pins.
"""
from __future__ import annotations

import dataclasses
import logging
import threading
import time
from concurrent.futures import Future, InvalidStateError
from typing import Callable, Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.checkpoint.ckpt import CheckpointError
from repro.checkpoint.program_store import (CheckpointRejectedError,
                                            ProgramStore,
                                            StaleCheckpointError)
from repro.runtime.elastic import ElasticMesh
from repro.serve.async_engine import (AsyncSolverEngine, EngineStoppedError,
                                      SolveResult)

log = logging.getLogger("repro.serve.router")


class FleetError(RuntimeError):
    """Base class for fleet-surfaced request failures."""


class NoReplicaAvailableError(FleetError):
    """No live replica can take this request (total fleet loss)."""


@dataclasses.dataclass
class FleetStats:
    """Fleet-lifetime counters (monitor/handler-written; read quiesced)."""
    submitted: int = 0
    answered: int = 0
    hedges: int = 0            # hedge legs launched
    hedge_wins: int = 0        # outer answered by a hedge leg
    cancelled_legs: int = 0    # losing legs cancelled while queued
    replays: int = 0           # legs replayed on a survivor
    deaths: int = 0           # replicas whose worker died
    drains: int = 0
    quarantines: int = 0
    replacements: int = 0
    restores: int = 0          # recoveries served from checkpoint
    reprogram_fallbacks: int = 0   # recoveries that had to re-program
    rejected_checkpoints: int = 0  # stale/corrupt/canary-failed restores
    repairs: int = 0           # block-repair rounds across the fleet
    recheckpoints: int = 0     # repaired plans persisted to the store
    maintenance_windows: int = 0   # repair-token grants (staggered)
    restore_s: List[float] = dataclasses.field(default_factory=list)
    reprogram_s: List[float] = dataclasses.field(default_factory=list)


class _Score:
    """Per-replica EWMA health composite; lower is healthier."""

    __slots__ = ("alpha", "canary", "miss", "queue")

    def __init__(self, alpha: float):
        self.alpha = alpha
        self.canary = 0.0      # EWMA of canary residual / trip threshold
        self.miss = 0.0        # EWMA of deadline-miss indicator
        self.queue = 0.0       # latest queue depth (instant, not EWMA)

    def _ewma(self, old: float, x: float) -> float:
        return (1.0 - self.alpha) * old + self.alpha * x

    def observe_answer(self, missed: bool) -> None:
        self.miss = self._ewma(self.miss, 1.0 if missed else 0.0)

    def observe_health(self, canary_ratio: float, queue_depth: int,
                       max_batch: int) -> None:
        self.canary = self._ewma(self.canary, min(canary_ratio, 10.0))
        self.queue = queue_depth / max(1, max_batch)

    def value(self) -> float:
        return self.canary + 2.0 * self.miss + 0.25 * self.queue


class _FleetRequest:
    __slots__ = ("matrix_id", "b", "deadline", "future", "t_submit",
                 "legs", "failures", "replicas_tried", "hedged")

    def __init__(self, matrix_id: str, b: np.ndarray,
                 deadline: Optional[float], future: Future,
                 t_submit: float):
        self.matrix_id = matrix_id
        self.b = b
        self.deadline = deadline       # absolute monotonic, or None
        self.future = future           # the caller-facing outer future
        self.t_submit = t_submit
        self.legs: List[Future] = []   # live inner futures
        self.failures: List[BaseException] = []
        self.replicas_tried: List[str] = []
        self.hedged = False


class _Replica:
    __slots__ = ("name", "device", "engine", "generation", "state",
                 "score", "inflight", "drained_at")

    def __init__(self, name: str, device, engine: AsyncSolverEngine,
                 alpha: float):
        self.name = name
        self.device = device
        self.engine = engine
        self.generation = 0
        self.state = "active"   # active|degraded|drained|quarantined|dead
        self.score = _Score(alpha)
        self.inflight: Dict[Future, _FleetRequest] = {}
        self.drained_at: Optional[float] = None

    @property
    def routable(self) -> bool:
        return self.state in ("active", "degraded")


@dataclasses.dataclass
class _MatrixRecord:
    a: np.ndarray
    key: jax.Array
    cfg: object            # AnalogConfig or None (service default)
    sig: tuple
    trip: float


class ReplicatedSolverFleet:
    """N health-scored `AsyncSolverEngine` replicas behind one router.

    `make_service` is a zero-argument factory producing a fresh
    `SolverService` per replica (and per replacement) - replicas must
    never share mutable service state.  `engine_kw` forwards to every
    `AsyncSolverEngine`; the fleet adds `name`, `device` and `chaos`
    itself.
    """

    def __init__(self, make_service: Callable[[], object],
                 n_replicas: int = 2, *,
                 engine_kw: Optional[dict] = None,
                 store: Optional[ProgramStore] = None,
                 mesh: Optional[ElasticMesh] = None,
                 devices: Optional[list] = None,
                 chaos=None,
                 clock=None,
                 hedge_delay: Optional[float] = None,
                 affinity_slack: float = 0.5,
                 ewma_alpha: float = 0.3,
                 degrade_score: float = 0.8,
                 drain_score: float = 1.5,
                 drain_grace: float = 0.25,
                 poll_interval: float = 0.002):
        if n_replicas < 1:
            raise ValueError("fleet needs at least one replica")
        self.make_service = make_service
        self.engine_kw = dict(engine_kw or {})
        self.store = store
        self.chaos = chaos
        self.clock = clock            # shared DeviceClock (drift aging)
        self.hedge_delay = hedge_delay
        self.affinity_slack = float(affinity_slack)
        self.ewma_alpha = float(ewma_alpha)
        self.degrade_score = float(degrade_score)
        self.drain_score = float(drain_score)
        self.drain_grace = float(drain_grace)
        self.poll_interval = float(poll_interval)
        self.stats = FleetStats()

        # maintenance staggering: at most ONE replica holds the repair
        # token at a time, so scrub/repair windows never overlap across
        # the fleet (the goodput invariant).  The token is a plain
        # attribute read lock-free by each engine's repair gate.
        self._repair_token: Optional[str] = None
        self._maint_rotor = 0

        placement = (mesh or ElasticMesh()).assign_replicas(
            n_replicas, devices)
        self._lock = threading.RLock()
        self._replicas: List[_Replica] = [
            self._make_replica(f"r{i}", placement[i])
            for i in range(n_replicas)]
        self._matrices: Dict[str, _MatrixRecord] = {}
        self._affinity: Dict[tuple, str] = {}   # sig -> replica name
        self._submits = 0                       # chaos corruption counter
        self._running = False
        self._monitor: Optional[threading.Thread] = None
        self._timers: List[threading.Timer] = []

    def _make_replica(self, name: str, device) -> _Replica:
        kw = dict(self.engine_kw)
        if self.clock is not None:
            # thread the shared device clock through every replica; the
            # repair gate reads the token without any lock (it runs
            # inside the engine's wait predicate), and on_repair
            # re-checkpoints repaired plans
            kw.setdefault("clock", self.clock)
            kw.setdefault("repair_gate",
                          lambda name=name: self._repair_token == name)
            kw.setdefault("on_repair",
                          lambda mid, solver, key, name=name:
                          self._on_repair(name, mid, solver, key))
        engine = AsyncSolverEngine(self.make_service(), name=name,
                                   device=device, chaos=self.chaos,
                                   **kw)
        return _Replica(name, device, engine, self.ewma_alpha)

    # ------------------------------------------------------------------
    # lifecycle
    # ------------------------------------------------------------------

    def start(self) -> "ReplicatedSolverFleet":
        with self._lock:
            if self._running:
                raise RuntimeError("fleet already running")
            self._running = True
            for r in self._replicas:
                r.engine.start()
        self._monitor = threading.Thread(target=self._monitor_loop,
                                         name="amc-fleet-monitor",
                                         daemon=True)
        self._monitor.start()
        return self

    def stop(self, drain: bool = True, timeout: Optional[float] = 10.0):
        with self._lock:
            self._running = False
            timers, self._timers = self._timers, []
        for t in timers:
            t.cancel()
        if self._monitor is not None:
            self._monitor.join(timeout)
            self._monitor = None
        for r in self._replicas:
            if r.engine.alive:
                r.engine.stop(drain=drain, timeout=timeout)

    def __enter__(self) -> "ReplicatedSolverFleet":
        return self.start()

    def __exit__(self, exc_type, exc, tb):
        self.stop(drain=exc_type is None)
        return False

    # ------------------------------------------------------------------
    # programming + durability
    # ------------------------------------------------------------------

    def program(self, matrix_id: str, a, key=None, cfg=None) -> None:
        """Program `a` on EVERY replica under the same key, then persist.

        Same key => bit-identical programmed stacks on every replica (the
        replicated-programming invariant above).  With a store attached,
        replica r0's solver is checkpointed together with the calibrated
        canary trip, so a future replacement can restore instead of
        re-program."""
        key = key if key is not None else jax.random.PRNGKey(0)
        a_host = np.asarray(a)
        with self._lock:
            replicas = [r for r in self._replicas if r.state != "dead"]
        if not replicas:
            raise NoReplicaAvailableError("no live replica to program")
        for r in replicas:
            r.engine.program(matrix_id, a, key, cfg=cfg)
        lead = replicas[0]
        sig = lead.engine.service.signature(matrix_id)
        trip = lead.engine.matrix_trip(matrix_id)
        with self._lock:
            self._matrices[matrix_id] = _MatrixRecord(
                a_host, key, cfg, sig, trip)
        if self.store is not None:
            self.store.save(matrix_id, lead.engine.service.solver(matrix_id),
                            a_host, key, sig, extra={"trip": float(trip)})

    # ------------------------------------------------------------------
    # routing
    # ------------------------------------------------------------------

    def _pick(self, sig: tuple,
              exclude: Tuple[str, ...] = ()) -> _Replica:
        """Least-loaded routable replica, with signature affinity: the
        replica already accumulating this signature keeps it while its
        score stays within `affinity_slack` of the best candidate.

        Ranking quantizes the health score (quarter-point buckets) before
        load and assignment count: sub-noise EWMA differences - e.g. the
        replica programmed last having seen fewer canary observations -
        must not defeat least-loaded spreading.  The final assignment-
        count key round-robins *new* signatures across equally-healthy
        replicas, so a multi-tenant fleet spreads deterministically
        instead of piling onto whichever replica sorts first."""
        cands = [r for r in self._replicas
                 if r.routable and r.name not in exclude]
        if not cands:
            # hedging excludes the primary; a 1-replica fleet falls back
            cands = [r for r in self._replicas if r.routable]
        if not cands:
            raise NoReplicaAvailableError(
                "no routable replica (all drained, quarantined or dead)")
        assigned: Dict[str, int] = {}
        for name in self._affinity.values():
            assigned[name] = assigned.get(name, 0) + 1
        cands.sort(key=lambda r: (0 if r.state == "active" else 1,
                                  int(r.score.value() / 0.25),
                                  len(r.inflight),
                                  assigned.get(r.name, 0)))
        best = cands[0]
        aff = self._affinity.get(sig)
        if aff is not None and aff != best.name:
            for r in cands:
                if r.name == aff:
                    if (r.score.value() - best.score.value()
                            <= self.affinity_slack):
                        best = r
                    break
        self._affinity[sig] = best.name
        return best

    def submit(self, matrix_id: str, b, *,
               deadline_s: Optional[float] = None,
               hedge: Optional[bool] = None) -> Future:
        """Route one (n,) rhs; returns a Future[SolveResult].

        The outer future NEVER hangs: it resolves with the first finite
        answer from any leg, or with a typed error once every leg has
        failed and no survivor can take a replay."""
        with self._lock:
            if not self._running:
                raise FleetError("fleet is not running")
            rec = self._matrices[matrix_id]
            # pick FIRST: a fully-drained fleet must reject with
            # NoReplicaAvailableError before any counter moves, so a
            # failed admission leaves `stats`/`_submits` (and the chaos
            # corruption schedule keyed on `_submits`) untouched
            replica = self._pick(rec.sig)
            self._submits += 1
            now = time.monotonic()
            deadline = (None if deadline_s is None
                        else now + float(deadline_s))
            req = _FleetRequest(matrix_id, np.array(b), deadline,
                                Future(), now)
            self.stats.submitted += 1
            self._launch_leg(req, replica)
            do_hedge = (hedge if hedge is not None
                        else (self.hedge_delay is not None
                              and deadline is not None))
            if do_hedge and self.hedge_delay is not None:
                t = threading.Timer(self.hedge_delay, self._hedge, (req,))
                t.daemon = True
                if len(self._timers) > 256:     # prune fired timers
                    self._timers = [x for x in self._timers if x.is_alive()]
                self._timers.append(t)
                t.start()
        return req.future

    def _launch_leg(self, req: _FleetRequest, replica: _Replica,
                    replay: bool = False) -> None:
        """Submit one leg of `req` to `replica` (lock held by caller)."""
        deadline_s = None
        if req.deadline is not None:
            deadline_s = max(1e-4, req.deadline - time.monotonic())
        try:
            inner = replica.engine.submit(req.matrix_id, req.b,
                                          deadline_s=deadline_s)
        except EngineStoppedError:
            # raced a death the monitor hasn't seen yet: route elsewhere
            self._note_dead(replica)
            survivor = self._pick(self._matrices[req.matrix_id].sig,
                                  exclude=(replica.name,))
            self._launch_leg(req, survivor, replay=replay)
            return
        req.legs.append(inner)
        req.replicas_tried.append(replica.name)
        replica.inflight[inner] = req
        if replay:
            self.stats.replays += 1
        inner.add_done_callback(
            lambda fut, rep=replica: self._on_leg_done(rep, fut))

    def _hedge(self, req: _FleetRequest) -> None:
        """Timer body: duplicate an unanswered request to the next-best
        replica (first finite answer wins)."""
        with self._lock:
            if not self._running or req.future.done() or req.hedged:
                return
            req.hedged = True
            self.stats.hedges += 1
            try:
                replica = self._pick(self._matrices[req.matrix_id].sig,
                                     exclude=tuple(req.replicas_tried))
            except (NoReplicaAvailableError, KeyError):
                return
            self._launch_leg(req, replica)
            replica.engine.flush_now()

    # ------------------------------------------------------------------
    # leg settlement
    # ------------------------------------------------------------------

    def _on_leg_done(self, replica: _Replica, inner: Future) -> None:
        with self._lock:
            req = replica.inflight.pop(inner, None)
            if req is None:
                return
            if inner.cancelled():
                return
            exc = inner.exception()
            if exc is not None:
                self._leg_failed(req, replica, inner, exc)
                return
            res: SolveResult = inner.result()
            replica.score.observe_answer(res.deadline_missed)
            x = np.asarray(res.x)
            if not np.all(np.isfinite(x)):
                self._leg_failed(req, replica, inner, FleetError(
                    f"non-finite answer from replica {replica.name!r}"))
                return
            try:
                req.future.set_result(res)
            except InvalidStateError:
                return                      # a sibling leg won the hedge
            self.stats.answered += 1
            if len(req.replicas_tried) > 1 and \
                    req.replicas_tried.index(replica.name) > 0:
                self.stats.hedge_wins += 1
            # the winner settles the race: cancel still-queued siblings
            for leg in req.legs:
                if leg is inner or leg.done():
                    continue
                for other in self._replicas:
                    if leg in other.inflight:
                        if other.engine.cancel(leg):
                            self.stats.cancelled_legs += 1
                        break

    def _leg_failed(self, req: _FleetRequest, replica: _Replica,
                    inner: Future, exc: BaseException) -> None:
        """One leg failed (lock held).  Replica death reroutes; anything
        else surfaces once no sibling leg can still answer."""
        req.failures.append(exc)
        if req.future.done():
            return
        if isinstance(exc, EngineStoppedError):
            try:
                survivor = self._pick(self._matrices[req.matrix_id].sig,
                                      exclude=(replica.name,))
                self._launch_leg(req, survivor, replay=True)
                return
            except NoReplicaAvailableError as e:
                exc = e
        if any(not leg.done() for leg in req.legs):
            return                          # a sibling may still answer
        try:
            req.future.set_exception(exc)
        except InvalidStateError:
            pass

    # ------------------------------------------------------------------
    # supervision: monitor loop, lifecycle ladder, death + replacement
    # ------------------------------------------------------------------

    def _monitor_loop(self) -> None:
        while True:
            with self._lock:
                if not self._running:
                    return
            try:
                self.review()
            except Exception:               # noqa: BLE001
                log.exception("fleet review failed")
            time.sleep(self.poll_interval)

    def review(self) -> None:
        """One supervision pass (the monitor calls this continuously;
        tests call it directly for determinism): scripted checkpoint
        corruption, health-score refresh, the lifecycle ladder, and
        dead-worker recovery."""
        if self.chaos is not None and self.store is not None:
            with self._lock:
                due = self.chaos.corruptions_due(self._submits)
            for ev in due:
                try:
                    self.store.corrupt(ev.matrix_id, ev.how)
                    log.warning("chaos: corrupted checkpoint of %r (%s)",
                                ev.matrix_id, ev.how)
                except CheckpointError:
                    pass                    # nothing stored yet
        to_replace: List[_Replica] = []
        with self._lock:
            if self.clock is not None:
                self._rotate_repair_token()
            for r in self._replicas:
                if r.state in ("quarantined", "dead"):
                    continue
                if not r.engine.alive:
                    self._note_dead(r)
                    to_replace.append(r)
                    continue
                snap = r.engine.health_snapshot()
                trips = snap["trip"]
                ratios = [snap["canary"][mid] / trips[mid]
                          for mid in snap["canary"] if trips[mid] > 0]
                r.score.observe_health(
                    max(ratios) if ratios else 0.0,
                    snap["queue_depth"],
                    max(1, r.engine.max_batch))
                score = r.score.value()
                if (self._repair_token == r.name
                        and r.state in ("active", "degraded")):
                    # the staggering invariant: a replica in its repair
                    # window is DEGRADED (deprioritized but routable) and
                    # is never drained or quarantined for the elevated
                    # canary its own maintenance causes
                    if r.state == "active":
                        r.state = "degraded"
                        log.info("replica %r degraded for maintenance "
                                 "window", r.name)
                    continue
                if r.state == "active" and score >= self.degrade_score:
                    r.state = "degraded"
                    log.warning("replica %r degraded (score %.2f)",
                                r.name, score)
                elif r.state == "degraded":
                    if score >= self.drain_score:
                        r.state = "drained"
                        r.drained_at = time.monotonic()
                        self.stats.drains += 1
                        log.warning("replica %r drained (score %.2f)",
                                    r.name, score)
                    elif score < 0.5 * self.degrade_score:
                        r.state = "active"
                elif r.state == "drained":
                    settled = not r.inflight
                    overstay = (r.drained_at is not None and
                                time.monotonic() - r.drained_at
                                > self.drain_grace)
                    if settled or overstay:
                        r.state = "quarantined"
                        self.stats.quarantines += 1
                        to_replace.append(r)
        for r in to_replace:
            self._quarantine_and_replace(r)

    def _rotate_repair_token(self) -> None:
        """Grant/release the fleet-wide repair token (lock held).

        Release when the holder is gone or has nothing left to repair;
        grant round-robin to the next routable replica with pending
        repairs, so maintenance windows stagger across the fleet instead
        of every replica repairing (and degrading) at once."""
        if self._repair_token is not None:
            holder = next((r for r in self._replicas
                           if r.name == self._repair_token), None)
            if (holder is None or not holder.engine.alive
                    or not holder.routable
                    or holder.engine.maintenance_pending == 0):
                self._repair_token = None
        if self._repair_token is None:
            n = len(self._replicas)
            for k in range(n):
                r = self._replicas[(self._maint_rotor + k) % n]
                if (r.routable and r.engine.alive
                        and r.engine.maintenance_pending > 0):
                    self._repair_token = r.name
                    self._maint_rotor = (self._replicas.index(r) + 1) % n
                    self.stats.maintenance_windows += 1
                    r.engine.flush_now()    # wake the worker to repair
                    break

    def _on_repair(self, name: str, mid: str, solver, key) -> None:
        """Engine on_repair callback (worker thread): count the round
        and persist the repaired plan, so a replacement replica restores
        post-repair stacks instead of pre-drift ones."""
        with self._lock:
            self.stats.repairs += 1
            rec = self._matrices.get(mid)
        if self.store is None or rec is None:
            return
        try:
            self.store.save(mid, solver, rec.a, rec.key, rec.sig,
                            extra={"trip": float(rec.trip)})
            with self._lock:
                self.stats.recheckpoints += 1
        except CheckpointError as e:
            log.warning("re-checkpoint of repaired %r failed: %s", mid, e)

    def _note_dead(self, replica: _Replica) -> None:
        """Mark a replica dead (lock held or reentrant)."""
        with self._lock:
            if replica.state == "dead":
                return
            replica.state = "dead"
            self.stats.deaths += 1
            log.error("replica %r is dead (worker lost)", replica.name)
            for sig, name in list(self._affinity.items()):
                if name == replica.name:
                    del self._affinity[sig]

    def _quarantine_and_replace(self, replica: _Replica) -> None:
        """Stop (if still up), replay every unresolved leg on survivors,
        rebuild the replica - restore from checkpoint when possible."""
        was_dead = replica.state == "dead"
        if not was_dead:
            with self._lock:
                replica.state = "quarantined"
                for sig, name in list(self._affinity.items()):
                    if name == replica.name:
                        del self._affinity[sig]
            try:
                # drain=False: unanswered legs resolve EngineStoppedError,
                # which _leg_failed turns into replays on survivors
                replica.engine.stop(drain=False, timeout=5.0)
            except RuntimeError:
                # worker stuck past the join timeout: treat as dead
                self._note_dead(replica)
        # legs a dead/stuck worker left unresolved never fire callbacks -
        # replay them explicitly (THE no-future-ever-hangs guarantee)
        with self._lock:
            orphans = [(inner, req) for inner, req in
                       list(replica.inflight.items())
                       if not inner.done()]
            replica.inflight.clear()
            for inner, req in orphans:
                if req.future.done():
                    continue
                try:
                    survivor = self._pick(
                        self._matrices[req.matrix_id].sig,
                        exclude=(replica.name,))
                except NoReplicaAvailableError as e:
                    try:
                        req.future.set_exception(e)
                    except InvalidStateError:
                        pass
                    continue
                self._launch_leg(req, survivor, replay=True)
        self._replace(replica)

    def _replace(self, replica: _Replica) -> None:
        """Rebuild a lost replica: fresh engine + service on the same
        device slot, programmed state restored from checkpoint when the
        store has a valid one, re-programmed from scratch otherwise."""
        with self._lock:
            if not self._running:
                return
            matrices = dict(self._matrices)
            survivors = [r for r in self._replicas
                         if r is not replica and r.state != "dead"
                         and r.engine.alive]
        fresh = self._make_replica(replica.name, replica.device)
        fresh.generation = replica.generation + 1
        fresh.engine.start()
        for mid, rec in matrices.items():
            self._recover_matrix(fresh, mid, rec, survivors)
        with self._lock:
            idx = self._replicas.index(replica)
            self._replicas[idx] = fresh
            self.stats.replacements += 1
        log.warning("replica %r replaced (generation %d)",
                    fresh.name, fresh.generation)

    def _recover_matrix(self, fresh: _Replica, mid: str,
                        rec: _MatrixRecord, survivors: List[_Replica]
                        ) -> None:
        """Restore-first recovery of one matrix onto a fresh replica."""
        if self.store is not None and self.store.has(mid) and survivors:
            template = survivors[0].engine.service.solver(mid)
            t0 = time.perf_counter()
            try:
                solver, meta = self.store.restore(
                    mid, template, rec.a, rec.key, rec.sig)
                trip = float(meta.get("trip", rec.trip))
                fresh.engine.install(mid, solver, rec.a, rec.key, trip,
                                     cfg=rec.cfg)
                self.stats.restores += 1
                self.stats.restore_s.append(time.perf_counter() - t0)
                return
            except (StaleCheckpointError, CheckpointRejectedError,
                    CheckpointError) as e:
                self.stats.rejected_checkpoints += 1
                log.warning("checkpoint restore of %r rejected (%s); "
                            "falling back to re-programming", mid, e)
        t0 = time.perf_counter()
        fresh.engine.program(mid, rec.a, rec.key, cfg=rec.cfg)
        self.stats.reprogram_fallbacks += 1
        self.stats.reprogram_s.append(time.perf_counter() - t0)

    # ------------------------------------------------------------------
    # introspection
    # ------------------------------------------------------------------

    def replica_states(self) -> Dict[str, str]:
        with self._lock:
            return {r.name: r.state for r in self._replicas}

    def replica_scores(self) -> Dict[str, float]:
        with self._lock:
            return {r.name: r.score.value() for r in self._replicas}

    def maintenance_gauges(self) -> Dict[str, dict]:
        """Per-replica drift gauges (report-only observability): each
        live replica's per-matrix maintenance summary plus its scrub /
        repair counters, as exported by `engine.health()`."""
        with self._lock:
            replicas = list(self._replicas)
        out: Dict[str, dict] = {}
        for r in replicas:
            if not r.engine.alive:
                continue
            snap = r.engine.health_snapshot()
            out[r.name] = {
                "maintenance": snap.get("maintenance", {}),
                "scrub_probes": snap.get("scrub_probes", 0),
                "repairs": snap.get("repairs", 0),
                "blocks_repaired": snap.get("blocks_repaired", 0),
            }
        return out

    def maintenance_quiesce(self, timeout: float = 30.0) -> bool:
        """Wait until every live replica's scrubber has caught up with
        the device clock.  The repair token is granted by the monitor
        one replica at a time, so this also waits out the staggered
        repair windows."""
        deadline = time.monotonic() + float(timeout)
        while time.monotonic() < deadline:
            with self._lock:
                replicas = [r for r in self._replicas if r.engine.alive]
            busy = any(r.engine.maintenance_pending > 0 for r in replicas)
            if not busy:
                done = all(
                    r.engine.maintenance_quiesce(timeout=0.01)
                    for r in replicas)
                if done:
                    return True
            time.sleep(self.poll_interval)
        return False

    def flush_now(self) -> None:
        with self._lock:
            replicas = list(self._replicas)
        for r in replicas:
            if r.engine.alive:
                r.engine.flush_now()

    @property
    def matrix_ids(self) -> Tuple[str, ...]:
        with self._lock:
            return tuple(self._matrices)

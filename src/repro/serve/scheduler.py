"""Continuous-batching flush policy for the multi-tenant solver service.

`PackedSolverScheduler` applies the keep-every-slot-busy discipline of
production serving to `serve.SolverService`'s packed flush: admit
streaming (matrix_id, rhs) requests, fire a signature bucket through the
packed `flush_all` dispatch the moment it fills, drain stragglers on
demand.  (The LM continuous-batching engine that used to share this
module lives in `repro.models.lm_scheduler` now - `serve/` is the solver
serving namespace.)
"""
from __future__ import annotations

from typing import Dict

import jax.numpy as jnp
import numpy as np


class PackedSolverScheduler:
    """Continuous-batching flush policy over a `serve.SolverService`.

    Requests stream in as (matrix_id, rhs) pairs; each `submit` returns a
    ticket.  The moment the submitting matrix's *signature bucket* (all
    tenants sharing its `plan_signature`) accumulates `max_batch` pending
    right-hand sides, that bucket alone flushes through the service's
    packed `flush_all` - one fused dispatch over (tenants x rhs) - while
    other buckets keep filling, exactly the keep-every-slot-busy
    discipline of `ContinuousBatchingEngine` applied to solver tenants.
    `drain()` flushes everything still queued; `result(ticket)` retrieves
    (and drops) a delivered solution, `ready(ticket)` polls.

    The scheduler must be the service's only queue writer: tickets map to
    answer columns by per-tenant submission order, so right-hand sides
    submitted or flushed *directly* on the service while a scheduler is
    attached would shift that mapping.  `_deliver` raises rather than
    mis-assign when it detects more answers than open tickets.  Admission
    is O(1): a per-signature running counter decides the flush trigger,
    and the O(num_tenants) bucket scan happens only when a flush fires.
    """

    def __init__(self, service, max_batch: int = 8):
        self.service = service
        self.max_batch = max_batch
        self._results: Dict[tuple, np.ndarray] = {}
        self._submitted: Dict[str, int] = {}    # tickets issued per tenant
        self._delivered: Dict[str, int] = {}    # tickets answered per tenant
        self._sig_pending: Dict[tuple, int] = {}   # open rhs per signature

    def submit(self, matrix_id: str, b: jnp.ndarray) -> tuple:
        """Queue one rhs; returns its ticket.  May trigger a bucket flush
        (in which case this and every bucket-mate's pending rhs resolve)."""
        self.service.submit(matrix_id, b)
        seq = self._submitted.get(matrix_id, 0)
        self._submitted[matrix_id] = seq + 1
        sig = self.service.signature(matrix_id)
        count = self._sig_pending.get(sig, 0) + 1
        # counter is written before the flush attempt: flush_all is
        # all-or-nothing, so a failed dispatch leaves the queues (and
        # this count) valid for a retry on the next submit or drain.
        # Once flush_all returns, the queues ARE consumed, so the counter
        # resets before delivery whatever _deliver decides.
        self._sig_pending[sig] = count
        if count >= self.max_batch:
            answers = self.service.flush_all(
                [mid for mid in self.service.matrix_ids
                 if self.service.signature(mid) == sig])
            self._sig_pending[sig] = 0
            self._deliver(answers)
        return (matrix_id, seq)

    def pending(self) -> int:
        """Right-hand sides admitted but not yet flushed, over all tenants."""
        return sum(self._sig_pending.values())

    def drain(self) -> None:
        """Flush every remaining queue (end of a serving window).

        Exception safety (the scheduler-layer extension of `flush_all`'s
        all-or-nothing staging): `flush_all` commits no queue/counter state
        until every bucket's dispatch succeeded, so a dispatch that raises
        mid-drain propagates with the service queues, the per-signature
        counters and every open ticket exactly as they were - `drain()`
        (or the next triggering submit) can simply be retried.  Counters
        are cleared only after `flush_all` returns, i.e. only once the
        queues really were consumed."""
        answers = self.service.flush_all()
        self._sig_pending.clear()   # queues consumed whatever happens next
        self._deliver(answers)

    def check_consistency(self) -> None:
        """Assert scheduler counters agree with the service's queues.

        The invariant the exception-safety contract preserves across
        failed dispatches: for every tenant, open tickets (issued minus
        answered) equal the service's pending queue depth, and the
        per-signature counters are exactly the bucket sums of those
        depths.  Cheap (host-side dict walks); failure-injection tests
        call it after every induced dispatch error, and a production
        caller may call it at flush boundaries."""
        per_sig: Dict[tuple, int] = {}
        for mid in self.service.matrix_ids:
            depth = self.service.pending(mid)
            open_tickets = (self._submitted.get(mid, 0)
                            - self._delivered.get(mid, 0))
            if depth != open_tickets:
                raise AssertionError(
                    f"tenant {mid!r}: {depth} queued rhs vs "
                    f"{open_tickets} open tickets")
            if depth:
                sig = self.service.signature(mid)
                per_sig[sig] = per_sig.get(sig, 0) + depth
        counters = {s: c for s, c in self._sig_pending.items() if c}
        if per_sig != counters:
            raise AssertionError(
                f"per-signature counters {counters} disagree with "
                f"service queues {per_sig}")

    def ready(self, ticket: tuple) -> bool:
        return ticket in self._results

    def result(self, ticket: tuple) -> np.ndarray:
        """The (n,) host-resident solution for `ticket` (one-shot: the
        entry is dropped)."""
        return self._results.pop(ticket)

    def _deliver(self, answers: Dict[str, np.ndarray]) -> None:
        # deliver every well-formed tenant first, then raise on any
        # contract violation - one externally-written queue must not
        # discard innocent tenants' already-computed answers.  The bad
        # tenant's open tickets are marked consumed (its answers cannot
        # be attributed), so a caller that catches the error and keeps
        # going can never have a *later* flush land on its stale tickets.
        bad = None
        for mid, xs in answers.items():
            base = self._delivered.get(mid, 0)
            open_tickets = self._submitted.get(mid, 0) - base
            if xs.shape[1] > open_tickets:
                bad = (mid, xs.shape[1], open_tickets)
                self._delivered[mid] = self._submitted.get(mid, 0)
                continue
            for j in range(xs.shape[1]):
                self._results[(mid, base + j)] = xs[:, j]
            self._delivered[mid] = base + xs.shape[1]
        if bad is not None:
            raise RuntimeError(
                f"flush answered {bad[1]} rhs for {bad[0]!r} but only "
                f"{bad[2]} tickets are open - the service's queue was "
                f"written outside this scheduler; the tenant's open "
                f"tickets are void")

"""Continuous batching: slot-level request scheduling over a shared cache.

Production serving keeps every batch slot busy: when one sequence finishes,
the next queued request is admitted into its slot immediately - prompts
stream through the same per-token decode step (teacher-forced) while
neighbouring slots keep generating.  This needs per-slot positions (each
sequence is at its own offset), which `attention_decode` supports natively,
plus per-slot cache invalidation on admission (`reset_slots`: attention
validity masks already exclude entries past the new position; recurrent
SSM/RG-LRU states are zeroed explicitly).

The host loop does slot bookkeeping; the per-token step stays one jitted
SPMD program - the standard split in production engines.

`PackedSolverScheduler` (bottom of this module) is the linear-solver
analogue over `serve.SolverService`: the same continuous-batching
discipline applied to the multi-tenant packed flush - admit streaming
(matrix_id, rhs) requests, fire a signature bucket through the packed
`flush_all` dispatch the moment it fills, drain stragglers on demand.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import transformer as tr


@dataclasses.dataclass
class Request:
    req_id: int
    prompt: List[int]
    max_new: int


def _batch_axis(path) -> int:
    return 1 if any(str(getattr(p, "key", "")) == "blocks" for p in path) else 0


def reset_slots(cache, mask: jnp.ndarray):
    """Zero the cache state of slots where mask[b] is True."""

    def one(path, leaf):
        ax = _batch_axis(path)
        shape = [1] * leaf.ndim
        shape[ax] = mask.shape[0]
        m = mask.reshape(shape)
        return jnp.where(m, jnp.zeros_like(leaf), leaf)

    return jax.tree_util.tree_map_with_path(one, cache)


class ContinuousBatchingEngine:
    """Greedy continuous-batching server with `n_slots` parallel lanes."""

    def __init__(self, cfg: ModelConfig, params, n_slots: int,
                 max_len: int, eos_id: Optional[int] = None):
        self.cfg = cfg
        self.params = params
        self.n_slots = n_slots
        self.max_len = max_len
        self.eos_id = eos_id
        self._cache = tr.init_cache(n_slots, max_len, cfg, dtype=jnp.float32)

        def step(params, cache, tokens_t, pos):
            logits, cache = tr.decode_step(params, cache, tokens_t, pos, cfg)
            return jnp.argmax(logits, axis=-1).astype(jnp.int32), cache

        self._step = jax.jit(step, donate_argnums=(1,))
        self._reset = jax.jit(reset_slots)

    def run(self, requests: List[Request]) -> Dict[int, List[int]]:
        """Serve all requests to completion; returns generated ids per req."""
        queue = list(requests)
        out: Dict[int, List[int]] = {r.req_id: [] for r in requests}
        # host-side slot state
        slot_req: List[Optional[Request]] = [None] * self.n_slots
        pos = np.zeros(self.n_slots, np.int32)
        cur = np.zeros(self.n_slots, np.int32)
        n_gen = np.zeros(self.n_slots, np.int32)
        cache = self._cache

        def admit(s):
            nonlocal cache
            if not queue:
                slot_req[s] = None
                return False
            req = queue.pop(0)
            slot_req[s] = req
            pos[s] = 0
            cur[s] = req.prompt[0]
            n_gen[s] = 0
            mask = jnp.asarray(np.arange(self.n_slots) == s)
            cache = self._reset(cache, mask)
            return True

        for s in range(self.n_slots):
            admit(s)

        while any(r is not None for r in slot_req):
            nxt, cache = self._step(self.params, cache,
                                    jnp.asarray(cur), jnp.asarray(pos))
            nxt = np.asarray(nxt)
            for s, req in enumerate(slot_req):
                if req is None:
                    continue
                in_prompt = pos[s] + 1 < len(req.prompt)
                if in_prompt:                      # stream the prompt
                    cur[s] = req.prompt[pos[s] + 1]
                else:                              # generating
                    tok = int(nxt[s])
                    out[req.req_id].append(tok)
                    n_gen[s] += 1
                    cur[s] = tok
                    done = (n_gen[s] >= req.max_new
                            or (self.eos_id is not None
                                and tok == self.eos_id)
                            or pos[s] + 2 >= self.max_len)
                    if done:
                        admit(s)
                        continue
                pos[s] += 1
        return out


class PackedSolverScheduler:
    """Continuous-batching flush policy over a `serve.SolverService`.

    Requests stream in as (matrix_id, rhs) pairs; each `submit` returns a
    ticket.  The moment the submitting matrix's *signature bucket* (all
    tenants sharing its `plan_signature`) accumulates `max_batch` pending
    right-hand sides, that bucket alone flushes through the service's
    packed `flush_all` - one fused dispatch over (tenants x rhs) - while
    other buckets keep filling, exactly the keep-every-slot-busy
    discipline of `ContinuousBatchingEngine` applied to solver tenants.
    `drain()` flushes everything still queued; `result(ticket)` retrieves
    (and drops) a delivered solution, `ready(ticket)` polls.

    The scheduler must be the service's only queue writer: tickets map to
    answer columns by per-tenant submission order, so right-hand sides
    submitted or flushed *directly* on the service while a scheduler is
    attached would shift that mapping.  `_deliver` raises rather than
    mis-assign when it detects more answers than open tickets.  Admission
    is O(1): a per-signature running counter decides the flush trigger,
    and the O(num_tenants) bucket scan happens only when a flush fires.
    """

    def __init__(self, service, max_batch: int = 8):
        self.service = service
        self.max_batch = max_batch
        self._results: Dict[tuple, np.ndarray] = {}
        self._submitted: Dict[str, int] = {}    # tickets issued per tenant
        self._delivered: Dict[str, int] = {}    # tickets answered per tenant
        self._sig_pending: Dict[tuple, int] = {}   # open rhs per signature

    def submit(self, matrix_id: str, b: jnp.ndarray) -> tuple:
        """Queue one rhs; returns its ticket.  May trigger a bucket flush
        (in which case this and every bucket-mate's pending rhs resolve)."""
        self.service.submit(matrix_id, b)
        seq = self._submitted.get(matrix_id, 0)
        self._submitted[matrix_id] = seq + 1
        sig = self.service.signature(matrix_id)
        count = self._sig_pending.get(sig, 0) + 1
        # counter is written before the flush attempt: flush_all is
        # all-or-nothing, so a failed dispatch leaves the queues (and
        # this count) valid for a retry on the next submit or drain.
        # Once flush_all returns, the queues ARE consumed, so the counter
        # resets before delivery whatever _deliver decides.
        self._sig_pending[sig] = count
        if count >= self.max_batch:
            answers = self.service.flush_all(
                [mid for mid in self.service.matrix_ids
                 if self.service.signature(mid) == sig])
            self._sig_pending[sig] = 0
            self._deliver(answers)
        return (matrix_id, seq)

    def pending(self) -> int:
        """Right-hand sides admitted but not yet flushed, over all tenants."""
        return sum(self._sig_pending.values())

    def drain(self) -> None:
        """Flush every remaining queue (end of a serving window).

        Exception safety (the scheduler-layer extension of `flush_all`'s
        all-or-nothing staging): `flush_all` commits no queue/counter state
        until every bucket's dispatch succeeded, so a dispatch that raises
        mid-drain propagates with the service queues, the per-signature
        counters and every open ticket exactly as they were - `drain()`
        (or the next triggering submit) can simply be retried.  Counters
        are cleared only after `flush_all` returns, i.e. only once the
        queues really were consumed."""
        answers = self.service.flush_all()
        self._sig_pending.clear()   # queues consumed whatever happens next
        self._deliver(answers)

    def check_consistency(self) -> None:
        """Assert scheduler counters agree with the service's queues.

        The invariant the exception-safety contract preserves across
        failed dispatches: for every tenant, open tickets (issued minus
        answered) equal the service's pending queue depth, and the
        per-signature counters are exactly the bucket sums of those
        depths.  Cheap (host-side dict walks); failure-injection tests
        call it after every induced dispatch error, and a production
        caller may call it at flush boundaries."""
        per_sig: Dict[tuple, int] = {}
        for mid in self.service.matrix_ids:
            depth = self.service.pending(mid)
            open_tickets = (self._submitted.get(mid, 0)
                            - self._delivered.get(mid, 0))
            if depth != open_tickets:
                raise AssertionError(
                    f"tenant {mid!r}: {depth} queued rhs vs "
                    f"{open_tickets} open tickets")
            if depth:
                sig = self.service.signature(mid)
                per_sig[sig] = per_sig.get(sig, 0) + depth
        counters = {s: c for s, c in self._sig_pending.items() if c}
        if per_sig != counters:
            raise AssertionError(
                f"per-signature counters {counters} disagree with "
                f"service queues {per_sig}")

    def ready(self, ticket: tuple) -> bool:
        return ticket in self._results

    def result(self, ticket: tuple) -> np.ndarray:
        """The (n,) host-resident solution for `ticket` (one-shot: the
        entry is dropped)."""
        return self._results.pop(ticket)

    def _deliver(self, answers: Dict[str, np.ndarray]) -> None:
        # deliver every well-formed tenant first, then raise on any
        # contract violation - one externally-written queue must not
        # discard innocent tenants' already-computed answers.  The bad
        # tenant's open tickets are marked consumed (its answers cannot
        # be attributed), so a caller that catches the error and keeps
        # going can never have a *later* flush land on its stale tickets.
        bad = None
        for mid, xs in answers.items():
            base = self._delivered.get(mid, 0)
            open_tickets = self._submitted.get(mid, 0) - base
            if xs.shape[1] > open_tickets:
                bad = (mid, xs.shape[1], open_tickets)
                self._delivered[mid] = self._submitted.get(mid, 0)
                continue
            for j in range(xs.shape[1]):
                self._results[(mid, base + j)] = xs[:, j]
            self._delivered[mid] = base + xs.shape[1]
        if bad is not None:
            raise RuntimeError(
                f"flush answered {bad[1]} rhs for {bad[0]!r} but only "
                f"{bad[2]} tickets are open - the service's queue was "
                f"written outside this scheduler; the tenant's open "
                f"tickets are void")

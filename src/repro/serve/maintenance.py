"""Drift-aware self-healing: device clock, scrub trends, repair scheduling.

DESIGN - the maintenance subsystem
==================================
Retention drift (the dominant time-domain failure mode of analog compute;
power-law G(t) = G * t^-nu in `physics/dynamics.py` / `core/nonideal.py`)
is something that happens to a programmed plan *while it serves*.  The
maintenance subsystem makes the serving stack live with that:

* **Simulated device clock.**  A `DeviceClock` is shared by everything
  that models time; per-array `programmed_at` timestamps live in
  `MatrixMaintenance`.  Aging never touches the stored conductances -
  drift is a readout effect - so the engine re-finalizes the retained
  FlatPlan at the current `PlanAges` (`ProgrammedSolver.aged`), exactly
  like PR 8's traced `r_wire` override and equally invisible to
  `plan_signature`.

* **Background scrubbing.**  On idle worker cycles the engine probes a
  few physical arrays round-robin: one cheap per-block MVM
  (`a_eff(drift_t=age) @ v` against a baseline recorded at programming
  time) - NOT a full solve, and never consuming a dispatch index, so
  chaos traces replay identically with scrubbing on or off (the
  dispatch-counter contract, TESTING.md).  Each block's relative
  deviation feeds a `BlockTrend` (EWMA slope + one-sided CUSUM of the
  deviation increments) that extrapolates predicted time-to-trip.

* **Proactive block repair.**  When a block's deviation crosses
  `block_trip`, or its trend predicts crossing within `repair_lead`
  clock seconds, the scheduler re-programs JUST that block
  (`ProgrammedSolver.repaired` -> `core.blockamc.repair_blocks` under a
  fresh fold_in key, write-verify included) and splices it into the
  serving stacks - cost scales with the degraded fraction, and the SLO
  canary never trips.  The reactive ladder (canary -> quarantine ->
  full re-program) stays as the backstop.

* **Fleet staggering.**  `ReplicatedSolverFleet` hands a rotating repair
  token to one replica at a time (`repair_gate`); a replica holding the
  token with repairs pending is scored `degraded` - routable at lower
  priority, never `quarantined` - so fleet goodput sees no dip while
  replicas take maintenance windows in turn.

Thresholds are physical: a block's deviation under pure drift is
|1 - age^-nu|, so `block_trip` directly bounds the per-array operator
error the engine tolerates before repairing.
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Callable, Dict, List, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core import blockamc

BlockRef = Tuple[str, int, int]          # ("inv"|"mvm", bucket, index)


class DeviceClock:
    """Advanceable simulated device time (seconds; t=0 at construction).

    Thread-safe; subscribers (engines) are notified outside the lock on
    every `advance`, so an idle worker wakes to scrub as soon as time
    moves even with no traffic in flight.
    """

    def __init__(self, t0: float = 0.0):
        self._t = float(t0)
        self._lock = threading.Lock()
        self._subs: List[Callable[[], None]] = []

    def now(self) -> float:
        with self._lock:
            return self._t

    def advance(self, dt: float) -> float:
        """Move time forward by `dt` seconds; returns the new time."""
        if dt < 0:
            raise ValueError(f"device time cannot run backwards (dt={dt})")
        with self._lock:
            self._t += float(dt)
            t = self._t
            subs = list(self._subs)
        for cb in subs:
            cb()
        return t

    def subscribe(self, cb: Callable[[], None]) -> None:
        with self._lock:
            if cb not in self._subs:
                self._subs.append(cb)

    def unsubscribe(self, cb: Callable[[], None]) -> None:
        with self._lock:
            if cb in self._subs:
                self._subs.remove(cb)


@dataclasses.dataclass(frozen=True)
class MaintenanceConfig:
    """Scrub/repair policy knobs (see the DESIGN note above).

    `scrub_blocks_per_cycle`: probes per idle maintenance cycle (the
    scrub cadence - a full sweep of a plan with B arrays takes
    ceil(B / this) idle cycles at one clock time).
    `block_trip`: relative per-block probe deviation that marks an array
    degraded (repair immediately).
    `repair_lead`: repair when the trend predicts `block_trip` will be
    crossed within this many clock seconds (0 = repair only on trip).
    `repair_batch`: max blocks repaired per maintenance cycle.
    `ewma_alpha` / `min_probes`: trend smoothing and the evidence floor
    before extrapolation is trusted.
    """
    scrub_blocks_per_cycle: int = 8
    block_trip: float = 0.05
    repair_lead: float = 0.0
    repair_batch: int = 8
    ewma_alpha: float = 0.5
    min_probes: int = 2


class BlockTrend:
    """EWMA-slope + CUSUM trend of one block's probe deviation.

    `slope` is an EWMA of the instantaneous deviation rate d(dev)/dt in
    clock units; `cusum` accumulates positive deviation increments (a
    one-sided drift detector - it only ever grows while the block
    degrades, so a noisy flat block never schedules a repair).  Linear
    extrapolation of the concave power-law deviation curve predicts the
    trip *early*, which is the safe direction for proactive repair.
    """

    __slots__ = ("alpha", "t", "dev", "slope", "probes", "cusum")

    def __init__(self, alpha: float = 0.5):
        self.alpha = float(alpha)
        self.t: Optional[float] = None
        self.dev: Optional[float] = None
        self.slope: Optional[float] = None
        self.probes = 0
        self.cusum = 0.0

    def observe(self, t: float, dev: float) -> None:
        if self.t is not None and t > self.t:
            inst = (dev - self.dev) / (t - self.t)
            self.slope = inst if self.slope is None else (
                self.alpha * inst + (1.0 - self.alpha) * self.slope)
            self.cusum = max(0.0, self.cusum + (dev - self.dev))
        self.t, self.dev = float(t), float(dev)
        self.probes += 1

    def ready(self, min_probes: int) -> bool:
        return self.probes >= min_probes and self.slope is not None

    def time_to_trip(self, trip: float) -> float:
        """Predicted clock seconds until `dev` crosses `trip` (inf if the
        trend is flat or improving; 0 if already over)."""
        if self.dev is None:
            return float("inf")
        if self.dev >= trip:
            return 0.0
        if self.slope is None or self.slope <= 0.0:
            return float("inf")
        return (trip - self.dev) / self.slope


class MatrixMaintenance:
    """Per-matrix maintenance state: ages, probe baselines, trends.

    Owned by the engine worker thread; the engine reads gauge summaries
    under its own lock.  Probing needs no digital targets: each block's
    baseline response (fresh `a_eff @ v` at age 1) is recorded at
    program/repair time, and deviation is measured against it - the
    block grades itself relative to its own healthy state.
    """

    def __init__(self, solver: "blockamc.ProgrammedSolver",
                 mcfg: MaintenanceConfig, now: float):
        if not solver.repairable:
            raise ValueError("maintenance needs a repairable solver "
                             "(retained flat plan + partitioned system)")
        self.mcfg = mcfg
        self.refs: Tuple[BlockRef, ...] = tuple(
            r.ref for r in solver.block_map())
        self.programmed_at: Dict[BlockRef, float] = {
            ref: now for ref in self.refs}
        self.probed_at: Dict[BlockRef, float] = {ref: now
                                                 for ref in self.refs}
        self.trends: Dict[BlockRef, BlockTrend] = {
            ref: BlockTrend(mcfg.ewma_alpha) for ref in self.refs}
        self.age_scale = 1.0                       # chaos AcceleratedDrift
        self.block_scale: Dict[BlockRef, float] = {}  # chaos HotBlock
        self.pending: set = set()                  # repairs scheduled
        self.synced_at = now                       # plan ages last baked at
        self.repair_rounds = 0
        self.blocks_repaired = 0
        self._cursor = 0
        self._probe_v: Dict[BlockRef, np.ndarray] = {}
        self._baseline: Dict[BlockRef, np.ndarray] = {}
        for ref in self.refs:
            self._calibrate(solver.flat, solver.cfg, ref)

    # -- block access ----------------------------------------------------

    @staticmethod
    def _pair(fplan: "blockamc.FlatPlan", ref: BlockRef):
        kind, b, i = ref
        grid = (fplan.inv_stacks if kind == "inv" else fplan.mvm_stacks)[b]
        return grid.pair(i)

    def _calibrate(self, fplan, cfg, ref: BlockRef) -> None:
        pair = self._pair(fplan, ref)
        c = pair.shape[1]
        v = np.linspace(1.0, 2.0, c, dtype=np.float64).astype(np.float32)
        v /= np.linalg.norm(v)
        self._probe_v[ref] = v
        self._baseline[ref] = np.asarray(
            pair.a_eff(cfg, drift_t=1.0) @ jnp.asarray(v))

    # -- aging -----------------------------------------------------------

    def age(self, ref: BlockRef, now: float) -> float:
        dt = max(0.0, now - self.programmed_at[ref])
        return 1.0 + dt * self.age_scale * self.block_scale.get(ref, 1.0)

    def plan_ages(self, fplan: "blockamc.FlatPlan",
                  now: float) -> "blockamc.PlanAges":
        def per_bucket(kind, stacks):
            return tuple(
                jnp.asarray([self.age((kind, b, i), now)
                             for i in range(g.shape[-3])], jnp.float32)
                for b, g in enumerate(stacks))
        return blockamc.PlanAges(per_bucket("inv", fplan.inv_stacks),
                                 per_bucket("mvm", fplan.mvm_stacks))

    # -- scrubbing -------------------------------------------------------

    def backlog(self, now: float) -> int:
        """Blocks not yet probed at the current clock time."""
        return sum(1 for ref in self.refs if self.probed_at[ref] < now)

    def probe(self, fplan, cfg, ref: BlockRef, now: float) -> float:
        """One cheap per-block canary MVM; relative deviation vs baseline."""
        pair = self._pair(fplan, ref)
        out = np.asarray(pair.a_eff(
            cfg, drift_t=float(self.age(ref, now)))
            @ jnp.asarray(self._probe_v[ref]))
        base = self._baseline[ref]
        return float(np.linalg.norm(out - base)
                     / (np.linalg.norm(base) + 1e-12))

    def scrub(self, fplan, cfg, now: float, budget: int) -> int:
        """Probe up to `budget` stale blocks round-robin; schedule repairs
        for blocks over `block_trip` or trending into it within
        `repair_lead`.  Returns the number of probes performed."""
        done = 0
        for _ in range(len(self.refs)):
            if done >= budget:
                break
            ref = self.refs[self._cursor]
            self._cursor = (self._cursor + 1) % len(self.refs)
            if self.probed_at[ref] >= now:
                continue
            dev = self.probe(fplan, cfg, ref, now)
            tr = self.trends[ref]
            tr.observe(now, dev)
            self.probed_at[ref] = now
            done += 1
            if dev >= self.mcfg.block_trip or (
                    tr.ready(self.mcfg.min_probes)
                    and tr.time_to_trip(self.mcfg.block_trip)
                    <= self.mcfg.repair_lead):
                self.pending.add(ref)
        return done

    # -- repair bookkeeping ----------------------------------------------

    def note_repaired(self, refs, fplan, cfg, now: float) -> None:
        """Reset age/trend/baseline of just-repaired blocks (fresh
        conductances => fresh self-reference)."""
        for ref in refs:
            self.programmed_at[ref] = now
            self.probed_at[ref] = now
            self.trends[ref] = BlockTrend(self.mcfg.ewma_alpha)
            self.pending.discard(ref)
            self._calibrate(fplan, cfg, ref)
        self.blocks_repaired += len(refs)

    # -- gauges ----------------------------------------------------------

    def gauges(self, now: float) -> Dict[str, float]:
        """Report-only drift gauges for health()/FleetStats/benchmarks."""
        devs = [t.dev for t in self.trends.values() if t.dev is not None]
        slopes = [t.slope for t in self.trends.values()
                  if t.slope is not None]
        ttts = [t.time_to_trip(self.mcfg.block_trip)
                for t in self.trends.values() if t.dev is not None]
        return {
            "age": max(self.age(ref, now) for ref in self.refs),
            "worst_dev": max(devs) if devs else 0.0,
            "trend_slope": max(slopes) if slopes else 0.0,
            "time_to_trip": min(ttts) if ttts else float("inf"),
            "scrub_backlog": float(self.backlog(now)),
            "pending_repairs": float(len(self.pending)),
            "blocks_repaired": float(self.blocks_repaired),
        }

"""Linear-system serving: program a matrix once, stream right-hand sides.

The ROADMAP serving scenario for the paper's cost model (programming the
arrays is the expensive one-time step; every subsequent solve is nearly
free): a registry of `ProgrammedSolver` handles keyed by matrix id, plus a
per-matrix request queue so right-hand sides that arrive between flushes are
solved in one fused `solve_many` call instead of one cascade walk each.

Deliberately synchronous and small - the batching discipline and the
program/solve cost split are the point; transport and scheduling live a
layer up (cf. serve/engine.py for the LM analogue).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp

from repro.core.analog import AnalogConfig
from repro.core.blockamc import ProgrammedSolver


@dataclasses.dataclass
class MatrixStats:
    """Per-programmed-matrix serving counters."""
    program_time_s: float        # time-to-first-solve cost, paid once
    solve_calls: int = 0         # fused solve invocations
    rhs_served: int = 0          # individual right-hand sides solved


class SolverService:
    """Program-once / solve-many front end over `ProgrammedSolver`.

    `program` pays the full programming cost (partition, Schur complements,
    conductance mapping, operator finalization and the first jit) exactly
    once per matrix; `solve` answers immediately; `submit` + `flush` batch
    queued right-hand sides into one fused multi-RHS solve.
    """

    def __init__(self, cfg: AnalogConfig, stages: Optional[int] = None):
        self.cfg = cfg
        self.stages = stages
        self._solvers: Dict[str, ProgrammedSolver] = {}
        self._queues: Dict[str, List[jnp.ndarray]] = {}
        self._stats: Dict[str, MatrixStats] = {}

    def program(self, matrix_id: str, a: jnp.ndarray,
                key: Optional[jax.Array] = None) -> ProgrammedSolver:
        """Program matrix `a` under `matrix_id` (replaces any previous one).

        Blocks until the first solve is hot (plan built, operators
        finalized, executor compiled for the single-rhs and smallest-batch
        shapes) so subsequent solves run at marginal cost - the measured
        wall time is recorded as the matrix's programming cost.  Refuses to
        replace a matrix that still has queued, unanswered right-hand sides
        (flush first).
        """
        if self._queues.get(matrix_id):
            raise RuntimeError(
                f"matrix {matrix_id!r} has {len(self._queues[matrix_id])} "
                f"pending rhs; flush before re-programming")
        key = key if key is not None else jax.random.PRNGKey(0)
        t0 = time.perf_counter()
        solver = ProgrammedSolver.program(a, key, self.cfg, self.stages)
        # Warm the jitted executor (single-rhs and smallest flush batch) as
        # part of programming time; flush pads to powers of two, so each
        # further batch-shape compile happens at most once per doubling.
        jax.block_until_ready(solver.solve(jnp.zeros((solver.n,),
                                                     dtype=a.dtype)))
        jax.block_until_ready(solver.solve(jnp.zeros((solver.n, 1),
                                                     dtype=a.dtype)))
        self._solvers[matrix_id] = solver
        self._queues[matrix_id] = []
        self._stats[matrix_id] = MatrixStats(
            program_time_s=time.perf_counter() - t0)
        return solver

    def solver(self, matrix_id: str) -> ProgrammedSolver:
        return self._solvers[matrix_id]

    def stats(self, matrix_id: str) -> MatrixStats:
        return self._stats[matrix_id]

    @property
    def matrix_ids(self):
        return tuple(self._solvers)

    def solve(self, matrix_id: str, b: jnp.ndarray) -> jnp.ndarray:
        """Immediate solve of one (n,) rhs or an (n, k) batch."""
        x = self._solvers[matrix_id].solve(b)
        st = self._stats[matrix_id]
        st.solve_calls += 1
        st.rhs_served += 1 if b.ndim == 1 else b.shape[1]
        return x

    def submit(self, matrix_id: str, b: jnp.ndarray) -> int:
        """Queue one (n,) rhs for the next flush; returns its queue slot."""
        n = self._solvers[matrix_id].n
        if b.shape != (n,):
            raise ValueError(f"submit takes one ({n},) rhs, got {b.shape}")
        q = self._queues[matrix_id]
        q.append(b)
        return len(q) - 1

    def pending(self, matrix_id: str) -> int:
        return len(self._queues[matrix_id])

    def flush(self, matrix_id: str) -> jnp.ndarray:
        """Solve all queued right-hand sides in one fused call.

        Returns (n, k) solutions, column j answering the j-th submit since
        the last flush; (n, 0) when the queue is empty.  The batch is padded
        to the next power of two before solving (zero columns, sliced away)
        so the jitted executor compiles at most one new shape per doubling
        instead of one per distinct queue length.
        """
        q = self._queues[matrix_id]
        solver = self._solvers[matrix_id]
        if not q:
            return jnp.zeros((solver.n, 0))
        k = len(q)
        k_pad = 1 << (k - 1).bit_length()
        bs = jnp.stack(q, axis=1)
        if k_pad > k:
            bs = jnp.pad(bs, ((0, 0), (0, k_pad - k)))
        xs = solver.solve_many(bs)[:, :k]
        self._queues[matrix_id] = []    # only drop requests once answered
        st = self._stats[matrix_id]
        st.solve_calls += 1
        st.rhs_served += k
        return xs

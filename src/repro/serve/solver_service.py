"""Linear-system serving: program a matrix once, stream right-hand sides.

The ROADMAP serving scenario for the paper's cost model (programming the
arrays is the expensive one-time step; every subsequent solve is nearly
free): a registry of `ProgrammedSolver` handles keyed by matrix id, plus a
per-matrix request queue so right-hand sides that arrive between flushes are
solved in one fused `solve_many` call instead of one cascade walk each.

Deliberately synchronous and small - the batching discipline and the
program/solve cost split are the point; transport and scheduling live a
layer up (cf. serve/engine.py for the LM analogue).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp

from repro.core.analog import AnalogConfig
from repro.core.blockamc import ProgrammedSolver, pad_rhs_pow2
from repro.hybrid import AnalogPreconditioner, solve_refined as _solve_refined


@dataclasses.dataclass
class MatrixStats:
    """Per-programmed-matrix serving counters."""
    program_time_s: float        # time-to-first-solve cost, paid once
    solve_calls: int = 0         # fused solve invocations
    rhs_served: int = 0          # individual right-hand sides solved
    refined_calls: int = 0       # hybrid analog-seed -> Krylov-refine calls
    refine_iters: int = 0        # total digital Krylov iterations spent


class SolverService:
    """Program-once / solve-many front end over `ProgrammedSolver`.

    `program` pays the full programming cost (partition, Schur complements,
    conductance mapping, operator finalization, arena compilation and the
    first jit) exactly once per matrix; `solve` answers immediately;
    `submit` + `flush` batch queued right-hand sides into one fused
    multi-RHS solve.  mode="fused" (default) serves from the arena-form
    single-dispatch executor; mode="reference" keeps the finalized
    schedule (TESTING.md four-way contract).
    """

    def __init__(self, cfg: AnalogConfig, stages: Optional[int] = None,
                 mode: str = "fused"):
        self.cfg = cfg
        self.stages = stages
        self.mode = mode   # "fused" arena executor (default) / "reference"
        self._solvers: Dict[str, ProgrammedSolver] = {}
        self._dense: Dict[str, jnp.ndarray] = {}
        self._queues: Dict[str, List[jnp.ndarray]] = {}
        self._stats: Dict[str, MatrixStats] = {}

    def program(self, matrix_id: str, a: jnp.ndarray,
                key: Optional[jax.Array] = None) -> ProgrammedSolver:
        """Program matrix `a` under `matrix_id` (replaces any previous one).

        Blocks until the first solve is hot (plan built, operators
        finalized, executor compiled for the single-rhs and smallest-batch
        shapes) so subsequent solves run at marginal cost - the measured
        wall time is recorded as the matrix's programming cost.  Refuses to
        replace a matrix that still has queued, unanswered right-hand sides
        (flush first).
        """
        if self._queues.get(matrix_id):
            raise RuntimeError(
                f"matrix {matrix_id!r} has {len(self._queues[matrix_id])} "
                f"pending rhs; flush before re-programming")
        key = key if key is not None else jax.random.PRNGKey(0)
        t0 = time.perf_counter()
        solver = ProgrammedSolver.program(a, key, self.cfg, self.stages,
                                          mode=self.mode)
        # Warm the jitted executor (single-rhs and smallest flush batch) as
        # part of programming time; solve_many pads to powers of two, so
        # each further batch-shape compile happens at most once per
        # doubling regardless of queue length.
        jax.block_until_ready(solver.solve(jnp.zeros((solver.n,),
                                                     dtype=a.dtype)))
        jax.block_until_ready(solver.solve(jnp.zeros((solver.n, 1),
                                                     dtype=a.dtype)))
        self._solvers[matrix_id] = solver
        self._dense[matrix_id] = a   # digital copy for hybrid refinement
        self._queues[matrix_id] = []
        self._stats[matrix_id] = MatrixStats(
            program_time_s=time.perf_counter() - t0)
        return solver

    def solver(self, matrix_id: str) -> ProgrammedSolver:
        return self._solvers[matrix_id]

    def stats(self, matrix_id: str) -> MatrixStats:
        return self._stats[matrix_id]

    @property
    def matrix_ids(self):
        return tuple(self._solvers)

    def solve(self, matrix_id: str, b: jnp.ndarray) -> jnp.ndarray:
        """Immediate solve of one (n,) rhs or an (n, k) batch."""
        x = self._solvers[matrix_id].solve(b)
        st = self._stats[matrix_id]
        st.solve_calls += 1
        st.rhs_served += 1 if b.ndim == 1 else b.shape[1]
        return x

    def solve_refined(self, matrix_id: str, b: jnp.ndarray, *,
                      tol: float = 1e-6, method: str = "cg",
                      maxiter: int = 400, restart: int = 32,
                      use_precond: bool = False) -> jnp.ndarray:
        """Hybrid solve: analog seed from the programmed arrays + digital
        Krylov refinement against the stored digital matrix.

        One fused call per (n,) rhs or (n, k) batch: the programmed solver
        supplies the seed, and `repro.hybrid` polishes to `tol` relative
        residual.  Defaults suit the f32 serving path; program the matrix
        under x64 and pass a tighter tol for full double precision.

        use_precond=False (default) refines seed-only - always convergent
        on the digital side whatever the programming noise.  use_precond=
        True additionally applies the programmed arrays as the Krylov
        preconditioner: much faster when noise x condition is small (see
        TESTING.md), but a strongly perturbed analog inverse can leave the
        SPD cone and stall CG, so it is opt-in for serving.
        """
        x, info = self._refine(matrix_id, b, tol=tol, method=method,
                               maxiter=maxiter, restart=restart,
                               use_precond=use_precond)
        self._count_refined(matrix_id, 1 if b.ndim == 1 else b.shape[1],
                            info)
        return x

    def _refine(self, matrix_id: str, b: jnp.ndarray, *, tol: float = 1e-6,
                method: str = "cg", maxiter: int = 400, restart: int = 32,
                use_precond: bool = False):
        """Stats-free refine core shared by solve_refined and flush."""
        a = self._dense[matrix_id]
        precond = AnalogPreconditioner.from_solver(self._solvers[matrix_id])
        return _solve_refined(a, b, precond, method=method, tol=tol,
                              maxiter=maxiter, restart=restart,
                              use_precond=use_precond)

    def _count_refined(self, matrix_id: str, n_rhs: int, info) -> None:
        st = self._stats[matrix_id]
        st.solve_calls += 1
        st.rhs_served += n_rhs
        st.refined_calls += 1
        st.refine_iters += int(jnp.sum(info.iters))

    def submit(self, matrix_id: str, b: jnp.ndarray) -> int:
        """Queue one (n,) rhs for the next flush; returns its queue slot."""
        n = self._solvers[matrix_id].n
        if b.shape != (n,):
            raise ValueError(f"submit takes one ({n},) rhs, got {b.shape}")
        q = self._queues[matrix_id]
        q.append(b)
        return len(q) - 1

    def pending(self, matrix_id: str) -> int:
        return len(self._queues[matrix_id])

    def flush(self, matrix_id: str, *, refined: bool = False,
              **refine_kw) -> jnp.ndarray:
        """Solve all queued right-hand sides in one fused call.

        Returns (n, k) solutions, column j answering the j-th submit since
        the last flush; (n, 0) when the queue is empty.  `solve_many` owns
        the power-of-two batch padding (so every caller - not just this
        service - compiles at most one new shape per doubling instead of
        one per distinct queue length); the stacked batch buffer is donated
        to the solve, since the queue is dropped once answered anyway.

        refined=True routes the batch through the fused analog-seed ->
        Krylov-refine path instead of the raw analog solve (the batch is
        padded here with zero columns, which start converged and never
        contribute iterations); `refine_kw` forwards to `solve_refined`
        (tol/method/maxiter/...).
        """
        q = self._queues[matrix_id]
        solver = self._solvers[matrix_id]
        if not q:
            return jnp.zeros((solver.n, 0),
                             dtype=self._dense[matrix_id].dtype)
        k = len(q)
        bs = jnp.stack(q, axis=1)
        if refined:
            bs, _ = pad_rhs_pow2(bs)   # the one serving padding policy
            xs_full, info = self._refine(matrix_id, bs, **refine_kw)
            xs = xs_full[:, :k]
            # only the k real columns count as served (padding columns are
            # zero right-hand sides: they start converged, zero iterations)
            self._count_refined(matrix_id, k, info)
        else:
            xs = solver.solve_many(bs, donate=True)
            st = self._stats[matrix_id]
            st.solve_calls += 1
            st.rhs_served += k
        self._queues[matrix_id] = []    # only drop requests once answered
        return xs

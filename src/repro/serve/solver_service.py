"""Linear-system serving: program a matrix once, stream right-hand sides.

The ROADMAP serving scenario for the paper's cost model (programming the
arrays is the expensive one-time step; every subsequent solve is nearly
free): a registry of `ProgrammedSolver` handles keyed by matrix id, plus a
per-matrix request queue so right-hand sides that arrive between flushes are
solved in one fused `solve_many` call instead of one cascade walk each.

Multi-tenant packing: `flush_all` is the cross-matrix analogue of the
per-matrix flush.  Pending queues are grouped by `plan_signature` (the
structural stackability key - see the packed-serving DESIGN note in
core/blockamc.py), each bucket's arena plans are packed leaf-for-leaf on a
leading instance axis (cached per id-set; the plans themselves are
immutable once programmed), ragged per-tenant queue lengths are zero-padded
to one shared power-of-two rhs width via `pad_rhs_pow2`, and the whole
bucket dispatches as ONE `execute_arena_packed` call instead of one
dispatch per tenant.  Answers scatter back per tenant, and per-tenant
counters go through the single `_record` bookkeeping helper so packed
solves are never double-counted.

Deliberately synchronous and small - the batching discipline and the
program/solve cost split are the point; transport and scheduling live a
layer up (cf. serve/engine.py for the LM analogue and
serve/scheduler.py's `PackedSolverScheduler` for the continuous-batching
flush policy over this service).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.analog import AnalogConfig
from repro.core.blockamc import (PackedArenaPlan, ProgrammedSolver,
                                 _execute_arena_packed_donated,
                                 pack_arena_plans, pad_rhs_pow2,
                                 plan_signature)
from repro.hybrid import (AnalogPreconditioner,
                          solve_fallback as _solve_fallback,
                          solve_refined as _solve_refined)


def _require_float_dtype(name: str, arr) -> None:
    """Front-door dtype gate: analog programming and dispatch are float
    pipelines; an int/bool/complex input would be silently cast (or crash
    deep inside a packed dispatch), so reject it with the field name."""
    if not jnp.issubdtype(jnp.asarray(arr).dtype, jnp.floating):
        raise ValueError(
            f"{name} must have a floating dtype, got {jnp.asarray(arr).dtype}"
            f" - cast explicitly if the int/bool input is intentional")


@dataclasses.dataclass
class MatrixStats:
    """Per-programmed-matrix serving counters."""
    program_time_s: float        # time-to-first-solve cost, paid once
    solve_calls: int = 0         # fused solve invocations
    rhs_served: int = 0          # individual right-hand sides solved
    refined_calls: int = 0       # hybrid analog-seed -> Krylov-refine calls
    refine_iters: int = 0        # total digital Krylov iterations spent


class SolverService:
    """Program-once / solve-many front end over `ProgrammedSolver`.

    `program` pays the full programming cost (partition, Schur complements,
    conductance mapping, operator finalization, arena compilation and the
    first jit) exactly once per matrix; `solve` answers immediately;
    `submit` + `flush` batch queued right-hand sides into one fused
    multi-RHS solve.  mode="fused" (default) serves from the arena-form
    single-dispatch executor; mode="reference" keeps the finalized
    schedule (TESTING.md four-way contract).
    """

    def __init__(self, cfg: AnalogConfig, stages: Optional[int] = None,
                 mode: str = "fused"):
        self.cfg = cfg
        self.stages = stages
        self.mode = mode   # "fused" arena executor (default) / "reference"
        self._solvers: Dict[str, ProgrammedSolver] = {}
        self._dense: Dict[str, jnp.ndarray] = {}
        self._queues: Dict[str, List[jnp.ndarray]] = {}
        self._stats: Dict[str, MatrixStats] = {}
        self._sigs: Dict[str, tuple] = {}
        self._cfgs: Dict[str, AnalogConfig] = {}   # per-matrix cfg override
        # packed cross-tenant plans: one cached (id tuple, pack) per
        # signature - the cache is bounded by the number of signatures,
        # not by the 2^M possible pending subsets.  A flush whose bucket
        # membership changed re-packs and replaces the entry; program()
        # invalidates entries containing the re-programmed id.
        self._packs: Dict[tuple, Tuple[Tuple[str, ...],
                                       PackedArenaPlan]] = {}

    def program(self, matrix_id: str, a: jnp.ndarray,
                key: Optional[jax.Array] = None,
                cfg: Optional[AnalogConfig] = None) -> ProgrammedSolver:
        """Program matrix `a` under `matrix_id` (replaces any previous one).

        Blocks until the first solve is hot (plan built, operators
        finalized, executor compiled for the single-rhs and smallest-batch
        shapes) so subsequent solves run at marginal cost - the measured
        wall time is recorded as the matrix's programming cost.  Refuses to
        replace a matrix that still has queued, unanswered right-hand sides
        (flush first - or `discard_pending` on a failover path that owns
        its own request replay, cf. serve/async_engine.py).

        `cfg` overrides the service config for this matrix only - the
        re-program failover path uses it to turn write-verify / fault
        remapping on for a quarantined matrix without re-bucketing healthy
        tenants.  Per-matrix configs compose with `flush_all` for free:
        the config is part of `plan_signature`, so differently-configured
        tenants simply land in different packing buckets.

        Front-door validation: `a` must be a finite square float matrix.
        A NaN/Inf entry would not fail here - it would poison the Schur
        cascade and come back as NaN *answers*, possibly for co-batched
        tenants sharing a packed dispatch - so it is rejected with a
        ValueError before any state changes.
        """
        if self._queues.get(matrix_id):
            raise RuntimeError(
                f"matrix {matrix_id!r} has {len(self._queues[matrix_id])} "
                f"pending rhs; flush before re-programming")
        _require_float_dtype("matrix", a)
        if a.ndim != 2 or a.shape[0] != a.shape[1]:
            raise ValueError(f"matrix must be square 2-D, got {a.shape}")
        if not bool(jnp.all(jnp.isfinite(a))):
            raise ValueError(
                f"matrix {matrix_id!r} contains non-finite entries; "
                f"refusing to program (NaN/Inf would poison every solve "
                f"dispatched against it)")
        cfg = cfg if cfg is not None else self.cfg
        key = key if key is not None else jax.random.PRNGKey(0)
        t0 = time.perf_counter()
        solver = ProgrammedSolver.program(a, key, cfg, self.stages,
                                          mode=self.mode)
        # Warm the jitted executor (single-rhs and smallest flush batch) as
        # part of programming time; solve_many pads to powers of two, so
        # each further batch-shape compile happens at most once per
        # doubling regardless of queue length.
        jax.block_until_ready(solver.solve(jnp.zeros((solver.n,),
                                                     dtype=a.dtype)))
        jax.block_until_ready(solver.solve(jnp.zeros((solver.n, 1),
                                                     dtype=a.dtype)))
        self._solvers[matrix_id] = solver
        self._dense[matrix_id] = a   # digital copy for hybrid refinement
        self._queues[matrix_id] = []
        self._stats[matrix_id] = MatrixStats(
            program_time_s=time.perf_counter() - t0)
        self._cfgs[matrix_id] = cfg
        self._sigs[matrix_id] = plan_signature(a.shape[0], self.stages, cfg)
        # any cached pack containing the replaced plan is stale
        self._packs = {sig: (ids, pp) for sig, (ids, pp)
                       in self._packs.items() if matrix_id not in ids}
        return solver

    def install(self, matrix_id: str, solver: ProgrammedSolver,
                a: jnp.ndarray,
                cfg: Optional[AnalogConfig] = None) -> ProgrammedSolver:
        """Register an already-programmed solver (checkpoint restore).

        The durable-recovery counterpart of `program`: the expensive
        pipeline (partition, Schur, conductance mapping, finalize, arena
        compile) was paid earlier - possibly in another process - and the
        solver's plans were restored from a `ProgramStore` checkpoint.
        Install performs the same front-door validation and executor
        warm-up as `program` (the jit caches are global and keyed on
        treedef + shape, so a restored plan of a signature this process
        has seen is already hot) and records the same bookkeeping, with
        `program_time_s` now measuring restore+warm instead of the full
        write-verify programming cost.  Physics validation (the canary
        residual against the original calibration threshold) is the
        caller's job - the service cannot know the original trip.
        """
        if self._queues.get(matrix_id):
            raise RuntimeError(
                f"matrix {matrix_id!r} has {len(self._queues[matrix_id])} "
                f"pending rhs; flush before re-installing")
        _require_float_dtype("matrix", a)
        if a.ndim != 2 or a.shape[0] != a.shape[1]:
            raise ValueError(f"matrix must be square 2-D, got {a.shape}")
        if a.shape[0] != solver.n:
            raise ValueError(
                f"solver was programmed for n={solver.n}, matrix is "
                f"{a.shape}")
        cfg = cfg if cfg is not None else solver.cfg
        sig = plan_signature(a.shape[0], self.stages, cfg)
        t0 = time.perf_counter()
        jax.block_until_ready(solver.solve(jnp.zeros((solver.n,),
                                                     dtype=a.dtype)))
        jax.block_until_ready(solver.solve(jnp.zeros((solver.n, 1),
                                                     dtype=a.dtype)))
        self._solvers[matrix_id] = solver
        self._dense[matrix_id] = a
        self._queues[matrix_id] = []
        self._stats[matrix_id] = MatrixStats(
            program_time_s=time.perf_counter() - t0)
        self._cfgs[matrix_id] = cfg
        self._sigs[matrix_id] = sig
        self._packs = {s: (ids, pp) for s, (ids, pp)
                       in self._packs.items() if matrix_id not in ids}
        return solver

    def refresh(self, matrix_id: str, solver: ProgrammedSolver) -> None:
        """Swap in a maintained variant of an already-programmed solver.

        The maintenance hot-path: aging re-finalizes and block repair
        splices produce a new `ProgrammedSolver` for the SAME matrix,
        config and plan signature (drift/repair never enter
        `plan_signature`), so queues, stats, sigs and the digital copy
        all stay - only the solver handle and any cached packed plan
        built from its arena are replaced.  Pending right-hand sides are
        fine: they are answered by the refreshed (healthier) solver at
        the next flush, which is the whole point of repairing in place.
        """
        old = self._solvers[matrix_id]          # unknown ids raise KeyError
        if solver.n != old.n:
            raise ValueError(
                f"refresh for {matrix_id!r} changed n: {old.n} -> "
                f"{solver.n}")
        self._solvers[matrix_id] = solver
        self._packs = {sig: (ids, pp) for sig, (ids, pp)
                       in self._packs.items() if matrix_id not in ids}

    def solver(self, matrix_id: str) -> ProgrammedSolver:
        return self._solvers[matrix_id]

    def stats(self, matrix_id: str) -> MatrixStats:
        return self._stats[matrix_id]

    def signature(self, matrix_id: str) -> tuple:
        """The matrix's `plan_signature` (the flush_all bucketing key)."""
        return self._sigs[matrix_id]

    def dense(self, matrix_id: str) -> jnp.ndarray:
        """The stored digital copy of the matrix (residual checks, hybrid
        refinement, digital fallback)."""
        return self._dense[matrix_id]

    def matrix_cfg(self, matrix_id: str) -> AnalogConfig:
        """The config this matrix was programmed under (per-matrix
        override aware; the service default when none was given)."""
        return self._cfgs[matrix_id]

    @property
    def matrix_ids(self):
        return tuple(self._solvers)

    def _record(self, matrix_id: str, n_rhs: int, info=None) -> None:
        """The one per-tenant bookkeeping path: every serving entry point
        (solve, solve_refined, flush, flush_all) counts one fused solve
        call of `n_rhs` right-hand sides here, so no path can double-count.
        `info` (a KrylovResult) marks the call as a hybrid refinement and
        adds its digital iteration count."""
        st = self._stats[matrix_id]
        st.solve_calls += 1
        st.rhs_served += n_rhs
        if info is not None:
            st.refined_calls += 1
            st.refine_iters += int(jnp.sum(info.iters))

    def solve(self, matrix_id: str, b: jnp.ndarray) -> jnp.ndarray:
        """Immediate solve of one (n,) rhs or an (n, k) batch."""
        x = self._solvers[matrix_id].solve(b)
        self._record(matrix_id, 1 if b.ndim == 1 else b.shape[1])
        return x

    def solve_refined(self, matrix_id: str, b: jnp.ndarray, *,
                      tol: float = 1e-6, method: str = "cg",
                      maxiter: int = 400, restart: int = 32,
                      use_precond: bool = False) -> jnp.ndarray:
        """Hybrid solve: analog seed from the programmed arrays + digital
        Krylov refinement against the stored digital matrix.

        One fused call per (n,) rhs or (n, k) batch: the programmed solver
        supplies the seed, and `repro.hybrid` polishes to `tol` relative
        residual.  Defaults suit the f32 serving path; program the matrix
        under x64 and pass a tighter tol for full double precision.

        use_precond=False (default) refines seed-only - always convergent
        on the digital side whatever the programming noise.  use_precond=
        True additionally applies the programmed arrays as the Krylov
        preconditioner: much faster when noise x condition is small (see
        TESTING.md), but a strongly perturbed analog inverse can leave the
        SPD cone and stall CG, so it is opt-in for serving.
        """
        x, info = self._refine(matrix_id, b, tol=tol, method=method,
                               maxiter=maxiter, restart=restart,
                               use_precond=use_precond)
        self._record(matrix_id, 1 if b.ndim == 1 else b.shape[1], info)
        return x

    def solve_fallback(self, matrix_id: str, b: jnp.ndarray, *,
                       tol: float = 1e-6, method: str = "cg",
                       maxiter: int = 800, restart: int = 32) -> jnp.ndarray:
        """Digital-only solve against the stored dense matrix (degraded
        mode - no analog seed, no analog preconditioner).

        The bottom of the quarantine -> re-program -> degrade ladder: the
        programmed arrays are not touched at all, so this answers
        correctly however faulted the device is (a broken crossbar can
        emit non-finite seeds that `solve_refined` would propagate into
        the Krylov recurrence).  Counted as a refined call in the stats -
        the digital iteration spend is the metric that matters.
        """
        a = self._dense[matrix_id]
        x, info = _solve_fallback(a, b, method=method, tol=tol,
                                  maxiter=maxiter, restart=restart)
        self._record(matrix_id, 1 if b.ndim == 1 else b.shape[1], info)
        return x

    def _refine(self, matrix_id: str, b: jnp.ndarray, *, tol: float = 1e-6,
                method: str = "cg", maxiter: int = 400, restart: int = 32,
                use_precond: bool = False):
        """Stats-free refine core shared by solve_refined and flush."""
        a = self._dense[matrix_id]
        precond = AnalogPreconditioner.from_solver(self._solvers[matrix_id])
        return _solve_refined(a, b, precond, method=method, tol=tol,
                              maxiter=maxiter, restart=restart,
                              use_precond=use_precond)

    def submit(self, matrix_id: str, b: jnp.ndarray) -> int:
        """Queue one (n,) rhs for the next flush; returns its queue slot.

        Admission copies the rhs to the host: flushes then assemble each
        batch as one numpy stack and pay a single device upload, instead
        of one stacking dispatch per queued column (which dominated the
        packed flush at production queue depths).  Always a *copy*
        (np.array, not asarray), so a caller reusing one buffer across
        submits cannot mutate an already-queued request.
        """
        n = self._solvers[matrix_id].n
        if b.shape != (n,):
            raise ValueError(f"submit takes one ({n},) rhs, got {b.shape}")
        _require_float_dtype("rhs", b)
        host = np.array(b)
        # Finite-ness is checked on the host snapshot we keep anyway (no
        # extra device sync): one NaN rhs admitted here would ride a fused
        # multi-rhs dispatch and - through the shared matmul - poison
        # nothing *numerically* for neighbours, but it would come back as
        # a NaN answer long after the caller that sent it is gone, and in
        # a packed bucket it would trip residual health tripwires for the
        # whole tenant.  Reject at the front door instead.
        if not np.all(np.isfinite(host)):
            raise ValueError(
                f"rhs for {matrix_id!r} contains non-finite entries; "
                f"rejected at admission (nothing was queued)")
        q = self._queues[matrix_id]
        q.append(host)
        return len(q) - 1

    def pending(self, matrix_id: str) -> int:
        return len(self._queues[matrix_id])

    def discard_pending(self, matrix_id: str) -> int:
        """Drop every queued rhs of one matrix; returns how many.

        The failover escape hatch: `program` refuses to replace a matrix
        with a live queue because the *service* would silently lose those
        requests.  A layer that keeps its own authoritative request copies
        (the async engine replays in-flight requests after a re-program)
        discards the service-side copies first, re-programs, and replays.
        """
        k = len(self._queues[matrix_id])
        self._queues[matrix_id] = []
        return k

    def flush(self, matrix_id: str, *, refined: bool = False,
              **refine_kw) -> jnp.ndarray:
        """Solve all queued right-hand sides in one fused call.

        Returns (n, k) solutions, column j answering the j-th submit since
        the last flush; (n, 0) when the queue is empty.  `solve_many` owns
        the power-of-two batch padding (so every caller - not just this
        service - compiles at most one new shape per doubling instead of
        one per distinct queue length); the stacked batch buffer is donated
        to the solve, since the queue is dropped once answered anyway.

        refined=True routes the batch through the fused analog-seed ->
        Krylov-refine path instead of the raw analog solve (the batch is
        padded here with zero columns, which start converged and never
        contribute iterations); `refine_kw` forwards to `solve_refined`
        (tol/method/maxiter/...).
        """
        q = self._queues[matrix_id]
        solver = self._solvers[matrix_id]
        if not q:
            return jnp.zeros((solver.n, 0),
                             dtype=self._dense[matrix_id].dtype)
        k = len(q)
        if refined:
            bs, _ = pad_rhs_pow2(self._stack_queue(matrix_id))
            xs_full, info = self._refine(matrix_id, bs, **refine_kw)
            xs = xs_full[:, :k]
            # only the k real columns count as served (padding columns are
            # zero right-hand sides: they start converged, zero iterations)
            self._record(matrix_id, k, info)
        else:
            xs = self._solve_queue(matrix_id)
            self._record(matrix_id, k)
        self._queues[matrix_id] = []    # only drop requests once answered
        return xs

    def _stack_queue(self, matrix_id: str) -> jnp.ndarray:
        """One tenant's queue as an (n, k) device batch: one host-side
        numpy stack + one upload (the flush assembly policy)."""
        return jnp.asarray(np.stack(self._queues[matrix_id], axis=1))

    def _solve_queue(self, matrix_id: str) -> jnp.ndarray:
        """The one per-matrix raw-solve body (no state mutation), shared
        by `flush` and `flush_all`'s single-tenant/reference fallback so
        the two paths cannot drift."""
        return self._solvers[matrix_id].solve_many(
            self._stack_queue(matrix_id), donate=True)

    def _packed_plan(self, sig: tuple,
                     ids: Tuple[str, ...]) -> PackedArenaPlan:
        """The packed arena plan for one tenant bucket.

        One entry is cached per *signature* and reused while the bucket's
        membership is stable (the steady state of a saturated service);
        a different pending subset re-packs and replaces it, so the cache
        never holds more than one pack per signature (plans are immutable
        once programmed; program() invalidates)."""
        cached = self._packs.get(sig)
        if cached is not None and cached[0] == ids:
            return cached[1]
        pp = pack_arena_plans([self._solvers[mid].arena for mid in ids])
        self._packs[sig] = (ids, pp)
        return pp

    def flush_all(self, matrix_ids=None):
        """Continuous-batching flush: answer every pending rhs of every
        matrix (or of `matrix_ids`) in one fused dispatch per signature
        bucket.

        Tenants are grouped by `plan_signature`; within a bucket, each
        tenant's queued columns stack to (n, k_i), ragged k_i zero-pad to
        the bucket's shared power-of-two width (`pad_rhs_pow2` - padding
        columns are zero right-hand sides and are sliced away before
        return), the bucket packs to an (M, n, k_pad) batch and ONE
        `execute_arena_packed` call (buffer donated, like `flush`) answers
        the whole fleet.  Returns {matrix_id: (n, k_id) solutions}, column
        j answering the j-th submit since the last flush; ids with empty
        queues are omitted.  All answers come back host-resident numpy
        (the delivery form: one device->host transfer per bucket, one
        small owned copy per tenant - so no answer pins the fleet buffer
        - and per-ticket column delivery is a free numpy view) -
        uniformly, including the fallback paths, so the result type never
        depends on how many tenants happened to be pending.
        Single-tenant buckets and mode="reference" services fall back to
        the per-matrix `flush` (the packed executor is arena-form only).
        """
        if matrix_ids is None:
            ids = tuple(self._queues)
        else:
            ids = tuple(dict.fromkeys(matrix_ids))   # dedupe, keep order
            for mid in ids:
                self._queues[mid]   # unknown ids raise KeyError, like solve
        pending = [mid for mid in ids if self._queues.get(mid)]
        buckets: Dict[tuple, List[str]] = {}
        for mid in pending:
            buckets.setdefault(self._sigs[mid], []).append(mid)
        # Phase 1 - dispatch every bucket WITHOUT touching service state,
        # so a failure in any bucket (pack error, device OOM, ...) leaves
        # every queue and counter exactly as it was: all-or-nothing.
        staged = []                     # (bucket ids, per-tenant ks, xs)
        for sig, bucket in buckets.items():
            if len(bucket) == 1 or self.mode != "fused":
                # single-tenant / reference fallback: the same per-matrix
                # solve body `flush` runs, staged like the packed buckets
                for mid in bucket:
                    staged.append(([mid], [len(self._queues[mid])],
                                   np.asarray(self._solve_queue(mid))[None]))
                continue
            ks = [len(self._queues[mid]) for mid in bucket]
            k_max = max(ks)
            n = self._solvers[bucket[0]].n
            # one host-side (M, n, k_max) assembly + one device upload:
            # ragged tenants zero-pad to the bucket's widest queue; the
            # dtype promotes over every queued column (np.stack promotes
            # within a tenant), matching what per-matrix flushes would do
            tenant_stacks = [np.stack(self._queues[mid], axis=1)
                             for mid in bucket]
            stacked = np.zeros(
                (len(bucket), n, k_max),
                dtype=np.result_type(*(s.dtype for s in tenant_stacks)))
            for i, cols in enumerate(tenant_stacks):
                stacked[i, :, :ks[i]] = cols
            bs, _ = pad_rhs_pow2(jnp.asarray(stacked))   # (M, n, k_pad)
            pp = self._packed_plan(sig, tuple(bucket))
            # one device->host transfer; per-tenant scatter below is one
            # (n, k_id) copy each, so no tenant's answer pins the whole
            # fleet buffer in memory after delivery
            staged.append((bucket, ks,
                           np.asarray(_execute_arena_packed_donated(pp,
                                                                    bs))))
        # Phase 2 - every dispatch succeeded: commit queues and counters.
        results: Dict[str, np.ndarray] = {}
        for bucket, ks, xs_host in staged:
            for i, (mid, k) in enumerate(zip(bucket, ks)):
                results[mid] = xs_host[i, :, :k].copy()
                self._record(mid, k)
                self._queues[mid] = []   # only drop requests once answered
        return results

"""Solver serving: program-once/solve-many, async SLOs, replicated fleet.

The LM generation engine that used to live here (`serve.Engine`,
`serve.serve_step`) moved to `repro.models.lm_engine` /
`repro.models.serve_step` - this package is the *solver* serving stack.
"""
from repro.serve.solver_service import SolverService, MatrixStats  # noqa: F401
from repro.serve.scheduler import PackedSolverScheduler  # noqa: F401
from repro.serve.async_engine import (  # noqa: F401
    AsyncSolverEngine, BackpressureError, DeadlineExceededError,
    EngineError, EngineStats, EngineStoppedError, SolveResult)
from repro.serve.router import (  # noqa: F401
    FleetError, FleetStats, NoReplicaAvailableError, ReplicatedSolverFleet)
from repro.serve.maintenance import (  # noqa: F401
    BlockTrend, DeviceClock, MaintenanceConfig, MatrixMaintenance)

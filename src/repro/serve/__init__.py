from repro.serve.serve_step import make_prefill_step, make_decode_step  # noqa: F401
from repro.serve.engine import Engine  # noqa: F401
from repro.serve.solver_service import SolverService, MatrixStats  # noqa: F401
from repro.serve.scheduler import PackedSolverScheduler  # noqa: F401
from repro.serve.async_engine import (  # noqa: F401
    AsyncSolverEngine, BackpressureError, DeadlineExceededError,
    EngineError, EngineStats, EngineStoppedError, SolveResult)

"""Async solver engine with SLOs: futures, deadlines, backpressure, failover.

`AsyncSolverEngine` turns the synchronous, caller-driven `SolverService`
into a real serving engine (the ROADMAP "millions of users" tentpole):

* **Worker-owned device** (the MaxText JetThread / queue-handoff split,
  echoed in `serve/scheduler.py`'s host-loop/jitted-step discipline): ONE
  background thread owns every device-touching operation - programming,
  packed dispatch, health checks, recovery.  Callers only touch host-side
  admission state under a lock, so no jax dispatch ever races another.
* **Deadline-aware futures**: `submit` returns a `concurrent.futures
  .Future` resolving to a `SolveResult` (answer + serving metadata) or a
  *typed* error - `DeadlineExceededError`, `EngineStoppedError` - never a
  silent hang.  A request whose deadline expires while still queued is
  shed before compute; one that completes late delivers its answer with
  `deadline_missed=True` (the bench counts both as SLO misses).
* **Size OR time flush triggers**: a signature bucket dispatches the
  moment it holds `max_batch` requests, when its oldest request has aged
  `flush_interval`, or when any member's deadline is within
  `deadline_margin` - whichever comes first.
* **Backpressure, never silent drop**: per-signature admission queues are
  bounded at `max_pending`; an overfull bucket rejects with
  `BackpressureError(retry_after_s=...)` at the front door.
* **Fault tolerance on every dispatch**: attempts run under
  `runtime.fault_tolerance.StepWatchdog` (straggler detection + optional
  hard timeout) and `retry_step` (exponential backoff).  A packed
  dispatch that keeps failing falls back to per-matrix isolation so one
  bad tenant cannot take the bucket down.
* **Quarantine -> re-program -> degrade ladder**: after each dispatch the
  engine samples a canary residual ||A x - b|| / ||b|| against the stored
  digital matrix (threshold calibrated at programming time, when the
  device is healthy by construction).  A tripped matrix is quarantined:
  its suspect answers are withheld, the arrays are re-programmed with a
  fresh key under the recovery config (write-verify + fault remapping
  on - the standard mitigations for the drift/stuck-at failure modes in
  `physics/dynamics.py` / `physics/faults.py`), and the in-flight
  requests replay against the fresh arrays.  If `max_reprograms`
  re-programs cannot restore health the matrix degrades to the digital
  `hybrid.refine.solve_fallback` path - every answer still arrives, with
  `mode="digital"` in its metadata.  Recovery health is always judged
  against the *original* calibration threshold, so a broken device can
  never grade its own homework.

Determinism: `runtime.chaos.ChaosInjector` hooks all three fault surfaces
(scripted dispatch exceptions, scripted latency, device faults via the
`NonidealConfig` physics knobs) keyed on the engine's dispatch counter,
so the whole failover ladder is exercised deterministically in tier-1
tests and the `benchmarks/engine_bench.py` chaos smoke.
"""
from __future__ import annotations

import contextlib
import dataclasses
import logging
import threading
import time
from concurrent.futures import Future
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.program_store import CheckpointRejectedError
from repro.runtime.chaos import HotBlock, ReplicaDeathError
from repro.runtime.fault_tolerance import StepWatchdog, retry_step
from repro.serve.maintenance import MaintenanceConfig, MatrixMaintenance

log = logging.getLogger("repro.serve.async_engine")


# ---------------------------------------------------------------------------
# Typed errors: a future resolves to an answer or one of these - never hangs
# ---------------------------------------------------------------------------

class EngineError(RuntimeError):
    """Base class for every engine-surfaced request failure."""


class BackpressureError(EngineError):
    """Admission rejected: the bucket is full.  `retry_after_s` estimates
    when the next flush will have drained it - retry then, don't spin."""

    def __init__(self, message: str, retry_after_s: float):
        super().__init__(message)
        self.retry_after_s = retry_after_s


class DeadlineExceededError(EngineError):
    """The request's deadline passed before an answer could be computed."""


class EngineStoppedError(EngineError):
    """The engine stopped (without drain) before answering this request."""


# ---------------------------------------------------------------------------
# Result / bookkeeping records
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class SolveResult:
    """One answered request plus its serving metadata."""
    x: np.ndarray             # (n,) host-resident solution
    matrix_id: str
    mode: str                 # "analog" | "digital" (degraded fallback)
    health: str               # matrix status at answer time
    reprograms: int           # recovery re-programs this matrix has had
    latency_s: float          # submit -> answer wall time
    deadline_missed: bool     # answered, but after the deadline
    dispatch_index: int       # engine dispatch attempt that answered it
    attempts: int             # dispatch attempts the flush needed (>=1)


@dataclasses.dataclass
class EngineStats:
    """Engine-lifetime counters (worker-written; read after quiescence)."""
    submitted: int = 0
    answered: int = 0
    rejected: int = 0          # BackpressureError at admission
    expired: int = 0           # shed before compute (deadline passed)
    deadline_misses: int = 0   # expired + answered-late
    dispatches: int = 0        # dispatch attempts (retries included)
    retries: int = 0
    straggles: int = 0         # watchdog-flagged slow dispatches
    isolations: int = 0        # packed dispatch fell back to per-matrix
    quarantines: int = 0
    reprograms: int = 0
    degraded: int = 0          # matrices that ended up on the digital path
    replays: int = 0           # requests replayed after a quarantine
    fallback_rhs: int = 0      # rhs answered by the digital fallback
    cancelled: int = 0         # requests cancelled while still queued
    scrub_probes: int = 0      # per-block maintenance canary MVMs
    age_refreshes: int = 0     # plan re-finalizations at new device ages
    repairs: int = 0           # block-repair rounds
    blocks_repaired: int = 0   # physical arrays re-programmed in place
    recovery_s: List[float] = dataclasses.field(default_factory=list)


class _Request:
    __slots__ = ("matrix_id", "b", "deadline", "future", "t_submit")

    def __init__(self, matrix_id: str, b: np.ndarray,
                 deadline: Optional[float], future: Future,
                 t_submit: float):
        self.matrix_id = matrix_id
        self.b = b
        self.deadline = deadline      # absolute time.monotonic(), or None
        self.future = future
        self.t_submit = t_submit


class _MatrixState:
    __slots__ = ("a", "n", "base_key", "base_cfg", "sig", "status",
                 "reprograms", "canary", "canary_norm", "trip",
                 "last_canary", "maint")

    def __init__(self, a: np.ndarray, base_key, base_cfg, sig):
        self.a = a                    # host f-dtype dense copy (residuals)
        self.n = a.shape[0]
        self.base_key = base_key
        self.base_cfg = base_cfg
        self.sig = sig
        self.status = "healthy"       # "healthy" | "degraded"
        self.reprograms = 0
        # deterministic canary rhs: fixed ramp, unit norm - no RNG, so the
        # health tripwire is identical run to run
        c = np.linspace(1.0, 2.0, self.n).astype(a.dtype)
        self.canary = c / np.linalg.norm(c)
        self.canary_norm = float(np.linalg.norm(self.canary))
        self.trip = np.inf            # calibrated right after programming
        self.last_canary = 0.0        # latest measured canary residual
        self.maint = None             # MatrixMaintenance when clock-driven


class AsyncSolverEngine:
    """Background-worker serving engine over a `SolverService`.

    The engine must be the service's only user once started: programming,
    submission and flushing all route through it (the service's own queues
    are used only transiently inside a dispatch attempt, so the service is
    always re-programmable between cycles - the failover precondition).
    """

    def __init__(self, service, *, max_batch: int = 8,
                 flush_interval: float = 0.05,
                 max_pending: int = 64,
                 deadline_margin: float = 0.02,
                 retries: int = 2, backoff: float = 0.01,
                 watchdog_factor: float = 3.0,
                 watchdog_timeout: Optional[float] = None,
                 health_factor: float = 10.0,
                 health_floor: float = 1e-3,
                 health_check_every: int = 1,
                 max_reprograms: int = 2,
                 recovery_nonideal=None,
                 fallback_method: str = "cg",
                 fallback_tol: float = 1e-6,
                 fallback_maxiter: int = 800,
                 chaos=None,
                 clock=None,
                 maintenance: Optional[MaintenanceConfig] = None,
                 scrub: bool = True,
                 repair_gate=None,
                 on_repair=None,
                 name: str = "engine",
                 device=None):
        self.service = service
        self.name = name              # chaos scope + fleet identity
        self.device = device          # optional pinned jax device
        self.max_batch = int(max_batch)
        self.flush_interval = float(flush_interval)
        self.max_pending = int(max_pending)
        self.deadline_margin = float(deadline_margin)
        self.retries = int(retries)
        self.backoff = float(backoff)
        self.health_factor = float(health_factor)
        self.health_floor = float(health_floor)
        self.health_check_every = int(health_check_every)
        self.max_reprograms = int(max_reprograms)
        self.recovery_nonideal = recovery_nonideal
        self.fallback_kw = dict(method=fallback_method, tol=fallback_tol,
                                maxiter=fallback_maxiter)
        self.chaos = chaos
        # drift-aware self-healing (see serve/maintenance.py DESIGN note):
        # `clock` turns on simulated device aging; `scrub=False` keeps the
        # aging but disables the proactive scrub/repair loop (the reactive
        # baseline the maintenance tests and maint_bench compare against).
        # `repair_gate` is a lock-free callable the fleet uses to stagger
        # repair windows (it is read inside the worker's wait predicate
        # with the engine lock held, so it MUST NOT take other locks);
        # `on_repair(matrix_id, solver, key)` lets the fleet re-checkpoint
        # repaired plans.
        self.clock = clock
        self.maintenance = (maintenance if maintenance is not None
                            else MaintenanceConfig())
        self.scrub_on = bool(scrub)
        self.repair_gate = repair_gate
        self.on_repair = on_repair
        self._maint_count = 0         # probe/repair counter - NEVER the
        #                               dispatch counter (chaos determinism)
        self.stats = EngineStats()
        self._watchdog = StepWatchdog(
            factor=watchdog_factor, warmup_steps=5,
            hard_timeout=watchdog_timeout,
            on_straggle=self._on_straggle)

        self._lock = threading.Lock()
        self._work = threading.Condition(self._lock)
        self._queues: Dict[tuple, List[_Request]] = {}
        self._matrix: Dict[str, _MatrixState] = {}
        self._control: List[Tuple[str, tuple, Future]] = []
        self._force_flush = False
        self._running = False
        self._drain_on_stop = True
        self._crashed = False
        self._dispatch_count = 0
        self._cycles = 0
        self._thread: Optional[threading.Thread] = None

    # ------------------------------------------------------------------
    # lifecycle
    # ------------------------------------------------------------------

    def start(self) -> "AsyncSolverEngine":
        if self._thread is not None and self._thread.is_alive():
            raise RuntimeError("engine already running")
        self._running = True
        self._crashed = False
        self._thread = threading.Thread(
            target=self._worker_entry,
            name=f"amc-engine-worker-{self.name}", daemon=True)
        self._thread.start()
        if self.clock is not None:
            self.clock.subscribe(self._wake)
        return self

    @property
    def alive(self) -> bool:
        """Worker thread running and not crashed."""
        return (self._thread is not None and self._thread.is_alive()
                and not self._crashed)

    @property
    def crashed(self) -> bool:
        return self._crashed

    def _on_device(self):
        if self.device is None:
            return contextlib.nullcontext()
        return jax.default_device(self.device)

    def _worker_entry(self) -> None:
        """Worker thread entry: pins the device and models hard death.

        A `ReplicaDeathError` (chaos-scripted or real) terminates the
        loop *without* draining: queued and in-flight futures stay
        unresolved, exactly like a process kill.  Resolving them is the
        fleet's replay contract, not the dying replica's."""
        try:
            with self._on_device():
                self._worker_loop()
        except ReplicaDeathError as e:
            with self._lock:
                self._crashed = True
                self._running = False
            log.error("replica %r worker died: %s", self.name, e)
        except BaseException as e:                     # noqa: BLE001
            # any OTHER escape is a worker crash too: mark the engine
            # dead so `submit` raises EngineStoppedError immediately
            # instead of enqueueing into a thread that no longer exists
            # (futures would hang forever)
            with self._lock:
                self._crashed = True
                self._running = False
            log.exception("engine %r worker crashed: %s", self.name, e)

    def stop(self, drain: bool = True, timeout: Optional[float] = None):
        """Stop the worker.  drain=True answers everything still queued
        first; drain=False resolves leftovers with `EngineStoppedError`.
        Raises if the worker fails to exit within `timeout` (a deadlock
        must fail loudly, not hang the caller)."""
        if self.clock is not None:
            self.clock.unsubscribe(self._wake)
        with self._work:
            self._running = False
            self._drain_on_stop = drain
            self._work.notify_all()
        if self._thread is not None:
            self._thread.join(timeout)
            if self._thread.is_alive():
                raise RuntimeError(
                    "engine worker did not exit within "
                    f"{timeout}s - possible deadlock")

    def __enter__(self) -> "AsyncSolverEngine":
        return self.start()

    def __exit__(self, exc_type, exc, tb):
        self.stop(drain=exc_type is None)
        return False

    # ------------------------------------------------------------------
    # programming (device-touching: runs on the worker once started)
    # ------------------------------------------------------------------

    def program(self, matrix_id: str, a, key=None, cfg=None) -> None:
        """Program a matrix for serving (blocks until hot + calibrated).

        Before `start()` this runs inline; after, it hands off to the
        worker thread (which owns the device) and blocks on the result,
        so callers never race a dispatch.  `cfg` optionally overrides
        the service default per matrix (composes with plan_signature)."""
        self._run_on_worker("program", (matrix_id, a, key, cfg))

    def install(self, matrix_id: str, solver, a, key, trip: float,
                cfg=None) -> None:
        """Install an already-programmed solver (checkpoint restore path).

        Skips the whole programming pipeline - the solver's conductance
        stacks were paid for earlier and persisted.  The canary still
        runs against `trip`, the threshold calibrated at ORIGINAL program
        time: a restored plan that cannot beat the health bar it was
        saved under is rejected with `CheckpointRejectedError` (the
        caller then falls back to full re-programming).  Same worker
        handoff as `program`."""
        self._run_on_worker("install", (matrix_id, solver, a, key, trip,
                                        cfg))

    def _run_on_worker(self, op: str, args: tuple) -> None:
        if self._thread is None or not self._thread.is_alive():
            with self._on_device():
                self._do_control(op, args)
            return
        fut: Future = Future()
        with self._work:
            if not self._running:
                raise EngineStoppedError("engine is stopping")
            self._control.append((op, args, fut))
            self._work.notify_all()
        fut.result()

    def _do_control(self, op: str, args: tuple) -> None:
        if op == "program":
            self._do_program(*args)
        elif op == "install":
            self._do_install(*args)
        else:                                          # pragma: no cover
            raise ValueError(f"unknown control op {op!r}")

    def _do_program(self, matrix_id: str, a, key, cfg=None) -> None:
        key = key if key is not None else jax.random.PRNGKey(0)
        self.service.program(matrix_id, a, key, cfg=cfg)
        st = _MatrixState(np.asarray(a), key,
                          self.service.matrix_cfg(matrix_id),
                          self.service.signature(matrix_id))
        # calibrate the health tripwire while the device is healthy by
        # construction: trip = max(floor, factor x fresh canary residual).
        # Stored once - recovery must beat THIS threshold, so a faulted
        # re-program can never recalibrate itself into "healthy".
        baseline = self._canary_residual(matrix_id, st)
        st.trip = max(self.health_floor, self.health_factor * baseline)
        self._init_maint(matrix_id, st)
        with self._lock:
            self._matrix[matrix_id] = st

    def _do_install(self, matrix_id: str, solver, a, key, trip: float,
                    cfg=None) -> None:
        self.service.install(matrix_id, solver, a, cfg=cfg)
        st = _MatrixState(np.asarray(a), key,
                          self.service.matrix_cfg(matrix_id),
                          self.service.signature(matrix_id))
        st.trip = float(trip)
        resid = self._canary_residual(matrix_id, st)
        if not (resid <= st.trip):
            raise CheckpointRejectedError(
                f"restored plan for {matrix_id!r} fails its original "
                f"calibration: canary residual {resid:.3e} > trip "
                f"{st.trip:.3e}")
        self._init_maint(matrix_id, st)
        with self._lock:
            self._matrix[matrix_id] = st

    # ------------------------------------------------------------------
    # admission
    # ------------------------------------------------------------------

    def submit(self, matrix_id: str, b, *,
               deadline_s: Optional[float] = None) -> Future:
        """Queue one (n,) rhs; returns a Future[SolveResult].

        `deadline_s` is relative (seconds from now).  Raises
        `BackpressureError` when the bucket is full, `ValueError` on
        malformed input, `KeyError` on an unknown matrix - all before any
        state changes, on the caller's thread."""
        with self._lock:
            if not self._running:
                raise EngineStoppedError("engine is not running")
            st = self._matrix[matrix_id]
        b_host = np.array(b)          # snapshot, like SolverService.submit
        if b_host.shape != (st.n,):
            raise ValueError(
                f"submit takes one ({st.n},) rhs, got {b_host.shape}")
        if not np.issubdtype(b_host.dtype, np.floating):
            raise ValueError(f"rhs must be float, got {b_host.dtype}")
        if not np.all(np.isfinite(b_host)):
            raise ValueError(f"rhs for {matrix_id!r} contains non-finite "
                             f"entries; rejected at admission")
        now = time.monotonic()
        deadline = None if deadline_s is None else now + float(deadline_s)
        fut: Future = Future()
        req = _Request(matrix_id, b_host, deadline, fut, now)
        with self._work:
            # a stopped engine AND a dead worker both refuse immediately:
            # enqueueing behind a thread that will never drain the queue
            # turns "typed error now" into "future hangs forever"
            if not self._running or self._crashed or (
                    self._thread is not None
                    and not self._thread.is_alive()):
                raise EngineStoppedError("engine is not running")
            q = self._queues.setdefault(st.sig, [])
            if len(q) >= self.max_pending:
                self.stats.rejected += 1
                oldest = q[0].t_submit
                retry_after = max(
                    0.0, oldest + self.flush_interval - now) or \
                    self.flush_interval
                raise BackpressureError(
                    f"bucket for {matrix_id!r} holds {len(q)} pending rhs "
                    f"(max_pending={self.max_pending}); retry after "
                    f"~{retry_after:.3f}s", retry_after)
            q.append(req)
            self.stats.submitted += 1
            self._work.notify_all()
        return fut

    def flush_now(self) -> None:
        """Force every non-empty bucket due on the next worker wakeup."""
        with self._work:
            self._force_flush = True
            self._work.notify_all()

    def cancel(self, fut: Future) -> bool:
        """Cancel a still-queued request (the hedge-loser path).

        Returns True if the request was removed before dispatch; False
        once it left the queue (the answer will arrive anyway - the
        caller just ignores it).  Never interrupts a running dispatch."""
        with self._work:
            for sig, q in self._queues.items():
                for i, r in enumerate(q):
                    if r.future is fut:
                        del q[i]
                        self.stats.cancelled += 1
                        fut.cancel()
                        return True
        return False

    def outstanding(self) -> List[Tuple[str, np.ndarray, Optional[float],
                                        Future]]:
        """Snapshot of still-queued requests as (matrix_id, b, deadline,
        future) - the fleet's replay source when this replica dies."""
        with self._lock:
            return [(r.matrix_id, r.b, r.deadline, r.future)
                    for q in self._queues.values() for r in q]

    # ------------------------------------------------------------------
    # introspection
    # ------------------------------------------------------------------

    def matrix_status(self, matrix_id: str) -> str:
        with self._lock:
            return self._matrix[matrix_id].status

    def matrix_trip(self, matrix_id: str) -> float:
        """The health-trip threshold calibrated at program time."""
        with self._lock:
            return float(self._matrix[matrix_id].trip)

    def pending(self) -> int:
        with self._lock:
            return sum(len(q) for q in self._queues.values())

    def health_snapshot(self) -> Dict[str, object]:
        """Cheap, lock-scoped health export for a router's scorer.

        "maintenance" carries the per-matrix drift gauges (trend slope,
        predicted time-to-trip, scrub backlog, blocks repaired, ...) -
        report-only observability, surfaced through `FleetStats` and the
        maint_bench artifact keys."""
        t_now = self.clock.now() if self.clock is not None else 0.0
        with self._lock:
            canaries = {mid: st.last_canary
                        for mid, st in self._matrix.items()}
            trips = {mid: st.trip for mid, st in self._matrix.items()}
            statuses = {mid: st.status for mid, st in self._matrix.items()}
            maint = {mid: st.maint.gauges(t_now)
                     for mid, st in self._matrix.items()
                     if st.maint is not None}
            return {
                "name": self.name,
                "alive": (self._thread is not None
                          and self._thread.is_alive()
                          and not self._crashed),
                "queue_depth": sum(len(q) for q in self._queues.values()),
                "answered": self.stats.answered,
                "deadline_misses": self.stats.deadline_misses,
                "quarantines": self.stats.quarantines,
                "canary": canaries,
                "trip": trips,
                "status": statuses,
                "scrub_probes": self.stats.scrub_probes,
                "repairs": self.stats.repairs,
                "blocks_repaired": self.stats.blocks_repaired,
                "maintenance": maint,
            }

    def health(self) -> Dict[str, object]:
        """Alias of `health_snapshot` (the observability entry point)."""
        return self.health_snapshot()

    @property
    def maintenance_pending(self) -> int:
        """Blocks currently scheduled for repair (the fleet's staggering
        signal: a replica with pending repairs wants the repair token)."""
        with self._lock:
            return sum(len(st.maint.pending)
                       for st in self._matrix.values()
                       if st.maint is not None)

    def maintenance_quiesce(self, timeout: float = 30.0) -> bool:
        """Block until the scrubber has nothing left to do at the current
        device time (ages synced, backlog probed, allowed repairs done).
        Returns False on timeout.  Deterministic-scenario helper: advance
        the clock, quiesce, then drive traffic."""
        deadline = time.monotonic() + float(timeout)
        while time.monotonic() < deadline:
            with self._lock:
                due = self._maint_due() and not self._crashed
            if not due:
                return True
            time.sleep(0.002)
        return False

    # ------------------------------------------------------------------
    # worker
    # ------------------------------------------------------------------

    def _on_straggle(self, dt: float, median: float) -> None:
        self.stats.straggles += 1
        log.warning("straggling dispatch: %.3fs (median %.3fs)", dt, median)

    def _bucket_due(self, q: List[_Request], now: float) -> bool:
        if not q:
            return False
        if self._force_flush or len(q) >= self.max_batch:
            return True
        if now - q[0].t_submit >= self.flush_interval:
            return True
        return any(r.deadline is not None
                   and r.deadline - now <= self.deadline_margin for r in q)

    def _next_wakeup(self, now: float) -> Optional[float]:
        """Seconds until the earliest time/deadline trigger, None = idle."""
        t_due = None
        for q in self._queues.values():
            if not q:
                continue
            t = q[0].t_submit + self.flush_interval
            for r in q:
                if r.deadline is not None:
                    t = min(t, r.deadline - self.deadline_margin)
            t_due = t if t_due is None else min(t_due, t)
        if t_due is None:
            return None
        return max(0.0, t_due - now)

    def _worker_loop(self) -> None:
        while True:
            with self._work:
                now = time.monotonic()
                while (self._running and not self._control
                       and not any(self._bucket_due(q, now)
                                   for q in self._queues.values())
                       and not self._maint_due()):
                    self._work.wait(self._next_wakeup(now))
                    now = time.monotonic()
                if not self._running:
                    break
                control = self._control
                self._control = []
                due: List[Tuple[tuple, List[_Request]]] = []
                for sig, q in self._queues.items():
                    if self._bucket_due(q, now):
                        due.append((sig, q))
                        self._queues[sig] = []
                self._force_flush = False
            for op, args, fut in control:
                self._run_control(op, args, fut)
            for _, reqs in due:
                self._dispatch_cycle(reqs)
            if not control and not due:
                # pure maintenance wakeup: the engine scrubs/repairs only
                # on otherwise-idle cycles, so foreground traffic always
                # wins the worker
                self._maintenance_cycle()
        # stopped: drain or void what's left
        with self._lock:
            leftovers = [r for q in self._queues.values() for r in q]
            for sig in self._queues:
                self._queues[sig] = []
            control = self._control
            self._control = []
        for op, args, fut in control:
            fut.set_exception(EngineStoppedError("engine stopped"))
        if self._drain_on_stop and leftovers:
            by_sig: Dict[tuple, List[_Request]] = {}
            for r in leftovers:
                by_sig.setdefault(self._matrix[r.matrix_id].sig,
                                  []).append(r)
            for reqs in by_sig.values():
                self._dispatch_cycle(reqs)
        else:
            for r in leftovers:
                r.future.set_exception(
                    EngineStoppedError("engine stopped before dispatch"))

    def _run_control(self, op: str, args: tuple, fut: Future) -> None:
        try:
            self._do_control(op, args)
            fut.set_result(None)
        except ReplicaDeathError:
            fut.set_exception(EngineStoppedError(
                f"replica {self.name!r} died during {op}"))
            raise
        except BaseException as e:                     # noqa: BLE001
            fut.set_exception(e)

    # ------------------------------------------------------------------
    # dispatch cycle (worker thread only)
    # ------------------------------------------------------------------

    def _dispatch_cycle(self, reqs: List[_Request]) -> None:
        try:
            self._dispatch_cycle_inner(reqs)
        except ReplicaDeathError:
            # hard replica death is NOT contained: the worker dies with
            # these futures unresolved (the fleet replays them), exactly
            # like a process kill mid-dispatch
            raise
        except BaseException as e:                     # noqa: BLE001
            # last-resort containment: no future may ever hang
            log.exception("dispatch cycle failed: %s", e)
            for r in reqs:
                if not r.future.done():
                    r.future.set_exception(e)

    def _dispatch_cycle_inner(self, reqs: List[_Request]) -> None:
        self._cycles += 1
        now = time.monotonic()
        # 1. shed requests whose deadline already passed - no compute
        live: List[_Request] = []
        for r in reqs:
            if r.deadline is not None and now > r.deadline:
                self.stats.expired += 1
                self.stats.deadline_misses += 1
                r.future.set_exception(DeadlineExceededError(
                    f"deadline passed {now - r.deadline:.3f}s before "
                    f"dispatch of {r.matrix_id!r}"))
            else:
                live.append(r)
        if not live:
            return
        # 2. scripted device faults land before the dispatch (chaos);
        #    aging events (accelerated drift / hot blocks) are keyed on
        #    the same dispatch counter - only maintenance PROBES live on
        #    a separate counter
        if self.chaos is not None:
            for ev in self.chaos.faults_due(self._dispatch_count, replica=self.name):
                self._apply_device_fault(ev)
            for ev in self.chaos.aging_due(self._dispatch_count,
                                           replica=self.name):
                self._apply_aging_event(ev)
        # 2b. bake current device ages into the serving plans, so this
        #     dispatch (and its canary) sees the drift accumulated since
        #     the last sync - with or without scrubbing enabled
        self._sync_clock()
        # 3. split per matrix, healthy vs degraded
        groups: Dict[str, List[_Request]] = {}
        for r in live:
            groups.setdefault(r.matrix_id, []).append(r)
        healthy = {mid: rs for mid, rs in groups.items()
                   if self._matrix[mid].status == "healthy"}
        degraded = {mid: rs for mid, rs in groups.items()
                    if self._matrix[mid].status != "healthy"}
        # 4. packed dispatch of the healthy fleet, with per-matrix
        #    isolation as the fallback when the pack itself keeps failing
        if healthy:
            try:
                answers, attempts = self._dispatch_packed(healthy)
                self._settle_healthy(healthy, answers, attempts)
            except Exception as e:                     # noqa: BLE001
                log.warning("packed dispatch failed after retries (%s); "
                            "isolating per matrix", e)
                self.stats.isolations += 1
                self._dispatch_isolated(healthy)
        # 5. degraded tenants always answer via the digital fallback
        for mid, rs in degraded.items():
            self._serve_fallback(mid, rs)

    # -- packed path ----------------------------------------------------

    def _dispatch_packed(self, groups: Dict[str, List[_Request]]):
        ids = list(groups)
        for mid, rs in groups.items():
            for r in rs:
                self.service.submit(mid, r.b)
        attempts = [0]

        def attempt():
            attempts[0] += 1
            idx = self._next_dispatch_index()
            if self.chaos is not None:
                self.chaos.on_dispatch(idx, replica=self.name)
            with self._watchdog:
                return self.service.flush_all(ids)

        try:
            # flush_all is all-or-nothing: a failed attempt leaves the
            # service queues intact, so retries re-flush the same batch
            answers = retry_step(
                attempt, retries=self.retries, backoff=self.backoff,
                on_retry=lambda i, e: self._count_retry(e))
        except BaseException:
            for mid in ids:
                self.service.discard_pending(mid)
            raise
        return answers, attempts[0]

    def _settle_healthy(self, groups: Dict[str, List[_Request]],
                        answers: Dict[str, np.ndarray],
                        attempts: int) -> None:
        """Health-gate each matrix's answers; resolve or quarantine.

        Two passes so a slow recovery never delays its co-batched
        neighbours: every matrix that passes its canary resolves first,
        then the tripped ones (answers withheld - they were computed on a
        faulted device) go down the recovery ladder."""
        check = (self._cycles % self.health_check_every) == 0
        tripped: List[Tuple[str, List[_Request]]] = []
        for mid, rs in groups.items():
            st = self._matrix[mid]
            if check and not self._matrix_healthy(mid, st):
                tripped.append((mid, rs))
                continue
            xs = answers[mid]
            for j, r in enumerate(rs):
                self._resolve(r, xs[:, j], "analog", attempts)
        for mid, rs in tripped:
            self._quarantine_and_recover(mid, rs)

    # -- isolation path -------------------------------------------------

    def _dispatch_isolated(self, groups: Dict[str, List[_Request]]) -> None:
        """Per-matrix dispatch after a packed failure: survivors answer,
        repeat offenders go down the quarantine ladder."""
        for mid, rs in groups.items():
            for r in rs:
                self.service.submit(mid, r.b)
            attempts = [0]

            def attempt(mid=mid):
                attempts[0] += 1
                idx = self._next_dispatch_index()
                if self.chaos is not None:
                    self.chaos.on_dispatch(idx, replica=self.name)
                with self._watchdog:
                    return np.asarray(self.service.flush(mid))

            try:
                xs = retry_step(
                    attempt, retries=self.retries, backoff=self.backoff,
                    on_retry=lambda i, e: self._count_retry(e))
            except Exception:                          # noqa: BLE001
                self.service.discard_pending(mid)
                self._quarantine_and_recover(mid, rs)
                continue
            st = self._matrix[mid]
            if not self._matrix_healthy(mid, st):
                self._quarantine_and_recover(mid, rs)
                continue
            for j, r in enumerate(rs):
                self._resolve(r, xs[:, j], "analog", attempts[0])

    # -- health / recovery ladder ---------------------------------------

    def _canary_residual(self, mid: str, st: _MatrixState) -> float:
        x = np.asarray(self.service.solver(mid).solve(
            jnp.asarray(st.canary)))
        if not np.all(np.isfinite(x)):
            st.last_canary = float("inf")
            return float("inf")
        resid = float(np.linalg.norm(st.a @ x - st.canary) / st.canary_norm)
        st.last_canary = resid
        return resid

    def _matrix_healthy(self, mid: str, st: _MatrixState) -> bool:
        return self._canary_residual(mid, st) <= st.trip

    def _quarantine_and_recover(self, mid: str,
                                replay: List[_Request]) -> None:
        """The ladder: quarantine -> re-program (fresh key, write-verify
        on) -> replay; degrade to digital when health can't be restored."""
        st = self._matrix[mid]
        self.stats.quarantines += 1
        t0 = time.monotonic()
        log.warning("quarantining %r (canary residual over %.2e)",
                    mid, st.trip)
        recovered = False
        for _ in range(self.max_reprograms):
            st.reprograms += 1
            self.stats.reprograms += 1
            key = jax.random.fold_in(st.base_key, st.reprograms)
            ni = self.recovery_nonideal
            if ni is None:
                # default recovery config: the programming-time
                # mitigations the physics subsystem models - write-verify
                # (IR-drop pre-distortion) + fault-aware remapping
                ni = dataclasses.replace(st.base_cfg.nonideal,
                                         compensate_wire=True,
                                         remap_faults=True)
            if self.chaos is not None:
                ni = self.chaos.reprogram_nonideal(mid, ni)
            self.service.program(mid, jnp.asarray(st.a), key,
                                 cfg=st.base_cfg.with_(nonideal=ni))
            with self._lock:
                st.sig = self.service.signature(mid)
            # whole-matrix re-program: every array is fresh, so the old
            # maintenance state (ages, trends, baselines) is void
            self._init_maint(mid, st)
            if self._matrix_healthy(mid, st):
                recovered = True
                break
        self.stats.recovery_s.append(time.monotonic() - t0)
        if recovered:
            with self._lock:
                st.status = "healthy"
            log.warning("recovered %r after %d re-program(s) in %.3fs",
                        mid, st.reprograms, self.stats.recovery_s[-1])
            if replay:
                self.stats.replays += len(replay)
                self._replay(mid, replay)
        else:
            with self._lock:
                st.status = "degraded"
            self.stats.degraded += 1
            log.error("could not restore %r after %d re-programs; "
                      "degrading to digital fallback", mid,
                      self.max_reprograms)
            if replay:
                self.stats.replays += len(replay)
                self._serve_fallback(mid, replay)

    def _replay(self, mid: str, reqs: List[_Request]) -> None:
        """Re-dispatch withheld requests against freshly programmed
        arrays (still inside the current cycle: recovery + replay happen
        before any later flush fires).  Replays get the same retry ladder
        as regular dispatches - a transient error here must not demote a
        just-recovered tenant to the digital path."""
        for r in reqs:
            self.service.submit(mid, r.b)
        attempts = [0]

        def attempt():
            attempts[0] += 1
            idx = self._next_dispatch_index()
            if self.chaos is not None:
                self.chaos.on_dispatch(idx, replica=self.name)
            with self._watchdog:
                return np.asarray(self.service.flush(mid))

        try:
            xs = retry_step(
                attempt, retries=self.retries, backoff=self.backoff,
                on_retry=lambda i, e: self._count_retry(e))
        except Exception:                              # noqa: BLE001
            self.service.discard_pending(mid)
            self._serve_fallback(mid, reqs)
            return
        for j, r in enumerate(reqs):
            self._resolve(r, xs[:, j], "analog", attempts[0])

    def _serve_fallback(self, mid: str, reqs: List[_Request]) -> None:
        """Digital-only degraded mode: one fused fallback solve, answers
        tagged mode="digital"."""
        try:
            bs = jnp.asarray(np.stack([r.b for r in reqs], axis=1))
            idx = self._next_dispatch_index()
            if self.chaos is not None:
                self.chaos.on_dispatch(idx, replica=self.name)
            with self._watchdog:
                xs = np.asarray(self.service.solve_fallback(
                    mid, bs, **self.fallback_kw))
            self.stats.fallback_rhs += len(reqs)
            for j, r in enumerate(reqs):
                self._resolve(r, xs[:, j], "digital", 1)
        except ReplicaDeathError:
            raise                   # hard death: futures stay for replay
        except BaseException as e:                     # noqa: BLE001
            for r in reqs:
                if not r.future.done():
                    r.future.set_exception(e)

    # -- drift maintenance (worker thread only) --------------------------
    #
    # The background scrubber of the maintenance subsystem (DESIGN note in
    # serve/maintenance.py).  Counter discipline: probes and repairs bump
    # `_maint_count`, NEVER `_next_dispatch_index` - a chaos trace replays
    # identically with scrubbing on or off (tests/test_maintenance.py).

    def _wake(self) -> None:
        """DeviceClock subscriber: nudge an idle worker to scrub."""
        with self._work:
            self._work.notify_all()

    def _init_maint(self, matrix_id: str, st: _MatrixState) -> None:
        """(Re)build per-matrix maintenance state after any full program.

        Maintenance needs a device clock, a drift model to age under, and
        a solver retaining its flat plan + partitioned system (checkpoint-
        restored solvers fall back to the reactive ladder)."""
        st.maint = None
        if self.clock is None:
            return
        solver = self.service.solver(matrix_id)
        if not getattr(solver, "repairable", False):
            return
        if self.service.matrix_cfg(matrix_id).nonideal.drift_nu == 0.0:
            return
        st.maint = MatrixMaintenance(solver, self.maintenance,
                                     self.clock.now())

    def _repair_allowed(self) -> bool:
        gate = self.repair_gate
        return gate is None or bool(gate())

    def _maint_due(self) -> bool:
        """Wait-predicate hook (called with the engine lock held - the
        repair gate must therefore be lock-free).  Age syncing for
        non-scrubbing engines happens lazily at dispatch instead, so a
        reactive baseline never wakes for maintenance."""
        if self.clock is None or not self.scrub_on:
            return False
        t = self.clock.now()
        for st in self._matrix.values():
            m = st.maint
            if m is None:
                continue
            if m.synced_at != t or m.backlog(t) > 0:
                return True
            if m.pending and self._repair_allowed():
                return True
        return False

    def _sync_clock(self) -> None:
        """Re-finalize every clock-tracked plan at current device ages
        (cheap no-op while the clock has not moved)."""
        if self.clock is None:
            return
        t = self.clock.now()
        with self._lock:
            items = list(self._matrix.items())
        for mid, st in items:
            if st.maint is not None and st.maint.synced_at != t:
                self._refresh_ages(mid, st, t)

    def _refresh_ages(self, mid: str, st: _MatrixState, t: float) -> None:
        m = st.maint
        solver = self.service.solver(mid)
        self.service.refresh(mid, solver.aged(m.plan_ages(solver.flat, t)))
        m.synced_at = t
        self.stats.age_refreshes += 1

    def _maintenance_cycle(self) -> None:
        """One idle maintenance pass: sync ages, probe a few blocks per
        matrix, repair what is (predicted to be) degrading.  Maintenance
        failures never take serving down: a matrix whose maintenance path
        breaks drops back to the reactive canary ladder."""
        if self.clock is None:
            return
        t = self.clock.now()
        with self._lock:
            items = list(self._matrix.items())
        for mid, st in items:
            m = st.maint
            if m is None:
                continue
            try:
                if m.synced_at != t:
                    self._refresh_ages(mid, st, t)
                if not self.scrub_on:
                    continue
                if m.backlog(t) > 0:
                    solver = self.service.solver(mid)
                    done = m.scrub(solver.flat, solver.cfg, t,
                                   self.maintenance.scrub_blocks_per_cycle)
                    self._maint_count += done
                    self.stats.scrub_probes += done
                if m.pending and self._repair_allowed():
                    self._do_repairs(mid, st, t)
            except ReplicaDeathError:
                raise
            except BaseException as e:                 # noqa: BLE001
                log.exception("maintenance for %r failed (%s); falling "
                              "back to the reactive ladder", mid, e)
                st.maint = None

    def _do_repairs(self, mid: str, st: _MatrixState, t: float) -> None:
        """Re-program just the scheduled blocks and splice them into the
        serving plan (`ProgrammedSolver.repaired`); cost scales with the
        degraded fraction, not n^2."""
        m = st.maint
        blocks = sorted(m.pending)[:self.maintenance.repair_batch]
        solver = self.service.solver(mid)
        if not solver.repairable:                      # pragma: no cover
            m.pending.clear()
            return
        m.repair_rounds += 1
        # fresh fold_in lineage, disjoint from the recovery (reprograms)
        # and chaos-fault (10_000+) key streams
        key = jax.random.fold_in(st.base_key, 20_000 + m.repair_rounds)
        repaired = solver.repaired(blocks, key)
        self.service.refresh(mid, repaired)
        m.note_repaired(blocks, repaired.flat, repaired.cfg, t)
        self._maint_count += 1
        self.stats.repairs += 1
        self.stats.blocks_repaired += len(blocks)
        log.info("repaired %d/%d block(s) of %r at device time %.3f",
                 len(blocks), len(m.refs), mid, t)
        if self.on_repair is not None:
            try:
                self.on_repair(mid, repaired, key)
            except Exception as e:                     # noqa: BLE001
                log.exception("on_repair callback for %r failed: %s",
                              mid, e)

    def _apply_aging_event(self, ev) -> None:
        """Chaos AcceleratedDrift / HotBlock: steepen the aging of a
        matrix (or one of its blocks) from now on.  Nothing is marked
        healthy/unhealthy here - the scrubber (or the canary) has to
        catch the consequences."""
        st = self._matrix.get(ev.matrix_id)
        if st is None or st.maint is None:
            return
        m = st.maint
        if isinstance(ev, HotBlock):
            ref = tuple(ev.block)
            m.block_scale[ref] = (m.block_scale.get(ref, 1.0)
                                  * float(ev.factor))
            log.warning("chaos: hot block %s in %r (x%g aging)",
                        ref, ev.matrix_id, ev.factor)
        else:
            m.age_scale *= float(ev.factor)
            log.warning("chaos: accelerated drift on %r (x%g aging)",
                        ev.matrix_id, ev.factor)
        m.synced_at = None            # force a re-bake at the new rates

    # -- bookkeeping -----------------------------------------------------

    def _apply_device_fault(self, ev) -> None:
        """Chaos DeviceFault: re-program the matrix's arrays under the
        faulty physics config (same dense target, deterministic key).
        The engine treats this exactly like silent hardware degradation -
        nothing is marked; the canary has to catch it."""
        st = self._matrix.get(ev.matrix_id)
        if st is None:
            return
        key = jax.random.fold_in(st.base_key, 10_000 + st.reprograms)
        self.service.program(
            ev.matrix_id, jnp.asarray(st.a), key,
            cfg=st.base_cfg.with_(nonideal=ev.nonideal))
        with self._lock:
            st.sig = self.service.signature(ev.matrix_id)
        self._init_maint(ev.matrix_id, st)
        log.warning("chaos: device fault injected into %r", ev.matrix_id)

    def _next_dispatch_index(self) -> int:
        idx = self._dispatch_count
        self._dispatch_count += 1
        self.stats.dispatches += 1
        return idx

    def _count_retry(self, e: BaseException) -> None:
        self.stats.retries += 1

    def _resolve(self, r: _Request, x: np.ndarray, mode: str,
                 attempts: int) -> None:
        now = time.monotonic()
        missed = r.deadline is not None and now > r.deadline
        if missed:
            self.stats.deadline_misses += 1
        st = self._matrix[r.matrix_id]
        self.stats.answered += 1
        r.future.set_result(SolveResult(
            x=np.array(x), matrix_id=r.matrix_id, mode=mode,
            health=st.status, reprograms=st.reprograms,
            latency_s=now - r.t_submit, deadline_missed=missed,
            dispatch_index=self._dispatch_count, attempts=attempts))

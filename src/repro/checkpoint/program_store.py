"""Durable programmed-state store for analog solvers.

Analog write-verify programming is the expensive, stochastic part of the
BlockAMC pipeline: partitioning, Schur complements, conductance mapping
with per-device noise, operator finalization and arena compilation.  A
`ProgramStore` persists the *result* of that work - the `FinalizedPlan`
and `ArenaPlan` pytrees of a `ProgrammedSolver` - through the atomic
checkpoint layer, so a replacement replica can reinstall conductance
stacks from disk instead of re-programming from scratch.

Validation is layered, cheapest first:

  1. identity:   the manifest records repr(plan_signature), a SHA-256 of
                 the host matrix bytes, and the program key.  A restore
                 against a different matrix, config, or key raises
                 `StaleCheckpointError` before any array is read.
  2. integrity:  the checkpoint layer cross-checks every leaf file
                 against its manifest shape/dtype
                 (`CheckpointCorruptionError`).
  3. physics:    the caller (engine install path) must re-run the canary
                 solve and compare against the trip threshold *calibrated
                 at original program time* (stored in the manifest extra);
                 a restored plan that fails it raises
                 `CheckpointRejectedError` and falls back to full
                 re-programming.  A checkpoint can be bytes-intact yet
                 physically wrong (drifted baseline, store corruption that
                 preserves shape); only a solve can tell.

Restore needs a *template* solver of the same `plan_signature` to supply
the treedef and static aux data - the stackability invariant (equal
signatures => identical treedefs, leaf shapes, and static metadata) is
exactly what makes any surviving same-signature replica a valid template.
"""
from __future__ import annotations

import hashlib
import os
import re
import threading
from typing import Any, Dict, Optional, Tuple

import numpy as np

from repro.checkpoint.ckpt import (CheckpointCorruptionError, CheckpointError,
                                   latest_step, load_manifest,
                                   restore_checkpoint, save_checkpoint)


class StaleCheckpointError(CheckpointError):
    """Checkpoint identity (signature / matrix hash / key) does not match."""


class CheckpointRejectedError(CheckpointError):
    """Restored plan failed post-restore validation (canary residual)."""


def _sanitize(matrix_id: str) -> str:
    return re.sub(r"[^A-Za-z0-9._-]", "_", matrix_id)


def _a_digest(a) -> str:
    arr = np.ascontiguousarray(np.asarray(a, dtype=np.float64))
    return hashlib.sha256(arr.tobytes()).hexdigest()


def _key_digest(key) -> str:
    arr = np.ascontiguousarray(np.asarray(key))
    return hashlib.sha256(arr.tobytes()).hexdigest()


class ProgramStore:
    """Per-matrix atomic save/restore of programmed solver state.

    Layout: <root>/<matrix_id>/step_<N>/ via the checkpoint layer, one
    store per fleet (replicas share programmed state by construction: the
    fleet programs every matrix with the same key on every replica, so
    the stacks are bit-identical and any replica's save serves them all).
    """

    def __init__(self, root: str):
        self.root = root
        os.makedirs(root, exist_ok=True)
        self._lock = threading.Lock()
        self._steps: Dict[str, int] = {}

    def _dir(self, matrix_id: str) -> str:
        return os.path.join(self.root, _sanitize(matrix_id))

    def has(self, matrix_id: str) -> bool:
        return latest_step(self._dir(matrix_id)) is not None

    def matrix_ids(self):
        if not os.path.isdir(self.root):
            return []
        return sorted(d for d in os.listdir(self.root)
                      if latest_step(os.path.join(self.root, d)) is not None)

    # -- save ---------------------------------------------------------------

    def save(self, matrix_id: str, solver, a, key, signature,
             extra: Optional[Dict[str, Any]] = None) -> str:
        """Persist a solver's programmed state with identity metadata.

        `extra` carries caller validation data (canary trip threshold,
        baseline residual) verbatim into the manifest.
        """
        meta = {
            "signature": repr(signature),
            "a_sha256": _a_digest(a),
            "key_sha256": _key_digest(key),
            "mode": solver.mode,
            "n": int(solver.n),
        }
        if extra:
            meta.update(extra)
        tree = {"fin": solver.finalized, "arena": solver.arena}
        with self._lock:
            step = self._steps.get(matrix_id)
            if step is None:
                prev = latest_step(self._dir(matrix_id))
                step = 0 if prev is None else prev + 1
            self._steps[matrix_id] = step + 1
        return save_checkpoint(self._dir(matrix_id), step, tree, extra=meta)

    # -- restore ------------------------------------------------------------

    def manifest(self, matrix_id: str) -> Dict[str, Any]:
        step = latest_step(self._dir(matrix_id))
        if step is None:
            raise CheckpointError(f"no checkpoint for {matrix_id!r}")
        return load_manifest(self._dir(matrix_id), step)

    def restore(self, matrix_id: str, template, a, key,
                signature) -> Tuple[Any, Dict[str, Any]]:
        """Rebuild a ProgrammedSolver from the latest checkpoint.

        `template` is any live same-signature ProgrammedSolver (e.g. from
        a surviving replica) supplying the treedef/static-aux skeleton.
        Returns (solver, manifest_extra).  Raises StaleCheckpointError on
        identity mismatch, CheckpointCorruptionError on damaged files.
        The caller owns physics validation (canary vs stored trip).
        """
        from repro.core.blockamc import ProgrammedSolver

        directory = self._dir(matrix_id)
        step = latest_step(directory)
        if step is None:
            raise CheckpointError(f"no checkpoint for {matrix_id!r}")
        manifest = load_manifest(directory, step)
        meta = manifest.get("extra", {})
        if meta.get("signature") != repr(signature):
            raise StaleCheckpointError(
                f"{matrix_id!r}: checkpoint signature "
                f"{meta.get('signature')!r} != expected {repr(signature)!r}")
        if meta.get("a_sha256") != _a_digest(a):
            raise StaleCheckpointError(
                f"{matrix_id!r}: checkpoint was programmed from a different "
                f"matrix (hash mismatch)")
        if meta.get("key_sha256") != _key_digest(key):
            raise StaleCheckpointError(
                f"{matrix_id!r}: checkpoint was programmed with a different "
                f"key")
        like = {"fin": template.finalized, "arena": template.arena}
        tree = restore_checkpoint(directory, step, like)
        solver = ProgrammedSolver(tree["fin"], arena=tree["arena"],
                                  mode=template.mode)
        return solver, meta

    # -- damage hooks (tests / chaos) ---------------------------------------

    def corrupt(self, matrix_id: str, how: str = "values") -> str:
        """Deliberately damage the latest checkpoint (chaos / tests).

        how="values":   perturb every floating-point leaf in place, keeping
                        each file's shape and dtype - manifest-consistent,
                        so only the physics canary can catch it.  (Every
                        leaf, not "the largest": redundant plan forms like
                        the arena's megakernel `program` mean a single-leaf
                        hit can miss the stacks the executor actually
                        reads.)
        how="truncate": truncate the largest leaf file - caught by the
                        integrity layer as CheckpointCorruptionError.
        """
        directory = self._dir(matrix_id)
        step = latest_step(directory)
        if step is None:
            raise CheckpointError(f"no checkpoint for {matrix_id!r}")
        manifest = load_manifest(directory, step)
        cdir = os.path.join(directory, f"step_{step:08d}")
        if how == "truncate":
            biggest = max(manifest["leaves"].values(),
                          key=lambda m: int(np.prod(m["shape"] or [1])))
            fpath = os.path.join(cdir, biggest["file"])
            with open(fpath, "r+b") as f:
                f.truncate(max(0, os.path.getsize(fpath) // 2))
            return fpath
        if how != "values":
            raise ValueError(f"unknown corruption mode {how!r}")
        rng = np.random.default_rng(0)
        touched = None
        for meta in manifest["leaves"].values():
            fpath = os.path.join(cdir, meta["file"])
            arr = np.load(fpath)
            if not np.issubdtype(arr.dtype, np.floating) or arr.size == 0:
                continue
            noise = rng.normal(0.0, 1.0, size=arr.shape)
            np.save(fpath, (arr * 3.0 + arr.std() * noise +
                            1.0).astype(arr.dtype))
            touched = fpath
        if touched is None:
            raise CheckpointError(
                f"{matrix_id!r}: no floating-point leaf to corrupt")
        return touched

"""Fault-tolerant checkpointing: atomic, sharded-aware, async-capable.

Layout: <dir>/step_<N>/ containing one .npy per pytree leaf (keyed by the
flattened path) plus MANIFEST.json (paths, shapes, dtypes, step).  Writes go
to a temp dir renamed into place - a crash mid-save never corrupts the
latest checkpoint - and restore validates the manifest before loading.
`restore_checkpoint(..., sharding_tree=...)` re-device_puts each leaf with
the *target* sharding, which is what makes elastic re-meshing (restore onto
a different mesh shape) a pure restart-path operation.

Leaf keys are `jax.tree_util.keystr` path strings, which distinguish a
dict key from a sequence index (`['0']` vs `[0]`) - the historical
str()-joined keys collapsed the two, so a checkpoint saved from a
list-shaped tree could silently restore into a dict-shaped one.  Files
are named by flatten order (`leaf_00000.npy`), with the manifest carrying
the key -> file map; restore cross-checks every loaded array against the
manifest's recorded shape/dtype and raises `CheckpointCorruptionError`
on any disagreement (a truncated or tampered leaf must never be silently
cast into the target structure).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Callable, Dict, Optional

import jax
import numpy as np

MANIFEST = "MANIFEST.json"


class CheckpointError(RuntimeError):
    """Base class for checkpoint-layer failures."""


class CheckpointCorruptionError(CheckpointError):
    """A leaf file disagrees with its manifest entry (shape/dtype/missing)."""


def _flatten(tree) -> Dict[str, Any]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    return {jax.tree_util.keystr(path): leaf for path, leaf in flat}


def save_checkpoint(directory: str, step: int, tree,
                    extra: Optional[Dict[str, Any]] = None) -> str:
    """Blocking atomic save.  Returns the final checkpoint path.

    `extra` is an optional JSON-serializable dict stored verbatim in the
    manifest under "extra" - validation metadata (programming signatures,
    calibration thresholds) rides along with the arrays it describes.
    """
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    flat = _flatten(tree)
    manifest: Dict[str, Any] = {"step": step, "leaves": {}}
    if extra is not None:
        manifest["extra"] = extra
    for i, (key, leaf) in enumerate(flat.items()):
        arr = np.asarray(jax.device_get(leaf))
        fname = f"leaf_{i:05d}.npy"
        np.save(os.path.join(tmp, fname), arr)
        manifest["leaves"][key] = {
            "file": fname, "shape": list(arr.shape), "dtype": str(arr.dtype)}
    with open(os.path.join(tmp, MANIFEST), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def load_manifest(directory: str, step: int) -> Dict[str, Any]:
    """The raw manifest dict of one checkpoint (metadata-only read)."""
    path = os.path.join(directory, f"step_{step:08d}", MANIFEST)
    with open(path) as f:
        return json.load(f)


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = []
    for name in os.listdir(directory):
        if name.startswith("step_") and not name.endswith(".tmp"):
            if os.path.exists(os.path.join(directory, name, MANIFEST)):
                steps.append(int(name.split("_")[1]))
    return max(steps) if steps else None


def restore_checkpoint(directory: str, step: int, tree_like,
                       sharding_tree=None):
    """Restore into the structure of `tree_like`.

    sharding_tree: optional pytree of jax.sharding.Sharding matching
    tree_like; when given, each leaf is device_put with its target sharding
    (the elastic re-mesh path).
    """
    path = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(path, MANIFEST)) as f:
        manifest = json.load(f)
    flat_like = _flatten(tree_like)
    shard_flat = _flatten(sharding_tree) if sharding_tree is not None else {}
    leaves_meta = manifest["leaves"]
    restored = {}
    for key, like in flat_like.items():
        meta = leaves_meta.get(key)
        if meta is None:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        fpath = os.path.join(path, meta["file"])
        try:
            arr = np.load(fpath)
        except (OSError, ValueError) as e:
            raise CheckpointCorruptionError(
                f"leaf {key!r}: cannot load {meta['file']}: {e}") from e
        if list(arr.shape) != list(meta["shape"]) or \
                str(arr.dtype) != meta["dtype"]:
            raise CheckpointCorruptionError(
                f"leaf {key!r}: file {meta['file']} is "
                f"{arr.dtype}{list(arr.shape)} but manifest recorded "
                f"{meta['dtype']}{meta['shape']}")
        like = np.asarray(like)
        if list(arr.shape) != list(like.shape):
            raise ValueError(
                f"shape mismatch for {key}: ckpt {arr.shape} vs {like.shape}")
        arr = arr.astype(like.dtype)
        if key in shard_flat:
            restored[key] = jax.device_put(arr, shard_flat[key])
        else:
            restored[key] = jax.numpy.asarray(arr)
    # rebuild the tree in tree_like's structure
    paths, treedef = jax.tree_util.tree_flatten_with_path(tree_like)
    keys = [jax.tree_util.keystr(p) for p, _ in paths]
    return jax.tree_util.tree_unflatten(treedef, [restored[k] for k in keys])


class CheckpointManager:
    """Async manager: save every k steps on a worker thread, keep last n."""

    def __init__(self, directory: str, every: int = 100, keep: int = 3):
        self.directory = directory
        self.every = every
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    def maybe_save(self, step: int, tree, blocking: bool = False) -> bool:
        if step % self.every != 0:
            return False
        if self._error is not None:
            raise self._error
        self.wait()
        # Materialise on host *before* handing to the thread so training can
        # mutate device buffers immediately (snapshot semantics).
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)

        def work():
            try:
                save_checkpoint(self.directory, step, host_tree)
                self._gc()
            except BaseException as e:   # surfaced on next maybe_save
                self._error = e

        if blocking:
            work()
        else:
            self._thread = threading.Thread(target=work, daemon=True)
            self._thread.start()
        return True

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            raise self._error

    def _gc(self):
        steps = sorted(
            int(n.split("_")[1]) for n in os.listdir(self.directory)
            if n.startswith("step_") and not n.endswith(".tmp"))
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:08d}"),
                          ignore_errors=True)

from repro.checkpoint.ckpt import (  # noqa: F401
    CheckpointCorruptionError, CheckpointError, CheckpointManager,
    latest_step, load_manifest, restore_checkpoint, save_checkpoint)
from repro.checkpoint.program_store import (  # noqa: F401
    CheckpointRejectedError, ProgramStore, StaleCheckpointError)

"""Device dynamics: retention drift and write-verify programming loops.

Two time-domain behaviours of the RRAM devices, layered on the static
variation/wire models of `core/nonideal.py`:

* **Retention drift** - programmed conductances relax over time following
  the standard power law G(t) = G(t0) * (t/t0)^-nu (t0 = 1 s).  Applied at
  *readout* time (`nonideal.readout_conductance` calls `drift_conductance`
  with the config's static `drift_t`/`drift_nu`), so one programmed plan
  can be evaluated at several retention times without reprogramming.

* **Write-verify** - iterative target-tracking programming: measure the
  *effective* matrix the circuit computes with (through the chosen wire
  model), nudge the programmed conductances by the residual, repeat:

      g <- clip(g + damping * (g_target - H_model(g)), 0, g_max).

  With model="first_order" this generalizes
  `nonideal.compensate_conductances` (same fixed point, expressed through
  the shared H interface); with model="nodal" the loop tracks the exact
  nodal oracle, which is what a hardware write-verify loop - measuring
  real sense currents - actually does.  Convergence: dH/dg = I + O(r G n),
  so the damped iteration contracts in the paper's operating regime.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.nonideal import effective_conductance
from repro.physics.nodal import nodal_effective_conductance


def drift_conductance(g: jnp.ndarray, t: float, nu: float,
                      t0: float = 1.0) -> jnp.ndarray:
    """Power-law retention drift G(t) = G(t0) * (t/t0)^-nu.

    `t` and `nu` are static Python floats (config fields); t <= t0 or
    nu == 0 is the no-drift identity.  The uniform scale factor is the
    standard deterministic drift model - per-device nu dispersion belongs
    to the variation model, not here.
    """
    if nu == 0.0 or t <= 0.0:
        return g
    return g * float((t / t0) ** (-nu))


def drift_traced(g: jnp.ndarray, age, nu: float) -> jnp.ndarray:
    """Traced-age variant of `drift_conductance` for live maintenance.

    `age` may be a traced scalar or a per-device vector (broadcast over
    the trailing array dims); ages are clamped to >= 1.0 - a device
    cannot be younger than freshly programmed, and the power law is
    normalized to t0 = 1.  This is the same factor
    `core.nonideal.readout_conductance` applies under a `drift_t`
    override; it lives here too so physics-level oracles can age a
    conductance stack without importing the serving stack's config
    plumbing.
    """
    if nu == 0.0:
        return g
    t = jnp.maximum(jnp.asarray(age, dtype=g.dtype), 1.0)
    factor = t ** jnp.asarray(-nu, dtype=g.dtype)
    if factor.ndim:
        factor = factor.reshape(factor.shape + (1,) * (g.ndim - factor.ndim))
    return g * factor


def write_verify(g_target: jnp.ndarray, r_seg: float, *,
                 model: str = "nodal", iters: int = 5,
                 damping: float = 1.0,
                 g_max: float | None = None) -> jnp.ndarray:
    """Iterative write-verify against a wire model; returns programmed g.

    Deterministic pre-distortion (the verify step reads the model, not a
    noisy device): after `iters` rounds the *effective* conductance
    H_model(g_prog) tracks g_target.  Programmed values stay physical
    (non-negative, optionally capped at g_max).
    """
    if r_seg == 0.0:
        return g_target
    if model == "first_order":
        heff = lambda g: effective_conductance(g, r_seg)          # noqa: E731
    elif model == "nodal":
        heff = lambda g: nodal_effective_conductance(g, r_seg)    # noqa: E731
    else:
        raise ValueError(f"unknown write-verify model: {model!r}")
    g = g_target
    for _ in range(iters):
        g = g + damping * (g_target - heff(g))
        g = jnp.maximum(g, 0.0) if g_max is None else jnp.clip(g, 0.0, g_max)
    return g

"""Physics-grade crossbar models: nodal wire oracle + device dynamics.

`nodal`    - batched block-tridiagonal MNA solve (the exact wire oracle)
`dynamics` - retention drift and write-verify programming loops
`faults`   - stuck-at injection with fault-aware row/column remapping

Everything integrates through `NonidealConfig` and the shared
programming/readout pipeline in `core/nonideal.py`, so the four BlockAMC
executors and the packed-serving layer consume these models unchanged.
"""
from repro.physics.dynamics import drift_conductance, write_verify
from repro.physics.faults import (apply_stuck_faults,
                                  fault_aware_permutations,
                                  sample_stuck_masks)
from repro.physics.nodal import (nodal_effective_conductance,
                                 nodal_effective_conductance_batched,
                                 nodal_inv_batched, nodal_inv_outputs,
                                 nodal_mvm_batched, nodal_mvm_currents,
                                 row_schur_blocks)

__all__ = [
    "drift_conductance", "write_verify",
    "apply_stuck_faults", "fault_aware_permutations", "sample_stuck_masks",
    "nodal_effective_conductance", "nodal_effective_conductance_batched",
    "nodal_inv_batched", "nodal_inv_outputs",
    "nodal_mvm_batched", "nodal_mvm_currents", "row_schur_blocks",
]

"""Batched nodal (MNA) crossbar solver: the physics-grade wire oracle.

DESIGN NOTE - structured solve of the wordline/bitline Laplacian
----------------------------------------------------------------

The exact crossbar circuit of `core/nonideal.py` (`_crossbar_laplacian`) is
a 2*nr*nc-node resistive network: bitline nodes b(i,j) coupled vertically by
wire segments (conductance gw = 1/r_seg), wordline nodes w(i,j) coupled
horizontally, and the RRAM cell g[i,j] bridging b(i,j) <-> w(i,j).  The
dense-numpy oracle solves the full [A, B; C, D] Laplacian at O((2 nr nc)^3)
- fine as HSPICE's stand-in at n <= 32, hopeless for Monte-Carlo batches.

This module reformulates the same system (same geometry, same answer) so a
whole batch of crossbars is one XLA dispatch:

1. **Residual unknowns.**  We solve for the deviation from the ideal-wire
   operating point, b(i,j) = v_in[j] + beta(i,j) and w(i,j) = omega(i,j)
   (ideal limit: beta = omega = 0).  The Laplacian is unchanged; the right
   hand side becomes O(g) instead of O(gw).  This is what makes float32
   batches usable: the solution *is* the IR-drop effect (~r*G*n relative),
   instead of an O(1) voltage from which the effect would be recovered by
   catastrophic cancellation against gw ~ 1e4 * g.

2. **Wordline elimination.**  Within row i the wordline nodes couple only to
   each other (tridiagonally, via WL segments) and to their own bitline
   nodes (via the cell).  Eliminating them analytically,

       W_i omega_i = g_i * (v_in + beta_i),
       W_i = tridiag(-gw, wd_i, -gw),
       wd_i[j] = g[i,j] + gw*((j>0) + (j<nc-1) + (j==nc-1)),

   (last term: the sense segment to the TIA virtual ground) leaves a
   block-tridiagonal system in beta alone - nr blocks of size nc with
   *constant* off-diagonal blocks -gw*I:

       -gw beta_{i-1} + S_i beta_i - gw beta_{i+1} = rhs_i,
       S_i = diag(db_i) - diag(g_i) W_i^{-1} diag(g_i),
       db_i[j] = g[i,j] + gw*((i>0) + (i<nr-1) + (i==0)),
       rhs_i = g_i * (W_i^{-1}(g_i * v_in) - v_in).

   (db's last term: the driver segment feeding b(0,j).)  Each W_i solve is a
   vectorized Thomas scan; S_i is SPD.

3. **Block-Thomas factor + sweeps.**  One `lax.scan` over rows factors the
   block-tridiagonal system, carrying the explicit inverse

       M_0 = S_0,   M_i = S_i - gw^2 M_{i-1}^{-1},   Minv_i = M_i^{-1}

   (S_i assembled on the fly inside the scan so only the Minv stack - the
   part the solve sweeps need - is ever materialized).  The forward/backward
   sweeps are then pure (nc x nc) matmuls,

       z_i = Minv_i (rhs_i + gw z_{i-1}),      x_i = z_i + gw Minv_i x_{i+1},

   which is exactly the shape the Pallas kernel in
   `kernels/banded_solve.py` runs for a whole Monte-Carlo batch in one
   pallas_call (`use_kernel=True`).  Factorization stays in XLA: the
   recursion is irreducibly sequential and batched `linalg.inv` is already
   optimal there.

4. **Outputs.**  Sense currents I_i = gw * omega_i[nc-1]; the exact
   effective conductance H = sense^T L^{-1} drive falls out of an identity
   drive (`nodal_effective_conductance` - the exact counterpart of the
   first-order `nonideal.effective_conductance`, which is what the
   differential validation suite compares).  The INV feedback circuit
   reduces algebraically to u = -g0 H^{-1} v_in: block-eliminating the
   internal nodes from the augmented MNA system of `mna_inv_outputs` leaves
   precisely the constraint sense^T L^{-1} drive u = -g0 v_in.

Everything here is pure jnp with static shapes: jit-, vmap- and scan-safe.
`r_seg` must be a static Python float (it selects the assembled circuit, as
in the rest of the repo).  Cost per crossbar: nr dense (nc x nc) inverses,
i.e. O(nr nc^3) ~ n^4 instead of the dense oracle's n^6.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# Structured assembly
# ---------------------------------------------------------------------------

def _wl_diag(g: jnp.ndarray, gw: float) -> jnp.ndarray:
    """Diagonal of the per-row wordline tridiagonal W_i; (nr, nc)."""
    nr, nc = g.shape
    j = jnp.arange(nc)
    seg = (j > 0).astype(g.dtype) + (j < nc - 1).astype(g.dtype) \
        + (j == nc - 1).astype(g.dtype)          # sense segment
    return g + gw * seg[None, :]


def _bl_diag(g: jnp.ndarray, gw: float) -> jnp.ndarray:
    """Diagonal entries db_i of the bitline blocks; (nr, nc)."""
    nr, nc = g.shape
    i = jnp.arange(nr)
    seg = (i > 0).astype(g.dtype) + (i < nr - 1).astype(g.dtype) \
        + (i == 0).astype(g.dtype)               # driver segment
    return g + gw * seg[:, None]


def _thomas_solve(d: jnp.ndarray, gw: float, rhs: jnp.ndarray) -> jnp.ndarray:
    """Solve tridiag(-gw, d, -gw) x = rhs with a vectorized Thomas scan.

    d: (..., m) diagonals; rhs: (..., m, k).  Scans over m; everything else
    is batch.  jit/vmap-safe (no data-dependent control flow).
    """
    d_m = jnp.moveaxis(d, -1, 0)                 # (m, ...)
    r_m = jnp.moveaxis(rhs, -2, 0)               # (m, ..., k)
    cp0 = jnp.zeros_like(d_m[0])
    dp0 = jnp.zeros_like(r_m[0])

    def fwd(carry, x):
        cp, dp = carry
        dj, rj = x
        denom = dj + gw * cp                     # b_j - a * cp_{j-1}, a = -gw
        cp_new = -gw / denom
        dp_new = (rj + gw * dp) / denom[..., None]
        return (cp_new, dp_new), (cp_new, dp_new)

    _, (cps, dps) = jax.lax.scan(fwd, (cp0, dp0), (d_m, r_m))

    def bwd(x_next, x):
        cpj, dpj = x
        xj = dpj - cpj[..., None] * x_next
        return xj, xj

    _, xs = jax.lax.scan(bwd, jnp.zeros_like(dp0), (cps[::-1], dps[::-1]))
    return jnp.moveaxis(xs[::-1], 0, -2)


def row_schur_blocks(g: jnp.ndarray, r_seg: float) -> jnp.ndarray:
    """The nr dense (nc x nc) diagonal blocks S_i after WL elimination.

    Exposed for property tests: each S_i is symmetric positive definite and
    the full block-tridiagonal operator (off-blocks -gw I) is SPD.
    """
    g = jnp.asarray(g)
    gw = 1.0 / r_seg
    wd = _wl_diag(g, gw)
    db = _bl_diag(g, gw)

    def one(g_i, wd_i, db_i):
        x = _thomas_solve(wd_i, gw, jnp.diag(g_i))     # W_i^{-1} diag(g_i)
        return jnp.diag(db_i) - g_i[:, None] * x

    return jax.vmap(one)(g, wd, db)


# ---------------------------------------------------------------------------
# Block-Thomas factor + solve sweeps
# ---------------------------------------------------------------------------

def _factor(g: jnp.ndarray, gw: float) -> jnp.ndarray:
    """Scan over rows: assemble S_i on the fly, carry M_i^{-1}; (nr, nc, nc)."""
    nr, nc = g.shape
    wd = _wl_diag(g, gw)
    db = _bl_diag(g, gw)

    def step(minv_prev, row):
        g_i, wd_i, db_i = row
        x = _thomas_solve(wd_i, gw, jnp.diag(g_i))
        s_i = jnp.diag(db_i) - g_i[:, None] * x
        m_i = s_i - (gw * gw) * minv_prev
        minv = jnp.linalg.inv(m_i)
        return minv, minv

    init = jnp.zeros((nc, nc), g.dtype)
    _, minvs = jax.lax.scan(step, init, (g, wd, db))
    return minvs


def _sweeps_jnp(minvs: jnp.ndarray, rhs: jnp.ndarray, gw: float) -> jnp.ndarray:
    """Forward/backward block-Thomas sweeps; same math as the Pallas kernel."""
    z0 = jnp.zeros(rhs.shape[1:], rhs.dtype)

    def fwd(z, x):
        mi, ri = x
        zn = mi @ (ri + gw * z)
        return zn, zn

    _, zs = jax.lax.scan(fwd, z0, (minvs, rhs))

    def bwd(xn, x):
        mi, zi = x
        xi = zi + gw * (mi @ xn)
        return xi, xi

    _, xs = jax.lax.scan(bwd, z0, (minvs[::-1], zs[::-1]))
    return xs[::-1]


def _sweeps(minvs: jnp.ndarray, rhs: jnp.ndarray, gw: float,
            use_kernel: bool) -> jnp.ndarray:
    """Batched sweep dispatch: (B, nr, nc, nc) x (B, nr, nc, k)."""
    if use_kernel:
        from repro.kernels import ops as _ops
        return _ops.block_tridiag_solve(minvs, rhs, gw=gw)
    return jax.vmap(lambda m, r: _sweeps_jnp(m, r, gw))(minvs, rhs)


# ---------------------------------------------------------------------------
# Single-crossbar MVM pipeline (2-D; batch via vmap around the stages)
# ---------------------------------------------------------------------------

def _mvm_prepare(g: jnp.ndarray, v: jnp.ndarray, gw: float):
    """Per-instance stage A: WL diagonals, residual rhs, Minv factor stack."""
    wd = _wl_diag(g, gw)
    gv = g[:, :, None] * v[None, :, :]                 # (nr, nc, k)
    y = _thomas_solve(wd, gw, gv)                      # W_i^{-1}(g_i * v)
    rhs = g[:, :, None] * (y - v[None, :, :])
    minvs = _factor(g, gw)
    return minvs, rhs, wd


def _mvm_recover(g: jnp.ndarray, v: jnp.ndarray, wd: jnp.ndarray,
                 beta: jnp.ndarray, gw: float) -> jnp.ndarray:
    """Per-instance stage C: WL voltages from beta, then sense currents."""
    omega = _thomas_solve(wd, gw, g[:, :, None] * (v[None, :, :] + beta))
    return gw * omega[:, -1, :]                        # (nr, k)


def _mvm_batched(g: jnp.ndarray, v: jnp.ndarray, gw: float,
                 use_kernel: bool) -> jnp.ndarray:
    """(B, nr, nc) x (B, nc, k) -> (B, nr, k) sense currents."""
    minvs, rhs, wd = jax.vmap(lambda gi, vi: _mvm_prepare(gi, vi, gw))(g, v)
    beta = _sweeps(minvs, rhs, gw, use_kernel)
    return jax.vmap(lambda gi, vi, wdi, bi:
                    _mvm_recover(gi, vi, wdi, bi, gw))(g, v, wd, beta)


# ---------------------------------------------------------------------------
# Public API
# ---------------------------------------------------------------------------

def nodal_mvm_currents(g, v_in, r_seg: float, *,
                       use_kernel: bool = False) -> jnp.ndarray:
    """Exact sense currents of the MVM crossbar (batched-JAX nodal solve).

    Drop-in jnp counterpart of `nonideal.mna_mvm_currents` (same geometry,
    pinned to it at rtol 1e-6 in tests/test_physics_oracle.py).  `v_in` may
    be (nc,) or (nc, k); `r_seg` is a static Python float.  Ideal limit
    r_seg == 0 short-circuits to g @ v_in at trace time.
    """
    g = jnp.asarray(g)
    v = jnp.asarray(v_in)
    if r_seg == 0.0:
        return g @ v
    vec = v.ndim == 1
    v2 = v[:, None] if vec else v
    out = _mvm_batched(g[None], v2[None].astype(g.dtype),
                       1.0 / float(r_seg), use_kernel)[0]
    return out[:, 0] if vec else out


def nodal_effective_conductance(g, r_seg: float, *,
                                use_kernel: bool = False) -> jnp.ndarray:
    """Exact effective conductance H = sense^T L^{-1} drive of the wired
    crossbar (identity drive through the MVM solve).

    The physics-grade counterpart of `nonideal.effective_conductance`:
    H @ v equals the exact sense currents for any drive v, so the circuit
    "computes with" H exactly - this is the matrix the differential
    validation suite pins the first-order model against.
    """
    g = jnp.asarray(g)
    if r_seg == 0.0:
        return g
    eye = jnp.eye(g.shape[1], dtype=g.dtype)
    return nodal_mvm_currents(g, eye, r_seg, use_kernel=use_kernel)


def nodal_inv_outputs(g, v_in, r_seg: float, g0: float, *,
                      use_kernel: bool = False) -> jnp.ndarray:
    """Exact OPA outputs of the INV feedback circuit with wire resistance.

    Counterpart of `nonideal.mna_inv_outputs`: block elimination of the
    internal nodes from the augmented system leaves H u = -g0 v_in with
    H the exact effective conductance, so u = -g0 H^{-1} v_in.
    """
    g = jnp.asarray(g)
    nr, nc = g.shape
    assert nr == nc, "INV circuit requires a square array"
    v = jnp.asarray(v_in)
    if r_seg == 0.0:
        return -g0 * jnp.linalg.solve(g, v)
    h = nodal_effective_conductance(g, r_seg, use_kernel=use_kernel)
    return -g0 * jnp.linalg.solve(h, v.astype(h.dtype))


# ---------------------------------------------------------------------------
# Monte-Carlo batches: one dispatch over a stack of crossbars
# ---------------------------------------------------------------------------

def _broadcast_drive(g: jnp.ndarray, v_in) -> tuple[jnp.ndarray, bool]:
    """Normalize v_in to (B, nc, k) against a (B, nr, nc) stack."""
    b, nr, nc = g.shape
    v = jnp.asarray(v_in, dtype=g.dtype)
    vec = False
    if v.ndim == 1:                       # (nc,) shared vector
        vec = True
        v = jnp.broadcast_to(v[None, :, None], (b, nc, 1))
    elif v.ndim == 2:
        if v.shape == (b, nc) and b != nc:   # per-instance vector
            vec = True
            v = v[:, :, None]
        else:                             # (nc, k) shared multi-drive
            # NB: when B == nc a (B, nc) array is read as a shared
            # multi-drive; pass (B, nc, 1) to force per-instance vectors.
            v = jnp.broadcast_to(v[None], (b,) + v.shape)
    return v, vec


def nodal_mvm_batched(g, v_in, r_seg: float, *, chunk: int | None = None,
                      use_kernel: bool = False) -> jnp.ndarray:
    """Sense currents for a whole crossbar batch in one dispatch.

    g: (B, nr, nc) conductance stack; v_in: (nc,), (B, nc), (nc, k) or
    (B, nc, k).  `chunk` bounds peak memory (the Minv factor stack is
    (chunk, nr, nc, nc)) by running the batch through `lax.map` in chunks
    *inside* the same jitted computation - still a single dispatch.
    At (B, n) = (64, 256) use chunk ~ 4: ~1 GB transient instead of ~17 GB.
    """
    g = jnp.asarray(g)
    v, vec = _broadcast_drive(g, v_in)
    if r_seg == 0.0:
        out = jnp.einsum("brc,bck->brk", g, v)
        return out[..., 0] if vec else out
    gw = 1.0 / float(r_seg)
    b = g.shape[0]
    if chunk is None or chunk >= b:
        out = _mvm_batched(g, v, gw, use_kernel)
        return out[..., 0] if vec else out
    pad = (-b) % chunk
    if pad:
        # zero-conductance padding: the wire network alone stays nonsingular
        # (grounded through the driver and sense segments)
        g = jnp.concatenate([g, jnp.zeros((pad,) + g.shape[1:], g.dtype)])
        v = jnp.concatenate([v, jnp.zeros((pad,) + v.shape[1:], v.dtype)])
    gc = g.reshape((-1, chunk) + g.shape[1:])
    vc = v.reshape((-1, chunk) + v.shape[1:])
    out = jax.lax.map(lambda xs: _mvm_batched(xs[0], xs[1], gw, use_kernel),
                      (gc, vc))
    out = out.reshape((-1,) + out.shape[2:])[:b]
    return out[..., 0] if vec else out


def nodal_effective_conductance_batched(g, r_seg: float, *,
                                        chunk: int | None = None,
                                        use_kernel: bool = False
                                        ) -> jnp.ndarray:
    """Exact H for a (B, nr, nc) stack of crossbars; (B, nr, nc) out."""
    g = jnp.asarray(g)
    if r_seg == 0.0:
        return g
    eye = jnp.eye(g.shape[2], dtype=g.dtype)
    return nodal_mvm_batched(g, eye, r_seg, chunk=chunk,
                             use_kernel=use_kernel)


def nodal_inv_batched(g, v_in, r_seg: float, g0: float, *,
                      chunk: int | None = None,
                      use_kernel: bool = False) -> jnp.ndarray:
    """INV outputs for a (B, n, n) stack: u = -g0 H^{-1} v per instance."""
    g = jnp.asarray(g)
    h = nodal_effective_conductance_batched(g, r_seg, chunk=chunk,
                                            use_kernel=use_kernel)
    v, vec = _broadcast_drive(g, v_in)
    out = -g0 * jnp.linalg.solve(h, v)
    return out[..., 0] if vec else out

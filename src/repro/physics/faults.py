"""Stuck-at device faults with fault-aware row/column remapping.

RRAM arrays ship with a fraction of devices stuck at G_on (shorted
filament) or G_off (broken filament / unformed cell), immune to
programming.  Two knobs in `NonidealConfig` inject them at programming
time (`nonideal.program_conductances`); the mitigation modelled here is
the standard one for in-memory computing: the row/column *peripheral
routing* is programmable, so the mapper can choose which logical matrix
row lands on which physical array row and steer faults onto entries that
tolerate them.

Simulation trick - logical space only: a physical fault at (i, j) under
row/column permutations p, q lands on logical entry (p[i], q[j]).  So
instead of permuting the programmed matrix and teaching every executor
about permuted peripherals, we permute the *fault masks* into logical
space and stamp them onto the unpermuted target.  Executors, plans and
the packed-serving layer are untouched.

Remap objective: minimize the per-fault squared target mismatch

    sum over faults  (g_target[logical] - g_stuck)^2,

NOT an aggregate row-energy sort.  The distinction matters for the INV
circuit: ranking rows by total energy steers every fault onto the
globally weakest rows, which minimizes Frobenius error by *concentrating*
the perturbation - and a perturbation concentrated on a few rows is what
pushes an inverted matrix toward singularity.  Per-entry matching instead
exploits the differential mapping directly: every signed entry leaves an
exact zero in one of the two arrays, so most stuck-OFF faults can be
routed onto zero-target entries where they cost nothing, scattered across
the array.  The assignment is a greedy jit-safe matching (one lax.scan of
masked argmins per axis): physical rows in decreasing fault burden pick
the cheapest remaining logical row, then the same for columns.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def sample_stuck_masks(key: jax.Array, shape, p_on: float, p_off: float):
    """Disjoint boolean masks of stuck-ON / stuck-OFF devices (p_on+p_off<=1)."""
    u = jax.random.uniform(key, shape)
    return u < p_on, u >= 1.0 - p_off


def _greedy_assign(cost: jnp.ndarray, burden: jnp.ndarray) -> jnp.ndarray:
    """Greedy min-cost matching: physical slot i (in decreasing `burden`
    order) takes the cheapest still-available logical slot.  Returns p with
    p[i] = logical index hosted by physical i.  Pure scan - jit/vmap-safe."""
    order = jnp.argsort(-burden)

    def step(avail, ci):
        a = jnp.argmin(jnp.where(avail, ci, jnp.inf))
        return avail.at[a].set(False), a

    _, assigned = jax.lax.scan(
        step, jnp.ones(cost.shape[1], bool), cost[order])
    return jnp.zeros(cost.shape[0], dtype=assigned.dtype).at[order].set(
        assigned)


def fault_aware_permutations(g_target: jnp.ndarray, on: jnp.ndarray,
                             off: jnp.ndarray, g_on: float, g_off: float):
    """Fault-aware row then column assignment; returns (p, q) with the
    convention that physical row i hosts logical row p[i] (ditto q for
    columns).  Cost of hosting logical entry (a, b) on a faulty device is
    (g_target[a, b] - g_stuck)^2."""
    fon = on.astype(g_target.dtype)
    foff = off.astype(g_target.dtype)
    con = (g_target - g_on) ** 2           # cost tables per logical entry
    coff = (g_target - g_off) ** 2
    # rows: cost[i, a] = sum_j on[i,j] con[a,j] + off[i,j] coff[a,j]
    cost_r = fon @ con.T + foff @ coff.T
    p = _greedy_assign(cost_r, jnp.sum(fon + foff, axis=1))
    inv_p = jnp.argsort(p)
    on_r, off_r = fon[inv_p], foff[inv_p]  # row-remapped logical masks
    # columns on top of the row assignment:
    # cost[j, b] = sum_a on_r[a,j] con[a,b] + off_r[a,j] coff[a,b]
    cost_c = on_r.T @ con + off_r.T @ coff
    q = _greedy_assign(cost_c, jnp.sum(on_r + off_r, axis=0))
    return p, q


def _apply_stuck_2d(g: jnp.ndarray, g_target: jnp.ndarray, key: jax.Array,
                    p_on: float, p_off: float, g_on: float, g_off: float,
                    remap: bool) -> jnp.ndarray:
    on, off = sample_stuck_masks(key, g.shape, p_on, p_off)
    if remap:
        p, q = fault_aware_permutations(g_target, on, off, g_on, g_off)
        # logical mask: entry (a, b) is faulty iff physical (p^-1 a, q^-1 b) is
        inv_p, inv_q = jnp.argsort(p), jnp.argsort(q)
        on = on[inv_p][:, inv_q]
        off = off[inv_p][:, inv_q]
    return jnp.where(on, g_on, jnp.where(off, g_off, g))


def apply_stuck_faults(g: jnp.ndarray, g_target: jnp.ndarray,
                       key: jax.Array, *, p_on: float, p_off: float,
                       g_on: float, g_off: float,
                       remap: bool = False) -> jnp.ndarray:
    """Stamp stuck-at faults onto a programmed (..., r, c) conductance stack.

    `g` is the post-write-noise state, `g_target` the noiseless targets the
    remapper matches against (the mapper knows its targets, not the noise).
    Faults are drawn independently per trailing 2-D array from `key`.
    """
    lead = g.shape[:-2]
    if not lead:
        return _apply_stuck_2d(g, g_target, key, p_on, p_off, g_on, g_off,
                               remap)
    flat_g = g.reshape((-1,) + g.shape[-2:])
    flat_t = g_target.reshape((-1,) + g.shape[-2:])
    keys = jax.random.split(key, flat_g.shape[0])
    out = jax.vmap(lambda gi, ti, ki: _apply_stuck_2d(
        gi, ti, ki, p_on, p_off, g_on, g_off, remap))(flat_g, flat_t, keys)
    return out.reshape(g.shape)

"""Physics oracle contract: dense-numpy MNA vs the batched-JAX nodal solver.

Ground-truth chain (TESTING.md "physics oracle contract"):

    dense numpy f64 MNA  (O(n^6), n <= 32)      -- HSPICE stand-in
      == batched JAX nodal solve (O(n^4), any n) @ rtol 1e-6   [this file]
      >> first-order wire model (O(n^2), hot path)  [test_wire_validation.py]

Parity tests run under x64 (the conditioning gw/g ~ 1e4 makes f32 parity
meaningless at 1e-6); the dtype-regression test pins the dense oracle to
float64 *without* x64 enabled - the satellite fix for the old `jnp.asarray`
truncation.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.experimental import enable_x64
from tests._hypothesis_compat import HAVE_HYPOTHESIS, given, settings, st


def property_cases(strategies, cases):
    """Hypothesis-or-deterministic property harness: with hypothesis the
    test explores the strategy space; without it the same body runs over a
    fixed case sweep (instead of skipping - the oracle contract must hold
    in the default tier on a bare container too)."""
    def deco(fn):
        if HAVE_HYPOTHESIS:
            return settings(max_examples=10, deadline=None)(
                given(**strategies)(fn))
        names = list(strategies)
        return pytest.mark.parametrize(
            ",".join(names),
            [tuple(c[k] for k in names) for c in cases])(fn)
    return deco

from repro.core import nonideal
from repro.data.matrices import random_rhs, wishart
from repro.kernels import ops, ref
from repro.physics import nodal

G0 = 100e-6


def _positive_array(n, seed=0, nc=None, dtype=np.float64):
    """Positive conductance array + drive vector as numpy (dtype-exact)."""
    rng = np.random.default_rng(seed)
    g = np.abs(rng.standard_normal((n, nc or n))).astype(dtype)
    g = g / g.max() * G0
    v = (np.abs(rng.standard_normal(nc or n)) + 0.1).astype(dtype)
    return g, v


# ---------------------------------------------------------------------------
# Dense-numpy vs batched-JAX parity (the acceptance bound: rtol <= 1e-6)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n", [2, 4, 8, 16])
def test_mvm_parity_dense_vs_nodal(n):
    g, v = _positive_array(n, seed=n)
    with enable_x64():
        i_dense = nonideal.mna_mvm_currents(g, v, 1.0)
        i_nodal = np.asarray(nodal.nodal_mvm_currents(
            jnp.asarray(g), jnp.asarray(v), 1.0))
    np.testing.assert_allclose(i_nodal, i_dense, rtol=1e-6)


@pytest.mark.parametrize("n", [2, 4, 8, 16])
def test_inv_parity_dense_vs_nodal(n):
    g, v = _positive_array(n, seed=100 + n)
    with enable_x64():
        u_dense = nonideal.mna_inv_outputs(g, v, 1.0, G0)
        u_nodal = np.asarray(nodal.nodal_inv_outputs(
            jnp.asarray(g), jnp.asarray(v), 1.0, G0))
    np.testing.assert_allclose(u_nodal, u_dense, rtol=1e-6)


def test_parity_at_n32_both_modes():
    """The acceptance bound at the largest dense-feasible size."""
    g, v = _positive_array(32, seed=7)
    with enable_x64():
        np.testing.assert_allclose(
            np.asarray(nodal.nodal_mvm_currents(jnp.asarray(g),
                                                jnp.asarray(v), 1.0)),
            nonideal.mna_mvm_currents(g, v, 1.0), rtol=1e-6)
        np.testing.assert_allclose(
            np.asarray(nodal.nodal_inv_outputs(jnp.asarray(g),
                                               jnp.asarray(v), 1.0, G0)),
            nonideal.mna_inv_outputs(g, v, 1.0, G0), rtol=1e-6)


@pytest.mark.parametrize("shape", [(8, 5), (5, 8), (1, 6), (6, 1)])
def test_mvm_parity_rectangular(shape):
    """The WL-elimination handles nr != nc (and degenerate 1-wide arrays)."""
    nr, nc = shape
    g, v = _positive_array(nr, seed=nr * 31 + nc, nc=nc)
    with enable_x64():
        np.testing.assert_allclose(
            np.asarray(nodal.nodal_mvm_currents(jnp.asarray(g),
                                                jnp.asarray(v), 1.0)),
            nonideal.mna_mvm_currents(g, v, 1.0), rtol=1e-6)


def test_effective_conductance_is_exact_transfer_matrix():
    """H = sense^T L^-1 drive: columns match unit-drive dense currents, and
    H @ v reproduces the nodal currents for arbitrary drives (linearity)."""
    n = 12
    g, v = _positive_array(n, seed=3)
    with enable_x64():
        h = np.asarray(nodal.nodal_effective_conductance(jnp.asarray(g), 1.0))
        h_dense = np.stack(
            [nonideal.mna_mvm_currents(g, np.eye(n)[:, j], 1.0)
             for j in range(n)], axis=1)
        np.testing.assert_allclose(h, h_dense, rtol=1e-6)
        np.testing.assert_allclose(
            h @ v,
            np.asarray(nodal.nodal_mvm_currents(jnp.asarray(g),
                                                jnp.asarray(v), 1.0)),
            rtol=1e-9)


def test_multi_rhs_matches_column_loop():
    n, k = 10, 4
    g, _ = _positive_array(n, seed=5)
    rng = np.random.default_rng(6)
    vs = np.abs(rng.standard_normal((n, k))) + 0.1
    with enable_x64():
        block = np.asarray(nodal.nodal_mvm_currents(
            jnp.asarray(g), jnp.asarray(vs), 1.0))
        for j in range(k):
            np.testing.assert_allclose(
                block[:, j],
                np.asarray(nodal.nodal_mvm_currents(
                    jnp.asarray(g), jnp.asarray(vs[:, j]), 1.0)),
                rtol=1e-10)


# ---------------------------------------------------------------------------
# Batch semantics: the batch axis is exactly a loop of singles
# ---------------------------------------------------------------------------

def test_batch_axis_is_loop_of_singles():
    b, n = 5, 8
    rng = np.random.default_rng(8)
    g = jnp.asarray(np.abs(rng.standard_normal((b, n, n))).astype(np.float32)
                    * G0)
    v = jnp.asarray((np.abs(rng.standard_normal(n)) + 0.1).astype(np.float32))
    batched = nodal.nodal_mvm_batched(g, v, 1.0)
    for i in range(b):
        np.testing.assert_allclose(
            np.asarray(batched[i]),
            np.asarray(nodal.nodal_mvm_currents(g[i], v, 1.0)),
            rtol=2e-5)
    # chunked execution (with a padding remainder) is the same computation
    chunked = nodal.nodal_mvm_batched(g, v, 1.0, chunk=2)
    np.testing.assert_allclose(np.asarray(chunked), np.asarray(batched),
                               rtol=1e-6)
    # batched effective conductance == per-instance H, with B == nc on
    # purpose: pins the identity-drive broadcast against the (B, nc)
    # vector/multi-drive ambiguity
    g8 = jnp.asarray(np.abs(rng.standard_normal((n, n, n))).astype(np.float32)
                     * G0)
    hb = nodal.nodal_effective_conductance_batched(g8, 1.0)
    for i in range(n):
        np.testing.assert_allclose(
            np.asarray(hb[i]),
            np.asarray(nodal.nodal_effective_conductance(g8[i], 1.0)),
            rtol=2e-5)


def test_inv_batched_matches_singles():
    b, n = 3, 8
    rng = np.random.default_rng(9)
    g = jnp.asarray(np.abs(rng.standard_normal((b, n, n))).astype(np.float32)
                    * G0)
    v = jnp.asarray((np.abs(rng.standard_normal(n)) + 0.1).astype(np.float32))
    batched = nodal.nodal_inv_batched(g, v, 1.0, G0)
    for i in range(b):
        np.testing.assert_allclose(
            np.asarray(batched[i]),
            np.asarray(nodal.nodal_inv_outputs(g[i], v, 1.0, G0)),
            rtol=2e-4)


# ---------------------------------------------------------------------------
# Pallas kernel parity (interpret mode on CPU - the tested contract)
# ---------------------------------------------------------------------------

def test_kernel_sweeps_match_jnp_scans():
    b, n = 4, 8
    rng = np.random.default_rng(10)
    g = jnp.asarray(np.abs(rng.standard_normal((b, n, n))).astype(np.float32)
                    * G0)
    v = jnp.asarray((np.abs(rng.standard_normal(n)) + 0.1).astype(np.float32))
    out_jnp = nodal.nodal_mvm_batched(g, v, 1.0)
    out_ker = nodal.nodal_mvm_batched(g, v, 1.0, use_kernel=True)
    np.testing.assert_allclose(np.asarray(out_ker), np.asarray(out_jnp),
                               rtol=1e-5)


def test_kernel_ops_vs_ref_oracle():
    """Direct kernel wrapper vs the pure-jnp oracle, ragged (pads to 128)."""
    rng = np.random.default_rng(11)
    minv = jnp.asarray(rng.standard_normal((3, 5, 6, 6)).astype(np.float32))
    rhs = jnp.asarray(rng.standard_normal((3, 5, 6, 2)).astype(np.float32))
    out = ops.block_tridiag_solve(minv, rhs, gw=0.7)
    want = ref.block_tridiag_solve_ref(minv, rhs, gw=0.7)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# Dense-oracle dtype regression (satellite fix)
# ---------------------------------------------------------------------------

def test_mna_oracle_returns_float64_without_x64():
    """The dense oracle must not lose precision to jax's default f32: it
    used to return via jnp.asarray, truncating the f64 solve silently."""
    g, v = _positive_array(8, seed=12)
    i = nonideal.mna_mvm_currents(jnp.asarray(g, dtype=jnp.float32), v, 1.0)
    assert isinstance(i, np.ndarray) and i.dtype == np.float64
    u = nonideal.mna_inv_outputs(jnp.asarray(g, dtype=jnp.float32), v, 1.0, G0)
    assert isinstance(u, np.ndarray) and u.dtype == np.float64
    # and the values carry genuine f64 information (not an f32 round-trip)
    assert not np.array_equal(i, i.astype(np.float32).astype(np.float64))


# ---------------------------------------------------------------------------
# Promoted from tests/test_extensions.py (the formerly lone MNA usage)
# ---------------------------------------------------------------------------

def test_compensation_against_exact_mna():
    """Compensated programming cancels the wire error in the exact circuit."""
    n = 16
    a = jnp.abs(wishart(jax.random.PRNGKey(1), n))
    g = a / jnp.max(a) * G0
    v = jnp.abs(random_rhs(jax.random.PRNGKey(2), n)) + 0.1
    i_ideal = np.asarray(g @ v)
    i_raw = np.asarray(nonideal.mna_mvm_currents(g, v, 1.0))
    g_prog = nonideal.compensate_conductances(g, 1.0)
    i_comp = np.asarray(nonideal.mna_mvm_currents(g_prog, v, 1.0))
    raw_err = np.linalg.norm(i_raw - i_ideal)
    comp_err = np.linalg.norm(i_comp - i_ideal)
    assert comp_err < 0.2 * raw_err


# ---------------------------------------------------------------------------
# Hypothesis properties
# ---------------------------------------------------------------------------

@property_cases(
    dict(seed=st.integers(0, 2 ** 16), n=st.integers(2, 10)),
    [dict(seed=0, n=2), dict(seed=11, n=5), dict(seed=77, n=8),
     dict(seed=1234, n=10)])
def test_property_ideal_limit(seed, n):
    """r_seg -> 0 recovers the ideal MVM g @ v."""
    g, v = _positive_array(n, seed=seed)
    with enable_x64():
        i = np.asarray(nodal.nodal_mvm_currents(jnp.asarray(g),
                                                jnp.asarray(v), 1e-9))
        np.testing.assert_allclose(i, g @ v, rtol=1e-5)


@property_cases(
    dict(seed=st.integers(0, 2 ** 16), n=st.integers(2, 6),
         r=st.floats(min_value=0.1, max_value=2.0)),
    [dict(seed=1, n=2, r=0.1), dict(seed=22, n=4, r=1.0),
     dict(seed=333, n=6, r=2.0)])
def test_property_laplacian_symmetric_psd(seed, n, r):
    """The full crossbar Laplacian is symmetric positive definite (the
    ground couplings through driver and sense segments kill the nullspace)."""
    g, _ = _positive_array(n, seed=seed)
    L, _, _ = nonideal._crossbar_laplacian(g, r)
    np.testing.assert_allclose(L, L.T, rtol=0, atol=0)
    assert np.linalg.eigvalsh(L).min() > 0.0


@property_cases(
    dict(seed=st.integers(0, 2 ** 16), n=st.integers(2, 6),
         r=st.floats(min_value=0.1, max_value=2.0)),
    [dict(seed=2, n=2, r=0.1), dict(seed=44, n=4, r=1.0),
     dict(seed=555, n=6, r=2.0)])
def test_property_schur_blocks_spd(seed, n, r):
    """Each WL-eliminated diagonal block S_i stays symmetric positive
    definite - the invariant the block-Thomas factor relies on."""
    g, _ = _positive_array(n, seed=seed)
    with enable_x64():
        s = np.asarray(nodal.row_schur_blocks(jnp.asarray(g), r))
    for i in range(n):
        np.testing.assert_allclose(s[i], s[i].T, rtol=0, atol=1e-18)
        assert np.linalg.eigvalsh(s[i]).min() > 0.0


# ---------------------------------------------------------------------------
# Monte-Carlo scale (acceptance: 64 crossbars at n = 256, one dispatch)
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_mc_batch_n256_one_dispatch():
    """A 64-crossbar Monte-Carlo batch at n = 256 runs as ONE jitted
    dispatch (chunked lax.map inside the jit bounds the Minv stack to
    ~1 GB), and the chunked result matches an unchunked single solve."""
    b, n = 64, 256
    key = jax.random.PRNGKey(0)
    g = jax.random.uniform(key, (b, n, n), minval=0.0, maxval=G0)
    v = jnp.ones((n,), jnp.float32)

    solve = jax.jit(lambda gs, vs: nodal.nodal_mvm_batched(
        gs, vs, 1.0, chunk=4))
    out = np.asarray(solve(g, v))
    assert out.shape == (b, n)
    assert np.all(np.isfinite(out))
    # wire drop: currents strictly below ideal, same order of magnitude
    ideal = np.asarray(jnp.einsum("brc,c->br", g, v))
    assert np.all(out < ideal)
    assert np.median(out / ideal) > 0.1
    # spot-check one instance against the single-crossbar path
    single = np.asarray(nodal.nodal_mvm_currents(g[0], v, 1.0))
    np.testing.assert_allclose(out[0], single, rtol=1e-4)

"""Multi-tenant packed serving: the packed-vs-loop equivalence contract.

The contract (TESTING.md "packed serving contract"): packing M
same-signature arena plans on a leading instance axis and executing the
fleet with `execute_arena_packed` answers every tenant with exactly the
numbers its own `execute_arena` produces - bit-for-bit when both run
eagerly on CPU on aligned power-of-two plans (batching the stacked-tile
dots over the instance axis neither reassociates any per-instance
reduction nor changes the per-slice dot kernel), last-ulp float tolerance
on ragged odd splits and under jit (XLA dot merging).  On top sit the serving paths: `SolverService.flush_all`
groups pending queues by `plan_signature`, pads ragged per-tenant queue
lengths to one shared power-of-two width and scatters per-tenant answers
back, and `PackedSolverScheduler` drives that flush with a
continuous-batching admission policy.

Signature bucketing properties (same signature => identical schedule +
arena layout) live in tests/test_plan_properties.py; packed megakernel
parity in tests/test_kernels.py.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import blockamc
from repro.core.analog import AnalogConfig
from repro.core.nonideal import NonidealConfig
from repro.data.matrices import wishart
from repro.serve import PackedSolverScheduler, SolverService

KEY = jax.random.PRNGKey(23)
KA, KB, KN = jax.random.split(KEY, 3)


def _fleet(m, n, cfg, stages):
    """M programmed instances: matrices, keys, per-instance arena plans."""
    keys = jax.random.split(KN, m)
    As = jnp.stack([wishart(jax.random.fold_in(KA, i), n) for i in range(m)])
    aps = [blockamc.compile_arena(blockamc.finalize(
        blockamc.build_flat_plan(As[i], keys[i], cfg, stages=stages), cfg))
        for i in range(m)]
    return As, keys, aps


REGIMES = [
    ("sigma", lambda n: AnalogConfig(
        array_size=max(n // 4, 4), nonideal=NonidealConfig(sigma=0.05))),
    ("wire", lambda n: AnalogConfig(
        array_size=max(n // 4, 4),
        nonideal=NonidealConfig(sigma=0.05, r_wire=1.0))),
    ("gain", lambda n: AnalogConfig(
        array_size=max(n // 4, 4), opa_gain=1e4)),
]


@pytest.mark.parametrize("n,stages", [(32, 2), (17, 1)])
@pytest.mark.parametrize("tag,make_cfg", REGIMES)
@pytest.mark.parametrize("multi_rhs", [False, True])
def test_packed_matches_per_instance_loop(n, stages, tag, make_cfg,
                                          multi_rhs):
    """Each tenant's packed solution == its own execute_arena: bit-for-bit
    eager on CPU, float tolerance jitted.  n=17 exercises ragged odd
    splits (no uniform program; levels path)."""
    cfg = make_cfg(n)
    m = 3
    _, _, aps = _fleet(m, n, cfg, stages)
    pp = blockamc.pack_arena_plans(aps)
    assert pp.num_instances == m
    bs = (jax.random.normal(KB, (m, n, 4)) if multi_rhs
          else jax.random.normal(KB, (m, n)))
    xs = blockamc.execute_arena_packed(pp, bs, use_kernel=False)
    xs_loop = jnp.stack([
        blockamc.execute_arena(aps[i], bs[i], use_kernel=False)
        for i in range(m)])
    if jax.default_backend() == "cpu" and n == 32:
        # aligned power-of-two plans: the batched dots compute each
        # instance slice with the same kernel as the unbatched dot
        np.testing.assert_array_equal(np.asarray(xs), np.asarray(xs_loop))
    else:
        # ragged odd splits: XLA:CPU's batched matmul may take a
        # different code path per slice on odd tile sizes - last-ulp only
        np.testing.assert_allclose(np.asarray(xs), np.asarray(xs_loop),
                                   rtol=1e-5, atol=1e-6)
    xs_jit = blockamc._execute_arena_packed(pp, bs)
    np.testing.assert_allclose(np.asarray(xs_jit), np.asarray(xs_loop),
                               rtol=1e-5, atol=1e-6)


def test_batched_programming_matches_sequential():
    """program_packed (one vmapped trace) == the sequential per-matrix
    pipeline at float tolerance, and still solves every system."""
    m, n, stages = 4, 32, 2
    cfg = AnalogConfig(array_size=8, nonideal=NonidealConfig(sigma=0.05))
    As, keys, aps = _fleet(m, n, cfg, stages)
    pp = blockamc.program_packed(As, keys, cfg, stages=stages)
    assert pp.num_instances == m
    bs = jax.random.normal(KB, (m, n, 2))
    xs = blockamc.execute_arena_packed(pp, bs, use_kernel=False)
    xs_seq = blockamc.execute_arena_packed(blockamc.pack_arena_plans(aps),
                                           bs, use_kernel=False)
    # same matrices, same noise keys; the batched pipeline runs under
    # jit/vmap, so agreement is float-tolerance (XLA reassociation in the
    # programming math), not bitwise
    np.testing.assert_allclose(np.asarray(xs), np.asarray(xs_seq),
                               rtol=2e-4, atol=2e-5)


def test_batched_programming_stages_align():
    """The batched pipeline builders compose: pack_partitioned +
    program_system_batched + finalize_batched + compile_arena_batched ==
    program_packed."""
    m, n, stages = 3, 16, 1
    cfg = AnalogConfig(array_size=8, nonideal=NonidealConfig(sigma=0.02))
    As, keys, _ = _fleet(m, n, cfg, stages)
    parts = blockamc.pack_partitioned(
        [blockamc.partition_system(As[i], cfg, stages) for i in range(m)])
    fplans = blockamc.program_system_batched(parts, keys, cfg)
    pp = blockamc.compile_arena_batched(
        blockamc.finalize_batched(fplans, cfg))
    pp2 = blockamc.program_packed(As, keys, cfg, stages=stages)
    bs = jax.random.normal(KB, (m, n, 2))
    np.testing.assert_allclose(
        np.asarray(blockamc.execute_arena_packed(pp, bs, use_kernel=False)),
        np.asarray(blockamc.execute_arena_packed(pp2, bs,
                                                 use_kernel=False)),
        rtol=1e-5, atol=1e-6)


def test_pack_rejects_mismatched_signatures():
    """Plans compiled from different (n, stages, cfg) cannot share one
    packed program and must be refused loudly."""
    cfg = AnalogConfig(array_size=8, nonideal=NonidealConfig(sigma=0.05))
    _, _, aps16 = _fleet(1, 16, cfg, 1)
    _, _, aps32 = _fleet(1, 32, cfg, 1)
    with pytest.raises(ValueError, match="not stackable"):
        blockamc.pack_arena_plans([aps16[0], aps32[0]])
    with pytest.raises(ValueError, match="at least one"):
        blockamc.pack_arena_plans([])


def test_packed_plan_is_pytree():
    cfg = AnalogConfig(array_size=8, nonideal=NonidealConfig(sigma=0.05))
    _, _, aps = _fleet(2, 16, cfg, 1)
    pp = blockamc.pack_arena_plans(aps)
    leaves, treedef = jax.tree_util.tree_flatten(pp)
    pp2 = jax.tree_util.tree_unflatten(treedef, leaves)
    bs = jax.random.normal(KB, (2, 16, 2))
    np.testing.assert_array_equal(
        np.asarray(blockamc.execute_arena_packed(pp, bs, use_kernel=False)),
        np.asarray(blockamc.execute_arena_packed(pp2, bs,
                                                 use_kernel=False)))
    hash(treedef)   # shared static metadata stays a valid jit cache key


def test_packed_kernel_rejects_nonuniform():
    """use_kernel=True on a plan without a whole-schedule program must
    fail loudly, exactly like the single-instance executor."""
    cfg = AnalogConfig(array_size=8, nonideal=NonidealConfig(sigma=0.05))
    _, _, aps = _fleet(2, 17, cfg, 1)      # ragged split: program is None
    pp = blockamc.pack_arena_plans(aps)
    assert pp.program_ops is None
    with pytest.raises(ValueError, match="uniform"):
        blockamc.execute_arena_packed(pp, jax.random.normal(KB, (2, 17)),
                                      use_kernel=True)


def test_packed_sharded_matches_unsharded():
    """Instance axis over a (1-device) mc mesh == the plain packed path."""
    from repro.launch.mesh import make_mc_mesh
    cfg = AnalogConfig(array_size=8, nonideal=NonidealConfig(sigma=0.05))
    m, n = 4, 16
    _, _, aps = _fleet(m, n, cfg, 1)
    pp = blockamc.pack_arena_plans(aps)
    bs = jax.random.normal(KB, (m, n, 3))
    xs = blockamc.execute_arena_packed(pp, bs, use_kernel=False)
    xs_sh = blockamc.execute_arena_packed_sharded(pp, bs,
                                                  mesh=make_mc_mesh(1))
    np.testing.assert_allclose(np.asarray(xs_sh), np.asarray(xs),
                               rtol=1e-6, atol=1e-7)
    # (the num_instances divisibility error needs a >1-device mesh; the
    # slow multi-device subprocess test below covers genuine sharding)


@pytest.mark.slow
def test_packed_sharded_multidevice():
    """Instance axis genuinely sharded over 4 host devices (subprocess:
    XLA device count must be set before jax initialises)."""
    import os
    import subprocess
    import sys
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = os.path.join(repo, "src")
    code = """
import jax, jax.numpy as jnp
from repro.core import blockamc
from repro.core.analog import AnalogConfig
from repro.core.nonideal import NonidealConfig
from repro.data.matrices import wishart
ka, kb, kn = jax.random.split(jax.random.PRNGKey(3), 3)
cfg = AnalogConfig(array_size=8, nonideal=NonidealConfig(sigma=0.05))
m, n = 8, 32
keys = jax.random.split(kn, m)
As = jnp.stack([wishart(jax.random.fold_in(ka, i), n) for i in range(m)])
pp = blockamc.program_packed(As, keys, cfg, stages=2)
bs = jax.random.normal(kb, (m, n, 4))
xs = blockamc.execute_arena_packed(pp, bs, use_kernel=False)
xs_sh = blockamc.execute_arena_packed_sharded(pp, bs)
assert jnp.allclose(xs_sh, xs, rtol=1e-5, atol=1e-6)
pp6 = blockamc.program_packed(As[:6], keys[:6], cfg, stages=2)
try:
    blockamc.execute_arena_packed_sharded(pp6, bs[:6])
except ValueError as e:
    assert "divide" in str(e)
else:
    raise SystemExit("divisibility error not raised")
print('OK', xs_sh.shape)
"""
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=560)
    assert out.returncode == 0, out.stderr[-4000:]
    assert "OK" in out.stdout


# ---------------------------------------------------------------------------
# SolverService.flush_all + scheduler
# ---------------------------------------------------------------------------

N = 32
CFG = AnalogConfig(array_size=8, nonideal=NonidealConfig(sigma=0.02))


def _service(m=4, n=N, stages=2):
    svc = SolverService(CFG, stages=stages)
    ids = [f"m{i}" for i in range(m)]
    for i, mid in enumerate(ids):
        svc.program(mid, wishart(jax.random.fold_in(KA, i), n),
                    jax.random.fold_in(KN, i))
    return svc, ids


def test_flush_all_ragged_bucket_matches_individual_solves():
    """Mixed per-tenant queue lengths: one packed dispatch answers every
    tenant with its own solver's numbers, pads never leak, counters count
    each rhs exactly once."""
    svc, ids = _service(m=4)
    counts = dict(zip(ids, (3, 5, 1, 8)))
    cols = {}
    for mid in ids:
        cols[mid] = [jax.random.normal(jax.random.fold_in(KB, 100 * int(
            mid[1:]) + j), (N,)) for j in range(counts[mid])]
        for b in cols[mid]:
            svc.submit(mid, b)
    expected = {mid: jnp.stack([svc.solver(mid).solve(b)
                                for b in cols[mid]], axis=1) for mid in ids}
    out = svc.flush_all()
    assert set(out) == set(ids)
    for mid in ids:
        assert out[mid].shape == (N, counts[mid])
        np.testing.assert_allclose(np.asarray(out[mid]),
                                   np.asarray(expected[mid]),
                                   rtol=1e-5, atol=1e-6)
        assert svc.pending(mid) == 0
        st = svc.stats(mid)
        assert st.solve_calls == 1                # one packed dispatch
        assert st.rhs_served == counts[mid]       # no double counting
    assert svc.flush_all() == {}                  # nothing left pending


def test_flush_all_matches_flush_loop():
    """flush_all == a loop of per-matrix flushes, tenant for tenant."""
    svc_a, ids = _service(m=3)
    svc_b, _ = _service(m=3)
    cols = {mid: [jax.random.normal(jax.random.fold_in(KB, 7 * i + j), (N,))
                  for j in range(4)] for i, mid in enumerate(ids)}
    for mid in ids:
        for b in cols[mid]:
            svc_a.submit(mid, b)
            svc_b.submit(mid, b)
    packed = svc_a.flush_all()
    for mid in ids:
        loop = svc_b.flush(mid)
        np.testing.assert_allclose(np.asarray(packed[mid]),
                                   np.asarray(loop), rtol=1e-5, atol=1e-6)
        assert svc_a.stats(mid).rhs_served == svc_b.stats(mid).rhs_served


def test_flush_all_mixed_signatures_and_singletons():
    """Tenants of different sizes land in different signature buckets;
    a single-tenant bucket falls back to the per-matrix flush."""
    svc = SolverService(CFG, stages=1)
    a16 = [wishart(jax.random.fold_in(KA, i), 16) for i in range(2)]
    a32 = wishart(jax.random.fold_in(KA, 9), 32)
    svc.program("s0", a16[0], jax.random.fold_in(KN, 0))
    svc.program("s1", a16[1], jax.random.fold_in(KN, 1))
    svc.program("big", a32, jax.random.fold_in(KN, 2))
    assert svc.signature("s0") == svc.signature("s1")
    assert svc.signature("s0") != svc.signature("big")
    b16 = [jax.random.normal(jax.random.fold_in(KB, j), (16,))
           for j in range(3)]
    b32 = jax.random.normal(KB, (32,))
    for b in b16:
        svc.submit("s0", b)
    svc.submit("s1", b16[0])
    svc.submit("big", b32)
    out = svc.flush_all()
    assert out["s0"].shape == (16, 3)
    assert out["s1"].shape == (16, 1)
    assert out["big"].shape == (32, 1)
    np.testing.assert_allclose(np.asarray(out["big"][:, 0]),
                               np.asarray(svc.solver("big").solve(b32)),
                               rtol=1e-5, atol=1e-6)
    # subset flush: only the requested ids are answered
    svc.submit("s0", b16[0])
    svc.submit("big", b32)
    out = svc.flush_all(matrix_ids=["big"])
    assert set(out) == {"big"} and svc.pending("s0") == 1
    # unknown ids raise like every other entry point (never silently skip)
    with pytest.raises(KeyError):
        svc.flush_all(matrix_ids=["big", "nope"])


def test_flush_all_reference_mode_falls_back():
    """mode="reference" services keep the finalized executor: flush_all
    still answers everything (per-matrix path, no packing)."""
    svc = SolverService(CFG, stages=1, mode="reference")
    for i in range(2):
        svc.program(f"m{i}", wishart(jax.random.fold_in(KA, i), N),
                    jax.random.fold_in(KN, i))
    for i in range(2):
        svc.submit(f"m{i}", jax.random.normal(jax.random.fold_in(KB, i),
                                              (N,)))
    out = svc.flush_all()
    assert set(out) == {"m0", "m1"}
    assert all(out[mid].shape == (N, 1) for mid in out)
    assert not svc._packs                         # nothing was packed


def test_reprogram_invalidates_pack_cache():
    """Re-programming a tenant drops every cached pack containing it, so
    the next flush_all packs the new plan (and solves the new matrix).
    The cache holds one (id tuple, pack) per signature."""
    svc, ids = _service(m=2)
    for mid in ids:
        svc.submit(mid, jax.random.normal(KB, (N,)))
    svc.flush_all()
    assert [ids_ for ids_, _ in svc._packs.values()] == [tuple(ids)]
    a_new = wishart(jax.random.fold_in(KA, 77), N)
    svc.program(ids[0], a_new, jax.random.fold_in(KN, 77))
    assert not svc._packs
    b = jax.random.normal(jax.random.fold_in(KB, 5), (N,))
    for mid in ids:
        svc.submit(mid, b)
    out = svc.flush_all()
    np.testing.assert_allclose(np.asarray(out[ids[0]][:, 0]),
                               np.asarray(svc.solver(ids[0]).solve(b)),
                               rtol=1e-5, atol=1e-6)


def test_scheduler_continuous_batching_flush():
    """PackedSolverScheduler fires a signature bucket the moment it holds
    max_batch pending rhs, leaves other buckets filling, and drains the
    stragglers on demand."""
    svc, ids = _service(m=3)
    sched = PackedSolverScheduler(svc, max_batch=4)
    b = [jax.random.normal(jax.random.fold_in(KB, j), (N,))
         for j in range(6)]
    t0 = sched.submit(ids[0], b[0])
    t1 = sched.submit(ids[0], b[1])
    t2 = sched.submit(ids[1], b[2])
    assert sched.pending() == 3 and not sched.ready(t0)
    t3 = sched.submit(ids[2], b[3])               # 4th pending -> flush
    assert sched.pending() == 0
    for t, bj in zip((t0, t1, t2, t3), b[:4]):
        assert sched.ready(t)
    np.testing.assert_allclose(np.asarray(sched.result(t1)),
                               np.asarray(svc.solver(ids[0]).solve(b[1])),
                               rtol=1e-5, atol=1e-6)
    assert not sched.ready(t1)                    # one-shot delivery
    # stragglers drain explicitly; tickets stay unique across generations
    t4 = sched.submit(ids[1], b[4])
    assert t4 == (ids[1], 1) and sched.pending() == 1
    sched.drain()
    assert sched.pending() == 0 and sched.ready(t4)
    np.testing.assert_allclose(np.asarray(sched.result(t4)),
                               np.asarray(svc.solver(ids[1]).solve(b[4])),
                               rtol=1e-5, atol=1e-6)


def test_scheduler_detects_external_queue_writes():
    """The scheduler owns its service's queues: ticket->column mapping is
    per-tenant submission order, so a direct service.submit alongside a
    scheduler must fail loudly at delivery, never mis-assign answers."""
    svc, ids = _service(m=2)
    sched = PackedSolverScheduler(svc, max_batch=8)
    b = jax.random.normal(KB, (N,))
    t_stale = sched.submit(ids[0], b)
    svc.submit(ids[0], b)          # bypasses the scheduler
    with pytest.raises(RuntimeError, match="outside this scheduler"):
        sched.drain()
    # the violated tenant's open tickets are void, its counters resynced:
    # a caller that catches the error and keeps going gets fresh answers
    # on fresh tickets, never a later flush landing on the stale one
    assert not sched.ready(t_stale) and sched.pending() == 0
    b2 = jax.random.normal(jax.random.fold_in(KB, 9), (N,))
    t_new = sched.submit(ids[0], b2)
    sched.drain()
    assert not sched.ready(t_stale) and sched.ready(t_new)
    np.testing.assert_allclose(np.asarray(sched.result(t_new)),
                               np.asarray(svc.solver(ids[0]).solve(b2)),
                               rtol=1e-5, atol=1e-6)


def test_scheduler_survives_injected_dispatch_failure(monkeypatch):
    """Exception-safety audit (ISSUE satellite): a dispatch that raises
    mid-drain leaves the service queues, the per-signature counters and
    every open ticket exactly as they were - `check_consistency` holds
    after the failure, a plain retry succeeds, and every ticket delivers
    its own tenant's numbers."""
    svc, ids = _service(m=3)
    sched = PackedSolverScheduler(svc, max_batch=8)
    b = [jax.random.normal(jax.random.fold_in(KB, j), (N,))
         for j in range(5)]
    tickets = [sched.submit(ids[j % 3], bj) for j, bj in enumerate(b)]
    sched.check_consistency()

    # inject: the packed executor dies on its next invocation only
    real = blockamc._execute_arena_packed_donated
    blows = {"left": 1}

    def exploding(pp, bs):
        if blows["left"]:
            blows["left"] -= 1
            raise RuntimeError("injected device OOM")
        return real(pp, bs)

    monkeypatch.setattr(blockamc, "_execute_arena_packed_donated",
                        exploding)
    import repro.serve.solver_service as ss
    monkeypatch.setattr(ss, "_execute_arena_packed_donated", exploding)

    with pytest.raises(RuntimeError, match="injected device OOM"):
        sched.drain()
    # all-or-nothing: nothing delivered, nothing dropped, counters intact
    assert sched.pending() == 5
    assert all(svc.pending(mid) > 0 for mid in ids)
    assert not any(sched.ready(t) for t in tickets)
    sched.check_consistency()
    assert all(svc.stats(mid).rhs_served == 0 for mid in ids)

    sched.drain()                                # plain retry, no reset
    sched.check_consistency()
    assert sched.pending() == 0
    for t, bj in zip(tickets, b):
        assert sched.ready(t)
        np.testing.assert_allclose(np.asarray(sched.result(t)),
                                   np.asarray(svc.solver(t[0]).solve(bj)),
                                   rtol=1e-5, atol=1e-6)


def test_scheduler_failure_on_triggering_submit_keeps_ticket(monkeypatch):
    """The same injected failure on the submit that *triggers* a flush:
    the submit raises, but its rhs and ticket stay queued and the next
    drain answers them (nothing queued is ever dropped)."""
    svc, ids = _service(m=2)
    sched = PackedSolverScheduler(svc, max_batch=2)
    b0 = jax.random.normal(KB, (N,))
    b1 = jax.random.normal(jax.random.fold_in(KB, 1), (N,))
    t0 = sched.submit(ids[0], b0)

    real = blockamc._execute_arena_packed_donated
    blows = {"left": 1}

    def exploding(pp, bs):
        if blows["left"]:
            blows["left"] -= 1
            raise RuntimeError("injected")
        return real(pp, bs)

    monkeypatch.setattr(blockamc, "_execute_arena_packed_donated",
                        exploding)
    import repro.serve.solver_service as ss
    monkeypatch.setattr(ss, "_execute_arena_packed_donated", exploding)

    with pytest.raises(RuntimeError, match="injected"):
        sched.submit(ids[1], b1)                 # 2nd pending -> flush dies
    t1 = (ids[1], 0)                             # its ticket is well-defined
    sched.check_consistency()
    assert sched.pending() == 2
    sched.drain()
    for t, bj in ((t0, b0), (t1, b1)):
        np.testing.assert_allclose(np.asarray(sched.result(t)),
                                   np.asarray(svc.solver(t[0]).solve(bj)),
                                   rtol=1e-5, atol=1e-6)

"""End-to-end behaviour tests for the whole system.

1. The paper's flow: noisy BlockAMC seed -> digital refinement -> converged
   solution, beating the zero-seed iteration count.
2. The LM flow: train a tiny model to improvement, checkpoint, restart,
   serve greedy generations from the trained weights.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.base import RunConfig
from repro.core import blockamc, hybrid
from repro.core.analog import AnalogConfig
from repro.core.nonideal import NonidealConfig
from repro.core.metrics import relative_error
from repro.checkpoint.ckpt import latest_step
from repro.data.matrices import random_rhs, wishart
from repro.models.lm_engine import Engine
from repro.train.trainer import Trainer
from tests.conftest import reduce_cfg


def test_paper_end_to_end_solver_flow():
    """BlockAMC (sigma=0.05, r=1) seed + CG refinement solves to 1e-6."""
    ka, kb, kn = jax.random.split(jax.random.PRNGKey(0), 3)
    a = wishart(ka, 128)
    b = random_rhs(kb, 128)
    x_ref = jnp.linalg.solve(a, b)
    cfg = AnalogConfig(array_size=32,
                       nonideal=NonidealConfig(sigma=0.05, r_wire=1.0))
    seed = blockamc.solve(a, b, kn, cfg, stages=2)
    seed_err = float(relative_error(x_ref, seed))
    x, it_seed = hybrid.iterations_to_tol(a, b, seed, tol=1e-6)
    _, it_zero = hybrid.iterations_to_tol(a, b, jnp.zeros_like(b), tol=1e-6)
    final_err = float(relative_error(x_ref, x))
    assert final_err < 1e-4 < seed_err     # refinement actually did the work
    assert int(it_seed) <= int(it_zero)


@pytest.mark.slow
def test_lm_end_to_end_train_ckpt_serve(tmp_path):
    cfg = reduce_cfg(get_config("glm4-9b"))
    run = RunConfig(model=cfg, mode="train", seq_len=32, global_batch=4,
                    remat="dots")
    trainer = Trainer(cfg, run, ckpt_dir=str(tmp_path), ckpt_every=10,
                      log_every=1000)
    hist = trainer.run(20)
    assert np.mean(hist["loss"][-5:]) < np.mean(hist["loss"][:5])
    assert latest_step(str(tmp_path)) == 20

    # restart picks up where we left off
    t2 = Trainer(cfg, run, ckpt_dir=str(tmp_path), ckpt_every=10,
                 log_every=1000)
    assert t2.start_step == 20

    # serve from trained weights
    engine = Engine(cfg, t2.state.params, max_len=48)
    prompts = jax.random.randint(jax.random.PRNGKey(9), (2, 8), 0, cfg.vocab)
    out = engine.generate(prompts, 8)
    assert out.shape == (2, 8)
    assert bool(jnp.all(jnp.isfinite(out.astype(jnp.float32))))

"""Golden regression tests: ideal-config BlockAMC == numerical solve.

With ideal interfaces (dac_bits=adc_bits=None), zero device noise and ideal
OPAs - the seed defaults of AnalogConfig - every BlockAMC cascade must
reproduce jnp.linalg.solve to float tolerance, for any partitioning depth
and for odd sizes (the paper's (n+1)/2 split).  Runs both executors so the
recursive reference and the flat level-scheduled path are pinned to the
same golden.
"""
import jax
import jax.numpy as jnp
import pytest

from repro.core import blockamc
from repro.core.analog import AnalogConfig
from repro.core.metrics import relative_error
from repro.data.matrices import random_rhs, wishart

KEY = jax.random.PRNGKey(7)
KA, KB, KN = jax.random.split(KEY, 3)

IDEAL = AnalogConfig(array_size=8)   # dac/adc None, sigma 0, ideal OPA


def _problem(n):
    a = wishart(KA, n)
    b = random_rhs(KB, n)
    return a, b, jnp.linalg.solve(a, b)


@pytest.mark.parametrize("executor", ["recursive", "flat"])
@pytest.mark.parametrize("stages", [0, 1, 2])
@pytest.mark.parametrize("n", [8, 17, 64])
def test_ideal_matches_linalg_solve(n, stages, executor):
    """n=17 exercises the odd split (A1 of size 9, then 5/4 at depth 2)."""
    a, b, x_ref = _problem(n)
    plan = blockamc.build_plan(a, KN, IDEAL, stages=stages)
    if executor == "recursive":
        x = blockamc.execute(plan, b, IDEAL)
    else:
        x = blockamc.execute_flat(blockamc.compile_plan(plan), b, IDEAL)
    assert float(relative_error(x_ref, x)) < 1e-4


@pytest.mark.parametrize("n", [8, 17, 64])
def test_ideal_original_amc_matches(n):
    a, b, x_ref = _problem(n)
    x = blockamc.solve_original(a, b, KN, IDEAL)
    assert float(relative_error(x_ref, x)) < 1e-4


def test_odd_split_point():
    """Paper: odd n partitions with A1 of size (n+1)/2."""
    a, _, _ = _problem(17)
    plan = blockamc.build_plan(a, KN, IDEAL, stages=1)
    assert plan.root.m == 9
    assert plan.root.inv1.n == 9 and plan.root.inv4s.n == 8

"""SolverService error/edge paths + the hybrid `solve_refined` mode.

Happy-path batching coverage lives in test_programmed_solver.py; this file
pins the service's failure discipline (nothing queued is ever dropped, bad
requests are rejected before touching state) and the new refined mode.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.analog import AnalogConfig
from repro.core.nonideal import NonidealConfig
from repro.data.matrices import random_rhs, wishart
from repro.serve import SolverService

KEY = jax.random.PRNGKey(21)
KA, KB, KN = jax.random.split(KEY, 3)
N = 32
CFG = AnalogConfig(array_size=16, nonideal=NonidealConfig(sigma=0.02))


def _service():
    svc = SolverService(CFG, stages=1)
    a = wishart(KA, N)
    svc.program("m0", a, KN)
    return svc, a


def test_flush_empty_queue_returns_n_by_0():
    svc, _ = _service()
    xs = svc.flush("m0")
    assert xs.shape == (N, 0)
    assert svc.stats("m0").solve_calls == 0     # nothing was solved
    xs = svc.flush("m0", refined=True)          # refined path: same contract
    assert xs.shape == (N, 0)


def test_submit_rejects_mismatched_rhs():
    svc, _ = _service()
    with pytest.raises(ValueError, match="rhs"):
        svc.submit("m0", jnp.zeros((N, 2)))     # matrix, not a vector
    with pytest.raises(ValueError, match="rhs"):
        svc.submit("m0", jnp.zeros((N + 1,)))   # wrong length
    with pytest.raises(ValueError, match="rhs"):
        svc.submit("m0", jnp.zeros(()))         # scalar
    assert svc.pending("m0") == 0               # rejected before queueing


def test_submit_snapshots_the_rhs_buffer():
    """Admission must copy: a caller reusing (and mutating) one buffer
    across submits cannot corrupt an already-queued request."""
    svc, _ = _service()
    buf = np.arange(N, dtype=np.float32)
    # expectation from an independent buffer: jnp.asarray(np_buf) may be
    # zero-copy on CPU, so solving from `buf` itself would race the
    # mutation below inside jax's async dispatch
    want = svc.solver("m0").solve(jnp.asarray(np.arange(N,
                                                        dtype=np.float32)))
    svc.submit("m0", buf)
    buf[:] = 0.0                    # caller reuses the buffer
    xs = svc.flush("m0")
    np.testing.assert_allclose(np.asarray(xs[:, 0]), np.asarray(want),
                               rtol=1e-5, atol=1e-6)


def test_unknown_matrix_id_raises():
    svc, _ = _service()
    with pytest.raises(KeyError):
        svc.solve("nope", jnp.zeros((N,)))
    with pytest.raises(KeyError):
        svc.submit("nope", jnp.zeros((N,)))
    with pytest.raises(KeyError):
        svc.solve_refined("nope", jnp.zeros((N,)))


def test_double_program_replaces_cleanly_or_refuses_over_pending():
    svc, a = _service()
    first = svc.solver("m0")
    svc.solve("m0", random_rhs(KB, N))
    # re-programming with an empty queue replaces solver and resets stats
    a2 = wishart(KB, N)
    svc.program("m0", a2, KN)
    assert svc.solver("m0") is not first
    st = svc.stats("m0")
    assert st.solve_calls == 0 and st.rhs_served == 0
    assert st.program_time_s > 0
    x = svc.solve("m0", random_rhs(KB, N))      # solves the *new* matrix
    np.testing.assert_allclose(
        np.asarray(x), np.asarray(svc.solver("m0").solve(random_rhs(KB, N))),
        rtol=1e-5, atol=1e-6)
    # ...but refuses while right-hand sides are still queued
    svc.submit("m0", random_rhs(KB, N))
    with pytest.raises(RuntimeError, match="pending"):
        svc.program("m0", a, KN)
    assert svc.pending("m0") == 1               # queue untouched by refusal


def test_solve_refined_beats_raw_solve():
    svc, a = _service()
    b = random_rhs(KB, N)
    x_raw = svc.solve("m0", b)
    x_ref = svc.solve_refined("m0", b, tol=1e-6, maxiter=200)
    res_raw = float(jnp.linalg.norm(b - a @ x_raw) / jnp.linalg.norm(b))
    res_ref = float(jnp.linalg.norm(b - a @ x_ref) / jnp.linalg.norm(b))
    assert res_ref <= 1e-5                      # f32 digital refinement
    assert res_ref < res_raw                    # the noisy solve alone
    st = svc.stats("m0")
    assert st.refined_calls == 1 and st.refine_iters >= 1
    assert st.solve_calls == 2 and st.rhs_served == 2


def test_refined_flush_matches_immediate_refined_solves():
    svc, a = _service()
    cols = [jax.random.normal(jax.random.fold_in(KB, j), (N,))
            for j in range(5)]
    for b in cols:
        svc.submit("m0", b)
    xs = svc.flush("m0", refined=True, tol=1e-6, maxiter=200)
    assert xs.shape == (N, 5) and svc.pending("m0") == 0
    for j, b in enumerate(cols):
        res = float(jnp.linalg.norm(b - a @ xs[:, j]) / jnp.linalg.norm(b))
        assert res <= 1e-5
        np.testing.assert_allclose(
            np.asarray(xs[:, j]),
            np.asarray(svc.solve_refined("m0", b, tol=1e-6, maxiter=200)),
            rtol=1e-4, atol=1e-5)
    assert svc.stats("m0").rhs_served == 10     # 5 flushed + 5 immediate
    assert svc.stats("m0").refined_calls == 6


def test_refined_flush_gmres_mode():
    svc, a = _service()
    for j in range(3):
        svc.submit("m0", jax.random.normal(jax.random.fold_in(KB, j), (N,)))
    xs = svc.flush("m0", refined=True, method="gmres", tol=1e-5,
                   maxiter=256, restart=16, use_precond=False)
    assert xs.shape == (N, 3)
    for j in range(3):
        b = jax.random.normal(jax.random.fold_in(KB, j), (N,))
        r = float(jnp.linalg.norm(b - a @ xs[:, j]) / jnp.linalg.norm(b))
        assert r <= 1e-4


# ------------------- front-door validation (admission) --------------------

def test_program_rejects_nonfinite_matrix_before_state_change():
    svc, a = _service()
    bad = np.asarray(a).copy()
    bad[2, 3] = np.nan
    with pytest.raises(ValueError, match="non-finite"):
        svc.program("m1", jnp.asarray(bad), KN)
    assert "m1" not in svc.matrix_ids           # nothing half-programmed
    inf = np.asarray(a).copy()
    inf[0, 0] = np.inf
    with pytest.raises(ValueError, match="non-finite"):
        svc.program("m1", jnp.asarray(inf), KN)


def test_program_rejects_wrong_dtype_and_shape():
    svc, _ = _service()
    with pytest.raises(ValueError, match="floating"):
        svc.program("m1", jnp.eye(N, dtype=jnp.int32), KN)
    with pytest.raises(ValueError, match="square"):
        svc.program("m1", jnp.zeros((N, N + 1)), KN)
    with pytest.raises(ValueError, match="square"):
        svc.program("m1", jnp.zeros((N,)), KN)
    assert "m1" not in svc.matrix_ids


def test_submit_rejects_nonfinite_and_wrong_dtype():
    svc, _ = _service()
    bad = np.ones(N)
    bad[7] = np.nan
    with pytest.raises(ValueError, match="non-finite"):
        svc.submit("m0", bad)
    with pytest.raises(ValueError, match="floating"):
        svc.submit("m0", np.arange(N))          # int64
    assert svc.pending("m0") == 0               # nothing was queued


def test_nan_rhs_cannot_corrupt_cobatched_tenants():
    """One tenant's NaN rhs must be rejected at its own front door - a
    co-batched healthy tenant's packed answers stay exactly what they
    would have been (the satellite regression from ISSUE.md)."""
    svc = SolverService(CFG, stages=1)
    a0, a1 = wishart(KA, N), wishart(jax.random.fold_in(KA, 1), N)
    svc.program("good", a0, KN)
    svc.program("evil", a1, jax.random.fold_in(KN, 1))
    good_b = [random_rhs(jax.random.fold_in(KB, j), N) for j in range(2)]
    for b in good_b:
        svc.submit("good", b)
    bad = np.ones(N)
    bad[0] = np.inf
    with pytest.raises(ValueError):
        svc.submit("evil", bad)
    svc.submit("evil", random_rhs(jax.random.fold_in(KB, 9), N))
    answers = svc.flush_all()
    # reference: the same healthy queue flushed alone on a fresh service
    ref = SolverService(CFG, stages=1)
    ref.program("good", a0, KN)
    for b in good_b:
        ref.submit("good", b)
    np.testing.assert_allclose(np.asarray(answers["good"]),
                               np.asarray(ref.flush("good")),
                               rtol=1e-5, atol=1e-6)
    assert np.all(np.isfinite(answers["evil"]))


def test_discard_pending_unblocks_reprogram():
    svc, a = _service()
    svc.submit("m0", random_rhs(KB, N))
    with pytest.raises(RuntimeError, match="pending"):
        svc.program("m0", a, KN)
    assert svc.discard_pending("m0") == 1
    assert svc.pending("m0") == 0
    svc.program("m0", a, KN)                    # now allowed
    assert svc.discard_pending("m0") == 0       # idempotent on empty


def test_per_matrix_cfg_override_rebuckets_only_that_tenant():
    svc, a = _service()
    a1 = wishart(jax.random.fold_in(KA, 2), N)
    svc.program("m1", a1, jax.random.fold_in(KN, 2))
    assert svc.signature("m0") == svc.signature("m1")
    wv = CFG.with_(nonideal=NonidealConfig(sigma=0.02, wv_iters=2))
    svc.program("m1", a1, jax.random.fold_in(KN, 3), cfg=wv)
    assert svc.signature("m0") != svc.signature("m1")
    assert svc.matrix_cfg("m1") is wv
    assert svc.matrix_cfg("m0") is CFG
    # differently-configured tenants still flush together (separate
    # buckets inside one flush_all call)
    svc.submit("m0", random_rhs(KB, N))
    svc.submit("m1", random_rhs(jax.random.fold_in(KB, 1), N))
    answers = svc.flush_all()
    assert set(answers) == {"m0", "m1"}
    for mid, am in (("m0", a), ("m1", a1)):
        b = random_rhs(KB if mid == "m0" else jax.random.fold_in(KB, 1), N)
        r = float(np.linalg.norm(np.asarray(am) @ answers[mid][:, 0]
                                 - np.asarray(b))
                  / np.linalg.norm(np.asarray(b)))
        assert r < 0.6                          # raw analog quality


def test_solve_fallback_is_digital_grade_and_counted():
    svc, a = _service()
    b = random_rhs(KB, N)
    x = svc.solve_fallback("m0", b, tol=1e-6)
    res = float(jnp.linalg.norm(b - a @ x) / jnp.linalg.norm(b))
    assert res <= 1e-5                          # no analog error floor
    bs = jnp.stack([b, random_rhs(jax.random.fold_in(KB, 1), N)], axis=1)
    xs = svc.solve_fallback("m0", bs, tol=1e-6)
    assert xs.shape == (N, 2)
    st = svc.stats("m0")
    assert st.rhs_served == 3 and st.refined_calls == 2
    assert st.refine_iters >= 1                 # digital spend is visible

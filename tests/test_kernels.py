"""Pallas kernel tests: shape/dtype sweeps, allclose vs pure-jnp oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

G0 = 100e-6
KEY = jax.random.PRNGKey(0)


def _inputs(b, r, c, dtype=jnp.float32, seed=0):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 3)
    v = jax.random.uniform(k1, (b, c), dtype=jnp.float32, minval=-1, maxval=1)
    gpos = jax.random.uniform(k2, (r, c), dtype=jnp.float32, maxval=G0)
    gneg = jax.random.uniform(k3, (r, c), dtype=jnp.float32, maxval=G0)
    return v.astype(dtype), gpos.astype(dtype), gneg.astype(dtype)


# ------------------------------ crossbar_mvm ------------------------------

@pytest.mark.parametrize("b,r,c", [
    (128, 128, 128),     # single tile
    (128, 256, 384),     # K-accumulation over 3 steps
    (256, 128, 256),     # batch grid
    (32, 100, 72),       # ragged -> padding path
    (1, 257, 130),       # heavily ragged
])
def test_crossbar_matches_ref(b, r, c):
    v, gpos, gneg = _inputs(b, r, c)
    out = ops.crossbar_mvm(v, gpos, gneg, g0=G0)
    expect = ref.crossbar_mvm_ref(v, gpos, gneg, g0=G0)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("l,b,r,c", [
    (1, 128, 128, 128),   # degenerate stack == plain batched MVM
    (4, 32, 64, 64),      # ragged trailing dims -> padding path
    (3, 5, 70, 130),      # heavily ragged, K-accumulation after padding
])
def test_crossbar_batched_matches_vmapped_ref(l, b, r, c):
    """Leading-dim entry point == per-array reference, incl. quantisers."""
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(4), 3)
    v = jax.random.uniform(k1, (l, b, c), minval=-1, maxval=1)
    gpos = jax.random.uniform(k2, (l, r, c), maxval=G0)
    gneg = jax.random.uniform(k3, (l, r, c), maxval=G0)
    out = ops.crossbar_mvm_batched(v, gpos, gneg, g0=G0, dac_bits=8,
                                   adc_bits=8)
    expect = jax.vmap(lambda vv, gp, gn: ref.crossbar_mvm_ref(
        vv, gp, gn, g0=G0, dac_bits=8, adc_bits=8))(v, gpos, gneg)
    assert out.shape == (l, b, r)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=1e-5, atol=1e-5)


def test_crossbar_batched_matches_flat_stack():
    """The batched kernel reproduces one flat-executor INV-bucket stack."""
    from repro.core import blockamc
    from repro.core.analog import AnalogConfig
    from repro.core.nonideal import NonidealConfig
    from repro.data.matrices import wishart
    cfg = AnalogConfig(array_size=16, nonideal=NonidealConfig(sigma=0.05))
    a = wishart(jax.random.PRNGKey(1), 64)
    fplan = blockamc.build_flat_plan(a, jax.random.PRNGKey(2), cfg, stages=2)
    grid = fplan.inv_stacks[0]              # (num, 16, 16) conductances
    num, s, _ = grid.shape
    v = jax.random.uniform(jax.random.PRNGKey(3), (num, 2, s),
                           minval=-1, maxval=1)
    out = ops.crossbar_mvm_batched(v, grid.gpos, grid.gneg, g0=cfg.g0)
    expect = jax.vmap(lambda vv, gp, gn: ref.crossbar_mvm_ref(
        vv, gp, gn, g0=cfg.g0))(v, grid.gpos, grid.gneg)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_crossbar_dtypes(dtype):
    v, gpos, gneg = _inputs(128, 128, 128, dtype=dtype)
    out = ops.crossbar_mvm(v, gpos, gneg, g0=G0)
    expect = ref.crossbar_mvm_ref(v, gpos, gneg, g0=G0)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("dac,adc", [(8, None), (None, 8), (6, 10), (8, 8)])
def test_crossbar_quantisation(dac, adc):
    """DAC before the sum, ADC after the complete sum - bit-exact vs oracle."""
    v, gpos, gneg = _inputs(128, 128, 256, seed=3)
    out = ops.crossbar_mvm(v, gpos, gneg, g0=G0, dac_bits=dac, adc_bits=adc)
    expect = ref.crossbar_mvm_ref(v, gpos, gneg, g0=G0, dac_bits=dac,
                                  adc_bits=adc)
    # f32 sum-order differences may flip a value across one ADC step at the
    # rounding boundary: allow <= 1 LSB.
    lsb = 2.0 / (2 ** adc - 1) if adc else 0.0
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=1e-5, atol=2e-5 + lsb)


def test_crossbar_matches_analog_layer():
    """Kernel == core/analog.py circuit model on the same crossbar pair."""
    from repro.core import analog
    from repro.core.analog import AnalogConfig
    cfg = AnalogConfig(array_size=64)
    a = jax.random.normal(jax.random.PRNGKey(5), (64, 64)) / 8.0
    scale = 1.0 / jnp.max(jnp.abs(a))
    pair = analog.map_matrix(a, jax.random.PRNGKey(6), cfg, scale)
    v = jax.random.uniform(jax.random.PRNGKey(7), (1, 64), minval=-1, maxval=1)
    out_kernel = ops.crossbar_mvm(v, pair.gpos, pair.gneg, g0=cfg.g0)[0]
    out_circuit = analog.amc_mvm(pair, v[0], cfg)
    np.testing.assert_allclose(np.asarray(out_kernel), np.asarray(out_circuit),
                               rtol=1e-4, atol=1e-6)


# ------------------------------- arena_mvm --------------------------------

def _arena_level_inputs(s=96, k=8, l=5, r=16, c=16, terms=2, seed=9):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 3)
    arena = jax.random.normal(k1, (s, k))
    opstack = jax.random.normal(k2, (l, r, c)) / c
    in_offs = jax.random.randint(k3, (l, terms), 0, s - c).astype(jnp.int32)
    in_signs = jnp.where(
        jax.random.bernoulli(k3, 0.5, (l, terms)), 1.0, -1.0
    ).astype(jnp.float32)
    # non-overlapping output windows, half of them accumulating pairs
    out_offs = jnp.asarray([s - (i // 2 + 1) * r for i in range(l)],
                           jnp.int32)
    out_init = jnp.asarray([1 if i % 2 == 0 else 0 for i in range(l)],
                           jnp.int32)
    return arena, opstack, in_offs, in_signs, out_offs, out_init


@pytest.mark.parametrize("dac,adc", [(None, None), (8, 8)])
def test_arena_level_matches_ref(dac, adc):
    """Megakernel (interpret on CPU) == sequential jnp oracle: signed
    multi-term gather, init-vs-accumulate windows, fused quantisers."""
    args = _arena_level_inputs()
    out = ops.arena_level_apply(*args, dac_bits=dac, adc_bits=adc)
    expect = ref.arena_level_ref(*args, dac_bits=dac, adc_bits=adc)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=1e-5, atol=1e-5)


def test_arena_level_preserves_untouched_cells():
    """Cells outside this level's output windows carry through unchanged."""
    arena, opstack, in_offs, in_signs, out_offs, out_init = \
        _arena_level_inputs(l=2, r=8)
    out = ops.arena_level_apply(arena, opstack, in_offs, in_signs,
                                out_offs, out_init)
    touched = set()
    for o in np.asarray(out_offs):
        touched.update(range(int(o), int(o) + 8))
    keep = np.asarray([i for i in range(arena.shape[0])
                       if i not in touched])
    np.testing.assert_array_equal(np.asarray(out)[keep],
                                  np.asarray(arena)[keep])


def test_arena_kernel_runs_whole_cascade():
    """One pallas_call executes a full uniform BlockAMC schedule (the
    single-dispatch serving form) - pinned against the slot-SSA path."""
    from repro.core import blockamc
    from repro.core.analog import AnalogConfig
    from repro.core.nonideal import NonidealConfig
    from repro.data.matrices import random_rhs, wishart
    cfg = AnalogConfig(array_size=8, nonideal=NonidealConfig(sigma=0.05),
                       opa_gain=1e4)
    a = wishart(jax.random.PRNGKey(1), 32)
    ap = blockamc.compile_arena(blockamc.finalize(
        blockamc.build_flat_plan(a, jax.random.PRNGKey(2), cfg, 2), cfg))
    assert ap.program is not None
    b = random_rhs(jax.random.PRNGKey(3), 32)
    np.testing.assert_allclose(
        np.asarray(blockamc.execute_arena(ap, b, use_kernel=True)),
        np.asarray(blockamc.execute_arena(ap, b, use_kernel=False)),
        rtol=1e-6, atol=1e-7)


def _arena_packed_inputs(m=3, s=96, k=8, t=5, r=16, c=16, terms=2, seed=11):
    """Shared (T, ...) window metadata, per-instance (M, T, R, C) ops."""
    _, opstack, in_offs, in_signs, out_offs, out_init = \
        _arena_level_inputs(s=s, k=k, l=t, r=r, c=c, terms=terms, seed=seed)
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed + 1))
    arena = jax.random.normal(k1, (m, s, k))
    ops_m = jax.random.normal(k2, (m, t, r, c)) / c
    return arena, ops_m, in_offs, in_signs, out_offs, out_init


@pytest.mark.parametrize("dac,adc", [(None, None), (8, 8)])
def test_arena_packed_matches_ref(dac, adc):
    """Instance-packed megakernel (interpret on CPU) == per-instance
    oracle replay of the shared tile program."""
    args = _arena_packed_inputs()
    out = ops.arena_packed_apply(*args, dac_bits=dac, adc_bits=adc)
    expect = ref.arena_packed_ref(*args, dac_bits=dac, adc_bits=adc)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=1e-5, atol=1e-5)


def test_arena_packed_matches_per_instance_level_calls():
    """The instance grid axis changes the dispatch, not the numbers: the
    packed kernel == M independent `arena_level_apply` runs of the same
    program."""
    arena, ops_m, in_offs, in_signs, out_offs, out_init = \
        _arena_packed_inputs(m=4)
    out = ops.arena_packed_apply(arena, ops_m, in_offs, in_signs,
                                 out_offs, out_init)
    for i in range(arena.shape[0]):
        one = ref.arena_level_ref(arena[i], ops_m[i], in_offs, in_signs,
                                  out_offs, out_init)
        np.testing.assert_allclose(np.asarray(out[i]), np.asarray(one),
                                   rtol=1e-5, atol=1e-5)


def test_arena_packed_kernel_runs_whole_fleet():
    """One pallas_call executes the full uniform schedule of a packed
    multi-tenant fleet - pinned against the stacked slot-SSA path."""
    from repro.core import blockamc
    from repro.core.analog import AnalogConfig
    from repro.core.nonideal import NonidealConfig
    from repro.data.matrices import wishart
    cfg = AnalogConfig(array_size=8, nonideal=NonidealConfig(sigma=0.05),
                       opa_gain=1e4)
    m, n = 3, 32
    keys = jax.random.split(jax.random.PRNGKey(2), m)
    As = jnp.stack([wishart(jax.random.fold_in(jax.random.PRNGKey(1), i),
                            n) for i in range(m)])
    pp = blockamc.program_packed(As, keys, cfg, stages=2)
    assert pp.program_ops is not None
    for bs in (jax.random.normal(jax.random.PRNGKey(3), (m, n)),
               jax.random.normal(jax.random.PRNGKey(4), (m, n, 3))):
        np.testing.assert_allclose(
            np.asarray(blockamc.execute_arena_packed(pp, bs,
                                                     use_kernel=True)),
            np.asarray(blockamc.execute_arena_packed(pp, bs,
                                                     use_kernel=False)),
            rtol=1e-6, atol=1e-7)


# ------------------------------- schur_gemm -------------------------------

@pytest.mark.parametrize("i,j,k", [
    (128, 128, 128),
    (256, 128, 384),
    (100, 60, 130),      # ragged
])
def test_schur_matches_ref(i, j, k):
    k1, k2, k3 = jax.random.split(KEY, 3)
    a4 = jax.random.normal(k1, (i, j))
    a3 = jax.random.normal(k2, (i, k))
    w = jax.random.normal(k3, (k, j))
    out = ops.schur_update(a4, a3, w)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(ref.schur_update_ref(a4, a3, w)),
                               rtol=1e-4, atol=1e-4)


def test_schur_in_blockamc_context():
    """Kernel result plugs into the actual Schur pre-processing."""
    from repro.data.matrices import wishart
    a = wishart(jax.random.PRNGKey(1), 256)
    m = 128
    a1, a2, a3, a4 = a[:m, :m], a[:m, m:], a[m:, :m], a[m:, m:]
    w = jnp.linalg.solve(a1, a2)
    out = ops.schur_update(a4, a3, w)
    expect = a4 - a3 @ w
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=2e-2, atol=2e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_schur_dtypes(dtype):
    k1, k2, k3 = jax.random.split(KEY, 3)
    a4 = jax.random.normal(k1, (128, 128)).astype(dtype)
    a3 = jax.random.normal(k2, (128, 128)).astype(dtype)
    w = jax.random.normal(k3, (128, 128)).astype(dtype)
    out = ops.schur_update(a4, a3, w)
    expect = ref.schur_update_ref(a4, a3, w)
    tol = 1e-4 if dtype == jnp.float32 else 0.5
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=tol, atol=tol)


# ----------------------------- flash_attention -----------------------------

def _ref_attn_inputs(bh, s, d, dtype=jnp.float32, seed=0):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(k1, (bh, s, d), jnp.float32).astype(dtype)
    k = jax.random.normal(k2, (bh, s, d), jnp.float32).astype(dtype)
    v = jax.random.normal(k3, (bh, s, d), jnp.float32).astype(dtype)
    return q, k, v


@pytest.mark.parametrize("bh,s,d", [
    (2, 128, 128),     # single tile
    (1, 384, 128),     # 3x3 K blocks, causal skipping
    (2, 200, 128),     # ragged S -> causal padding path
])
def test_flash_attention_matches_ref(bh, s, d):
    q, k, v = _ref_attn_inputs(bh, s, d)
    out = ops.flash_attention(q, k, v)
    expect = ref.flash_attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 2e-5),
                                       (jnp.bfloat16, 3e-2)])
def test_flash_attention_dtypes(dtype, tol):
    q, k, v = _ref_attn_inputs(2, 256, 128, dtype=dtype)
    out = ops.flash_attention(q, k, v)
    expect = ref.flash_attention_ref(q, k, v)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(expect, np.float32),
        rtol=tol, atol=tol)


def test_flash_attention_in_model_layer():
    """Model attention with use_flash == the q-chunked jnp path."""
    import dataclasses
    from repro.configs import get_config
    from repro.models import attention as attn_mod
    from repro.models.attention import attention, init_attention
    cfg = dataclasses.replace(
        get_config("glm4-9b"), n_layers=1, d_model=256, n_heads=2,
        kv_heads=1, head_dim=128, vocab=64, d_ff=64,
        param_dtype="float32", compute_dtype="float32")
    params = init_attention(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 128, 256))
    pos = jnp.broadcast_to(jnp.arange(128)[None], (2, 128))
    out_flash = attention(params, x, pos, cfg, use_flash=True)
    out_chunk = attention(params, x, pos, cfg, use_flash=False)
    np.testing.assert_allclose(np.asarray(out_flash), np.asarray(out_chunk),
                               rtol=2e-4, atol=2e-4)

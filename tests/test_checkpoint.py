"""Checkpoint layer + durable programmed-state store contract (TESTING.md).

The contract under test:

* flatten keys are `jax.tree_util.keystr` paths, so a dict key `"0"` and
  a sequence index `0` are DIFFERENT leaves - the historical str()-joined
  keys collapsed them, letting a list-shaped checkpoint silently restore
  into a dict-shaped tree;
* every loaded leaf is cross-checked against the manifest's recorded
  shape/dtype: a truncated or rewritten file raises
  `CheckpointCorruptionError`, never a silent cast;
* `extra` manifest metadata (programming signatures, canary trips) rides
  along verbatim;
* `ProgramStore` round-trips a `ProgrammedSolver`'s programmed state
  bit-identically (same conductance stacks => same answers on CPU), and
  its identity layer rejects restores against a different matrix, key or
  plan signature with `StaleCheckpointError` BEFORE any array is read;
* `corrupt(how="truncate")` is caught by the integrity layer;
  `corrupt(how="values")` is manifest-consistent by design - restore
  succeeds but the answers are wrong, which is exactly why the fleet's
  install path re-runs the canary against the ORIGINAL trip threshold
  (that rejection is pinned in test_router.py).
"""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (CheckpointCorruptionError, CheckpointError,
                              ProgramStore, StaleCheckpointError, latest_step,
                              load_manifest, restore_checkpoint,
                              save_checkpoint)
from repro.core.analog import AnalogConfig
from repro.core.blockamc import ProgrammedSolver, plan_signature
from repro.core.nonideal import NonidealConfig
from repro.data.matrices import wishart

KEY = jax.random.PRNGKey(11)
N = 16
CFG = AnalogConfig(array_size=8, nonideal=NonidealConfig(sigma=0.02))


# ---------------------------------------------------------------------------
# flatten-key aliasing regression
# ---------------------------------------------------------------------------

def test_list_index_and_dict_key_do_not_alias(tmp_path):
    """A checkpoint saved from {"x": [a, b]} must NOT restore into
    {"x": {"0": ..., "1": ...}} - under the old str()-joined keys both
    flattened to "x/0", "x/1" and the restore silently succeeded."""
    a = np.arange(4.0)
    b = np.full(4, 7.0)
    save_checkpoint(str(tmp_path), 0, {"x": [a, b]})
    with pytest.raises(KeyError):
        restore_checkpoint(str(tmp_path), 0,
                           {"x": {"0": np.zeros(4), "1": np.zeros(4)}})


def test_list_tree_roundtrip_exact(tmp_path):
    tree = {"x": [np.arange(4.0), np.full(4, 7.0)], "y": np.eye(3)}
    save_checkpoint(str(tmp_path), 0, tree)
    out = restore_checkpoint(
        str(tmp_path), 0,
        {"x": [np.zeros(4), np.zeros(4)], "y": np.zeros((3, 3))})
    assert np.array_equal(np.asarray(out["x"][0]), tree["x"][0])
    assert np.array_equal(np.asarray(out["x"][1]), tree["x"][1])
    assert np.array_equal(np.asarray(out["y"]), tree["y"])


# ---------------------------------------------------------------------------
# integrity layer: manifest cross-check
# ---------------------------------------------------------------------------

def _leaf_file(directory, step, key):
    manifest = load_manifest(directory, step)
    meta = manifest["leaves"][key]
    return os.path.join(directory, f"step_{step:08d}", meta["file"])


def test_truncated_leaf_raises_corruption(tmp_path):
    save_checkpoint(str(tmp_path), 0, {"w": np.arange(64.0)})
    fpath = _leaf_file(str(tmp_path), 0, "['w']")
    with open(fpath, "r+b") as f:
        f.truncate(os.path.getsize(fpath) // 2)
    with pytest.raises(CheckpointCorruptionError):
        restore_checkpoint(str(tmp_path), 0, {"w": np.zeros(64)})


def test_manifest_shape_mismatch_raises_corruption(tmp_path):
    """A leaf file rewritten with a different shape/dtype than the
    manifest recorded must fail the cross-check - even when its shape
    happens to match the target tree (the silent-cast hazard)."""
    save_checkpoint(str(tmp_path), 0, {"w": np.arange(8.0)})
    fpath = _leaf_file(str(tmp_path), 0, "['w']")
    np.save(fpath, np.arange(8, dtype=np.int32))        # dtype flip
    with pytest.raises(CheckpointCorruptionError):
        restore_checkpoint(str(tmp_path), 0, {"w": np.zeros(8)})


def test_extra_metadata_roundtrip(tmp_path):
    save_checkpoint(str(tmp_path), 3, {"w": np.zeros(2)},
                    extra={"trip": 0.5, "signature": "sig"})
    assert latest_step(str(tmp_path)) == 3
    manifest = load_manifest(str(tmp_path), 3)
    assert manifest["extra"] == {"trip": 0.5, "signature": "sig"}


# ---------------------------------------------------------------------------
# ProgramStore: durable programmed-solver state
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def programmed():
    a = wishart(KEY, N)
    solver = ProgrammedSolver.program(a, jax.random.fold_in(KEY, 1), CFG,
                                      stages=1)
    sig = plan_signature(N, 1, CFG)
    return a, jax.random.fold_in(KEY, 1), solver, sig


def test_program_store_roundtrip_bit_identical(tmp_path, programmed):
    a, key, solver, sig = programmed
    store = ProgramStore(str(tmp_path))
    store.save("m", solver, a, key, sig, extra={"trip": 0.5})
    assert store.has("m") and store.matrix_ids() == ["m"]

    restored, meta = store.restore("m", solver, a, key, sig)
    assert meta["trip"] == 0.5
    assert restored.n == solver.n and restored.mode == solver.mode
    b = np.asarray(jax.random.normal(jax.random.fold_in(KEY, 2), (N,)))
    x0 = np.asarray(solver.solve(jnp.asarray(b)))
    x1 = np.asarray(restored.solve(jnp.asarray(b)))
    # same conductance stacks => the same fused program => identical bits
    assert np.array_equal(x0, x1)


def test_program_store_stale_rejections(tmp_path, programmed):
    a, key, solver, sig = programmed
    store = ProgramStore(str(tmp_path))
    store.save("m", solver, a, key, sig)

    other_a = wishart(jax.random.fold_in(KEY, 99), N)
    with pytest.raises(StaleCheckpointError):
        store.restore("m", solver, other_a, key, sig)
    with pytest.raises(StaleCheckpointError):
        store.restore("m", solver, a, jax.random.fold_in(KEY, 98), sig)
    other_cfg = AnalogConfig(array_size=8,
                             nonideal=NonidealConfig(sigma=0.05))
    with pytest.raises(StaleCheckpointError):
        store.restore("m", solver, a, key, plan_signature(N, 1, other_cfg))
    with pytest.raises(CheckpointError):
        store.restore("missing", solver, a, key, sig)


def test_program_store_truncate_corruption_detected(tmp_path, programmed):
    a, key, solver, sig = programmed
    store = ProgramStore(str(tmp_path))
    store.save("m", solver, a, key, sig)
    store.corrupt("m", how="truncate")
    with pytest.raises(CheckpointCorruptionError):
        store.restore("m", solver, a, key, sig)


def test_program_store_value_corruption_survives_integrity(
        tmp_path, programmed):
    """how="values" is manifest-consistent: identity and integrity layers
    pass, the restored answers are wrong - only the physics canary (the
    fleet install path) can catch it.  Pin that split here."""
    a, key, solver, sig = programmed
    store = ProgramStore(str(tmp_path))
    store.save("m", solver, a, key, sig)
    store.corrupt("m", how="values")
    restored, _ = store.restore("m", solver, a, key, sig)   # no raise
    b = np.asarray(jax.random.normal(jax.random.fold_in(KEY, 3), (N,)))
    x_good = np.asarray(solver.solve(jnp.asarray(b)))
    x_bad = np.asarray(restored.solve(jnp.asarray(b)))
    assert not np.allclose(x_good, x_bad, atol=1e-6)

"""Property-based plan-compilation invariants (flat + finalized forms).

The fixed-size goldens (test_flat_executor / test_golden_regression) pin
*values*; these tests pin the *structural* invariants of `compile_plan` /
`finalize` for random (n, stages) draws:

  * the flat schedule is well-formed: straight-line SSA over virtual
    registers (every register written before read, one new register per
    level), every operand length type-checks, and the final register is the
    full n-vector;
  * the shape buckets cover all physical arrays: every stacked array is
    referenced by the schedule at least once, every schedule reference is
    in range, and bucket keys match stack shapes;
  * finalized MVM windows tile exactly: each tile-row's input windows are
    contiguous from 0 to the level's input length, each tile is used
    exactly once, and group stacks/windows are congruent;
  * the arena allocator is sound (hypothesis target of the fused
    executor): no two overlapping live ranges share arena cells, every
    consumer/producer window stays inside its (live) slot, and the arena
    extent is peak liveness exactly on aligned schedules / within one
    slot of it on ragged ones.

Runs under hypothesis when installed (tests/_hypothesis_compat.py); a
fixed-size parametrized sweep keeps tier-1 coverage without it.
"""
import jax
import jax.numpy as jnp
import pytest

from repro.core import blockamc
from repro.core.analog import AnalogConfig
from repro.core.nonideal import NonidealConfig
from repro.data.matrices import wishart
from tests._hypothesis_compat import HAVE_HYPOTHESIS, given, settings, st

KEY = jax.random.PRNGKey(5)
KA, KN = jax.random.split(KEY)


def _check_flat_plan(fplan: blockamc.FlatPlan, n: int) -> None:
    inv_counts = [g.shape[-3] for g in fplan.inv_stacks]
    mvm_counts = [g.shape[-3] for g in fplan.mvm_stacks]
    used_inv, used_mvm = set(), set()
    lengths = {0: n}                  # register 0 is the cascade input
    next_reg = 1
    for instr in fplan.schedule:
        op = instr[0]
        if op == "slice":
            _, src, lo, hi = instr
            assert 0 <= src < next_reg, "read before write"
            assert 0 <= lo < hi <= lengths[src]
            lengths[next_reg] = hi - lo
        elif op == "inv":
            _, bk, i, src = instr
            assert 0 <= src < next_reg
            assert 0 <= bk < len(inv_counts) and 0 <= i < inv_counts[bk]
            used_inv.add((bk, i))
            r, c = fplan.inv_stacks[bk].shape[-2:]
            assert r == c == lengths[src], "INV operand length mismatch"
            lengths[next_reg] = r
        elif op == "mvm":
            _, rows, src = instr
            assert 0 <= src < next_reg
            in_len, out_len = None, 0
            for row in rows:
                row_cols, row_rows = 0, None
                for bk, i in row:
                    assert 0 <= bk < len(mvm_counts)
                    assert 0 <= i < mvm_counts[bk]
                    used_mvm.add((bk, i))
                    r, c = fplan.mvm_stacks[bk].shape[-2:]
                    row_rows = r if row_rows is None else row_rows
                    assert r == row_rows, "ragged tile-row heights"
                    row_cols += c
                in_len = row_cols if in_len is None else in_len
                assert row_cols == in_len, "tile-rows span different widths"
                out_len += row_rows
            assert in_len == lengths[src], "MVM operand length mismatch"
            lengths[next_reg] = out_len
        elif op == "add":
            _, s1, r1, s2, r2 = instr
            assert s1 in (-1, 1) and s2 in (-1, 1)
            assert 0 <= r1 < next_reg and 0 <= r2 < next_reg
            assert lengths[r1] == lengths[r2]
            lengths[next_reg] = lengths[r1]
        elif op == "catneg":
            _, r1, r2 = instr
            assert 0 <= r1 < next_reg and 0 <= r2 < next_reg
            lengths[next_reg] = lengths[r1] + lengths[r2]
        else:
            raise AssertionError(f"unknown schedule op {op!r}")
        next_reg += 1
    assert next_reg == len(fplan.schedule) + 1     # one register per level
    assert lengths[next_reg - 1] == n              # output is the n-vector
    # buckets cover all arrays: every stacked array is used, keys match
    assert used_inv == {(b, i) for b, c in enumerate(inv_counts)
                        for i in range(c)}
    assert used_mvm == {(b, i) for b, c in enumerate(mvm_counts)
                        for i in range(c)}
    for g, (_, shape) in zip(fplan.inv_stacks, fplan.inv_keys):
        assert tuple(g.shape[-2:]) == tuple(shape)
    for g, (_, shape) in zip(fplan.mvm_stacks, fplan.mvm_keys):
        assert tuple(g.shape[-2:]) == tuple(shape)
    assert fplan.num_arrays == sum(inv_counts) + sum(mvm_counts)


def _check_finalized(fin: blockamc.FinalizedPlan) -> None:
    fmvm_levels = [i for i in fin.schedule if i[0] == "fmvm"]
    assert len(fmvm_levels) == len(fin.mvm_levels)
    assert {i[1] for i in fmvm_levels} == set(range(len(fin.mvm_levels)))
    assert not any(i[0] == "mvm" for i in fin.schedule)  # all rewritten
    for lvl in fin.mvm_levels:
        for stack, wins in zip(lvl.stacks, lvl.windows):
            assert stack.shape[0] == len(wins)
            for lo, hi in wins:
                assert hi - lo == stack.shape[-1], "window != tile width"
        if lvl.divs:
            assert len(lvl.divs) == len(lvl.rows)
        seen = set()
        totals = set()
        for refs in lvl.rows:
            off = 0
            for g, i in refs:
                assert (g, i) not in seen, "tile used twice"
                seen.add((g, i))
                lo, hi = lvl.windows[g][i]
                assert lo == off, "windows do not tile contiguously"
                off = hi
            totals.add(off)
        assert len(totals) == 1, "tile-rows span different input lengths"
        assert seen == {(g, i) for g, wins in enumerate(lvl.windows)
                        for i in range(len(wins))}, "orphaned tiles"


def _check_arena(ap: blockamc.ArenaPlan) -> None:
    """Arena allocator invariants (the DESIGN note's layout contract).

    * live-range exclusivity: two materialized registers whose lifetimes
      overlap in schedule time never overlap in arena address space;
    * window containment: every consumer term window reads inside the slot
      of a register that is live at that level, and every tile's output
      window lies inside its destination register's slot;
    * the arena extent equals the schedule's peak liveness on aligned
      (single leaf shape) schedules and never exceeds peak + the largest
      slot on ragged ones (fragmentation slack: optimal offline packing
      can itself exceed peak liveness, so a slack-free bound is
      unattainable in general).
    """
    ranges = ap.slot_ranges      # per mreg: (offset, length, def, last_use)
    assert len(ranges) == len(ap.slot_offsets)
    assert all(r[0] == o for r, o in zip(ranges, ap.slot_offsets))
    # live-range exclusivity
    for i, (o1, l1, d1, u1) in enumerate(ranges):
        assert l1 > 0 and d1 <= u1
        for (o2, l2, d2, u2) in ranges[i + 1:]:
            time_overlap = not (u1 < d2 or u2 < d1)
            addr_overlap = not (o1 + l1 <= o2 or o2 + l2 <= o1)
            assert not (time_overlap and addr_overlap), \
                "live ranges share arena cells"
    # consumer/producer window containment, level by level (a level's
    # schedule position is its output register's def position)
    for level in ap.levels:
        p = ranges[level[0][2]][2]
        for sid, idx, m_out, out_local, init, segments in level:
            rows, cols = ap.stacks[sid].shape[-2:]
            covered = 0
            for dst_lo, seg_len, terms in segments:
                assert dst_lo == covered, "segments not contiguous"
                covered += seg_len
                assert terms, "empty gather term list"
                for m, off, sign in terms:
                    assert sign in (1, -1)
                    _, ln, d, u = ranges[m]
                    assert d < p <= u, "reads a register not live here"
                    assert 0 <= off and off + seg_len <= ln, \
                        "consumer window escapes its slot"
            assert covered == cols, "gather does not cover the operand"
            _, ln_out, d_out, _ = ranges[m_out]
            assert d_out == p, "tile writes a register it does not define"
            assert 0 <= out_local and out_local + rows <= ln_out, \
                "output window escapes its slot"
    # the output spec reads slots that survive to the end of the schedule
    end = max(u for (_, _, _, u) in ranges)
    for dst_lo, seg_len, terms in ap.out_spec:
        for m, off, sign in terms:
            _, ln, _, u = ranges[m]
            assert u == end and off + seg_len <= ln
    # extent vs peak liveness
    assert ap.arena_size >= ap.peak_liveness   # disjointness lower bound
    max_len = max(ln for (_, ln, _, _) in ranges)
    assert ap.arena_size <= ap.peak_liveness + max_len, \
        (ap.arena_size, ap.peak_liveness, max_len)
    if len({s.shape[-2:] for s in ap.stacks}) == 1 and ap.kernel_ok:
        assert ap.arena_size == ap.peak_liveness, \
            "aligned schedule fragmented"


def _build_and_check(n: int, stages: int, sigma: float) -> None:
    cfg = AnalogConfig(array_size=max(-(-n // max(2 ** stages, 1)), 2),
                       nonideal=NonidealConfig(sigma=sigma), opa_gain=1e4)
    a = wishart(KA, n)
    fplan = blockamc.compile_plan(blockamc.build_plan(a, KN, cfg,
                                                      stages=stages))
    _check_flat_plan(fplan, n)
    fin = blockamc.finalize(fplan, cfg)
    _check_finalized(fin)
    _check_arena(blockamc.compile_arena(fin))


@pytest.mark.parametrize("n,stages", [
    (8, 0), (17, 1), (24, 2), (33, 2), (64, 2), (13, 3),
])
def test_plan_invariants_fixed(n, stages):
    _build_and_check(n, stages, sigma=0.05)


@given(n=st.integers(min_value=6, max_value=48),
       stages=st.integers(min_value=0, max_value=3),
       noisy=st.booleans())
@settings(max_examples=15, deadline=None)
def test_plan_invariants_random(n, stages, noisy):
    """Random n x stages (ragged odd splits included): schedule well-formed,
    buckets cover all arrays, finalized windows tile exactly."""
    _build_and_check(n, stages, sigma=0.05 if noisy else 0.0)


# ---------------------------------------------------------------------------
# plan_signature: the packed-serving stackability key
# ---------------------------------------------------------------------------

def _structural_fingerprint(a, cfg, stages):
    """Every static artifact of the compile pipeline for one matrix - what
    two same-signature matrices must share exactly for their plans to pack
    on one instance axis (the stackability invariant, DESIGN note in
    core/blockamc.py)."""
    fplan = blockamc.compile_plan(blockamc.build_plan(a, KN, cfg,
                                                      stages=stages))
    ap = blockamc.compile_arena(blockamc.finalize(fplan, cfg))
    leaf_shapes = tuple(s.shape for s in ap.stacks)
    return (fplan.schedule, fplan.inv_keys, fplan.mvm_keys, leaf_shapes,
            ap.levels, ap.out_spec, ap.slot_offsets, ap.slot_ranges,
            ap.arena_size, ap.in_off, ap.kernel_ok)


def _check_signature_bucketing(n, stages, sigma):
    cfg = AnalogConfig(array_size=max(-(-n // max(2 ** max(stages, 1), 1)),
                                      2),
                       nonideal=NonidealConfig(sigma=sigma))
    sig = blockamc.plan_signature(n, stages, cfg)
    assert sig == blockamc.plan_signature(n, stages, cfg)
    hash(sig)                       # usable as a flush_all bucket key
    # same signature => two *different random matrices* compile to
    # identical schedules, bucket shapes and arena layouts
    a1 = wishart(jax.random.fold_in(KA, 1000 + n), n)
    a2 = wishart(jax.random.fold_in(KA, 2000 + n), n)
    assert _structural_fingerprint(a1, cfg, stages) == \
        _structural_fingerprint(a2, cfg, stages)
    # ...and therefore genuinely stack (pack_arena_plans accepts them)
    aps = [blockamc.compile_arena(blockamc.finalize(
        blockamc.compile_plan(blockamc.build_plan(a, KN, cfg,
                                                  stages=stages)), cfg))
        for a in (a1, a2)]
    pp = blockamc.pack_arena_plans(aps)
    assert pp.num_instances == 2
    # different problem shape => different signature
    assert blockamc.plan_signature(n + 1, stages, cfg) != sig
    if stages > 0:
        assert blockamc.plan_signature(n, stages - 1, cfg) != sig
    assert blockamc.plan_signature(
        n, stages, cfg.with_(array_size=cfg.array_size + 1)) != sig


@pytest.mark.parametrize("n,stages", [(16, 1), (17, 1), (32, 2), (33, 2)])
def test_signature_bucketing_fixed(n, stages):
    _check_signature_bucketing(n, stages, sigma=0.05)


@given(n=st.integers(min_value=6, max_value=40),
       stages=st.integers(min_value=0, max_value=3),
       noisy=st.booleans())
@settings(max_examples=10, deadline=None)
def test_signature_bucketing_random(n, stages, noisy):
    """Random (n, stages): equal signatures imply identical schedule +
    arena layout across different matrices; unequal n/stages/array_size
    hash apart."""
    _check_signature_bucketing(n, stages, sigma=0.05 if noisy else 0.0)


def test_signature_resolves_auto_stages():
    """stages=None buckets with the explicitly resolved depth, exactly
    like partition_system."""
    cfg = AnalogConfig(array_size=16)
    n = 64
    depth = blockamc.required_stages(n, cfg.array_size)
    assert blockamc.plan_signature(n, None, cfg) == \
        blockamc.plan_signature(n, depth, cfg)


@pytest.mark.skipif(not HAVE_HYPOTHESIS, reason="hypothesis not installed")
def test_hypothesis_is_exercised_in_ci():
    """Guard: CI installs hypothesis, so the property tests above run
    there even when local environments skip them."""
    assert HAVE_HYPOTHESIS

"""Beyond-paper extensions: wire-drop compensation + bit-sliced mapping."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from tests._hypothesis_compat import given, settings, st

from repro.core import analog, nonideal
from repro.core.analog import AnalogConfig
from repro.core.nonideal import NonidealConfig
from repro.data.matrices import random_rhs, wishart

G0 = 100e-6


# ----------------------- IR-drop compensation ------------------------------

@pytest.mark.parametrize("n", [16, 64, 256])
def test_compensation_recovers_target(n):
    """effective_conductance(compensate(G)) ~= G (ref [29] mitigation)."""
    a = jnp.abs(wishart(jax.random.PRNGKey(0), n))
    g = a / jnp.max(a) * G0
    g_prog = nonideal.compensate_conductances(g, 1.0)
    g_eff = nonideal.effective_conductance(g_prog, 1.0)
    uncomp_dev = float(jnp.linalg.norm(
        nonideal.effective_conductance(g, 1.0) - g) / jnp.linalg.norm(g))
    comp_dev = float(jnp.linalg.norm(g_eff - g) / jnp.linalg.norm(g))
    assert comp_dev < 0.05 * uncomp_dev


#  (test_compensation_against_exact_mna moved to tests/test_physics_oracle.py,
#   home of everything pinned against the dense MNA oracle)


def test_compensation_zero_r_identity():
    g = jnp.ones((8, 8)) * G0 * 0.5
    np.testing.assert_array_equal(
        np.asarray(nonideal.compensate_conductances(g, 0.0)), np.asarray(g))


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2 ** 16))
def test_property_compensation_physical(seed):
    """Programmed conductances stay non-negative for any positive target."""
    g = jax.random.uniform(jax.random.PRNGKey(seed), (12, 12), maxval=G0)
    g_prog = nonideal.compensate_conductances(g, 1.5)
    assert bool(jnp.all(g_prog >= 0.0))
    assert bool(jnp.all(g_prog >= g - 1e-12))   # compensation only adds


# ------------------------- bit-sliced mapping -------------------------------

def test_sliced_mvm_exact_when_noiseless():
    """2x4-bit slices reconstruct an 8-bit-grid matrix exactly."""
    cfg = AnalogConfig(array_size=32)
    a = jax.random.uniform(jax.random.PRNGKey(0), (32, 32),
                           minval=-1.0, maxval=1.0)
    # snap target to the representable k/256 grid (k <= 255)
    a = jnp.floor(jnp.minimum(jnp.abs(a), 255 / 256) * 256) / 256 * jnp.sign(a)
    v = random_rhs(jax.random.PRNGKey(1), 32)
    scale = 1.0   # already <= 255/256
    pairs = analog.map_matrix_sliced(a, jax.random.PRNGKey(2), cfg, scale,
                                     n_slices=2, bits_per_slice=4)
    out = analog.amc_mvm_sliced(pairs, v, cfg)
    np.testing.assert_allclose(np.asarray(out), np.asarray(-a @ v),
                               rtol=1e-4, atol=1e-5)


def test_sliced_mvm_extends_device_precision():
    """The honest bit-slicing claim (ISAAC): with 4-bit-resolution devices,
    two shift-added slices reach ~8-bit effective MVM precision, far beyond
    one 4-bit array.  (Under purely *additive* conductance noise slicing
    gives no SNR gain - the high slice re-enters at weight 1 - so the win
    is quantisation, which n_slices=1 vs 2 at fixed bits_per_slice shows.)"""
    cfg = AnalogConfig(array_size=64)   # noiseless: isolate quantisation
    a = jax.random.uniform(jax.random.PRNGKey(3), (64, 64),
                           minval=-1.0, maxval=1.0)
    v = random_rhs(jax.random.PRNGKey(4), 64)
    scale = (255 / 256) / jnp.max(jnp.abs(a))
    ref = -(a * scale) @ v
    key = jax.random.PRNGKey(100)
    one = analog.amc_mvm_sliced(
        analog.map_matrix_sliced(a, key, cfg, scale, n_slices=1,
                                 bits_per_slice=4), v, cfg)
    two = analog.amc_mvm_sliced(
        analog.map_matrix_sliced(a, key, cfg, scale, n_slices=2,
                                 bits_per_slice=4), v, cfg)
    err1 = float(jnp.linalg.norm(one - ref))
    err2 = float(jnp.linalg.norm(two - ref))
    assert err2 < err1 / 8.0      # ~16x expected; allow margin

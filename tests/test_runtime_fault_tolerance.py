"""Deep coverage of runtime fault-tolerance pieces the serving engine
leans on (`StepWatchdog`, `retry_step`) plus `ElasticMesh` gaps.

The basics (straggler flag fires, flaky fn recovers, exhaustion
re-raises) live in tests/test_optim_runtime.py; this file pins the
*contracts*: exact exponential backoff schedule (injected sleep, no
waiting), the `on_retry` hook ordering, non-retriable pass-through,
warmup and rolling-window semantics, and the hard-timeout timer that
fires mid-step rather than after it.
"""
import threading
import time

import pytest

from repro.runtime.elastic import ElasticMesh
from repro.runtime.fault_tolerance import StepWatchdog, retry_step


# ------------------------------ retry_step --------------------------------

def test_retry_backoff_schedule_is_exponential():
    sleeps, hooks = [], []

    def broken():
        raise RuntimeError("transient")

    with pytest.raises(RuntimeError):
        retry_step(broken, retries=3, backoff=0.1,
                   on_retry=lambda i, e: hooks.append(i),
                   sleep=sleeps.append)
    # attempt k sleeps backoff * 2**k; no sleep after the final give-up
    assert sleeps == pytest.approx([0.1, 0.2, 0.4])
    assert hooks == [0, 1, 2]


def test_retry_zero_backoff_never_sleeps():
    sleeps = []
    calls = {"n": 0}

    def flaky():
        calls["n"] += 1
        if calls["n"] < 2:
            raise RuntimeError("once")
        return "ok"

    assert retry_step(flaky, retries=2, backoff=0.0,
                      sleep=sleeps.append) == "ok"
    assert sleeps == []


def test_retry_on_retry_sees_the_exception():
    seen = []

    def flaky():
        if not seen:
            raise RuntimeError("first failure")
        return 1

    assert retry_step(flaky, retries=1,
                      on_retry=lambda i, e: seen.append((i, str(e)))) == 1
    assert seen == [(0, "first failure")]


def test_retry_nonretriable_propagates_immediately():
    calls = {"n": 0}

    def typed():
        calls["n"] += 1
        raise ValueError("not transient")

    with pytest.raises(ValueError):
        retry_step(typed, retries=5, retriable=(RuntimeError,))
    assert calls["n"] == 1             # no retry burned on a typed error


def test_retry_reraises_original_exception_object():
    err = RuntimeError("the original")

    def broken():
        raise err

    with pytest.raises(RuntimeError) as ei:
        retry_step(broken, retries=1)
    assert ei.value is err             # failover ladders match on identity


def test_retry_custom_retriable_tuple():
    calls = {"n": 0}

    def flaky():
        calls["n"] += 1
        if calls["n"] == 1:
            raise OSError("fd gone")
        return calls["n"]

    assert retry_step(flaky, retries=1, retriable=(OSError,)) == 2


# ------------------------------ StepWatchdog ------------------------------

def test_watchdog_quiet_during_warmup():
    events = []
    wd = StepWatchdog(factor=1.0, warmup_steps=5,
                      on_straggle=lambda t, m: events.append(t))
    # every step "exceeds" factor x median at factor=1, but warmup masks it
    for _ in range(5):
        with wd:
            time.sleep(0.002)
    assert events == []
    assert len(wd.durations) == 5


def test_watchdog_median_is_robust_to_one_outlier():
    events = []
    wd = StepWatchdog(factor=3.0, warmup_steps=3,
                      on_straggle=lambda t, m: events.append((t, m)))
    for _ in range(5):
        with wd:
            time.sleep(0.01)
    with wd:
        time.sleep(0.1)                # straggler no 1
    # the outlier joined the window but the *median* barely moved: a
    # subsequent normal step must not be flagged ...
    with wd:
        time.sleep(0.01)
    # ... and a second genuine straggler still is
    with wd:
        time.sleep(0.1)
    assert wd.straggles == 2
    assert all(t > 3.0 * m for t, m in events)


def test_watchdog_rolling_window_is_bounded():
    wd = StepWatchdog(factor=100.0, warmup_steps=0)
    for _ in range(130):
        with wd:
            pass
    assert len(wd.durations) == 100    # oldest durations fell off


def test_watchdog_hard_timeout_fires_mid_step():
    fired = threading.Event()
    wd = StepWatchdog(factor=3.0, warmup_steps=0, hard_timeout=0.05,
                      on_straggle=lambda t, m: fired.set())
    with wd:
        # the timer must fire while the step is still running - that is
        # the hang-detection contract (a hung step never reaches __exit__)
        assert fired.wait(timeout=2.0)
    assert fired.is_set()


def test_watchdog_hard_timeout_cancelled_on_fast_step():
    fired = threading.Event()
    wd = StepWatchdog(factor=3.0, warmup_steps=0, hard_timeout=0.2,
                      on_straggle=lambda t, m: fired.set())
    with wd:
        pass
    time.sleep(0.3)                    # past the would-be deadline
    assert not fired.is_set()


def test_watchdog_exception_still_cancels_timer_and_records():
    fired = threading.Event()
    wd = StepWatchdog(factor=3.0, warmup_steps=0, hard_timeout=0.2,
                      on_straggle=lambda t, m: fired.set())
    with pytest.raises(RuntimeError):
        with wd:
            raise RuntimeError("step died")
    time.sleep(0.3)
    assert not fired.is_set()          # timer cancelled despite the raise
    assert len(wd.durations) == 1      # the failed step's duration counts


# ------------------------------ ElasticMesh -------------------------------

def test_elastic_min_model_axis_floor():
    # 64 devices: candidates 16, 8, 4 all divide; the largest >= floor wins
    assert ElasticMesh(min_model_axis=4).choose_shape(64) == (4, 16)
    # floor prunes the small candidates: 2 would divide 10, but 2 < 4
    assert ElasticMesh(min_model_axis=4).choose_shape(10) == (10, 1)


def test_elastic_min_model_axis_forces_fallback():
    # nothing >= the floor divides 6 -> the (n, 1) fallback
    em = ElasticMesh(min_model_axis=4)
    assert em.choose_shape(6) == (6, 1)


def test_elastic_custom_candidate_order_is_respected():
    em = ElasticMesh(model_axis_candidates=(3, 2, 1))
    assert em.choose_shape(12) == (4, 3)
    assert em.choose_shape(8) == (4, 2)


def test_elastic_divisor_constraints_combine():
    em = ElasticMesh()
    # model axis must divide every listed model dim: gcd pressure
    assert em.choose_shape(64, model_divisors=(12, 20)) == (16, 4)
    assert em.choose_shape(64, model_divisors=(7,)) == (64, 1)


def test_elastic_make_mesh_shapes_and_axis_names():
    em = ElasticMesh()
    import jax
    mesh = em.make_mesh(jax.devices())
    assert mesh.axis_names == ("data", "model")
    assert mesh.devices.size == len(jax.devices())


# ------------------- replica-scoped chaos events (fleet) -------------------
#
# The fleet-level events ReplicaDeath / ReplicaStall / CheckpointCorruption
# share the injector's dispatch-counter keying with the PR-7 events, plus a
# `replica` scope: None matches every engine, a name pins the event to one
# engine's counter.  The end-to-end ladder lives in tests/test_router.py;
# these pin the firing semantics the ladder depends on.

def _chaos(*events, sleeps=None):
    from repro.runtime.chaos import ChaosInjector
    return ChaosInjector(events, sleep=(sleeps.append if sleeps is not None
                                        else (lambda s: None)))


def test_replica_death_scoping_and_fire_once():
    from repro.runtime import ReplicaDeath, ReplicaDeathError

    chaos = _chaos(ReplicaDeath(at_dispatch=2, replica="r0"))
    chaos.on_dispatch(5, replica="r1")      # scoped away: no fire
    chaos.on_dispatch(1, replica="r0")      # too early: no fire
    with pytest.raises(ReplicaDeathError):
        chaos.on_dispatch(2, replica="r0")
    chaos.on_dispatch(3, replica="r0")      # fire-once: dead events stay dead
    assert chaos.fired == 1


def test_replica_death_error_is_not_an_exception():
    """ReplicaDeathError must sail past `except Exception` containment and
    retry_step's retriable filter - it models a worker-killing fault."""
    from repro.runtime import ReplicaDeathError

    assert not issubclass(ReplicaDeathError, Exception)

    def dying():
        raise ReplicaDeathError("chaos")

    with pytest.raises(ReplicaDeathError):
        retry_step(dying, retries=3, backoff=0.0, sleep=lambda s: None)


def test_replica_stall_window_semantics():
    from repro.runtime import ReplicaStall

    sleeps = []
    chaos = _chaos(ReplicaStall(at_dispatch=2, seconds=0.5, until_dispatch=4,
                                replica="r0"), sleeps=sleeps)
    for idx in range(7):
        chaos.on_dispatch(idx, replica="r0")
    # armed on EVERY dispatch in [2, 4], silent outside the window
    assert sleeps == [0.5, 0.5, 0.5]
    assert chaos.fired == 1                 # logged once, not per dispatch
    chaos.on_dispatch(9, replica="r0")      # retired past the window
    assert len(sleeps) == 3


def test_replica_none_scope_matches_everyone():
    from repro.runtime import ReplicaStall

    sleeps = []
    chaos = _chaos(ReplicaStall(at_dispatch=0, seconds=0.1), sleeps=sleeps)
    chaos.on_dispatch(0, replica="r0")
    chaos.on_dispatch(0, replica="r1")
    chaos.on_dispatch(0)                    # engine outside any fleet
    assert sleeps == [0.1, 0.1, 0.1]


def test_checkpoint_corruption_due_fire_once():
    from repro.runtime import CheckpointCorruption

    ev = CheckpointCorruption(at_dispatch=3, matrix_id="m", how="truncate")
    chaos = _chaos(ev)
    assert chaos.corruptions_due(2) == []
    assert chaos.corruptions_due(5) == [ev]
    assert chaos.corruptions_due(6) == []   # fire-once
    assert chaos.log == [(5, ev)]


# ------------------------ replica placement (fleet) ------------------------

def test_assign_replicas_round_robin_wraps():
    em = ElasticMesh()
    pool = ["d0", "d1", "d2"]
    assert em.assign_replicas(5, pool) == ["d0", "d1", "d2", "d0", "d1"]
    assert em.assign_replicas(2, pool) == ["d0", "d1"]
    # deterministic in (n_replicas, pool order): same call, same placement
    assert em.assign_replicas(5, pool) == em.assign_replicas(5, pool)
    with pytest.raises(ValueError):
        em.assign_replicas(1, [])


def test_assign_replicas_default_pool_is_jax_devices():
    import jax
    em = ElasticMesh()
    got = em.assign_replicas(2)
    dev = jax.devices()
    assert got == [dev[0], dev[1 % len(dev)]]

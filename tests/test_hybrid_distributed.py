"""Hybrid refinement + distributed solver + macro/area model tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import area_energy, blockamc, distributed, hybrid, macro
from repro.core.analog import AnalogConfig
from repro.core.nonideal import NonidealConfig
from repro.core.metrics import relative_error
from repro.data.matrices import wishart, random_rhs

KA, KB, KN = jax.random.split(jax.random.PRNGKey(0), 3)


# ------------------------------- hybrid ----------------------------------

def test_cg_refine_converges():
    a = wishart(KA, 64)
    b = random_rhs(KB, 64)
    x_ref = jnp.linalg.solve(a, b)
    x = hybrid.cg_refine(a, b, jnp.zeros_like(b), 80)
    assert float(relative_error(x_ref, x)) < 1e-4


def test_analog_seed_saves_iterations():
    """The paper's positioning: AMC seed accelerates digital iteration.

    The seed comes from a `ProgrammedSolver` - programmed once, *outside*
    the iteration - and the refinement runs through the batched hybrid
    drivers, so this exercises the genuine analog->digital hand-off (the
    old version rebuilt the plan per call and only ever timed the digital
    path).  Richardson is the discriminating iteration: its saving is
    proportional to log(seed error), where Krylov methods barely move.
    """
    a = wishart(KA, 96)
    b = random_rhs(KB, 96)
    cfg = AnalogConfig(array_size=48, nonideal=NonidealConfig(sigma=0.05))
    solver = blockamc.ProgrammedSolver.program(a, KN, cfg, stages=1)
    x_seed = solver.solve(b)
    assert float(jnp.linalg.norm(b - a @ x_seed)) > 0.0   # noisy, not exact
    _, it_seed = hybrid.iterations_to_tol(a, b, x_seed, tol=1e-5,
                                          method="richardson",
                                          max_iters=20000)
    _, it_zero = hybrid.iterations_to_tol(a, b, jnp.zeros_like(b), tol=1e-5,
                                          method="richardson",
                                          max_iters=20000)
    assert int(it_seed) < int(it_zero)                    # strict saving
    # and the batched driver seeded with the same x0 agrees on convergence
    res = hybrid.pcg(hybrid.matvec_from_dense(a), b, x0=x_seed, tol=1e-5,
                     maxiter=500)
    assert bool(res.converged)


@pytest.mark.slow
def test_refined_256_two_stage_reaches_1e10():
    """The 256^2 paper config (Fig. 8: two stages, 64^2 arrays) refined to
    full double precision: seed-only CG from the programmed analog solve
    reaches 1e-10 where the sigma=0.05 analog cascade alone cannot."""
    from jax.experimental import enable_x64
    with enable_x64():
        n = 256
        a = wishart(KA, n, dtype=jnp.float64)
        b = random_rhs(KB, n).astype(jnp.float64)
        cfg = AnalogConfig(array_size=64,
                           nonideal=NonidealConfig(sigma=0.05))
        precond = hybrid.AnalogPreconditioner.program(a, KN, cfg, stages=2)
        raw_res = float(jnp.linalg.norm(b - a @ precond(b))
                        / jnp.linalg.norm(b))
        assert raw_res > 1e-6
        x, res = hybrid.solve_refined(a, b, precond, method="cg", tol=1e-10,
                                      maxiter=2000, use_precond=False)
        assert bool(res.converged) and float(res.resnorm) <= 1e-10


def test_richardson_reduces_residual():
    a = wishart(KA, 32)
    b = random_rhs(KB, 32)
    x0 = jnp.zeros_like(b)
    x = hybrid.richardson_refine(a, b, x0, 200)
    r0 = float(jnp.linalg.norm(b - a @ x0))
    r1 = float(jnp.linalg.norm(b - a @ x))
    assert r1 < 0.1 * r0


def test_iterations_to_tol_fuel_bound():
    a = wishart(KA, 32)
    b = random_rhs(KB, 32)
    _, k = hybrid.iterations_to_tol(a, b, jnp.zeros_like(b), tol=1e-30,
                                    max_iters=17)
    assert int(k) == 17


# ----------------------------- distributed --------------------------------

@pytest.mark.slow
def test_distributed_matches_sequential_ideal():
    n = 128
    a = wishart(KA, n)
    b = random_rhs(KB, n)
    x_ref = jnp.linalg.solve(a, b)
    cfg = AnalogConfig(array_size=32)
    x = distributed.solve_distributed(a, b, KN, cfg, stages=2)
    assert float(relative_error(x_ref, x)) < 1e-4


def test_distributed_with_noise_finite():
    n = 64
    a = wishart(KA, n)
    b = random_rhs(KB, n)
    cfg = AnalogConfig(array_size=16, nonideal=NonidealConfig(sigma=0.05))
    x = distributed.solve_distributed(a, b, KN, cfg, stages=1)
    assert bool(jnp.all(jnp.isfinite(x)))


def test_block_inv():
    a = wishart(KA, 96)
    ai = distributed.block_inv(a, 24)
    np.testing.assert_allclose(np.asarray(ai @ a), np.eye(96),
                               atol=5e-4)


def test_mvm_tiled_vec_matches_dense():
    n = 64
    a = wishart(KA, n)
    v = random_rhs(KB, n)
    cfg = AnalogConfig(array_size=16)
    scale = 1.0 / jnp.max(jnp.abs(a))
    grid = distributed.map_tiled_vec(a, KN, cfg, scale)
    out = distributed.mvm_tiled_vec(grid, v, cfg)
    np.testing.assert_allclose(np.asarray(out), np.asarray(-(a * scale) @ v),
                               rtol=1e-4, atol=1e-6)


# ------------------------------ macro model --------------------------------

def test_one_stage_latency_five_cycles():
    perf = macro.solver_performance("one_stage", n_solves=1)
    assert perf["latency_cycles"] == 5.0


def test_one_stage_shared_opa_serialises():
    """One shared OPA set: initiation interval == 5 cycles per solve."""
    perf = macro.solver_performance("one_stage", n_solves=8)
    assert perf["initiation_interval"] == 5.0


def test_two_stage_pipelines_across_macros():
    """Four macros + dedicated MVM sets: II better than latency."""
    perf = macro.solver_performance("two_stage", n_solves=8)
    assert perf["latency_cycles"] > 5.0          # deeper cascade
    assert perf["initiation_interval"] < perf["latency_cycles"]


# --------------------------- area/energy model -----------------------------

def test_area_power_savings_match_paper():
    """Abstract: 48.83% area and 40% energy saving for one-stage; Fig. 10:
    12.3% / 37.4% for two-stage."""
    rep = area_energy.report()
    sav = area_energy.savings(rep)
    assert abs(sav["area"]["one_stage"] - 0.4883) < 2e-3
    assert abs(sav["area"]["two_stage"] - 0.1230) < 2e-3
    assert abs(sav["power"]["one_stage"] - 0.400) < 2e-3
    assert abs(sav["power"]["two_stage"] - 0.374) < 2e-3


def test_area_totals_match_paper():
    rep = area_energy.report()
    assert abs(rep["area"]["original"]["total"] - 0.01577) < 1e-5
    assert abs(rep["area"]["one_stage"]["total"] - 0.00807) < 1e-4
    assert abs(rep["area"]["two_stage"]["total"] - 0.01383) < 1e-4


def test_unit_costs_positive():
    cal = area_energy.solve_calibration()
    for kind in ("area", "power"):
        u = cal[kind]
        assert u.opa_fixed > 0 and u.opa_per_width > 0
        assert u.dac > 0 and u.adc > 0 and u.cell > 0

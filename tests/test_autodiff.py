"""Differentiable solver contract (TESTING.md "differentiable solver
contract"): implicit-diff VJP through the arena executor and its riders.

Covers:

  * finite-difference gradient checks of `jax.grad` through
    `ProgrammedSolver.solve` across the full grid stages {0, 1, 2} x
    nonideality {ideal, sigma, wire} x rhs {(n,), (n, k)};
  * the packed (multi-tenant) executor's gradient;
  * the implicit-diff VJP around `solve_refined` against the closed-form
    adjoint (lambda = A^-T w, A_bar = -lambda x^T);
  * the backward pass re-programs nothing: the grad jaxpr contains no
    factorization (`lu`) and no `while_loop` primitives;
  * straight-through converter gradients (surrogate = gradient of the
    clip; primal bit-identical);
  * `AnalogPreconditioner` as a pytree under jit/grad/vmap: array-only
    leaves, hashable static aux, and a retrace guard across re-programmed
    instances (the PR 4 pattern);
  * seed sanitization: a fully-faulted (stuck-at) crossbar yields a
    non-finite analog seed, and `solve_refined` still converges from the
    zeroed seed;
  * wire calibration: gradient descent through the solver recovers a
    planted wire resistance from the exact nodal oracle to < 5%.

All tolerance-sensitive checks run in f64 via `enable_x64` - the contract
is about *structure* of the gradients; f32 only adds rounding noise.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.experimental import enable_x64

from repro.calib import calibrate_wire
from repro.core import blockamc
from repro.core.analog import AnalogConfig
from repro.core.nonideal import NonidealConfig
from repro.core.quantization import quantize
from repro.data.matrices import random_rhs, wishart
from repro.hybrid import AnalogPreconditioner, pcg, pcg_fixed, solve_refined
from repro.hybrid.operators import matvec_from_dense

KEY = jax.random.PRNGKey(21)
KA, KB, KN, KW = jax.random.split(KEY, 4)

N = 8

NONIDEAL_GRID = {
    "ideal": NonidealConfig(),
    "sigma": NonidealConfig(sigma=0.05),
    "wire": NonidealConfig(sigma=0.01, r_wire=1.0),
}


def _fd_grad(f, x, eps=1e-5):
    """Central finite-difference gradient of scalar f at x, elementwise."""
    x = np.asarray(x)
    flat = x.ravel()
    g = np.zeros_like(flat)
    for i in range(flat.size):
        xp = flat.copy()
        xm = flat.copy()
        xp[i] += eps
        xm[i] -= eps
        g[i] = (f(jnp.asarray(xp.reshape(x.shape))) -
                f(jnp.asarray(xm.reshape(x.shape)))) / (2 * eps)
    return g.reshape(x.shape)


def _spd(key, n, dtype):
    a = jax.random.normal(key, (n, n), dtype)
    return a @ a.T + n * jnp.eye(n, dtype=dtype)


# ------------------- FD grid through ProgrammedSolver ----------------------

@pytest.mark.parametrize("stages", [0, 1, 2])
@pytest.mark.parametrize("ni", sorted(NONIDEAL_GRID))
@pytest.mark.parametrize("shape", ["vec", "mat"])
def test_grad_through_solve_matches_fd(stages, ni, shape):
    """jax.grad of w . solve(b) wrt b matches central differences."""
    with enable_x64():
        a = _spd(KA, N, jnp.float64)
        cfg = AnalogConfig(array_size=N, nonideal=NONIDEAL_GRID[ni])
        solver = blockamc.ProgrammedSolver.program(a, KN, cfg, stages=stages)
        b = (random_rhs(KB, N) if shape == "vec"
             else jax.random.normal(KB, (N, 3))).astype(jnp.float64)
        w = jax.random.normal(KW, b.shape, jnp.float64)

        def loss(bb):
            return jnp.sum(w * solver.solve(bb))

        g = jax.grad(loss)(b)
        fd = _fd_grad(lambda bb: float(loss(bb)), b)
        np.testing.assert_allclose(np.asarray(g), fd, rtol=1e-4, atol=1e-9)


def test_grad_through_packed_executor_matches_fd():
    """The packed multi-tenant executor carries gradients per instance."""
    with enable_x64():
        cfg = AnalogConfig(array_size=4)
        solvers = [
            blockamc.ProgrammedSolver.program(
                _spd(jax.random.fold_in(KA, i), N, jnp.float64),
                jax.random.fold_in(KN, i), cfg)
            for i in range(2)
        ]
        pp = blockamc.pack_arena_plans([s.arena for s in solvers])
        bs = jax.random.normal(KB, (2, N, 2), jnp.float64)
        w = jax.random.normal(KW, bs.shape, jnp.float64)

        def loss(bb):
            return jnp.sum(w * blockamc.execute_arena_packed(pp, bb))

        g = jax.grad(loss)(bs)
        fd = _fd_grad(lambda bb: float(loss(bb)), bs)
        np.testing.assert_allclose(np.asarray(g), fd, rtol=1e-4, atol=1e-9)
        # per-instance isolation: instance 0's grad is independent of
        # instance 1's rhs (block-diagonal Jacobian)
        bs2 = bs.at[1].mul(3.0)
        np.testing.assert_allclose(np.asarray(jax.grad(loss)(bs2)[0]),
                                   np.asarray(g[0]), rtol=1e-12)


# ----------------- implicit diff around solve_refined ----------------------

def test_grad_through_solve_refined_matches_analytic_adjoint():
    """IFT adjoint: d(w.x)/db = A^-T w, d(w.x)/dA = -(A^-T w) x^T."""
    with enable_x64():
        n = 12
        a = _spd(KA, n, jnp.float64)
        b = random_rhs(KB, n).astype(jnp.float64)
        w = jax.random.normal(KW, (n,), jnp.float64)
        cfg = AnalogConfig(array_size=8)
        precond = AnalogPreconditioner.program(a, KN, cfg)

        def loss(aa, bb):
            x, _ = solve_refined(aa, bb, precond, method="cg", tol=1e-12,
                                 maxiter=600, use_precond=False)
            return jnp.sum(w * x)

        g_a, g_b = jax.grad(loss, argnums=(0, 1))(a, b)
        lam = np.linalg.solve(np.asarray(a).T, np.asarray(w))
        x = np.linalg.solve(np.asarray(a), np.asarray(b))
        np.testing.assert_allclose(np.asarray(g_b), lam, rtol=1e-7)
        np.testing.assert_allclose(np.asarray(g_a), -np.outer(lam, x),
                                   rtol=1e-6, atol=1e-10)


def test_pcg_fixed_matches_pcg_and_differentiates():
    """pcg_fixed == pcg(tol=0, maxiter=k) numerically, and grads flow."""
    with enable_x64():
        n = 16
        a = _spd(KA, n, jnp.float64)
        bt = jax.random.normal(KB, (3, n), jnp.float64)
        mv = matvec_from_dense(a)
        ref = pcg(mv, bt, tol=0.0, maxiter=6)
        fix = pcg_fixed(mv, bt, iters=6)
        np.testing.assert_allclose(np.asarray(fix.x), np.asarray(ref.x),
                                   rtol=1e-12)

        g = jax.grad(lambda bb: jnp.sum(pcg_fixed(mv, bb, iters=6).x))(bt)
        assert bool(jnp.all(jnp.isfinite(g))) and float(
            jnp.abs(g).max()) > 0.0


# ----------------------- no re-programming in backward ---------------------

def _collect_primitives(jaxpr, acc):
    for eqn in jaxpr.eqns:
        acc.add(eqn.primitive.name)
        for val in eqn.params.values():
            vals = val if isinstance(val, (list, tuple)) else [val]
            for sub in vals:
                if hasattr(sub, "jaxpr"):       # ClosedJaxpr
                    _collect_primitives(sub.jaxpr, acc)
                elif hasattr(sub, "eqns"):      # raw Jaxpr
                    _collect_primitives(sub, acc)
    return acc


def test_backward_pass_reprograms_nothing():
    """The grad jaxpr through the arena executor holds no factorization
    (`lu` runs at programming/compile time only) and no while_loop - the
    backward is one transposed cascade, ~1 forward solve."""
    with enable_x64():
        a = _spd(KA, N, jnp.float64)
        cfg = AnalogConfig(array_size=4, nonideal=NONIDEAL_GRID["wire"])
        solver = blockamc.ProgrammedSolver.program(a, KN, cfg)
        b = random_rhs(KB, N).astype(jnp.float64)

        def loss(bb):
            return jnp.sum(solver.solve(bb, jit=False))

        prims = _collect_primitives(
            jax.make_jaxpr(jax.grad(loss))(b).jaxpr, set())
        assert "lu" not in prims, prims
        assert "while" not in prims, prims


# ------------------------- straight-through converters ---------------------

def test_quantize_straight_through_gradient():
    v = jnp.asarray([-1.4, -0.6, 0.0, 0.3, 0.99, 1.7], jnp.float32)
    out = quantize(v, 8, 1.0)
    # primal: plain clip+round quantiser, bit-identical to the pre-STE form
    levels = 2 ** 8 - 1
    step = 2.0 / levels
    np.testing.assert_array_equal(
        np.asarray(out),
        np.round(np.clip(np.asarray(v), -1.0, 1.0) / step) * step)
    # surrogate: gradient of the clip (1 inside full-scale, 0 outside)
    g = jax.grad(lambda u: jnp.sum(quantize(u, 8, 1.0)))(v)
    np.testing.assert_array_equal(np.asarray(g),
                                  np.asarray([0., 1., 1., 1., 1., 0.],
                                             np.float32))


def test_grad_flows_through_quantized_converters():
    """With real DAC/ADC bits the solver still yields finite, useful
    gradients (STE), where the exact derivative would be zero a.e."""
    with enable_x64():
        a = _spd(KA, N, jnp.float64)
        cfg = AnalogConfig(array_size=N, dac_bits=10, adc_bits=10,
                           v_fullscale=4.0)
        solver = blockamc.ProgrammedSolver.program(a, KN, cfg)
        b = 0.1 * random_rhs(KB, N).astype(jnp.float64)
        g = jax.grad(lambda bb: jnp.sum(solver.solve(bb)))(b)
        assert bool(jnp.all(jnp.isfinite(g)))
        assert float(jnp.abs(g).max()) > 0.0


# ----------------------- preconditioner pytree audit -----------------------

def _program_pair():
    cfg = AnalogConfig(array_size=4)
    a = _spd(KA, N, jnp.float32)
    return (AnalogPreconditioner.program(a, jax.random.fold_in(KN, 0), cfg),
            AnalogPreconditioner.program(a, jax.random.fold_in(KN, 1), cfg))


def test_preconditioner_pytree_leaves_are_arrays_only():
    p1, p2 = _program_pair()
    leaves, treedef = jax.tree_util.tree_flatten(p1)
    # every leaf is a jax array (calibratable data or int plan arrays);
    # static metadata (mode, level/window tuples) must live in aux_data
    assert leaves and all(isinstance(l, jax.Array) for l in leaves)
    hash(treedef)  # aux_data must stay hashable (jit cache key)
    assert treedef == jax.tree_util.tree_flatten(p2)[1]
    # differentiable leaves are exactly the inexact ones; int leaves
    # (pivots, window programs) ride along but take no cotangent
    assert any(jnp.issubdtype(l.dtype, jnp.inexact) for l in leaves)


def test_preconditioner_retrace_guard_across_reprogram():
    """Re-programming (same matrix, new key) must hit the same jit cache
    entry: structure and aux are key-stable (the PR 4 executor pattern)."""
    apply = jax.jit(lambda p, v: p(v))
    if not hasattr(apply, "_cache_size"):
        pytest.skip("jax.jit cache introspection unavailable")
    p1, p2 = _program_pair()
    v = random_rhs(KB, N)
    apply(p1, v).block_until_ready()
    before = apply._cache_size()
    apply(p2, v).block_until_ready()
    apply(p1, 2.0 * v).block_until_ready()
    assert apply._cache_size() == before


def test_preconditioner_composes_with_grad_and_vmap():
    p1, _ = _program_pair()
    v = random_rhs(KB, N)
    g = jax.grad(lambda u: jnp.sum(p1(u)))(v)
    assert bool(jnp.all(jnp.isfinite(g))) and float(jnp.abs(g).max()) > 0.0
    vs = jnp.stack([v, 2.0 * v, -v])
    batched = jax.vmap(p1)(vs)
    np.testing.assert_allclose(np.asarray(batched[1]),
                               np.asarray(p1(2.0 * v)), rtol=1e-6)


# --------------------------- seed sanitization -----------------------------

def test_stuck_at_seed_is_sanitized_per_column():
    """A fully stuck-OFF crossbar programs a singular effective operator;
    the analog seed goes non-finite, and `solve_refined` must degrade to
    the zero seed instead of answering NaN."""
    with enable_x64():
        n = 8
        a = _spd(KA, n, jnp.float64)
        cfg = AnalogConfig(array_size=n, nonideal=NonidealConfig(
            p_stuck_off=1.0, g_stuck_off=0.0))
        precond = AnalogPreconditioner.program(a, KN, cfg)
        b = random_rhs(KB, n).astype(jnp.float64)
        seed = precond(b)
        assert not bool(jnp.all(jnp.isfinite(seed)))   # the hazard is real
        x, res = solve_refined(a, b, precond, method="cg", tol=1e-10,
                               maxiter=400, use_precond=False)
        assert bool(jnp.all(jnp.isfinite(x)))
        assert bool(res.converged)
        np.testing.assert_allclose(np.asarray(x),
                                   np.linalg.solve(np.asarray(a),
                                                   np.asarray(b)),
                                   rtol=1e-6)


# ------------------------------ calibration --------------------------------

def test_wire_grad_matches_fd():
    """d(solver output)/d(r_wire) through finalize -> arena matches FD."""
    with enable_x64():
        a = _spd(KA, N, jnp.float64)
        cfg = AnalogConfig(array_size=4)
        fplan = blockamc.compile_plan(blockamc.build_plan(a, KN, cfg))
        b = random_rhs(KB, N).astype(jnp.float64)

        def out_at(r):
            fin = blockamc.finalize(fplan, cfg, r_wire=r)
            return jnp.sum(blockamc.execute_arena(
                blockamc.compile_arena(fin), b))

        g = jax.grad(out_at)(jnp.asarray(1.0, jnp.float64))
        eps = 1e-4
        fd = (float(out_at(jnp.asarray(1.0 + eps))) -
              float(out_at(jnp.asarray(1.0 - eps)))) / (2 * eps)
        np.testing.assert_allclose(float(g), fd, rtol=1e-5)


def test_wire_calibration_recovers_planted_resistance():
    """Acceptance: descend through the differentiable solver to recover a
    planted 1 Ohm from the exact nodal oracle to < 5% relative error."""
    with enable_x64():
        a = _spd(jax.random.fold_in(KA, 3), N, jnp.float64)
        cal = calibrate_wire(a, r_true=1.0, steps=120)
        assert cal.rel_err(1.0) < 0.05, (cal.r_hat, cal.loss)
        assert cal.history[-1] < cal.history[0]   # the descent descended

"""Shared test fixtures: reduced per-family model configs."""
import dataclasses

import pytest

from repro.configs import get_config


def reduce_cfg(cfg, **overrides):
    """Shrink any arch config to smoke-test size, keeping its topology."""
    kw = dict(
        n_layers=4 if not cfg.layer_pattern else 2 * len(cfg.layer_pattern),
        d_model=64,
        d_ff=128 if cfg.d_ff else 0,
        vocab=512,
        n_heads=4 if cfg.n_heads else 0,
        kv_heads=min(cfg.kv_heads, 2) if cfg.kv_heads else 0,
        head_dim=16,
        lru_width=64 if cfg.lru_width else None,
        n_experts=4 if cfg.n_experts else 0,
        local_window=8,
        ssm_state=16,
        ssm_head_dim=8,
        ssm_chunk=4,
        param_dtype="float32",
        compute_dtype="float32",
    )
    kw.update(overrides)
    return dataclasses.replace(cfg, **kw)


@pytest.fixture
def tiny_dense():
    return reduce_cfg(get_config("glm4-9b"))

"""Continuous batching: slot reuse correctness vs the static engine."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import transformer as tr
from repro.models.lm_engine import Engine
from repro.models.lm_scheduler import ContinuousBatchingEngine, Request, reset_slots
from tests.conftest import reduce_cfg


@pytest.fixture(scope="module")
def setup():
    cfg = reduce_cfg(get_config("glm4-9b"))
    params = tr.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _static_reference(cfg, params, prompt, max_new, max_len):
    engine = Engine(cfg, params, max_len=max_len)
    toks = jnp.asarray(prompt, jnp.int32)[None, :]
    return [int(t) for t in np.asarray(engine.generate(toks, max_new))[0]]


@pytest.mark.slow
def test_continuous_matches_static_per_request(setup):
    """Each request served via slot reuse == the same request served alone."""
    cfg, params = setup
    key = jax.random.PRNGKey(7)
    prompts = [
        [int(t) for t in jax.random.randint(jax.random.fold_in(key, i),
                                            (ln,), 0, cfg.vocab)]
        for i, ln in enumerate([5, 9, 4, 7, 6])
    ]
    reqs = [Request(req_id=i, prompt=p, max_new=6)
            for i, p in enumerate(prompts)]
    cbe = ContinuousBatchingEngine(cfg, params, n_slots=2, max_len=32)
    got = cbe.run(reqs)
    assert sorted(got) == [0, 1, 2, 3, 4]
    for i, p in enumerate(prompts):
        ref = _static_reference(cfg, params, p, 6, 32)
        assert got[i] == ref, (i, got[i], ref)


def test_more_requests_than_slots_all_complete(setup):
    cfg, params = setup
    reqs = [Request(req_id=i, prompt=[1 + i, 2 + i], max_new=3)
            for i in range(7)]
    cbe = ContinuousBatchingEngine(cfg, params, n_slots=3, max_len=16)
    got = cbe.run(reqs)
    assert len(got) == 7
    assert all(len(v) == 3 for v in got.values())


def test_reset_slots_zeroes_only_masked(setup):
    cfg, params = setup
    cache = tr.init_cache(3, 8, cfg, dtype=jnp.float32)
    # fill with ones, reset slot 1
    cache = jax.tree.map(lambda x: jnp.ones_like(x), cache)
    mask = jnp.asarray([False, True, False])
    cache2 = reset_slots(cache, mask)
    for path, leaf in jax.tree_util.tree_flatten_with_path(cache2)[0]:
        ax = 1 if any(str(getattr(p, "key", "")) == "blocks" for p in path) else 0
        moved = jnp.moveaxis(leaf, ax, 0)
        assert float(jnp.sum(jnp.abs(moved[1]))) == 0.0
        assert float(jnp.min(jnp.abs(moved[0]))) == 1.0
        assert float(jnp.min(jnp.abs(moved[2]))) == 1.0

"""AMC circuit primitive tests: signs, mapping, quantisation, tiling, gain."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import analog
from repro.core.analog import AnalogConfig, map_matrix, map_tiled
from repro.data.matrices import wishart, random_rhs

KEY = jax.random.PRNGKey(0)
KA, KB, KN = jax.random.split(KEY, 3)
CFG = AnalogConfig(array_size=16)


def test_mvm_sign_and_value():
    a = wishart(KA, 16)
    v = random_rhs(KB, 16)
    scale = 1.0 / jnp.max(jnp.abs(a))
    pair = map_matrix(a, KN, CFG, scale)
    out = analog.amc_mvm(pair, v, CFG)
    np.testing.assert_allclose(np.asarray(out), np.asarray(-(a * scale) @ v),
                               rtol=1e-4, atol=1e-6)


def test_inv_sign_and_value():
    a = wishart(KA, 16)
    v = random_rhs(KB, 16)
    scale = 1.0 / jnp.max(jnp.abs(a))
    pair = map_matrix(a, KN, CFG, scale)
    out = analog.amc_inv(pair, v, CFG)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(-jnp.linalg.solve(a * scale, v)),
        rtol=1e-3, atol=1e-5)


def test_differential_split_nonnegative():
    """A = A+ - A- with both arrays' conductances physical (>= 0)."""
    a = wishart(KA, 16) - 0.2   # force signed entries
    scale = 1.0 / jnp.max(jnp.abs(a))
    pair = map_matrix(a, KN, CFG, scale)
    assert bool(jnp.all(pair.gpos >= 0))
    assert bool(jnp.all(pair.gneg >= 0))
    # exactly one of the differential pair is nonzero per cell (ideal map)
    assert bool(jnp.all((pair.gpos * pair.gneg) == 0.0))
    np.testing.assert_allclose(np.asarray(pair.a_eff(CFG)),
                               np.asarray(a * scale), rtol=1e-5, atol=1e-7)


def test_tiled_mvm_equals_dense():
    """Partitioned MVM over 4 tiles == single-array MVM (refs [13]-[15])."""
    a = wishart(KA, 32)
    v = random_rhs(KB, 32)
    scale = 1.0 / jnp.max(jnp.abs(a))
    grid = map_tiled(a, KN, CFG, scale)   # 2x2 grid of 16-tiles
    assert len(grid) == 2 and len(grid[0]) == 2
    out = analog.amc_mvm_tiled(grid, v, CFG)
    np.testing.assert_allclose(np.asarray(out), np.asarray(-(a * scale) @ v),
                               rtol=1e-4, atol=1e-6)


def test_tiled_mvm_ragged():
    """Non-multiple sizes produce edge tiles of the remainder size."""
    a = wishart(KA, 20)
    v = random_rhs(KB, 20)
    scale = 1.0 / jnp.max(jnp.abs(a))
    grid = map_tiled(a, KN, CFG, scale)   # 16+4 per side
    assert grid[0][0].shape == (16, 16)
    assert grid[1][1].shape == (4, 4)
    out = analog.amc_mvm_tiled(grid, v, CFG)
    np.testing.assert_allclose(np.asarray(out), np.asarray(-(a * scale) @ v),
                               rtol=1e-4, atol=1e-6)


@pytest.mark.parametrize("bits,tol", [(4, 0.15), (8, 0.01), (12, 1e-3)])
def test_quantization_error_scales_with_bits(bits, tol):
    v = random_rhs(KB, 256)
    vq = analog.quantize(v, bits, 1.0)
    err = float(jnp.max(jnp.abs(v - vq)))
    assert err <= 2.0 / (2 ** bits - 1)
    assert err <= tol


def test_quantization_ideal_passthrough():
    v = random_rhs(KB, 64)
    np.testing.assert_array_equal(np.asarray(analog.quantize(v, None, 1.0)),
                                  np.asarray(v))


def test_finite_gain_error_grows_with_array_size():
    """Summing-node error scales with row conductance sum (paper Fig. 6c)."""
    errs = []
    for n in [16, 64, 256]:
        a = wishart(KA, n)
        v = random_rhs(KB, n)
        scale = 1.0 / jnp.max(jnp.abs(a))
        cfg = AnalogConfig(array_size=n, opa_gain=1e4)
        pair = map_matrix(a, KN, cfg, scale)
        out = analog.amc_inv(pair, v, cfg)
        ref = -jnp.linalg.solve(a * scale, v)
        errs.append(float(jnp.linalg.norm(out - ref) / jnp.linalg.norm(ref)))
    assert errs[0] < errs[1] < errs[2]


def test_finite_gain_converges_to_ideal():
    a = wishart(KA, 32)
    v = random_rhs(KB, 32)
    scale = 1.0 / jnp.max(jnp.abs(a))
    ref = -jnp.linalg.solve(a * scale, v)
    prev = None
    for gain in [1e3, 1e5, 1e7]:
        cfg = AnalogConfig(array_size=32, opa_gain=gain)
        pair = map_matrix(a, KN, cfg, scale)
        err = float(jnp.linalg.norm(analog.amc_inv(pair, v, cfg) - ref))
        if prev is not None:
            assert err < prev
        prev = err


def test_quantizer_single_source_of_truth():
    """The converter quantiser has one definition (core/quantization.py):
    the circuit model, the Pallas kernel body and the jnp oracles must all
    bind the same function - and it must behave identically through each
    import path (the copy-paste-twin regression guard)."""
    from repro.core import quantization
    from repro.kernels import crossbar_mvm, ref
    assert analog.quantize is quantization.quantize
    assert crossbar_mvm._quantize is quantization.quantize
    assert ref._quantize is quantization.quantize
    v = random_rhs(KB, 128) * 1.5        # exercise clipping
    for bits in (None, 4, 8):
        np.testing.assert_array_equal(
            np.asarray(analog.quantize(v, bits, 1.0)),
            np.asarray(quantization.quantize(v, bits, 1.0)))


@pytest.mark.parametrize("lead", [(6,), (2, 3), (5, 2, 3)])
def test_tilegrid_a_eff_batched_wire_model(lead):
    """TileGrid.a_eff with leading batch axes must equal per-pair
    CrossbarPair.a_eff tile-for-tile under the first-order wire model
    (the vmapped-reshape path the flat executor's stacks rely on)."""
    s = 8
    cfg = AnalogConfig(array_size=s,
                       nonideal=analog.NonidealConfig(sigma=0.05, r_wire=1.0))
    kp, kn = jax.random.split(KN)
    gpos = jax.random.uniform(kp, lead + (s, s), maxval=cfg.g0)
    gneg = jax.random.uniform(kn, lead + (s, s), maxval=cfg.g0)
    grid = analog.TileGrid(gpos, gneg, jnp.float32(1.0), cfg.g0)
    a_eff = grid.a_eff(cfg)
    assert a_eff.shape == lead + (s, s)
    flat_p = gpos.reshape((-1, s, s))
    flat_n = gneg.reshape((-1, s, s))
    flat_eff = a_eff.reshape((-1, s, s))
    for i in range(flat_p.shape[0]):
        pair = analog.CrossbarPair(flat_p[i], flat_n[i], jnp.float32(1.0),
                                   cfg.g0)
        np.testing.assert_allclose(np.asarray(flat_eff[i]),
                                   np.asarray(pair.a_eff(cfg)),
                                   rtol=1e-6, atol=1e-9)


def test_tilegrid_a_eff_unbatched_matches_pair():
    """No leading axes: TileGrid.a_eff takes the direct (non-vmapped) wire
    path and must still equal CrossbarPair.a_eff exactly."""
    s = 8
    cfg = AnalogConfig(array_size=s,
                       nonideal=analog.NonidealConfig(sigma=0.05, r_wire=1.0))
    kp, kn = jax.random.split(KN)
    gpos = jax.random.uniform(kp, (s, s), maxval=cfg.g0)
    gneg = jax.random.uniform(kn, (s, s), maxval=cfg.g0)
    grid = analog.TileGrid(gpos, gneg, jnp.float32(1.0), cfg.g0)
    pair = analog.CrossbarPair(gpos, gneg, jnp.float32(1.0), cfg.g0)
    np.testing.assert_array_equal(np.asarray(grid.a_eff(cfg)),
                                  np.asarray(pair.a_eff(cfg)))

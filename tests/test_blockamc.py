"""BlockAMC algorithm tests: Algorithm 1 fidelity, signs, stages, edge cases."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from tests._hypothesis_compat import given, settings, st

from repro.core import blockamc, analog
from repro.core.analog import AnalogConfig
from repro.core.nonideal import NonidealConfig
from repro.core.metrics import relative_error
from repro.data.matrices import wishart, toeplitz, random_rhs

KEY = jax.random.PRNGKey(0)
KA, KB, KN = jax.random.split(KEY, 3)


def _solve_refs(n, family=wishart):
    a = family(KA, n)
    b = random_rhs(KB, n)
    return a, b, jnp.linalg.solve(a, b)


@pytest.mark.parametrize("stages", [0, 1, 2, 3, None])
def test_ideal_exact(stages):
    """With ideal devices the cascade equals the numerical solution."""
    a, b, x_ref = _solve_refs(64)
    cfg = AnalogConfig(array_size=8)
    x = blockamc.solve(a, b, KN, cfg, stages=stages)
    assert float(relative_error(x_ref, x)) < 1e-4


@pytest.mark.parametrize("n", [
    7, 13,
    pytest.param(65, marks=pytest.mark.slow),
    pytest.param(100, marks=pytest.mark.slow),
])
def test_odd_sizes(n):
    """Paper: odd n partitions with A1 of size (n+1)/2."""
    a, b, x_ref = _solve_refs(n)
    cfg = AnalogConfig(array_size=max(4, n // 3))
    x = blockamc.solve(a, b, KN, cfg, stages=None)
    assert float(relative_error(x_ref, x)) < 1e-4


def test_five_step_cascade_signs():
    """Intermediate signals carry exactly the signs of Algorithm 1."""
    n = 16
    a, b, _ = _solve_refs(n)
    cfg = AnalogConfig(array_size=8)
    m = 8
    a1, a2 = a[:m, :m], a[:m, m:]
    a3, a4 = a[m:, :m], a[m:, m:]
    f, g = b[:m], b[m:]
    scale = 1.0 / jnp.max(jnp.abs(a))
    k1, k2, k3, k4 = jax.random.split(KN, 4)
    p1 = analog.map_matrix(a1, k1, cfg, scale)
    p3 = analog.map_matrix(a3, k3, cfg, scale)

    neg_yt = analog.amc_inv(p1, f, cfg)            # step 1 output: -y_t
    y_t_expected = jnp.linalg.solve(a1 * scale, f)
    np.testing.assert_allclose(np.asarray(neg_yt), -np.asarray(y_t_expected),
                               rtol=2e-3, atol=1e-5)

    gt = analog.amc_mvm(p3, neg_yt, cfg)           # step 2 output: +g_t
    gt_expected = (a3 * scale) @ y_t_expected
    np.testing.assert_allclose(np.asarray(gt), np.asarray(gt_expected),
                               rtol=2e-3, atol=1e-5)


def test_zero_offdiag_block_reduces_schur():
    """Paper: if A2 or A3 is zero, A4s == A4 (no pre-processing needed)."""
    n = 32
    a = wishart(KA, n)
    a = a.at[:16, 16:].set(0.0)   # A2 = 0
    b = random_rhs(KB, n)
    x_ref = jnp.linalg.solve(a, b)
    cfg = AnalogConfig(array_size=16)
    plan = blockamc.build_plan(a, KN, cfg, stages=1)
    # A4s should equal A4 (up to mapping scale) when A2 == 0.
    a4s_eff = plan.root.inv4s.pair.a_eff(cfg) / plan.scale
    np.testing.assert_allclose(np.asarray(a4s_eff), np.asarray(a[16:, 16:]),
                               rtol=1e-4, atol=1e-6)
    x = blockamc.execute(plan, b, cfg)
    assert float(relative_error(x_ref, x)) < 1e-4


def test_two_stage_structure():
    """Two-stage on 256 gives leaf arrays of 64 (16 blocks; paper Fig. 8)."""
    a = wishart(KA, 256)
    cfg = AnalogConfig(array_size=64)
    plan = blockamc.build_plan(a, KN, cfg, stages=2)
    root = plan.root
    assert isinstance(root, blockamc.BlockPlan)
    assert isinstance(root.inv1, blockamc.BlockPlan)
    assert isinstance(root.inv1.inv1, blockamc.LeafInvPlan)
    assert root.inv1.inv1.pair.shape == (64, 64)
    # A2/A3 at stage 1 are 128-wide -> 2x2 grids of 64-tiles
    assert len(root.mvm2) == 2 and len(root.mvm2[0]) == 2
    assert root.mvm2[0][0].shape == (64, 64)


def test_required_stages():
    assert blockamc.required_stages(512, 256) == 1
    assert blockamc.required_stages(512, 64) == 3
    assert blockamc.required_stages(256, 256) == 0
    assert blockamc.required_stages(257, 256) == 1


@pytest.mark.slow
def test_variation_block_beats_original():
    """Paper Fig. 7 headline: BlockAMC accuracy >= original AMC (medians)."""
    n = 128
    a, b, x_ref = _solve_refs(n)
    cfg = AnalogConfig(array_size=64, nonideal=NonidealConfig(sigma=0.05))
    errs_b, errs_o = [], []
    for s in range(16):
        kn = jax.random.PRNGKey(1000 + s)
        errs_b.append(float(relative_error(
            x_ref, blockamc.solve(a, b, kn, cfg, stages=1))))
        errs_o.append(float(relative_error(
            x_ref, blockamc.solve_original(a, b, kn, cfg))))
    assert np.median(errs_b) <= np.median(errs_o) * 1.1


def test_finite_opa_gain_block_beats_original():
    """Paper Fig. 6(c): even with ideal mapping, smaller arrays win."""
    n = 128
    a, b, x_ref = _solve_refs(n)
    cfg = AnalogConfig(array_size=64, opa_gain=1e4)
    xb = blockamc.solve(a, b, KN, cfg, stages=1)
    xo = blockamc.solve_original(a, b, KN, cfg)
    assert float(relative_error(x_ref, xb)) < float(relative_error(x_ref, xo))


def test_vmap_over_noise_keys():
    """40-seed Monte Carlo via vmap (the paper's experiment shape)."""
    n = 32
    a, b, x_ref = _solve_refs(n)
    cfg = AnalogConfig(array_size=16, nonideal=NonidealConfig(sigma=0.05))
    keys = jax.random.split(KN, 8)
    xs = jax.vmap(lambda k: blockamc.solve(a, b, k, cfg, stages=1))(keys)
    assert xs.shape == (8, n)
    errs = jax.vmap(lambda x: relative_error(x_ref, x))(xs)
    assert bool(jnp.all(jnp.isfinite(errs)))
    # different keys -> different noise -> different errors
    assert float(jnp.std(errs)) > 0.0


def test_jit_solve():
    n = 32
    a, b, x_ref = _solve_refs(n)
    cfg = AnalogConfig(array_size=16)
    f = jax.jit(lambda a, b, k: blockamc.solve(a, b, k, cfg, stages=1))
    x = f(a, b, KN)
    assert float(relative_error(x_ref, x)) < 1e-4


@settings(max_examples=15, deadline=None)
@given(n=st.integers(min_value=4, max_value=48),
       seed=st.integers(min_value=0, max_value=2 ** 16))
def test_property_ideal_solves_any_wellconditioned_system(n, seed):
    """Property: ideal BlockAMC solves any diagonally-regularised system."""
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 3)
    raw = jax.random.normal(k1, (n, n)) / jnp.sqrt(n)
    a = raw + 2.0 * jnp.eye(n)          # well-conditioned, signed entries
    b = random_rhs(k2, n)
    x_ref = jnp.linalg.solve(a, b)
    cfg = AnalogConfig(array_size=max(2, n // 2))
    x = blockamc.solve(a, b, k3, cfg, stages=None)
    assert float(relative_error(x_ref, x)) < 5e-4


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(min_value=0, max_value=2 ** 16))
def test_property_toeplitz(seed):
    kk = jax.random.PRNGKey(seed)
    k1, k2, k3 = jax.random.split(kk, 3)
    a = toeplitz(k1, 24)
    b = random_rhs(k2, 24)
    x_ref = jnp.linalg.solve(a, b)
    cfg = AnalogConfig(array_size=12)
    x = blockamc.solve(a, b, k3, cfg, stages=1)
    assert float(relative_error(x_ref, x)) < 1e-3

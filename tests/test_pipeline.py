"""Pipeline parallelism (GPipe over a mesh axis): subprocess host-mesh test."""
import os
import subprocess
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_sub(code: str, n_devices: int = 4, timeout: int = 560) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=timeout)
    assert out.returncode == 0, out.stderr[-4000:]
    return out.stdout


def test_pipeline_matches_sequential_and_differentiates():
    out = run_sub("""
import jax, jax.numpy as jnp, numpy as np
from functools import partial
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map
from repro.train.pipeline import pipeline_apply, split_stages

S, M, B, D = 4, 8, 2, 16   # stages, microbatches, batch, width
L = 8                      # total layers (2 per stage)
mesh = jax.make_mesh((S,), ('pp',))

key = jax.random.PRNGKey(0)
w = jax.random.normal(key, (L, D, D)) * (0.5 / jnp.sqrt(D))
x = jax.random.normal(jax.random.PRNGKey(1), (M, B, D))

def layer(wi, h):
    return jnp.tanh(h @ wi)

def stage_fn(stage_w, h):
    # stage_w: (L/S, D, D)
    def body(h, wi):
        return layer(wi, h), None
    h, _ = jax.lax.scan(body, h, stage_w)
    return h

# ---- sequential reference ----
def seq_all(w, x):
    def body(h, wi):
        return layer(wi, h), None
    def one(xm):
        h, _ = jax.lax.scan(body, xm, w)
        return h
    return jax.vmap(one)(x)

ref = seq_all(w, x)

# ---- pipelined ----
w_staged = split_stages(w, S)    # (S, L/S, D, D)

@partial(shard_map, mesh=mesh, in_specs=(P('pp'), P(None)),
         out_specs=P('pp'), check_rep=False)
def pipe(w_local, x_all):
    out = pipeline_apply(lambda p, h: stage_fn(p[0], h), w_local, x_all, 'pp')
    return out[None]             # (1, M, B, D) per stage

outs = pipe(w_staged, x)         # (S, M, B, D)
got = outs[-1]                   # last stage holds the results
np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                           rtol=2e-5, atol=2e-5)
print('OK forward')

# ---- differentiability: grads flow through ppermute ----
def loss_pipe(w_staged, x):
    outs = pipe(w_staged, x)
    return jnp.sum(outs[-1] ** 2)

def loss_seq(w, x):
    return jnp.sum(seq_all(w, x) ** 2)

g_pipe = jax.grad(loss_pipe)(w_staged, x).reshape(L, D, D)
g_seq = jax.grad(loss_seq)(w, x)
np.testing.assert_allclose(np.asarray(g_pipe), np.asarray(g_seq),
                           rtol=2e-4, atol=2e-4)
print('OK grads')
""", n_devices=4)
    assert "OK forward" in out and "OK grads" in out

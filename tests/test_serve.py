"""Serving-path correctness: the recurrent decode paths must match the
parallel (training/prefill) forward exactly - the strongest numerics test
for the SSM chunked scan and RG-LRU associative scan."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import transformer as tr
from repro.models.lm_engine import Engine
from tests.conftest import reduce_cfg

B, S = 2, 12


def _decode_all(params, cfg, tokens, cache_len):
    """Greedy per-token decode over a whole sequence; collect logits."""
    cache = tr.init_cache(B, cache_len, cfg, dtype=jnp.float32)
    outs = []
    for t in range(tokens.shape[1]):
        logits, cache = tr.decode_step(params, cache, tokens[:, t],
                                       jnp.int32(t), cfg)
        outs.append(logits)
    return jnp.stack(outs, axis=1)    # (B, S, V)


@pytest.mark.parametrize("arch", [
    "glm4-9b",              # dense GQA
    "mamba2-130m",          # SSD chunked vs recurrent
    "recurrentgemma-2b",    # RG-LRU assoc-scan vs recurrent + local attn
    "phi3.5-moe-42b-a6.6b", # MoE routing in decode
])
@pytest.mark.slow
def test_decode_matches_forward(arch):
    cfg = reduce_cfg(get_config(arch))
    if cfg.family == "ssm":
        cfg = dataclasses.replace(cfg, ssm_chunk=4)   # S=12 -> 3 chunks
    if cfg.n_experts:
        # capacity dropping differs between full-sequence routing (T=B*S)
        # and decode routing (T=B); disable drops for exact equivalence.
        cfg = dataclasses.replace(cfg, capacity_factor=float(cfg.n_experts))
    params = tr.init_params(jax.random.PRNGKey(0), cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab)
    logits_fwd, _ = tr.forward(params, cfg, tokens=tokens)
    logits_dec = _decode_all(params, cfg, tokens, cache_len=S)
    np.testing.assert_allclose(
        np.asarray(logits_dec, dtype=np.float32),
        np.asarray(logits_fwd, dtype=np.float32), rtol=2e-2, atol=2e-3)


@pytest.mark.slow
def test_prefill_matches_decode_continuation(tiny_dense):
    """prefill(prompt) then decode must equal decoding token by token."""
    cfg = tiny_dense
    params = tr.init_params(jax.random.PRNGKey(0), cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab)
    cache_len = S + 4
    logits_pre, cache_pre = tr.prefill(params, cfg, tokens=tokens,
                                       cache_len=cache_len,
                                       cache_dtype=jnp.float32)
    # token-by-token reference
    cache = tr.init_cache(B, cache_len, cfg, dtype=jnp.float32)
    for t in range(S):
        logits_seq, cache = tr.decode_step(params, cache, tokens[:, t],
                                           jnp.int32(t), cfg)
    np.testing.assert_allclose(np.asarray(logits_pre, np.float32),
                               np.asarray(logits_seq, np.float32),
                               rtol=2e-2, atol=2e-3)
    # continue one step from both caches: must agree
    nxt = jnp.argmax(logits_pre, axis=-1).astype(jnp.int32)
    a, _ = tr.decode_step(params, cache_pre, nxt, jnp.int32(S), cfg)
    b, _ = tr.decode_step(params, cache, nxt, jnp.int32(S), cfg)
    np.testing.assert_allclose(np.asarray(a, np.float32),
                               np.asarray(b, np.float32),
                               rtol=2e-2, atol=2e-3)


@pytest.mark.slow
def test_sliding_window_ring_buffer():
    """RecurrentGemma local attention: ring-buffer decode == windowed fwd."""
    cfg = reduce_cfg(get_config("recurrentgemma-2b"), local_window=4)
    params = tr.init_params(jax.random.PRNGKey(0), cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab)
    logits_fwd, _ = tr.forward(params, cfg, tokens=tokens)
    logits_dec = _decode_all(params, cfg, tokens, cache_len=S)
    np.testing.assert_allclose(
        np.asarray(logits_dec, np.float32),
        np.asarray(logits_fwd, np.float32), rtol=2e-2, atol=2e-3)


def test_engine_generate(tiny_dense):
    cfg = tiny_dense
    params = tr.init_params(jax.random.PRNGKey(0), cfg)
    engine = Engine(cfg, params, max_len=32)
    prompts = jax.random.randint(jax.random.PRNGKey(3), (B, 8), 0, cfg.vocab)
    out = engine.generate(prompts, 6)
    assert out.shape == (B, 6)
    assert bool(jnp.all((out >= 0) & (out < tr.padded_vocab(cfg))))
    # greedy generation is deterministic
    out2 = engine.generate(prompts, 6)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(out2))

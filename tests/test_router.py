"""Replicated serving fleet contract (TESTING.md "Replicated serving").

The contract under test:

* every fleet future resolves - with a `SolveResult` or a typed error -
  through replica stalls, worker deaths and checkpoint damage; never a
  silent hang;
* replicated programming (same key on every replica) makes any replica
  able to answer any request, so a dead replica's in-flight legs replay
  on survivors and healthy tenants see ZERO deadline misses during the
  loss;
* a hedged request turns a stalled replica into one wasted dispatch: the
  duplicate leg on the next-best replica wins the race;
* the lifecycle ladder degraded -> drained -> quarantined -> replaced is
  driven by the health score (gray failure), not just liveness;
* replacement replicas restore programmed state from the `ProgramStore`
  checkpoint and re-validate it against the ORIGINAL canary trip; a
  stale or damaged checkpoint is rejected (`rejected_checkpoints`) and
  recovery falls back to full re-programming - a faulted restore can
  never grade its own homework.

Everything is driven deterministically: chaos events key on dispatch
counters, traffic comes in flush-spaced waves, and the only waits are
bounded polls on fleet counters.
"""
import time

import jax
import numpy as np
import pytest

from repro.checkpoint import ProgramStore
from repro.core.analog import AnalogConfig
from repro.core.blockamc import ProgrammedSolver, plan_signature
from repro.core.nonideal import NonidealConfig
from repro.data.matrices import wishart
from repro.runtime import (ChaosInjector, CheckpointCorruption, ReplicaDeath,
                           ReplicaStall)
from repro.serve import ReplicatedSolverFleet, SolverService

KEY = jax.random.PRNGKey(7)
N = 16
CFG = AnalogConfig(array_size=8, nonideal=NonidealConfig(sigma=0.02))
# raw analog answers at sigma=0.02 carry ~0.1-0.2 relative residual;
# replayed/hedged answers come from bit-identical stacks, same bound
ANALOG_RES = 0.8
ENGINE_KW = dict(flush_interval=0.004, max_batch=4)


def _service(sigma=0.02):
    cfg = AnalogConfig(array_size=8, nonideal=NonidealConfig(sigma=sigma))
    return lambda: SolverService(cfg, stages=1)


def _matrix(i):
    g = jax.random.normal(jax.random.fold_in(KEY, i), (N, N))
    return np.asarray(g @ g.T / N + np.eye(N, dtype=np.float32))


def _resid(a, x, b):
    return float(np.linalg.norm(a @ x - b) / np.linalg.norm(b))


def _wait(cond, timeout=10.0, poll=0.02):
    t_end = time.monotonic() + timeout
    while time.monotonic() < t_end:
        if cond():
            return True
        time.sleep(poll)
    return False


# ---------------------------------------------------------------------------
# basic replicated serving
# ---------------------------------------------------------------------------

def test_fleet_serves_and_spreads(tmp_path):
    """Two replicas, two tenants: every answer is finite and accurate,
    programmed state is persisted, and routing uses both replicas (the
    assignment round-robin spreads distinct signatures)."""
    store = ProgramStore(str(tmp_path))
    mats = {f"m{i}": _matrix(i) for i in range(2)}
    fleet = ReplicatedSolverFleet(_service(), 2, engine_kw=ENGINE_KW,
                                  store=store)
    with fleet:
        for mid, a in mats.items():
            fleet.program(mid, a, jax.random.fold_in(KEY, hash(mid) % 100))
        assert sorted(fleet.matrix_ids) == sorted(mats)
        assert sorted(store.matrix_ids()) == sorted(mats)

        futs = []
        for w in range(3):
            for mid in mats:
                b = np.asarray(jax.random.normal(
                    jax.random.fold_in(KEY, 50 + w), (N,)))
                futs.append((mid, b, fleet.submit(mid, b)))
            fleet.flush_now()
            time.sleep(0.03)
        for mid, b, fut in futs:
            res = fut.result(timeout=10)
            assert _resid(mats[mid], np.asarray(res.x), b) < ANALOG_RES
            assert not res.deadline_missed
    assert fleet.stats.answered == len(futs)
    assert fleet.stats.deaths == 0 and fleet.stats.replays == 0


def test_fleet_submit_validation():
    fleet = ReplicatedSolverFleet(_service(), 1, engine_kw=ENGINE_KW)
    with pytest.raises(RuntimeError):      # not running yet
        fleet.submit("m", np.zeros(N))
    with fleet:
        fleet.program("m", _matrix(0), KEY)
        with pytest.raises(KeyError):
            fleet.submit("nope", np.zeros(N))


# ---------------------------------------------------------------------------
# hedged requests
# ---------------------------------------------------------------------------

def test_hedged_request_beats_stalled_replica():
    """r0 stalls 0.6s on every dispatch; the hedge leg on r1 answers the
    outer future long before the primary wakes up."""
    chaos = ChaosInjector([ReplicaStall(at_dispatch=0, seconds=0.6,
                                        replica="r0")])
    fleet = ReplicatedSolverFleet(_service(), 2, engine_kw=ENGINE_KW,
                                  chaos=chaos, hedge_delay=0.03)
    a = _matrix(3)
    with fleet:
        fleet.program("m", a, KEY)
        b = np.asarray(jax.random.normal(jax.random.fold_in(KEY, 4), (N,)))
        t0 = time.monotonic()
        fut = fleet.submit("m", b, deadline_s=5.0, hedge=True)
        fleet.flush_now()
        res = fut.result(timeout=10)
        elapsed = time.monotonic() - t0
    assert _resid(a, np.asarray(res.x), b) < ANALOG_RES
    assert not res.deadline_missed
    assert elapsed < 0.45                  # did not wait out the 0.6s stall
    assert fleet.stats.hedges >= 1
    assert fleet.stats.hedge_wins >= 1
    assert chaos.fired >= 1                # the stall really was armed


# ---------------------------------------------------------------------------
# replica death: the acceptance scenario
# ---------------------------------------------------------------------------

def test_replica_death_replay_and_checkpoint_restore(tmp_path):
    """3 replicas, 3 tenants, r0's worker dies mid-traffic: every future
    resolves, the dead replica's in-flight legs replay on survivors with
    zero deadline misses, and the replacement restores all three
    programmed matrices from checkpoint (no re-programming)."""
    store = ProgramStore(str(tmp_path))
    chaos = ChaosInjector([ReplicaDeath(at_dispatch=1, replica="r0")])
    mats = {f"m{i}": _matrix(10 + i) for i in range(3)}
    fleet = ReplicatedSolverFleet(_service(), 3, engine_kw=ENGINE_KW,
                                  store=store, chaos=chaos)
    with fleet:
        for i, (mid, a) in enumerate(mats.items()):
            fleet.program(mid, a, jax.random.fold_in(KEY, 200 + i))

        futs = []
        for wave in range(4):
            for mid in mats:
                for j in range(3):
                    b = np.asarray(jax.random.normal(
                        jax.random.fold_in(KEY, 17 * wave + j), (N,)))
                    futs.append((mid, b,
                                 fleet.submit(mid, b, deadline_s=5.0)))
            fleet.flush_now()
            time.sleep(0.05)

        for mid, b, fut in futs:
            res = fut.result(timeout=15)   # NEVER hangs
            assert np.all(np.isfinite(np.asarray(res.x)))
            assert _resid(mats[mid], np.asarray(res.x), b) < ANALOG_RES
            assert not res.deadline_missed  # healthy tenants: zero misses
        assert _wait(lambda: fleet.stats.replacements >= 1)
        # post-recovery the fleet is whole and still serves
        assert set(fleet.replica_states().values()) == {"active"}
        b = np.asarray(jax.random.normal(jax.random.fold_in(KEY, 5), (N,)))
        res = fleet.submit("m0", b).result(timeout=10)
        assert _resid(mats["m0"], np.asarray(res.x), b) < ANALOG_RES

    assert chaos.fired >= 1
    assert fleet.stats.deaths == 1
    assert fleet.stats.replays >= 1        # in-flight replayed on survivors
    assert fleet.stats.replacements == 1
    # durable recovery: all three matrices restored, none re-programmed
    assert fleet.stats.restores == len(mats)
    assert fleet.stats.reprogram_fallbacks == 0
    assert fleet.stats.rejected_checkpoints == 0
    assert fleet.stats.answered == len(futs) + 1


# ---------------------------------------------------------------------------
# checkpoint validation: corrupt + stale must fall back to re-programming
# ---------------------------------------------------------------------------

def _run_death_recovery(store, chaos):
    """Shared scaffold: 2 replicas, 1 tenant, scripted r0 death.

    A generator: yields the running fleet once "m" is programmed and its
    checkpoint saved (so the caller can damage the store), then drives
    waves of traffic through the death and recovery, asserts the
    universal invariants (every future resolves with an accurate answer;
    the recovered fleet still serves), and yields the stopped fleet for
    stats assertions."""
    a = _matrix(20)
    fleet = ReplicatedSolverFleet(_service(), 2, engine_kw=ENGINE_KW,
                                  store=store, chaos=chaos)
    with fleet:
        fleet.program("m", a, jax.random.fold_in(KEY, 21))
        yield fleet                        # caller damages the store here
        futs = []
        for wave in range(4):
            for j in range(3):
                b = np.asarray(jax.random.normal(
                    jax.random.fold_in(KEY, 31 * wave + j), (N,)))
                futs.append((b, fleet.submit("m", b)))
            fleet.flush_now()
            time.sleep(0.05)
        for b, fut in futs:
            res = fut.result(timeout=15)
            assert _resid(a, np.asarray(res.x), b) < ANALOG_RES
        assert _wait(lambda: fleet.stats.replacements >= 1)
        # the recovered fleet still serves correct answers
        b = np.asarray(jax.random.normal(jax.random.fold_in(KEY, 6), (N,)))
        res = fleet.submit("m", b).result(timeout=10)
        assert _resid(a, np.asarray(res.x), b) < ANALOG_RES
    yield fleet


@pytest.mark.parametrize("how", ["values", "truncate"])
def test_corrupted_checkpoint_falls_back_to_reprogram(tmp_path, how):
    """how="truncate" dies at the integrity layer (manifest cross-check);
    how="values" is bytes-consistent and must be caught by the physics
    canary re-run against the ORIGINAL trip.  Both reject the restore
    and re-program from scratch - and recovery still completes."""
    store = ProgramStore(str(tmp_path))
    chaos = ChaosInjector([ReplicaDeath(at_dispatch=1, replica="r0")])
    gen = _run_death_recovery(store, chaos)
    fleet = next(gen)                      # fleet running, "m" programmed
    store.corrupt("m", how=how)
    for fleet in gen:                      # drive to completion
        pass
    assert fleet.stats.deaths == 1
    assert fleet.stats.rejected_checkpoints >= 1
    assert fleet.stats.reprogram_fallbacks >= 1
    assert fleet.stats.restores == 0
    assert len(fleet.stats.reprogram_s) >= 1


def test_stale_checkpoint_rejected(tmp_path):
    """A checkpoint from a different programming epoch (right signature,
    wrong matrix bytes) is identity-rejected before any array loads."""
    store = ProgramStore(str(tmp_path))
    chaos = ChaosInjector([ReplicaDeath(at_dispatch=1, replica="r0")])
    gen = _run_death_recovery(store, chaos)
    fleet = next(gen)
    # overwrite with a same-signature checkpoint of a DIFFERENT matrix
    other_a = _matrix(99)
    other = ProgrammedSolver.program(
        np.asarray(other_a, dtype=np.float32),
        jax.random.fold_in(KEY, 98), CFG, stages=1)
    store.save("m", other, other_a, jax.random.fold_in(KEY, 98),
               plan_signature(N, 1, CFG))
    for fleet in gen:
        pass
    assert fleet.stats.rejected_checkpoints >= 1
    assert fleet.stats.reprogram_fallbacks >= 1
    assert fleet.stats.restores == 0


def test_chaos_scripted_checkpoint_corruption(tmp_path):
    """The fleet applies `CheckpointCorruption` events from the chaos
    script (keyed on its submit counter), and the damaged checkpoint is
    then rejected on restore like any other corruption."""
    store = ProgramStore(str(tmp_path))
    chaos = ChaosInjector([
        CheckpointCorruption(at_dispatch=1, matrix_id="m", how="values"),
        ReplicaDeath(at_dispatch=2, replica="r0"),
    ])
    gen = _run_death_recovery(store, chaos)
    next(gen)
    for fleet in gen:
        pass
    corrupt_fired = [e for _, e in chaos.log
                     if isinstance(e, CheckpointCorruption)]
    assert len(corrupt_fired) == 1         # fired exactly once
    assert fleet.stats.rejected_checkpoints >= 1
    assert fleet.stats.reprogram_fallbacks >= 1


# ---------------------------------------------------------------------------
# lifecycle ladder: gray failure drains through the score, not liveness
# ---------------------------------------------------------------------------

def test_gray_failure_drains_quarantines_replaces(tmp_path):
    """A stalled-but-alive replica misses a deadline; with alpha=1 the
    miss EWMA saturates and the ladder runs degraded -> drained ->
    quarantined -> replaced while the worker is still technically alive.
    The replacement restores from checkpoint."""
    store = ProgramStore(str(tmp_path))
    chaos = ChaosInjector([ReplicaStall(at_dispatch=0, seconds=0.15,
                                        replica="r0")])
    a = _matrix(30)
    fleet = ReplicatedSolverFleet(_service(), 2, engine_kw=ENGINE_KW,
                                  store=store, chaos=chaos,
                                  ewma_alpha=1.0, drain_grace=0.05)
    with fleet:
        fleet.program("m", a, jax.random.fold_in(KEY, 31))
        b = np.asarray(jax.random.normal(jax.random.fold_in(KEY, 32), (N,)))
        fut = fleet.submit("m", b, deadline_s=0.02)   # lands on r0
        fleet.flush_now()
        res = fut.result(timeout=10)       # answered late, not dropped
        assert res.deadline_missed
        assert _wait(lambda: fleet.stats.replacements >= 1)
        assert set(fleet.replica_states().values()) == {"active"}
    assert fleet.stats.deaths == 0         # the worker never died
    assert fleet.stats.drains >= 1
    assert fleet.stats.quarantines >= 1
    assert fleet.stats.replacements >= 1
    assert fleet.stats.restores >= 1

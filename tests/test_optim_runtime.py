"""Optimizer extras (BlockAMC preconditioner, grad compression, schedule)
and runtime fault-tolerance pieces (watchdog, retry, elastic mesh)."""
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim.adamw import AdamW
from repro.optim.blockamc_precond import BlockAMCPrecond
from repro.optim.grad_compression import (dequantize_int8, init_error_state,
                                          quantize_int8)
from repro.optim.schedule import warmup_cosine
from repro.runtime.elastic import ElasticMesh
from repro.runtime.fault_tolerance import StepWatchdog, retry_step


# ------------------------------ AdamW ------------------------------------

def test_adamw_converges_quadratic():
    opt = AdamW(lr=0.1, weight_decay=0.0)
    params = {"w": jnp.array([5.0, -3.0])}
    state = opt.init(params)
    for _ in range(200):
        grads = {"w": 2 * params["w"]}          # d/dw ||w||^2
        params, state = opt.update(grads, state, params)
    assert float(jnp.max(jnp.abs(params["w"]))) < 1e-2


def test_adamw_bf16_moments():
    opt = AdamW(lr=1e-2, moments_dtype=jnp.bfloat16)
    params = {"w": jnp.ones((4, 4))}
    state = opt.init(params)
    assert state.m["w"].dtype == jnp.bfloat16
    new_p, new_s = opt.update({"w": jnp.ones((4, 4))}, state, params)
    assert new_s.v["w"].dtype == jnp.bfloat16
    assert bool(jnp.all(jnp.isfinite(new_p["w"])))


def test_schedule_shape():
    assert float(warmup_cosine(0, warmup=100, total=1000)) == pytest.approx(0.01)
    assert float(warmup_cosine(100, warmup=100, total=1000)) == pytest.approx(1.0)
    assert float(warmup_cosine(1000, warmup=100, total=1000)) == pytest.approx(0.1)


# --------------------- BlockAMC preconditioner ----------------------------

def test_precond_matches_direct_inverse_root():
    pre = BlockAMCPrecond(damping=1e-2, leaf_size=8, max_dim=64)
    params = {"w": jnp.zeros((16, 32))}
    state = pre.init(params)
    g = jax.random.normal(jax.random.PRNGKey(0), (16, 32))
    state = pre.update_stats({"w": g}, state)
    state = pre.refresh_inverses(state)
    out = pre.precondition({"w": g}, state)["w"]
    gram = 0.95 * jnp.eye(32) * 1e-2 + 0.05 * (g.T @ g) / 16
    a = gram + 1e-2 * jnp.eye(32)
    evals, evecs = jnp.linalg.eigh(a)
    inv_root = (evecs * (1.0 / jnp.sqrt(evals))) @ evecs.T
    expect = g @ inv_root
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=2e-2, atol=1e-3)


def test_precond_analog_path_close_to_digital():
    pre_d = BlockAMCPrecond(damping=5e-2, leaf_size=8, max_dim=64)
    pre_a = BlockAMCPrecond(damping=5e-2, leaf_size=8, max_dim=64,
                            use_analog=True, refine_iters=8)
    params = {"w": jnp.zeros((16, 16))}
    g = jax.random.normal(jax.random.PRNGKey(1), (16, 16))
    sd = pre_d.update_stats({"w": g}, pre_d.init(params))
    sa = pre_a.update_stats({"w": g}, pre_a.init(params))
    outd = pre_d.precondition({"w": g}, pre_d.refresh_inverses(sd))["w"]
    outa = pre_a.precondition({"w": g}, pre_a.refresh_inverses(sa))["w"]
    rel = float(jnp.linalg.norm(outa - outd) / jnp.linalg.norm(outd))
    assert rel < 0.05


def test_precond_accelerates_illconditioned_quadratic():
    """Minimise 0.5 x A x^T with kappa(A)=1e3.

    The Gram statistic over a batch of gradient samples g_i = x_i A is
    E[g^T g] ~ A^2, so inverse-root preconditioning x A (A^2+l)^-1/2 ~ x
    - the Newton direction, with a dimension-uniform convergence rate,
    while plain GD is stability-capped at lr <= 2/lambda_max."""
    key = jax.random.PRNGKey(2)
    q, _ = jnp.linalg.qr(jax.random.normal(key, (32, 32)))
    eigs = jnp.logspace(0, 3, 32)
    a = (q * eigs) @ q.T

    pre = BlockAMCPrecond(damping=1e-3, leaf_size=8, max_dim=64, beta=0.0)
    samples = jax.random.normal(jax.random.PRNGKey(3), (256, 32)) @ a
    state = pre.update_stats({"x": samples}, pre.init({"x": samples}))
    state = pre.refresh_inverses(state)

    def run(precond: bool, steps=60):
        x = jnp.ones((1, 32))
        lr = 0.3 if precond else 1e-3    # GD capped by 2/lambda_max = 2e-3
        for _ in range(steps):
            g = x @ a
            if precond:
                g = pre.precondition({"x": g}, state)["x"]
            x = x - lr * g
        return float(0.5 * (x @ a @ x.T)[0, 0])

    assert run(True) < 0.1 * run(False)


# ------------------------- grad compression -------------------------------

def test_int8_roundtrip_error_bounded():
    x = jax.random.normal(jax.random.PRNGKey(0), (1000,))
    q, s = quantize_int8(x)
    err = jnp.abs(dequantize_int8(q, s) - x)
    assert float(jnp.max(err)) <= float(s) * 0.5 + 1e-7


def test_error_feedback_unbiased_over_time():
    """With error feedback, the accumulated compressed sum tracks the true
    sum (bias does not accumulate)."""
    import repro.optim.grad_compression as gc
    x = 0.01 * jax.random.normal(jax.random.PRNGKey(1), (512,))
    err = jnp.zeros_like(x)
    acc_comp = jnp.zeros_like(x)
    for _ in range(50):
        g32 = x + err
        q, s = gc.quantize_int8(g32)
        deq = gc.dequantize_int8(q, s)
        err = g32 - deq
        acc_comp = acc_comp + deq
    acc_true = 50 * x
    rel = float(jnp.linalg.norm(acc_comp - acc_true)
                / jnp.linalg.norm(acc_true))
    assert rel < 0.02


# ----------------------------- runtime ------------------------------------

def test_watchdog_flags_straggler():
    events = []
    wd = StepWatchdog(factor=3.0, warmup_steps=3,
                      on_straggle=lambda t, m: events.append((t, m)))
    for _ in range(5):
        with wd:
            time.sleep(0.01)
    with wd:
        time.sleep(0.2)     # 20x median
    assert len(events) == 1
    assert wd.straggles == 1


def test_retry_step_recovers():
    calls = {"n": 0}

    def flaky():
        calls["n"] += 1
        if calls["n"] < 3:
            raise RuntimeError("transient collective failure")
        return 42

    assert retry_step(flaky, retries=3) == 42
    assert calls["n"] == 3


def test_retry_step_exhausts():
    def broken():
        raise RuntimeError("dead host")

    with pytest.raises(RuntimeError):
        retry_step(broken, retries=2)


def test_elastic_mesh_shapes():
    em = ElasticMesh()
    assert em.choose_shape(256) == (16, 16)
    assert em.choose_shape(192) == (12, 16)
    # model-dim divisibility constraint knocks the axis down
    assert em.choose_shape(256, model_divisors=(40,)) == (32, 8)
    assert em.choose_shape(7) == (7, 1)

"""Non-ideality model tests: MNA oracle agreement, limits, statistics."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from tests._hypothesis_compat import given, settings, st

from repro.core import nonideal
from repro.data.matrices import wishart, random_rhs

G0 = 100e-6


def _positive_array(n, seed=0):
    ka, kb = jax.random.split(jax.random.PRNGKey(seed))
    a = jnp.abs(wishart(ka, n))
    g = a / jnp.max(a) * G0
    v = jnp.abs(random_rhs(kb, n)) + 0.1
    return g, v


@pytest.mark.parametrize("n", [8, 16, 24])
def test_first_order_matches_mna_mvm(n):
    """Linearised wire model tracks the exact MNA to a few % of the effect."""
    g, v = _positive_array(n)
    i_exact = np.asarray(nonideal.mna_mvm_currents(g, v, 1.0))
    i_ideal = np.asarray(g @ v)
    i_fo = np.asarray(nonideal.effective_conductance(g, 1.0) @ v)
    d_exact, d_fo = i_exact - i_ideal, i_fo - i_ideal
    ratio = np.linalg.norm(d_exact) / np.linalg.norm(d_fo)
    corr = d_exact @ d_fo / (np.linalg.norm(d_exact) * np.linalg.norm(d_fo))
    assert 0.9 < ratio < 1.1
    assert corr > 0.99


@pytest.mark.parametrize("n", [8, 16])
def test_first_order_matches_mna_inv(n):
    g, v = _positive_array(n)
    vo_exact = np.asarray(nonideal.mna_inv_outputs(g, v, 1.0, G0))
    vo_ideal = np.asarray(-jnp.linalg.solve(g / G0, v))
    vo_fo = np.asarray(
        -jnp.linalg.solve(nonideal.effective_conductance(g, 1.0) / G0, v))
    d_exact, d_fo = vo_exact - vo_ideal, vo_fo - vo_ideal
    ratio = np.linalg.norm(d_exact) / np.linalg.norm(d_fo)
    assert 0.9 < ratio < 1.1


def test_mna_ideal_limit():
    """r_seg -> 0 recovers ideal MVM currents and INV outputs."""
    g, v = _positive_array(12)
    i = np.asarray(nonideal.mna_mvm_currents(g, v, 1e-8))
    np.testing.assert_allclose(i, np.asarray(g @ v), rtol=1e-5)
    vo = np.asarray(nonideal.mna_inv_outputs(g, v, 1e-8, G0))
    np.testing.assert_allclose(
        vo, np.asarray(-jnp.linalg.solve(g / G0, v)), rtol=1e-4)


def test_effective_conductance_zero_r():
    g, _ = _positive_array(8)
    np.testing.assert_array_equal(
        np.asarray(nonideal.effective_conductance(g, 0.0)), np.asarray(g))


def test_effective_conductance_reduces_g():
    """Wire resistance can only reduce effective conductance (monotone)."""
    g, _ = _positive_array(16)
    ge = nonideal.effective_conductance(g, 1.0)
    assert bool(jnp.all(ge <= g + 1e-12))
    assert bool(jnp.all(ge >= 0.0))


def test_wire_effect_grows_with_size():
    """Larger arrays suffer more IR drop - the BlockAMC scalability premise."""
    devs = []
    for n in [8, 16, 32]:
        g, v = _positive_array(n)
        i_ideal = g @ v
        i_fo = nonideal.effective_conductance(g, 1.0) @ v
        devs.append(float(jnp.linalg.norm(i_fo - i_ideal)
                          / jnp.linalg.norm(i_ideal)))
    assert devs[0] < devs[1] < devs[2]


def test_variation_statistics():
    """Additive sigma*G0 noise: sample std matches, clipped at zero."""
    g = jnp.full((200, 200), 0.5 * G0)
    gn = nonideal.apply_variation(g, jax.random.PRNGKey(3), 0.05 * G0)
    resid = np.asarray(gn - g)
    assert abs(resid.std() - 0.05 * G0) / (0.05 * G0) < 0.05
    assert bool(jnp.all(gn >= 0.0))


def test_variation_zero_sigma_identity():
    g, _ = _positive_array(8)
    gn = nonideal.apply_variation(g, jax.random.PRNGKey(0), 0.0)
    np.testing.assert_array_equal(np.asarray(gn), np.asarray(g))


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(min_value=0, max_value=2 ** 16),
       r=st.floats(min_value=0.1, max_value=2.0))
def test_property_effective_conductance_bounds(seed, r):
    """Property: 0 <= G_eff <= G for any positive array and r in [0.1, 2]."""
    key = jax.random.PRNGKey(seed)
    g = jax.random.uniform(key, (12, 12), minval=0.0, maxval=G0)
    ge = nonideal.effective_conductance(g, r)
    assert bool(jnp.all(ge <= g + 1e-15))
    assert bool(jnp.all(jnp.isfinite(ge)))

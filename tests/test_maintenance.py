"""Drift-aware self-healing: the maintenance contract (TESTING.md).

Pinned here:

* the simulated `DeviceClock` + traced `drift_t` override age a programmed
  plan without touching its conductance stacks;
* the aging acceptance scenario: under continuous power-law drift the
  scrubbing engine sustains ZERO SLO canary trips and zero deadline
  misses, where the reactive baseline (scrub=False, identical otherwise)
  quarantines repeatedly;
* counter discipline: maintenance probes/repairs never consume dispatch
  indices, so a scripted chaos trace fires at identical dispatch indices
  with heavy scrubbing and with none (the determinism regression);
* chaos `HotBlock` forces a LOCALIZED repair: only the hot array is
  re-programmed, the rest of the plan is left alone;
* `submit` after `stop()` - and after a generic worker crash - raises
  `EngineStoppedError` immediately instead of enqueueing into a dead
  worker; a fully-drained fleet rejects with `NoReplicaAvailableError`
  before any counter moves;
* the fleet staggers repair windows (repair token) and a maintaining
  replica is `degraded`, never quarantined.
"""
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.analog import AnalogConfig
from repro.core.nonideal import NonidealConfig, readout_conductance
from repro.data.matrices import wishart
from repro.runtime import (AcceleratedDrift, ChaosInjector,
                           DispatchException, HotBlock)
from repro.serve import (AsyncSolverEngine, BlockTrend, DeviceClock,
                         EngineStoppedError, MaintenanceConfig,
                         NoReplicaAvailableError, ReplicatedSolverFleet,
                         SolverService)

KEY = jax.random.PRNGKey(9)
N = 16
DRIFT = NonidealConfig(sigma=0.0, drift_nu=0.05)
CFG = AnalogConfig(array_size=8, nonideal=DRIFT)
MCFG = MaintenanceConfig(scrub_blocks_per_cycle=16, block_trip=0.02,
                         repair_batch=16)
RNG = np.random.default_rng(3)


def _matrix():
    return wishart(KEY, N)


def _engine(clock, scrub=True, chaos=None, **kw):
    svc = SolverService(CFG, stages=2)
    kw.setdefault("flush_interval", 0.01)
    kw.setdefault("health_floor", 0.05)
    kw.setdefault("maintenance", MCFG)
    return AsyncSolverEngine(svc, clock=clock, scrub=scrub, chaos=chaos,
                             name=f"eng-{scrub}", **kw)


def _drive(eng, clock, waves=6, per_wave=3, dt=0.6, quiesce=True):
    misses = 0
    for _ in range(waves):
        clock.advance(dt)
        if quiesce:
            assert eng.maintenance_quiesce(60.0)
        futs = [eng.submit("m", RNG.standard_normal(N).astype(np.float32))
                for _ in range(per_wave)]
        eng.flush_now()
        for f in futs:
            misses += f.result(timeout=30).deadline_missed
    return misses


# ---------------------------------------------------------------------------
# units: clock, trend detector, drift override
# ---------------------------------------------------------------------------

def test_device_clock():
    clock = DeviceClock()
    assert clock.now() == 0.0
    assert clock.advance(2.5) == 2.5
    fired = []
    clock.subscribe(lambda: fired.append(clock.now()))
    clock.advance(0.5)
    assert fired == [3.0]
    with pytest.raises(ValueError):
        clock.advance(-1.0)
    clock.unsubscribe(next(iter(clock._subs)))
    clock.advance(1.0)
    assert fired == [3.0]


def test_block_trend_extrapolates():
    tr = BlockTrend(alpha=0.5)
    assert tr.time_to_trip(0.1) == float("inf")
    tr.observe(0.0, 0.00)
    tr.observe(1.0, 0.02)       # slope 0.02 / s
    assert tr.ready(2)
    assert tr.time_to_trip(0.1) == pytest.approx((0.1 - 0.02) / 0.02)
    assert tr.cusum > 0.0
    tr.observe(2.0, 0.08)
    assert tr.time_to_trip(0.1) == pytest.approx(
        (0.1 - 0.08) / tr.slope)
    tr.observe(3.0, 0.2)
    assert tr.time_to_trip(0.1) == 0.0       # already over


def test_drift_override_matches_static_config():
    """The traced drift_t override is the SAME power law as the frozen
    drift_t config constant, and ages below 1 clamp to fresh."""
    g = jnp.abs(jax.random.normal(KEY, (5, 8, 8)))
    ni = NonidealConfig(drift_nu=0.07, drift_t=50.0)
    np.testing.assert_array_equal(
        np.asarray(readout_conductance(g, ni, drift_t=50.0)),
        np.asarray(readout_conductance(g, ni)))
    ni0 = NonidealConfig(drift_nu=0.07)
    np.testing.assert_array_equal(
        np.asarray(readout_conductance(g, ni0, drift_t=0.25)),
        np.asarray(g))
    # per-device age vector broadcasts over the stack axis
    ages = jnp.asarray([1.0, 10.0, 100.0, 1.0, 5.0])
    out = np.asarray(readout_conductance(g, ni0, drift_t=ages))
    for i, t in enumerate(np.asarray(ages)):
        np.testing.assert_allclose(
            out[i], np.asarray(g[i]) * t ** -0.07, rtol=1e-6)


def test_service_refresh_swaps_solver_keeps_bookkeeping():
    svc = SolverService(CFG, stages=2)
    svc.program("m", _matrix(), KEY)
    before = svc.stats("m").program_time_s
    svc.submit("m", np.ones(N, np.float32))
    aged = svc.solver("m").aged(30.0)
    svc.refresh("m", aged)
    assert svc.solver("m") is aged
    assert svc.pending("m") == 1            # queue survives the refresh
    assert svc.stats("m").program_time_s == before
    with pytest.raises(KeyError):
        svc.refresh("nope", aged)


# ---------------------------------------------------------------------------
# the aging acceptance scenario (ISSUE 10)
# ---------------------------------------------------------------------------

def test_self_healing_beats_reactive_baseline():
    """Continuous drift on a simulated clock: the scrubbing engine repairs
    blocks ahead of the canary and sustains zero quarantines and zero
    deadline misses; the reactive baseline quarantines repeatedly."""
    clock = DeviceClock()
    with _engine(clock, scrub=True) as eng:
        eng.program("m", _matrix(), KEY)
        misses = _drive(eng, clock)
        h = eng.health()
    assert h["quarantines"] == 0
    assert misses == 0
    assert h["repairs"] > 0 and h["scrub_probes"] > 0
    assert h["status"]["m"] == "healthy"
    gauges = h["maintenance"]["m"]
    assert gauges["blocks_repaired"] > 0
    assert gauges["scrub_backlog"] == 0.0

    clock2 = DeviceClock()
    with _engine(clock2, scrub=False) as eng2:
        eng2.program("m", _matrix(), KEY)
        _drive(eng2, clock2, quiesce=False)
        h2 = eng2.health()
    assert h2["quarantines"] > 0
    assert h2["scrub_probes"] == 0 and h2["repairs"] == 0


def test_health_exports_drift_gauges():
    clock = DeviceClock()
    with _engine(clock, scrub=True) as eng:
        eng.program("m", _matrix(), KEY)
        clock.advance(0.4)
        assert eng.maintenance_quiesce(60.0)
        h = eng.health()
    g = h["maintenance"]["m"]
    for key in ("age", "worst_dev", "trend_slope", "time_to_trip",
                "scrub_backlog", "pending_repairs", "blocks_repaired"):
        assert key in g
    assert h["scrub_probes"] > 0


# ---------------------------------------------------------------------------
# chaos: determinism + aging events
# ---------------------------------------------------------------------------

def _chaos_run(clock_steps):
    """Fixed traffic against a scripted chaos trace; returns the dispatch
    indices every scripted event fired at, plus the engine's counters."""
    chaos = ChaosInjector([DispatchException(at_dispatch=2)])
    clock = DeviceClock()
    with _engine(clock, scrub=True, chaos=chaos) as eng:
        eng.program("m", _matrix(), KEY)
        for dt in clock_steps:
            clock.advance(dt)
            assert eng.maintenance_quiesce(60.0)
            futs = [eng.submit(
                "m", RNG.standard_normal(N).astype(np.float32))
                for _ in range(2)]
            eng.flush_now()
            for f in futs:
                f.result(timeout=30)
        h = eng.health()
    return [idx for idx, _ in chaos.log], h


def test_probes_never_consume_dispatch_indices():
    """Satellite 1: replaying the same chaos trace with heavy scrubbing
    (clock advancing every wave => probes + repairs between dispatches)
    and with no maintenance at all (clock frozen) fires the scripted
    events at IDENTICAL dispatch indices."""
    fired_heavy, h_heavy = _chaos_run([0.6] * 6)
    fired_idle, h_idle = _chaos_run([0.0] * 6)
    assert fired_heavy == fired_idle
    assert h_heavy["scrub_probes"] > 0       # maintenance really ran
    assert h_idle["scrub_probes"] == 0       # and really didn't
    assert h_heavy["quarantines"] == h_idle["quarantines"] == 0


def test_hot_block_repairs_only_the_hot_array():
    """Chaos HotBlock: one array ages 10x faster; base drift stays under
    block_trip for the whole horizon and the hot block's deviation stays
    under the matrix canary floor, so every repair round touches exactly
    the hot block and nothing ever quarantines."""
    hot = ("mvm", 0, 0)
    chaos = ChaosInjector([HotBlock(at_dispatch=0, matrix_id="m",
                                    block=hot, factor=10.0)])
    clock = DeviceClock()
    with _engine(clock, scrub=True, chaos=chaos) as eng:
        eng.program("m", _matrix(), KEY)
        # first wave delivers the chaos event (dispatch-counter keyed)
        misses = _drive(eng, clock, waves=4, per_wave=2, dt=0.1)
        h = eng.health()
    assert misses == 0
    assert chaos.fired == 1
    assert h["quarantines"] == 0
    assert h["repairs"] > 0
    # every repair re-programmed exactly one array: the hot one
    assert h["blocks_repaired"] == h["repairs"]


def test_accelerated_drift_event_fires_once():
    chaos = ChaosInjector([AcceleratedDrift(at_dispatch=0, matrix_id="m",
                                            factor=30.0)])
    assert chaos.aging_due(0) != []
    assert chaos.aging_due(1) == []          # fire-once
    assert chaos.fired == 1


# ---------------------------------------------------------------------------
# satellite 2: no enqueueing into dead workers
# ---------------------------------------------------------------------------

def test_submit_after_stop_raises_immediately():
    svc = SolverService(CFG, stages=2)
    eng = AsyncSolverEngine(svc, flush_interval=0.01)
    eng.program("m", _matrix(), KEY)
    eng.start()
    eng.stop()
    with pytest.raises(EngineStoppedError):
        eng.submit("m", np.ones(N, np.float32))


def test_submit_after_worker_crash_raises_immediately():
    """A generic (non-ReplicaDeath) exception escaping the worker loop
    must mark the engine stopped: later submits raise instead of
    enqueueing futures no thread will ever resolve."""
    svc = SolverService(CFG, stages=2)
    eng = AsyncSolverEngine(svc, flush_interval=0.01)
    eng.program("m", _matrix(), KEY)
    eng._bucket_due = lambda q, now: (_ for _ in ()).throw(
        RuntimeError("scripted worker crash"))
    eng.start()
    eng.submit("m", np.ones(N, np.float32))   # wake the worker -> crash
    deadline = time.monotonic() + 5.0
    while eng.alive and time.monotonic() < deadline:
        time.sleep(0.005)
    assert not eng.alive and eng.crashed
    with pytest.raises(EngineStoppedError):
        eng.submit("m", np.ones(N, np.float32))


def test_drained_fleet_submit_rejects_before_counting():
    fleet = ReplicatedSolverFleet(lambda: SolverService(CFG, stages=2),
                                  n_replicas=1)
    with fleet:
        fleet.program("m", _matrix(), KEY)
        with fleet._lock:
            for r in fleet._replicas:
                r.state = "drained"
        before = (fleet.stats.submitted, fleet._submits)
        with pytest.raises(NoReplicaAvailableError):
            fleet.submit("m", np.ones(N, np.float32))
        assert (fleet.stats.submitted, fleet._submits) == before
        with fleet._lock:
            for r in fleet._replicas:
                r.state = "active"


# ---------------------------------------------------------------------------
# fleet: staggered maintenance windows
# ---------------------------------------------------------------------------

def test_fleet_staggers_repairs_and_never_quarantines():
    clock = DeviceClock()
    fleet = ReplicatedSolverFleet(
        lambda: SolverService(CFG, stages=2), n_replicas=2, clock=clock,
        engine_kw=dict(flush_interval=0.01, health_floor=0.05,
                       maintenance=MCFG))
    with fleet:
        fleet.program("m", _matrix(), KEY)
        for _ in range(5):
            clock.advance(0.6)
            assert fleet.maintenance_quiesce(60.0)
            futs = [fleet.submit(
                "m", RNG.standard_normal(N).astype(np.float32))
                for _ in range(4)]
            fleet.flush_now()
            for f in futs:
                r = f.result(timeout=30)
                assert np.all(np.isfinite(r.x))
        gauges = fleet.maintenance_gauges()
        states = fleet.replica_states()
        stats = fleet.stats
    # repair windows were granted one replica at a time, both replicas
    # got to repair, and nobody was drained or quarantined for it
    assert stats.maintenance_windows > 1
    assert stats.repairs > 0
    assert stats.quarantines == 0 and stats.deaths == 0
    assert all(d["repairs"] > 0 for d in gauges.values())
    assert all(s in ("active", "degraded") for s in states.values())

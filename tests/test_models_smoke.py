"""Per-architecture smoke tests (required by the brief): a REDUCED config of
the same family runs one forward + one train step on CPU with correct output
shapes and no NaNs."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCHS, get_config
from repro.configs.base import RunConfig
from repro.models import transformer as tr
from repro.optim.adamw import AdamW
from repro.train.train_step import init_train_state, make_train_step
from tests.conftest import reduce_cfg

B, S = 2, 16


def _batch(cfg, key=jax.random.PRNGKey(1)):
    if cfg.frontend == "vit_stub":
        return {
            "embeds": jax.random.normal(key, (B, S, cfg.d_model)) * 0.02,
            "labels": jax.random.randint(key, (B, S), 0, cfg.vocab),
        }
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab)
    return {"tokens": toks, "labels": toks}


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_forward_shapes_finite(arch):
    cfg = reduce_cfg(get_config(arch))
    params = tr.init_params(jax.random.PRNGKey(0), cfg)
    batch = _batch(cfg)
    logits, aux = tr.forward(params, cfg, tokens=batch.get("tokens"),
                             embeds=batch.get("embeds"))
    assert logits.shape == (B, S, tr.padded_vocab(cfg))
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", sorted(ARCHS))
@pytest.mark.slow
def test_train_step(arch):
    cfg = reduce_cfg(get_config(arch))
    run = RunConfig(model=cfg, mode="train", seq_len=S, global_batch=B,
                    remat="dots")
    opt = AdamW(lr=1e-3)
    state, _ = init_train_state(jax.random.PRNGKey(0), cfg, run, opt)
    step = jax.jit(make_train_step(cfg, run, opt))
    new_state, metrics = step(state, _batch(cfg))
    assert bool(jnp.isfinite(metrics["loss"]))
    assert int(new_state.opt.step) == 1
    # parameters actually changed
    moved = jax.tree.map(
        lambda a, b: bool(jnp.any(a != b)), state.params, new_state.params)
    assert any(jax.tree.leaves(moved))


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_decode_step(arch):
    cfg = reduce_cfg(get_config(arch))
    params = tr.init_params(jax.random.PRNGKey(0), cfg)
    cache = tr.init_cache(B, 32, cfg, dtype=jnp.float32)
    toks = jax.random.randint(jax.random.PRNGKey(2), (B,), 0, cfg.vocab)
    logits, new_cache = tr.decode_step(params, cache, toks, jnp.int32(0), cfg)
    assert logits.shape == (B, tr.padded_vocab(cfg))
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))
    assert jax.tree.structure(new_cache) == jax.tree.structure(cache)

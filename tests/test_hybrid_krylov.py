"""Batched hybrid Krylov subsystem: drivers, preconditioner, acceptance.

Covers the `repro.hybrid` contract (TESTING.md "hybrid refinement
contract"):

  * driver correctness (pcg on SPD, gmres on nonsymmetric) and fuel bounds;
  * per-RHS convergence masks (converged columns freeze, iteration counts
    are per-column);
  * the acceptance criterion: BlockAMC-preconditioned CG/GMRES reaches
    1e-10 relative residual on cond(A) ~ 1e4 Wishart systems in measurably
    fewer iterations than unpreconditioned digital CG;
  * multi-RHS jitted path vs single-RHS eager path consistency;
  * the differential sweep vs numpy.linalg.solve across cond x sigma,
    including the regime where the raw analog solve cannot reach 1e-10;
  * Monte-Carlo batched and sharded refinement equality.

Everything needing tolerances beyond f32 runs under the
`jax.experimental.enable_x64` context: the analog substrate stays an
approximation either way, but the *digital* refinement then iterates in
f64 - the mixed-precision split of Le Gallo et al.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.experimental import enable_x64

from repro import hybrid
from repro.core.analog import AnalogConfig
from repro.core.nonideal import NonidealConfig
from repro.data.matrices import random_rhs, toeplitz, wishart, \
    wishart_with_cond
from repro.hybrid import AnalogPreconditioner, gmres, matvec_from_dense, pcg

KEY = jax.random.PRNGKey(7)
KA, KB, KN = jax.random.split(KEY, 3)

# The acceptance regime (documented in TESTING.md): write-verified
# programming - small device variation, wire model with compensation.
# Larger sigma x condition products push the noisy inverse out of the SPD
# cone (perturbation O(kappa sigma sqrt(n)) vs the smallest eigenvalue);
# PCG then needs sigma ~ 0 while GMRES stays robust - both are pinned here.
WRITE_VERIFIED = NonidealConfig(sigma=1e-4, r_wire=1.0, compensate_wire=True)


# ------------------------------ drivers -----------------------------------

def test_pcg_matches_direct_solve():
    a = wishart(KA, 48)
    b = random_rhs(KB, 48)
    res = pcg(matvec_from_dense(a), b, tol=1e-6, maxiter=500)
    assert bool(res.converged)
    np.testing.assert_allclose(np.asarray(res.x),
                               np.asarray(jnp.linalg.solve(a, b)),
                               rtol=1e-4, atol=1e-5)


def test_gmres_solves_nonsymmetric():
    a = toeplitz(KA, 40)            # general (non-SPD) system
    b = random_rhs(KB, 40)
    res = gmres(matvec_from_dense(a), b, tol=1e-5, restart=20, maxiter=400)
    assert bool(res.converged)
    assert float(res.resnorm) <= 1e-5


def test_fuel_bound_and_iteration_counts():
    a = wishart(KA, 32)
    b = random_rhs(KB, 32)
    res = pcg(matvec_from_dense(a), b, tol=1e-30, maxiter=13)
    assert int(res.iters) == 13 and not bool(res.converged)
    resg = gmres(matvec_from_dense(a), b, tol=1e-30, restart=4, maxiter=8)
    assert int(resg.iters) <= 8 and not bool(resg.converged)


def test_per_rhs_masks_freeze_converged_columns():
    """One zero rhs, one eigenvector rhs, one generic rhs: per-column
    iteration counts differ and early-converged columns stay frozen."""
    n = 32
    a = wishart(KA, n)
    evals, evecs = jnp.linalg.eigh(a)
    b_zero = jnp.zeros((n,))
    b_eig = evecs[:, -1]            # one CG step solves it exactly
    b_gen = random_rhs(KB, n)
    bt = jnp.stack([b_zero, b_eig, b_gen])
    res = pcg(matvec_from_dense(a), bt, tol=1e-5, maxiter=500)
    assert res.iters.shape == (3,)
    assert int(res.iters[0]) == 0           # b = 0 starts converged
    assert bool(jnp.all(res.x[0] == 0.0))
    assert bool(res.converged.all())
    assert int(res.iters[1]) < int(res.iters[2])
    # frozen column matches its solo run bit-for-bit in iteration count
    solo = pcg(matvec_from_dense(a), b_eig, tol=1e-5, maxiter=500)
    assert int(solo.iters) == int(res.iters[1])


# ---------------------- acceptance: cond ~ 1e4 ----------------------------

def test_preconditioned_krylov_beats_plain_cg_cond1e4():
    """Acceptance: analog-preconditioned CG and GMRES reach 1e-10 on a
    cond(A) ~ 1e4 Wishart system in measurably fewer iterations than
    unpreconditioned digital CG (recorded in artifacts/bench/hybrid.json
    by benchmarks/hybrid_refinement.py)."""
    with enable_x64():
        n = 64
        a = wishart_with_cond(KA, n, 1e4, dtype=jnp.float64)
        b = random_rhs(KB, n).astype(jnp.float64)
        mv = matvec_from_dense(a)
        plain = pcg(mv, b, tol=1e-10, maxiter=4000)
        assert bool(plain.converged)

        # PCG wants an (almost) SPD inverse: ideal devices, finite OPA gain
        cfg_cg = AnalogConfig(array_size=n // 2, opa_gain=1e5)
        m_cg = AnalogPreconditioner.program(a, KN, cfg_cg, stages=1)
        res_cg = pcg(mv, b, precond=m_cg, x0=m_cg(b), tol=1e-10, maxiter=4000)
        assert bool(res_cg.converged) and float(res_cg.resnorm) <= 1e-10
        assert int(res_cg.iters) * 2 < int(plain.iters)

        # GMRES tolerates genuinely noisy programming (write-verified level)
        cfg_gm = AnalogConfig(array_size=n // 2, nonideal=WRITE_VERIFIED)
        m_gm = AnalogPreconditioner.program(a, KN, cfg_gm, stages=1)
        res_gm = gmres(mv, b, precond=m_gm, x0=m_gm(b), tol=1e-10,
                       restart=16, maxiter=4000)
        assert bool(res_gm.converged) and float(res_gm.resnorm) <= 1e-10
        assert int(res_gm.iters) * 2 < int(plain.iters)


def test_multi_rhs_jitted_matches_single_rhs_eager():
    """The documented consistency contract: the jitted multi-RHS path
    equals k single-RHS eager runs to float tolerance (XLA batching only
    reassociates matmul reductions; see TESTING.md for the bound)."""
    with enable_x64():
        n, k = 48, 5
        a = wishart_with_cond(KA, n, 1e3, dtype=jnp.float64)
        bs = jax.random.normal(KB, (n, k), dtype=jnp.float64)
        cfg = AnalogConfig(array_size=n // 2, nonideal=WRITE_VERIFIED)
        precond = AnalogPreconditioner.program(a, KN, cfg, stages=1)
        xs, res = hybrid.solve_refined(a, bs, precond, method="gmres",
                                       tol=1e-10, maxiter=640, restart=16)
        assert xs.shape == (n, k) and bool(res.converged.all())
        for j in range(k):
            xj, rj = hybrid.solve_refined(a, bs[:, j], precond,
                                          method="gmres", tol=1e-10,
                                          maxiter=640, restart=16, jit=False)
            assert bool(rj.converged)
            np.testing.assert_allclose(np.asarray(xs[:, j]), np.asarray(xj),
                                       rtol=1e-6, atol=1e-7)


# ------------------- differential sweep vs numpy --------------------------

@pytest.mark.parametrize("cond", [1e1, 1e3, 1e5])
@pytest.mark.parametrize("sigma", [0.0, 0.05])
def test_differential_refined_vs_numpy(cond, sigma):
    """Hybrid-refined solve vs numpy.linalg.solve across cond x sigma.

    Refinement must reach 1e-10 relative residual everywhere; with
    sigma=0.05 the raw analog solve cannot (its residual stays above 1e-3),
    so the digital loop is doing real work.  Noisy preconditioners are
    unusable at these sigma x cond products (see the acceptance test), so
    the sigma>0 sweep runs seed-only refinement (use_precond=False).
    """
    with enable_x64():
        n = 48
        a = wishart_with_cond(KA, n, cond, dtype=jnp.float64)
        b = random_rhs(KB, n).astype(jnp.float64)
        cfg = AnalogConfig(array_size=n // 2,
                           nonideal=NonidealConfig(sigma=sigma))
        precond = AnalogPreconditioner.program(a, KN, cfg, stages=1)
        raw = precond(b)                    # the raw analog solve
        raw_res = float(jnp.linalg.norm(b - a @ raw) / jnp.linalg.norm(b))
        x, res = hybrid.solve_refined(a, b, precond, method="cg", tol=1e-10,
                                      maxiter=6000, use_precond=sigma == 0.0)
        assert bool(res.converged)
        assert float(res.resnorm) <= 1e-10
        if sigma > 0.0:
            assert raw_res > 1e-3           # analog alone cannot get there
        # numpy agreement: forward error bounded by cond * residual
        x_np = np.linalg.solve(np.asarray(a, np.float64),
                               np.asarray(b, np.float64))
        rel = np.linalg.norm(np.asarray(x) - x_np) / np.linalg.norm(x_np)
        assert rel <= cond * 1e-9


# ------------------- Monte-Carlo batched + sharded ------------------------

def test_refined_batched_matches_per_key_and_sharded():
    from repro.launch.mesh import make_mc_mesh
    with enable_x64():
        n = 32
        a = wishart_with_cond(KA, n, 1e2, dtype=jnp.float64)
        b = random_rhs(KB, n).astype(jnp.float64)
        cfg = AnalogConfig(array_size=n // 2, nonideal=WRITE_VERIFIED)
        keys = jax.random.split(KN, 4)
        res_b = hybrid.solve_refined_batched(a, b, keys, cfg, stages=1,
                                             method="gmres", tol=1e-10,
                                             maxiter=320, restart=16)
        assert res_b.x.shape == (4, n) and bool(res_b.converged.all())
        # per-key reference: program + refine each key independently
        for i in range(4):
            precond = AnalogPreconditioner.program(a, keys[i], cfg, stages=1)
            xi, ri = hybrid.solve_refined(a, b, precond, method="gmres",
                                          tol=1e-10, maxiter=320, restart=16)
            np.testing.assert_allclose(np.asarray(res_b.x[i]), np.asarray(xi),
                                       rtol=1e-6, atol=1e-8)
        res_s = hybrid.solve_refined_batched_sharded(
            a, b, keys, cfg, stages=1, method="gmres", tol=1e-10,
            maxiter=320, restart=16, mesh=make_mc_mesh(1))
        np.testing.assert_allclose(np.asarray(res_s.x), np.asarray(res_b.x),
                                   rtol=1e-6, atol=1e-8)


def test_core_hybrid_shim_reexports():
    """`repro.core.hybrid` stays import-compatible with the old module."""
    from repro.core import hybrid as shim
    assert shim.pcg is pcg and shim.gmres is gmres
    assert shim.AnalogPreconditioner is AnalogPreconditioner
    for name in ("richardson_refine", "cg_refine", "iterations_to_tol",
                 "solve_refined", "solve_refined_batched",
                 "solve_refined_batched_sharded", "matvec_from_dense"):
        assert hasattr(shim, name)


# -------------------- truth in reporting (recurrence drift) ----------------

def _true_resnorm(a, x, b):
    r = np.asarray(b) - np.asarray(x) @ np.asarray(a).T
    return (np.linalg.norm(r, axis=-1) /
            np.linalg.norm(np.asarray(b), axis=-1))


def test_pcg_reports_true_residual_at_f32_cond1e6():
    """At f32 x cond ~ 1e6 the CG recurrence residual keeps shrinking long
    after the true residual stagnates near eps * cond.  The reported
    resnorm/converged must describe the TRUE exit residual (one extra
    matvec at exit), never the recurrence - the docstring's
    ||b - A x|| <= tol * ||b|| contract."""
    n = 48
    a = wishart_with_cond(KA, n, 1e6, dtype=jnp.float32)
    bt = jnp.stack([random_rhs(KB, n), random_rhs(KN, n)]).astype(jnp.float32)
    tol = 1e-6                      # unattainable: below eps_f32 * cond
    res = pcg(matvec_from_dense(a), bt, tol=tol, maxiter=3000)
    ext = _true_resnorm(a, res.x, bt)
    # rtol covers f32 reduction-order noise between XLA and numpy matvecs
    # at a stagnated residual; the recurrence residual (the bug this pins)
    # would be off by orders of magnitude here.
    np.testing.assert_allclose(np.asarray(res.resnorm), ext, rtol=1e-2)
    # never over-report: converged implies the externally-checked residual
    for c, e in zip(np.asarray(res.converged), ext):
        assert (not c) or e <= tol * 1.0001
    # and the regime is the interesting one: CG actually stagnated above tol
    assert float(ext.max()) > tol


def test_gmres_reports_true_residual_at_restart_boundary():
    """Restarted GMRES reports at cycle granularity; the reported resnorm
    must equal the externally recomputed residual of the reported x even
    when the fuel bound cuts the last cycle off."""
    n = 48
    a = wishart_with_cond(KA, n, 1e6, dtype=jnp.float32)
    bt = jnp.stack([random_rhs(KB, n), random_rhs(KN, n)]).astype(jnp.float32)
    tol = 1e-6
    res = gmres(matvec_from_dense(a), bt, tol=tol, restart=5, maxiter=35)
    ext = _true_resnorm(a, res.x, bt)
    np.testing.assert_allclose(np.asarray(res.resnorm), ext, rtol=1e-4)
    for c, e in zip(np.asarray(res.converged), ext):
        assert (not c) or e <= tol * 1.0001


def test_pcg_fixed_equals_pcg_zero_tol():
    """pcg_fixed(iters=k) is numerically the pcg(tol=0, maxiter=k) budget
    path (same recurrences, no masks needed when nothing converges)."""
    with enable_x64():
        n = 24
        a = wishart_with_cond(KA, n, 1e3, dtype=jnp.float64)
        bt = jnp.stack([random_rhs(KB, n),
                        jnp.zeros((n,))]).astype(jnp.float64)
        ref = pcg(matvec_from_dense(a), bt, tol=0.0, maxiter=7)
        fix = hybrid.pcg_fixed(matvec_from_dense(a), bt, iters=7)
        np.testing.assert_allclose(np.asarray(fix.x), np.asarray(ref.x),
                                   rtol=1e-12, atol=1e-300)
        # the zero column stays a fixed point without masks
        assert bool(jnp.all(fix.x[1] == 0.0))
        np.testing.assert_allclose(np.asarray(fix.resnorm),
                                   np.asarray(ref.resnorm), rtol=1e-10,
                                   atol=1e-300)

"""Flat level-scheduled executor vs the recursive reference.

The contract (see TESTING.md): `compile_plan` is a pure restructuring of a
SolvePlan, so `execute_flat` computes with *identical* programmed arrays and
must match `_exec_inv`'s cascade to float tolerance for every cfg - and
bit-for-bit on the CPU backend, where both executors lower to the same
LAPACK calls in the same order.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import blockamc
from repro.core.analog import AnalogConfig
from repro.core.nonideal import NonidealConfig
from repro.data.matrices import random_rhs, wishart

KEY = jax.random.PRNGKey(3)
KA, KB, KN = jax.random.split(KEY, 3)

CASES = [
    # (n, stages, cfg)
    (8, 0, AnalogConfig(array_size=8)),
    (16, 1, AnalogConfig(array_size=8)),
    (17, 1, AnalogConfig(array_size=16,
                         nonideal=NonidealConfig(sigma=0.05))),
    (32, 2, AnalogConfig(array_size=8,
                         nonideal=NonidealConfig(sigma=0.05))),
    (33, 2, AnalogConfig(array_size=16, nonideal=NonidealConfig(sigma=0.02)),
     ),
    (32, 3, AnalogConfig(array_size=4)),
    (16, 1, AnalogConfig(array_size=8, opa_gain=1e4)),
    (16, 1, AnalogConfig(array_size=8, dac_bits=8, adc_bits=8)),
    (24, 1, AnalogConfig(array_size=8,
                         nonideal=NonidealConfig(sigma=0.05, r_wire=1.0))),
]


def _pair(n, stages, cfg):
    a = wishart(KA, n)
    b = random_rhs(KB, n)
    plan = blockamc.build_plan(a, KN, cfg, stages=stages)
    return plan, b


@pytest.mark.parametrize("n,stages,cfg", CASES)
def test_flat_matches_recursive(n, stages, cfg):
    plan, b = _pair(n, stages, cfg)
    x_rec = blockamc.execute(plan, b, cfg)
    x_flat = blockamc.execute_flat(blockamc.compile_plan(plan), b, cfg)
    if jax.default_backend() == "cpu":
        # same arrays, same op order, same LAPACK -> bit-for-bit
        np.testing.assert_array_equal(np.asarray(x_rec), np.asarray(x_flat))
    else:
        np.testing.assert_allclose(np.asarray(x_rec), np.asarray(x_flat),
                                   rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("k", [1, 3, 8])
def test_flat_multi_rhs_matches_per_column(k):
    """(n, k) right-hand sides == k independent recursive solves."""
    n, stages = 32, 2
    cfg = AnalogConfig(array_size=8, nonideal=NonidealConfig(sigma=0.05))
    a = wishart(KA, n)
    bs = jax.random.normal(KB, (n, k))
    plan = blockamc.build_plan(a, KN, cfg, stages=stages)
    xs_flat = blockamc.execute_flat(blockamc.compile_plan(plan), bs, cfg)
    assert xs_flat.shape == (n, k)
    for j in range(k):
        x_rec = blockamc.execute(plan, bs[:, j], cfg)
        np.testing.assert_allclose(np.asarray(xs_flat[:, j]),
                                   np.asarray(x_rec), rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("n_keys", [
    1, 4,
    pytest.param(16, marks=pytest.mark.slow),
])
def test_solve_batched_matches_vmapped_solve(n_keys):
    n, stages = 32, 1
    cfg = AnalogConfig(array_size=16, nonideal=NonidealConfig(sigma=0.05))
    a = wishart(KA, n)
    b = random_rhs(KB, n)
    keys = jax.random.split(KN, n_keys)
    xs_b = blockamc.solve_batched(a, b, keys, cfg, stages=stages)
    xs_v = jax.vmap(lambda k: blockamc.solve(a, b, k, cfg, stages=stages))(
        keys)
    assert xs_b.shape == (n_keys, n)
    np.testing.assert_allclose(np.asarray(xs_b), np.asarray(xs_v),
                               rtol=1e-4, atol=1e-5)
    # independent noise draws differ across keys
    if n_keys > 1:
        assert float(jnp.std(xs_b, axis=0).max()) > 0.0


def test_solve_original_batched_matches():
    n = 24
    cfg = AnalogConfig(nonideal=NonidealConfig(sigma=0.05))
    a = wishart(KA, n)
    b = random_rhs(KB, n)
    keys = jax.random.split(KN, 4)
    xs_b = blockamc.solve_original_batched(a, b, keys, cfg)
    xs_v = jax.vmap(lambda k: blockamc.solve_original(a, b, k, cfg))(keys)
    np.testing.assert_allclose(np.asarray(xs_b), np.asarray(xs_v),
                               rtol=1e-4, atol=1e-5)


def test_fig8_structure_16_arrays_of_64():
    """Two-stage 256^2 compiles to 16 physical arrays of 64x64 (Fig. 8)."""
    a = wishart(KA, 256)
    cfg = AnalogConfig(array_size=64)
    fplan = blockamc.build_flat_plan(a, KN, cfg, stages=2)
    assert fplan.num_arrays == 16
    # all arrays are 64x64, bucketed by cascade depth
    for grid, (depth, shape) in zip(fplan.inv_stacks, fplan.inv_keys):
        assert shape == (64, 64) and depth == 2
    assert sum(g.shape[-3] for g in fplan.inv_stacks) == 4
    for grid, (depth, shape) in zip(fplan.mvm_stacks, fplan.mvm_keys):
        assert shape == (64, 64)
    assert sum(g.shape[-3] for g in fplan.mvm_stacks) == 12


def test_schedule_dedupes_reused_arrays():
    """A1 serves cascade steps 1 and 5 but is programmed (stacked) once."""
    a = wishart(KA, 32)
    cfg = AnalogConfig(array_size=16)
    fplan = blockamc.build_flat_plan(a, KN, cfg, stages=2)
    inv_levels = [i for i in fplan.schedule if i[0] == "inv"]
    assert len(inv_levels) == 9                   # 3^stages INV applications
    distinct = {(i[1], i[2]) for i in inv_levels}
    assert len(distinct) == 4                     # 2^stages programmed leaves
    assert fplan.num_levels == len(fplan.schedule)


def test_flat_plan_jit_and_vmap():
    """FlatPlan is a pytree: jits as a carried constant and vmaps over keys."""
    n = 16
    cfg = AnalogConfig(array_size=8, nonideal=NonidealConfig(sigma=0.05))
    a = wishart(KA, n)
    b = random_rhs(KB, n)
    keys = jax.random.split(KN, 3)
    fplans = jax.vmap(lambda k: blockamc.build_flat_plan(a, k, cfg, 1))(keys)
    f = jax.jit(lambda fp, b: blockamc.execute_flat(fp, b, cfg))
    xs = jax.vmap(lambda fp: f(fp, b))(fplans)
    assert xs.shape == (3, n)
    assert bool(jnp.all(jnp.isfinite(xs)))

"""Four-way executor equivalence: recursive / flat / finalized / fused-arena.

The contract (TESTING.md): the three reference executors agree bit-for-bit
on CPU when run eagerly (`execute` == `execute_flat` == eager
`execute_finalized` - unchanged from the three-way contract), and the
fused-arena executor (`compile_arena` / `execute_arena`, the serving fast
path) is pinned against them at float tolerance: it applies explicit
INV-bucket inverses instead of `lu_solve` and folds the summing-node
divisor into the tile operators, both of which reassociate rounding by
design.  The grid covers stages {0, 1, 2} x regimes {ideal, sigma, wire,
finite opa_gain} x rhs {(n,), (n, k)}, ragged splits included.

The arena's own structural invariants (allocator live ranges, window
containment, peak liveness) live in tests/test_plan_properties.py; the
Pallas megakernel parity (interpret=True) in tests/test_kernels.py.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import blockamc
from repro.core.analog import AnalogConfig
from repro.core.nonideal import NonidealConfig
from repro.data.matrices import random_rhs, wishart

KEY = jax.random.PRNGKey(7)
KA, KB, KN = jax.random.split(KEY, 3)

STAGES = (0, 1, 2)
REGIMES = [
    ("ideal", lambda n: AnalogConfig(array_size=max(n // 2, 4))),
    ("sigma", lambda n: AnalogConfig(
        array_size=max(n // 2, 4), nonideal=NonidealConfig(sigma=0.05))),
    ("wire", lambda n: AnalogConfig(
        array_size=max(n // 2, 4),
        nonideal=NonidealConfig(sigma=0.05, r_wire=1.0))),
    ("gain", lambda n: AnalogConfig(
        array_size=max(n // 2, 4), opa_gain=1e4)),
]
# n=32 keeps power-of-two tiling (uniform whole-schedule program); n=17/33
# exercise ragged odd splits (multi-segment gathers, per-level fallback).
SIZES = (32, 17)


def _four_ways(n, stages, cfg, b):
    a = wishart(KA, n)
    plan = blockamc.build_plan(a, KN, cfg, stages=stages)
    fplan = blockamc.compile_plan(plan)
    fin = blockamc.finalize(fplan, cfg)
    ap = blockamc.compile_arena(fin)
    if b.ndim == 1:
        x_rec = blockamc.execute(plan, b, cfg)
    else:
        x_rec = jnp.stack([blockamc.execute(plan, b[:, j], cfg)
                           for j in range(b.shape[1])], axis=1)
    x_flat = blockamc.execute_flat(fplan, b, cfg)
    x_fin = blockamc.execute_finalized(fin, b)
    x_arena = blockamc.execute_arena(ap, b, use_kernel=False)
    return x_rec, x_flat, x_fin, x_arena


@pytest.mark.parametrize("n", SIZES)
@pytest.mark.parametrize("stages", STAGES)
@pytest.mark.parametrize("tag,make_cfg", REGIMES)
@pytest.mark.parametrize("multi_rhs", [False, True])
def test_four_way_equivalence(n, stages, tag, make_cfg, multi_rhs):
    cfg = make_cfg(n)
    b = jax.random.normal(KB, (n, 4)) if multi_rhs else random_rhs(KB, n)
    x_rec, x_flat, x_fin, x_arena = _four_ways(n, stages, cfg, b)
    # the existing promise: reference executors are bit-for-bit on CPU
    # (multi-rhs recursive runs column-wise, so flat batching is pinned at
    # float tolerance there - same contract as test_flat_executor)
    if jax.default_backend() == "cpu" and not multi_rhs:
        np.testing.assert_array_equal(np.asarray(x_rec), np.asarray(x_flat))
        np.testing.assert_array_equal(np.asarray(x_flat), np.asarray(x_fin))
    else:
        np.testing.assert_allclose(np.asarray(x_rec), np.asarray(x_flat),
                                   rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(np.asarray(x_flat), np.asarray(x_fin),
                                   rtol=1e-5, atol=1e-6)
    # the fused arena executor is float-tolerance by design (explicit
    # inverse + folded divisors reassociate rounding)
    np.testing.assert_allclose(np.asarray(x_arena), np.asarray(x_fin),
                               rtol=2e-4, atol=2e-5)


@pytest.mark.parametrize("mode", ["reference", "fused"])
def test_jitted_solver_matches_eager(mode):
    """ProgrammedSolver's shared jitted executors == the eager schedule
    at float tolerance, for both modes, single and multi rhs."""
    n, stages = 32, 2
    cfg = AnalogConfig(array_size=8, nonideal=NonidealConfig(sigma=0.05))
    a = wishart(KA, n)
    solver = blockamc.ProgrammedSolver.program(a, KN, cfg, stages=stages)
    for b in (random_rhs(KB, n), jax.random.normal(KB, (n, 5))):
        x_eager = solver.solve(b, jit=False, mode=mode)
        x_jit = solver.solve(b, mode=mode)
        np.testing.assert_allclose(np.asarray(x_jit), np.asarray(x_eager),
                                   rtol=1e-5, atol=1e-6)


def test_fused_solves_the_system():
    """End to end: the fused path still solves A x = b (ideal config)."""
    n = 64
    cfg = AnalogConfig(array_size=16)
    a = wishart(KA, n)
    b = random_rhs(KB, n)
    solver = blockamc.ProgrammedSolver.program(a, KN, cfg, stages=2)
    assert solver.mode == "fused"
    np.testing.assert_allclose(np.asarray(solver.solve(b)),
                               np.asarray(jnp.linalg.solve(a, b)),
                               rtol=1e-3, atol=1e-4)


def test_solve_many_pads_and_slices():
    """solve_many owns the pow-2 padding: distinct k hit one compiled
    shape per doubling and padding columns never leak into results."""
    n = 32
    cfg = AnalogConfig(array_size=16, nonideal=NonidealConfig(sigma=0.02))
    a = wishart(KA, n)
    solver = blockamc.ProgrammedSolver.program(a, KN, cfg, stages=1)
    xs5 = solver.solve_many(jax.random.normal(KB, (n, 5)))
    assert xs5.shape == (n, 5)
    for k in (3, 5, 7, 8):
        bs = jax.random.normal(jax.random.fold_in(KB, k), (n, k))
        xs = solver.solve_many(bs)
        assert xs.shape == (n, k)
        # column j == single solve of column j (same jitted executor)
        np.testing.assert_allclose(np.asarray(xs[:, 0]),
                                   np.asarray(solver.solve(bs[:, 0])),
                                   rtol=1e-5, atol=1e-6)
    # unpadded dispatch still available
    xs = solver.solve_many(jax.random.normal(KB, (n, 6)), pad_to_pow2=False)
    assert xs.shape == (n, 6)


def test_solve_many_does_not_retrace_across_k():
    """Distinct queue lengths share one executor trace per pow-2 bucket:
    5, 6, 7 and 8 rhs all dispatch the warmed (n, 8) shape."""
    n = 32
    cfg = AnalogConfig(array_size=16)
    a = wishart(KA, n)
    solver = blockamc.ProgrammedSolver.program(a, KN, cfg, stages=1)
    fn = blockamc._execute_arena
    if not hasattr(fn, "_cache_size"):
        pytest.skip("jit cache introspection not available")
    solver.solve_many(jax.random.normal(KB, (n, 8)))   # warm the bucket
    before = fn._cache_size()
    for k in (5, 6, 7, 8):
        solver.solve_many(jax.random.normal(jax.random.fold_in(KB, k),
                                            (n, k)))
    assert fn._cache_size() == before, "distinct k re-traced the executor"


def test_mc_fused_matches_reference_mode():
    """solve_batched(mode='fused') == reference mode at float tolerance,
    plain and sharded (per-key finalize + arena-compile under vmap)."""
    from repro.launch.mesh import make_mc_mesh
    n = 32
    cfg = AnalogConfig(array_size=16, nonideal=NonidealConfig(sigma=0.05))
    a = wishart(KA, n)
    b = random_rhs(KB, n)
    keys = jax.random.split(KN, 4)
    xs_ref = blockamc.solve_batched(a, b, keys, cfg, stages=1)
    xs_fus = blockamc.solve_batched(a, b, keys, cfg, stages=1, mode="fused")
    np.testing.assert_allclose(np.asarray(xs_fus), np.asarray(xs_ref),
                               rtol=2e-4, atol=2e-5)
    xs_sh = blockamc.solve_batched_sharded(a, b, keys, cfg, stages=1,
                                           mesh=make_mc_mesh(1),
                                           mode="fused")
    np.testing.assert_allclose(np.asarray(xs_sh), np.asarray(xs_fus),
                               rtol=1e-6, atol=1e-7)


def test_preconditioner_modes_agree():
    """AnalogPreconditioner fused apply == reference apply (float tol),
    and the pytree round-trips with both plans attached."""
    from repro.hybrid import AnalogPreconditioner
    n = 32
    cfg = AnalogConfig(array_size=16, nonideal=NonidealConfig(sigma=0.02))
    a = wishart(KA, n)
    pre_f = AnalogPreconditioner.program(a, KN, cfg, stages=1)
    pre_r = AnalogPreconditioner.program(a, KN, cfg, stages=1,
                                         mode="reference")
    v = jax.random.normal(KB, (5, n))
    np.testing.assert_allclose(np.asarray(pre_f(v)), np.asarray(pre_r(v)),
                               rtol=2e-4, atol=2e-5)
    leaves, td = jax.tree_util.tree_flatten(pre_f)
    pre_2 = jax.tree_util.tree_unflatten(td, leaves)
    np.testing.assert_array_equal(np.asarray(pre_f(v)),
                                  np.asarray(pre_2(v)))
    hash(td)    # jit cache key: aux (mode + plan metadata) stays hashable


def test_arena_plan_is_pytree():
    cfg = AnalogConfig(array_size=8, nonideal=NonidealConfig(sigma=0.05))
    a = wishart(KA, 16)
    b = random_rhs(KB, 16)
    ap = blockamc.compile_arena(
        blockamc.finalize(blockamc.build_flat_plan(a, KN, cfg, 1), cfg))
    leaves, treedef = jax.tree_util.tree_flatten(ap)
    ap2 = jax.tree_util.tree_unflatten(treedef, leaves)
    np.testing.assert_array_equal(
        np.asarray(blockamc.execute_arena(ap, b)),
        np.asarray(blockamc.execute_arena(ap2, b)))
    hash(treedef)

    # donation-capable jitted entry point works on the pytree
    xs = blockamc._execute_arena_donated(ap, jax.random.normal(KB, (16, 2)))
    assert xs.shape == (16, 2)


def test_fused_kernel_smoke_interpret():
    """The CI fused-executor smoke: the whole-schedule Pallas megakernel
    (interpret=True on CPU) reproduces the jnp slot path on a uniform
    power-of-two plan, single and multi rhs."""
    n = 16
    cfg = AnalogConfig(array_size=4, nonideal=NonidealConfig(sigma=0.05))
    a = wishart(KA, n)
    ap = blockamc.compile_arena(
        blockamc.finalize(blockamc.build_flat_plan(a, KN, cfg, 2), cfg))
    assert ap.program is not None and ap.kernel_ok
    for b in (random_rhs(KB, n), jax.random.normal(KB, (n, 3))):
        x_j = blockamc.execute_arena(ap, b, use_kernel=False)
        x_k = blockamc.execute_arena(ap, b, use_kernel=True)
        np.testing.assert_allclose(np.asarray(x_k), np.asarray(x_j),
                                   rtol=1e-6, atol=1e-7)

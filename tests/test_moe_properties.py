"""MoE dispatch invariants (property-based) + pipeline/misc coverage."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from tests._hypothesis_compat import given, settings, st

from repro.configs import get_config
from repro.models import moe as moe_mod
from repro.models.moe import capacity, effective_groups, moe_ffn
from repro.sharding import api as shapi
from tests.conftest import reduce_cfg


def _moe_cfg(**kw):
    base = reduce_cfg(get_config("phi3.5-moe-42b-a6.6b"))
    return dataclasses.replace(base, **kw)


def test_moe_matches_dense_expert_reference():
    """With no drops, group dispatch == per-token dense expert mixture."""
    cfg = _moe_cfg(capacity_factor=8.0)
    params = moe_mod.init_moe(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, cfg.d_model)) * 0.5
    out, _ = moe_ffn(params, x, cfg)

    # reference: explicit per-token top-k mixture over all experts
    xf = x.reshape(-1, cfg.d_model)
    logits = xf @ params["router"]
    probs = jax.nn.softmax(logits, -1)
    gw, gi = jax.lax.top_k(probs, cfg.top_k)
    gw = gw / jnp.sum(gw, -1, keepdims=True)

    def expert(e, t):
        h = jax.nn.silu(xf[t] @ params["gate"][e]) * (xf[t] @ params["up"][e])
        return h @ params["down"][e]

    ref = jnp.zeros_like(xf)
    for t in range(xf.shape[0]):
        acc = jnp.zeros((cfg.d_model,))
        for j in range(cfg.top_k):
            acc = acc + gw[t, j] * expert(int(gi[t, j]), t)
        ref = ref.at[t].set(acc)
    np.testing.assert_allclose(np.asarray(out.reshape(-1, cfg.d_model)),
                               np.asarray(ref), rtol=2e-4, atol=2e-5)


@pytest.mark.slow
def test_moe_group_count_invariance_no_drops():
    """Output is independent of the dp_groups hint when capacity is ample."""
    cfg = _moe_cfg(capacity_factor=16.0)   # ample: no drops at any g
    params = moe_mod.init_moe(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 64, cfg.d_model)) * 0.5
    outs = []
    from jax.sharding import Mesh
    import numpy as onp
    mesh = Mesh(onp.asarray(jax.devices()[:1]).reshape(1, 1),
                ("data", "model"))
    for g in (1, 2, 4):
        pol = shapi.ShardingPolicy(mesh, {}, meta={"dp_groups": g})
        with shapi.policy_scope(pol):
            out, _ = moe_ffn(params, x, cfg)
        outs.append(np.asarray(out))
    np.testing.assert_allclose(outs[0], outs[1], rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(outs[0], outs[2], rtol=1e-5, atol=1e-6)


@settings(max_examples=20, deadline=None)
@given(t=st.integers(8, 4096), k=st.integers(1, 4), e=st.integers(2, 128),
       cf=st.floats(1.0, 4.0))
def test_capacity_properties(t, k, e, cf):
    cfg = dataclasses.replace(_moe_cfg(), top_k=k, n_experts=e,
                              capacity_factor=cf)
    c = capacity(t, cfg)
    assert c >= 1
    assert c * e >= min(t * k, e)         # enough slots for balanced load
    if c >= 8:
        assert c % 8 == 0                 # layout padding above the floor


@settings(max_examples=20, deadline=None)
@given(t=st.integers(1, 4096), g=st.sampled_from([1, 2, 4, 8, 16, 32]))
def test_effective_groups_properties(t, g):
    eg = effective_groups(t, g)
    assert eg >= 1 and g % eg == 0
    if eg > 1:
        assert t % eg == 0 and t // eg >= 64


def test_moe_aux_loss_balanced_vs_collapsed():
    """Aux loss is ~1 for uniform routing, ~E for collapsed routing."""
    cfg = _moe_cfg()
    params = moe_mod.init_moe(jax.random.PRNGKey(0), cfg, jnp.float32)
    # positive activations so a positive router column dominates every token
    x = jnp.abs(jax.random.normal(jax.random.PRNGKey(1),
                                  (2, 32, cfg.d_model))) * 0.3 + 0.1
    _, aux_uniform = moe_ffn(params, x, cfg)
    collapsed = dict(params)
    collapsed["router"] = jnp.zeros_like(params["router"]).at[:, 0].set(1.0)
    _, aux_collapsed = moe_ffn(collapsed, x, cfg)
    assert float(aux_collapsed) > 2.0 * float(aux_uniform)
    assert float(aux_collapsed) == pytest.approx(cfg.n_experts, rel=0.1)

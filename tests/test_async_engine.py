"""Async engine serving-robustness contract (TESTING.md).

The contract under test:

* every `submit` future resolves - to a `SolveResult` or a *typed* error
  (`DeadlineExceededError`, `EngineStoppedError`, `BackpressureError` at
  admission) - never a silent hang;
* a request whose deadline passes while queued is shed before compute;
  one answered late carries `deadline_missed=True`;
* a full bucket rejects with `BackpressureError` (backpressure, never a
  silent drop);
* the failover ladder: canary-tripped matrices quarantine, re-program
  with a fresh key, replay their in-flight requests; when health cannot
  be restored they degrade to the digital fallback with `mode="digital"`
  in every answer's metadata - and healthy co-batched tenants are never
  dragged into any of it;
* the whole ladder is exercised *deterministically* through
  `runtime.chaos.ChaosInjector` (dispatch-counter keyed, no wall-clock).

The 16-tenant scenario at the bottom is the PR's acceptance criterion
verbatim: injected stuck-at faults plus one scripted dispatch exception,
zero deadline misses among healthy tenants, quarantine + re-program of
the faulted matrix within one flush interval, every future resolved.
"""
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.analog import AnalogConfig
from repro.core.nonideal import NonidealConfig
from repro.data.matrices import random_rhs, wishart
from repro.runtime import (ChaosInjector, DeviceFault, DispatchException,
                           DispatchLatency)
from repro.serve import (AsyncSolverEngine, BackpressureError,
                         DeadlineExceededError, EngineStoppedError,
                         SolverService)

KEY = jax.random.PRNGKey(5)
N = 16
CFG = AnalogConfig(array_size=8, nonideal=NonidealConfig(sigma=0.02))
# severe enough that no re-program key can pass the canary by luck
SEVERE = NonidealConfig(sigma=0.02, p_stuck_off=0.6, g_stuck_off=0.0)
# raw analog answers at sigma=0.02 carry ~0.1-0.2 relative residual; the
# engine health gate is calibrated against that, tests assert below 0.6
ANALOG_RES = 0.6


def _service():
    return SolverService(CFG, stages=1)


def _program(eng, mids):
    for i, mid in enumerate(mids):
        a = wishart(jax.random.fold_in(KEY, i), N)
        eng.program(mid, a, jax.random.fold_in(KEY, 100 + i))


def _rhs(i):
    return random_rhs(jax.random.fold_in(KEY, 1000 + i), N)


def _residual(svc, r, b):
    a = np.asarray(svc.dense(r.matrix_id))
    return float(np.linalg.norm(a @ r.x - np.asarray(b))
                 / np.linalg.norm(np.asarray(b)))


# ------------------------------ happy path --------------------------------

def test_happy_path_all_analog():
    svc = _service()
    eng = AsyncSolverEngine(svc, max_batch=4, flush_interval=0.02)
    _program(eng, ["m0", "m1"])
    with eng:
        subs = [("m%d" % (i % 2), _rhs(i)) for i in range(8)]
        futs = [(mid, b, eng.submit(mid, b, deadline_s=30.0))
                for mid, b in subs]
        for mid, b, f in futs:
            r = f.result(timeout=60)
            assert r.matrix_id == mid
            assert r.mode == "analog" and r.health == "healthy"
            assert not r.deadline_missed
            assert r.latency_s >= 0.0 and r.attempts >= 1
            assert _residual(svc, r, b) < ANALOG_RES
    assert eng.stats.answered == 8 and eng.stats.submitted == 8
    assert eng.stats.deadline_misses == 0
    assert eng.stats.quarantines == 0
    assert eng.pending() == 0


def test_program_after_start_routes_through_worker():
    eng = AsyncSolverEngine(_service(), max_batch=2, flush_interval=0.02)
    with eng:
        _program(eng, ["late"])       # worker-thread handoff, blocks til hot
        b = _rhs(0)
        r = eng.submit("late", b).result(timeout=60)
        assert r.mode == "analog"


def test_flush_now_forces_early_dispatch():
    eng = AsyncSolverEngine(_service(), max_batch=64, flush_interval=60.0)
    _program(eng, ["m0"])
    with eng:
        f = eng.submit("m0", _rhs(0))
        assert not f.done()
        eng.flush_now()
        f.result(timeout=60)          # without the flush this would sit 60s


# --------------------------- deadlines / SLOs -----------------------------

def test_expired_request_is_shed_with_typed_error():
    eng = AsyncSolverEngine(_service(), max_batch=64, flush_interval=60.0)
    _program(eng, ["m0"])
    with eng:
        f = eng.submit("m0", _rhs(0), deadline_s=-1.0)   # already dead
        with pytest.raises(DeadlineExceededError):
            f.result(timeout=60)
    assert eng.stats.expired == 1
    assert eng.stats.deadline_misses == 1
    assert eng.stats.answered == 0     # shed before compute


def test_late_answer_carries_deadline_missed():
    chaos = ChaosInjector([DispatchLatency(at_dispatch=0, seconds=0.4)])
    eng = AsyncSolverEngine(_service(), max_batch=1, flush_interval=0.01,
                            deadline_margin=0.0, chaos=chaos)
    _program(eng, ["m0"])
    with eng:
        # alive at dispatch time (0.2s out), but the scripted straggler
        # makes the answer land past it
        r = eng.submit("m0", _rhs(0), deadline_s=0.2).result(timeout=60)
    assert r.deadline_missed
    assert eng.stats.deadline_misses == 1 and eng.stats.expired == 0
    assert chaos.fired == 1


# ------------------------------ backpressure ------------------------------

def test_backpressure_rejects_with_retry_after():
    eng = AsyncSolverEngine(_service(), max_batch=64, flush_interval=60.0,
                            max_pending=4)
    _program(eng, ["m0"])
    with eng:
        futs = [eng.submit("m0", _rhs(i)) for i in range(4)]
        with pytest.raises(BackpressureError) as ei:
            eng.submit("m0", _rhs(99))
        assert ei.value.retry_after_s > 0.0
        assert eng.stats.rejected == 1
        # the admitted four still answer (stop drains)
    for f in futs:
        assert f.result(timeout=60).mode == "analog"
    assert eng.stats.answered == 4


# --------------------------- admission validation -------------------------

def test_submit_validation_is_front_door():
    eng = AsyncSolverEngine(_service(), max_batch=64, flush_interval=60.0)
    _program(eng, ["m0"])
    with pytest.raises(EngineStoppedError):
        eng.submit("m0", _rhs(0))               # not started yet
    with eng:
        with pytest.raises(KeyError):
            eng.submit("nope", _rhs(0))
        with pytest.raises(ValueError):
            eng.submit("m0", jnp.zeros((N, 2)))          # wrong shape
        with pytest.raises(ValueError):
            eng.submit("m0", np.arange(N))               # int dtype
        bad = np.ones(N)
        bad[3] = np.nan
        with pytest.raises(ValueError):
            eng.submit("m0", bad)                        # non-finite
        assert eng.pending() == 0 and eng.stats.submitted == 0


# ------------------------------ stop semantics ----------------------------

def test_stop_without_drain_voids_futures_typed():
    eng = AsyncSolverEngine(_service(), max_batch=64, flush_interval=60.0)
    _program(eng, ["m0"])
    eng.start()
    futs = [eng.submit("m0", _rhs(i)) for i in range(3)]
    eng.stop(drain=False, timeout=30)
    for f in futs:
        with pytest.raises(EngineStoppedError):
            f.result(timeout=60)
    with pytest.raises(EngineStoppedError):
        eng.submit("m0", _rhs(9))                # post-stop admission


def test_stop_with_drain_answers_leftovers():
    eng = AsyncSolverEngine(_service(), max_batch=64, flush_interval=60.0)
    _program(eng, ["m0"])
    eng.start()
    futs = [eng.submit("m0", _rhs(i)) for i in range(3)]
    eng.stop(drain=True, timeout=60)
    assert all(f.result(timeout=60).mode == "analog" for f in futs)


# ------------------------- retry / isolation paths ------------------------

def test_scripted_exception_absorbed_by_retry_ladder():
    chaos = ChaosInjector([DispatchException(at_dispatch=0)])
    eng = AsyncSolverEngine(_service(), max_batch=2, flush_interval=0.02,
                            retries=2, backoff=0.0, chaos=chaos)
    _program(eng, ["m0"])
    with eng:
        futs = [eng.submit("m0", _rhs(i)) for i in range(2)]
        for f in futs:
            assert f.result(timeout=60).mode == "analog"
    assert eng.stats.retries == 1
    assert eng.stats.isolations == 0             # retry fixed it in-pack
    assert chaos.fired == 1


def test_packed_failure_falls_back_to_isolation():
    # retries=0: the one scripted failure exhausts the packed ladder, the
    # engine isolates per matrix and both tenants still answer analog
    chaos = ChaosInjector([DispatchException(at_dispatch=0)])
    eng = AsyncSolverEngine(_service(), max_batch=2, flush_interval=0.02,
                            retries=0, chaos=chaos)
    _program(eng, ["m0", "m1"])
    with eng:
        fa = eng.submit("m0", _rhs(0))
        fb = eng.submit("m1", _rhs(1))
        assert fa.result(timeout=60).mode == "analog"
        assert fb.result(timeout=60).mode == "analog"
    assert eng.stats.isolations == 1
    assert eng.stats.quarantines == 0


# --------------------- quarantine / re-program / degrade ------------------

def test_device_fault_quarantines_reprograms_and_replays():
    chaos = ChaosInjector([
        DeviceFault(at_dispatch=1, matrix_id="m0", nonideal=SEVERE)])
    svc = _service()
    eng = AsyncSolverEngine(svc, max_batch=4, flush_interval=0.05,
                            chaos=chaos)
    _program(eng, ["m0", "m1"])
    with eng:
        # dispatch 0: healthy round
        r0 = [eng.submit(m, _rhs(i), deadline_s=60.0)
              for i, m in enumerate(["m0", "m0", "m1", "m1"])]
        for f in r0:
            assert f.result(timeout=120).reprograms == 0
        # dispatch 1: the fault lands on m0; canary trips; replay answers
        subs = [("m0", _rhs(10)), ("m0", _rhs(11)),
                ("m1", _rhs(12)), ("m1", _rhs(13))]
        r1 = [(m, b, eng.submit(m, b, deadline_s=120.0)) for m, b in subs]
        for m, b, f in r1:
            r = f.result(timeout=120)
            assert r.mode == "analog" and not r.deadline_missed
            assert r.reprograms == (1 if m == "m0" else 0)
            assert _residual(svc, r, b) < ANALOG_RES
    assert eng.stats.quarantines == 1
    assert eng.stats.reprograms == 1
    assert eng.stats.replays == 2                # m0's withheld pair
    assert eng.stats.degraded == 0
    assert len(eng.stats.recovery_s) == 1
    assert eng.matrix_status("m0") == "healthy"


def test_persistent_fault_degrades_to_digital_fallback():
    chaos = ChaosInjector([
        DeviceFault(at_dispatch=0, matrix_id="p0", nonideal=SEVERE,
                    persistent=True)])
    svc = _service()
    eng = AsyncSolverEngine(svc, max_batch=2, flush_interval=0.05,
                            max_reprograms=2, chaos=chaos)
    _program(eng, ["p0"])
    with eng:
        futs = [(b, eng.submit("p0", b)) for b in [_rhs(0), _rhs(1)]]
        for b, f in futs:
            r = f.result(timeout=120)
            assert r.mode == "digital" and r.health == "degraded"
            assert r.reprograms == 2
            # the digital fallback never touches the faulted arrays: tight
            assert _residual(svc, r, b) < 1e-4
        # second round: stays on the digital path, no re-quarantine churn
        f2 = [eng.submit("p0", _rhs(10 + i)) for i in range(2)]
        assert all(f.result(timeout=120).mode == "digital" for f in f2)
    assert eng.stats.quarantines == 1            # quarantined exactly once
    assert eng.stats.degraded == 1
    assert eng.stats.fallback_rhs == 4
    assert eng.matrix_status("p0") == "degraded"


def test_chaos_schedule_is_deterministic():
    """Same scripted schedule, same submissions -> identical firing log."""
    logs = []
    for _ in range(2):
        chaos = ChaosInjector([
            DeviceFault(at_dispatch=1, matrix_id="m0", nonideal=SEVERE),
            DispatchException(at_dispatch=2)])
        eng = AsyncSolverEngine(_service(), max_batch=2, flush_interval=5.0,
                                backoff=0.0, chaos=chaos)
        _program(eng, ["m0"])
        with eng:
            for rnd in range(2):
                fs = [eng.submit("m0", _rhs(10 * rnd + i)) for i in range(2)]
                for f in fs:
                    f.result(timeout=120)
        logs.append([(idx, type(ev).__name__) for idx, ev in chaos.log])
    assert logs[0] == logs[1]
    assert logs[0] == [(1, "DeviceFault"), (2, "DispatchException")]


# ----------------------- the acceptance scenario --------------------------

def test_sixteen_tenants_chaos_acceptance():
    """ISSUE acceptance: stuck-at faults + one scripted dispatch exception
    at 16 tenants -> zero deadline misses among healthy tenants, the
    faulted matrix quarantined and re-programmed within one flush
    interval, and every future resolves."""
    m = 16
    mids = ["t%02d" % i for i in range(m)]
    flush_interval = 5.0          # flushes are size-triggered (max_batch=m)
    chaos = ChaosInjector([
        DeviceFault(at_dispatch=1, matrix_id="t00", nonideal=SEVERE),
        DispatchException(at_dispatch=2)])
    svc = _service()
    eng = AsyncSolverEngine(svc, max_batch=m, flush_interval=flush_interval,
                            max_pending=4 * m, retries=2, backoff=0.0,
                            chaos=chaos)
    _program(eng, mids)
    with eng:
        # round 1 - dispatch 0, everyone healthy
        r1 = [(mid, eng.submit(mid, _rhs(i), deadline_s=120.0))
              for i, mid in enumerate(mids)]
        for mid, f in r1:
            assert f.result(timeout=240).mode == "analog"
        # round 2 - the fault lands on t00 before dispatch 1; the scripted
        # exception hits t00's replay (dispatch 2) for good measure
        r2 = [(mid, eng.submit(mid, _rhs(100 + i), deadline_s=120.0))
              for i, mid in enumerate(mids)]
        results = {mid: f.result(timeout=240) for mid, f in r2}   # all resolve
    healthy = [results[mid] for mid in mids if mid != "t00"]
    assert all(r.mode == "analog" and not r.deadline_missed
               for r in healthy)
    assert all(r.reprograms == 0 for r in healthy)
    faulted = results["t00"]
    assert not faulted.deadline_missed
    assert faulted.mode == "analog" and faulted.reprograms >= 1
    assert eng.stats.deadline_misses == 0        # zero, healthy or not
    assert eng.stats.quarantines == 1
    assert eng.matrix_status("t00") == "healthy"
    # "within one flush interval": recovery (quarantine -> re-program ->
    # healthy) completed in less wall time than the engine's flush period
    assert len(eng.stats.recovery_s) == 1
    assert eng.stats.recovery_s[0] < flush_interval
    assert [(i, type(e).__name__) for i, e in chaos.log] == [
        (1, "DeviceFault"), (2, "DispatchException")]


# ------------------------------ thread stress -----------------------------

def test_thread_stress_concurrent_submitters():
    """Concurrent submitters racing the worker: every future resolves
    within the timeout (a deadlock fails loudly here, not by hanging CI
    - `.result(timeout=...)` raises and `stop(timeout=...)` raises)."""
    eng = AsyncSolverEngine(_service(), max_batch=8, flush_interval=0.005,
                            max_pending=256)
    _program(eng, ["s0", "s1"])
    n_threads, per_thread = 4, 12
    results, errors = [], []
    lock = threading.Lock()

    def submitter(t):
        futs = []
        for i in range(per_thread):
            mid = "s%d" % ((t + i) % 2)
            while True:
                try:
                    futs.append(eng.submit(mid, _rhs(100 * t + i)))
                    break
                except BackpressureError as e:
                    time.sleep(min(e.retry_after_s, 0.05))
        for f in futs:
            try:
                r = f.result(timeout=120)
                with lock:
                    results.append(r)
            except Exception as e:                      # noqa: BLE001
                with lock:
                    errors.append(e)

    eng.start()
    try:
        threads = [threading.Thread(target=submitter, args=(t,))
                   for t in range(n_threads)]
        for th in threads:
            th.start()
        for th in threads:
            th.join(timeout=240)
            assert not th.is_alive(), "submitter thread hung"
    finally:
        eng.stop(drain=True, timeout=60)   # raises on worker deadlock
    assert not errors
    assert len(results) == n_threads * per_thread
    assert all(r.mode == "analog" for r in results)
    assert eng.stats.answered == n_threads * per_thread

"""Block-level repair equivalence (the maintenance contract, TESTING.md).

The splice invariant under test: re-programming ONLY a subset of a plan's
physical arrays under root key K (`repair_blocks` + `splice_finalized` +
`splice_arena`) must produce, for those arrays, exactly the values a FULL
re-program under K would - bit-for-bit on eager CPU - while every
untouched slice stays bit-for-bit what it was.  In particular repairing
*all* blocks under K is indistinguishable from `ProgrammedSolver
.program(a, K)` at the FlatPlan, FinalizedPlan AND ArenaPlan levels.

This is what makes block repair a safe maintenance primitive: a repaired
plan is never a third artifact to validate - it IS the re-programmed
plan, restricted to the degraded fraction (cost scales with #blocks, not
n^2 - benchmarks/maint_bench.py pins the ratio).

Hypothesis drives (stages, nonideality, subset seed) when installed; a
fixed parametrized sweep keeps tier-1 coverage without it (the
_hypothesis_compat degradation contract).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import blockamc
from repro.core.analog import AnalogConfig
from repro.core.nonideal import NonidealConfig
from repro.data.matrices import wishart
from tests._hypothesis_compat import HAVE_HYPOTHESIS, given, settings, st

KEY = jax.random.PRNGKey(7)
N = 16

NONIDEAL = {
    "sigma": NonidealConfig(sigma=0.05),
    "wire": NonidealConfig(sigma=0.02, r_wire=1.0,
                           wire_model="first_order",
                           compensate_wire=True, wv_iters=2),
    "faults": NonidealConfig(sigma=0.02, p_stuck_off=0.05,
                             g_stuck_off=0.0, remap_faults=True),
}


def _cfg(variant: str) -> AnalogConfig:
    return AnalogConfig(array_size=8, nonideal=NONIDEAL[variant],
                        opa_gain=1e4)


def _solver(a, key, cfg, stages):
    return blockamc.ProgrammedSolver.program(a, key, cfg, stages)


def _assert_grids_equal(g1, g2):
    assert len(g1) == len(g2)
    for x, y in zip(g1, g2):
        np.testing.assert_array_equal(np.asarray(x.gpos), np.asarray(y.gpos))
        np.testing.assert_array_equal(np.asarray(x.gneg), np.asarray(y.gneg))


def _assert_fin_equal(f1, f2):
    for l1, l2 in zip(f1.lu_stacks, f2.lu_stacks):
        np.testing.assert_array_equal(np.asarray(l1[0]), np.asarray(l2[0]))
        np.testing.assert_array_equal(np.asarray(l1[1]), np.asarray(l2[1]))
    assert len(f1.mvm_levels) == len(f2.mvm_levels)
    for lv1, lv2 in zip(f1.mvm_levels, f2.mvm_levels):
        assert len(lv1.stacks) == len(lv2.stacks)
        for s1, s2 in zip(lv1.stacks, lv2.stacks):
            np.testing.assert_array_equal(np.asarray(s1), np.asarray(s2))
        for d1, d2 in zip(lv1.divs, lv2.divs):
            np.testing.assert_array_equal(np.asarray(d1), np.asarray(d2))


def _assert_arena_equal(a1, a2):
    assert len(a1.stacks) == len(a2.stacks)
    for s1, s2 in zip(a1.stacks, a2.stacks):
        np.testing.assert_array_equal(np.asarray(s1), np.asarray(s2))


def _check_repair_equivalence(stages: int, variant: str, subset_seed: int):
    cfg = _cfg(variant)
    a = wishart(jax.random.fold_in(KEY, 11), N)
    k1 = jax.random.fold_in(KEY, 1)
    k2 = jax.random.fold_in(KEY, 2)
    old = _solver(a, k1, cfg, stages)
    ref = _solver(a, k2, cfg, stages)
    refs = [r.ref for r in old.block_map()]
    assert len(refs) == old.flat.num_arrays

    # 1. repairing EVERY block under k2 == full re-program under k2,
    #    bit-for-bit at all three plan levels
    full = old.repaired(refs, k2)
    _assert_grids_equal(full.flat.inv_stacks, ref.flat.inv_stacks)
    _assert_grids_equal(full.flat.mvm_stacks, ref.flat.mvm_stacks)
    _assert_fin_equal(full._fin, ref._fin)
    _assert_arena_equal(full.arena, ref.arena)

    # 2. a strict subset: repaired slices match the k2 plan exactly,
    #    untouched slices match the original k1 plan exactly
    rng = np.random.default_rng(subset_seed)
    k_sub = max(1, len(refs) // 3)
    subset = [refs[i] for i in
              sorted(rng.choice(len(refs), size=k_sub, replace=False))]
    part = old.repaired(subset, k2)
    chosen = set(subset)
    for kind, stacks, old_stacks, ref_stacks in (
            ("inv", part.flat.inv_stacks, old.flat.inv_stacks,
             ref.flat.inv_stacks),
            ("mvm", part.flat.mvm_stacks, old.flat.mvm_stacks,
             ref.flat.mvm_stacks)):
        for b, grid in enumerate(stacks):
            for i in range(grid.gpos.shape[0]):
                want = ref_stacks[b] if (kind, b, i) in chosen \
                    else old_stacks[b]
                np.testing.assert_array_equal(
                    np.asarray(grid.gpos[i]), np.asarray(want.gpos[i]))
                np.testing.assert_array_equal(
                    np.asarray(grid.gneg[i]), np.asarray(want.gneg[i]))

    # 3. the spliced executors agree with a from-scratch finalize of the
    #    spliced flat plan (the splice never invents numbers)
    refin = blockamc.finalize(part.flat, cfg)
    _assert_fin_equal(part._fin, refin)
    _assert_arena_equal(part.arena, blockamc.compile_arena(refin))


@pytest.mark.parametrize("stages", [1, 2])
@pytest.mark.parametrize("variant", sorted(NONIDEAL))
def test_repair_equivalence_sweep(stages, variant):
    _check_repair_equivalence(stages, variant, subset_seed=0)


@pytest.mark.skipif(not HAVE_HYPOTHESIS, reason="hypothesis not installed")
@settings(max_examples=12, deadline=None)
@given(stages=st.sampled_from([1, 2]),
       variant=st.sampled_from(sorted(NONIDEAL)),
       subset_seed=st.integers(min_value=0, max_value=2**16))
def test_repair_equivalence_property(stages, variant, subset_seed):
    _check_repair_equivalence(stages, variant, subset_seed)


def test_block_map_covers_plan():
    cfg = _cfg("sigma")
    solver = _solver(wishart(KEY, N), KEY, cfg, 2)
    recs = solver.block_map()
    assert len(recs) == solver.num_arrays
    assert len({r.ref for r in recs}) == len(recs)
    for rec in recs:
        kind, b, i = rec.ref
        stacks = (solver.flat.inv_stacks if kind == "inv"
                  else solver.flat.mvm_stacks)
        assert 0 <= b < len(stacks)
        assert 0 <= i < stacks[b].gpos.shape[0]
        assert stacks[b].gpos.shape[-2:] == rec.shape


def test_repair_unknown_block_raises():
    cfg = _cfg("sigma")
    solver = _solver(wishart(KEY, N), KEY, cfg, 1)
    with pytest.raises(KeyError):
        solver.repaired([("inv", 99, 0)], KEY)


def test_restored_solver_is_not_repairable():
    """A solver rebuilt from checkpointed plans (no flat plan / parts)
    refuses block repair with a ValueError - the caller falls back to a
    full re-program, never a silent no-op."""
    cfg = _cfg("sigma")
    solver = _solver(wishart(KEY, N), KEY, cfg, 1)
    bare = blockamc.ProgrammedSolver(solver._fin, solver._arena)
    assert not bare.repairable
    with pytest.raises(ValueError):
        bare.repaired([("inv", 0, 0)], KEY)

"""Multi-device tests run in subprocesses (XLA host-device-count must be set
before jax initialises): a small dry-run cell, sharded train step execution
on a host mesh, grad compression across a pod axis, elastic re-mesh restore.
"""
import json
import os
import subprocess
import sys

import pytest

# Production-mesh compiles and multi-host dry runs: the tier-1 'sharding'
# slow set (satellite of the level-scheduled-executor PR).
pytestmark = pytest.mark.slow

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_sub(code: str, n_devices: int = 8, timeout: int = 560) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=timeout)
    assert out.returncode == 0, out.stderr[-4000:]
    return out.stdout


def test_dryrun_cell_compiles_on_production_mesh():
    """One real dry-run cell: 512 fake devices, 16x16 mesh, decode shape."""
    out = run_sub("""
from repro.launch.dryrun import lower_cell
r = lower_cell('mamba2-130m', 'decode_32k')
assert r['n_chips'] == 256, r
assert r['flops_per_chip'] > 0
assert r['dominant'] is not None
print('OK', r['dominant'])
""", n_devices=512)
    assert "OK" in out


def test_sharded_train_step_executes():
    """Train step EXECUTES (not just compiles) on a 4x2 host mesh and
    matches the single-device loss."""
    out = run_sub("""
import dataclasses, jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs import get_config
from repro.configs.base import RunConfig
from repro.launch.mesh import make_host_mesh
from repro.optim.adamw import AdamW
from repro.sharding import api as shapi, partition
from repro.train.train_step import init_train_state, make_train_step
from repro.data.pipeline import SyntheticLM

cfg = dataclasses.replace(get_config('glm4-9b'), n_layers=2, d_model=64,
                          d_ff=128, vocab=512, n_heads=4, kv_heads=2,
                          head_dim=16, param_dtype='float32',
                          compute_dtype='float32')
run = RunConfig(model=cfg, mode='train', seq_len=32, global_batch=8,
                remat='dots', fsdp=True)
opt = AdamW(lr=1e-3)
state, _ = init_train_state(jax.random.PRNGKey(0), cfg, run, opt)
batch = SyntheticLM(cfg, run, seed=1).batch(0)
step = make_train_step(cfg, run, opt)

# single device reference
_, m_ref = jax.jit(step)(state, batch)

mesh = make_host_mesh(4, 2)
rules = partition.activation_rules(mesh, cfg, run)
with shapi.policy_scope(shapi.ShardingPolicy(mesh, rules)):
    state_sh = partition.make_state_shardings(
        jax.eval_shape(lambda: state), mesh, run.fsdp)
    state_p = jax.device_put(state, state_sh)
    batch_p = jax.device_put(batch, NamedSharding(mesh, P('data', None)))
    jitted = jax.jit(step, in_shardings=(state_sh, None),
                     out_shardings=(state_sh, None))
    new_state, metrics = jitted(state_p, batch_p)
np.testing.assert_allclose(float(metrics['loss']), float(m_ref['loss']),
                           rtol=1e-4)
print('OK sharded loss', float(metrics['loss']))
""", n_devices=8)
    assert "OK sharded" in out


def test_grad_compression_cross_pod():
    """compressed_psum over a 'pod' axis: result close to exact psum."""
    out = run_sub("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map
from repro.optim.grad_compression import compressed_psum, init_error_state

mesh = jax.make_mesh((2, 4), ('pod', 'data'))
g = jax.random.normal(jax.random.PRNGKey(0), (2, 256))   # per-pod grads

def f(g_local, err):
    total, new_err = compressed_psum({'g': g_local[0]}, 'pod', {'g': err[0]})
    return total['g'][None], new_err['g'][None]

fn = shard_map(f, mesh=mesh, in_specs=(P('pod'), P('pod')),
               out_specs=(P('pod'), P('pod')), check_rep=False)
err0 = jnp.zeros((2, 256))
total, err = fn(g, err0)
exact = jnp.sum(g, axis=0)
rel = float(jnp.linalg.norm(total[0] - exact) / jnp.linalg.norm(exact))
assert rel < 0.02, rel
print('OK compressed psum rel', rel)
""", n_devices=8)
    assert "OK compressed" in out


def test_elastic_remesh_restore(tmp_path):
    """Checkpoint on a 4x2 mesh, restore onto 2x2 (elastic downsize)."""
    out = run_sub(f"""
import dataclasses, jax, jax.numpy as jnp, numpy as np
from repro.checkpoint.ckpt import save_checkpoint, restore_checkpoint
from repro.configs import get_config
from repro.configs.base import RunConfig
from repro.launch.mesh import make_host_mesh
from repro.optim.adamw import AdamW
from repro.runtime.elastic import ElasticMesh
from repro.sharding import partition
from repro.train.train_step import init_train_state

cfg = dataclasses.replace(get_config('glm4-9b'), n_layers=2, d_model=64,
                          d_ff=128, vocab=512, n_heads=4, kv_heads=2,
                          head_dim=16, param_dtype='float32')
run = RunConfig(model=cfg, mode='train', seq_len=16, global_batch=4, fsdp=True)
opt = AdamW(lr=1e-3)
state, _ = init_train_state(jax.random.PRNGKey(0), cfg, run, opt)

mesh_a = make_host_mesh(4, 2)
sh_a = partition.make_state_shardings(jax.eval_shape(lambda: state), mesh_a, True)
state_a = jax.device_put(state, sh_a)
save_checkpoint({str(tmp_path)!r}, 3, state_a)

# elastic: 4 devices survive -> new 1x4 mesh (prefers the largest valid
# model axis), restore with new shardings
em = ElasticMesh()
assert em.choose_shape(4, model_divisors=(64,)) == (1, 4)
mesh_b = make_host_mesh(1, 4)
sh_b = partition.make_state_shardings(jax.eval_shape(lambda: state), mesh_b, True)
restored = restore_checkpoint({str(tmp_path)!r}, 3, state, sharding_tree=sh_b)
for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
print('OK elastic restore')
""", n_devices=8)
    assert "OK elastic" in out


def test_solver_cell_compiles_on_production_mesh():
    """The paper-technique cell: distributed BlockAMC lowered at 256 chips."""
    out = run_sub("""
from repro.launch.dryrun import lower_solver_cell
r = lower_solver_cell(n=2048, stages=1)
assert r['n_chips'] == 256
assert r['flops_per_chip'] > 0
print('OK solver', r['dominant'])
""", n_devices=512)
    assert "OK solver" in out


def test_train_cli_host_scale():
    """launch/train.py end to end at host scale (the CLI path)."""
    import subprocess
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.train", "--arch", "mamba2-130m",
         "--shape", "train_4k", "--steps", "5", "--host-scale"],
        env=env, capture_output=True, text=True, timeout=560)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "done: loss" in out.stderr or "done: loss" in out.stdout

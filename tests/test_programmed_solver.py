"""Finalization layer: ProgrammedSolver / FinalizedPlan vs the flat executor.

The reference-side contract (TESTING.md four-way contract, legs 1-3):
`finalize` precomputes exactly the operators `execute_flat` derives per
call - same LU factors, same per-tile effective matrices, same
accumulation order - so the finalized executor (mode="reference") matches
the flat one bit-for-bit on CPU when both run the schedule eagerly (and
the flat one in turn matches the recursive reference).  The jitted
reference path lets XLA merge each level's same-shape tile dots, which
reassociates final-ulp rounding only: float-tolerance equal.  The solver's
default mode="fused" arena executor (leg 4) is pinned in
tests/test_fused_arena.py.
"""
import os
import subprocess
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import blockamc
from repro.core.analog import AnalogConfig
from repro.core.nonideal import NonidealConfig
from repro.data.matrices import random_rhs, wishart
from repro.serve import SolverService

KEY = jax.random.PRNGKey(11)
KA, KB, KN = jax.random.split(KEY, 3)

# Acceptance grid: n in {8, 17, 64} x stages {0, 1, 2}, ragged splits
# included, for device variation, first-order wire model and finite
# OPA gain + 8-bit converter configs.
SIZES = (8, 17, 64)
STAGES = (0, 1, 2)
CFGS = [
    ("sigma", lambda n: AnalogConfig(
        array_size=max(n, 4), nonideal=NonidealConfig(sigma=0.05))),
    ("wire", lambda n: AnalogConfig(
        array_size=max(n // 2, 4),
        nonideal=NonidealConfig(sigma=0.05, r_wire=1.0))),
    ("gain_quant", lambda n: AnalogConfig(
        array_size=max(n // 2, 4), opa_gain=1e4, dac_bits=8, adc_bits=8)),
]


@pytest.mark.parametrize("n", SIZES)
@pytest.mark.parametrize("stages", STAGES)
@pytest.mark.parametrize("tag,make_cfg", CFGS)
def test_finalized_matches_flat_bitwise(n, stages, tag, make_cfg):
    cfg = make_cfg(n)
    a = wishart(KA, n)
    b = random_rhs(KB, n)
    fplan = blockamc.compile_plan(blockamc.build_plan(a, KN, cfg,
                                                      stages=stages))
    x_flat = blockamc.execute_flat(fplan, b, cfg)
    solver = blockamc.ProgrammedSolver.from_plan(fplan, cfg)
    x_fin = solver.solve(b, jit=False, mode="reference")
    if jax.default_backend() == "cpu":
        # precomputed operators == per-call derivations, op order identical
        np.testing.assert_array_equal(np.asarray(x_flat), np.asarray(x_fin))
    else:
        np.testing.assert_allclose(np.asarray(x_flat), np.asarray(x_fin),
                                   rtol=1e-6, atol=1e-6)
    # jitted reference path: XLA dot merging reassociates last-ulp only
    x_jit = solver.solve(b, mode="reference")
    np.testing.assert_allclose(np.asarray(x_flat), np.asarray(x_jit),
                               rtol=1e-5, atol=1e-6)


def test_finalized_multi_rhs_bitwise_and_shapes():
    n, stages, k = 32, 2, 8
    cfg = AnalogConfig(array_size=8, nonideal=NonidealConfig(sigma=0.05))
    a = wishart(KA, n)
    bs = jax.random.normal(KB, (n, k))
    fplan = blockamc.compile_plan(blockamc.build_plan(a, KN, cfg,
                                                      stages=stages))
    solver = blockamc.ProgrammedSolver.from_plan(fplan, cfg)
    xs_fin = solver.solve(bs, jit=False, mode="reference")
    assert xs_fin.shape == (n, k)
    np.testing.assert_array_equal(
        np.asarray(blockamc.execute_flat(fplan, bs, cfg)),
        np.asarray(xs_fin))
    np.testing.assert_allclose(
        np.asarray(solver.solve_many(bs, mode="reference")),
        np.asarray(xs_fin), rtol=1e-5, atol=1e-6)
    # the serving-default fused path solves the same system (float tol;
    # pinned more tightly in tests/test_fused_arena.py)
    np.testing.assert_allclose(np.asarray(solver.solve_many(bs)),
                               np.asarray(xs_fin), rtol=2e-4, atol=2e-5)


def test_programmed_solver_program_endtoend():
    """program() == build + compile + finalize; solves the system."""
    n = 24
    cfg = AnalogConfig(array_size=8)
    a = wishart(KA, n)
    b = random_rhs(KB, n)
    solver = blockamc.ProgrammedSolver.program(a, KN, cfg)
    assert solver.n == n
    assert solver.cfg is cfg
    x = solver.solve(b)
    np.testing.assert_allclose(np.asarray(x),
                               np.asarray(jnp.linalg.solve(a, b)),
                               rtol=1e-3, atol=1e-4)


def test_finalized_plan_is_pytree():
    """FinalizedPlan jits as an argument and round-trips flatten/unflatten."""
    cfg = AnalogConfig(array_size=8, nonideal=NonidealConfig(sigma=0.05))
    a = wishart(KA, 16)
    b = random_rhs(KB, 16)
    fin = blockamc.finalize(blockamc.build_flat_plan(a, KN, cfg, 1), cfg)
    leaves, treedef = jax.tree_util.tree_flatten(fin)
    fin2 = jax.tree_util.tree_unflatten(treedef, leaves)
    np.testing.assert_array_equal(
        np.asarray(blockamc.execute_finalized(fin, b)),
        np.asarray(blockamc.execute_finalized(fin2, b)))
    hash(treedef)   # schedule/cfg aux must stay hashable for the jit cache


def test_partition_program_split_matches_fused_build():
    """partition_system + program_system == build_plan (same noise draws)."""
    n = 33
    cfg = AnalogConfig(array_size=16, nonideal=NonidealConfig(sigma=0.05))
    a = wishart(KA, n)
    b = random_rhs(KB, n)
    parts = blockamc.partition_system(a, cfg, stages=2)
    plan_split = blockamc.program_system(parts, KN, cfg)
    plan_fused = blockamc.build_plan(a, KN, cfg, stages=2)
    np.testing.assert_array_equal(
        np.asarray(blockamc.execute(plan_split, b, cfg)),
        np.asarray(blockamc.execute(plan_fused, b, cfg)))


def test_solve_batched_sharded_matches_batched():
    """shard_map path (1-device mesh here) == plain vmapped solve_batched."""
    from repro.launch.mesh import make_mc_mesh
    n = 32
    cfg = AnalogConfig(array_size=16, nonideal=NonidealConfig(sigma=0.05))
    a = wishart(KA, n)
    b = random_rhs(KB, n)
    keys = jax.random.split(KN, 4)
    xs_b = blockamc.solve_batched(a, b, keys, cfg, stages=1)
    xs_s = blockamc.solve_batched_sharded(a, b, keys, cfg, stages=1,
                                          mesh=make_mc_mesh(1))
    np.testing.assert_allclose(np.asarray(xs_s), np.asarray(xs_b),
                               rtol=1e-5, atol=1e-6)
    # (the num_keys divisibility error needs a >1-device mesh; covered by
    # the slow multi-device subprocess test below)


@pytest.mark.slow
def test_solve_batched_sharded_multidevice():
    """Key axis genuinely sharded over 4 host devices (subprocess: XLA
    device count must be set before jax initialises)."""
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = os.path.join(repo, "src")
    code = """
import jax, jax.numpy as jnp
from repro.core import blockamc
from repro.core.analog import AnalogConfig
from repro.core.nonideal import NonidealConfig
from repro.data.matrices import wishart, random_rhs
ka, kb, kn = jax.random.split(jax.random.PRNGKey(1), 3)
a = wishart(ka, 32); b = random_rhs(kb, 32)
cfg = AnalogConfig(array_size=16, nonideal=NonidealConfig(sigma=0.05))
keys = jax.random.split(kn, 8)
xs_b = blockamc.solve_batched(a, b, keys, cfg, stages=1)
xs_s = blockamc.solve_batched_sharded(a, b, keys, cfg, stages=1)
assert jnp.allclose(xs_s, xs_b, rtol=1e-5, atol=1e-6)
print('OK', xs_s.shape)
"""
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=560)
    assert out.returncode == 0, out.stderr[-4000:]
    assert "OK" in out.stdout


def test_solver_service_batches_submitted_rhs():
    n = 32
    cfg = AnalogConfig(array_size=16, nonideal=NonidealConfig(sigma=0.05))
    svc = SolverService(cfg, stages=1)
    a = wishart(KA, n)
    svc.program("gram0", a, KN)
    solver = svc.solver("gram0")

    # flush solves every queued rhs exactly like individual solves
    cols = [jax.random.normal(jax.random.fold_in(KB, j), (n,))
            for j in range(5)]
    for b in cols:
        svc.submit("gram0", b)
    assert svc.pending("gram0") == 5
    xs = svc.flush("gram0")
    assert xs.shape == (n, 5) and svc.pending("gram0") == 0
    for j, b in enumerate(cols):
        np.testing.assert_allclose(np.asarray(xs[:, j]),
                                   np.asarray(solver.solve(b)),
                                   rtol=1e-5, atol=1e-6)

    # empty flush, immediate solve, stats accounting
    assert svc.flush("gram0").shape == (n, 0)
    svc.solve("gram0", cols[0])
    st = svc.stats("gram0")
    assert st.rhs_served == 6 and st.solve_calls == 2
    assert st.program_time_s > 0
    with pytest.raises(ValueError, match="rhs"):
        svc.submit("gram0", jnp.zeros((n, 2)))
    with pytest.raises(ValueError, match="rhs"):
        svc.submit("gram0", jnp.zeros((n + 1,)))   # wrong length, right ndim
    # a failing flush must not drop queued requests
    svc.submit("gram0", cols[0])
    assert svc.pending("gram0") == 1
    # re-programming over pending requests must refuse, not drop them
    with pytest.raises(RuntimeError, match="pending"):
        svc.program("gram0", a, KN)
    xs = svc.flush("gram0")
    assert xs.shape == (n, 1)


@pytest.mark.slow
def test_programmed_solver_amortizes_256_two_stage():
    """End-to-end amortization guard: after programming a 256^2 two-stage
    solver, the marginal cost of the 64th streamed rhs must be far below
    the time-to-first-solve (catches silent re-tracing/re-factorizing)."""
    n = 256
    cfg = AnalogConfig(array_size=64, nonideal=NonidealConfig(sigma=0.05))
    a = wishart(KA, n)

    t0 = time.perf_counter()
    solver = blockamc.ProgrammedSolver.program(a, KN, cfg, stages=2)
    jax.block_until_ready(solver.solve(random_rhs(KB, n)))
    ttfs = time.perf_counter() - t0

    marginal = float("inf")
    for j in range(64):
        b = jax.random.normal(jax.random.fold_in(KB, j), (n,))
        t0 = time.perf_counter()
        jax.block_until_ready(solver.solve(b))
        dt = time.perf_counter() - t0
        if j == 63:
            marginal = dt
    # programming includes plan build + finalize + jit compile (seconds);
    # a marginal solve is sub-ms.  20x leaves headroom for CPU noise while
    # still failing instantly if solve() re-traces or re-factorizes.
    assert marginal * 20 < ttfs, (marginal, ttfs)

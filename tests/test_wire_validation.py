"""Differential validation: first-order wire model vs the nodal oracle.

The serving hot path prices IR drop with the O(n^2) first-order
perturbation (`nonideal.effective_conductance`); the physics subsystem
provides the exact nodal answer (`repro.physics.nodal`).  This suite pins
the cheap model's error *envelope* against the oracle across array size n
and wire resistance r, so any future change to either model that moves
the gap gets caught.

Measured gap (‖H_fo − H‖ / ‖H − g‖, i.e. error relative to the wire
effect itself, dense uniform targets at half scale):

      n \\ r    0.25      1.0      2.0
        8     0.0005   0.0021   0.0042
       16     0.0011   0.0044   0.0113
       32     0.0052   0.0218   0.0388
       64     0.0185   0.0617   0.1202

The envelope asserts ~2x these values; the monotone tests pin the shape
(gap grows with both n and r — the first-order expansion in r·g·n leaves
its validity region as arrays scale, the reason fig9's oracle sweep runs
the nodal model at n >= 64).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.experimental import enable_x64

from repro.core import blockamc, nonideal
from repro.core.analog import AnalogConfig
from repro.core.nonideal import NonidealConfig
from repro.data.matrices import wishart
from repro.physics import nodal_effective_conductance

G0 = 100e-6


def _gap_and_effect(n, r_wire, seed=0):
    """Returns (‖H_fo − H‖/‖H − g‖, ‖H − g‖/‖g‖) in float64."""
    rng = np.random.default_rng(seed)
    g_np = rng.uniform(0.0, 0.5, (n, n)) * G0
    with enable_x64():
        g = jnp.asarray(g_np, dtype=jnp.float64)
        h = nodal_effective_conductance(g, r_wire)
        h_fo = nonideal.effective_conductance(g, r_wire)
        effect = float(jnp.linalg.norm(h - g))
        gap = float(jnp.linalg.norm(h_fo - h))
        return gap / effect, effect / float(jnp.linalg.norm(g))


@pytest.mark.parametrize("n,r_wire,bound", [
    (8, 0.25, 1e-3), (8, 1.0, 5e-3), (8, 2.0, 1e-2),
    (16, 1.0, 1e-2), (16, 2.0, 2.5e-2),
    (32, 1.0, 5e-2), (32, 2.0, 8e-2),
])
def test_first_order_gap_envelope(n, r_wire, bound):
    gap, _ = _gap_and_effect(n, r_wire)
    assert gap < bound


def test_gap_grows_with_array_size():
    gaps = [_gap_and_effect(n, 1.0)[0] for n in (8, 16, 32)]
    assert all(a < b for a, b in zip(gaps, gaps[1:]))


def test_gap_grows_with_wire_resistance():
    gaps = [_gap_and_effect(16, r)[0] for r in (0.25, 1.0, 2.0)]
    assert all(a < b for a, b in zip(gaps, gaps[1:]))


def test_wire_effect_itself_is_significant():
    """Sanity anchor: the quantity the models disagree about is not noise —
    at n=32, r=1 the wire effect moves H by ~2% of ‖g‖."""
    _, effect = _gap_and_effect(32, 1.0)
    assert effect > 5e-3


@pytest.mark.slow
def test_first_order_leaves_validity_at_n64():
    """At n=64 the cheap model's error reaches >3% of the wire effect at
    r=1 and ~12% at r=2 — the regime fig9's nightly oracle sweep covers."""
    gap1, _ = _gap_and_effect(64, 1.0)
    gap2, _ = _gap_and_effect(64, 2.0)
    assert 0.03 < gap1 < 0.12
    assert 0.06 < gap2 < 0.25
    assert gap1 < gap2


# ---------------------- solver-level recalibration --------------------------

def test_solver_error_first_order_vs_nodal():
    """fig9 recalibration at solve level: inside the validity envelope
    (n=32 tiled to 16x16 arrays, r=1) pricing wires with the cheap model
    vs the oracle must give nearly the same end-to-end solve error
    (calibrated 2.523e-3 vs 2.520e-3)."""
    a = wishart(jax.random.PRNGKey(0), 32)
    b = jax.random.normal(jax.random.PRNGKey(1), (32,))
    x_ref = jnp.linalg.solve(a, b)
    errs = {}
    for model in ("first_order", "nodal"):
        ni = NonidealConfig(r_wire=1.0, wire_model=model)
        cfg = AnalogConfig(array_size=16, nonideal=ni)
        x = blockamc.solve(a, b, jax.random.PRNGKey(2), cfg, stages=1)
        errs[model] = float(jnp.linalg.norm(x - x_ref)
                            / jnp.linalg.norm(x_ref))
    assert errs["nodal"] > 1e-4            # wires actually in play
    assert abs(errs["first_order"] - errs["nodal"]) < 0.2 * errs["nodal"]


def test_wire_model_none_disables_wires():
    """wire_model='none' must ignore r_wire entirely (control for the
    differential suite: the gap measured above comes from the wire model,
    not from programming noise)."""
    a = wishart(jax.random.PRNGKey(0), 32)
    b = jax.random.normal(jax.random.PRNGKey(1), (32,))
    ni_off = NonidealConfig(r_wire=1.0, wire_model="none")
    ni_zero = NonidealConfig(r_wire=0.0)
    cfg_off = AnalogConfig(array_size=16, nonideal=ni_off)
    cfg_zero = AnalogConfig(array_size=16, nonideal=ni_zero)
    x_off = blockamc.solve(a, b, jax.random.PRNGKey(2), cfg_off, stages=1)
    x_zero = blockamc.solve(a, b, jax.random.PRNGKey(2), cfg_zero, stages=1)
    np.testing.assert_allclose(np.asarray(x_off), np.asarray(x_zero),
                               rtol=1e-6)

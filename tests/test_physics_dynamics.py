"""Device dynamics and stuck-at faults, unit level and end to end.

Companion to tests/test_physics_oracle.py: that file pins the nodal wire
solver against the dense MNA oracle; this one covers the *device* half of
the physics subsystem — write-verify programming, retention drift, and
stuck-at fault injection with fault-aware remapping — including full
campaigns through `ProgrammedSolver.solve` so every knob is exercised on
the exact path the serving stack uses.

Margins are calibrated (not aspirational): e.g. at p_stuck_off = 2% on a
16x16-tiled n=32 Wishart solve, the no-remap relative error is 0.04-0.85
across seeds while remapping drives it below 1e-6, because every
stuck-OFF fault can be routed onto an exact-zero differential target.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import blockamc, nonideal
from repro.core.analog import AnalogConfig
from repro.core.nonideal import NonidealConfig
from repro.data.matrices import wishart
from repro.physics import (apply_stuck_faults, drift_conductance,
                           fault_aware_permutations, nodal_effective_conductance,
                           sample_stuck_masks, write_verify)

G0 = 100e-6


def _solve_err(a, b, cfg, seed=0, stages=1):
    x_ref = jnp.linalg.solve(a, b)
    x = blockamc.solve(a, b, jax.random.PRNGKey(seed), cfg, stages=stages)
    return float(jnp.linalg.norm(x - x_ref) / jnp.linalg.norm(x_ref))


def _diff_target(n, seed):
    """gpos of a signed matrix: ~half the entries are exact zeros."""
    a = wishart(jax.random.PRNGKey(seed), n)
    return jnp.maximum(a / jnp.max(jnp.abs(a)), 0.0) * G0


# --------------------------- fault unit tests -------------------------------

def test_stuck_masks_disjoint_and_rates():
    on, off = sample_stuck_masks(jax.random.PRNGKey(0), (400, 400),
                                 0.05, 0.10)
    assert not bool(jnp.any(on & off))
    assert abs(float(jnp.mean(on)) - 0.05) < 0.01
    assert abs(float(jnp.mean(off)) - 0.10) < 0.01


def test_fault_permutations_are_valid():
    g = _diff_target(16, 3)
    on, off = sample_stuck_masks(jax.random.PRNGKey(1), g.shape, 0.02, 0.05)
    p, q = fault_aware_permutations(g, on, off, G0, 0.0)
    np.testing.assert_array_equal(np.sort(np.asarray(p)), np.arange(16))
    np.testing.assert_array_equal(np.sort(np.asarray(q)), np.arange(16))


def test_no_faults_is_identity():
    g = _diff_target(8, 5)
    for remap in (False, True):
        gf = apply_stuck_faults(g, g, jax.random.PRNGKey(2),
                                p_on=0.0, p_off=0.0, g_on=G0, g_off=0.0,
                                remap=remap)
        np.testing.assert_array_equal(np.asarray(gf), np.asarray(g))


def test_remap_routes_stuck_off_onto_zero_targets():
    """Differential targets have exact zeros; with remap every stuck-OFF
    fault should land on one, making the stamped error (essentially) zero."""
    for s in range(3):
        g = _diff_target(16, 10 + s)
        errs = {}
        for remap in (False, True):
            gf = apply_stuck_faults(g, g, jax.random.PRNGKey(40 + s),
                                    p_on=0.0, p_off=0.05, g_on=G0,
                                    g_off=0.0, remap=remap)
            errs[remap] = float(jnp.linalg.norm(gf - g) / jnp.linalg.norm(g))
        assert errs[False] > 5e-3          # faults really landed somewhere
        assert errs[True] < 1e-8 * max(1.0, errs[False] / 1e-8)
        assert errs[True] < 1e-6


def test_remap_improves_mixed_fault_error():
    """Stuck-ON faults (full-scale G0) can't always hide, but per-entry
    matching must still beat the unmapped placement on Frobenius error."""
    for s in range(3):
        g = _diff_target(16, 10 + s)
        errs = {}
        for remap in (False, True):
            gf = apply_stuck_faults(g, g, jax.random.PRNGKey(40 + s),
                                    p_on=0.01, p_off=0.05, g_on=G0,
                                    g_off=0.0, remap=remap)
            errs[remap] = float(jnp.linalg.norm(gf - g) / jnp.linalg.norm(g))
        assert errs[True] < 0.9 * errs[False]


def test_faults_batched_matches_per_tile():
    """The (..., r, c) vmap path must equal per-tile application with the
    same split keys (packed-serving stacks rely on this)."""
    g = jnp.stack([_diff_target(8, s) for s in range(3)])
    key = jax.random.PRNGKey(7)
    batched = apply_stuck_faults(g, g, key, p_on=0.01, p_off=0.05,
                                 g_on=G0, g_off=0.0, remap=True)
    keys = jax.random.split(key, 3)
    for i in range(3):
        single = apply_stuck_faults(g[i], g[i], keys[i], p_on=0.01,
                                    p_off=0.05, g_on=G0, g_off=0.0,
                                    remap=True)
        np.testing.assert_allclose(np.asarray(batched[i]),
                                   np.asarray(single), rtol=1e-6)


# --------------------- e2e stuck-at campaign (satellite) --------------------

def test_remap_recovers_solve_accuracy():
    """Stuck-at campaign through ProgrammedSolver.solve: at 2% stuck-OFF
    devices, fault-aware remapping recovers essentially fault-free accuracy
    while the unmapped solver is off by several percent or worse."""
    errs = {False: [], True: []}
    for s in range(4):
        a = wishart(jax.random.PRNGKey(100 + s), 32)
        b = jax.random.normal(jax.random.PRNGKey(200 + s), (32,))
        x_ref = jnp.linalg.solve(a, b)
        for remap in (False, True):
            ni = NonidealConfig(p_stuck_off=0.02, remap_faults=remap)
            cfg = AnalogConfig(array_size=16, nonideal=ni)
            ps = blockamc.ProgrammedSolver.program(
                a, jax.random.PRNGKey(300 + s), cfg, stages=1)
            x = ps.solve(b)
            errs[remap].append(float(jnp.linalg.norm(x - x_ref)
                                     / jnp.linalg.norm(x_ref)))
    for e_plain, e_remap in zip(errs[False], errs[True]):
        assert e_plain > 0.01              # faults visibly hurt every seed
        assert e_remap < 1e-3              # remap recovers every seed
    assert np.median(errs[True]) < 0.05 * np.median(errs[False])


# ------------------------------ drift ---------------------------------------

def test_drift_unit_power_law():
    g = _diff_target(8, 0)
    np.testing.assert_allclose(
        np.asarray(drift_conductance(g, 100.0, 0.1)),
        np.asarray(g) * 100.0 ** -0.1, rtol=1e-12)
    # identity cases: no elapsed time, t0 itself, or nu = 0
    for t, nu in ((0.0, 0.1), (1.0, 0.1), (100.0, 0.0)):
        np.testing.assert_array_equal(
            np.asarray(drift_conductance(g, t, nu)), np.asarray(g))


def test_drift_error_grows_monotonically():
    """Retention drift at readout: solve error must grow monotonically in
    elapsed time (calibrated: ~1e-7 at t=0 up to ~0.4 at t=1000 s)."""
    a = wishart(jax.random.PRNGKey(0), 32)
    b = jax.random.normal(jax.random.PRNGKey(1), (32,))
    errs = []
    for t in (0.0, 10.0, 100.0, 1000.0):
        ni = NonidealConfig(drift_t=t, drift_nu=0.05)
        cfg = AnalogConfig(array_size=16, nonideal=ni)
        errs.append(_solve_err(a, b, cfg))
    assert errs[0] < 1e-4                  # no drift -> quantization floor
    assert errs[1] > 1e-2                  # drift visibly hurts
    assert all(e1 < e2 for e1, e2 in zip(errs, errs[1:]))


# --------------------------- write-verify -----------------------------------

def test_write_verify_nodal_converges():
    """Fixed-point write-verify against the nodal oracle: three iterations
    buy >= 1e4x residual reduction at n=16, r_wire=1 (calibrated 1.3e-2 at
    one iteration down to 2.8e-6 at three, 6e-10 at five)."""
    g_t = _diff_target(16, 2)
    base = float(jnp.linalg.norm(
        nodal_effective_conductance(g_t, 1.0) - g_t) / jnp.linalg.norm(g_t))
    res = {}
    for iters in (1, 3, 5):
        g = write_verify(g_t, 1.0, model="nodal", iters=iters)
        assert bool(jnp.all(g >= 0.0))     # physical conductances only
        res[iters] = float(jnp.linalg.norm(
            nodal_effective_conductance(g, 1.0) - g_t)
            / jnp.linalg.norm(g_t))
    assert res[3] < 1e-3 * base
    assert res[5] <= res[3]               # may tie at the f32 floor


def test_write_verify_rejects_unknown_model():
    with pytest.raises(ValueError):
        write_verify(_diff_target(4, 0), 1.0, model="exact")


def test_config_write_verify_e2e():
    """compensate_wire + nodal write-verify through the full solver: the
    compensated solve lands ~1e4x below the uncompensated wire error
    (calibrated 2.3e-7 vs 2.5e-3 at n=32, r_wire=1)."""
    a = wishart(jax.random.PRNGKey(0), 32)
    b = jax.random.normal(jax.random.PRNGKey(1), (32,))
    ni_raw = NonidealConfig(r_wire=1.0, wire_model="nodal")
    ni_wv = NonidealConfig(r_wire=1.0, wire_model="nodal",
                           compensate_wire=True, wv_iters=3)
    err_raw = _solve_err(a, b, AnalogConfig(array_size=16, nonideal=ni_raw))
    err_wv = _solve_err(a, b, AnalogConfig(array_size=16, nonideal=ni_wv))
    assert err_raw > 1e-3                  # wires visibly hurt uncompensated
    assert err_wv < 1e-4
    assert err_wv < 1e-2 * err_raw


# ------------------- executor equivalence under physics ---------------------

def test_reference_vs_fused_under_physics_config():
    """The four-executor contract survives the physics pipeline: reference
    and fused-arena executors agree under nodal readout + drift, because
    both consume the same programmed state through a_eff."""
    a = wishart(jax.random.PRNGKey(0), 32)
    b = jax.random.normal(jax.random.PRNGKey(1), (32,))
    ni = NonidealConfig(sigma=0.01, r_wire=1.0, wire_model="nodal",
                        drift_t=100.0, drift_nu=0.05)
    cfg = AnalogConfig(array_size=16, nonideal=ni)
    ps = blockamc.ProgrammedSolver.program(a, jax.random.PRNGKey(2), cfg,
                                           stages=1)
    x_ref = ps.solve(b, mode="reference")
    x_fus = ps.solve(b, mode="fused")
    np.testing.assert_allclose(np.asarray(x_fus), np.asarray(x_ref),
                               rtol=1e-5, atol=1e-9)

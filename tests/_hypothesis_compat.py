"""Optional-hypothesis shim for the property-based test modules.

`hypothesis` is a dev-only dependency (requirements-dev.txt).  When it is
missing, importing it at module top used to abort collection of four whole
test modules - including their plain pytest tests.  Import `given`,
`settings` and `st` from here instead:

    from tests._hypothesis_compat import given, settings, st

With hypothesis installed this re-exports the real API unchanged.  Without
it, `@given(...)` marks the test as skipped (reason: hypothesis not
installed) and the strategy/settings stand-ins accept any arguments, so the
suite degrades to skips instead of collection errors and every
non-property test still runs.
"""
from __future__ import annotations

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - CI installs hypothesis
    import pytest

    HAVE_HYPOTHESIS = False

    def given(*_args, **_kwargs):
        return pytest.mark.skip(reason="hypothesis not installed")

    def settings(*_args, **_kwargs):
        def deco(fn):
            return fn
        return deco

    class _AnyStrategy:
        """Stand-in accepted by the `given` stub; never generates values."""

        def __init__(self, name):
            self._name = name

        def __repr__(self):
            return f"<stub strategy {self._name}>"

    class st:  # noqa: N801 - mirrors `strategies as st`
        @staticmethod
        def integers(*_a, **_k):
            return _AnyStrategy("integers")

        @staticmethod
        def floats(*_a, **_k):
            return _AnyStrategy("floats")

        @staticmethod
        def sampled_from(*_a, **_k):
            return _AnyStrategy("sampled_from")

        @staticmethod
        def booleans(*_a, **_k):
            return _AnyStrategy("booleans")

        @staticmethod
        def lists(*_a, **_k):
            return _AnyStrategy("lists")

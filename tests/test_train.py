"""Training-loop behaviour: learning, microbatch equivalence, ckpt/restart."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.ckpt import (CheckpointManager, latest_step,
                                   restore_checkpoint, save_checkpoint)
from repro.configs import get_config
from repro.configs.base import RunConfig
from repro.data.pipeline import SyntheticLM
from repro.optim.adamw import AdamW
from repro.train.train_step import init_train_state, make_train_step
from repro.train.trainer import Trainer
from tests.conftest import reduce_cfg


def _run_cfg(cfg, **kw):
    base = dict(mode="train", seq_len=32, global_batch=4, remat="dots")
    base.update(kw)
    return RunConfig(model=cfg, **base)


def test_loss_decreases(tiny_dense):
    run = _run_cfg(tiny_dense)
    trainer = Trainer(tiny_dense, run, seed=0, log_every=1000)
    hist = trainer.run(30)
    first5 = np.mean(hist["loss"][:5])
    last5 = np.mean(hist["loss"][-5:])
    assert last5 < first5 - 0.1, (first5, last5)


@pytest.mark.slow
def test_microbatch_equivalence(tiny_dense):
    """4 microbatches must produce (nearly) the same update as 1 big batch."""
    cfg = tiny_dense
    opt = AdamW(lr=1e-3)
    run1 = _run_cfg(cfg)
    run4 = _run_cfg(cfg, microbatch=1)
    state, _ = init_train_state(jax.random.PRNGKey(0), cfg, run1, opt)
    batch = SyntheticLM(cfg, run1, seed=3).batch(0)
    s1, m1 = jax.jit(make_train_step(cfg, run1, opt))(state, batch)
    s4, m4 = jax.jit(make_train_step(cfg, run4, opt))(state, batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m4["loss"]),
                               rtol=1e-4)
    for a, b in zip(jax.tree.leaves(s1.params), jax.tree.leaves(s4.params)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=5e-3, atol=5e-5)


def test_checkpoint_roundtrip(tiny_dense, tmp_path):
    run = _run_cfg(tiny_dense)
    opt = AdamW(lr=1e-3)
    state, _ = init_train_state(jax.random.PRNGKey(0), tiny_dense, run, opt)
    save_checkpoint(str(tmp_path), 7, state)
    assert latest_step(str(tmp_path)) == 7
    restored = restore_checkpoint(str(tmp_path), 7, state)
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_atomicity(tiny_dense, tmp_path):
    """A .tmp dir from a crashed save must not be visible as a checkpoint."""
    run = _run_cfg(tiny_dense)
    opt = AdamW(lr=1e-3)
    state, _ = init_train_state(jax.random.PRNGKey(0), tiny_dense, run, opt)
    save_checkpoint(str(tmp_path), 1, state)
    os.makedirs(str(tmp_path / "step_00000002.tmp"))
    assert latest_step(str(tmp_path)) == 1


@pytest.mark.slow
def test_trainer_resume(tiny_dense, tmp_path):
    """Kill after N steps; a new Trainer resumes from the checkpoint."""
    run = _run_cfg(tiny_dense)
    t1 = Trainer(tiny_dense, run, ckpt_dir=str(tmp_path), ckpt_every=5,
                 log_every=1000)
    t1.run(10)
    assert latest_step(str(tmp_path)) == 10
    t2 = Trainer(tiny_dense, run, ckpt_dir=str(tmp_path), ckpt_every=5,
                 log_every=1000)
    assert t2.start_step == 10
    # resumed state equals the state that was checkpointed
    for a, b in zip(jax.tree.leaves(t1.state.params),
                    jax.tree.leaves(t2.state.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    hist = t2.run(3)
    assert hist["step"] == [10, 11, 12]


def test_checkpoint_manager_async(tiny_dense, tmp_path):
    run = _run_cfg(tiny_dense)
    opt = AdamW(lr=1e-3)
    state, _ = init_train_state(jax.random.PRNGKey(0), tiny_dense, run, opt)
    mgr = CheckpointManager(str(tmp_path), every=2, keep=2)
    for step in range(1, 9):
        mgr.maybe_save(step, state)
    mgr.wait()
    # keep=2: only the last two checkpoints survive gc
    steps = sorted(int(n.split("_")[1]) for n in os.listdir(str(tmp_path))
                   if n.startswith("step_"))
    assert steps == [6, 8]


def test_data_pipeline_determinism(tiny_dense):
    run = _run_cfg(tiny_dense)
    d1 = SyntheticLM(tiny_dense, run, seed=5).batch(3)
    d2 = SyntheticLM(tiny_dense, run, seed=5).batch(3)
    np.testing.assert_array_equal(np.asarray(d1["tokens"]),
                                  np.asarray(d2["tokens"]))
    d3 = SyntheticLM(tiny_dense, run, seed=5).batch(4)
    assert not np.array_equal(np.asarray(d1["tokens"]),
                              np.asarray(d3["tokens"]))


def test_labels_are_next_tokens(tiny_dense):
    run = _run_cfg(tiny_dense)
    b = SyntheticLM(tiny_dense, run, seed=1).batch(0)
    np.testing.assert_array_equal(np.asarray(b["tokens"][:, 1:]),
                                  np.asarray(b["labels"][:, :-1]))

"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (us_per_call is CPU wall time
where meaningful, 0.0 for pure-accuracy rows) and writes JSON artifacts to
artifacts/bench/ consumed by EXPERIMENTS.md.

  fig6  - ideal-mapping accuracy (finite OPA gain), step cascade
  fig7  - device variation, Wishart/Toeplitz, 40 sims
  fig8  - two-stage solver
  fig9  - variation + interconnect resistance (cheap-vs-oracle columns;
          --wire-oracle prices every column with the exact nodal model)
  fig9_oracle - opt-in n >= 64 exact-MNA sweep (nightly artifact)
  fig10 - area/power breakdown + macro timing model
  hybrid, distributed, kernels - beyond-figure system benchmarks
  engine - serving-engine SLOs under open-loop Poisson traffic, with and
           without a scripted chaos schedule (report-only keys)
  router - replicated-fleet SLOs + replica-loss recovery: checkpoint
           restore vs full re-programming (report-only keys)
  maint  - drift self-healing availability (scrub vs reactive) + block
           repair vs full re-program cost ratio (report-only keys)
  grad   - differentiable solver: backward-vs-forward marginal cost of the
           implicit-diff VJP + wire-calibration convergence curve

Fast mode (default): fewer Monte-Carlo sims and capped sizes so the suite
finishes in minutes on one CPU core; --paper runs the full 40-sim, 512-size
protocol.
"""
from __future__ import annotations

import argparse
import os
import sys

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from benchmarks import (common, distributed_solver, engine_bench,
                        fig6_accuracy, fig7_variation, fig8_twostage,
                        fig9_interconnect, fig10_area_power, grad_bench,
                        hybrid_refinement, kernel_bench, maint_bench,
                        router_bench)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--paper", action="store_true",
                    help="full 40-sim protocol up to 512x512")
    ap.add_argument("--only", default=None,
                    help="comma-separated subset, e.g. fig6,fig10")
    ap.add_argument("--smoke", action="store_true",
                    help="CI smoke mode: tiniest configs, <1 min per suite")
    ap.add_argument("--bench-warmup", type=int, default=None,
                    help="warmup calls before timing (default %d)"
                         % common.TIMED_WARMUP)
    ap.add_argument("--bench-iters", type=int, default=None,
                    help="timed calls per median (default %d)"
                         % common.TIMED_ITERS)
    ap.add_argument("--bench-tenants", type=int, default=None,
                    help="tenant count for the multi-tenant packed bench "
                         "(default: 4 in smoke mode, 4 and 16 otherwise)")
    ap.add_argument("--wire-oracle", action="store_true",
                    help="price interconnect with the exact nodal MNA "
                         "oracle (repro.physics) instead of the first-order "
                         "model, at every fig9 size and column")
    args = ap.parse_args()

    if args.wire_oracle:
        fig9_interconnect.WIRE_ORACLE = True

    if args.bench_warmup is not None:
        common.TIMED_WARMUP = args.bench_warmup
    if args.bench_iters is not None:
        common.TIMED_ITERS = args.bench_iters
    if args.bench_tenants is not None:
        kernel_bench.TENANTS = (args.bench_tenants,)

    if args.paper:
        hybrid_refinement.N = hybrid_refinement.N_PAPER
    if not args.paper:
        common.N_SIMS_PAPER = 8
        common.SIZES_PAPER = (8, 16, 32, 64, 128, 256)
        fig7_variation.N_SIMS_PAPER = 8
        fig7_variation.SIZES_PAPER = common.SIZES_PAPER
        fig8_twostage.N_SIMS_PAPER = 8
        fig8_twostage.SIZES = (64, 128, 256)
        fig9_interconnect.N_SIMS_PAPER = 8
        fig9_interconnect.SIZES = (16, 32, 64, 128)
        fig9_interconnect.ORACLE_SIZES = (64, 128)
        fig6_accuracy.SIZES_PAPER = common.SIZES_PAPER

    if args.smoke:            # after fast-mode defaults: smoke tightens them
        kernel_bench.SMOKE = True
        hybrid_refinement.SMOKE = True
        engine_bench.SMOKE = True
        grad_bench.SMOKE = True
        router_bench.SMOKE = True
        maint_bench.SMOKE = True
        common.N_SIMS_PAPER = 4
        common.SIZES_PAPER = (8, 16, 32, 64)
        fig7_variation.N_SIMS_PAPER = 4
        fig7_variation.SIZES_PAPER = common.SIZES_PAPER
        fig8_twostage.N_SIMS_PAPER = 4
        fig8_twostage.SIZES = (64,)
        fig9_interconnect.N_SIMS_PAPER = 4
        fig9_interconnect.SIZES = (16, 32)
        fig9_interconnect.ORACLE_SIZES = (64,)
        fig9_interconnect.ORACLE_SIMS = 2
        fig6_accuracy.SIZES_PAPER = common.SIZES_PAPER

    suites = {
        "fig6": fig6_accuracy.main,
        "fig7": fig7_variation.main,
        "fig8": fig8_twostage.main,
        "fig9": fig9_interconnect.main,
        "fig9_oracle": fig9_interconnect.oracle_main,
        "fig10": fig10_area_power.main,
        "hybrid": hybrid_refinement.main,
        "distributed": distributed_solver.main,
        "kernels": kernel_bench.main,
        "engine": engine_bench.main,
        "grad": grad_bench.main,
        "router": router_bench.main,
        "maint": maint_bench.main,
    }
    # fig9_oracle is opt-in (--only): the exact-MNA sweep at n >= 64 is a
    # nightly artifact, too heavy for the default minutes-long suite.
    default = [s for s in suites if s != "fig9_oracle"]
    chosen = (args.only.split(",") if args.only else default)
    print("name,us_per_call,derived")
    for name in chosen:
        suites[name]()


if __name__ == "__main__":
    main()

"""Differentiable-solver benchmarks: backward cost + calibration curve.

Two artifacts feed artifacts/bench/grad.json (TESTING.md "differentiable
solver contract"):

  * backward-vs-forward marginal cost of the arena executor's implicit-diff
    VJP.  The contract is backward <= 1.5x forward: the VJP is one
    transposed cascade (same shared-stack batched dots as the forward, no
    re-factorization, no re-programming), so a value-and-grad call costs
    about one extra forward solve.  `fwd_us` / `grad_us` are gated by the
    nightly diff_bench 25% rolling-regression rule; the ratio itself is a
    report-only key (no `_us` suffix) since it divides two noisy medians.

  * wire-calibration convergence: loss and r_hat trajectories of
    `repro.calib.calibrate_wire` recovering a planted 1 Ohm from the exact
    nodal oracle, plus the final relative recovery error (acceptance:
    < 5%).  Report-only keys - accuracy, not wall time.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import csv_row, save_json, timed
from repro.calib import calibrate_wire
from repro.core import blockamc
from repro.core.analog import AnalogConfig
from repro.core.nonideal import NonidealConfig

SMOKE = False


def _problem(n: int):
    ka, kb, kn = jax.random.split(jax.random.PRNGKey(5), 3)
    a = jax.random.normal(ka, (n, n), jnp.float32)
    a = a @ a.T + n * jnp.eye(n, dtype=jnp.float32)
    b = jax.random.normal(kb, (n,), jnp.float32)
    return a, b, kn


def backward_cost_bench(out):
    """Jitted forward solve vs jitted value-and-grad through the arena."""
    sizes = (32,) if SMOKE else (32, 64)
    for n in sizes:
        a, b, kn = _problem(n)
        cfg = AnalogConfig(array_size=n // 4,
                           nonideal=NonidealConfig(sigma=0.05, r_wire=1.0))
        solver = blockamc.ProgrammedSolver.program(a, kn, cfg, stages=2)
        ap = solver.arena

        fwd = jax.jit(lambda bb: blockamc.execute_arena(ap, bb))
        vag = jax.jit(jax.value_and_grad(
            lambda bb: jnp.sum(blockamc.execute_arena(ap, bb))))

        fwd_us = timed(fwd, b)
        grad_us = timed(vag, b)
        # marginal backward cost in units of one forward solve; the
        # forward inside value_and_grad is re-paid, so the pure backward
        # increment is (grad - fwd) / fwd
        marginal = max(grad_us - fwd_us, 0.0) / fwd_us
        csv_row(f"grad_arena_n{n}", grad_us,
                f"fwd={fwd_us:.1f}us;marginal_bwd={marginal:.2f}x_fwd")
        out[f"arena_n{n}"] = {
            "fwd_us": fwd_us,
            "grad_us": grad_us,
            "marginal_bwd_over_fwd": marginal,   # report-only ratio
        }


def calibration_bench(out):
    """Wire-recovery convergence curve (accuracy artifact, report-only)."""
    n = 8 if SMOKE else 16
    steps = 60 if SMOKE else 120
    ka = jax.random.PRNGKey(9)
    a = jax.random.normal(ka, (n, n), jnp.float64 if
                          jax.config.jax_enable_x64 else jnp.float32)
    a = a @ a.T + n * jnp.eye(n, dtype=a.dtype)
    cal = calibrate_wire(a, r_true=1.0, steps=steps)
    rel = cal.rel_err(1.0)
    csv_row(f"grad_calib_n{n}", 0.0,
            f"steps={steps};r_hat={cal.r_hat:.4f};rel_err={rel:.4f}")
    # thin the curves to ~20 points so the artifact stays small
    stride = max(1, steps // 20)
    out[f"calib_n{n}"] = {
        "steps": steps,
        "r_true": 1.0,
        "r_hat": cal.r_hat,
        "rel_err": rel,
        "loss_curve": list(cal.history[::stride]) + [cal.history[-1]],
        "r_curve": list(cal.r_history[::stride]) + [cal.r_history[-1]],
    }


def main() -> None:
    out = {}
    backward_cost_bench(out)
    calibration_bench(out)
    save_json("grad", out)


if __name__ == "__main__":
    main()

"""Paper Fig. 9: variation + interconnect resistance (1 ohm/segment).

BlockAMC (one- and two-stage) vs original AMC, Wishart + Toeplitz.  Paper
claims up to ~10% relative-error reduction for one-stage and a further
improvement for two-stage (smaller arrays => shorter wire paths).

Two wire models price the interconnect (see tests/test_wire_validation.py
for the pinned envelope between them):

  * "first_order" - the O(n^2) perturbation used on the hot path;
  * "nodal"       - the exact batched MNA solve (repro.physics.nodal).

`run()` records cheap-vs-oracle columns (`*_nodal` medians + `model_gap`)
for sizes up to ORACLE_MAX_N; setting WIRE_ORACLE (run.py --wire-oracle)
switches *every* size and column to the nodal oracle instead.  The
separate `oracle_main()` suite (run.py --only fig9_oracle; nightly) sweeps
the n >= 64 regime where the first-order model leaves its validity
envelope and writes artifacts/bench/fig9_oracle.json with matrix-level
H-gap metrics plus solve-level medians under both models.  Metric keys
deliberately avoid the `_us`/`_s`/`speedup` timing suffixes so
diff_bench.py reports them without gating (accuracy deltas between
nightlies are expected as seeds move).
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import (N_SIMS_PAPER, csv_row, mc_errors, save_json)
from repro.core.analog import AnalogConfig
from repro.core.nonideal import NonidealConfig

SIZES = (16, 32, 64, 128, 256, 512)
# Record the *_nodal oracle columns for sizes up to this (per-tile nodal
# readout is O(tile^4); above it the cheap model is the only affordable
# option in the fast suite - the nightly oracle sweep covers the rest).
ORACLE_MAX_N = 64
WIRE_ORACLE = False           # run.py --wire-oracle: oracle for ALL columns

ORACLE_SIZES = (64, 128, 256)
ORACLE_SIMS = 4


def _ni(sigma=0.05, model="first_order", **kw):
    return NonidealConfig(sigma=sigma, r_wire=1.0, wire_model=model, **kw)


def run(n_sims=None):
    # resolve at call time so run.py's fast-mode overrides stick
    n_sims = N_SIMS_PAPER if n_sims is None else n_sims
    base_model = "nodal" if WIRE_ORACLE else "first_order"
    ni = _ni(model=base_model)
    ni_comp = _ni(model=base_model, compensate_wire=True)
    out = {"wire_model": base_model}
    for family in ("wishart", "toeplitz"):
        rows = []
        for n in SIZES:
            cfg1 = AnalogConfig(array_size=max(n // 2, 4), nonideal=ni)
            cfg2 = AnalogConfig(array_size=max(n // 4, 4), nonideal=ni)
            cfgc = AnalogConfig(array_size=max(n // 2, 4), nonideal=ni_comp)
            e1 = mc_errors(family, n, cfg1, "blockamc", n_sims, stages=1)
            e2 = mc_errors(family, n, cfg2, "blockamc", n_sims, stages=2)
            ec = mc_errors(family, n, cfgc, "blockamc", n_sims, stages=1)
            eo = mc_errors(family, n, cfg1, "original", n_sims)
            row = {"n": n,
                   "one_stage_median": float(np.median(e1)),
                   "two_stage_median": float(np.median(e2)),
                   "one_stage_compensated": float(np.median(ec)),
                   "orig_median": float(np.median(eo))}
            if not WIRE_ORACLE and n <= ORACLE_MAX_N:
                # cheap-vs-oracle differential columns (same seeds)
                cfg1n = AnalogConfig(array_size=max(n // 2, 4),
                                     nonideal=_ni(model="nodal"))
                e1n = mc_errors(family, n, cfg1n, "blockamc", n_sims,
                                stages=1)
                med = float(np.median(e1n))
                row["one_stage_nodal"] = med
                row["model_gap"] = abs(row["one_stage_median"] - med) / med
            rows.append(row)
        out[family] = rows
    return out


def main():
    out = run()
    save_json("fig9_interconnect", out)
    for family in ("wishart", "toeplitz"):
        rows = out[family]
        r = rows[-1]
        red1 = (r["orig_median"] - r["one_stage_median"]) / r["orig_median"]
        red2 = (r["orig_median"] - r["two_stage_median"]) / r["orig_median"]
        csv_row(f"fig9_{family}_n{r['n']}", 0.0,
                f"orig={r['orig_median']:.3f};one={r['one_stage_median']:.3f};"
                f"two={r['two_stage_median']:.3f};red1={red1:.1%};red2={red2:.1%}")
        csv_row(f"fig9_{family}_compensated", 0.0,
                f"one={r['one_stage_median']:.3f};"
                f"one_comp={r['one_stage_compensated']:.3f} "
                f"(ref [29] write-verify mitigation)")
        with_gap = [x for x in rows if "model_gap" in x]
        if with_gap:
            g = with_gap[-1]
            csv_row(f"fig9_{family}_model_gap_n{g['n']}", 0.0,
                    f"first_order={g['one_stage_median']:.4f};"
                    f"nodal={g['one_stage_nodal']:.4f};"
                    f"gap={g['model_gap']:.1%}")
    return out


# ------------------- nightly oracle sweep (fig9_oracle) ---------------------

def oracle_sweep(sizes=None, n_sims=None):
    """n >= 64 differential sweep: matrix-level H-gap between the wire
    models plus solve-level medians under each, per size."""
    import jax
    import jax.numpy as jnp
    from jax.experimental import enable_x64

    from repro.core import nonideal as ni_mod
    from repro.physics import nodal_effective_conductance

    sizes = ORACLE_SIZES if sizes is None else sizes
    n_sims = ORACLE_SIMS if n_sims is None else n_sims
    g0 = 100e-6
    rows = []
    for n in sizes:
        rng = np.random.default_rng(n)
        g_np = rng.uniform(0.0, 0.5, (n, n)) * g0
        with enable_x64():
            g = jnp.asarray(g_np, dtype=jnp.float64)
            h = nodal_effective_conductance(g, 1.0)
            h_fo = ni_mod.effective_conductance(g, 1.0)
            effect = float(jnp.linalg.norm(h - g))
            gap = float(jnp.linalg.norm(h_fo - h))
            g_norm = float(jnp.linalg.norm(g))
        row = {"n": n,
               "h_gap_rel_to_effect": gap / effect,
               "wire_effect_rel": effect / g_norm}
        for model in ("first_order", "nodal"):
            cfg = AnalogConfig(array_size=max(n // 2, 4),
                               nonideal=_ni(model=model))
            errs = mc_errors("wishart", n, cfg, "blockamc", n_sims,
                             stages=1)
            row[f"median_err_{model}"] = float(np.median(errs))
        row["solve_model_gap"] = abs(
            row["median_err_first_order"] - row["median_err_nodal"]
        ) / row["median_err_nodal"]
        rows.append(row)
    return {"r_wire": 1.0, "rows": rows}


def oracle_main():
    out = oracle_sweep()
    save_json("fig9_oracle", out)
    for r in out["rows"]:
        csv_row(f"fig9_oracle_n{r['n']}", 0.0,
                f"h_gap={r['h_gap_rel_to_effect']:.2%};"
                f"fo={r['median_err_first_order']:.4f};"
                f"nodal={r['median_err_nodal']:.4f};"
                f"solve_gap={r['solve_model_gap']:.1%}")
    return out


if __name__ == "__main__":
    main()

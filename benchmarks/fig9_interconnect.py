"""Paper Fig. 9: variation + interconnect resistance (1 ohm/segment).

BlockAMC (one- and two-stage) vs original AMC, Wishart + Toeplitz.  Paper
claims up to ~10% relative-error reduction for one-stage and a further
improvement for two-stage (smaller arrays => shorter wire paths).
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import (N_SIMS_PAPER, csv_row, mc_errors, save_json)
from repro.core.analog import AnalogConfig
from repro.core.nonideal import NonidealConfig

SIZES = (16, 32, 64, 128, 256, 512)


def run(n_sims=None):
    # resolve at call time so run.py's fast-mode overrides stick
    n_sims = N_SIMS_PAPER if n_sims is None else n_sims
    ni = NonidealConfig(sigma=0.05, r_wire=1.0)
    ni_comp = NonidealConfig(sigma=0.05, r_wire=1.0, compensate_wire=True)
    out = {}
    for family in ("wishart", "toeplitz"):
        rows = []
        for n in SIZES:
            cfg1 = AnalogConfig(array_size=max(n // 2, 4), nonideal=ni)
            cfg2 = AnalogConfig(array_size=max(n // 4, 4), nonideal=ni)
            cfgc = AnalogConfig(array_size=max(n // 2, 4), nonideal=ni_comp)
            e1 = mc_errors(family, n, cfg1, "blockamc", n_sims, stages=1)
            e2 = mc_errors(family, n, cfg2, "blockamc", n_sims, stages=2)
            ec = mc_errors(family, n, cfgc, "blockamc", n_sims, stages=1)
            eo = mc_errors(family, n, cfg1, "original", n_sims)
            rows.append({"n": n,
                         "one_stage_median": float(np.median(e1)),
                         "two_stage_median": float(np.median(e2)),
                         "one_stage_compensated": float(np.median(ec)),
                         "orig_median": float(np.median(eo))})
        out[family] = rows
    return out


def main():
    out = run()
    save_json("fig9_interconnect", out)
    for family, rows in out.items():
        r = rows[-1]
        red1 = (r["orig_median"] - r["one_stage_median"]) / r["orig_median"]
        red2 = (r["orig_median"] - r["two_stage_median"]) / r["orig_median"]
        csv_row(f"fig9_{family}_n{r['n']}", 0.0,
                f"orig={r['orig_median']:.3f};one={r['one_stage_median']:.3f};"
                f"two={r['two_stage_median']:.3f};red1={red1:.1%};red2={red2:.1%}")
        csv_row(f"fig9_{family}_compensated", 0.0,
                f"one={r['one_stage_median']:.3f};"
                f"one_comp={r['one_stage_compensated']:.3f} "
                f"(ref [29] write-verify mitigation)")
    return out


if __name__ == "__main__":
    main()

"""Diff two benchmark-artifact directories (nightly perf trajectory).

    python benchmarks/diff_bench.py BASELINE_DIR CURRENT_DIR [--out diff.md]

Flattens every `*.json` in both directories to dotted numeric paths and
reports, per metric, the old value, new value and relative change; metrics
whose |relative change| exceeds the report threshold are flagged.

The nightly additionally *gates*: direction-aware regressions beyond
--gate-threshold (default 25%, far above runner noise at the default
warmup+median timing protocol) make the script exit nonzero so the job
fails instead of silently accumulating a slowdown.  A metric counts as a
regression when a time-like value (`*_us`, `*_s`, `us_per_call`) grows or
a `speedup`-like value shrinks; accuracy/config metrics only ever report.
The rule is name-based, so new serving-path keys gate automatically - the
multi-tenant `packed_*` entries (speedup_flush / speedup_program /
flush_all_us ...) entered the rolling baseline the first nightly after
they landed.  --no-gate restores report-only behaviour.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
from typing import Dict, List, Tuple


def _flatten(obj, prefix="") -> Dict[str, float]:
    out: Dict[str, float] = {}
    if isinstance(obj, dict):
        for k, v in obj.items():
            out.update(_flatten(v, f"{prefix}.{k}" if prefix else str(k)))
    elif isinstance(obj, (list, tuple)):
        for i, v in enumerate(obj):
            out.update(_flatten(v, f"{prefix}[{i}]"))
    elif isinstance(obj, bool):
        out[prefix] = float(obj)
    elif isinstance(obj, (int, float)):
        out[prefix] = float(obj)
    return out


def _load_dir(path: str) -> Dict[str, Dict[str, float]]:
    out = {}
    if not os.path.isdir(path):
        return out
    for name in sorted(os.listdir(path)):
        if name.endswith(".json"):
            try:
                with open(os.path.join(path, name)) as f:
                    out[name] = _flatten(json.load(f))
            except (json.JSONDecodeError, OSError) as e:
                print(f"warning: skipping {name}: {e}", file=sys.stderr)
    return out


def _regression_direction(key: str) -> int:
    """+1 if larger is worse (times), -1 if smaller is worse (speedups),
    0 if the metric has no gating direction (accuracy, configs, flags).

    Ratio-of-times metrics like `amortization` (= ttfs/marginal) are
    deliberately ungated: both numerator and denominator are themselves
    gated times, and a pure programming-time *improvement* shrinks the
    ratio - gating it would fail the nightly on a strict win.  Single-shot
    measurements (`time_to_first_solve_us`: one perf_counter sample around
    plan build + jit compile, outside the warmup+median protocol the 25%
    threshold is calibrated for) are report-only as well.
    """
    leaf = key.rsplit(".", 1)[-1].lower()
    if "amortization" in leaf or "time_to_first_solve" in leaf:
        return 0
    if "speedup" in leaf:
        return -1
    if leaf.endswith("_us") or leaf.endswith("_s") or leaf == "us_per_call":
        return +1
    return 0


def diff(baseline_dir: str, current_dir: str, threshold: float = 0.10,
         gate_threshold: float = 0.25
         ) -> Tuple[str, List[str]]:
    """Returns (markdown report, list of gated regression descriptions)."""
    base = _load_dir(baseline_dir)
    cur = _load_dir(current_dir)
    lines = ["# Bench diff", "",
             f"baseline: `{baseline_dir}`  current: `{current_dir}`", ""]
    regressions: List[str] = []
    if not base:
        lines.append("_no baseline artifacts (first nightly run?) - "
                     "nothing to diff_")
    for name in sorted(set(base) | set(cur)):
        if name not in base:
            lines.append(f"## {name}: NEW (no baseline)")
            continue
        if name not in cur:
            lines.append(f"## {name}: MISSING from current run")
            continue
        b, c = base[name], cur[name]
        flagged, changed = [], 0
        for key in sorted(set(b) | set(c)):
            if key not in b or key not in c:
                flagged.append(f"- `{key}`: "
                               f"{'added' if key not in b else 'removed'}")
                continue
            if b[key] == c[key]:
                continue
            changed += 1
            rel = ((c[key] - b[key]) / abs(b[key])) if b[key] else float("inf")
            if abs(rel) >= threshold:
                flagged.append(f"- `{key}`: {b[key]:g} -> {c[key]:g} "
                               f"({rel:+.1%})")
            direction = _regression_direction(key)
            if direction and rel * direction >= gate_threshold:
                regressions.append(f"{name}:{key}: {b[key]:g} -> {c[key]:g} "
                                   f"({rel:+.1%})")
        lines.append(f"## {name}: {changed} metric(s) changed, "
                     f"{len(flagged)} flagged (>= {threshold:.0%})")
        lines.extend(flagged)
    if regressions:
        lines += ["", f"## GATED REGRESSIONS (>= {gate_threshold:.0%}, "
                      f"direction-aware)"]
        lines += [f"- {r}" for r in regressions]
    return "\n".join(lines) + "\n", regressions


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("baseline_dir")
    ap.add_argument("current_dir")
    ap.add_argument("--threshold", type=float, default=0.10,
                    help="relative change that gets flagged (default 10%%)")
    ap.add_argument("--gate-threshold", type=float, default=0.25,
                    help="direction-aware regression that fails the run "
                         "(default 25%%)")
    ap.add_argument("--no-gate", action="store_true",
                    help="report only; never exit nonzero")
    ap.add_argument("--out", default=None, help="also write the report here")
    args = ap.parse_args()
    report, regressions = diff(args.baseline_dir, args.current_dir,
                               args.threshold, args.gate_threshold)
    print(report)
    if args.out:
        os.makedirs(os.path.dirname(os.path.abspath(args.out)), exist_ok=True)
        with open(args.out, "w") as f:
            f.write(report)
    if regressions and not args.no_gate:
        print(f"FAIL: {len(regressions)} gated regression(s) "
              f">= {args.gate_threshold:.0%}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
